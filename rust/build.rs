//! Embeds a fingerprint of the crate's own source into the build as
//! `POCLRS_BUILD_ID`. The persistent kernel cache folds it into every
//! on-disk key (see `cache::key`), so artifacts compiled by a *different
//! build of the compiler* — e.g. after editing a `kcc` pass without
//! bumping any version — can never be served (pocl hashes its build into
//! `POCL_CACHE_DIR` keys for exactly this reason). The fingerprint is a
//! content hash, not a timestamp: identical sources produce identical
//! ids, so the cache survives clean rebuilds and is shared across
//! machines building the same code.
//!
//! No `cargo:rerun-if` directives are emitted on purpose: cargo then
//! re-runs this script whenever any file in the package changes, which
//! is precisely when the fingerprint must be recomputed.

use std::fs;
use std::path::{Path, PathBuf};

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    if let Ok(rd) = fs::read_dir(dir) {
        for entry in rd.flatten() {
            let p = entry.path();
            if p.is_dir() {
                collect_rs(&p, out);
            } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                out.push(p);
            }
        }
    }
}

fn main() {
    let mut files = Vec::new();
    collect_rs(Path::new("src"), &mut files);
    files.sort();
    let mut h = FNV_OFFSET;
    for f in &files {
        h = fnv_bytes(h, f.to_string_lossy().as_bytes());
        if let Ok(bytes) = fs::read(f) {
            h = fnv_bytes(h, &bytes);
        }
    }
    println!("cargo:rustc-env=POCLRS_BUILD_ID={h:016x}");
}
