//! Lane-batched (structure-of-arrays) gang executor — the vector mapping
//! stage that finally turns the compiler's retained data-parallelism into
//! throughput.
//!
//! Where [`super::gang`] emulates lockstep by dispatching every
//! instruction once **per lane**, this engine dispatches once **per
//! gang**: each instruction of a parallel region is evaluated over
//! [`VLane`] values holding all `W` lanes at once (`RealVec64`-backed for
//! varying floats, packed arrays for ints/pointers — the §5 vecmath layer
//! finally has a consumer on the execution path). Lane-invariant values
//! stay in the scalar `Uni` form and are computed once per gang. This
//! dynamic lattice is the runtime realisation of the §4.6 uniformity
//! analysis: everything the static exports
//! (`WorkGroupFunction::reg_uniform` / `region_divergent`) prove uniform
//! is guaranteed to stay in `Uni` form here, and the interpreter's
//! value-level view additionally uniforms what the static analysis must
//! conservatively call varying (e.g. same-valued global loads). An AOT
//! vectoriser, which has no runtime values, would consume the static
//! exports directly; this engine's counters (`uniform_insts`) are the
//! measurable check that the exports are not vacuous.
//! Divergent branches fall back to the masked per-lane path until the
//! region's closing barrier, exactly like the scalar gang engine (and
//! like a real vectoriser's scalarised path); ragged tail gangs
//! (`wg_size % W` lanes) always run per-lane.
//!
//! The result: on uniform-control kernels the interpreter dispatch count
//! drops by ~`W`× vs the scalar gang (see [`GangStats::dispatches`] and
//! the `BENCH_engines` snapshot) — the Fig. 12 throughput story the paper
//! tells for SIMD targets, now measurable in this repo.

use crate::cl::error::{Error, Result};
use crate::ir::func::Function;
use crate::ir::inst::{BinOp, BlockId, Imm, Inst, MathFn, Operand, Reg, SlotId, Term, UnOp, WiFn};
use crate::ir::types::{Scalar, Type};
use crate::kcc::WorkGroupFunction;
use crate::vecmath::{RealVec, RealVec64};

use super::gang::{note_barrier, run_lane_to_barrier, GangStats};
use super::interp::{
    bin_scalar, eval_bin, eval_cast, eval_math, eval_un, norm_val, normalize_to, wi_value,
    LaunchCtx, SlotStore,
};
use super::mem::MemoryRefs;
use super::value::{norm_float, norm_int, Val, VLane, VVal, SP_PRIVATE};

/// Gang widths the engine is monomorphised for (4 ≈ NEON/AltiVec, 8 ≈
/// AVX2, 16 ≈ AVX-512; 2 covers f64 on 128-bit SIMD). Other widths fall
/// back to the per-lane gang engine.
pub const SUPPORTED_WIDTHS: &[usize] = &[2, 4, 8, 16];

/// Execute one work-group in lane-batched gangs of `width` lanes.
///
/// Widths outside [`SUPPORTED_WIDTHS`] degrade gracefully to the per-lane
/// [`super::gang`] engine rather than failing the launch.
pub fn run_workgroup(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    width: usize,
) -> Result<GangStats> {
    match width {
        2 => run_wg::<2>(wgf, args, mem, ctx),
        4 => run_wg::<4>(wgf, args, mem, ctx),
        8 => run_wg::<8>(wgf, args, mem, ctx),
        16 => run_wg::<16>(wgf, args, mem, ctx),
        _ => super::gang::run_workgroup(wgf, args, mem, ctx, width),
    }
}

/// Lane-batched private-variable storage: one [`VLane`] cell per scalar
/// cell of the scalar engines' `SlotStore`, same layout. Shared with the
/// bytecode engine, which keeps gang state in exactly this form so its
/// per-region fallback into this engine is free.
pub(crate) struct VecStore<const W: usize> {
    /// Cell values (uniform or per-lane).
    pub(crate) cells: Vec<VLane<W>>,
    /// Slot → first cell index.
    pub(crate) base: Vec<u32>,
}

impl<const W: usize> VecStore<W> {
    pub(crate) fn for_function(f: &Function) -> VecStore<W> {
        let mut base = Vec::with_capacity(f.slots.len());
        let mut total = 0u32;
        for s in &f.slots {
            base.push(total);
            total += s.count as u32;
        }
        VecStore { cells: vec![VLane::Uni(VVal::i(0)); total as usize], base }
    }

    pub(crate) fn slot_base(&self, s: SlotId) -> u64 {
        self.base[s.0 as usize] as u64
    }

    /// Flatten to one scalar store per lane (divergence fallback entry).
    pub(crate) fn split(&self) -> Vec<SlotStore> {
        (0..W)
            .map(|l| SlotStore {
                cells: self.cells.iter().map(|c| c.get(l)).collect(),
                base: self.base.clone(),
            })
            .collect()
    }

    /// Re-import per-lane stores after reconvergence; identical lanes
    /// (bitwise) collapse back to the uniform form.
    pub(crate) fn merge(&mut self, stores: &[SlotStore]) {
        for (i, cell) in self.cells.iter_mut().enumerate() {
            let lanes: Vec<VVal> = stores.iter().map(|s| s.cells[i].clone()).collect();
            *cell = VLane::from_lanes(lanes);
        }
    }
}

/// Per-gang persistent state: private cells plus the lanes' local ids.
pub(crate) struct GangState<const W: usize> {
    pub(crate) store: VecStore<W>,
    pub(crate) local_ids: [[u64; 3]; W],
}

/// The lane-batched instruction evaluator: a register frame of [`VLane`]
/// values bound to uniform argument values and launch geometry.
struct VecMachine<'a, const W: usize> {
    regs: Vec<VLane<W>>,
    args: &'a [VVal],
    ctx: &'a LaunchCtx,
    local_ids: [[u64; 3]; W],
}

fn run_wg<const W: usize>(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
) -> Result<GangStats> {
    let f = &wgf.reg_fn;
    let n = wgf.wg_size();
    let [lx, ly, _lz] = wgf.local_size;
    let mut stats = GangStats::default();

    let local_id = |wi: usize| -> [u64; 3] {
        [(wi % lx) as u64, ((wi / lx) % ly) as u64, (wi / (lx * ly)) as u64]
    };

    // The gang partition is fixed for the whole launch: full-width gangs
    // run lane-batched, a ragged tail (n % W work-items) runs per-lane.
    // Private state persists across regions per gang / per tail lane.
    let full_gangs = n / W;
    let mut gangs: Vec<GangState<W>> = (0..full_gangs)
        .map(|g| GangState {
            store: VecStore::for_function(f),
            local_ids: std::array::from_fn(|l| local_id(g * W + l)),
        })
        .collect();
    let mut tail: Vec<(SlotStore, [u64; 3])> = (full_gangs * W..n)
        .map(|wi| (SlotStore::for_function(f), local_id(wi)))
        .collect();

    // Walk barriers exactly like the scalar gang engine: all work-items
    // sit at `cur`; every gang executes the region to the next barrier.
    let mut cur: BlockId = f.entry;
    loop {
        let block = f.block(cur);
        debug_assert!(block.has_barrier());
        let start = match &block.term {
            Term::Ret => return Ok(stats),
            Term::Jump(s) => *s,
            Term::Br { .. } => return Err(Error::exec("barrier block with branch terminator")),
        };
        let mut next_barrier: Option<BlockId> = None;
        for gang in gangs.iter_mut() {
            stats.gangs += 1;
            let reached = run_gang_region_vec(f, args, mem, ctx, gang, start, &mut stats)?;
            note_barrier(&mut next_barrier, reached, "across gangs")?;
        }
        if !tail.is_empty() {
            stats.gangs += 1;
        }
        for (store, lid) in tail.iter_mut() {
            let reached = run_lane_to_barrier(f, args, mem, ctx, store, start, *lid, &mut stats)?;
            note_barrier(&mut next_barrier, reached, "across gangs")?;
        }
        cur = next_barrier.expect("work-group is non-empty");
    }
}

/// Run one gang through one region (from `start` to the next barrier
/// block), lane-batched until divergence; on a divergent branch the gang
/// flushes its state to per-lane stores and finishes the region with the
/// masked per-lane path, then re-imports (re-uniforming identical lanes).
/// Also the bytecode engine's per-region fallback for uncovered regions.
pub(crate) fn run_gang_region_vec<const W: usize>(
    f: &Function,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    gang: &mut GangState<W>,
    start: BlockId,
    stats: &mut GangStats,
) -> Result<BlockId> {
    let mut vm = VecMachine::<W> {
        regs: vec![VLane::Uni(VVal::i(0)); f.reg_count() as usize],
        args,
        ctx,
        local_ids: gang.local_ids,
    };
    let mut cur = start;
    loop {
        if f.block(cur).has_barrier() {
            return Ok(cur);
        }
        for (def, inst) in &f.block(cur).insts {
            vm.eval_inst(def, inst, &mut gang.store, mem, stats)?;
        }
        match &f.block(cur).term {
            Term::Jump(t) => cur = *t,
            Term::Ret => return Err(Error::exec("unexpected ret inside region")),
            Term::Br { cond, t, f: fb } => {
                let (tv, fv) = (*t, *fb);
                let c = vm.op_val(cond, &gang.store);
                if let VLane::Uni(v) = &c {
                    // Uniform condition (the common, compiler-predicted
                    // case): one branch decision for the whole gang.
                    cur = if v.scalar().truthy() { tv } else { fv };
                    continue;
                }
                let mut lane_targets = [tv; W];
                for (l, tgt) in lane_targets.iter_mut().enumerate() {
                    *tgt = if c.get(l).scalar().truthy() { tv } else { fv };
                }
                if lane_targets.iter().all(|&x| x == lane_targets[0]) {
                    cur = lane_targets[0];
                    continue;
                }
                // Divergence: registers are block-local (IR invariant), so
                // only private cells need flushing to per-lane form.
                stats.diverged += 1;
                let mut stores = gang.store.split();
                let mut reached: Option<BlockId> = None;
                for (l, store) in stores.iter_mut().enumerate() {
                    let bar = run_lane_to_barrier(
                        f,
                        args,
                        mem,
                        ctx,
                        store,
                        lane_targets[l],
                        gang.local_ids[l],
                        stats,
                    )?;
                    note_barrier(&mut reached, bar, "within gang")?;
                }
                gang.store.merge(&stores);
                return Ok(reached.expect("gang is non-empty"));
            }
        }
    }
}

impl<const W: usize> VecMachine<'_, W> {
    /// Operand → lane value. Immediates, arguments and slot bases are
    /// uniform by construction; registers carry whatever the defining
    /// instruction produced.
    fn op_val(&self, op: &Operand, store: &VecStore<W>) -> VLane<W> {
        match op {
            Operand::Reg(r) => self.regs[r.0 as usize].clone(),
            Operand::Imm(Imm::Int(v, s)) => VLane::Uni(VVal::S(Val::I(norm_int(*v, *s)))),
            Operand::Imm(Imm::Float(v, s)) => VLane::Uni(VVal::S(Val::F(norm_float(*v, *s)))),
            Operand::Arg(a) => VLane::Uni(self.args[*a as usize].clone()),
            Operand::Slot(s) => VLane::Uni(VVal::ptr(SP_PRIVATE, store.slot_base(*s))),
        }
    }

    /// Evaluate one instruction for the whole gang.
    fn eval_inst(
        &mut self,
        def: &Option<Reg>,
        inst: &Inst,
        store: &mut VecStore<W>,
        mem: &mut MemoryRefs<'_>,
        stats: &mut GangStats,
    ) -> Result<()> {
        let v = match inst {
            Inst::Barrier { .. } | Inst::Marker { .. } => {
                stats.uniform_insts += 1;
                VLane::Uni(VVal::i(0))
            }
            Inst::Wi { func, dim } => {
                let (v, uniform) = wi_vlane(*func, *dim, self.ctx, &self.local_ids);
                if uniform {
                    stats.uniform_insts += 1;
                } else {
                    stats.vector_insts += 1;
                }
                v
            }
            Inst::Load { ty, ptr } => self.load(ty, ptr, store, mem, stats)?,
            Inst::Store { ty, ptr, val } => {
                self.store_inst(ty, ptr, val, store, mem, stats)?;
                VLane::Uni(VVal::i(0))
            }
            // Fixed-arity pure shapes marshal operands on the stack (the
            // hot path: Bin/Gep dominate region bodies).
            Inst::Bin { a, b, .. } => {
                let ops = [self.op_val(a, store), self.op_val(b, store)];
                eval_pure(inst, &ops, stats)?
            }
            Inst::Gep { base, idx, .. } => {
                let ops = [self.op_val(base, store), self.op_val(idx, store)];
                eval_pure(inst, &ops, stats)?
            }
            Inst::Un { a, .. } => {
                let ops = [self.op_val(a, store)];
                eval_pure(inst, &ops, stats)?
            }
            Inst::Cast { a, .. } => {
                let ops = [self.op_val(a, store)];
                eval_pure(inst, &ops, stats)?
            }
            _ => {
                let ops: Vec<VLane<W>> =
                    inst.operands().iter().map(|o| self.op_val(o, store)).collect();
                eval_pure(inst, &ops, stats)?
            }
        };
        if let Some(r) = def {
            self.regs[r.0 as usize] = v;
        }
        Ok(())
    }

    /// Typed load: uniform addresses load once per gang, varying addresses
    /// gather per lane (private cells gather each lane's own view).
    fn load(
        &self,
        ty: &Type,
        ptr: &Operand,
        store: &VecStore<W>,
        mem: &mut MemoryRefs<'_>,
        stats: &mut GangStats,
    ) -> Result<VLane<W>> {
        let pv = self.op_val(ptr, store);
        if pv.is_uniform() {
            stats.uniform_insts += 1;
        } else {
            stats.vector_insts += 1;
        }
        load_vlane(&pv, ty, store, mem)
    }

    /// Typed store: uniform address+value store once; varying forms
    /// scatter in lane order (lane `W-1` last, matching lockstep).
    fn store_inst(
        &self,
        ty: &Type,
        ptr: &Operand,
        val: &Operand,
        store: &mut VecStore<W>,
        mem: &mut MemoryRefs<'_>,
        stats: &mut GangStats,
    ) -> Result<()> {
        let pv = self.op_val(ptr, store);
        let vv = self.op_val(val, store);
        if pv.is_uniform() && vv.is_uniform() {
            stats.uniform_insts += 1;
        } else {
            stats.vector_insts += 1;
        }
        store_vlane(&pv, &vv, ty, store, mem)
    }
}

/// Typed load kernel (stats-free; callers attribute the dispatch).
pub(crate) fn load_vlane<const W: usize>(
    pv: &VLane<W>,
    ty: &Type,
    store: &VecStore<W>,
    mem: &mut MemoryRefs<'_>,
) -> Result<VLane<W>> {
    match pv {
        VLane::Uni(p) => match p.scalar() {
            Val::Ptr { space: SP_PRIVATE, offset } => store
                .cells
                .get(offset as usize)
                .cloned()
                .ok_or_else(|| Error::exec("private load out of bounds")),
            Val::Ptr { space, offset } => Ok(VLane::Uni(mem.load(space, offset, ty)?)),
            _ => Err(Error::exec("load through non-pointer")),
        },
        VLane::P(SP_PRIVATE, offs) => {
            let mut out = Vec::with_capacity(W);
            for (l, off) in offs.iter().enumerate() {
                let cell = store
                    .cells
                    .get(*off as usize)
                    .ok_or_else(|| Error::exec("private load out of bounds"))?;
                out.push(cell.get(l));
            }
            Ok(VLane::from_lanes(out))
        }
        VLane::P(space, offs) => {
            let mut out = Vec::with_capacity(W);
            for off in offs.iter() {
                out.push(mem.load(*space, *off, ty)?);
            }
            Ok(VLane::from_lanes(out))
        }
        VLane::Lanes(ps) => {
            let mut out = Vec::with_capacity(W);
            for (l, p) in ps.iter().enumerate() {
                match p.scalar() {
                    Val::Ptr { space: SP_PRIVATE, offset } => {
                        let cell = store
                            .cells
                            .get(offset as usize)
                            .ok_or_else(|| Error::exec("private load out of bounds"))?;
                        out.push(cell.get(l));
                    }
                    Val::Ptr { space, offset } => out.push(mem.load(space, offset, ty)?),
                    _ => return Err(Error::exec("load through non-pointer")),
                }
            }
            Ok(VLane::from_lanes(out))
        }
        VLane::F(_) | VLane::I(_) => Err(Error::exec("load through non-pointer")),
    }
}

/// Typed store kernel (stats-free): uniform address+value store once;
/// varying forms scatter in lane order (lane `W-1` last, matching
/// per-lane lockstep order).
pub(crate) fn store_vlane<const W: usize>(
    pv: &VLane<W>,
    vv: &VLane<W>,
    ty: &Type,
    store: &mut VecStore<W>,
    mem: &mut MemoryRefs<'_>,
) -> Result<()> {
    match pv {
        VLane::Uni(p) => match p.scalar() {
            Val::Ptr { space: SP_PRIVATE, offset } => {
                let nv = normalize_vlane(vv, ty);
                let cell = store
                    .cells
                    .get_mut(offset as usize)
                    .ok_or_else(|| Error::exec("private store out of bounds"))?;
                *cell = nv;
                Ok(())
            }
            Val::Ptr { space, offset } => {
                // Every lane writes the same address: the last lane's
                // value lands, matching per-lane lockstep order.
                let v = normalize_to(&vv.get(W - 1), ty);
                mem.store(space, offset, ty, &v)
            }
            _ => Err(Error::exec("store through non-pointer")),
        },
        VLane::P(SP_PRIVATE, offs) => {
            for (l, off) in offs.iter().enumerate() {
                let v = normalize_to(&vv.get(l), ty);
                let cell = store
                    .cells
                    .get_mut(*off as usize)
                    .ok_or_else(|| Error::exec("private store out of bounds"))?;
                cell.set_lane(l, v);
            }
            Ok(())
        }
        VLane::P(space, offs) => {
            for (l, off) in offs.iter().enumerate() {
                let v = normalize_to(&vv.get(l), ty);
                mem.store(*space, *off, ty, &v)?;
            }
            Ok(())
        }
        VLane::Lanes(ps) => {
            for (l, p) in ps.iter().enumerate() {
                let v = normalize_to(&vv.get(l), ty);
                match p.scalar() {
                    Val::Ptr { space: SP_PRIVATE, offset } => {
                        let cell = store
                            .cells
                            .get_mut(offset as usize)
                            .ok_or_else(|| Error::exec("private store out of bounds"))?;
                        cell.set_lane(l, v);
                    }
                    Val::Ptr { space, offset } => mem.store(space, offset, ty, &v)?,
                    _ => return Err(Error::exec("store through non-pointer")),
                }
            }
            Ok(())
        }
        VLane::F(_) | VLane::I(_) => Err(Error::exec("store through non-pointer")),
    }
}

/// Evaluate a pure (memory-free) instruction: once if every operand is
/// uniform, else through the SIMD fast paths, else one lane at a time.
fn eval_pure<const W: usize>(
    inst: &Inst,
    ops: &[VLane<W>],
    stats: &mut GangStats,
) -> Result<VLane<W>> {
    if ops.iter().all(|o| o.is_uniform()) {
        stats.uniform_insts += 1;
        let sv: Vec<VVal> = ops.iter().map(|o| o.get(0)).collect();
        return Ok(VLane::Uni(eval_pure_scalar(inst, &sv)?));
    }
    if let Some(v) = eval_fast(inst, ops)? {
        stats.vector_insts += 1;
        return Ok(v);
    }
    stats.vector_insts += 1;
    let mut out = Vec::with_capacity(W);
    for l in 0..W {
        let lane_ops: Vec<VVal> = ops.iter().map(|o| o.get(l)).collect();
        out.push(eval_pure_scalar(inst, &lane_ops)?);
    }
    Ok(VLane::from_lanes(out))
}

/// SIMD fast paths for scalar-typed float/int operations over packed
/// lanes; returns `None` when the generic per-lane path must run.
fn eval_fast<const W: usize>(inst: &Inst, ops: &[VLane<W>]) -> Result<Option<VLane<W>>> {
    match inst {
        Inst::Bin { op, ty, .. } => bin_fast(*op, ty, &ops[0], &ops[1]),
        Inst::Math { func, ty, .. } if ops.len() == 1 => Ok(math_fast(*func, ty, &ops[0])),
        _ => Ok(None),
    }
}

/// SIMD fast path for a scalar-typed binary op over packed lanes (shared
/// with the bytecode engine); `None` when the per-lane path must run.
pub(crate) fn bin_fast<const W: usize>(
    op: BinOp,
    ty: &Type,
    lhs: &VLane<W>,
    rhs: &VLane<W>,
) -> Result<Option<VLane<W>>> {
    if ty.lanes() != 1 {
        return Ok(None);
    }
    let s = ty.elem_scalar().unwrap_or(Scalar::I32);
    use BinOp::*;
    let bitwise = matches!(op, And | Or | Xor | Shl | Shr);
    if s.is_float() && !bitwise {
        let (Some(a), Some(b)) = (as_f_lanes(lhs), as_f_lanes(rhs)) else {
            return Ok(None);
        };
        if matches!(op, Add | Sub | Mul | Div | Rem) {
            let mut r = match op {
                Add => a + b,
                Sub => a - b,
                Mul => a * b,
                Div => a / b,
                _ => {
                    let mut o = a;
                    for (x, y) in o.0.iter_mut().zip(&b.0) {
                        *x %= *y;
                    }
                    o
                }
            };
            if s == Scalar::F32 {
                for x in r.0.iter_mut() {
                    *x = *x as f32 as f64;
                }
            }
            return Ok(Some(VLane::F(r)));
        }
        // Comparisons / logical ops on floats → bool lanes.
        let mut o = [0i64; W];
        for (l, slot) in o.iter_mut().enumerate() {
            let (x, y) = (a.0[l], b.0[l]);
            *slot = match op {
                Eq => (x == y) as i64,
                Ne => (x != y) as i64,
                Lt => (x < y) as i64,
                Le => (x <= y) as i64,
                Gt => (x > y) as i64,
                Ge => (x >= y) as i64,
                LAnd => (x != 0.0 && y != 0.0) as i64,
                LOr => (x != 0.0 || y != 0.0) as i64,
                _ => unreachable!("arith and bitwise handled above"),
            };
        }
        return Ok(Some(VLane::I(o)));
    }
    if !s.is_float() {
        let (Some(a), Some(b)) = (as_scalar_vals(lhs), as_scalar_vals(rhs)) else {
            return Ok(None);
        };
        let mut o = [0i64; W];
        for (l, slot) in o.iter_mut().enumerate() {
            *slot = bin_scalar(op, s, a[l], b[l])?.as_i();
        }
        return Ok(Some(VLane::I(o)));
    }
    Ok(None)
}

/// SIMD fast path for the single-argument float elementals (shared with
/// the bytecode engine); `None` when the per-lane path must run.
pub(crate) fn math_fast<const W: usize>(
    func: MathFn,
    ty: &Type,
    arg: &VLane<W>,
) -> Option<VLane<W>> {
    if ty.lanes() != 1
        || !ty.is_float()
        || !matches!(
            func,
            MathFn::Sqrt
                | MathFn::NativeSqrt
                | MathFn::RSqrt
                | MathFn::NativeRSqrt
                | MathFn::Exp
                | MathFn::NativeExp
                | MathFn::Sin
                | MathFn::NativeSin
                | MathFn::Cos
                | MathFn::NativeCos
                | MathFn::Log
                | MathFn::NativeLog
                | MathFn::Fabs
        )
    {
        return None;
    }
    let a = as_f_lanes(arg)?;
    let s = ty.elem_scalar().unwrap_or(Scalar::F32);
    Some(VLane::F(vec_math(func, s, a)))
}

/// Lane-batched math elementals through the vecmath layer, bit-identical
/// to the scalarised `math_scalar` path of the interpreter.
fn vec_math<const W: usize>(func: MathFn, s: Scalar, a: RealVec64<W>) -> RealVec64<W> {
    use MathFn::*;
    if s == Scalar::F64 {
        return match func {
            Sqrt | NativeSqrt => RealVec64(a.0.map(f64::sqrt)),
            RSqrt | NativeRSqrt => RealVec64(a.0.map(|x| 1.0 / x.sqrt())),
            Exp | NativeExp => a.exp(),
            Sin | NativeSin => a.sin(),
            Cos | NativeCos => a.cos(),
            Log | NativeLog => a.log(),
            Fabs => a.fabs(),
            _ => unreachable!("guarded by eval_fast"),
        };
    }
    match func {
        // f64 ops whose result rounds to f32 (matches `math_scalar`).
        Sqrt | NativeSqrt => RealVec64(a.0.map(|x| x.sqrt() as f32 as f64)),
        RSqrt | NativeRSqrt => RealVec64(a.0.map(|x| (1.0 / x.sqrt()) as f32 as f64)),
        // f32 elementals, lane-for-lane the `scalar32` algorithms.
        _ => {
            let v = RealVec::<W>(a.0.map(|x| x as f32));
            let r = match func {
                Exp | NativeExp => v.exp(),
                Sin | NativeSin => v.sin(),
                Cos | NativeCos => v.cos(),
                Log | NativeLog => v.log(),
                Fabs => v.fabs(),
                _ => unreachable!("guarded by eval_fast"),
            };
            RealVec64(r.0.map(|x| x as f64))
        }
    }
}

/// View a lane value as per-lane `f64`s (the float coercion the scalar
/// machine's `Val::as_f` applies).
fn as_f_lanes<const W: usize>(v: &VLane<W>) -> Option<RealVec64<W>> {
    match v {
        VLane::Uni(VVal::S(x)) => Some(RealVec64([x.as_f(); W])),
        VLane::F(rv) => Some(*rv),
        VLane::I(a) => Some(RealVec64(a.map(|x| x as f64))),
        VLane::P(_, o) => Some(RealVec64(o.map(|x| x as f64))),
        _ => None,
    }
}

/// View a lane value as one scalar [`Val`] per lane.
fn as_scalar_vals<const W: usize>(v: &VLane<W>) -> Option<[Val; W]> {
    match v {
        VLane::Uni(VVal::S(x)) => Some([*x; W]),
        VLane::F(rv) => Some(rv.0.map(Val::F)),
        VLane::I(a) => Some(a.map(Val::I)),
        VLane::P(sp, o) => {
            let sp = *sp;
            Some(o.map(|offset| Val::Ptr { space: sp, offset }))
        }
        _ => None,
    }
}

/// Apply the store-side type normalisation lane-wise.
fn normalize_vlane<const W: usize>(v: &VLane<W>, ty: &Type) -> VLane<W> {
    match v {
        VLane::Uni(x) => VLane::Uni(normalize_to(x, ty)),
        other => {
            let lanes: Vec<VVal> = (0..W).map(|l| normalize_to(&other.get(l), ty)).collect();
            VLane::from_lanes(lanes)
        }
    }
}

/// Evaluate one pure instruction on scalar operand values — the per-lane
/// / uniform kernel, semantically identical to the scalar `Machine` arms.
fn eval_pure_scalar(inst: &Inst, ops: &[VVal]) -> Result<VVal> {
    match inst {
        Inst::Bin { op, ty, .. } => eval_bin(*op, ty, &ops[0], &ops[1]),
        Inst::Un { op, ty, .. } => eval_un(*op, ty, &ops[0]),
        Inst::Cast { to, from, .. } => Ok(eval_cast(&ops[0], from, to)),
        Inst::Math { func, ty, .. } => eval_math(*func, ty, ops),
        Inst::Select { ty, .. } => select_scalar(ty, &ops[0], &ops[1], &ops[2]),
        Inst::VecBuild { ty, .. } => {
            let s = ty
                .elem_scalar()
                .ok_or_else(|| Error::exec("vector build of non-value type"))?;
            Ok(VVal::V(ops.iter().map(|e| norm_val(e.scalar(), s)).collect()))
        }
        Inst::VecExtract { lane, .. } => Ok(VVal::S(ops[0].lane(*lane as usize))),
        Inst::VecInsert { lane, .. } => {
            let mut base = match ops[0].clone() {
                VVal::V(l) => l,
                VVal::S(s) => vec![s],
            };
            base[*lane as usize] = ops[1].scalar();
            Ok(VVal::V(base))
        }
        Inst::Splat { ty, .. } => {
            let s =
                ty.elem_scalar().ok_or_else(|| Error::exec("splat to non-vector type"))?;
            Ok(VVal::V(vec![norm_val(ops[0].scalar(), s); ty.lanes()]))
        }
        Inst::Gep { elem, .. } => gep_scalar(elem, &ops[0], &ops[1]),
        _ => Err(Error::exec("not a pure instruction")),
    }
}

/// Scalar select kernel (one lane / the uniform case).
pub(crate) fn select_scalar(ty: &Type, c: &VVal, av: &VVal, bv: &VVal) -> Result<VVal> {
    let lanes = ty.lanes();
    if lanes == 1 {
        Ok(if c.scalar().truthy() { av.clone() } else { bv.clone() })
    } else {
        let out: Vec<Val> = (0..lanes)
            .map(|l| {
                let cl = if c.lanes() == 1 { c.lane(0) } else { c.lane(l) };
                if cl.truthy() {
                    av.lane(l)
                } else {
                    bv.lane(l)
                }
            })
            .collect();
        Ok(VVal::V(out))
    }
}

/// Scalar address-calculation kernel: private memory is cell-addressed
/// (index added raw), other spaces scale by the element size.
pub(crate) fn gep_scalar(elem: &Type, base: &VVal, idx: &VVal) -> Result<VVal> {
    let b = base.scalar();
    let i = idx.scalar().as_i();
    match b {
        Val::Ptr { space: SP_PRIVATE, offset } => {
            Ok(VVal::ptr(SP_PRIVATE, (offset as i64 + i) as u64))
        }
        Val::Ptr { space, offset } => {
            Ok(VVal::ptr(space, (offset as i64 + i * elem.size() as i64) as u64))
        }
        _ => Err(Error::exec("gep on non-pointer")),
    }
}

/// Lane-batched binary-op kernel (stats-free, shared with the bytecode
/// engine): computed once when both operands are uniform, else through
/// the SIMD fast path, else one lane at a time — the exact evaluation
/// sequence [`eval_pure`] applies, so results are bit-identical across
/// engines. Returns the value plus whether the uniform path was taken.
pub(crate) fn bin_vlane<const W: usize>(
    op: BinOp,
    ty: &Type,
    a: &VLane<W>,
    b: &VLane<W>,
) -> Result<(VLane<W>, bool)> {
    if a.is_uniform() && b.is_uniform() {
        return Ok((VLane::Uni(eval_bin(op, ty, &a.get(0), &b.get(0))?), true));
    }
    if let Some(v) = bin_fast(op, ty, a, b)? {
        return Ok((v, false));
    }
    let mut out = Vec::with_capacity(W);
    for l in 0..W {
        out.push(eval_bin(op, ty, &a.get(l), &b.get(l))?);
    }
    Ok((VLane::from_lanes(out), false))
}

/// Lane-batched unary-op kernel (stats-free).
pub(crate) fn un_vlane<const W: usize>(
    op: UnOp,
    ty: &Type,
    a: &VLane<W>,
) -> Result<(VLane<W>, bool)> {
    if a.is_uniform() {
        return Ok((VLane::Uni(eval_un(op, ty, &a.get(0))?), true));
    }
    let mut out = Vec::with_capacity(W);
    for l in 0..W {
        out.push(eval_un(op, ty, &a.get(l))?);
    }
    Ok((VLane::from_lanes(out), false))
}

/// Lane-batched cast kernel (stats-free; casts cannot fail).
pub(crate) fn cast_vlane<const W: usize>(
    to: &Type,
    from: &Type,
    a: &VLane<W>,
) -> (VLane<W>, bool) {
    if a.is_uniform() {
        return (VLane::Uni(eval_cast(&a.get(0), from, to)), true);
    }
    let mut out = Vec::with_capacity(W);
    for l in 0..W {
        out.push(eval_cast(&a.get(l), from, to));
    }
    (VLane::from_lanes(out), false)
}

/// Lane-batched math-builtin kernel (stats-free).
pub(crate) fn math_vlane<const W: usize>(
    func: MathFn,
    ty: &Type,
    ops: &[&VLane<W>],
) -> Result<(VLane<W>, bool)> {
    if ops.iter().all(|o| o.is_uniform()) {
        let sv: Vec<VVal> = ops.iter().map(|o| o.get(0)).collect();
        return Ok((VLane::Uni(eval_math(func, ty, &sv)?), true));
    }
    if ops.len() == 1 {
        if let Some(v) = math_fast(func, ty, ops[0]) {
            return Ok((v, false));
        }
    }
    let mut out = Vec::with_capacity(W);
    for l in 0..W {
        let lane_ops: Vec<VVal> = ops.iter().map(|o| o.get(l)).collect();
        out.push(eval_math(func, ty, &lane_ops)?);
    }
    Ok((VLane::from_lanes(out), false))
}

/// Lane-batched select kernel (stats-free).
pub(crate) fn select_vlane<const W: usize>(
    ty: &Type,
    c: &VLane<W>,
    a: &VLane<W>,
    b: &VLane<W>,
) -> Result<(VLane<W>, bool)> {
    if c.is_uniform() && a.is_uniform() && b.is_uniform() {
        return Ok((VLane::Uni(select_scalar(ty, &c.get(0), &a.get(0), &b.get(0))?), true));
    }
    let mut out = Vec::with_capacity(W);
    for l in 0..W {
        out.push(select_scalar(ty, &c.get(l), &a.get(l), &b.get(l))?);
    }
    Ok((VLane::from_lanes(out), false))
}

/// Lane-batched address-calculation kernel (stats-free).
pub(crate) fn gep_vlane<const W: usize>(
    elem: &Type,
    base: &VLane<W>,
    idx: &VLane<W>,
) -> Result<(VLane<W>, bool)> {
    if base.is_uniform() && idx.is_uniform() {
        return Ok((VLane::Uni(gep_scalar(elem, &base.get(0), &idx.get(0))?), true));
    }
    let mut out = Vec::with_capacity(W);
    for l in 0..W {
        out.push(gep_scalar(elem, &base.get(l), &idx.get(l))?);
    }
    Ok((VLane::from_lanes(out), false))
}

/// Work-item geometry kernel: local/global ids vary per lane, everything
/// else (sizes, group ids, dims) is gang-uniform.
pub(crate) fn wi_vlane<const W: usize>(
    func: WiFn,
    dim: u32,
    ctx: &LaunchCtx,
    local_ids: &[[u64; 3]; W],
) -> (VLane<W>, bool) {
    match func {
        WiFn::LocalId | WiFn::GlobalId => {
            let mut a = [0i64; W];
            for (slot, lid) in a.iter_mut().zip(local_ids) {
                *slot = wi_value(func, dim, ctx, lid) as i64;
            }
            (VLane::I(a), false)
        }
        _ => (VLane::Uni(VVal::i(wi_value(func, dim, ctx, &local_ids[0]) as i64)), true),
    }
}
