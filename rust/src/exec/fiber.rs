//! Fiber-based work-group executor — the FreeOCL / Twin Peaks baseline
//! (§7).
//!
//! Each work-item is a lightweight "fiber" running the *region-form*
//! function (`reg_fn`, barriers intact). The scheduler round-robins the
//! fibers: each runs until it hits a barrier, is parked, and resumes after
//! every other fiber reaches the same barrier. This is the architecture
//! the paper argues against: per-work-item control flow prevents static
//! parallelisation across the work-group, and the context switches are
//! pure overhead.
//!
//! Because barriers live in dedicated blocks (after `kcc::barriers`
//! normalisation) and registers never cross blocks, a fiber context is
//! just its resume block plus its private-variable cells — an idealised
//! (cheapest possible) fiber, which makes the measured fiber-vs-pocl gap
//! a *lower bound* on the real gap.

use crate::cl::error::{Error, Result};
use crate::kcc::WorkGroupFunction;

use super::interp::{Flow, LaunchCtx, Machine, SlotStore};
use super::mem::MemoryRefs;
use super::value::VVal;

/// Execute one work-group with one fiber per work-item.
pub fn run_workgroup(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
) -> Result<()> {
    let f = &wgf.reg_fn;
    let n = wgf.wg_size();
    let [lx, ly, _lz] = wgf.local_size;
    // Per-fiber state: resume block + private cells.
    let mut resume = vec![f.entry; n];
    let mut done = vec![false; n];
    let mut stores: Vec<SlotStore> = (0..n).map(|_| SlotStore::for_function(f)).collect();

    let mut rounds = 0usize;
    loop {
        let mut barrier_hit: Option<crate::ir::inst::BlockId> = None;
        let mut any_running = false;
        for wi in 0..n {
            if done[wi] {
                continue;
            }
            any_running = true;
            // Context switch: bind this fiber's private store.
            let store = &mut stores[wi];
            let mut m = Machine::new(f, args, store, mem, ctx);
            m.local_id = [
                (wi % lx) as u64,
                ((wi / lx) % ly) as u64,
                (wi / (lx * ly)) as u64,
            ];
            let mut cur = resume[wi];
            loop {
                match m.exec_block(f, cur, true)? {
                    Flow::Goto(b) => cur = b,
                    Flow::Done => {
                        done[wi] = true;
                        break;
                    }
                    Flow::AtBarrier(bb) => {
                        // Park at the barrier; resume past it next round.
                        match f.block(bb).term {
                            crate::ir::inst::Term::Jump(succ) => resume[wi] = succ,
                            crate::ir::inst::Term::Ret => {
                                done[wi] = true;
                            }
                            _ => return Err(Error::exec("barrier block with branch terminator")),
                        }
                        match barrier_hit {
                            None => barrier_hit = Some(bb),
                            Some(prev) if prev == bb => {}
                            Some(prev) => {
                                return Err(Error::exec(format!(
                                    "barrier divergence: work-items at bb{} and bb{}",
                                    prev.0, bb.0
                                )))
                            }
                        }
                        break;
                    }
                }
            }
        }
        if !any_running {
            return Ok(());
        }
        // All fibers must agree: either all done, or all at the same barrier.
        if barrier_hit.is_some() && done.iter().any(|d| *d) && done.iter().any(|d| !*d) {
            // Mixed: some returned while others wait at a barrier → the
            // kernel violated the all-or-none barrier rule. The implicit
            // exit barrier makes normal termination hit this path with
            // done=true for all, so reaching here is a real divergence —
            // unless the "done" fibers finished at the exit barrier this
            // very round, which `Term::Ret` handling above folds into done.
        }
        rounds += 1;
        if rounds > 100_000_000 {
            return Err(Error::exec("fiber scheduler exceeded round budget"));
        }
    }
}
