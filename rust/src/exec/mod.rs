//! Execution engines for compiled work-group functions.
//!
//! The engine matrix (which engine consumes which compiler artifact):
//!
//! * [`serial`] — runs the WI-loop-materialised `loop_fn` straight through
//!   (paper `basic`); one dispatch per instruction per work-item, no
//!   per-instruction scheduling overhead. Wins for tiny work-groups.
//! * [`gang`] — per-lane lockstep execution of `reg_fn` regions: every
//!   instruction is dispatched once per lane, lane frames swapped per
//!   instruction. The reference model for SIMD mapping, and the fallback
//!   path for divergent control flow.
//! * [`vecgang`] — lane-batched (structure-of-arrays) execution of
//!   `reg_fn` regions: one dispatch per gang over [`value::VLane`] values,
//!   uniform values computed once per gang, varying floats carried in
//!   `vecmath::RealVec64`. ~width× fewer dispatches than [`gang`] on
//!   uniform-control kernels; divergent branches degrade to the [`gang`]
//!   per-lane path until the region's closing barrier.
//! * [`fiber`] — per-work-item fibers over `reg_fn` (FreeOCL / Twin Peaks
//!   baseline; the architecture the paper argues against).
//! * [`bytecode`] — threaded-dispatch tier over flattened, fused bytecode
//!   lowered from `reg_fn` regions at compile time (cached in poclbin):
//!   pre-resolved slots, PC branch targets, superinstructions; runs on
//!   the same [`value::VLane`] gang values as [`vecgang`] and falls back
//!   to it per region for uncovered regions.
//! * [`jit`] — template-jitted x86-64 machine code lowered from the
//!   bytecode form at compile time (no LLVM, W^X `mmap` buffer): inline
//!   templates for the common subset, helper dispatch into the shared
//!   `vecgang` kernels for the rest, per-region fallback to [`bytecode`]
//!   and wholesale fallback on non-x86-64 hosts.
//!
//! The scalar engines share the [`interp::Machine`] instruction evaluator
//! and the vector engine reuses its per-operation kernels, so a result
//! difference between engines is a scheduling bug, not a semantics
//! difference — the property the cross-engine tests rely on.

pub mod bytecode;
pub mod fiber;
pub mod gang;
pub mod interp;
pub mod jit;
pub mod mem;
pub mod serial;
pub mod value;
pub mod vecgang;

pub use interp::LaunchCtx;
pub use mem::MemoryRefs;
pub use value::{Val, VLane, VVal};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::kcc::{compile_workgroup, CompileOptions};

    /// Engines under test.
    #[derive(Clone, Copy, Debug, PartialEq)]
    enum Engine {
        Serial,
        Gang(usize),
        GangVec(usize),
        Bytecode(usize),
        Jit(usize),
        Fiber,
    }

    /// Kernel argument descriptions for the mini-harness.
    #[derive(Clone)]
    enum Arg {
        Buf(Vec<f32>),
        I(i64),
    }

    /// Run `src`'s first kernel over `groups` × `local` work-items with
    /// a zero global offset, returning every buffer's final contents.
    fn run(
        src: &str,
        local: [usize; 3],
        groups: [usize; 3],
        args: &[Arg],
        engine: Engine,
        horizontal: bool,
    ) -> Vec<Vec<f32>> {
        run_off(src, local, groups, [0; 3], args, engine, horizontal)
    }

    /// Like [`run`], with an explicit global work-item offset — every
    /// engine must honour `global_offset` the same way (scheduler
    /// sub-launches depend on it).
    fn run_off(
        src: &str,
        local: [usize; 3],
        groups: [usize; 3],
        global_offset: [u64; 3],
        args: &[Arg],
        engine: Engine,
        horizontal: bool,
    ) -> Vec<Vec<f32>> {
        let m = compile(src).unwrap();
        let k = &m.kernels[0];
        // The jit tier specialises its templates for the compile-time
        // gang width, so thread the engine's width through.
        let gang_width = match engine {
            Engine::Gang(w) | Engine::GangVec(w) | Engine::Bytecode(w) | Engine::Jit(w) => w,
            Engine::Serial | Engine::Fiber => 0,
        };
        let opts = CompileOptions { horizontal, gang_width, ..Default::default() };
        let wgf = compile_workgroup(k, local, &opts).unwrap();

        // Bind arguments by walking the kernel's parameter list: __local
        // pointer params (explicit or converted automatic locals) get
        // slices of local memory; everything else takes the next
        // user-provided argument. Buffers are laid out in global memory.
        let mut global = Vec::new();
        let mut arg_vals = Vec::new();
        let mut buf_offsets = Vec::new();
        let mut local_mem_size = 0usize;
        let mut user = args.iter();
        for p in &wgf.reg_fn.params {
            if p.is_local_buf {
                arg_vals.push(VVal::ptr(value::SP_LOCAL, local_mem_size as u64));
                // Explicit local pointers are sized by the host
                // (clSetKernelArg); the harness default is 4 KiB.
                local_mem_size += p.auto_local_size.unwrap_or(4096);
                continue;
            }
            match user.next().expect("not enough user args") {
                Arg::Buf(data) => {
                    let off = global.len();
                    global.resize(off + data.len() * 4, 0);
                    mem::write_f32s(&mut global, off, data);
                    buf_offsets.push(Some((off, data.len())));
                    arg_vals.push(VVal::ptr(value::SP_GLOBAL, off as u64));
                }
                Arg::I(v) => {
                    buf_offsets.push(None);
                    arg_vals.push(VVal::i(*v));
                }
            }
        }
        let mut local_mem = vec![0u8; local_mem_size.max(1)];

        let ctx_base = LaunchCtx {
            group_id: [0; 3],
            num_groups: [groups[0] as u64, groups[1] as u64, groups[2] as u64],
            global_offset,
            local_size: local,
            work_dim: 3,
        };
        for gz in 0..groups[2] {
            for gy in 0..groups[1] {
                for gx in 0..groups[0] {
                    let ctx = LaunchCtx {
                        group_id: [gx as u64, gy as u64, gz as u64],
                        ..ctx_base
                    };
                    let mut mem_refs =
                        MemoryRefs { global: &mut global, local: &mut local_mem };
                    match engine {
                        Engine::Serial => {
                            serial::run_workgroup(&wgf, &arg_vals, &mut mem_refs, &ctx).unwrap()
                        }
                        Engine::Gang(w) => {
                            gang::run_workgroup(&wgf, &arg_vals, &mut mem_refs, &ctx, w)
                                .map(|_| ())
                                .unwrap()
                        }
                        Engine::GangVec(w) => {
                            vecgang::run_workgroup(&wgf, &arg_vals, &mut mem_refs, &ctx, w)
                                .map(|_| ())
                                .unwrap()
                        }
                        Engine::Bytecode(w) => {
                            bytecode::run_workgroup(&wgf, &arg_vals, &mut mem_refs, &ctx, w)
                                .map(|_| ())
                                .unwrap()
                        }
                        Engine::Jit(w) => {
                            jit::run_workgroup(&wgf, &arg_vals, &mut mem_refs, &ctx, w)
                                .map(|_| ())
                                .unwrap()
                        }
                        Engine::Fiber => {
                            fiber::run_workgroup(&wgf, &arg_vals, &mut mem_refs, &ctx).unwrap()
                        }
                    }
                }
            }
        }
        // Read buffers back.
        buf_offsets
            .iter()
            .filter_map(|o| o.map(|(off, len)| mem::read_f32s(&global, off, len)))
            .collect()
    }

    fn all_engines() -> Vec<Engine> {
        vec![
            Engine::Serial,
            Engine::Gang(4),
            Engine::Gang(8),
            Engine::GangVec(4),
            Engine::GangVec(8),
            Engine::Bytecode(4),
            Engine::Bytecode(8),
            Engine::Jit(4),
            Engine::Jit(8),
            Engine::Fiber,
        ]
    }

    const VECADD: &str = "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
        size_t i = get_global_id(0);
        c[i] = a[i] + b[i];
    }";

    #[test]
    fn vecadd_all_engines() {
        let a: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..32).map(|i| (i * 10) as f32).collect();
        for e in all_engines() {
            let out = run(
                VECADD,
                [8, 1, 1],
                [4, 1, 1],
                &[Arg::Buf(a.clone()), Arg::Buf(b.clone()), Arg::Buf(vec![0.0; 32])],
                e,
                true,
            );
            let expect: Vec<f32> = (0..32).map(|i| (i + i * 10) as f32).collect();
            assert_eq!(out[2], expect, "engine {e:?}");
        }
    }

    const BARRIER_REVERSE: &str = "__kernel void rev(__global float *x, __local float *t) {
        size_t i = get_local_id(0);
        size_t n = get_local_size(0);
        t[i] = x[get_global_id(0)];
        barrier(CLK_LOCAL_MEM_FENCE);
        x[get_global_id(0)] = t[n - 1u - i];
    }";

    #[test]
    fn barrier_semantics_all_engines() {
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        for e in all_engines() {
            let out = run(BARRIER_REVERSE, [8, 1, 1], [2, 1, 1], &[Arg::Buf(x.clone())], e, true);
            let expect: Vec<f32> = vec![
                7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0, 0.0, // group 0 reversed
                15.0, 14.0, 13.0, 12.0, 11.0, 10.0, 9.0, 8.0, // group 1 reversed
            ];
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    const CONDITIONAL_BARRIER: &str = "__kernel void cb(__global float *x, __local float *t, int c) {
        size_t i = get_local_id(0);
        if (c > 0) {
            t[i] = x[i] * 2.0f;
            barrier(CLK_LOCAL_MEM_FENCE);
            x[i] = t[(i + 1u) % get_local_size(0)];
        }
        x[i] += 100.0f;
    }";

    #[test]
    fn conditional_barrier_taken_branch() {
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        for e in all_engines() {
            let out = run(
                CONDITIONAL_BARRIER,
                [8, 1, 1],
                [1, 1, 1],
                &[Arg::Buf(x.clone()), Arg::I(1)],
                e,
                true,
            );
            let expect: Vec<f32> =
                (0..8).map(|i| ((i + 1) % 8) as f32 * 2.0 + 100.0).collect();
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    #[test]
    fn conditional_barrier_untaken_branch() {
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        for e in all_engines() {
            let out = run(
                CONDITIONAL_BARRIER,
                [8, 1, 1],
                [1, 1, 1],
                &[Arg::Buf(x.clone()), Arg::I(0)],
                e,
                true,
            );
            let expect: Vec<f32> = (0..8).map(|i| i as f32 + 100.0).collect();
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    const BLOOP: &str = "__kernel void bl(__global float *x, __local float *t, int iters) {
        size_t i = get_local_id(0);
        size_t n = get_local_size(0);
        for (int k = 0; k < iters; k++) {
            t[i] = x[i];
            barrier(CLK_LOCAL_MEM_FENCE);
            x[i] = t[(i + 1u) % n] + 1.0f;
            barrier(CLK_GLOBAL_MEM_FENCE);
        }
    }";

    #[test]
    fn barrier_in_loop_all_engines() {
        let x: Vec<f32> = (0..4).map(|i| (i * i) as f32).collect();
        let reference = |mut v: Vec<f32>, iters: usize| {
            for _ in 0..iters {
                let t = v.clone();
                for i in 0..4 {
                    v[i] = t[(i + 1) % 4] + 1.0;
                }
            }
            v
        };
        for e in all_engines() {
            let out =
                run(BLOOP, [4, 1, 1], [1, 1, 1], &[Arg::Buf(x.clone()), Arg::I(3)], e, true);
            assert_eq!(out[0], reference(x.clone(), 3), "engine {e:?}");
        }
    }

    const DIVERGENT: &str = "__kernel void dv(__global float *x) {
        size_t i = get_global_id(0);
        float v = x[i];
        if (v > 4.0f) { v = v * 2.0f; } else { v = v - 1.0f; }
        int k = 0;
        while (k < (int)(i % 3u)) { v += 10.0f; k++; }
        x[i] = v;
    }";

    #[test]
    fn divergent_control_flow_all_engines() {
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let expect: Vec<f32> = (0..16u32)
            .map(|i| {
                let v = i as f32;
                let mut v = if v > 4.0 { v * 2.0 } else { v - 1.0 };
                v += 10.0 * (i % 3) as f32;
                v
            })
            .collect();
        for e in all_engines() {
            let out = run(DIVERGENT, [8, 1, 1], [2, 1, 1], &[Arg::Buf(x.clone())], e, true);
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    const DCT_LIKE: &str = "__kernel void dctish(__global float *out, __global const float *in, uint w) {
        uint i = (uint)get_local_id(0);
        float acc = 0.0f;
        for (uint k = 0u; k < w; k++) {
            acc += in[k * w + i] * 0.5f;
        }
        out[i] = acc;
    }";

    #[test]
    fn horizontal_parallelization_preserves_semantics() {
        let w = 8usize;
        let input: Vec<f32> = (0..w * w).map(|i| i as f32).collect();
        let expect: Vec<f32> = (0..w)
            .map(|i| (0..w).map(|k| input[k * w + i] * 0.5).sum())
            .collect();
        for horizontal in [false, true] {
            for e in all_engines() {
                let out = run(
                    DCT_LIKE,
                    [w, 1, 1],
                    [1, 1, 1],
                    &[Arg::Buf(vec![0.0; w]), Arg::Buf(input.clone()), Arg::I(w as i64)],
                    e,
                    horizontal,
                );
                assert_eq!(out[0], expect, "engine {e:?} horizontal={horizontal}");
            }
        }
    }

    const VEC_KERNEL: &str = "__kernel void vk(__global float4 *v) {
        size_t i = get_global_id(0);
        float4 a = v[i];
        float4 b = (float4)(1.0f, 2.0f, 3.0f, 4.0f);
        a = a * b + a.wzyx;
        v[i] = a;
    }";

    #[test]
    fn vector_types_all_engines() {
        // 4 float4s = 16 floats.
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let expect: Vec<f32> = (0..4)
            .flat_map(|q| {
                let v = &x[q * 4..q * 4 + 4];
                vec![
                    v[0] * 1.0 + v[3],
                    v[1] * 2.0 + v[2],
                    v[2] * 3.0 + v[1],
                    v[3] * 4.0 + v[0],
                ]
            })
            .collect();
        for e in all_engines() {
            let out = run(VEC_KERNEL, [4, 1, 1], [1, 1, 1], &[Arg::Buf(x.clone())], e, true);
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    const AUTO_LOCAL: &str = "__kernel void al(__global float *x) {
        __local float tile[8];
        size_t i = get_local_id(0);
        tile[i] = x[i] * 3.0f;
        barrier(CLK_LOCAL_MEM_FENCE);
        x[i] = tile[7u - i];
    }";

    #[test]
    fn automatic_local_buffers_all_engines() {
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let expect: Vec<f32> = (0..8).map(|i| (7 - i) as f32 * 3.0).collect();
        for e in all_engines() {
            let out = run(AUTO_LOCAL, [8, 1, 1], [1, 1, 1], &[Arg::Buf(x.clone())], e, true);
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    #[test]
    fn two_dimensional_launch() {
        let src = "__kernel void t2(__global float *x, uint w) {
            size_t gx = get_global_id(0);
            size_t gy = get_global_id(1);
            x[gy * (size_t)w + gx] = (float)(gx * 100u + gy);
        }";
        let w = 8usize;
        let expect: Vec<f32> =
            (0..w * w).map(|i| ((i % w) * 100 + i / w) as f32).collect();
        for e in all_engines() {
            let out = run(
                src,
                [4, 2, 1],
                [2, 4, 1],
                &[Arg::Buf(vec![0.0; w * w]), Arg::I(w as i64)],
                e,
                true,
            );
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    const OFFSET_KERNEL: &str = "__kernel void off(__global float *x) {
        size_t i = get_global_id(0);
        x[i] = (float)(i * 2u) + (float)get_global_offset(0);
    }";

    #[test]
    fn global_offset_honoured_by_all_engines() {
        // 2 groups × 4 WIs at offset 16: global ids 16..24, so exactly
        // x[16..24] is written and both get_global_id and
        // get_global_offset must reflect the shift. Every engine —
        // serial, gang, vecgang, bytecode, jit, fiber — must agree;
        // scheduler sub-launches build on this.
        let expect: Vec<f32> = (0..32)
            .map(|j| if (16..24).contains(&j) { (j * 2 + 16) as f32 } else { 0.0 })
            .collect();
        for e in all_engines() {
            let out = run_off(
                OFFSET_KERNEL,
                [4, 1, 1],
                [2, 1, 1],
                [16, 0, 0],
                &[Arg::Buf(vec![0.0; 32])],
                e,
                true,
            );
            assert_eq!(out[0], expect, "engine {e:?}");
        }
    }

    #[test]
    fn math_builtins_match_reference() {
        let src = "__kernel void mb(__global float *x) {
            size_t i = get_global_id(0);
            float v = x[i];
            x[i] = sqrt(v) + exp(v * 0.1f) + sin(v) * cos(v) + fmax(v, 2.0f);
        }";
        let x: Vec<f32> = (0..8).map(|i| i as f32 + 0.5).collect();
        let expect: Vec<f32> = x
            .iter()
            .map(|&v| {
                v.sqrt()
                    + crate::vecmath::scalar32::exp(v * 0.1)
                    + crate::vecmath::scalar32::sin(v) * crate::vecmath::scalar32::cos(v)
                    + v.max(2.0)
            })
            .collect();
        for e in all_engines() {
            let out = run(src, [8, 1, 1], [1, 1, 1], &[Arg::Buf(x.clone())], e, true);
            for (got, want) in out[0].iter().zip(&expect) {
                assert!((got - want).abs() < 1e-5, "engine {e:?}: {got} vs {want}");
            }
        }
    }
}
