//! Runtime values for the execution engines.

use crate::ir::types::{AddrSpace, Scalar, Type};

/// A scalar runtime value. Integers (including bool) are carried as `i64`
/// and normalised to their declared width on every operation; floats are
/// carried as `f64` with `f32` rounding applied for F32-typed ops.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Val {
    /// Integer / bool.
    I(i64),
    /// Float.
    F(f64),
    /// Pointer: address space + offset. Offsets are **bytes** for
    /// global/local/constant memory and **cells** for private slots.
    Ptr { space: u8, offset: u64 },
}

/// Address-space tags packed into `Val::Ptr::space`.
pub const SP_GLOBAL: u8 = 0;
pub const SP_LOCAL: u8 = 1;
pub const SP_CONSTANT: u8 = 2;
pub const SP_PRIVATE: u8 = 3;

/// Convert an `AddrSpace` to its runtime tag.
pub fn space_tag(s: AddrSpace) -> u8 {
    match s {
        AddrSpace::Global => SP_GLOBAL,
        AddrSpace::Local => SP_LOCAL,
        AddrSpace::Constant => SP_CONSTANT,
        AddrSpace::Private => SP_PRIVATE,
    }
}

impl Val {
    /// Interpret as integer (trap-free; floats truncate).
    pub fn as_i(self) -> i64 {
        match self {
            Val::I(v) => v,
            Val::F(v) => v as i64,
            Val::Ptr { offset, .. } => offset as i64,
        }
    }
    /// Interpret as float.
    pub fn as_f(self) -> f64 {
        match self {
            Val::I(v) => v as f64,
            Val::F(v) => v,
            Val::Ptr { offset, .. } => offset as f64,
        }
    }
    /// Truth value (C semantics).
    pub fn truthy(self) -> bool {
        match self {
            Val::I(v) => v != 0,
            Val::F(v) => v != 0.0,
            Val::Ptr { .. } => true,
        }
    }
}

/// Normalise an integer to a scalar type's width/signedness.
pub fn norm_int(v: i64, s: Scalar) -> i64 {
    match s {
        Scalar::Bool => (v != 0) as i64,
        Scalar::I32 => v as i32 as i64,
        Scalar::U32 => (v as u32) as i64,
        Scalar::I64 => v,
        Scalar::U64 => v, // bit pattern identical; comparisons handle sign
        Scalar::F32 | Scalar::F64 => v,
    }
}

/// Normalise a float to a scalar type's precision.
pub fn norm_float(v: f64, s: Scalar) -> f64 {
    match s {
        Scalar::F32 => v as f32 as f64,
        _ => v,
    }
}

/// A register value: scalar or short vector of lanes.
#[derive(Debug, Clone, PartialEq)]
pub enum VVal {
    /// Scalar.
    S(Val),
    /// Vector (2–16 lanes).
    V(Vec<Val>),
}

impl VVal {
    /// The single scalar (panics on vectors).
    pub fn scalar(&self) -> Val {
        match self {
            VVal::S(v) => *v,
            VVal::V(_) => panic!("expected scalar, found vector"),
        }
    }
    /// Lane view: scalars behave like a 1-lane vector.
    pub fn lane(&self, i: usize) -> Val {
        match self {
            VVal::S(v) => *v,
            VVal::V(l) => l[i],
        }
    }
    /// Lane count.
    pub fn lanes(&self) -> usize {
        match self {
            VVal::S(_) => 1,
            VVal::V(l) => l.len(),
        }
    }
    /// Shorthand constructors.
    pub fn i(v: i64) -> VVal {
        VVal::S(Val::I(v))
    }
    /// Float shorthand.
    pub fn f(v: f64) -> VVal {
        VVal::S(Val::F(v))
    }
    /// Pointer shorthand.
    pub fn ptr(space: u8, offset: u64) -> VVal {
        VVal::S(Val::Ptr { space, offset })
    }
    /// Zero value of a type.
    pub fn zero(ty: &Type) -> VVal {
        let z = if ty.is_float() { Val::F(0.0) } else { Val::I(0) };
        match ty {
            Type::Vec(_, n) => VVal::V(vec![z; *n as usize]),
            _ => VVal::S(z),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_normalisation() {
        assert_eq!(norm_int(0x1_0000_0001, Scalar::U32), 1);
        assert_eq!(norm_int(-1, Scalar::U32), 0xFFFF_FFFF);
        assert_eq!(norm_int(i64::from(i32::MAX) + 1, Scalar::I32), i64::from(i32::MIN));
        assert_eq!(norm_int(7, Scalar::Bool), 1);
    }

    #[test]
    fn float_normalisation() {
        let v = 1.000_000_119_209_290_f64; // not representable in f32
        assert_ne!(norm_float(v, Scalar::F32), v);
        assert_eq!(norm_float(v, Scalar::F64), v);
    }

    #[test]
    fn vval_lanes() {
        let v = VVal::V(vec![Val::F(1.0), Val::F(2.0)]);
        assert_eq!(v.lanes(), 2);
        assert_eq!(v.lane(1), Val::F(2.0));
        assert_eq!(VVal::i(3).lane(0), Val::I(3));
    }
}
