//! Runtime values for the execution engines.
//!
//! [`Val`]/[`VVal`] are the scalar-machine values (one work-item at a
//! time). [`VLane`] is the lane-batched (structure-of-arrays) value of the
//! vector gang engine: one logical value *per gang*, holding either a
//! single scalar shared by every lane (uniform) or one value per lane in a
//! packed SoA layout that the `vecmath` SIMD layer can consume directly.

use crate::ir::types::{AddrSpace, Scalar, Type};
use crate::vecmath::RealVec64;

/// A scalar runtime value. Integers (including bool) are carried as `i64`
/// and normalised to their declared width on every operation; floats are
/// carried as `f64` with `f32` rounding applied for F32-typed ops.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Val {
    /// Integer / bool.
    I(i64),
    /// Float.
    F(f64),
    /// Pointer: address space + offset. Offsets are **bytes** for
    /// global/local/constant memory and **cells** for private slots.
    Ptr { space: u8, offset: u64 },
}

/// Address-space tags packed into `Val::Ptr::space`.
pub const SP_GLOBAL: u8 = 0;
pub const SP_LOCAL: u8 = 1;
pub const SP_CONSTANT: u8 = 2;
pub const SP_PRIVATE: u8 = 3;

/// Convert an `AddrSpace` to its runtime tag.
pub fn space_tag(s: AddrSpace) -> u8 {
    match s {
        AddrSpace::Global => SP_GLOBAL,
        AddrSpace::Local => SP_LOCAL,
        AddrSpace::Constant => SP_CONSTANT,
        AddrSpace::Private => SP_PRIVATE,
    }
}

impl Val {
    /// Interpret as integer (trap-free; floats truncate).
    pub fn as_i(self) -> i64 {
        match self {
            Val::I(v) => v,
            Val::F(v) => v as i64,
            Val::Ptr { offset, .. } => offset as i64,
        }
    }
    /// Interpret as float.
    pub fn as_f(self) -> f64 {
        match self {
            Val::I(v) => v as f64,
            Val::F(v) => v,
            Val::Ptr { offset, .. } => offset as f64,
        }
    }
    /// Truth value (C semantics).
    pub fn truthy(self) -> bool {
        match self {
            Val::I(v) => v != 0,
            Val::F(v) => v != 0.0,
            Val::Ptr { .. } => true,
        }
    }
}

/// Normalise an integer to a scalar type's width/signedness.
pub fn norm_int(v: i64, s: Scalar) -> i64 {
    match s {
        Scalar::Bool => (v != 0) as i64,
        Scalar::I32 => v as i32 as i64,
        Scalar::U32 => (v as u32) as i64,
        Scalar::I64 => v,
        Scalar::U64 => v, // bit pattern identical; comparisons handle sign
        Scalar::F32 | Scalar::F64 => v,
    }
}

/// Normalise a float to a scalar type's precision.
pub fn norm_float(v: f64, s: Scalar) -> f64 {
    match s {
        Scalar::F32 => v as f32 as f64,
        _ => v,
    }
}

/// A register value: scalar or short vector of lanes.
#[derive(Debug, Clone, PartialEq)]
pub enum VVal {
    /// Scalar.
    S(Val),
    /// Vector (2–16 lanes).
    V(Vec<Val>),
}

impl VVal {
    /// The single scalar (panics on vectors).
    pub fn scalar(&self) -> Val {
        match self {
            VVal::S(v) => *v,
            VVal::V(_) => panic!("expected scalar, found vector"),
        }
    }
    /// Lane view: scalars behave like a 1-lane vector.
    pub fn lane(&self, i: usize) -> Val {
        match self {
            VVal::S(v) => *v,
            VVal::V(l) => l[i],
        }
    }
    /// Lane count.
    pub fn lanes(&self) -> usize {
        match self {
            VVal::S(_) => 1,
            VVal::V(l) => l.len(),
        }
    }
    /// Shorthand constructors.
    pub fn i(v: i64) -> VVal {
        VVal::S(Val::I(v))
    }
    /// Float shorthand.
    pub fn f(v: f64) -> VVal {
        VVal::S(Val::F(v))
    }
    /// Pointer shorthand.
    pub fn ptr(space: u8, offset: u64) -> VVal {
        VVal::S(Val::Ptr { space, offset })
    }
    /// Zero value of a type.
    pub fn zero(ty: &Type) -> VVal {
        let z = if ty.is_float() { Val::F(0.0) } else { Val::I(0) };
        match ty {
            Type::Vec(_, n) => VVal::V(vec![z; *n as usize]),
            _ => VVal::S(z),
        }
    }
}

/// A lane-batched value: what one virtual register (or private cell) holds
/// for a whole gang of `W` work-items in the vector engine.
///
/// The representation is the engine's dynamic uniformity lattice: values
/// proven identical across lanes stay in the scalar `Uni` form (computed
/// once per gang — the §4.6/§4.7 uniform-merging payoff), varying scalar
/// floats/ints/pointers live in packed structure-of-arrays forms that
/// lane-batched operators consume without per-lane boxing, and everything
/// else (short vectors, mixed kinds) falls back to one [`VVal`] per lane.
#[derive(Debug, Clone)]
pub enum VLane<const W: usize> {
    /// Identical on every lane; stored once.
    Uni(VVal),
    /// Varying scalar float, one `f64` per lane (`RealVec64`-backed so the
    /// vecmath layer's SIMD operators apply directly).
    F(RealVec64<W>),
    /// Varying scalar integer/bool, one `i64` per lane.
    I([i64; W]),
    /// Varying pointer within a single address space, one offset per lane.
    P(u8, [u64; W]),
    /// General fallback: one value per lane (short vectors, mixed kinds).
    Lanes(Box<[VVal; W]>),
}

/// Bit-level value identity: like `PartialEq` but NaN-stable (two NaN
/// lanes with the same bit pattern compare identical), so re-uniforming
/// after divergence never mis-classifies.
fn val_identical(a: &Val, b: &Val) -> bool {
    match (a, b) {
        (Val::F(x), Val::F(y)) => x.to_bits() == y.to_bits(),
        _ => a == b,
    }
}

fn vval_identical(a: &VVal, b: &VVal) -> bool {
    match (a, b) {
        (VVal::S(x), VVal::S(y)) => val_identical(x, y),
        (VVal::V(x), VVal::V(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| val_identical(p, q))
        }
        _ => false,
    }
}

impl<const W: usize> VLane<W> {
    /// The value lane `lane` observes.
    pub fn get(&self, lane: usize) -> VVal {
        match self {
            VLane::Uni(v) => v.clone(),
            VLane::F(rv) => VVal::S(Val::F(rv.0[lane])),
            VLane::I(a) => VVal::S(Val::I(a[lane])),
            VLane::P(sp, o) => VVal::S(Val::Ptr { space: *sp, offset: o[lane] }),
            VLane::Lanes(ls) => ls[lane].clone(),
        }
    }

    /// True for the uniform (computed-once) form.
    pub fn is_uniform(&self) -> bool {
        matches!(self, VLane::Uni(_))
    }

    /// Pack per-lane values into the tightest representation: uniform if
    /// all lanes are identical, else an SoA form, else the general form.
    pub fn from_lanes(lanes: Vec<VVal>) -> VLane<W> {
        debug_assert_eq!(lanes.len(), W);
        if lanes.iter().all(|v| vval_identical(v, &lanes[0])) {
            return VLane::Uni(lanes.into_iter().next().expect("non-empty gang"));
        }
        if lanes.iter().all(|v| matches!(v, VVal::S(Val::F(_)))) {
            let mut a = [0.0f64; W];
            for (slot, v) in a.iter_mut().zip(&lanes) {
                if let VVal::S(Val::F(x)) = v {
                    *slot = *x;
                }
            }
            return VLane::F(RealVec64(a));
        }
        if lanes.iter().all(|v| matches!(v, VVal::S(Val::I(_)))) {
            let mut a = [0i64; W];
            for (slot, v) in a.iter_mut().zip(&lanes) {
                if let VVal::S(Val::I(x)) = v {
                    *slot = *x;
                }
            }
            return VLane::I(a);
        }
        if let VVal::S(Val::Ptr { space, .. }) = lanes[0] {
            if lanes.iter().all(
                |v| matches!(v, VVal::S(Val::Ptr { space: s, .. }) if *s == space),
            ) {
                let mut a = [0u64; W];
                for (slot, v) in a.iter_mut().zip(&lanes) {
                    if let VVal::S(Val::Ptr { offset, .. }) = v {
                        *slot = *offset;
                    }
                }
                return VLane::P(space, a);
            }
        }
        let arr: [VVal; W] = match lanes.try_into() {
            Ok(a) => a,
            Err(_) => unreachable!("lane count matches W"),
        };
        VLane::Lanes(Box::new(arr))
    }

    /// Overwrite one lane, demoting the representation if needed.
    pub fn set_lane(&mut self, lane: usize, v: VVal) {
        match self {
            VLane::F(rv) => {
                if let VVal::S(Val::F(x)) = &v {
                    rv.0[lane] = *x;
                    return;
                }
            }
            VLane::I(a) => {
                if let VVal::S(Val::I(x)) = &v {
                    a[lane] = *x;
                    return;
                }
            }
            VLane::P(sp, o) => {
                if let VVal::S(Val::Ptr { space, offset }) = &v {
                    if space == sp {
                        o[lane] = *offset;
                        return;
                    }
                }
            }
            VLane::Lanes(ls) => {
                ls[lane] = v;
                return;
            }
            VLane::Uni(_) => {}
        }
        // Representation mismatch (or uniform being split): demote to the
        // general per-lane form and retry.
        let mut lanes: Vec<VVal> = (0..W).map(|l| self.get(l)).collect();
        lanes[lane] = v;
        *self = VLane::from_lanes(lanes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_normalisation() {
        assert_eq!(norm_int(0x1_0000_0001, Scalar::U32), 1);
        assert_eq!(norm_int(-1, Scalar::U32), 0xFFFF_FFFF);
        assert_eq!(norm_int(i64::from(i32::MAX) + 1, Scalar::I32), i64::from(i32::MIN));
        assert_eq!(norm_int(7, Scalar::Bool), 1);
    }

    #[test]
    fn float_normalisation() {
        let v = 1.000_000_119_209_290_f64; // not representable in f32
        assert_ne!(norm_float(v, Scalar::F32), v);
        assert_eq!(norm_float(v, Scalar::F64), v);
    }

    #[test]
    fn vval_lanes() {
        let v = VVal::V(vec![Val::F(1.0), Val::F(2.0)]);
        assert_eq!(v.lanes(), 2);
        assert_eq!(v.lane(1), Val::F(2.0));
        assert_eq!(VVal::i(3).lane(0), Val::I(3));
    }

    #[test]
    fn vlane_packing_and_access() {
        let u = VLane::<4>::from_lanes(vec![VVal::i(3); 4]);
        assert!(u.is_uniform());
        let f = VLane::<4>::from_lanes((0..4).map(|i| VVal::f(i as f64)).collect());
        assert!(matches!(f, VLane::F(_)));
        assert_eq!(f.get(2), VVal::f(2.0));
        let p = VLane::<4>::from_lanes((0..4).map(|i| VVal::ptr(SP_GLOBAL, i * 8)).collect());
        assert!(matches!(p, VLane::P(SP_GLOBAL, _)));
    }

    #[test]
    fn vlane_set_lane_demotes_uniform() {
        let mut v = VLane::<4>::Uni(VVal::i(1));
        v.set_lane(2, VVal::i(9));
        assert!(!v.is_uniform());
        assert_eq!(v.get(0), VVal::i(1));
        assert_eq!(v.get(2), VVal::i(9));
        // Re-packing detects identical lanes, NaN included.
        let nan = f64::NAN;
        let w = VLane::<2>::from_lanes(vec![VVal::f(nan), VVal::f(nan)]);
        assert!(w.is_uniform());
    }
}
