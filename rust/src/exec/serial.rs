//! Serial work-group executor: runs the WI-loop-materialised function
//! (`loop_fn`) straight through — the execution model of the paper's
//! `basic` device.

use crate::cl::error::Result;
use crate::kcc::WorkGroupFunction;

use super::interp::{LaunchCtx, Machine, SlotStore};
use super::mem::MemoryRefs;
use super::value::VVal;

/// Execute one work-group. `args` are the kernel arguments (including
/// converted automatic locals); the work-group context parameters are
/// appended here from `ctx`.
pub fn run_workgroup(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
) -> Result<()> {
    let f = &wgf.loop_fn;
    let mut full_args = args.to_vec();
    for d in 0..3 {
        full_args.push(VVal::i(ctx.group_id[d] as i64));
    }
    for d in 0..3 {
        full_args.push(VVal::i(ctx.num_groups[d] as i64));
    }
    for d in 0..3 {
        full_args.push(VVal::i(ctx.global_offset[d] as i64));
    }
    let mut slots = SlotStore::for_function(f);
    let mut m = Machine::new(f, &full_args, &mut slots, mem, ctx);
    m.run(f, f.entry)
}
