//! Gang (SIMD) work-group executor: the parallel *mapping* stage for
//! data-parallel hardware.
//!
//! Consumes the region-form function (`reg_fn`) plus the parallel-region
//! structure the kernel compiler exposed: work-items advance **in
//! lockstep, instruction by instruction, in gangs of `width` lanes**
//! (width 8 ≈ AVX2, width 4 ≈ NEON / AltiVec — Table 1 of the paper).
//! Uniform branches keep the gang converged; divergent branches fall back
//! to per-lane execution until the region's closing barrier — the same
//! degradation a real vectoriser's masked/scalarised path exhibits, which
//! is exactly what makes BinarySearch/NBody-style kernels the worst cases
//! in Fig. 12.

use crate::cl::error::{Error, Result};
use crate::ir::inst::{BlockId, Term};
use crate::kcc::WorkGroupFunction;

use super::interp::{Flow, LaunchCtx, Machine, SlotStore};
use super::mem::MemoryRefs;
use super::value::VVal;

/// Execution statistics (consumed by benches/tests), shared by the
/// per-lane gang engine and the lane-batched vector engine so their
/// dispatch counts are directly comparable.
#[derive(Debug, Clone, Copy, Default)]
pub struct GangStats {
    /// Gangs executed (chunks × regions).
    pub gangs: usize,
    /// Gangs that diverged and fell back to per-lane execution.
    pub diverged: usize,
    /// Lane-batched instruction dispatches: one interpreter dispatch
    /// covered a whole gang's worth of lanes (vector engine only).
    pub vector_insts: usize,
    /// Uniform instruction dispatches: evaluated once per gang because the
    /// value is provably or dynamically lane-invariant (vector engine).
    pub uniform_insts: usize,
    /// Per-lane instruction dispatches (the scalar gang engine's lockstep
    /// loop, and both engines' divergence/tail fallback paths).
    pub lane_insts: usize,
    /// Bytecode dispatches: one `loop { match }` step of the threaded
    /// tier, covering a whole gang (superinstructions count once).
    pub bytecode_insts: usize,
    /// Gang-regions executed through the bytecode tier.
    pub bytecode_gangs: usize,
    /// Gang-regions that had no lowered bytecode and fell back to the
    /// lane-batched region interpreter.
    pub bytecode_fallbacks: usize,
    /// Bytecode (super)instructions retired by jitted machine code —
    /// these pay *no* interpreter dispatch, so they are excluded from
    /// [`GangStats::dispatches`].
    pub jit_insts: usize,
    /// Gang-regions executed through jitted machine code.
    pub jit_gangs: usize,
    /// Gang-regions the JIT engine ran a tier below the jitted code
    /// (region not jitted, constants failed to marshal, or no bytecode).
    pub jit_fallbacks: usize,
}

impl GangStats {
    /// Total interpreter dispatches — the throughput metric the vector
    /// engine is built to shrink (each dispatch is one `match` over the
    /// instruction plus operand marshalling).
    pub fn dispatches(&self) -> usize {
        self.vector_insts + self.uniform_insts + self.lane_insts + self.bytecode_insts
    }
}

/// Reconcile the barrier one gang/lane reached with the barrier the rest
/// of the work-group reached so far. Conforming kernels always agree;
/// disagreement is the OpenCL barrier-divergence error, reported with
/// `scope` ("across gangs" / "within gang") for context.
pub(crate) fn note_barrier(
    agreed: &mut Option<BlockId>,
    reached: BlockId,
    scope: &str,
) -> Result<()> {
    match *agreed {
        None => *agreed = Some(reached),
        Some(prev) if prev == reached => {}
        Some(prev) => {
            return Err(Error::exec(format!(
                "barrier divergence {scope}: bb{} vs bb{}",
                prev.0, reached.0
            )))
        }
    }
    Ok(())
}

/// Execute one work-group in lockstep gangs of `width` lanes.
pub fn run_workgroup(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    width: usize,
) -> Result<GangStats> {
    let f = &wgf.reg_fn;
    let n = wgf.wg_size();
    let [lx, ly, _lz] = wgf.local_size;
    let mut stats = GangStats::default();

    // One private store per work-item (persists across regions → context
    // arrays are implicit here; the gang engine *is* the consumer of the
    // privatisation analysis in spirit, with per-lane cells).
    let mut stores: Vec<SlotStore> = (0..n).map(|_| SlotStore::for_function(f)).collect();
    // Per-lane register frames, swapped into the machine per instruction.
    let mut lane_regs: Vec<Vec<super::value::VVal>> =
        (0..n).map(|_| vec![VVal::i(0); f.reg_count() as usize]).collect();

    let local_id = |wi: usize| -> [u64; 3] {
        [(wi % lx) as u64, ((wi / lx) % ly) as u64, (wi / (lx * ly)) as u64]
    };

    // Walk barriers: all work-items sit at `cur`; execute the region to
    // the next barrier for every gang; repeat.
    let mut cur: BlockId = f.entry;
    loop {
        let block = f.block(cur);
        debug_assert!(block.has_barrier());
        let start = match &block.term {
            Term::Ret => return Ok(stats),
            Term::Jump(s) => *s,
            Term::Br { .. } => return Err(Error::exec("barrier block with branch terminator")),
        };
        let mut next_barrier: Option<BlockId> = None;
        for chunk_start in (0..n).step_by(width) {
            let lanes: Vec<usize> = (chunk_start..(chunk_start + width).min(n)).collect();
            stats.gangs += 1;
            let reached = run_gang_region(
                f, args, mem, ctx, &mut stores, &mut lane_regs, &lanes, start, local_id,
                &mut stats,
            )?;
            note_barrier(&mut next_barrier, reached, "across gangs")?;
        }
        cur = next_barrier.expect("work-group is non-empty");
    }
}

/// Run one gang through one region (from `start` to the next barrier
/// block), in lockstep until divergence.
#[allow(clippy::too_many_arguments)]
fn run_gang_region(
    f: &crate::ir::func::Function,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    stores: &mut [SlotStore],
    lane_regs: &mut [Vec<VVal>],
    lanes: &[usize],
    start: BlockId,
    local_id: impl Fn(usize) -> [u64; 3],
    stats: &mut GangStats,
) -> Result<BlockId> {
    let mut cur = start;
    loop {
        if f.block(cur).has_barrier() {
            return Ok(cur);
        }
        // Lockstep: each instruction evaluated for every lane before the
        // next instruction — the interpreter-level model of a vectorised
        // work-item loop body. Instructions are borrowed, not cloned
        // (cloning `Inst` allocates for call/vector operand lists and
        // dominated the hot loop; see EXPERIMENTS.md §Perf).
        for (def, inst) in &f.block(cur).insts {
            for &wi in lanes {
                stats.lane_insts += 1;
                let store = &mut stores[wi];
                let mut m = Machine {
                    regs: std::mem::take(&mut lane_regs[wi]),
                    args,
                    slots: store,
                    mem,
                    ctx,
                    local_id: local_id(wi),
                };
                let v = m.eval(f, inst)?;
                if let Some(r) = def {
                    m.regs[r.0 as usize] = v;
                }
                lane_regs[wi] = std::mem::take(&mut m.regs);
            }
        }
        // Terminator: converged or divergent?
        match f.block(cur).term.clone() {
            Term::Jump(t) => cur = t,
            Term::Ret => {
                // Region form always funnels into the exit barrier; a bare
                // Ret here means the kernel returned mid-region (possible
                // for "dead" blocks) — treat as reaching the exit barrier.
                return Err(Error::exec("unexpected ret inside region"));
            }
            Term::Br { cond, t, f: fb } => {
                let mut target: Option<BlockId> = None;
                let mut diverged = false;
                let mut lane_targets = Vec::with_capacity(lanes.len());
                for &wi in lanes {
                    let c = match cond {
                        crate::ir::inst::Operand::Reg(r) => {
                            lane_regs[wi][r.0 as usize].scalar().truthy()
                        }
                        ref op => {
                            // Immediates/args are lane-invariant.
                            let store = &mut stores[wi];
                            let m = Machine {
                                regs: Vec::new(),
                                args,
                                slots: store,
                                mem,
                                ctx,
                                local_id: local_id(wi),
                            };
                            m.operand(op).scalar().truthy()
                        }
                    };
                    let tgt = if c { t } else { fb };
                    lane_targets.push(tgt);
                    match target {
                        None => target = Some(tgt),
                        Some(prev) if prev != tgt => diverged = true,
                        _ => {}
                    }
                }
                if !diverged {
                    cur = target.unwrap();
                } else {
                    // Fall back: finish the region per-lane (the masked /
                    // scalarised path of a real vectoriser). Registers are
                    // block-local (IR invariant), so lanes restart from
                    // their branch targets with fresh frames.
                    stats.diverged += 1;
                    let mut reached: Option<BlockId> = None;
                    for (i, &wi) in lanes.iter().enumerate() {
                        let bar = run_lane_to_barrier(
                            f,
                            args,
                            mem,
                            ctx,
                            &mut stores[wi],
                            lane_targets[i],
                            local_id(wi),
                            stats,
                        )?;
                        note_barrier(&mut reached, bar, "within gang")?;
                    }
                    return Ok(reached.unwrap());
                }
            }
        }
    }
}

/// Run one lane (work-item) from `start` to the next barrier block with a
/// fresh register frame (registers are block-local, so frames carry no
/// state across blocks). Shared by the scalar gang's divergence fallback
/// and the vector engine's divergence + tail-gang paths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_lane_to_barrier(
    f: &crate::ir::func::Function,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    store: &mut SlotStore,
    start: BlockId,
    local_id: [u64; 3],
    stats: &mut GangStats,
) -> Result<BlockId> {
    let mut m = Machine {
        regs: vec![VVal::i(0); f.reg_count() as usize],
        args,
        slots: store,
        mem,
        ctx,
        local_id,
    };
    let mut pos = start;
    loop {
        if f.block(pos).has_barrier() {
            return Ok(pos);
        }
        stats.lane_insts += f.block(pos).insts.len();
        match m.exec_block(f, pos, true)? {
            Flow::Goto(b) => pos = b,
            Flow::Done => return Err(Error::exec("lane returned mid-region")),
            Flow::AtBarrier(bb) => return Ok(bb),
        }
    }
}
