//! Byte-addressed device memory with typed access.
//!
//! Global/local/constant memory are flat byte buffers (global is backed by
//! the device's Bufalloc region); private variables live in typed cell
//! storage managed by the engines, not here.

use crate::cl::error::{Error, Result};
use crate::ir::types::{Scalar, Type};

use super::value::{norm_int, Val, VVal};

/// Mutable views of the memory spaces a kernel invocation can touch.
pub struct MemoryRefs<'a> {
    /// Device global memory (also serves __constant).
    pub global: &'a mut [u8],
    /// Per-work-group local memory.
    pub local: &'a mut [u8],
}

impl<'a> MemoryRefs<'a> {
    fn space(&mut self, tag: u8) -> &mut [u8] {
        match tag {
            super::value::SP_LOCAL => self.local,
            _ => self.global,
        }
    }

    /// Load a typed value at a byte offset.
    pub fn load(&mut self, tag: u8, offset: u64, ty: &Type) -> Result<VVal> {
        let s = ty.elem_scalar().ok_or_else(|| Error::exec("load of non-value type"))?;
        let lanes = ty.lanes();
        let esz = s.size();
        let buf = self.space(tag);
        let need = offset as usize + esz * lanes;
        if need > buf.len() {
            return Err(Error::exec(format!(
                "out-of-bounds load: {}+{} > {} (space {tag})",
                offset,
                esz * lanes,
                buf.len()
            )));
        }
        let mut vals = Vec::with_capacity(lanes);
        for l in 0..lanes {
            let off = offset as usize + l * esz;
            vals.push(load_scalar(buf, off, s));
        }
        Ok(if lanes == 1 { VVal::S(vals[0]) } else { VVal::V(vals) })
    }

    /// Store a typed value at a byte offset.
    pub fn store(&mut self, tag: u8, offset: u64, ty: &Type, v: &VVal) -> Result<()> {
        let s = ty.elem_scalar().ok_or_else(|| Error::exec("store of non-value type"))?;
        let lanes = ty.lanes();
        let esz = s.size();
        let buf = self.space(tag);
        let need = offset as usize + esz * lanes;
        if need > buf.len() {
            return Err(Error::exec(format!(
                "out-of-bounds store: {}+{} > {} (space {tag})",
                offset,
                esz * lanes,
                buf.len()
            )));
        }
        for l in 0..lanes {
            let off = offset as usize + l * esz;
            store_scalar(buf, off, s, v.lane(l));
        }
        Ok(())
    }
}

fn load_scalar(buf: &[u8], off: usize, s: Scalar) -> Val {
    match s {
        Scalar::F32 => {
            Val::F(f32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as f64)
        }
        Scalar::F64 => Val::F(f64::from_le_bytes(buf[off..off + 8].try_into().unwrap())),
        Scalar::I32 => Val::I(i32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as i64),
        Scalar::U32 => {
            Val::I(u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as i64)
        }
        Scalar::I64 | Scalar::U64 => {
            Val::I(i64::from_le_bytes(buf[off..off + 8].try_into().unwrap()))
        }
        Scalar::Bool => Val::I((buf[off] != 0) as i64),
    }
}

fn store_scalar(buf: &mut [u8], off: usize, s: Scalar, v: Val) {
    match s {
        Scalar::F32 => buf[off..off + 4].copy_from_slice(&(v.as_f() as f32).to_le_bytes()),
        Scalar::F64 => buf[off..off + 8].copy_from_slice(&v.as_f().to_le_bytes()),
        Scalar::I32 | Scalar::U32 => {
            buf[off..off + 4].copy_from_slice(&(norm_int(v.as_i(), s) as u32).to_le_bytes())
        }
        Scalar::I64 | Scalar::U64 => buf[off..off + 8].copy_from_slice(&v.as_i().to_le_bytes()),
        Scalar::Bool => buf[off] = v.truthy() as u8,
    }
}

/// Host-side helpers for filling/reading flat buffers.
pub fn write_f32s(buf: &mut [u8], offset: usize, data: &[f32]) {
    for (i, v) in data.iter().enumerate() {
        buf[offset + i * 4..offset + i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
}

/// Read f32s back from a flat buffer.
pub fn read_f32s(buf: &[u8], offset: usize, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| f32::from_le_bytes(buf[offset + i * 4..offset + i * 4 + 4].try_into().unwrap()))
        .collect()
}

/// Write i32s into a flat buffer.
pub fn write_i32s(buf: &mut [u8], offset: usize, data: &[i32]) {
    for (i, v) in data.iter().enumerate() {
        buf[offset + i * 4..offset + i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
}

/// Read i32s back from a flat buffer.
pub fn read_i32s(buf: &[u8], offset: usize, n: usize) -> Vec<i32> {
    (0..n)
        .map(|i| i32::from_le_bytes(buf[offset + i * 4..offset + i * 4 + 4].try_into().unwrap()))
        .collect()
}

/// Write u32s into a flat buffer.
pub fn write_u32s(buf: &mut [u8], offset: usize, data: &[u32]) {
    for (i, v) in data.iter().enumerate() {
        buf[offset + i * 4..offset + i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
}

/// Read u32s back from a flat buffer.
pub fn read_u32s(buf: &[u8], offset: usize, n: usize) -> Vec<u32> {
    (0..n)
        .map(|i| u32::from_le_bytes(buf[offset + i * 4..offset + i * 4 + 4].try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::value::SP_GLOBAL;

    #[test]
    fn roundtrip_scalars() {
        let mut g = vec![0u8; 64];
        let mut l = vec![0u8; 0];
        let mut m = MemoryRefs { global: &mut g, local: &mut l };
        m.store(SP_GLOBAL, 0, &Type::F32, &VVal::f(1.5)).unwrap();
        m.store(SP_GLOBAL, 8, &Type::I32, &VVal::i(-3)).unwrap();
        assert_eq!(m.load(SP_GLOBAL, 0, &Type::F32).unwrap(), VVal::f(1.5));
        assert_eq!(m.load(SP_GLOBAL, 8, &Type::I32).unwrap(), VVal::i(-3));
    }

    #[test]
    fn roundtrip_vectors() {
        let mut g = vec![0u8; 64];
        let mut l = vec![0u8; 0];
        let mut m = MemoryRefs { global: &mut g, local: &mut l };
        let ty = Type::Vec(Scalar::F32, 4);
        let v = VVal::V(vec![Val::F(1.0), Val::F(2.0), Val::F(3.0), Val::F(4.0)]);
        m.store(SP_GLOBAL, 16, &ty, &v).unwrap();
        assert_eq!(m.load(SP_GLOBAL, 16, &ty).unwrap(), v);
        // Lanes are consecutive f32s.
        assert_eq!(read_f32s(&g, 16, 4), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn oob_is_an_error() {
        let mut g = vec![0u8; 8];
        let mut l = vec![0u8; 0];
        let mut m = MemoryRefs { global: &mut g, local: &mut l };
        assert!(m.load(SP_GLOBAL, 8, &Type::F32).is_err());
        assert!(m.store(SP_GLOBAL, 6, &Type::F32, &VVal::f(0.0)).is_err());
    }

    #[test]
    fn u32_sign_handling() {
        let mut g = vec![0u8; 8];
        let mut l = vec![0u8; 0];
        let mut m = MemoryRefs { global: &mut g, local: &mut l };
        m.store(SP_GLOBAL, 0, &Type::U32, &VVal::i(-1)).unwrap();
        assert_eq!(m.load(SP_GLOBAL, 0, &Type::U32).unwrap(), VVal::i(0xFFFF_FFFF));
    }
}
