//! Threaded-bytecode execution tier — stage (a) of the native-code tier.
//!
//! The interpreters pay one dispatch per IR instruction per gang *plus*
//! operand marshalling (register `Vec` indexing through an `Operand`
//! `match`, HashMap-free but still two indirections). This tier removes
//! that constant factor without leaving safe Rust: [`lower`] flattens
//! each uniform, barrier-free parallel region of `reg_fn` into linear
//! bytecode with pre-resolved register/constant slots and
//! program-counter branch targets, fusing the hottest adjacent idioms
//! (address-calc+load, load+binop, binop+store, mul+add, cmp+branch)
//! into superinstructions; [`run_workgroup`] executes it with a tight
//! `loop { match }` over the same SoA [`crate::exec::VLane`] gang values
//! the vector engine uses — same evaluation kernels, so bit-identical
//! results.
//!
//! Coverage is incremental by construction: regions the lowerer rejects
//! (divergent control, vector-build/shuffle ops, …) simply have no
//! bytecode and run through [`crate::exec::vecgang`] per region on the
//! same gang state; a dynamically divergent branch falls back to the
//! shared per-lane path mid-region. The lowered program rides in the
//! poclbin cache (format v3), so warm starts skip lowering too.

mod lower;
mod prog;
mod run;

pub use lower::{lower, LowerStats};
pub use prog::{BcConst, BcInst, BcRegion, BcSlot, BytecodeProgram};
pub use run::run_workgroup;
pub(crate) use run::{diverge, resolve_consts, run_region, BcGang};
