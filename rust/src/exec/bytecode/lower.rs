//! Region → bytecode lowering: the target-specific mapping stage that
//! flattens uniform, barrier-free parallel regions of `reg_fn` into the
//! linear [`BcRegion`] form, fusing the hottest adjacent-instruction
//! idioms into superinstructions along the way.
//!
//! Legality is conservative: a region group is lowered only if every
//! sibling region sharing the entry block is statically non-divergent
//! (`region_divergent`), contains only the supported scalar instruction
//! set, and flows only into closure blocks or barrier blocks. Anything
//! else is simply left out of the program — the engine falls back to
//! `vecgang` per region, so coverage can grow without a correctness
//! cliff.
//!
//! Fusion safety: a producer is folded into its consumer only when the
//! producer's register has exactly **one** use in the whole closure
//! (registers are block-local and never renumbered, so a function-wide
//! count is exact). The fused instruction evaluates the same kernels in
//! the same order as the unfused pair — `MulAdd` in particular stays a
//! separate mul-then-add (never an FMA), preserving bit-identical
//! results.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::ir::func::Function;
use crate::ir::inst::{BinOp, BlockId, Imm, Inst, Operand, Reg, Term};
use crate::ir::types::Scalar;
use crate::kcc::Region;

use super::prog::{BcConst, BcInst, BcRegion, BcSlot, BytecodeProgram};

/// Lowering statistics, folded into `CompileStats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct LowerStats {
    /// Regions covered by the bytecode program.
    pub covered_regions: usize,
    /// Superinstructions formed (each replaces two dispatches with one).
    pub fused: usize,
    /// Total bytecode instructions emitted.
    pub insts: usize,
}

/// Lower every coverable region of `f`. Returns `None` when nothing is
/// coverable (the engine then falls back to `vecgang` wholesale).
pub fn lower(
    f: &Function,
    regions: &[Region],
    region_divergent: &[bool],
) -> (Option<BytecodeProgram>, LowerStats) {
    let mut stats = LowerStats::default();
    // Sibling regions share an entry block (the `Jump` target of their
    // opening barrier); the engine enters by block, so lower per group.
    let mut groups: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
    for (i, r) in regions.iter().enumerate() {
        if let Term::Jump(s) = f.block(r.pre).term {
            groups.entry(s.0).or_default().push(i);
        }
    }
    let mut out = Vec::new();
    for (start, idxs) in groups {
        if idxs.iter().any(|&i| region_divergent.get(i).copied().unwrap_or(true)) {
            continue;
        }
        if let Some(r) = lower_group(f, regions, &idxs, BlockId(start), &mut stats) {
            stats.covered_regions += idxs.len();
            stats.insts += r.code.len();
            out.push(r);
        }
    }
    if out.is_empty() {
        return (None, stats);
    }
    (Some(BytecodeProgram { reg_count: f.reg_count(), regions: out }), stats)
}

/// Dedup key for the constant pool (floats keyed by bit pattern).
#[derive(PartialEq, Eq, Hash)]
enum ConstKey {
    Int(i64, Scalar),
    Float(u64, Scalar),
    Arg(u32),
    Slot(u32),
}

/// Operand → slot resolver with a deduplicated constant pool.
struct Pool {
    reg_count: u32,
    map: HashMap<ConstKey, u32>,
    consts: Vec<BcConst>,
}

impl Pool {
    fn slot(&mut self, op: &Operand) -> BcSlot {
        match op {
            Operand::Reg(r) => r.0,
            Operand::Imm(Imm::Int(v, s)) => self.konst(ConstKey::Int(*v, *s), BcConst::Int(*v, *s)),
            Operand::Imm(Imm::Float(v, s)) => {
                self.konst(ConstKey::Float(v.to_bits(), *s), BcConst::Float(*v, *s))
            }
            Operand::Arg(a) => self.konst(ConstKey::Arg(*a), BcConst::Arg(*a)),
            Operand::Slot(s) => self.konst(ConstKey::Slot(s.0), BcConst::Slot(*s)),
        }
    }

    fn konst(&mut self, key: ConstKey, val: BcConst) -> BcSlot {
        if let Some(&i) = self.map.get(&key) {
            return self.reg_count + i;
        }
        let i = self.consts.len() as u32;
        self.map.insert(key, i);
        self.consts.push(val);
        self.reg_count + i
    }
}

fn lower_group(
    f: &Function,
    regions: &[Region],
    idxs: &[usize],
    start: BlockId,
    stats: &mut LowerStats,
) -> Option<BcRegion> {
    // Empty region (two adjacent barriers): the opening barrier jumps
    // straight to the closing one.
    if f.block(start).has_barrier() {
        return Some(BcRegion {
            start,
            consts: Vec::new(),
            code: vec![BcInst::End { barrier: start }],
        });
    }
    // Closure: union of the sibling regions' body blocks.
    let mut closure: Vec<BlockId> =
        idxs.iter().flat_map(|&i| regions[i].blocks.iter().copied()).collect();
    closure.sort();
    closure.dedup();
    let in_closure: HashSet<BlockId> = closure.iter().copied().collect();
    if !in_closure.contains(&start) {
        return None;
    }

    // Legality: supported scalar instruction set only, every
    // value-producing instruction keeps its def, no returns, and control
    // flow stays within the closure or exits to barrier blocks.
    for &b in &closure {
        let blk = f.block(b);
        for (def, inst) in &blk.insts {
            match inst {
                Inst::Bin { .. }
                | Inst::Un { .. }
                | Inst::Cast { .. }
                | Inst::Load { .. }
                | Inst::Gep { .. }
                | Inst::Wi { .. }
                | Inst::Math { .. }
                | Inst::Select { .. } => {
                    if def.is_none() {
                        return None;
                    }
                }
                Inst::Store { .. } | Inst::Marker { .. } => {}
                // Short-vector ops and (impossible here) barriers fall
                // back to the vecgang region interpreter.
                _ => return None,
            }
        }
        if matches!(blk.term, Term::Ret) {
            return None;
        }
        for s in blk.term.succs() {
            if !in_closure.contains(&s) && !f.block(s).has_barrier() {
                return None;
            }
        }
    }

    // Register use counts over the closure (defs are function-unique, so
    // this is exact) — the single-use guard of the peephole fuser.
    let mut uses = vec![0u32; f.reg_count() as usize];
    for &b in &closure {
        let blk = f.block(b);
        for (_, inst) in &blk.insts {
            for op in inst.operands() {
                if let Operand::Reg(r) = op {
                    uses[r.0 as usize] += 1;
                }
            }
        }
        if let Term::Br { cond: Operand::Reg(r), .. } = &blk.term {
            uses[r.0 as usize] += 1;
        }
    }

    // Linear layout: entry block first, the rest in id order.
    let mut order: Vec<BlockId> = vec![start];
    order.extend(closure.iter().copied().filter(|&b| b != start));

    let mut pool = Pool { reg_count: f.reg_count(), map: HashMap::new(), consts: Vec::new() };
    let mut code: Vec<BcInst> = Vec::new();
    let mut block_pc: HashMap<u32, u32> = HashMap::new();
    // Branch-target fields hold IR block ids until patched below.
    let mut fixups: Vec<usize> = Vec::new();
    let mut end_targets: Vec<BlockId> = Vec::new();

    for (oi, &b) in order.iter().enumerate() {
        block_pc.insert(b.0, code.len() as u32);
        let block_base = code.len();
        let blk = f.block(b);
        for (def, inst) in &blk.insts {
            if matches!(inst, Inst::Marker { .. }) {
                continue; // no-ops cost a dispatch in vecgang, none here
            }
            emit_inst(def, inst, &mut pool, &mut code, block_base, &uses, stats);
        }
        match &blk.term {
            Term::Jump(t) => {
                if f.block(*t).has_barrier() {
                    code.push(BcInst::End { barrier: *t });
                } else if order.get(oi + 1) == Some(t) {
                    // Fall through to the next block.
                } else {
                    fixups.push(code.len());
                    code.push(BcInst::Jump { pc: t.0 });
                }
            }
            Term::Br { cond, t, f: fb } => {
                let (ir_t, ir_f) = (*t, *fb);
                for tgt in [ir_t, ir_f] {
                    if !in_closure.contains(&tgt) && !end_targets.contains(&tgt) {
                        end_targets.push(tgt);
                    }
                }
                let fused = match if code.len() > block_base { code.last() } else { None } {
                    Some(BcInst::Bin { op, ty, dst, a: ca, b: cb })
                        if op.is_cmp()
                            && matches!(cond, Operand::Reg(r)
                                if r.0 == *dst && uses[r.0 as usize] == 1) =>
                    {
                        Some(BcInst::CmpBr {
                            op: *op,
                            ty: ty.clone(),
                            a: *ca,
                            b: *cb,
                            t: ir_t.0,
                            f: ir_f.0,
                            ir_t,
                            ir_f,
                        })
                    }
                    _ => None,
                };
                if let Some(cb) = fused {
                    code.pop();
                    stats.fused += 1;
                    fixups.push(code.len());
                    code.push(cb);
                } else {
                    let c = pool.slot(cond);
                    fixups.push(code.len());
                    code.push(BcInst::Br { cond: c, t: ir_t.0, f: ir_f.0, ir_t, ir_f });
                }
            }
            Term::Ret => unreachable!("rejected by the legality scan"),
        }
    }

    // End stubs for conditional branches that exit to a barrier.
    let mut end_pc: HashMap<u32, u32> = HashMap::new();
    for tgt in end_targets {
        end_pc.insert(tgt.0, code.len() as u32);
        code.push(BcInst::End { barrier: tgt });
    }
    // Patch branch targets from IR block ids to program counters.
    for i in fixups {
        let resolve = |b: u32| -> u32 {
            *block_pc.get(&b).or_else(|| end_pc.get(&b)).expect("branch target was emitted")
        };
        match &mut code[i] {
            BcInst::Jump { pc } => *pc = resolve(*pc),
            BcInst::Br { t, f, .. } | BcInst::CmpBr { t, f, .. } => {
                *t = resolve(*t);
                *f = resolve(*f);
            }
            _ => unreachable!("only branches are fixed up"),
        }
    }
    Some(BcRegion { start, consts: pool.consts, code })
}

/// Translate one IR instruction, fusing it with the immediately
/// preceding emission when the superinstruction patterns apply.
#[allow(clippy::too_many_arguments)]
fn emit_inst(
    def: &Option<Reg>,
    inst: &Inst,
    pool: &mut Pool,
    code: &mut Vec<BcInst>,
    block_base: usize,
    uses: &[u32],
    stats: &mut LowerStats,
) {
    let dst = def.map(|r| r.0);
    let last = if code.len() > block_base { code.last() } else { None };
    let fused: Option<BcInst> = match inst {
        // Address calculation feeding its load.
        Inst::Load { ty, ptr: Operand::Reg(p) } => match last {
            Some(BcInst::Gep { elem, dst: gd, base, idx })
                if *gd == p.0 && uses[p.0 as usize] == 1 =>
            {
                Some(BcInst::GepLoad {
                    elem: elem.clone(),
                    ty: ty.clone(),
                    dst: dst.expect("load defines a register"),
                    base: *base,
                    idx: *idx,
                })
            }
            _ => None,
        },
        Inst::Bin { op, ty, a, b } => {
            let d = dst.expect("bin defines a register");
            match last {
                // mul feeding add → separate mul-then-add superinstruction.
                Some(BcInst::Bin { op: BinOp::Mul, ty: mty, dst: md, a: ma, b: mb })
                    if *op == BinOp::Add && mty == ty =>
                {
                    let am = matches!(a, Operand::Reg(r) if r.0 == *md);
                    let bm = matches!(b, Operand::Reg(r) if r.0 == *md);
                    if am != bm && uses[*md as usize] == 1 {
                        let (ma, mb) = (*ma, *mb);
                        let c = pool.slot(if am { b } else { a });
                        Some(BcInst::MulAdd { ty: ty.clone(), dst: d, a: ma, b: mb, c, mul_first: am })
                    } else {
                        None
                    }
                }
                // Load feeding a binop.
                Some(BcInst::Load { ty: lty, dst: ld, ptr }) => {
                    let am = matches!(a, Operand::Reg(r) if r.0 == *ld);
                    let bm = matches!(b, Operand::Reg(r) if r.0 == *ld);
                    if am != bm && uses[*ld as usize] == 1 {
                        let (lty, ptr) = (lty.clone(), *ptr);
                        let other = pool.slot(if am { b } else { a });
                        Some(BcInst::LoadBin {
                            op: *op,
                            ty: ty.clone(),
                            load_ty: lty,
                            dst: d,
                            ptr,
                            other,
                            load_first: am,
                        })
                    } else {
                        None
                    }
                }
                _ => None,
            }
        }
        // Binop feeding its store.
        Inst::Store { ty, ptr, val: Operand::Reg(v) } => match last {
            Some(BcInst::Bin { op, ty: bty, dst: bd, a, b })
                if *bd == v.0 && uses[v.0 as usize] == 1 =>
            {
                let (op, bty, a, b) = (*op, bty.clone(), *a, *b);
                Some(BcInst::BinStore {
                    op,
                    ty: bty,
                    store_ty: ty.clone(),
                    ptr: pool.slot(ptr),
                    a,
                    b,
                })
            }
            _ => None,
        },
        _ => None,
    };
    if let Some(fi) = fused {
        code.pop();
        stats.fused += 1;
        code.push(fi);
        return;
    }
    let bi = match inst {
        Inst::Bin { op, ty, a, b } => BcInst::Bin {
            op: *op,
            ty: ty.clone(),
            dst: dst.expect("bin defines a register"),
            a: pool.slot(a),
            b: pool.slot(b),
        },
        Inst::Un { op, ty, a } => BcInst::Un {
            op: *op,
            ty: ty.clone(),
            dst: dst.expect("un defines a register"),
            a: pool.slot(a),
        },
        Inst::Cast { to, from, a } => BcInst::Cast {
            to: to.clone(),
            from: from.clone(),
            dst: dst.expect("cast defines a register"),
            a: pool.slot(a),
        },
        Inst::Load { ty, ptr } => BcInst::Load {
            ty: ty.clone(),
            dst: dst.expect("load defines a register"),
            ptr: pool.slot(ptr),
        },
        Inst::Store { ty, ptr, val } => {
            BcInst::Store { ty: ty.clone(), ptr: pool.slot(ptr), val: pool.slot(val) }
        }
        Inst::Gep { elem, base, idx } => BcInst::Gep {
            elem: elem.clone(),
            dst: dst.expect("gep defines a register"),
            base: pool.slot(base),
            idx: pool.slot(idx),
        },
        Inst::Wi { func, dim } => {
            BcInst::Wi { func: *func, dim: *dim, dst: dst.expect("wi defines a register") }
        }
        Inst::Math { func, ty, args } => BcInst::Math {
            func: *func,
            ty: ty.clone(),
            dst: dst.expect("math defines a register"),
            args: args.iter().map(|o| pool.slot(o)).collect(),
        },
        Inst::Select { ty, cond, a, b } => BcInst::Select {
            ty: ty.clone(),
            dst: dst.expect("select defines a register"),
            cond: pool.slot(cond),
            a: pool.slot(a),
            b: pool.slot(b),
        },
        _ => unreachable!("rejected by the legality scan"),
    };
    code.push(bi);
}

#[cfg(test)]
mod tests {
    use super::super::prog::BcInst;
    use crate::frontend::compile;
    use crate::kcc::{compile_workgroup, CompileOptions, WorkGroupFunction};

    fn wg(src: &str, local: [usize; 3]) -> WorkGroupFunction {
        let m = compile(src).unwrap();
        let k = m.kernels.into_iter().next().unwrap();
        compile_workgroup(&k, local, &CompileOptions::default()).unwrap()
    }

    #[test]
    fn vecadd_lowers_with_gep_load_fusion() {
        let w = wg(
            "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
                 size_t i = get_global_id(0);
                 c[i] = a[i] + b[i];
             }",
            [8, 1, 1],
        );
        let bc = w.bytecode.as_ref().expect("uniform kernel is coverable");
        assert_eq!(bc.reg_count, w.reg_fn.reg_count());
        assert_eq!(w.stats.bytecode_regions, w.stats.regions, "full coverage");
        assert!(w.stats.bytecode_fused > 0, "gep+load idioms fuse: {:?}", w.stats);
        let has_gepload = bc
            .regions
            .iter()
            .any(|r| r.code.iter().any(|i| matches!(i, BcInst::GepLoad { .. })));
        assert!(has_gepload, "{bc:?}");
        // Every region ends in End and branch targets stay in range.
        for r in &bc.regions {
            assert!(matches!(r.code.last(), Some(BcInst::End { .. })));
            for i in &r.code {
                match i {
                    BcInst::Jump { pc } => assert!((*pc as usize) < r.code.len()),
                    BcInst::Br { t, f, .. } | BcInst::CmpBr { t, f, .. } => {
                        assert!((*t as usize) < r.code.len());
                        assert!((*f as usize) < r.code.len());
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn divergent_region_is_not_lowered() {
        let w = wg(
            "__kernel void k(__global float *x, uint w) {
                 float v = x[get_global_id(0)];
                 if (get_global_id(0) > (size_t)w) { v = v * 2.0f; }
                 x[get_global_id(0)] = v;
             }",
            [8, 1, 1],
        );
        assert!(w.stats.divergent_regions >= 1);
        assert!(
            w.stats.bytecode_regions < w.stats.regions,
            "divergent regions stay uncovered: {:?}",
            w.stats
        );
    }

    #[test]
    fn uniform_loop_lowers_with_cmp_branch_fusion() {
        // `horizontal: false` keeps the reduction loop a plain uniform
        // inner loop (no implicit-barrier instrumentation), and the
        // `j * 2u` condition keeps the compare's producer non-adjacent so
        // the compare is still the last emission when the branch fuses.
        let m = compile(
            "__kernel void k(__global float *x, uint n) {
                 float acc = 0.0f;
                 for (uint j = 0u; j * 2u < n; j++) { acc = acc + x[j]; }
                 x[get_global_id(0)] = acc;
             }",
        )
        .unwrap();
        let k = m.kernels.into_iter().next().unwrap();
        let opts = CompileOptions { horizontal: false, ..Default::default() };
        let w = compile_workgroup(&k, [4, 1, 1], &opts).unwrap();
        let bc = w.bytecode.as_ref().expect("uniform loop is coverable");
        let has_cmpbr = bc
            .regions
            .iter()
            .any(|r| r.code.iter().any(|i| matches!(i, BcInst::CmpBr { .. })));
        assert!(has_cmpbr, "loop exit test fuses into cmp+branch: {bc:?}");
    }

    #[test]
    fn vector_build_ops_fall_back() {
        // Vector construction/swizzle instructions are outside the
        // supported set — the whole region stays with `vecgang`.
        let w = wg(
            "__kernel void vk(__global float4 *v) {
                 size_t i = get_global_id(0);
                 float4 a = v[i];
                 float4 b = (float4)(1.0f, 2.0f, 3.0f, 4.0f);
                 a = a * b + a.wzyx;
                 v[i] = a;
             }",
            [4, 1, 1],
        );
        assert_eq!(w.stats.bytecode_regions, 0, "{:?}", w.stats);
        assert!(w.bytecode.is_none());
    }
}
