//! The flattened bytecode program: the artifact the threaded-dispatch
//! engine executes (and `poclbin` v3 caches).
//!
//! One [`BcRegion`] per coverable parallel region of `reg_fn`: a linear
//! instruction array with branch targets pre-resolved to program-counter
//! indices and every operand pre-resolved to a *slot* — `slot <
//! reg_count` addresses the gang's register frame, anything above
//! addresses the region's constant pool (immediates, arguments, alloca
//! base pointers), which the engine materialises once per work-group.
//! The hottest adjacent-instruction idioms are fused into
//! superinstructions at lowering time, so one dispatch covers what cost
//! the region interpreters two.

use crate::ir::inst::{BinOp, BlockId, MathFn, SlotId, UnOp, WiFn};
use crate::ir::types::{Scalar, Type};

/// Operand slot: index into the register frame (`< reg_count`) or the
/// region's constant pool (`>= reg_count`, biased by `reg_count`).
pub type BcSlot = u32;

/// A constant-pool entry, resolved to a uniform [`crate::exec::VLane`]
/// once per work-group (arguments and slot bases are launch-invariant).
#[derive(Debug, Clone, PartialEq)]
pub enum BcConst {
    /// Integer immediate (normalised to `Scalar` at resolve time).
    Int(i64, Scalar),
    /// Float immediate (normalised to `Scalar` at resolve time).
    Float(f64, Scalar),
    /// Work-group function argument by index.
    Arg(u32),
    /// Base pointer of a private alloca slot.
    Slot(SlotId),
}

/// One flattened bytecode instruction. `dst`/operand fields are
/// [`BcSlot`]s; `t`/`f`/`pc` branch fields are indices into the owning
/// region's `code` array. `ir_t`/`ir_f` keep the original IR block
/// targets so a dynamically divergent branch can hand the gang's lanes
/// to the per-lane fallback (and so barrier targets stay identifiable).
#[derive(Debug, Clone, PartialEq)]
pub enum BcInst {
    /// `dst = a <op> b`.
    Bin { op: BinOp, ty: Type, dst: BcSlot, a: BcSlot, b: BcSlot },
    /// `dst = <op> a`.
    Un { op: UnOp, ty: Type, dst: BcSlot, a: BcSlot },
    /// `dst = (to) a`.
    Cast { to: Type, from: Type, dst: BcSlot, a: BcSlot },
    /// `dst = load ty, ptr`.
    Load { ty: Type, dst: BcSlot, ptr: BcSlot },
    /// `store val, ptr`.
    Store { ty: Type, ptr: BcSlot, val: BcSlot },
    /// `dst = base + idx * sizeof(elem)`.
    Gep { elem: Type, dst: BcSlot, base: BcSlot, idx: BcSlot },
    /// `dst = wi_fn(dim)`.
    Wi { func: WiFn, dim: u32, dst: BcSlot },
    /// `dst = math_fn(args...)`.
    Math { func: MathFn, ty: Type, dst: BcSlot, args: Vec<BcSlot> },
    /// `dst = cond ? a : b`.
    Select { ty: Type, dst: BcSlot, cond: BcSlot, a: BcSlot, b: BcSlot },
    /// Superinstruction: `dst = load ty, (base + idx * sizeof(elem))` —
    /// address calculation fused with the dependent load.
    GepLoad { elem: Type, ty: Type, dst: BcSlot, base: BcSlot, idx: BcSlot },
    /// Superinstruction: `t = load load_ty, ptr; dst = t <op> other`
    /// (`load_first` = the loaded value is the *left* operand).
    LoadBin {
        op: BinOp,
        ty: Type,
        load_ty: Type,
        dst: BcSlot,
        ptr: BcSlot,
        other: BcSlot,
        load_first: bool,
    },
    /// Superinstruction: `store (a <op> b), ptr` — binop feeding a store.
    BinStore { op: BinOp, ty: Type, store_ty: Type, ptr: BcSlot, a: BcSlot, b: BcSlot },
    /// Superinstruction: `dst = (a * b) + c` evaluated as the separate
    /// mul-then-add the IR wrote (never contracted to an FMA, so results
    /// stay bit-identical to the interpreters). `mul_first` = the product
    /// was the *left* operand of the add.
    MulAdd { ty: Type, dst: BcSlot, a: BcSlot, b: BcSlot, c: BcSlot, mul_first: bool },
    /// Superinstruction: compare-and-branch (`a <op> b ? t : f`).
    CmpBr {
        op: BinOp,
        ty: Type,
        a: BcSlot,
        b: BcSlot,
        t: u32,
        f: u32,
        ir_t: BlockId,
        ir_f: BlockId,
    },
    /// Unconditional jump to `pc` (only emitted when the target is not
    /// the fall-through instruction).
    Jump { pc: u32 },
    /// Conditional branch on an already-computed value.
    Br { cond: BcSlot, t: u32, f: u32, ir_t: BlockId, ir_f: BlockId },
    /// Region exit: the gang reached the barrier block `barrier`.
    End { barrier: BlockId },
}

/// One lowered parallel region.
#[derive(Debug, Clone, PartialEq)]
pub struct BcRegion {
    /// The IR block the region is entered from (the `Jump` target of its
    /// opening barrier block) — the engine keys fallback dispatch on it.
    pub start: BlockId,
    /// Constant pool; entry `i` is addressed as slot `reg_count + i`.
    pub consts: Vec<BcConst>,
    /// Flattened instruction stream; execution starts at `code[0]`.
    pub code: Vec<BcInst>,
}

/// A compiled bytecode program: every coverable region of one `reg_fn`.
#[derive(Debug, Clone, PartialEq)]
pub struct BytecodeProgram {
    /// Register-frame size the slots were resolved against (must equal
    /// the consuming `reg_fn`'s `reg_count`).
    pub reg_count: u32,
    /// Lowered regions (uncovered regions simply have no entry here).
    pub regions: Vec<BcRegion>,
}

impl BytecodeProgram {
    /// Total instructions across all regions (reported via `--stats`).
    pub fn inst_count(&self) -> usize {
        self.regions.iter().map(|r| r.code.len()).sum()
    }
}
