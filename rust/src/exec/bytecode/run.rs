//! The threaded-dispatch engine: a tight `loop { match }` over flattened
//! bytecode, one dispatch per (super)instruction per gang.
//!
//! Execution state is exactly the vector engine's: SoA [`VLane`] gang
//! values, a [`VecStore`] of private cells, the same uniform → SIMD-fast
//! → per-lane evaluation kernels — so results are bit-identical to every
//! other engine by construction. What changes is the dispatch cost:
//! operands are pre-resolved slot indices into a flat register frame (no
//! operand `match`, no per-region frame allocation — frames persist per
//! gang because registers are block-local), branch targets are program
//! counters, and the fused superinstructions retire two or three IR
//! instructions per dispatch.
//!
//! Fallback is per *region*: a region without lowered bytecode (divergent
//! control, unsupported ops) runs through
//! [`vecgang::run_gang_region_vec`] on the very same gang state. A
//! dynamically divergent branch inside bytecode hands the gang's lanes to
//! the shared per-lane path, exactly like the vector engine.

use crate::cl::error::{Error, Result};
use crate::ir::func::Function;
use crate::ir::inst::{BinOp, BlockId, Term};
use crate::kcc::WorkGroupFunction;

use super::super::gang::{note_barrier, run_lane_to_barrier, GangStats};
use super::super::interp::{LaunchCtx, SlotStore};
use super::super::mem::MemoryRefs;
use super::super::value::{norm_float, norm_int, Val, VLane, VVal, SP_PRIVATE};
use super::super::vecgang::{
    self, bin_vlane, cast_vlane, gep_vlane, load_vlane, math_vlane, select_vlane, store_vlane,
    un_vlane, wi_vlane, GangState, VecStore,
};
use super::prog::{BcConst, BcInst, BcSlot};

/// Execute one work-group through the bytecode tier in gangs of `width`
/// lanes. Widths outside [`vecgang::SUPPORTED_WIDTHS`] — and programs
/// with no lowered bytecode at all — degrade to the vector engine.
pub fn run_workgroup(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    width: usize,
) -> Result<GangStats> {
    match width {
        2 => run_wg::<2>(wgf, args, mem, ctx),
        4 => run_wg::<4>(wgf, args, mem, ctx),
        8 => run_wg::<8>(wgf, args, mem, ctx),
        16 => run_wg::<16>(wgf, args, mem, ctx),
        _ => vecgang::run_workgroup(wgf, args, mem, ctx, width),
    }
}

/// Per-gang persistent state: the vector engine's gang state (private
/// cells + lane ids — so falling back per region is free) plus the flat
/// register frame bytecode slots index into. The frame persists across
/// regions: registers are block-local (IR invariant), so no stale value
/// is ever read, and the per-region allocation the interpreters pay
/// disappears.
pub(crate) struct BcGang<const W: usize> {
    pub(crate) gs: GangState<W>,
    pub(crate) frame: Vec<VLane<W>>,
}

/// Resolve every region's constant pool once per work-group: launch
/// arguments, normalised immediates and private-slot base pointers are
/// all launch-invariant and gang-uniform. Shared with the JIT engine,
/// whose regions carry the same pools.
pub(crate) fn resolve_consts<const W: usize>(
    f: &Function,
    regions: &[super::prog::BcRegion],
    args: &[VVal],
) -> Vec<Vec<VLane<W>>> {
    let mut bases: Vec<u64> = Vec::with_capacity(f.slots.len());
    let mut total = 0u64;
    for s in &f.slots {
        bases.push(total);
        total += s.count as u64;
    }
    regions
        .iter()
        .map(|r| {
            r.consts
                .iter()
                .map(|c| match c {
                    BcConst::Int(v, s) => VLane::Uni(VVal::S(Val::I(norm_int(*v, *s)))),
                    BcConst::Float(v, s) => VLane::Uni(VVal::S(Val::F(norm_float(*v, *s)))),
                    BcConst::Arg(a) => VLane::Uni(args[*a as usize].clone()),
                    BcConst::Slot(s) => VLane::Uni(VVal::ptr(SP_PRIVATE, bases[s.0 as usize])),
                })
                .collect()
        })
        .collect()
}

fn run_wg<const W: usize>(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
) -> Result<GangStats> {
    let f = &wgf.reg_fn;
    // A missing program (non-CPU target, decode mismatch) or one lowered
    // against a different register frame degrades wholesale.
    let prog = match wgf.bytecode.as_ref().filter(|p| p.reg_count == f.reg_count()) {
        Some(p) => p,
        None => return vecgang::run_workgroup(wgf, args, mem, ctx, W),
    };

    // Region entry block → lowered-region index (fallback dispatch key).
    let mut region_of: Vec<Option<usize>> = vec![None; f.blocks.len()];
    for (i, r) in prog.regions.iter().enumerate() {
        if let Some(slot) = region_of.get_mut(r.start.0 as usize) {
            *slot = Some(i);
        }
    }

    let consts: Vec<Vec<VLane<W>>> = resolve_consts(f, &prog.regions, args);

    let n = wgf.wg_size();
    let [lx, ly, _lz] = wgf.local_size;
    let mut stats = GangStats::default();

    let local_id = |wi: usize| -> [u64; 3] {
        [(wi % lx) as u64, ((wi / lx) % ly) as u64, (wi / (lx * ly)) as u64]
    };

    // Same gang partition as the vector engine: full-width gangs through
    // bytecode, the ragged tail per-lane.
    let full_gangs = n / W;
    let mut gangs: Vec<BcGang<W>> = (0..full_gangs)
        .map(|g| BcGang {
            gs: GangState {
                store: VecStore::for_function(f),
                local_ids: std::array::from_fn(|l| local_id(g * W + l)),
            },
            frame: vec![VLane::Uni(VVal::i(0)); f.reg_count() as usize],
        })
        .collect();
    let mut tail: Vec<(SlotStore, [u64; 3])> = (full_gangs * W..n)
        .map(|wi| (SlotStore::for_function(f), local_id(wi)))
        .collect();

    // Barrier walk, identical to the interpreters.
    let mut cur: BlockId = f.entry;
    loop {
        let block = f.block(cur);
        debug_assert!(block.has_barrier());
        let start = match &block.term {
            Term::Ret => return Ok(stats),
            Term::Jump(s) => *s,
            Term::Br { .. } => return Err(Error::exec("barrier block with branch terminator")),
        };
        let region = region_of.get(start.0 as usize).copied().flatten();
        let mut next_barrier: Option<BlockId> = None;
        for gang in gangs.iter_mut() {
            stats.gangs += 1;
            let reached = match region {
                Some(ri) => {
                    stats.bytecode_gangs += 1;
                    let r = &prog.regions[ri];
                    run_region(f, &r.code, &consts[ri], args, mem, ctx, gang, &mut stats)?
                }
                None => {
                    stats.bytecode_fallbacks += 1;
                    vecgang::run_gang_region_vec(
                        f,
                        args,
                        mem,
                        ctx,
                        &mut gang.gs,
                        start,
                        &mut stats,
                    )?
                }
            };
            note_barrier(&mut next_barrier, reached, "across gangs")?;
        }
        if !tail.is_empty() {
            stats.gangs += 1;
        }
        for (store, lid) in tail.iter_mut() {
            let reached = run_lane_to_barrier(f, args, mem, ctx, store, start, *lid, &mut stats)?;
            note_barrier(&mut next_barrier, reached, "across gangs")?;
        }
        cur = next_barrier.expect("work-group is non-empty");
    }
}

/// Slot read: the frame for `slot < nregs`, the constant pool above.
#[inline]
fn rd<'a, const W: usize>(
    frame: &'a [VLane<W>],
    consts: &'a [VLane<W>],
    nregs: usize,
    s: BcSlot,
) -> &'a VLane<W> {
    let s = s as usize;
    if s < nregs {
        &frame[s]
    } else {
        &consts[s - nregs]
    }
}

/// Branch decision for the whole gang: `Ok(next_pc)` when the lanes
/// agree (uniform condition or dynamically converged packed lanes),
/// `Err(lane_targets)` on true divergence.
fn decide<const W: usize>(
    c: &VLane<W>,
    tpc: u32,
    fpc: u32,
    ir_t: BlockId,
    ir_f: BlockId,
) -> std::result::Result<usize, [BlockId; W]> {
    if let VLane::Uni(v) = c {
        return Ok(if v.scalar().truthy() { tpc } else { fpc } as usize);
    }
    let mut lane_targets = [ir_t; W];
    for (l, tgt) in lane_targets.iter_mut().enumerate() {
        *tgt = if c.get(l).scalar().truthy() { ir_t } else { ir_f };
    }
    if lane_targets.iter().all(|&x| x == lane_targets[0]) {
        Ok(if lane_targets[0] == ir_t { tpc } else { fpc } as usize)
    } else {
        Err(lane_targets)
    }
}

/// Divergence fallback: flush the gang to per-lane stores, run each lane
/// from its branch target to the region's closing barrier on the shared
/// per-lane path, re-import (re-uniforming identical lanes) — the exact
/// sequence the vector engine runs on a divergent branch.
/// Shared with the JIT engine (same gang state, same protocol).
#[allow(clippy::too_many_arguments)]
pub(crate) fn diverge<const W: usize>(
    f: &Function,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    gs: &mut GangState<W>,
    lane_targets: &[BlockId; W],
    stats: &mut GangStats,
) -> Result<BlockId> {
    stats.diverged += 1;
    let mut stores = gs.store.split();
    let mut reached: Option<BlockId> = None;
    for (l, store) in stores.iter_mut().enumerate() {
        let bar = run_lane_to_barrier(
            f,
            args,
            mem,
            ctx,
            store,
            lane_targets[l],
            gs.local_ids[l],
            stats,
        )?;
        note_barrier(&mut reached, bar, "within gang")?;
    }
    gs.store.merge(&stores);
    Ok(reached.expect("gang is non-empty"))
}

/// The dispatch loop: run one gang through one lowered region, from
/// `code[0]` to an `End` (or a divergent branch's per-lane finish).
/// Returns the barrier block the gang reached.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_region<const W: usize>(
    f: &Function,
    code: &[BcInst],
    consts: &[VLane<W>],
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    gang: &mut BcGang<W>,
    stats: &mut GangStats,
) -> Result<BlockId> {
    let nregs = gang.frame.len();
    let mut pc = 0usize;
    loop {
        match &code[pc] {
            BcInst::Bin { op, ty, dst, a, b } => {
                let v = bin_vlane(
                    *op,
                    ty,
                    rd(&gang.frame, consts, nregs, *a),
                    rd(&gang.frame, consts, nregs, *b),
                )?
                .0;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Un { op, ty, dst, a } => {
                let v = un_vlane(*op, ty, rd(&gang.frame, consts, nregs, *a))?.0;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Cast { to, from, dst, a } => {
                let v = cast_vlane(to, from, rd(&gang.frame, consts, nregs, *a)).0;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Load { ty, dst, ptr } => {
                let v = load_vlane(
                    rd(&gang.frame, consts, nregs, *ptr),
                    ty,
                    &gang.gs.store,
                    mem,
                )?;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Store { ty, ptr, val } => {
                store_vlane(
                    rd(&gang.frame, consts, nregs, *ptr),
                    rd(&gang.frame, consts, nregs, *val),
                    ty,
                    &mut gang.gs.store,
                    mem,
                )?;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Gep { elem, dst, base, idx } => {
                let v = gep_vlane(
                    elem,
                    rd(&gang.frame, consts, nregs, *base),
                    rd(&gang.frame, consts, nregs, *idx),
                )?
                .0;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Wi { func, dim, dst } => {
                let v = wi_vlane(*func, *dim, ctx, &gang.gs.local_ids).0;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Math { func, ty, dst, args: margs } => {
                let ops: Vec<&VLane<W>> =
                    margs.iter().map(|s| rd(&gang.frame, consts, nregs, *s)).collect();
                let v = math_vlane(*func, ty, &ops)?.0;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::Select { ty, dst, cond, a, b } => {
                let v = select_vlane(
                    ty,
                    rd(&gang.frame, consts, nregs, *cond),
                    rd(&gang.frame, consts, nregs, *a),
                    rd(&gang.frame, consts, nregs, *b),
                )?
                .0;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::GepLoad { elem, ty, dst, base, idx } => {
                let p = gep_vlane(
                    elem,
                    rd(&gang.frame, consts, nregs, *base),
                    rd(&gang.frame, consts, nregs, *idx),
                )?
                .0;
                let v = load_vlane(&p, ty, &gang.gs.store, mem)?;
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::LoadBin { op, ty, load_ty, dst, ptr, other, load_first } => {
                let lv = load_vlane(
                    rd(&gang.frame, consts, nregs, *ptr),
                    load_ty,
                    &gang.gs.store,
                    mem,
                )?;
                let v = if *load_first {
                    bin_vlane(*op, ty, &lv, rd(&gang.frame, consts, nregs, *other))?.0
                } else {
                    bin_vlane(*op, ty, rd(&gang.frame, consts, nregs, *other), &lv)?.0
                };
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::BinStore { op, ty, store_ty, ptr, a, b } => {
                let v = bin_vlane(
                    *op,
                    ty,
                    rd(&gang.frame, consts, nregs, *a),
                    rd(&gang.frame, consts, nregs, *b),
                )?
                .0;
                store_vlane(
                    rd(&gang.frame, consts, nregs, *ptr),
                    &v,
                    store_ty,
                    &mut gang.gs.store,
                    mem,
                )?;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::MulAdd { ty, dst, a, b, c, mul_first } => {
                // Separate mul-then-add, never contracted to an FMA, so
                // results stay bit-identical to the interpreters.
                let m = bin_vlane(
                    BinOp::Mul,
                    ty,
                    rd(&gang.frame, consts, nregs, *a),
                    rd(&gang.frame, consts, nregs, *b),
                )?
                .0;
                let v = if *mul_first {
                    bin_vlane(BinOp::Add, ty, &m, rd(&gang.frame, consts, nregs, *c))?.0
                } else {
                    bin_vlane(BinOp::Add, ty, rd(&gang.frame, consts, nregs, *c), &m)?.0
                };
                gang.frame[*dst as usize] = v;
                stats.bytecode_insts += 1;
                pc += 1;
            }
            BcInst::CmpBr { op, ty, a, b, t, f: fpc, ir_t, ir_f } => {
                let cv = bin_vlane(
                    *op,
                    ty,
                    rd(&gang.frame, consts, nregs, *a),
                    rd(&gang.frame, consts, nregs, *b),
                )?
                .0;
                stats.bytecode_insts += 1;
                match decide(&cv, *t, *fpc, *ir_t, *ir_f) {
                    Ok(npc) => pc = npc,
                    Err(lt) => return diverge(f, args, mem, ctx, &mut gang.gs, &lt, stats),
                }
            }
            BcInst::Jump { pc: target } => pc = *target as usize,
            BcInst::Br { cond, t, f: fpc, ir_t, ir_f } => {
                let d = decide(
                    rd(&gang.frame, consts, nregs, *cond),
                    *t,
                    *fpc,
                    *ir_t,
                    *ir_f,
                );
                match d {
                    Ok(npc) => pc = npc,
                    Err(lt) => return diverge(f, args, mem, ctx, &mut gang.gs, &lt, stats),
                }
            }
            BcInst::End { barrier } => return Ok(*barrier),
        }
    }
}
