//! The core IR interpreter: executes one invocation of a function over
//! one "lane" (a work-item, or a whole work-group function).
//!
//! All engines share `Machine` (the instruction evaluator); they differ in
//! *scheduling*: the serial engine runs the WI-loop-materialised function
//! straight through, the fiber engine round-robins work-items between
//! barriers, and the gang engine steps regions in lane-lockstep.

use crate::cl::error::{Error, Result};
use crate::ir::func::Function;
use crate::ir::inst::{BinOp, BlockId, Imm, Inst, MathFn, Operand, SlotId, Term, UnOp, WiFn};
use crate::ir::types::{Scalar, Type};
use crate::vecmath::{scalar32, scalar64};

use super::mem::MemoryRefs;
use super::value::{norm_float, norm_int, space_tag, Val, VVal, SP_PRIVATE};

/// Launch geometry shared by all engines.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaunchCtx {
    /// Work-group id per dimension.
    pub group_id: [u64; 3],
    /// Number of work-groups per dimension.
    pub num_groups: [u64; 3],
    /// Global offset per dimension.
    pub global_offset: [u64; 3],
    /// Local size per dimension.
    pub local_size: [usize; 3],
    /// Work dimension (1–3).
    pub work_dim: u32,
}

/// Private-variable storage: each slot is a contiguous run of cells in one
/// flat vector (layout computed once per function).
pub struct SlotStore {
    /// Cell values.
    pub cells: Vec<VVal>,
    /// Slot → first cell index.
    pub base: Vec<u32>,
}

impl SlotStore {
    /// Allocate storage for a function's slots.
    pub fn for_function(f: &Function) -> SlotStore {
        let mut base = Vec::with_capacity(f.slots.len());
        let mut total = 0u32;
        for s in &f.slots {
            base.push(total);
            total += s.count as u32;
        }
        SlotStore { cells: vec![VVal::S(Val::I(0)); total as usize], base }
    }

    /// Base cell index of a slot.
    pub fn slot_base(&self, s: SlotId) -> u64 {
        self.base[s.0 as usize] as u64
    }
}

/// The instruction evaluator: a register frame bound to argument values,
/// slot storage, memory, and a launch context + local id.
pub struct Machine<'m, 'a> {
    /// Register values (indexed by register number).
    pub regs: Vec<VVal>,
    /// Argument values.
    pub args: &'a [VVal],
    /// Private cells.
    pub slots: &'a mut SlotStore,
    /// Global/local memory.
    pub mem: &'a mut MemoryRefs<'m>,
    /// Launch geometry.
    pub ctx: &'a LaunchCtx,
    /// The local id this machine evaluates `get_local_id` to (engines that
    /// run pre-materialisation IR set this per work-item; the serial
    /// engine never sees `Wi` instructions).
    pub local_id: [u64; 3],
}

/// Where control went after executing a block.
pub enum Flow {
    /// Jumped to the given block.
    Goto(BlockId),
    /// Function returned.
    Done,
    /// Execution stopped at a barrier instruction inside the block
    /// (engines that run barrier-carrying IR): the block and instruction
    /// index of the barrier.
    AtBarrier(BlockId),
}

impl<'m, 'a> Machine<'m, 'a> {
    /// Create a machine with a frame sized for `f`.
    pub fn new(
        f: &Function,
        args: &'a [VVal],
        slots: &'a mut SlotStore,
        mem: &'a mut MemoryRefs<'m>,
        ctx: &'a LaunchCtx,
    ) -> Machine<'m, 'a> {
        Machine {
            regs: vec![VVal::S(Val::I(0)); f.reg_count() as usize],
            args,
            slots,
            mem,
            ctx,
            local_id: [0; 3],
        }
    }

    /// Run from `entry` until `Ret`, ignoring barriers (they must have
    /// been compiled away — loop_fn path).
    pub fn run(&mut self, f: &Function, entry: BlockId) -> Result<()> {
        let mut cur = entry;
        let mut steps = 0usize;
        loop {
            match self.exec_block(f, cur, false)? {
                Flow::Goto(b) => cur = b,
                Flow::Done => return Ok(()),
                Flow::AtBarrier(_) => {
                    return Err(Error::exec("unexpected barrier in materialised function"))
                }
            }
            steps += 1;
            if steps > 1_000_000_000 {
                return Err(Error::exec("kernel exceeded block-step budget (infinite loop?)"));
            }
        }
    }

    /// Execute a single block. If `stop_at_barrier`, returns
    /// `Flow::AtBarrier` when a barrier instruction is met (the barrier
    /// block's successor is where execution should resume).
    pub fn exec_block(&mut self, f: &Function, bb: BlockId, stop_at_barrier: bool) -> Result<Flow> {
        let block = f.block(bb);
        for (def, inst) in &block.insts {
            if inst.is_barrier() {
                if stop_at_barrier {
                    return Ok(Flow::AtBarrier(bb));
                }
                continue;
            }
            let v = self.eval(f, inst)?;
            if let Some(r) = def {
                self.regs[r.0 as usize] = v;
            }
        }
        match &block.term {
            Term::Jump(t) => Ok(Flow::Goto(*t)),
            Term::Br { cond, t, f: fb } => {
                let c = self.operand(cond).scalar().truthy();
                Ok(Flow::Goto(if c { *t } else { *fb }))
            }
            Term::Ret => Ok(Flow::Done),
        }
    }

    /// Operand → value.
    #[inline]
    pub fn operand(&self, op: &Operand) -> VVal {
        match op {
            Operand::Reg(r) => self.regs[r.0 as usize].clone(),
            Operand::Imm(Imm::Int(v, s)) => VVal::S(Val::I(norm_int(*v, *s))),
            Operand::Imm(Imm::Float(v, s)) => VVal::S(Val::F(norm_float(*v, *s))),
            Operand::Arg(a) => self.args[*a as usize].clone(),
            Operand::Slot(s) => VVal::ptr(SP_PRIVATE, self.slots.slot_base(*s)),
        }
    }

    /// Evaluate one (non-barrier, non-terminator) instruction.
    pub fn eval(&mut self, f: &Function, inst: &Inst) -> Result<VVal> {
        match inst {
            Inst::Bin { op, ty, a, b } => {
                let (av, bv) = (self.operand(a), self.operand(b));
                eval_bin(*op, ty, &av, &bv)
            }
            Inst::Un { op, ty, a } => {
                let av = self.operand(a);
                eval_un(*op, ty, &av)
            }
            Inst::Cast { to, from, a } => {
                let av = self.operand(a);
                Ok(eval_cast(&av, from, to))
            }
            Inst::Load { ty, ptr } => {
                let p = self.operand(ptr).scalar();
                match p {
                    Val::Ptr { space: SP_PRIVATE, offset } => {
                        Ok(self.slots.cells[offset as usize].clone())
                    }
                    Val::Ptr { space, offset } => self.mem.load(space, offset, ty),
                    _ => Err(Error::exec("load through non-pointer")),
                }
            }
            Inst::Store { ty, ptr, val } => {
                let p = self.operand(ptr).scalar();
                let v = self.operand(val);
                let v = normalize_to(&v, ty);
                match p {
                    Val::Ptr { space: SP_PRIVATE, offset } => {
                        let cell = self
                            .slots
                            .cells
                            .get_mut(offset as usize)
                            .ok_or_else(|| Error::exec("private store out of bounds"))?;
                        *cell = v;
                        Ok(VVal::i(0))
                    }
                    Val::Ptr { space, offset } => {
                        self.mem.store(space, offset, ty, &v)?;
                        Ok(VVal::i(0))
                    }
                    _ => Err(Error::exec("store through non-pointer")),
                }
            }
            Inst::Gep { elem, base, idx } => {
                let b = self.operand(base).scalar();
                let i = self.operand(idx).scalar().as_i();
                match b {
                    Val::Ptr { space: SP_PRIVATE, offset } => {
                        // Private memory is cell-addressed.
                        Ok(VVal::ptr(SP_PRIVATE, (offset as i64 + i) as u64))
                    }
                    Val::Ptr { space, offset } => {
                        let off = offset as i64 + i * elem.size() as i64;
                        Ok(VVal::ptr(space, off as u64))
                    }
                    _ => Err(Error::exec("gep on non-pointer")),
                }
            }
            Inst::Wi { func, dim } => {
                Ok(VVal::i(wi_value(*func, *dim, self.ctx, &self.local_id) as i64))
            }
            Inst::Math { func, ty, args } => {
                let vals: Vec<VVal> = args.iter().map(|a| self.operand(a)).collect();
                eval_math(*func, ty, &vals)
            }
            Inst::Select { ty, cond, a, b } => {
                let c = self.operand(cond);
                let (av, bv) = (self.operand(a), self.operand(b));
                let lanes = ty.lanes();
                if lanes == 1 {
                    Ok(if c.scalar().truthy() { av } else { bv })
                } else {
                    let out: Vec<Val> = (0..lanes)
                        .map(|l| {
                            let cl = if c.lanes() == 1 { c.lane(0) } else { c.lane(l) };
                            if cl.truthy() {
                                av.lane(l)
                            } else {
                                bv.lane(l)
                            }
                        })
                        .collect();
                    Ok(VVal::V(out))
                }
            }
            Inst::VecBuild { ty, elems } => {
                let s = ty.elem_scalar().unwrap();
                let out: Vec<Val> =
                    elems.iter().map(|e| norm_val(self.operand(e).scalar(), s)).collect();
                Ok(VVal::V(out))
            }
            Inst::VecExtract { a, lane, .. } => {
                let v = self.operand(a);
                Ok(VVal::S(v.lane(*lane as usize)))
            }
            Inst::VecInsert { a, lane, v, .. } => {
                let mut base = match self.operand(a) {
                    VVal::V(l) => l,
                    VVal::S(s) => vec![s],
                };
                let nv = self.operand(v).scalar();
                base[*lane as usize] = nv;
                Ok(VVal::V(base))
            }
            Inst::Splat { ty, a } => {
                let s = ty.elem_scalar().unwrap();
                let v = norm_val(self.operand(a).scalar(), s);
                Ok(VVal::V(vec![v; ty.lanes()]))
            }
            Inst::Barrier { .. } | Inst::Marker { .. } => Ok(VVal::i(0)),
        }
        .map_err(|e| add_ctx(e, f, inst))
    }
}

fn add_ctx(e: Error, f: &Function, inst: &Inst) -> Error {
    match e {
        Error::Exec(m) => Error::Exec(format!("{m} (in `{}`, inst {:?})", f.name, inst)),
        other => other,
    }
}

/// Evaluate a work-item geometry query for one work-item. Shared by the
/// scalar machine and the lane-batched vector machine so every engine
/// derives ids from the same formulas.
pub fn wi_value(func: WiFn, dim: u32, ctx: &LaunchCtx, local_id: &[u64; 3]) -> u64 {
    let d = dim.min(2) as usize;
    match func {
        WiFn::LocalId => local_id[d],
        WiFn::GroupId => ctx.group_id[d],
        WiFn::GlobalId => {
            ctx.group_id[d] * ctx.local_size[d] as u64 + local_id[d] + ctx.global_offset[d]
        }
        WiFn::LocalSize => ctx.local_size[d] as u64,
        WiFn::GlobalSize => ctx.num_groups[d] * ctx.local_size[d] as u64,
        WiFn::NumGroups => ctx.num_groups[d],
        WiFn::GlobalOffset => ctx.global_offset[d],
        WiFn::WorkDim => ctx.work_dim as u64,
    }
}

/// Normalise a value to a scalar type (int widths wrap, floats round).
pub fn norm_val(v: Val, s: Scalar) -> Val {
    match (v, s.is_float()) {
        (Val::I(i), false) => Val::I(norm_int(i, s)),
        (Val::I(i), true) => Val::F(norm_float(i as f64, s)),
        (Val::F(f), true) => Val::F(norm_float(f, s)),
        (Val::F(f), false) => Val::I(norm_int(f as i64, s)),
        (p @ Val::Ptr { .. }, _) => p,
    }
}

/// Normalise a (possibly vector) value to a type's element scalar — the
/// rounding/wrapping every store applies before hitting memory.
pub fn normalize_to(v: &VVal, ty: &Type) -> VVal {
    let Some(s) = ty.elem_scalar() else { return v.clone() };
    match v {
        VVal::S(x) => VVal::S(norm_val(*x, s)),
        VVal::V(l) => VVal::V(l.iter().map(|x| norm_val(*x, s)).collect()),
    }
}

/// Binary op over scalars or lane-wise over vectors (with scalar
/// broadcast).
pub fn eval_bin(op: BinOp, ty: &Type, a: &VVal, b: &VVal) -> Result<VVal> {
    let lanes = ty.lanes().max(a.lanes()).max(b.lanes());
    let s = ty.elem_scalar().unwrap_or(Scalar::I32);
    if lanes == 1 {
        return Ok(VVal::S(bin_scalar(op, s, a.scalar(), b.scalar())?));
    }
    let mut out = Vec::with_capacity(lanes);
    for l in 0..lanes {
        let al = if a.lanes() == 1 { a.lane(0) } else { a.lane(l) };
        let bl = if b.lanes() == 1 { b.lane(0) } else { b.lane(l) };
        out.push(bin_scalar(op, s, al, bl)?);
    }
    Ok(VVal::V(out))
}

/// Binary op on two scalar values (the per-lane kernel of [`eval_bin`]).
pub fn bin_scalar(op: BinOp, s: Scalar, a: Val, b: Val) -> Result<Val> {
    use BinOp::*;
    if s.is_float() && !matches!(op, And | Or | Xor | Shl | Shr) {
        let (x, y) = (a.as_f(), b.as_f());
        let r = match op {
            Add => x + y,
            Sub => x - y,
            Mul => x * y,
            Div => x / y,
            Rem => x % y,
            Eq => return Ok(Val::I((x == y) as i64)),
            Ne => return Ok(Val::I((x != y) as i64)),
            Lt => return Ok(Val::I((x < y) as i64)),
            Le => return Ok(Val::I((x <= y) as i64)),
            Gt => return Ok(Val::I((x > y) as i64)),
            Ge => return Ok(Val::I((x >= y) as i64)),
            LAnd => return Ok(Val::I((x != 0.0 && y != 0.0) as i64)),
            LOr => return Ok(Val::I((x != 0.0 || y != 0.0) as i64)),
            _ => unreachable!(),
        };
        return Ok(Val::F(norm_float(r, s)));
    }
    let (x, y) = (norm_int(a.as_i(), s), norm_int(b.as_i(), s));
    let unsigned = matches!(s, Scalar::U32 | Scalar::U64 | Scalar::Bool);
    let r = match op {
        Add => x.wrapping_add(y),
        Sub => x.wrapping_sub(y),
        Mul => x.wrapping_mul(y),
        Div => {
            if y == 0 {
                return Err(Error::exec("integer division by zero"));
            }
            if unsigned {
                ((x as u64) / (y as u64)) as i64
            } else {
                x.wrapping_div(y)
            }
        }
        Rem => {
            if y == 0 {
                return Err(Error::exec("integer remainder by zero"));
            }
            if unsigned {
                ((x as u64) % (y as u64)) as i64
            } else {
                x.wrapping_rem(y)
            }
        }
        And => x & y,
        Or => x | y,
        Xor => x ^ y,
        Shl => x.wrapping_shl(y as u32),
        Shr => {
            if unsigned {
                ((x as u64) >> (y as u64 & 63)) as i64
            } else {
                x >> (y & 63)
            }
        }
        Eq => return Ok(Val::I((x == y) as i64)),
        Ne => return Ok(Val::I((x != y) as i64)),
        Lt | Le | Gt | Ge => {
            let c = if unsigned {
                let (ux, uy) = (x as u64, y as u64);
                match op {
                    Lt => ux < uy,
                    Le => ux <= uy,
                    Gt => ux > uy,
                    _ => ux >= uy,
                }
            } else {
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    _ => x >= y,
                }
            };
            return Ok(Val::I(c as i64));
        }
        LAnd => return Ok(Val::I((x != 0 && y != 0) as i64)),
        LOr => return Ok(Val::I((x != 0 || y != 0) as i64)),
    };
    Ok(Val::I(norm_int(r, s)))
}

/// Unary op over scalars or lane-wise over vectors.
pub fn eval_un(op: UnOp, ty: &Type, a: &VVal) -> Result<VVal> {
    let s = ty.elem_scalar().unwrap_or(Scalar::I32);
    let f = |v: Val| -> Val {
        match op {
            UnOp::Neg => {
                if s.is_float() {
                    Val::F(-v.as_f())
                } else {
                    Val::I(norm_int(v.as_i().wrapping_neg(), s))
                }
            }
            UnOp::Not => Val::I(norm_int(!v.as_i(), s)),
            UnOp::LNot => Val::I(!v.truthy() as i64),
        }
    };
    Ok(match a {
        VVal::S(v) => VVal::S(f(*v)),
        VVal::V(l) => VVal::V(l.iter().map(|v| f(*v)).collect()),
    })
}

/// Numeric conversion to `to` (scalar-to-vector casts splat).
pub fn eval_cast(a: &VVal, _from: &Type, to: &Type) -> VVal {
    let Some(s) = to.elem_scalar() else { return a.clone() };
    let conv = |v: Val| norm_val(v, s);
    match (a, to.lanes()) {
        (VVal::S(v), 1) => VVal::S(conv(*v)),
        (VVal::S(v), n) => VVal::V(vec![conv(*v); n]),
        (VVal::V(l), _) => VVal::V(l.iter().map(|v| conv(*v)).collect()),
    }
}

/// Math builtin dispatch — scalar fns from `vecmath` applied lane-wise
/// (the Vecmathlib linkage of §5).
pub fn eval_math(func: MathFn, ty: &Type, args: &[VVal]) -> Result<VVal> {
    use MathFn::*;
    let s = ty.elem_scalar().unwrap_or(Scalar::F32);
    let lanes = ty.lanes();
    // Reductions over vectors first.
    match func {
        Dot => {
            let mut acc = 0.0f64;
            for l in 0..args[0].lanes() {
                acc += args[0].lane(l).as_f() * args[1].lane(l).as_f();
            }
            return Ok(VVal::S(Val::F(norm_float(acc, s))));
        }
        Length => {
            let mut acc = 0.0f64;
            for l in 0..args[0].lanes() {
                let v = args[0].lane(l).as_f();
                acc += v * v;
            }
            return Ok(VVal::S(Val::F(norm_float(acc.sqrt(), s))));
        }
        Distance => {
            let mut acc = 0.0f64;
            for l in 0..args[0].lanes() {
                let d = args[0].lane(l).as_f() - args[1].lane(l).as_f();
                acc += d * d;
            }
            return Ok(VVal::S(Val::F(norm_float(acc.sqrt(), s))));
        }
        Normalize => {
            let mut acc = 0.0f64;
            for l in 0..args[0].lanes() {
                let v = args[0].lane(l).as_f();
                acc += v * v;
            }
            let inv = 1.0 / acc.sqrt();
            let out: Vec<Val> = (0..args[0].lanes())
                .map(|l| Val::F(norm_float(args[0].lane(l).as_f() * inv, s)))
                .collect();
            return Ok(VVal::V(out));
        }
        _ => {}
    }
    let lane_of = |a: &VVal, l: usize| if a.lanes() == 1 { a.lane(0) } else { a.lane(l) };
    let mut out = Vec::with_capacity(lanes);
    for l in 0..lanes {
        let v = math_scalar(func, s, args, |i| lane_of(&args[i], l))?;
        out.push(v);
    }
    Ok(if lanes == 1 { VVal::S(out[0]) } else { VVal::V(out) })
}

fn math_scalar(
    func: MathFn,
    s: Scalar,
    _args: &[VVal],
    get: impl Fn(usize) -> Val,
) -> Result<Val> {
    use MathFn::*;
    // Integer builtins.
    if s.is_int() {
        let a = get(0).as_i();
        return Ok(Val::I(norm_int(
            match func {
                Min => a.min(get(1).as_i()),
                Max => a.max(get(1).as_i()),
                Clamp => a.max(get(1).as_i()).min(get(2).as_i()),
                Abs => a.abs(),
                _ => return Err(Error::exec(format!("{func:?} on integer type"))),
            },
            s,
        )));
    }
    let x = get(0).as_f();
    let f64p = s == Scalar::F64;
    let r = match func {
        Sqrt => x.sqrt(),
        RSqrt | NativeRSqrt => 1.0 / x.sqrt(),
        NativeSqrt => x.sqrt(),
        Exp | NativeExp => {
            if f64p {
                scalar64::exp(x)
            } else {
                scalar32::exp(x as f32) as f64
            }
        }
        Exp2 => {
            if f64p {
                scalar64::exp(x * core::f64::consts::LN_2)
            } else {
                scalar32::exp2(x as f32) as f64
            }
        }
        Log | NativeLog => {
            if f64p {
                scalar64::log(x)
            } else {
                scalar32::log(x as f32) as f64
            }
        }
        Log2 => {
            if f64p {
                scalar64::log(x) * core::f64::consts::LOG2_E
            } else {
                scalar32::log2(x as f32) as f64
            }
        }
        Sin | NativeSin => {
            if f64p {
                scalar64::sin(x)
            } else {
                scalar32::sin(x as f32) as f64
            }
        }
        Cos | NativeCos => {
            if f64p {
                scalar64::cos(x)
            } else {
                scalar32::cos(x as f32) as f64
            }
        }
        Tan => {
            if f64p {
                scalar64::sin(x) / scalar64::cos(x)
            } else {
                scalar32::tan(x as f32) as f64
            }
        }
        Fabs => {
            if f64p {
                scalar64::fabs(x)
            } else {
                scalar32::fabs(x as f32) as f64
            }
        }
        Floor => x.floor(),
        Ceil => x.ceil(),
        Round => x.round(),
        Trunc => x.trunc(),
        Pow => {
            if f64p {
                scalar64::pow(x, get(1).as_f())
            } else {
                scalar32::pow(x as f32, get(1).as_f() as f32) as f64
            }
        }
        Fmin | Min => x.min(get(1).as_f()),
        Fmax | Max => x.max(get(1).as_f()),
        Fmod => x % get(1).as_f(),
        Mad | Fma => x * get(1).as_f() + get(2).as_f(),
        Clamp => x.max(get(1).as_f()).min(get(2).as_f()),
        Abs => x.abs(),
        Mix => {
            let (y, a) = (get(1).as_f(), get(2).as_f());
            x + (y - x) * a
        }
        NativeDivide => x / get(1).as_f(),
        NativeRecip => 1.0 / x,
        Dot | Length | Normalize | Distance => unreachable!("handled above"),
    };
    Ok(Val::F(norm_float(r, s)))
}
