//! Template JIT tier — stage (b) of the native-code tier: x86-64
//! machine code for the hot parallel regions, no LLVM.
//!
//! The bytecode tier removed per-instruction operand marshalling but
//! still pays one interpreter dispatch per (super)instruction per
//! gang. This tier removes the dispatch too: the lowerer walks the
//! *bytecode* form of each region (operand slots and PC branch targets
//! already resolved, superinstructions already fused) and emits a
//! template of hand-encoded x86-64 per instruction — gang-strided
//! loads/stores over a flat `u64` payload frame, inline int/float
//! arithmetic, compares, casts and bounds-checked global/local memory
//! access — into an `mmap`ed W^X code buffer (`emit::ExecMem`:
//! written read-write, flipped to read-execute, never both).
//!
//! Anything the templates do not cover (math elementals, divisions,
//! vector values, private-memory cells, …) is dispatched through a
//! per-region helper table back into the shared `vecgang` kernels, so
//! results stay bit-identical to every interpreter tier. Whole regions
//! the lowerer rejects keep running on the bytecode tier; dynamically
//! divergent branches hand their lanes to the same per-lane fallback
//! every other engine uses.
//!
//! The tier is compiled out on non-x86-64 (or non-Linux) hosts: this
//! module then exports a stub [`JitProgram`] plus an [`attach`] no-op,
//! and [`run_workgroup`] degrades wholesale to the bytecode engine.
//! `POCLRS_JIT=0` is the runtime kill switch (checked at attach time).

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
mod emit;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
mod lower;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
mod run;

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub use lower::JitProgram;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub use run::run_workgroup;

use crate::kcc::WorkGroupFunction;

/// Lower `wgf`'s bytecode program to machine code for `gang_width`
/// lanes and attach the result, updating the compile-time jit counters
/// in `wgf.stats`. No-op when a jit program is already attached, when
/// there is no bytecode to lower from, when `POCLRS_JIT=0`, or (on
/// unsupported hosts) always — uncovered regions are reported through
/// `stats.jit_fallbacks` so `--stats` shows why nothing was jitted.
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub fn attach(wgf: &mut WorkGroupFunction, gang_width: usize) {
    if wgf.jit.is_some() {
        return;
    }
    let prog = match wgf.bytecode.as_ref() {
        Some(p) => p,
        None => return,
    };
    if std::env::var("POCLRS_JIT").ok().as_deref() == Some("0") {
        wgf.stats.jit_fallbacks = prog.regions.len();
        return;
    }
    match lower::lower(&wgf.reg_fn, prog, gang_width) {
        Some((jp, st)) => {
            wgf.stats.jit_regions = st.regions;
            wgf.stats.jit_insts = st.insts;
            wgf.stats.jit_fallbacks = st.fallbacks;
            wgf.jit = Some(std::sync::Arc::new(jp));
        }
        None => {
            wgf.stats.jit_fallbacks = prog.regions.len();
        }
    }
}

// ---------------------------------------------------------------------
// Stubs for hosts without jit support: same public surface, wholesale
// degradation to the bytecode tier.

/// Stub jit program for hosts the tier is compiled out on.
#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
#[derive(Debug)]
pub struct JitProgram;

#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
impl JitProgram {
    /// Number of regions that were actually jitted (always zero here).
    pub fn covered_regions(&self) -> usize {
        0
    }
}

/// Stub attach: never jits, reports every bytecode region as a jit
/// fallback so `--stats` stays honest on unsupported hosts.
#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
pub fn attach(wgf: &mut WorkGroupFunction, _gang_width: usize) {
    if let Some(p) = wgf.bytecode.as_ref() {
        wgf.stats.jit_fallbacks = p.regions.len();
    }
}

/// Stub runner: the jit engine degrades wholesale to the bytecode tier
/// on hosts the templates are compiled out on.
#[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
pub fn run_workgroup(
    wgf: &WorkGroupFunction,
    args: &[super::value::VVal],
    mem: &mut super::mem::MemoryRefs<'_>,
    ctx: &super::interp::LaunchCtx,
    width: usize,
) -> crate::cl::error::Result<super::gang::GangStats> {
    super::bytecode::run_workgroup(wgf, args, mem, ctx, width)
}
