//! Template lowering: bytecode regions → x86-64 machine code.
//!
//! Each [`BcRegion`] is lowered independently: every instruction either
//! gets an *inline template* (a fixed per-lane instruction sequence over
//! the slot-major `u64` payload frame), a *helper dispatch* (a call into
//! the shared `vecgang` evaluation kernels through a pre-built [`Desc`],
//! used for everything whose semantics are too subtle to re-encode —
//! math elementals, divisions, private-memory traffic, selects), or —
//! if neither is sound — rejects the whole region, which then keeps
//! running on the bytecode tier (`jit_fallbacks` counts these).
//!
//! # Payload frame and kinds
//!
//! The JIT frame is a flat `u64` array, slot-major: payload of slot `s`
//! lane `l` lives at `frame[s * W + l]`. Slot indices are exactly the
//! bytecode's [`BcSlot`]s (registers, then the region's constant pool,
//! then one scratch slot used to de-fuse superinstructions). Each
//! payload is the bit pattern of the value the interpreters would hold:
//! normalised integers as two's complement, floats as `f64` bits,
//! pointers as their offset. A static, per-region *kind* inference
//! (sound because bytecode registers are block-local, so every def
//! precedes its uses in PC order) assigns each slot `I`/`F`/pointer
//! kinds; any read of a kindless slot rejects the region.
//!
//! Private (alloca) memory stays inside the gang's `VecStore` cells and
//! is only touched through helper dispatches. For private *loads* the
//! result kind comes from a whole-function provenance scan
//! ([`alloca_classes`]) that proves which cells only ever hold one
//! payload class; cells that might be punned demote the loads (and with
//! them the region) to the bytecode tier.
//!
//! # Counters and errors
//!
//! Executed-instruction counts are accumulated into the context's
//! `insts` field in batches (flushed at every branch, helper call and
//! region exit), mirroring the bytecode engine's `bytecode_insts`.
//! Error paths are approximate by one batch: a bounds fault or helper
//! error aborts the region, and aborted launches only report stats on a
//! best-effort basis.

use crate::exec::value::{space_tag, SP_PRIVATE};
use crate::ir::func::Function;
use crate::ir::inst::{BinOp, BlockId, Inst, MathFn, Operand, UnOp, WiFn};
use crate::ir::types::{AddrSpace, Scalar, Type};

use super::super::bytecode::{BcConst, BcInst, BcRegion, BcSlot, BytecodeProgram};
use super::emit::{Asm, Cc, ExecMem, Label, R14, R15, RAX, RCX, RDI, RDX, RSI, XMM0, XMM1};
use super::run::{helper_addr, off_base, off_len, OFF_DIV_IDX, OFF_DIV_MASK, OFF_EXIT, OFF_FRAME, OFF_INSTS};

/// Static payload kind of one frame slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Kind {
    /// Normalised integer payload (`i64` two's complement).
    I,
    /// Float payload (`f64` bits; `F32` values are kept normalised).
    F,
    /// Pointer payload (offset bits) into address space `tag`; tag
    /// [`SP_PRIVATE`] means "private, but into an unknown alloca slot".
    P(u8),
    /// Pointer payload into private alloca slot `SlotId(n)` (so loads
    /// through it can be typed from the slot's cell class).
    Ps(u32),
}

/// A frame slot together with its inferred payload kind — the unit the
/// runtime helper uses to marshal payloads to/from `VLane` values.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SlotK {
    pub(crate) slot: BcSlot,
    pub(crate) kind: Kind,
}

/// One helper-dispatched operation: the jitted code calls back into the
/// runtime with an index into the region's `descs` table, and the
/// helper runs the corresponding shared `vecgang` kernel.
#[derive(Debug, Clone)]
pub(crate) enum Desc {
    /// `dst = a <op> b` via `bin_vlane` (divisions, bool/vector-ish
    /// combos, float logical ops).
    Bin { op: BinOp, ty: Type, dst: SlotK, a: SlotK, b: SlotK },
    /// `dst = <op> a` via `un_vlane`.
    Un { op: UnOp, ty: Type, dst: SlotK, a: SlotK },
    /// `dst = (to) a` via `cast_vlane` (float→int casts saturate like
    /// Rust `as`, so they are never inlined).
    Cast { to: Type, from: Type, dst: SlotK, a: SlotK },
    /// `dst = cond ? a : b` via `select_vlane`.
    Select { ty: Type, dst: SlotK, cond: SlotK, a: SlotK, b: SlotK },
    /// `dst = wi_fn(dim)` via `wi_vlane`.
    Wi { func: WiFn, dim: u32, dst: SlotK },
    /// `dst = math_fn(args…)` via `math_vlane`.
    Math { func: MathFn, ty: Type, dst: SlotK, args: Vec<SlotK> },
    /// `dst = load ty, ptr` via `load_vlane` (private cells).
    Load { ty: Type, dst: SlotK, ptr: SlotK },
    /// `store val, ptr` via `store_vlane` (private cells and
    /// combinations the inline templates do not cover).
    Store { ty: Type, ptr: SlotK, val: SlotK },
}

/// One jitted region: entry offset into the shared [`ExecMem`] plus the
/// metadata the runtime needs to drive it.
#[derive(Debug)]
pub(crate) struct JitRegion {
    /// Byte offset of the region's entry point in the program's code.
    pub(crate) entry: usize,
    /// Helper-dispatch table (indexed by the jitted `call`s).
    pub(crate) descs: Vec<Desc>,
    /// `End` targets: `exit` field → IR barrier block reached.
    pub(crate) ends: Vec<BlockId>,
    /// Divergence table: `div_idx` field → `(ir_t, ir_f)` IR targets.
    pub(crate) branches: Vec<(BlockId, BlockId)>,
    /// Static bytecode-instruction count (for compile stats).
    pub(crate) insts: usize,
}

/// A jitted program: one entry per bytecode region (`None` = the region
/// was rejected and keeps running on the bytecode tier).
#[derive(Debug)]
pub struct JitProgram {
    /// Gang width the templates were emitted for.
    pub(crate) width: usize,
    /// Register-frame size the slots were resolved against.
    pub(crate) reg_count: u32,
    /// Frame size in slots (max over regions of regs + consts + 1).
    pub(crate) frame_slots: usize,
    /// Per-region lowering results, parallel to the bytecode regions.
    pub(crate) regions: Vec<Option<JitRegion>>,
    /// The executable code (all regions concatenated).
    pub(crate) code: ExecMem,
}

impl JitProgram {
    /// Number of regions that were actually jitted.
    pub fn covered_regions(&self) -> usize {
        self.regions.iter().filter(|r| r.is_some()).count()
    }
}

/// Lowering statistics, reported through `CompileStats`.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct JitLowerStats {
    /// Regions successfully lowered to machine code.
    pub(crate) regions: usize,
    /// Static bytecode instructions covered by those regions.
    pub(crate) insts: usize,
    /// Regions rejected (they keep running on the bytecode tier).
    pub(crate) fallbacks: usize,
}

// ---------------------------------------------------------------------
// Private-cell classes (provenance scan)
// ---------------------------------------------------------------------

/// The payload class a private alloca cell is proven to hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CellClass {
    I,
    F,
    /// Pointer into space `tag` ([`SP_PRIVATE`] = private, slot unknown).
    P(u8),
    /// Possibly punned / vector-valued — loads from it are untypable.
    Other,
}

/// Where a value may have come from, for the store-site soundness scan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Prov {
    /// Base pointer of alloca slot `n` (possibly offset by geps).
    Slot(u32),
    /// Non-private pointer with known space tag.
    Ptr(u8),
    /// Possibly-private pointer into an unknown slot.
    PtrPriv,
    /// A plain (non-pointer) value.
    Val,
}

fn class_of_type(ty: &Type) -> CellClass {
    match ty {
        Type::Scalar(s) if s.is_float() => CellClass::F,
        Type::Scalar(_) => CellClass::I,
        Type::Ptr(_, sp) => CellClass::P(space_tag(*sp)),
        _ => CellClass::Other,
    }
}

fn prov_of(f: &Function, map: &[Option<Prov>], op: &Operand) -> Prov {
    match op {
        Operand::Reg(r) => map.get(r.0 as usize).copied().flatten().unwrap_or(Prov::PtrPriv),
        Operand::Imm(_) => Prov::Val,
        Operand::Arg(a) => match f.params.get(*a as usize).map(|p| &p.ty) {
            Some(Type::Ptr(_, AddrSpace::Private)) => Prov::PtrPriv,
            Some(Type::Ptr(_, sp)) => Prov::Ptr(space_tag(*sp)),
            _ => Prov::Val,
        },
        Operand::Slot(s) => Prov::Slot(s.0),
    }
}

/// True if storing a value of provenance `vp` with store type `ty` into
/// alloca slot `s` preserves the slot's declared cell class.
fn store_ok(classes: &[CellClass], s: u32, ty: &Type, vp: Prov) -> bool {
    let sc = class_of_type(ty);
    match classes.get(s as usize).copied() {
        Some(CellClass::I) => sc == CellClass::I && vp == Prov::Val,
        Some(CellClass::F) => sc == CellClass::F && vp == Prov::Val,
        Some(CellClass::P(SP_PRIVATE)) => {
            sc == CellClass::P(SP_PRIVATE) && matches!(vp, Prov::Slot(_) | Prov::PtrPriv)
        }
        Some(CellClass::P(t)) => sc == CellClass::P(t) && vp == Prov::Ptr(t),
        _ => false,
    }
}

/// Whole-function provenance scan: start every alloca slot at the class
/// of its declared element type, then demote any slot whose stores
/// might pun the payload class (wrong store type, pointer value into a
/// scalar cell, …). A store through a pointer that could alias *any*
/// private slot demotes everything. The result types private loads in
/// jitted regions; demoted slots push their regions to the bytecode
/// tier instead of risking a misread payload.
fn alloca_classes(f: &Function) -> Vec<CellClass> {
    let mut classes: Vec<CellClass> = f.slots.iter().map(|a| class_of_type(&a.ty)).collect();
    let nregs = f.reg_count() as usize;
    let mut kill_all = false;
    for blk in &f.blocks {
        let mut map: Vec<Option<Prov>> = vec![None; nregs];
        for (dst, inst) in &blk.insts {
            let p = match inst {
                Inst::Gep { base, .. } => match prov_of(f, &map, base) {
                    Prov::Val => Prov::PtrPriv,
                    other => other,
                },
                Inst::Cast { a, .. } => prov_of(f, &map, a),
                Inst::Select { a, b, .. } => {
                    let (pa, pb) = (prov_of(f, &map, a), prov_of(f, &map, b));
                    if pa == pb {
                        pa
                    } else {
                        Prov::PtrPriv
                    }
                }
                Inst::Load { ty, .. } => match ty {
                    Type::Ptr(_, AddrSpace::Private) => Prov::PtrPriv,
                    Type::Ptr(_, sp) => Prov::Ptr(space_tag(*sp)),
                    _ => Prov::Val,
                },
                Inst::Store { ty, ptr, val } => {
                    match prov_of(f, &map, ptr) {
                        Prov::Slot(s) => {
                            if !store_ok(&classes, s, ty, prov_of(f, &map, val)) {
                                if let Some(c) = classes.get_mut(s as usize) {
                                    *c = CellClass::Other;
                                }
                            }
                        }
                        Prov::PtrPriv => kill_all = true,
                        Prov::Ptr(_) | Prov::Val => {}
                    }
                    Prov::Val
                }
                _ => Prov::Val,
            };
            if let Some(r) = dst {
                if let Some(e) = map.get_mut(r.0 as usize) {
                    *e = Some(p);
                }
            }
        }
    }
    if kill_all {
        for c in classes.iter_mut() {
            *c = CellClass::Other;
        }
    }
    classes
}

/// Static payload kind of a constant-pool entry (shared with the
/// runtime, which must marshal launch arguments under the same kinds).
pub(crate) fn const_kind(f: &Function, c: &BcConst) -> Option<Kind> {
    match c {
        BcConst::Int(..) => Some(Kind::I),
        BcConst::Float(..) => Some(Kind::F),
        BcConst::Arg(a) => match f.params.get(*a as usize).map(|p| &p.ty) {
            Some(Type::Ptr(_, sp)) => Some(Kind::P(space_tag(*sp))),
            Some(Type::Scalar(s)) if s.is_float() => Some(Kind::F),
            Some(Type::Scalar(_)) => Some(Kind::I),
            _ => None,
        },
        BcConst::Slot(s) => Some(Kind::Ps(s.0)),
    }
}

fn kind_intlike(k: Kind) -> bool {
    matches!(k, Kind::I | Kind::P(_) | Kind::Ps(_))
}

fn cc_int(op: BinOp, unsigned: bool) -> Cc {
    match (op, unsigned) {
        (BinOp::Eq, _) => Cc::E,
        (BinOp::Ne, _) => Cc::Ne,
        (BinOp::Lt, true) => Cc::B,
        (BinOp::Lt, false) => Cc::L,
        (BinOp::Le, true) => Cc::Be,
        (BinOp::Le, false) => Cc::Le,
        (BinOp::Gt, true) => Cc::A,
        (BinOp::Gt, false) => Cc::G,
        (BinOp::Ge, true) => Cc::Ae,
        _ => Cc::Ge,
    }
}

// ---------------------------------------------------------------------
// Program lowering
// ---------------------------------------------------------------------

/// Lower `prog` for gang width `width`. Returns `None` when the tier
/// cannot apply at all (unsupported width, mismatched register frame,
/// no coverable region, or the executable mapping failed — e.g. a
/// hardened kernel denying W^X flips); individual uncoverable regions
/// just stay `None` inside the returned program.
pub(crate) fn lower(
    f: &Function,
    prog: &BytecodeProgram,
    width: usize,
) -> Option<(JitProgram, JitLowerStats)> {
    let helper = helper_addr(width)?;
    if prog.reg_count != f.reg_count() {
        return None;
    }
    let classes = alloca_classes(f);
    let mut all = Vec::new();
    let mut regions = Vec::with_capacity(prog.regions.len());
    let mut frame_slots = 1usize;
    let mut stats = JitLowerStats::default();
    for r in &prog.regions {
        match lower_region(f, &classes, r, prog.reg_count, width, helper) {
            Some(lr) => {
                frame_slots = frame_slots.max(prog.reg_count as usize + r.consts.len() + 1);
                let entry = all.len();
                all.extend_from_slice(&lr.bytes);
                stats.regions += 1;
                stats.insts += lr.insts;
                regions.push(Some(JitRegion {
                    entry,
                    descs: lr.descs,
                    ends: lr.ends,
                    branches: lr.branches,
                    insts: lr.insts,
                }));
            }
            None => {
                stats.fallbacks += 1;
                regions.push(None);
            }
        }
    }
    if stats.regions == 0 {
        return None;
    }
    let code = ExecMem::new(&all)?;
    Some((JitProgram { width, reg_count: prog.reg_count, frame_slots, regions, code }, stats))
}

struct Lowered {
    bytes: Vec<u8>,
    descs: Vec<Desc>,
    ends: Vec<BlockId>,
    branches: Vec<(BlockId, BlockId)>,
    insts: usize,
}

struct RegionAsm<'a> {
    classes: &'a [CellClass],
    asm: Asm,
    descs: Vec<Desc>,
    ends: Vec<BlockId>,
    branches: Vec<(BlockId, BlockId)>,
    kinds: Vec<Option<Kind>>,
    ckinds: Vec<Option<Kind>>,
    nregs: u32,
    scratch: u32,
    scratch_kind: Option<Kind>,
    w: usize,
    pending: i32,
    insts: usize,
    exit: Label,
    err: Label,
    helper: u64,
}

fn lower_region(
    f: &Function,
    classes: &[CellClass],
    region: &BcRegion,
    nregs: u32,
    width: usize,
    helper: u64,
) -> Option<Lowered> {
    if region.code.is_empty() {
        return None;
    }
    let mut asm = Asm::new();
    let exit = asm.label();
    let err = asm.label();
    let mut ra = RegionAsm {
        classes,
        asm,
        descs: Vec::new(),
        ends: Vec::new(),
        branches: Vec::new(),
        kinds: vec![None; nregs as usize],
        ckinds: region.consts.iter().map(|c| const_kind(f, c)).collect(),
        nregs,
        scratch: nregs + region.consts.len() as u32,
        scratch_kind: None,
        w: width,
        pending: 0,
        insts: 0,
        exit,
        err,
        helper,
    };

    // Pre-pass: allocate labels for every branch-target PC.
    let mut labels: Vec<Option<Label>> = vec![None; region.code.len()];
    for inst in &region.code {
        let mut mark = |pc: u32| -> Option<()> {
            let e = labels.get_mut(pc as usize)?;
            if e.is_none() {
                *e = Some(ra.asm.label());
            }
            Some(())
        };
        match inst {
            BcInst::Jump { pc } => mark(*pc)?,
            BcInst::Br { t, f, .. } | BcInst::CmpBr { t, f, .. } => {
                mark(*t)?;
                mark(*f)?;
            }
            _ => {}
        }
    }

    // Prologue: rdi = ctx. Keep ctx in r15 and the frame base in r14;
    // one stack adjust keeps rsp 16-byte aligned at helper call sites.
    ra.asm.push_r14();
    ra.asm.push_r15();
    ra.asm.sub_rsp_8();
    ra.asm.mov_rr(R15, RDI);
    ra.asm.mov_r_mem(R14, R15, OFF_FRAME);

    for (pc, inst) in region.code.iter().enumerate() {
        if let Some(Some(l)) = labels.get(pc) {
            ra.flush();
            ra.asm.bind(*l);
        }
        match inst {
            BcInst::Bin { op, ty, dst, a, b } => {
                ra.count();
                ra.emit_bin(*op, ty, *dst, *a, *b)?;
            }
            BcInst::Un { op, ty, dst, a } => {
                ra.count();
                ra.emit_un(*op, ty, *dst, *a)?;
            }
            BcInst::Cast { to, from, dst, a } => {
                ra.count();
                ra.emit_cast(to, from, *dst, *a)?;
            }
            BcInst::Load { ty, dst, ptr } => {
                ra.count();
                ra.emit_load(ty, *dst, *ptr)?;
            }
            BcInst::Store { ty, ptr, val } => {
                ra.count();
                ra.emit_store(ty, *ptr, *val)?;
            }
            BcInst::Gep { elem, dst, base, idx } => {
                ra.count();
                ra.emit_gep(elem, *dst, *base, *idx)?;
            }
            BcInst::Wi { func, dim, dst } => {
                ra.count();
                ra.emit_wi(*func, *dim, *dst)?;
            }
            BcInst::Math { func, ty, dst, args } => {
                ra.count();
                ra.emit_math(*func, ty, *dst, args)?;
            }
            BcInst::Select { ty, dst, cond, a, b } => {
                ra.count();
                ra.emit_select(ty, *dst, *cond, *a, *b)?;
            }
            BcInst::GepLoad { elem, ty, dst, base, idx } => {
                ra.count();
                let sc = ra.scratch;
                ra.emit_gep(elem, sc, *base, *idx)?;
                ra.emit_load(ty, *dst, sc)?;
            }
            BcInst::LoadBin { op, ty, load_ty, dst, ptr, other, load_first } => {
                ra.count();
                let sc = ra.scratch;
                ra.emit_load(load_ty, sc, *ptr)?;
                let (x, y) = if *load_first { (sc, *other) } else { (*other, sc) };
                ra.emit_bin(*op, ty, *dst, x, y)?;
            }
            BcInst::BinStore { op, ty, store_ty, ptr, a, b } => {
                ra.count();
                let sc = ra.scratch;
                ra.emit_bin(*op, ty, sc, *a, *b)?;
                ra.emit_store(store_ty, *ptr, sc)?;
            }
            BcInst::MulAdd { ty, dst, a, b, c, mul_first } => {
                ra.count();
                let sc = ra.scratch;
                ra.emit_bin(BinOp::Mul, ty, sc, *a, *b)?;
                let (x, y) = if *mul_first { (sc, *c) } else { (*c, sc) };
                ra.emit_bin(BinOp::Add, ty, *dst, x, y)?;
            }
            BcInst::CmpBr { op, ty, a, b, t, f, ir_t, ir_f } => {
                ra.count();
                let sc = ra.scratch;
                ra.emit_bin(*op, ty, sc, *a, *b)?;
                ra.emit_br(sc, *t, *f, *ir_t, *ir_f, &labels)?;
            }
            BcInst::Jump { pc } => {
                ra.flush();
                let l = labels.get(*pc as usize).copied().flatten()?;
                ra.asm.jmp(l);
            }
            BcInst::Br { cond, t, f, ir_t, ir_f } => {
                ra.emit_br(*cond, *t, *f, *ir_t, *ir_f, &labels)?;
            }
            BcInst::End { barrier } => {
                ra.flush();
                let eidx = ra.ends.len() as i32;
                ra.ends.push(*barrier);
                ra.asm.mov_mem32_imm(R15, OFF_EXIT, eidx);
                ra.asm.xor_r32_r32(RAX, RAX);
                ra.asm.jmp(ra.exit);
            }
        }
    }

    // Shared bounds-fault path (also the fall-through for a region that
    // somehow lacks a terminator): return code 2 = error.
    ra.asm.bind(ra.err);
    ra.asm.mov_r32_imm(RAX, 2);
    ra.asm.bind(ra.exit);
    ra.asm.add_rsp_8();
    ra.asm.pop_r15();
    ra.asm.pop_r14();
    ra.asm.ret();

    let bytes = ra.asm.finish()?;
    Some(Lowered { bytes, descs: ra.descs, ends: ra.ends, branches: ra.branches, insts: ra.insts })
}

impl RegionAsm<'_> {
    fn count(&mut self) {
        self.pending += 1;
        self.insts += 1;
    }

    fn flush(&mut self) {
        if self.pending > 0 {
            self.asm.add_mem64_imm(R15, OFF_INSTS, self.pending);
            self.pending = 0;
        }
    }

    fn disp(&self, slot: u32, lane: usize) -> i32 {
        ((slot as usize * self.w + lane) * 8) as i32
    }

    fn kind_of(&self, slot: u32) -> Option<Kind> {
        if slot == self.scratch {
            self.scratch_kind
        } else if slot < self.nregs {
            self.kinds.get(slot as usize).copied().flatten()
        } else {
            self.ckinds.get((slot - self.nregs) as usize).copied().flatten()
        }
    }

    fn set_kind(&mut self, slot: u32, k: Kind) {
        if slot == self.scratch {
            self.scratch_kind = Some(k);
        } else if let Some(e) = self.kinds.get_mut(slot as usize) {
            *e = Some(k);
        }
    }

    fn sk(&self, slot: u32) -> Option<SlotK> {
        Some(SlotK { slot, kind: self.kind_of(slot)? })
    }

    /// Emit a call into the runtime helper for `desc`. The current
    /// instruction batch is flushed first (the helper may fail), and a
    /// non-zero return aborts the region with the helper's code in eax.
    fn call_helper(&mut self, desc: Desc) {
        self.flush();
        let idx = self.descs.len() as i32;
        self.descs.push(desc);
        self.asm.mov_rr(RDI, R15);
        self.asm.mov_r32_imm(RSI, idx);
        self.asm.mov_r_imm64(RAX, self.helper);
        self.asm.call_r(RAX);
        self.asm.test_r32_r32(RAX, RAX);
        self.asm.jcc(Cc::Ne, self.exit);
    }

    /// Load slot payload and normalise it as the interpreter's
    /// `norm_int` would for scalar `s` (pointer payloads included).
    fn load_int_norm(&mut self, r: u8, slot: u32, lane: usize, s: Scalar) {
        self.asm.mov_r_mem(r, R14, self.disp(slot, lane));
        match s {
            Scalar::I32 => self.asm.movsxd_rr(r, r),
            Scalar::U32 => self.asm.mov_r32_r32(r, r),
            _ => {}
        }
    }

    fn renorm(&mut self, r: u8, s: Scalar) {
        match s {
            Scalar::I32 => self.asm.movsxd_rr(r, r),
            Scalar::U32 => self.asm.mov_r32_r32(r, r),
            _ => {}
        }
    }

    /// Load a slot as an f64 into `xmm`: float payloads directly,
    /// integer payloads through the same signed conversion `as_f` does.
    fn load_float(&mut self, xmm: u8, slot: u32, k: Kind, lane: usize) {
        let d = self.disp(slot, lane);
        match k {
            Kind::F => self.asm.movsd_x_mem(xmm, R14, d),
            _ => self.asm.cvtsi2sd_x_mem(xmm, R14, d),
        }
    }

    fn emit_bin(&mut self, op: BinOp, ty: &Type, dst: u32, a: u32, b: u32) -> Option<()> {
        if ty.lanes() != 1 {
            return None;
        }
        let s = ty.elem_scalar().unwrap_or(Scalar::I32);
        let ka = self.kind_of(a)?;
        let kb = self.kind_of(b)?;
        let logical = matches!(op, BinOp::LAnd | BinOp::LOr);
        let float_path =
            s.is_float() && !matches!(op, BinOp::And | BinOp::Or | BinOp::Xor | BinOp::Shl | BinOp::Shr);
        let dk = if op.is_cmp() || logical {
            Kind::I
        } else if float_path {
            Kind::F
        } else {
            Kind::I
        };

        if float_path {
            let inline_ok = matches!(ka, Kind::I | Kind::F) && matches!(kb, Kind::I | Kind::F);
            if inline_ok && matches!(op, BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div) {
                for l in 0..self.w {
                    self.load_float(XMM0, a, ka, l);
                    self.load_float(XMM1, b, kb, l);
                    match op {
                        BinOp::Add => self.asm.addsd(XMM0, XMM1),
                        BinOp::Sub => self.asm.subsd(XMM0, XMM1),
                        BinOp::Mul => self.asm.mulsd(XMM0, XMM1),
                        _ => self.asm.divsd(XMM0, XMM1),
                    }
                    if s == Scalar::F32 {
                        self.asm.cvtsd2ss(XMM0, XMM0);
                        self.asm.cvtss2sd(XMM0, XMM0);
                    }
                    let d = self.disp(dst, l);
                    self.asm.movsd_mem_x(R14, d, XMM0);
                }
                self.set_kind(dst, Kind::F);
                return Some(());
            }
            if inline_ok && op.is_cmp() {
                for l in 0..self.w {
                    self.load_float(XMM0, a, ka, l);
                    self.load_float(XMM1, b, kb, l);
                    match op {
                        BinOp::Lt => {
                            self.asm.ucomisd(XMM1, XMM0);
                            self.asm.setcc(Cc::A, RAX);
                        }
                        BinOp::Le => {
                            self.asm.ucomisd(XMM1, XMM0);
                            self.asm.setcc(Cc::Ae, RAX);
                        }
                        BinOp::Gt => {
                            self.asm.ucomisd(XMM0, XMM1);
                            self.asm.setcc(Cc::A, RAX);
                        }
                        BinOp::Ge => {
                            self.asm.ucomisd(XMM0, XMM1);
                            self.asm.setcc(Cc::Ae, RAX);
                        }
                        BinOp::Eq => {
                            self.asm.ucomisd(XMM0, XMM1);
                            self.asm.setcc(Cc::E, RAX);
                            self.asm.setcc(Cc::Np, RCX);
                            self.asm.and_r8_r8(RAX, RCX);
                        }
                        _ => {
                            // Ne: unordered compares as not-equal.
                            self.asm.ucomisd(XMM0, XMM1);
                            self.asm.setcc(Cc::Ne, RAX);
                            self.asm.setcc(Cc::P, RCX);
                            self.asm.or_r8_r8(RAX, RCX);
                        }
                    }
                    self.asm.movzx_r32_r8(RAX, RAX);
                    let d = self.disp(dst, l);
                    self.asm.mov_mem_r(R14, d, RAX);
                }
                self.set_kind(dst, Kind::I);
                return Some(());
            }
        } else {
            let inline_ok = matches!(s, Scalar::I32 | Scalar::U32 | Scalar::I64 | Scalar::U64)
                && kind_intlike(ka)
                && kind_intlike(kb);
            if inline_ok && !matches!(op, BinOp::Div | BinOp::Rem) {
                let unsigned = matches!(s, Scalar::U32 | Scalar::U64);
                for l in 0..self.w {
                    self.load_int_norm(RAX, a, l, s);
                    self.load_int_norm(RCX, b, l, s);
                    if op.is_cmp() {
                        self.asm.cmp_rr(RAX, RCX);
                        self.asm.setcc(cc_int(op, unsigned), RAX);
                        self.asm.movzx_r32_r8(RAX, RAX);
                    } else {
                        match op {
                            BinOp::Add => self.asm.add_rr(RAX, RCX),
                            BinOp::Sub => self.asm.sub_rr(RAX, RCX),
                            BinOp::Mul => self.asm.imul_rr(RAX, RCX),
                            BinOp::And => self.asm.and_rr(RAX, RCX),
                            BinOp::Or => self.asm.or_rr(RAX, RCX),
                            BinOp::Xor => self.asm.xor_rr(RAX, RCX),
                            BinOp::Shl => self.asm.shl_r_cl(RAX),
                            BinOp::Shr => {
                                if s.is_signed() {
                                    self.asm.sar_r_cl(RAX);
                                } else {
                                    self.asm.shr_r_cl(RAX);
                                }
                            }
                            BinOp::LAnd => {
                                self.asm.test_rr(RAX, RAX);
                                self.asm.setcc(Cc::Ne, RAX);
                                self.asm.test_rr(RCX, RCX);
                                self.asm.setcc(Cc::Ne, RCX);
                                self.asm.and_r8_r8(RAX, RCX);
                                self.asm.movzx_r32_r8(RAX, RAX);
                            }
                            _ => {
                                // LOr: (a|b) != 0 on normalised payloads.
                                self.asm.or_rr(RAX, RCX);
                                self.asm.setcc(Cc::Ne, RAX);
                                self.asm.movzx_r32_r8(RAX, RAX);
                            }
                        }
                        if !logical {
                            self.renorm(RAX, s);
                        }
                    }
                    let d = self.disp(dst, l);
                    self.asm.mov_mem_r(R14, d, RAX);
                }
                self.set_kind(dst, Kind::I);
                return Some(());
            }
        }

        // Everything else (divisions, bool scalars, float logicals,
        // pointer-payload float ops) → shared kernel.
        let (da, db) = (self.sk(a)?, self.sk(b)?);
        self.set_kind(dst, dk);
        self.call_helper(Desc::Bin {
            op,
            ty: ty.clone(),
            dst: SlotK { slot: dst, kind: dk },
            a: da,
            b: db,
        });
        Some(())
    }

    fn emit_un(&mut self, op: UnOp, ty: &Type, dst: u32, a: u32) -> Option<()> {
        if ty.lanes() != 1 {
            return None;
        }
        let s = ty.elem_scalar().unwrap_or(Scalar::I32);
        let ka = self.kind_of(a)?;
        match op {
            UnOp::Neg if s.is_float() => {
                if ka == Kind::F {
                    for l in 0..self.w {
                        let da = self.disp(a, l);
                        self.asm.mov_r_mem(RAX, R14, da);
                        self.asm.mov_r_imm64(RCX, 0x8000_0000_0000_0000);
                        self.asm.xor_rr(RAX, RCX);
                        let d = self.disp(dst, l);
                        self.asm.mov_mem_r(R14, d, RAX);
                    }
                    self.set_kind(dst, Kind::F);
                    return Some(());
                }
                self.helper_un(op, ty, dst, Kind::F, a)
            }
            UnOp::Neg => {
                if matches!(s, Scalar::I32 | Scalar::U32 | Scalar::I64 | Scalar::U64)
                    && kind_intlike(ka)
                {
                    for l in 0..self.w {
                        let da = self.disp(a, l);
                        self.asm.mov_r_mem(RCX, R14, da);
                        self.asm.xor_r32_r32(RAX, RAX);
                        self.asm.sub_rr(RAX, RCX);
                        self.renorm(RAX, s);
                        let d = self.disp(dst, l);
                        self.asm.mov_mem_r(R14, d, RAX);
                    }
                    self.set_kind(dst, Kind::I);
                    return Some(());
                }
                self.helper_un(op, ty, dst, Kind::I, a)
            }
            UnOp::Not => {
                if matches!(s, Scalar::I32 | Scalar::U32 | Scalar::I64 | Scalar::U64)
                    && kind_intlike(ka)
                {
                    for l in 0..self.w {
                        let da = self.disp(a, l);
                        self.asm.mov_r_mem(RAX, R14, da);
                        self.asm.mov_r_imm64(RCX, u64::MAX);
                        self.asm.xor_rr(RAX, RCX);
                        self.renorm(RAX, s);
                        let d = self.disp(dst, l);
                        self.asm.mov_mem_r(R14, d, RAX);
                    }
                    self.set_kind(dst, Kind::I);
                    return Some(());
                }
                self.helper_un(op, ty, dst, Kind::I, a)
            }
            UnOp::LNot => {
                for l in 0..self.w {
                    match ka {
                        Kind::I => {
                            let da = self.disp(a, l);
                            self.asm.mov_r_mem(RAX, R14, da);
                            self.asm.test_rr(RAX, RAX);
                            self.asm.setcc(Cc::E, RAX);
                            self.asm.movzx_r32_r8(RAX, RAX);
                        }
                        Kind::F => {
                            // !truthy(f) = (f == 0.0), ordered: NaN → 0.
                            self.load_float(XMM0, a, Kind::F, l);
                            self.asm.xorps(XMM1, XMM1);
                            self.asm.ucomisd(XMM0, XMM1);
                            self.asm.setcc(Cc::E, RAX);
                            self.asm.setcc(Cc::Np, RCX);
                            self.asm.and_r8_r8(RAX, RCX);
                            self.asm.movzx_r32_r8(RAX, RAX);
                        }
                        _ => {
                            // Pointers are always truthy: !p = 0.
                            self.asm.xor_r32_r32(RAX, RAX);
                        }
                    }
                    let d = self.disp(dst, l);
                    self.asm.mov_mem_r(R14, d, RAX);
                }
                self.set_kind(dst, Kind::I);
                Some(())
            }
        }
    }

    fn helper_un(&mut self, op: UnOp, ty: &Type, dst: u32, dk: Kind, a: u32) -> Option<()> {
        let da = self.sk(a)?;
        self.set_kind(dst, dk);
        self.call_helper(Desc::Un { op, ty: ty.clone(), dst: SlotK { slot: dst, kind: dk }, a: da });
        Some(())
    }

    fn emit_cast(&mut self, to: &Type, from: &Type, dst: u32, a: u32) -> Option<()> {
        if to.lanes() != 1 || from.lanes() != 1 {
            return None;
        }
        let ka = self.kind_of(a)?;
        // Pointer payloads pass through casts unchanged (norm_val), and
        // non-value target types clone — both are payload copies.
        if matches!(ka, Kind::P(_) | Kind::Ps(_)) || to.elem_scalar().is_none() {
            for l in 0..self.w {
                let da = self.disp(a, l);
                self.asm.mov_r_mem(RAX, R14, da);
                let d = self.disp(dst, l);
                self.asm.mov_mem_r(R14, d, RAX);
            }
            self.set_kind(dst, ka);
            return Some(());
        }
        let ss = to.elem_scalar()?;
        if ss.is_float() {
            for l in 0..self.w {
                self.load_float(XMM0, a, ka, l);
                if ss == Scalar::F32 {
                    self.asm.cvtsd2ss(XMM0, XMM0);
                    self.asm.cvtss2sd(XMM0, XMM0);
                }
                let d = self.disp(dst, l);
                self.asm.movsd_mem_x(R14, d, XMM0);
            }
            self.set_kind(dst, Kind::F);
            return Some(());
        }
        if ka == Kind::F {
            // float → int saturates like Rust `as`; keep the kernel's
            // exact semantics by dispatching.
            let da = self.sk(a)?;
            self.set_kind(dst, Kind::I);
            self.call_helper(Desc::Cast {
                to: to.clone(),
                from: from.clone(),
                dst: SlotK { slot: dst, kind: Kind::I },
                a: da,
            });
            return Some(());
        }
        for l in 0..self.w {
            let da = self.disp(a, l);
            self.asm.mov_r_mem(RAX, R14, da);
            if ss == Scalar::Bool {
                self.asm.test_rr(RAX, RAX);
                self.asm.setcc(Cc::Ne, RAX);
                self.asm.movzx_r32_r8(RAX, RAX);
            } else {
                self.renorm(RAX, ss);
            }
            let d = self.disp(dst, l);
            self.asm.mov_mem_r(R14, d, RAX);
        }
        self.set_kind(dst, Kind::I);
        Some(())
    }

    fn emit_gep(&mut self, elem: &Type, dst: u32, base: u32, idx: u32) -> Option<()> {
        let kb = self.kind_of(base)?;
        let ki = self.kind_of(idx)?;
        if ki == Kind::F {
            return None;
        }
        match kb {
            Kind::Ps(_) | Kind::P(SP_PRIVATE) => {
                // Private memory is cell-addressed: index added raw.
                for l in 0..self.w {
                    let db = self.disp(base, l);
                    self.asm.mov_r_mem(RAX, R14, db);
                    let di = self.disp(idx, l);
                    self.asm.mov_r_mem(RCX, R14, di);
                    self.asm.add_rr(RAX, RCX);
                    let d = self.disp(dst, l);
                    self.asm.mov_mem_r(R14, d, RAX);
                }
            }
            Kind::P(_) => {
                let esz = i32::try_from(elem.size()).ok()?;
                for l in 0..self.w {
                    let db = self.disp(base, l);
                    self.asm.mov_r_mem(RAX, R14, db);
                    let di = self.disp(idx, l);
                    self.asm.mov_r_mem(RCX, R14, di);
                    self.asm.imul_r_imm(RCX, esz);
                    self.asm.add_rr(RAX, RCX);
                    let d = self.disp(dst, l);
                    self.asm.mov_mem_r(R14, d, RAX);
                }
            }
            _ => return None,
        }
        self.set_kind(dst, kb);
        Some(())
    }

    /// Emit the shared per-lane pointer/bounds preamble for a global or
    /// local access: leaves the offset in rdx, the buffer base in rcx,
    /// and faults to `.err` exactly when the interpreter's
    /// `offset + elem_size > len` check would.
    fn emit_bounds(&mut self, ptr: u32, lane: usize, tag: u8, esz: i32) {
        let dp = self.disp(ptr, lane);
        self.asm.mov_r_mem(RDX, R14, dp);
        self.asm.mov_rr(RAX, RDX);
        self.asm.add_r_imm(RAX, esz);
        self.asm.jcc(Cc::B, self.err);
        self.asm.cmp_r_mem(RAX, R15, off_len(tag));
        self.asm.jcc(Cc::A, self.err);
        self.asm.mov_r_mem(RCX, R15, off_base(tag));
    }

    fn emit_load(&mut self, ty: &Type, dst: u32, ptr: u32) -> Option<()> {
        let kp = self.kind_of(ptr)?;
        match kp {
            Kind::Ps(sid) => {
                // Private load: always through the kernel (cells hold
                // whole VLane values); result kind = proven cell class.
                let dk = match self.classes.get(sid as usize)? {
                    CellClass::I => Kind::I,
                    CellClass::F => Kind::F,
                    CellClass::P(t) => Kind::P(*t),
                    CellClass::Other => return None,
                };
                let dp = self.sk(ptr)?;
                self.set_kind(dst, dk);
                self.call_helper(Desc::Load {
                    ty: ty.clone(),
                    dst: SlotK { slot: dst, kind: dk },
                    ptr: dp,
                });
                Some(())
            }
            Kind::P(SP_PRIVATE) => None,
            Kind::P(t) => {
                if ty.lanes() != 1 {
                    return None;
                }
                let s = ty.elem_scalar()?;
                let esz = i32::try_from(s.size()).ok()?;
                for l in 0..self.w {
                    self.emit_bounds(ptr, l, t, esz);
                    let d = self.disp(dst, l);
                    match s {
                        Scalar::F32 => {
                            self.asm.load_f32_sib();
                            self.asm.cvtss2sd(XMM0, XMM0);
                            self.asm.movsd_mem_x(R14, d, XMM0);
                        }
                        Scalar::F64 => {
                            self.asm.load_f64_sib();
                            self.asm.movsd_mem_x(R14, d, XMM0);
                        }
                        Scalar::I32 => {
                            self.asm.load_i32_sib();
                            self.asm.mov_mem_r(R14, d, RAX);
                        }
                        Scalar::U32 => {
                            self.asm.load_u32_sib();
                            self.asm.mov_mem_r(R14, d, RAX);
                        }
                        Scalar::I64 | Scalar::U64 => {
                            self.asm.load_i64_sib();
                            self.asm.mov_mem_r(R14, d, RAX);
                        }
                        Scalar::Bool => {
                            self.asm.cmp_bool_sib();
                            self.asm.setcc(Cc::Ne, RAX);
                            self.asm.movzx_r32_r8(RAX, RAX);
                            self.asm.mov_mem_r(R14, d, RAX);
                        }
                    }
                }
                self.set_kind(dst, if s.is_float() { Kind::F } else { Kind::I });
                Some(())
            }
            _ => None,
        }
    }

    fn emit_store(&mut self, ty: &Type, ptr: u32, val: u32) -> Option<()> {
        let kp = self.kind_of(ptr)?;
        let kv = self.kind_of(val)?;
        let t = match kp {
            Kind::Ps(_) | Kind::P(SP_PRIVATE) => {
                // Private store: the kernel path keeps VecStore cells
                // (and their normalisation) exactly coherent.
                let (dp, dv) = (self.sk(ptr)?, self.sk(val)?);
                self.call_helper(Desc::Store { ty: ty.clone(), ptr: dp, val: dv });
                return Some(());
            }
            Kind::P(t) => t,
            _ => return None,
        };
        let inline = if ty.lanes() != 1 {
            None
        } else {
            ty.elem_scalar().and_then(|s| {
                let ok = match s {
                    Scalar::F32 | Scalar::F64 => matches!(kv, Kind::I | Kind::F),
                    Scalar::Bool => kv == Kind::I,
                    _ => kind_intlike(kv),
                };
                if ok {
                    Some(s)
                } else {
                    None
                }
            })
        };
        let s = match inline {
            Some(s) => s,
            None => {
                let (dp, dv) = (self.sk(ptr)?, self.sk(val)?);
                self.call_helper(Desc::Store { ty: ty.clone(), ptr: dp, val: dv });
                return Some(());
            }
        };
        let esz = i32::try_from(s.size()).ok()?;
        for l in 0..self.w {
            self.emit_bounds(ptr, l, t, esz);
            let dv = self.disp(val, l);
            match s {
                Scalar::F64 => {
                    self.load_float(XMM0, val, kv, l);
                    self.asm.store_f64_sib();
                }
                Scalar::F32 => {
                    self.load_float(XMM0, val, kv, l);
                    self.asm.cvtsd2ss(XMM0, XMM0);
                    self.asm.store_f32_sib();
                }
                Scalar::I32 | Scalar::U32 => {
                    self.asm.mov_r_mem(RAX, R14, dv);
                    self.asm.store_u32_sib();
                }
                Scalar::I64 | Scalar::U64 => {
                    self.asm.mov_r_mem(RAX, R14, dv);
                    self.asm.store_u64_sib();
                }
                Scalar::Bool => {
                    self.asm.mov_r_mem(RAX, R14, dv);
                    self.asm.test_rr(RAX, RAX);
                    self.asm.setcc(Cc::Ne, RAX);
                    self.asm.store_u8_sib();
                }
            }
        }
        Some(())
    }

    fn emit_wi(&mut self, func: WiFn, dim: u32, dst: u32) -> Option<()> {
        self.set_kind(dst, Kind::I);
        self.call_helper(Desc::Wi { func, dim, dst: SlotK { slot: dst, kind: Kind::I } });
        Some(())
    }

    fn emit_math(&mut self, func: MathFn, ty: &Type, dst: u32, args: &[BcSlot]) -> Option<()> {
        if ty.lanes() != 1 || !matches!(ty.elem_scalar(), Some(s) if s.is_float()) {
            return None;
        }
        let mut sks = Vec::with_capacity(args.len());
        for &a in args {
            sks.push(self.sk(a)?);
        }
        self.set_kind(dst, Kind::F);
        self.call_helper(Desc::Math {
            func,
            ty: ty.clone(),
            dst: SlotK { slot: dst, kind: Kind::F },
            args: sks,
        });
        Some(())
    }

    fn emit_select(&mut self, ty: &Type, dst: u32, cond: u32, a: u32, b: u32) -> Option<()> {
        if ty.lanes() != 1 {
            return None;
        }
        let ka = self.kind_of(a)?;
        let kb = self.kind_of(b)?;
        let kc = self.kind_of(cond)?;
        // select picks an operand unnormalised, so the result kind must
        // be a single consistent payload class.
        let dk = if ka == kb {
            ka
        } else {
            match (ka, kb) {
                (Kind::Ps(_) | Kind::P(SP_PRIVATE), Kind::Ps(_) | Kind::P(SP_PRIVATE)) => {
                    Kind::P(SP_PRIVATE)
                }
                _ => return None,
            }
        };
        self.set_kind(dst, dk);
        self.call_helper(Desc::Select {
            ty: ty.clone(),
            dst: SlotK { slot: dst, kind: dk },
            cond: SlotK { slot: cond, kind: kc },
            a: SlotK { slot: a, kind: ka },
            b: SlotK { slot: b, kind: kb },
        });
        Some(())
    }

    /// Emit a conditional branch: evaluate per-lane truthiness into an
    /// edx mask, take the uniform edges inline, and report divergence
    /// (return code 1 + mask + branch index) otherwise.
    fn emit_br(
        &mut self,
        cond: u32,
        t: u32,
        f: u32,
        ir_t: BlockId,
        ir_f: BlockId,
        labels: &[Option<Label>],
    ) -> Option<()> {
        let kc = self.kind_of(cond)?;
        self.flush();
        let lt = labels.get(t as usize).copied().flatten()?;
        let lf = labels.get(f as usize).copied().flatten()?;
        if matches!(kc, Kind::P(_) | Kind::Ps(_)) {
            // Pointers are always truthy → unconditionally true edge.
            self.asm.jmp(lt);
            return Some(());
        }
        self.asm.xor_r32_r32(RDX, RDX);
        for l in 0..self.w {
            match kc {
                Kind::I => {
                    let dc = self.disp(cond, l);
                    self.asm.mov_r_mem(RAX, R14, dc);
                    self.asm.test_rr(RAX, RAX);
                    self.asm.setcc(Cc::Ne, RAX);
                }
                _ => {
                    // Kind::F: truthy = (f != 0.0), NaN included.
                    self.load_float(XMM0, cond, Kind::F, l);
                    self.asm.xorps(XMM1, XMM1);
                    self.asm.ucomisd(XMM0, XMM1);
                    self.asm.setcc(Cc::Ne, RAX);
                    self.asm.setcc(Cc::P, RCX);
                    self.asm.or_r8_r8(RAX, RCX);
                }
            }
            self.asm.movzx_r32_r8(RAX, RAX);
            if l > 0 {
                self.asm.shl_r32_imm8(RAX, l as u8);
            }
            self.asm.or_r32_r32(RDX, RAX);
        }
        self.asm.test_r32_r32(RDX, RDX);
        self.asm.jcc(Cc::E, lf);
        self.asm.cmp_r32_imm(RDX, ((1u32 << self.w) - 1) as i32);
        self.asm.jcc(Cc::E, lt);
        let bidx = self.branches.len() as i32;
        self.branches.push((ir_t, ir_f));
        self.asm.mov_mem32_r32(R15, OFF_DIV_MASK, RDX);
        self.asm.mov_mem32_imm(R15, OFF_DIV_IDX, bidx);
        self.asm.mov_r32_imm(RAX, 1);
        self.asm.jmp(self.exit);
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_classes_from_types() {
        assert_eq!(class_of_type(&Type::Scalar(Scalar::F32)), CellClass::F);
        assert_eq!(class_of_type(&Type::Scalar(Scalar::Bool)), CellClass::I);
        assert_eq!(
            class_of_type(&Type::Scalar(Scalar::F32).ptr(AddrSpace::Global)),
            CellClass::P(0)
        );
        assert_eq!(class_of_type(&Type::Vec(Scalar::F32, 4)), CellClass::Other);
    }

    #[test]
    fn int_compare_condition_codes() {
        assert_eq!(cc_int(BinOp::Lt, true) as u8, Cc::B as u8);
        assert_eq!(cc_int(BinOp::Lt, false) as u8, Cc::L as u8);
        assert_eq!(cc_int(BinOp::Ge, true) as u8, Cc::Ae as u8);
        assert_eq!(cc_int(BinOp::Eq, false) as u8, Cc::E as u8);
    }
}
