//! Hand-rolled x86-64 instruction encoding and W^X executable memory.
//!
//! [`Asm`] is a minimal one-pass assembler: methods append the exact
//! byte sequence of one instruction (verified against GNU as/objdump in
//! the unit tests below), labels are bound to offsets and rel32 branch
//! fixups are patched in [`Asm::finish`]. Only the small instruction
//! vocabulary the template JIT needs is implemented, and always in the
//! most general encoding (disp32 addressing, imm32 ALU forms) so every
//! emission site is byte-for-byte predictable.
//!
//! [`ExecMem`] owns the finished machine code: an anonymous `mmap`'d
//! region that is written while `RW` and flipped to `RX` before any
//! execution (W^X — the mapping is never writable and executable at
//! once). Allocation failure is reported as `None`, which the caller
//! treats as "no JIT" rather than an error.

/// Condition codes (the `cc` nibble of `SETcc` / `Jcc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub(crate) enum Cc {
    /// Equal (ZF=1).
    E = 0x4,
    /// Not equal (ZF=0).
    Ne = 0x5,
    /// Below (unsigned <, CF=1). Also "carry".
    B = 0x2,
    /// Above or equal (unsigned >=, CF=0).
    Ae = 0x3,
    /// Below or equal (unsigned <=).
    Be = 0x6,
    /// Above (unsigned >).
    A = 0x7,
    /// Less (signed <).
    L = 0xc,
    /// Greater or equal (signed >=).
    Ge = 0xd,
    /// Less or equal (signed <=).
    Le = 0xe,
    /// Greater (signed >).
    G = 0xf,
    /// Parity (PF=1, i.e. unordered after `ucomisd`).
    P = 0xa,
    /// No parity (PF=0, i.e. ordered after `ucomisd`).
    Np = 0xb,
}

/// General-purpose register numbers (hardware encoding).
pub(crate) const RAX: u8 = 0;
pub(crate) const RCX: u8 = 1;
pub(crate) const RDX: u8 = 2;
pub(crate) const RSI: u8 = 6;
pub(crate) const RDI: u8 = 7;
pub(crate) const R14: u8 = 14;
pub(crate) const R15: u8 = 15;
/// XMM register numbers.
pub(crate) const XMM0: u8 = 0;
pub(crate) const XMM1: u8 = 1;

/// A branch-target label (index into the assembler's label table).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Label(u32);

/// One-pass assembler for a single region's code.
pub(crate) struct Asm {
    code: Vec<u8>,
    labels: Vec<Option<usize>>,
    fixups: Vec<(usize, Label)>,
}

fn modrm(md: u8, reg: u8, rm: u8) -> u8 {
    (md << 6) | ((reg & 7) << 3) | (rm & 7)
}

impl Asm {
    pub(crate) fn new() -> Asm {
        Asm { code: Vec::new(), labels: Vec::new(), fixups: Vec::new() }
    }

    /// Current offset (for statistics; labels are the branch mechanism).
    pub(crate) fn len(&self) -> usize {
        self.code.len()
    }

    /// Allocate an unbound label.
    pub(crate) fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() as u32 - 1)
    }

    /// Bind `l` to the current offset.
    pub(crate) fn bind(&mut self, l: Label) {
        self.labels[l.0 as usize] = Some(self.code.len());
    }

    /// Patch all rel32 fixups and return the finished bytes. `None` if a
    /// label was never bound (an internal lowering bug — the caller falls
    /// back to the interpreter tiers rather than executing bad code).
    pub(crate) fn finish(mut self) -> Option<Vec<u8>> {
        for (pos, l) in &self.fixups {
            let target = self.labels[l.0 as usize]?;
            let rel = (target as i64) - (*pos as i64 + 4);
            let rel32 = i32::try_from(rel).ok()?;
            self.code[*pos..*pos + 4].copy_from_slice(&rel32.to_le_bytes());
        }
        Some(self.code)
    }

    fn bytes(&mut self, b: &[u8]) {
        self.code.extend_from_slice(b);
    }

    fn imm32(&mut self, v: i32) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }

    fn imm64(&mut self, v: u64) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }

    /// REX prefix. `w` = 64-bit operand, `r` extends modrm.reg, `b`
    /// extends modrm.rm/base.
    fn rex(&mut self, w: bool, r: u8, b: u8) {
        let mut v = 0x40u8;
        if w {
            v |= 8;
        }
        if r >= 8 {
            v |= 4;
        }
        if b >= 8 {
            v |= 1;
        }
        if v != 0x40 || false {
            self.code.push(v);
        } else {
            self.code.push(v);
        }
    }

    /// REX emitted only when needed (32/8-bit forms with low registers).
    fn rex_opt(&mut self, w: bool, r: u8, b: u8) {
        let mut v = 0x40u8;
        if w {
            v |= 8;
        }
        if r >= 8 {
            v |= 4;
        }
        if b >= 8 {
            v |= 1;
        }
        if v != 0x40 {
            self.code.push(v);
        }
    }

    /// `[base + disp32]` modrm tail. `base` must not need a SIB byte.
    fn mem_disp32(&mut self, reg: u8, base: u8, disp: i32) {
        debug_assert!(base & 7 != 4, "rsp/r12 base needs a SIB byte");
        self.code.push(modrm(0b10, reg, base));
        self.imm32(disp);
    }

    // --- prologue / epilogue -------------------------------------------

    pub(crate) fn push_r14(&mut self) {
        self.bytes(&[0x41, 0x56]);
    }
    pub(crate) fn push_r15(&mut self) {
        self.bytes(&[0x41, 0x57]);
    }
    pub(crate) fn pop_r15(&mut self) {
        self.bytes(&[0x41, 0x5f]);
    }
    pub(crate) fn pop_r14(&mut self) {
        self.bytes(&[0x41, 0x5e]);
    }
    pub(crate) fn sub_rsp_8(&mut self) {
        self.bytes(&[0x48, 0x83, 0xec, 0x08]);
    }
    pub(crate) fn add_rsp_8(&mut self) {
        self.bytes(&[0x48, 0x83, 0xc4, 0x08]);
    }
    pub(crate) fn ret(&mut self) {
        self.code.push(0xc3);
    }

    // --- 64-bit moves ---------------------------------------------------

    /// `mov dst, src` (64-bit reg-reg).
    pub(crate) fn mov_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, src, dst);
        self.code.push(0x89);
        self.code.push(modrm(0b11, src, dst));
    }

    /// `mov dst, qword [base + disp32]`.
    pub(crate) fn mov_r_mem(&mut self, dst: u8, base: u8, disp: i32) {
        self.rex(true, dst, base);
        self.code.push(0x8b);
        self.mem_disp32(dst, base, disp);
    }

    /// `mov qword [base + disp32], src`.
    pub(crate) fn mov_mem_r(&mut self, base: u8, disp: i32, src: u8) {
        self.rex(true, src, base);
        self.code.push(0x89);
        self.mem_disp32(src, base, disp);
    }

    /// `mov dword [base + disp32], src32`.
    pub(crate) fn mov_mem32_r32(&mut self, base: u8, disp: i32, src: u8) {
        self.rex_opt(false, src, base);
        self.code.push(0x89);
        self.mem_disp32(src, base, disp);
    }

    /// `mov dword [base + disp32], imm32`.
    pub(crate) fn mov_mem32_imm(&mut self, base: u8, disp: i32, imm: i32) {
        self.rex_opt(false, 0, base);
        self.code.push(0xc7);
        self.mem_disp32(0, base, disp);
        self.imm32(imm);
    }

    /// `add qword [base + disp32], imm32` (sign-extended).
    pub(crate) fn add_mem64_imm(&mut self, base: u8, disp: i32, imm: i32) {
        self.rex(true, 0, base);
        self.code.push(0x81);
        self.mem_disp32(0, base, disp);
        self.imm32(imm);
    }

    /// `mov r32, imm32` (zero-extends into the full register).
    pub(crate) fn mov_r32_imm(&mut self, dst: u8, imm: i32) {
        self.rex_opt(false, 0, dst);
        self.code.push(0xb8 + (dst & 7));
        self.imm32(imm);
    }

    /// `movabs dst, imm64`.
    pub(crate) fn mov_r_imm64(&mut self, dst: u8, imm: u64) {
        self.rex(true, 0, dst);
        self.code.push(0xb8 + (dst & 7));
        self.imm64(imm);
    }

    /// `movsxd dst, src32` (sign-extend low 32 bits).
    pub(crate) fn movsxd_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, dst, src);
        self.code.push(0x63);
        self.code.push(modrm(0b11, dst, src));
    }

    /// `mov dst32, src32` (zero-extend low 32 bits).
    pub(crate) fn mov_r32_r32(&mut self, dst: u8, src: u8) {
        self.rex_opt(false, src, dst);
        self.code.push(0x89);
        self.code.push(modrm(0b11, src, dst));
    }

    // --- 64-bit ALU -----------------------------------------------------

    /// `add dst, src`.
    pub(crate) fn add_rr(&mut self, dst: u8, src: u8) {
        self.alu_rr(0x01, dst, src);
    }
    /// `sub dst, src`.
    pub(crate) fn sub_rr(&mut self, dst: u8, src: u8) {
        self.alu_rr(0x29, dst, src);
    }
    /// `and dst, src`.
    pub(crate) fn and_rr(&mut self, dst: u8, src: u8) {
        self.alu_rr(0x21, dst, src);
    }
    /// `or dst, src`.
    pub(crate) fn or_rr(&mut self, dst: u8, src: u8) {
        self.alu_rr(0x09, dst, src);
    }
    /// `xor dst, src`.
    pub(crate) fn xor_rr(&mut self, dst: u8, src: u8) {
        self.alu_rr(0x31, dst, src);
    }
    /// `cmp a, b`.
    pub(crate) fn cmp_rr(&mut self, a: u8, b: u8) {
        self.alu_rr(0x39, a, b);
    }

    fn alu_rr(&mut self, opcode: u8, dst: u8, src: u8) {
        self.rex(true, src, dst);
        self.code.push(opcode);
        self.code.push(modrm(0b11, src, dst));
    }

    /// `imul dst, src` (64-bit two-operand).
    pub(crate) fn imul_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, dst, src);
        self.bytes(&[0x0f, 0xaf]);
        self.code.push(modrm(0b11, dst, src));
    }

    /// `imul dst, dst, imm32`.
    pub(crate) fn imul_r_imm(&mut self, dst: u8, imm: i32) {
        self.rex(true, dst, dst);
        self.code.push(0x69);
        self.code.push(modrm(0b11, dst, dst));
        self.imm32(imm);
    }

    /// `add dst, imm32` (sign-extended).
    pub(crate) fn add_r_imm(&mut self, dst: u8, imm: i32) {
        self.alu_r_imm(0, dst, imm);
    }

    /// `cmp a, imm32` (sign-extended).
    pub(crate) fn cmp_r_imm(&mut self, a: u8, imm: i32) {
        self.alu_r_imm(7, a, imm);
    }

    fn alu_r_imm(&mut self, ext: u8, dst: u8, imm: i32) {
        self.rex(true, ext, dst);
        self.code.push(0x81);
        self.code.push(modrm(0b11, ext, dst));
        self.imm32(imm);
    }

    /// `cmp r, qword [base + disp32]`.
    pub(crate) fn cmp_r_mem(&mut self, r: u8, base: u8, disp: i32) {
        self.rex(true, r, base);
        self.code.push(0x3b);
        self.mem_disp32(r, base, disp);
    }

    /// `test a, b` (64-bit).
    pub(crate) fn test_rr(&mut self, a: u8, b: u8) {
        self.rex(true, b, a);
        self.code.push(0x85);
        self.code.push(modrm(0b11, b, a));
    }

    /// `test a32, b32`.
    pub(crate) fn test_r32_r32(&mut self, a: u8, b: u8) {
        self.rex_opt(false, b, a);
        self.code.push(0x85);
        self.code.push(modrm(0b11, b, a));
    }

    /// `cmp a32, imm32`.
    pub(crate) fn cmp_r32_imm(&mut self, a: u8, imm: i32) {
        self.rex_opt(false, 7, a);
        self.code.push(0x81);
        self.code.push(modrm(0b11, 7, a));
        self.imm32(imm);
    }

    /// `xor dst32, dst32` (zero a register).
    pub(crate) fn xor_r32_r32(&mut self, dst: u8, src: u8) {
        self.rex_opt(false, src, dst);
        self.code.push(0x31);
        self.code.push(modrm(0b11, src, dst));
    }

    /// `or dst32, src32`.
    pub(crate) fn or_r32_r32(&mut self, dst: u8, src: u8) {
        self.rex_opt(false, src, dst);
        self.code.push(0x09);
        self.code.push(modrm(0b11, src, dst));
    }

    /// `shl r32, imm8`.
    pub(crate) fn shl_r32_imm8(&mut self, r: u8, imm: u8) {
        self.rex_opt(false, 4, r);
        self.code.push(0xc1);
        self.code.push(modrm(0b11, 4, r));
        self.code.push(imm);
    }

    /// `shl r, cl` (64-bit).
    pub(crate) fn shl_r_cl(&mut self, r: u8) {
        self.shift_cl(4, r);
    }
    /// `shr r, cl` (64-bit logical).
    pub(crate) fn shr_r_cl(&mut self, r: u8) {
        self.shift_cl(5, r);
    }
    /// `sar r, cl` (64-bit arithmetic).
    pub(crate) fn sar_r_cl(&mut self, r: u8) {
        self.shift_cl(7, r);
    }

    fn shift_cl(&mut self, ext: u8, r: u8) {
        self.rex(true, ext, r);
        self.code.push(0xd3);
        self.code.push(modrm(0b11, ext, r));
    }

    // --- flags → values -------------------------------------------------

    /// `setcc r8` (r8 must be al/cl/dl — no REX path).
    pub(crate) fn setcc(&mut self, cc: Cc, r8: u8) {
        debug_assert!(r8 < 4);
        self.bytes(&[0x0f, 0x90 + cc as u8]);
        self.code.push(modrm(0b11, 0, r8));
    }

    /// `movzx dst32, src8` (src8 must be al/cl/dl).
    pub(crate) fn movzx_r32_r8(&mut self, dst: u8, src: u8) {
        debug_assert!(dst < 8 && src < 4);
        self.bytes(&[0x0f, 0xb6]);
        self.code.push(modrm(0b11, dst, src));
    }

    /// `and dst8, src8` (low byte registers).
    pub(crate) fn and_r8_r8(&mut self, dst: u8, src: u8) {
        debug_assert!(dst < 4 && src < 4);
        self.code.push(0x20);
        self.code.push(modrm(0b11, src, dst));
    }

    /// `or dst8, src8` (low byte registers).
    pub(crate) fn or_r8_r8(&mut self, dst: u8, src: u8) {
        debug_assert!(dst < 4 && src < 4);
        self.code.push(0x08);
        self.code.push(modrm(0b11, src, dst));
    }

    // --- [rcx + rdx] memory accesses (the bounds-checked buffer slot) ---

    /// `movsxd rax, dword [rcx + rdx]`.
    pub(crate) fn load_i32_sib(&mut self) {
        self.bytes(&[0x48, 0x63, 0x04, 0x11]);
    }
    /// `mov eax, dword [rcx + rdx]` (zero-extends).
    pub(crate) fn load_u32_sib(&mut self) {
        self.bytes(&[0x8b, 0x04, 0x11]);
    }
    /// `mov rax, qword [rcx + rdx]`.
    pub(crate) fn load_i64_sib(&mut self) {
        self.bytes(&[0x48, 0x8b, 0x04, 0x11]);
    }
    /// `cmp byte [rcx + rdx], 0`.
    pub(crate) fn cmp_bool_sib(&mut self) {
        self.bytes(&[0x80, 0x3c, 0x11, 0x00]);
    }
    /// `mov dword [rcx + rdx], eax`.
    pub(crate) fn store_u32_sib(&mut self) {
        self.bytes(&[0x89, 0x04, 0x11]);
    }
    /// `mov qword [rcx + rdx], rax`.
    pub(crate) fn store_u64_sib(&mut self) {
        self.bytes(&[0x48, 0x89, 0x04, 0x11]);
    }
    /// `mov byte [rcx + rdx], al`.
    pub(crate) fn store_u8_sib(&mut self) {
        self.bytes(&[0x88, 0x04, 0x11]);
    }
    /// `movss xmm0, dword [rcx + rdx]`.
    pub(crate) fn load_f32_sib(&mut self) {
        self.bytes(&[0xf3, 0x0f, 0x10, 0x04, 0x11]);
    }
    /// `movss dword [rcx + rdx], xmm0`.
    pub(crate) fn store_f32_sib(&mut self) {
        self.bytes(&[0xf3, 0x0f, 0x11, 0x04, 0x11]);
    }
    /// `movsd xmm0, qword [rcx + rdx]`.
    pub(crate) fn load_f64_sib(&mut self) {
        self.bytes(&[0xf2, 0x0f, 0x10, 0x04, 0x11]);
    }
    /// `movsd qword [rcx + rdx], xmm0`.
    pub(crate) fn store_f64_sib(&mut self) {
        self.bytes(&[0xf2, 0x0f, 0x11, 0x04, 0x11]);
    }

    // --- SSE scalar double ---------------------------------------------

    /// `movsd xmm, qword [base + disp32]`.
    pub(crate) fn movsd_x_mem(&mut self, xmm: u8, base: u8, disp: i32) {
        self.code.push(0xf2);
        self.rex_opt(false, xmm, base);
        self.bytes(&[0x0f, 0x10]);
        self.mem_disp32(xmm, base, disp);
    }

    /// `movsd qword [base + disp32], xmm`.
    pub(crate) fn movsd_mem_x(&mut self, base: u8, disp: i32, xmm: u8) {
        self.code.push(0xf2);
        self.rex_opt(false, xmm, base);
        self.bytes(&[0x0f, 0x11]);
        self.mem_disp32(xmm, base, disp);
    }

    /// `cvtsi2sd xmm, qword [base + disp32]` (i64 → f64).
    pub(crate) fn cvtsi2sd_x_mem(&mut self, xmm: u8, base: u8, disp: i32) {
        self.code.push(0xf2);
        self.rex(true, xmm, base);
        self.bytes(&[0x0f, 0x2a]);
        self.mem_disp32(xmm, base, disp);
    }

    /// `cvtsi2sd xmm, r64`.
    pub(crate) fn cvtsi2sd_x_r(&mut self, xmm: u8, r: u8) {
        self.code.push(0xf2);
        self.rex(true, xmm, r);
        self.bytes(&[0x0f, 0x2a]);
        self.code.push(modrm(0b11, xmm, r));
    }

    /// `addsd dst, src`.
    pub(crate) fn addsd(&mut self, dst: u8, src: u8) {
        self.sse_f2(0x58, dst, src);
    }
    /// `subsd dst, src`.
    pub(crate) fn subsd(&mut self, dst: u8, src: u8) {
        self.sse_f2(0x5c, dst, src);
    }
    /// `mulsd dst, src`.
    pub(crate) fn mulsd(&mut self, dst: u8, src: u8) {
        self.sse_f2(0x59, dst, src);
    }
    /// `divsd dst, src`.
    pub(crate) fn divsd(&mut self, dst: u8, src: u8) {
        self.sse_f2(0x5e, dst, src);
    }
    /// `cvtsd2ss dst, src` (f64 → f32).
    pub(crate) fn cvtsd2ss(&mut self, dst: u8, src: u8) {
        self.sse_f2(0x5a, dst, src);
    }

    fn sse_f2(&mut self, opcode: u8, dst: u8, src: u8) {
        self.code.push(0xf2);
        self.bytes(&[0x0f, opcode]);
        self.code.push(modrm(0b11, dst, src));
    }

    /// `cvtss2sd dst, src` (f32 → f64).
    pub(crate) fn cvtss2sd(&mut self, dst: u8, src: u8) {
        self.code.push(0xf3);
        self.bytes(&[0x0f, 0x5a]);
        self.code.push(modrm(0b11, dst, src));
    }

    /// `ucomisd a, b` (sets ZF/PF/CF from the compare `a ? b`).
    pub(crate) fn ucomisd(&mut self, a: u8, b: u8) {
        self.bytes(&[0x66, 0x0f, 0x2e]);
        self.code.push(modrm(0b11, a, b));
    }

    /// `xorps dst, src` (zero an XMM register).
    pub(crate) fn xorps(&mut self, dst: u8, src: u8) {
        self.bytes(&[0x0f, 0x57]);
        self.code.push(modrm(0b11, dst, src));
    }

    // --- control flow ---------------------------------------------------

    /// `jmp label` (rel32).
    pub(crate) fn jmp(&mut self, l: Label) {
        self.code.push(0xe9);
        self.fixups.push((self.code.len(), l));
        self.imm32(0);
    }

    /// `jcc label` (rel32).
    pub(crate) fn jcc(&mut self, cc: Cc, l: Label) {
        self.bytes(&[0x0f, 0x80 + cc as u8]);
        self.fixups.push((self.code.len(), l));
        self.imm32(0);
    }

    /// `call r`.
    pub(crate) fn call_r(&mut self, r: u8) {
        self.rex_opt(false, 2, r);
        self.code.push(0xff);
        self.code.push(modrm(0b11, 2, r));
    }
}

// ---------------------------------------------------------------------
// Executable memory (W^X)
// ---------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
mod sys {
    // Raw libc FFI (the crate is dependency-free; libc itself is always
    // linked on this target).
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn mprotect(addr: *mut u8, len: usize, prot: i32) -> i32;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const PROT_EXEC: i32 = 4;
    pub const MAP_PRIVATE_ANON: i32 = 0x22;
}

/// An owned, executable mapping of finished machine code.
///
/// The code is copied into an anonymous read+write mapping which is then
/// `mprotect`ed to read+execute — the pages are never writable and
/// executable at the same time, and the mapping is unmapped on drop.
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub(crate) struct ExecMem {
    ptr: *mut u8,
    map_len: usize,
}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
// SAFETY: the mapping is immutable (RX) for its whole lifetime after
// construction, so sharing raw pointers to it across threads is safe.
unsafe impl Send for ExecMem {}
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
unsafe impl Sync for ExecMem {}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
impl ExecMem {
    /// Map `code` into executable memory. `None` on any `mmap`/`mprotect`
    /// failure — the caller then runs without a JIT program.
    pub(crate) fn new(code: &[u8]) -> Option<ExecMem> {
        if code.is_empty() {
            return None;
        }
        let page = 4096usize;
        let map_len = code.len().div_ceil(page) * page;
        // SAFETY: anonymous private mapping; all arguments are valid.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                map_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_PRIVATE_ANON,
                -1,
                0,
            )
        };
        if ptr.is_null() || ptr as isize == -1 {
            return None;
        }
        // SAFETY: `ptr` is a fresh RW mapping of at least `code.len()`.
        unsafe {
            std::ptr::copy_nonoverlapping(code.as_ptr(), ptr, code.len());
            if sys::mprotect(ptr, map_len, sys::PROT_READ | sys::PROT_EXEC) != 0 {
                sys::munmap(ptr, map_len);
                return None;
            }
        }
        Some(ExecMem { ptr, map_len })
    }

    /// Pointer to the code at byte offset `off` (a region entry point).
    pub(crate) fn at(&self, off: usize) -> *const u8 {
        debug_assert!(off < self.map_len);
        // SAFETY: `off` is within the mapping (asserted above).
        unsafe { self.ptr.add(off) }
    }
}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
impl Drop for ExecMem {
    fn drop(&mut self) {
        // SAFETY: `ptr`/`map_len` are the exact mapping from `new`.
        unsafe {
            sys::munmap(self.ptr, self.map_len);
        }
    }
}

#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
impl std::fmt::Debug for ExecMem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ExecMem({} bytes)", self.map_len)
    }
}

#[cfg(test)]
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
mod tests {
    use super::*;

    fn emit(f: impl FnOnce(&mut Asm)) -> Vec<u8> {
        let mut a = Asm::new();
        f(&mut a);
        a.finish().unwrap()
    }

    #[test]
    fn prologue_epilogue_bytes() {
        assert_eq!(emit(|a| a.push_r14()), [0x41, 0x56]);
        assert_eq!(emit(|a| a.push_r15()), [0x41, 0x57]);
        assert_eq!(emit(|a| a.sub_rsp_8()), [0x48, 0x83, 0xec, 0x08]);
        assert_eq!(emit(|a| a.add_rsp_8()), [0x48, 0x83, 0xc4, 0x08]);
        assert_eq!(emit(|a| a.pop_r15()), [0x41, 0x5f]);
        assert_eq!(emit(|a| a.pop_r14()), [0x41, 0x5e]);
        assert_eq!(emit(|a| a.ret()), [0xc3]);
    }

    #[test]
    fn mov_encodings() {
        // mov r15, rdi ; mov rdi, r15
        assert_eq!(emit(|a| a.mov_rr(R15, RDI)), [0x49, 0x89, 0xff]);
        assert_eq!(emit(|a| a.mov_rr(RDI, R15)), [0x4c, 0x89, 0xff]);
        // mov r14, [r15 + 0x10]
        assert_eq!(
            emit(|a| a.mov_r_mem(R14, R15, 0x10)),
            [0x4d, 0x8b, 0xb7, 0x10, 0x00, 0x00, 0x00]
        );
        // mov rax, [r14 + 0x20] ; mov [r14 + 0x20], rax
        assert_eq!(
            emit(|a| a.mov_r_mem(RAX, R14, 0x20)),
            [0x49, 0x8b, 0x86, 0x20, 0x00, 0x00, 0x00]
        );
        assert_eq!(
            emit(|a| a.mov_mem_r(R14, 0x20, RAX)),
            [0x49, 0x89, 0x86, 0x20, 0x00, 0x00, 0x00]
        );
        // mov [r15 + 0x38], edx
        assert_eq!(
            emit(|a| a.mov_mem32_r32(R15, 0x38, RDX)),
            [0x41, 0x89, 0x97, 0x38, 0x00, 0x00, 0x00]
        );
        // mov dword [r15 + 0x30], 7
        assert_eq!(
            emit(|a| a.mov_mem32_imm(R15, 0x30, 7)),
            [0x41, 0xc7, 0x87, 0x30, 0x00, 0x00, 0x00, 0x07, 0x00, 0x00, 0x00]
        );
        // add qword [r15 + 0x28], 5
        assert_eq!(
            emit(|a| a.add_mem64_imm(R15, 0x28, 5)),
            [0x49, 0x81, 0x87, 0x28, 0x00, 0x00, 0x00, 0x05, 0x00, 0x00, 0x00]
        );
        // mov eax, 42 ; mov esi, 3 ; movabs rax, imm64
        assert_eq!(emit(|a| a.mov_r32_imm(RAX, 42)), [0xb8, 0x2a, 0x00, 0x00, 0x00]);
        assert_eq!(emit(|a| a.mov_r32_imm(RSI, 3)), [0xbe, 0x03, 0x00, 0x00, 0x00]);
        assert_eq!(
            emit(|a| a.mov_r_imm64(RAX, 0x1122334455667788)),
            [0x48, 0xb8, 0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11]
        );
        // movsxd rax, eax ; mov eax, eax
        assert_eq!(emit(|a| a.movsxd_rr(RAX, RAX)), [0x48, 0x63, 0xc0]);
        assert_eq!(emit(|a| a.mov_r32_r32(RAX, RAX)), [0x89, 0xc0]);
        // mov rax, rcx (reg-reg between low registers)
        assert_eq!(emit(|a| a.mov_rr(RAX, RCX)), [0x48, 0x89, 0xc8]);
    }

    #[test]
    fn alu_encodings() {
        assert_eq!(emit(|a| a.add_rr(RAX, RCX)), [0x48, 0x01, 0xc8]);
        assert_eq!(emit(|a| a.sub_rr(RAX, RCX)), [0x48, 0x29, 0xc8]);
        assert_eq!(emit(|a| a.and_rr(RAX, RCX)), [0x48, 0x21, 0xc8]);
        assert_eq!(emit(|a| a.or_rr(RAX, RCX)), [0x48, 0x09, 0xc8]);
        assert_eq!(emit(|a| a.xor_rr(RAX, RCX)), [0x48, 0x31, 0xc8]);
        assert_eq!(emit(|a| a.cmp_rr(RAX, RCX)), [0x48, 0x39, 0xc8]);
        assert_eq!(emit(|a| a.imul_rr(RAX, RCX)), [0x48, 0x0f, 0xaf, 0xc1]);
        assert_eq!(
            emit(|a| a.imul_r_imm(RAX, 8)),
            [0x48, 0x69, 0xc0, 0x08, 0x00, 0x00, 0x00]
        );
        assert_eq!(
            emit(|a| a.add_r_imm(RAX, 4)),
            [0x48, 0x81, 0xc0, 0x04, 0x00, 0x00, 0x00]
        );
        assert_eq!(
            emit(|a| a.cmp_r_imm(RAX, 4)),
            [0x48, 0x81, 0xf8, 0x04, 0x00, 0x00, 0x00]
        );
        assert_eq!(
            emit(|a| a.cmp_r_mem(RAX, R15, 0x10)),
            [0x49, 0x3b, 0x87, 0x10, 0x00, 0x00, 0x00]
        );
        assert_eq!(emit(|a| a.test_rr(RAX, RAX)), [0x48, 0x85, 0xc0]);
        assert_eq!(emit(|a| a.test_r32_r32(RAX, RAX)), [0x85, 0xc0]);
        assert_eq!(emit(|a| a.xor_r32_r32(RDX, RDX)), [0x31, 0xd2]);
        assert_eq!(emit(|a| a.or_r32_r32(RDX, RAX)), [0x09, 0xc2]);
        assert_eq!(emit(|a| a.shl_r32_imm8(RAX, 3)), [0xc1, 0xe0, 0x03]);
        assert_eq!(emit(|a| a.shl_r_cl(RAX)), [0x48, 0xd3, 0xe0]);
        assert_eq!(emit(|a| a.shr_r_cl(RAX)), [0x48, 0xd3, 0xe8]);
        assert_eq!(emit(|a| a.sar_r_cl(RAX)), [0x48, 0xd3, 0xf8]);
        assert_eq!(
            emit(|a| a.cmp_r32_imm(RDX, 0xff)),
            [0x81, 0xfa, 0xff, 0x00, 0x00, 0x00]
        );
    }

    #[test]
    fn setcc_and_byte_ops() {
        assert_eq!(emit(|a| a.setcc(Cc::E, RAX)), [0x0f, 0x94, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::Ne, RAX)), [0x0f, 0x95, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::L, RAX)), [0x0f, 0x9c, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::Le, RAX)), [0x0f, 0x9e, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::G, RAX)), [0x0f, 0x9f, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::Ge, RAX)), [0x0f, 0x9d, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::B, RAX)), [0x0f, 0x92, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::Be, RAX)), [0x0f, 0x96, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::A, RAX)), [0x0f, 0x97, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::Ae, RAX)), [0x0f, 0x93, 0xc0]);
        assert_eq!(emit(|a| a.setcc(Cc::P, RCX)), [0x0f, 0x9a, 0xc1]);
        assert_eq!(emit(|a| a.setcc(Cc::Np, RCX)), [0x0f, 0x9b, 0xc1]);
        assert_eq!(emit(|a| a.movzx_r32_r8(RAX, RAX)), [0x0f, 0xb6, 0xc0]);
        assert_eq!(emit(|a| a.and_r8_r8(RAX, RCX)), [0x20, 0xc8]);
        assert_eq!(emit(|a| a.or_r8_r8(RAX, RCX)), [0x08, 0xc8]);
    }

    #[test]
    fn sib_memory_encodings() {
        assert_eq!(emit(|a| a.load_i32_sib()), [0x48, 0x63, 0x04, 0x11]);
        assert_eq!(emit(|a| a.load_u32_sib()), [0x8b, 0x04, 0x11]);
        assert_eq!(emit(|a| a.load_i64_sib()), [0x48, 0x8b, 0x04, 0x11]);
        assert_eq!(emit(|a| a.cmp_bool_sib()), [0x80, 0x3c, 0x11, 0x00]);
        assert_eq!(emit(|a| a.store_u32_sib()), [0x89, 0x04, 0x11]);
        assert_eq!(emit(|a| a.store_u64_sib()), [0x48, 0x89, 0x04, 0x11]);
        assert_eq!(emit(|a| a.store_u8_sib()), [0x88, 0x04, 0x11]);
        assert_eq!(emit(|a| a.load_f32_sib()), [0xf3, 0x0f, 0x10, 0x04, 0x11]);
        assert_eq!(emit(|a| a.store_f32_sib()), [0xf3, 0x0f, 0x11, 0x04, 0x11]);
        assert_eq!(emit(|a| a.load_f64_sib()), [0xf2, 0x0f, 0x10, 0x04, 0x11]);
        assert_eq!(emit(|a| a.store_f64_sib()), [0xf2, 0x0f, 0x11, 0x04, 0x11]);
    }

    #[test]
    fn sse_encodings() {
        // movsd xmm0, [r14 + 8] ; movsd [r14 + 8], xmm0
        assert_eq!(
            emit(|a| a.movsd_x_mem(XMM0, R14, 8)),
            [0xf2, 0x41, 0x0f, 0x10, 0x86, 0x08, 0x00, 0x00, 0x00]
        );
        assert_eq!(
            emit(|a| a.movsd_mem_x(R14, 8, XMM0)),
            [0xf2, 0x41, 0x0f, 0x11, 0x86, 0x08, 0x00, 0x00, 0x00]
        );
        // cvtsi2sd xmm0, qword [r14 + 8] ; cvtsi2sd xmm0, rax
        assert_eq!(
            emit(|a| a.cvtsi2sd_x_mem(XMM0, R14, 8)),
            [0xf2, 0x49, 0x0f, 0x2a, 0x86, 0x08, 0x00, 0x00, 0x00]
        );
        assert_eq!(emit(|a| a.cvtsi2sd_x_r(XMM0, RAX)), [0xf2, 0x48, 0x0f, 0x2a, 0xc0]);
        assert_eq!(emit(|a| a.addsd(XMM0, XMM1)), [0xf2, 0x0f, 0x58, 0xc1]);
        assert_eq!(emit(|a| a.subsd(XMM0, XMM1)), [0xf2, 0x0f, 0x5c, 0xc1]);
        assert_eq!(emit(|a| a.mulsd(XMM0, XMM1)), [0xf2, 0x0f, 0x59, 0xc1]);
        assert_eq!(emit(|a| a.divsd(XMM0, XMM1)), [0xf2, 0x0f, 0x5e, 0xc1]);
        assert_eq!(emit(|a| a.cvtsd2ss(XMM0, XMM0)), [0xf2, 0x0f, 0x5a, 0xc0]);
        assert_eq!(emit(|a| a.cvtss2sd(XMM0, XMM0)), [0xf3, 0x0f, 0x5a, 0xc0]);
        assert_eq!(emit(|a| a.ucomisd(XMM0, XMM1)), [0x66, 0x0f, 0x2e, 0xc1]);
        assert_eq!(emit(|a| a.xorps(XMM1, XMM1)), [0x0f, 0x57, 0xc9]);
    }

    #[test]
    fn control_flow_and_fixups() {
        // call rax
        assert_eq!(emit(|a| a.call_r(RAX)), [0xff, 0xd0]);
        // Forward jump: jmp over one `ret`; rel32 = 1.
        let code = emit(|a| {
            let l = a.label();
            a.jmp(l);
            a.ret();
            a.bind(l);
            a.ret();
        });
        assert_eq!(code, [0xe9, 0x01, 0x00, 0x00, 0x00, 0xc3, 0xc3]);
        // Backward conditional jump to offset 0 from a jcc at offset 1:
        // rel32 = 0 - (3 + 4) = -7.
        let code = emit(|a| {
            let l = a.label();
            a.bind(l);
            a.ret();
            a.jcc(Cc::Ne, l);
        });
        assert_eq!(code, [0xc3, 0x0f, 0x85, 0xf9, 0xff, 0xff, 0xff]);
        // Unbound label → finish fails instead of emitting garbage.
        let mut a = Asm::new();
        let l = a.label();
        a.jmp(l);
        assert!(a.finish().is_none());
    }

    #[test]
    fn exec_mem_runs_machine_code() {
        // mov eax, 42 ; ret
        let mut a = Asm::new();
        a.mov_r32_imm(RAX, 42);
        a.ret();
        let code = a.finish().unwrap();
        let mem = ExecMem::new(&code).expect("mmap");
        // SAFETY: the bytes are a complete, ABI-correct function.
        let f: unsafe extern "C" fn() -> u32 = unsafe { std::mem::transmute(mem.at(0)) };
        assert_eq!(unsafe { f() }, 42);
    }
}
