//! Runtime for the jitted tier: the execution context the templates
//! address, the helper the jitted code calls back into, and a
//! work-group driver with three dispatch tiers — jitted machine code
//! per region, bytecode for regions the JIT rejected, and the vector
//! engine for regions the bytecode lowerer rejected.
//!
//! The contract with [`super::lower`] is the `#[repr(C)]` [`JitCtx`]
//! header: the templates address it through `r15` using the `OFF_*`
//! constants exported here (checked by a unit test against real field
//! offsets), and every helper call is `dispatch::<W>(ctx, desc_index)`
//! with the SysV C ABI. The jitted code returns a protocol code in
//! `eax`: `0` = region ended at a barrier (`ctx.exit` indexes
//! `JitRegion::ends`), `1` = dynamically divergent branch (`ctx.div_idx`
//! indexes `JitRegion::branches`, `ctx.div_mask` has one bit per lane),
//! `2` = runtime error (bounds failure from a template, or a helper
//! error parked in `ctx.error`).
//!
//! Frames are flat `u64` payload arrays in slot-major order
//! (`frame[slot * W + lane]`), sized `frame_slots * W` per gang and
//! persistent across regions — registers are block-local (the same IR
//! invariant the bytecode tier leans on), so no stale payload is ever
//! read. Region constants are marshalled into the frame before entry;
//! a launch argument whose runtime value does not match the statically
//! inferred payload kind demotes that region to the bytecode tier for
//! the whole launch (counted in `jit_fallbacks`).
//!
//! Divergence and private memory use the *same* state as the other
//! engines: the gang owns a [`BcGang`] whose `VecStore` the helper
//! mutates in place, so a divergent jit region hands its lanes to
//! [`bytecode::diverge`] unchanged and results stay bit-identical.

use std::slice;

use crate::cl::error::{Error, Result};
use crate::ir::func::Function;
use crate::ir::inst::{BlockId, Term};
use crate::kcc::WorkGroupFunction;

use super::super::bytecode::{self, BcConst, BcGang};
use super::super::gang::{note_barrier, run_lane_to_barrier, GangStats};
use super::super::interp::{LaunchCtx, SlotStore};
use super::super::mem::MemoryRefs;
use super::super::value::{norm_float, norm_int, Val, VLane, VVal, SP_LOCAL, SP_PRIVATE};
use super::super::vecgang::{
    self, bin_vlane, cast_vlane, load_vlane, math_vlane, select_vlane, store_vlane, un_vlane,
    wi_vlane, GangState, VecStore,
};
use super::lower::{const_kind, Desc, JitProgram, JitRegion, Kind, SlotK};

// ---------------------------------------------------------------------
// The template ↔ runtime ABI.

/// Execution context the jitted code addresses through `r15`. The
/// leading fields up to `_pad` are the machine-visible header — their
/// offsets are frozen by the `OFF_*` constants below and asserted by a
/// unit test; the trailing fields are Rust-only state the helper uses.
#[repr(C)]
struct JitCtx<const W: usize> {
    /// Payload frame, slot-major: `frame[slot * W + lane]`.   (+0x00)
    frame: *mut u64,
    /// Global-memory base pointer.                            (+0x08)
    global_base: *mut u8,
    /// Global-memory length in bytes.                         (+0x10)
    global_len: u64,
    /// Local-memory base pointer.                             (+0x18)
    local_base: *mut u8,
    /// Local-memory length in bytes.                          (+0x20)
    local_len: u64,
    /// Retired-instruction counter (templates add batches).   (+0x28)
    insts: u64,
    /// `ends` index set by an `End` exit.                     (+0x30)
    exit: u32,
    /// `branches` index set by a divergent branch.            (+0x34)
    div_idx: u32,
    /// Per-lane truth mask set by a divergent branch.         (+0x38)
    div_mask: u32,
    _pad: u32,
    // --- Rust-only state (never addressed from templates) ---
    /// Helper-dispatch table of the active region.
    descs: *const Desc,
    ndescs: usize,
    /// The gang's private cells (shared with every other engine).
    store: *mut VecStore<W>,
    /// The gang's per-lane local ids.
    local_ids: *const [[u64; 3]; W],
    launch: *const LaunchCtx,
    /// Helper error park: filled before returning protocol code 2.
    error: *mut Option<Error>,
}

/// Template displacement of `JitCtx::frame`.
pub(crate) const OFF_FRAME: i32 = 0x00;
/// Template displacement of `JitCtx::insts`.
pub(crate) const OFF_INSTS: i32 = 0x28;
/// Template displacement of `JitCtx::exit`.
pub(crate) const OFF_EXIT: i32 = 0x30;
/// Template displacement of `JitCtx::div_idx`.
pub(crate) const OFF_DIV_IDX: i32 = 0x34;
/// Template displacement of `JitCtx::div_mask`.
pub(crate) const OFF_DIV_MASK: i32 = 0x38;

/// Displacement of the memory *base* pointer for an address-space tag.
pub(crate) fn off_base(tag: u8) -> i32 {
    if tag == SP_LOCAL {
        0x18
    } else {
        0x08
    }
}

/// Displacement of the memory *length* for an address-space tag.
pub(crate) fn off_len(tag: u8) -> i32 {
    if tag == SP_LOCAL {
        0x20
    } else {
        0x10
    }
}

/// Address of the monomorphised helper for a gang width, baked into
/// the emitted `call` sequences. `None` = width has no jit support.
pub(crate) fn helper_addr(width: usize) -> Option<u64> {
    match width {
        2 => {
            let p: unsafe extern "C" fn(*mut JitCtx<2>, u32) -> u32 = dispatch::<2>;
            Some(p as usize as u64)
        }
        4 => {
            let p: unsafe extern "C" fn(*mut JitCtx<4>, u32) -> u32 = dispatch::<4>;
            Some(p as usize as u64)
        }
        8 => {
            let p: unsafe extern "C" fn(*mut JitCtx<8>, u32) -> u32 = dispatch::<8>;
            Some(p as usize as u64)
        }
        16 => {
            let p: unsafe extern "C" fn(*mut JitCtx<16>, u32) -> u32 = dispatch::<16>;
            Some(p as usize as u64)
        }
        _ => None,
    }
}

// ---------------------------------------------------------------------
// The helper: marshal frame payloads to `VLane` values, run the shared
// `vecgang` kernel, marshal the result back.

/// Read one frame slot as a gang value under its inferred payload kind.
///
/// # Safety
/// `frame` must point at a live frame with at least `(slot + 1) * W`
/// payload words.
unsafe fn read_slot<const W: usize>(frame: *const u64, s: SlotK) -> VLane<W> {
    let mut lanes = Vec::with_capacity(W);
    for l in 0..W {
        let raw = *frame.add(s.slot as usize * W + l);
        lanes.push(match s.kind {
            Kind::I => VVal::S(Val::I(raw as i64)),
            Kind::F => VVal::S(Val::F(f64::from_bits(raw))),
            Kind::P(t) => VVal::ptr(t, raw),
            Kind::Ps(_) => VVal::ptr(SP_PRIVATE, raw),
        });
    }
    VLane::from_lanes(lanes)
}

/// Write a gang value back into one frame slot under its payload kind.
///
/// # Safety
/// Same frame requirements as [`read_slot`].
unsafe fn write_slot<const W: usize>(frame: *mut u64, s: SlotK, v: &VLane<W>) {
    for l in 0..W {
        let vv = v.get(l);
        // Never panic inside the `extern "C"` call chain: a vector
        // value in a scalar slot (cannot happen for lowered regions)
        // degrades to its first component.
        let sv = match &vv {
            VVal::S(x) => *x,
            VVal::V(xs) => xs.first().copied().unwrap_or(Val::I(0)),
        };
        let raw = match s.kind {
            Kind::I => sv.as_i() as u64,
            Kind::F => sv.as_f().to_bits(),
            Kind::P(_) | Kind::Ps(_) => match sv {
                Val::Ptr { offset, .. } => offset,
                other => other.as_i() as u64,
            },
        };
        *frame.add(s.slot as usize * W + l) = raw;
    }
}

/// Run one helper-dispatched operation through the shared kernels.
///
/// # Safety
/// `frame` must satisfy [`read_slot`]'s requirements for every slot
/// named by `desc`.
unsafe fn run_desc<const W: usize>(
    frame: *mut u64,
    desc: &Desc,
    store: &mut VecStore<W>,
    mem: &mut MemoryRefs<'_>,
    launch: &LaunchCtx,
    local_ids: &[[u64; 3]; W],
) -> Result<()> {
    match desc {
        Desc::Bin { op, ty, dst, a, b } => {
            let va = read_slot::<W>(frame, *a);
            let vb = read_slot::<W>(frame, *b);
            let v = bin_vlane(*op, ty, &va, &vb)?.0;
            write_slot(frame, *dst, &v);
        }
        Desc::Un { op, ty, dst, a } => {
            let va = read_slot::<W>(frame, *a);
            let v = un_vlane(*op, ty, &va)?.0;
            write_slot(frame, *dst, &v);
        }
        Desc::Cast { to, from, dst, a } => {
            let va = read_slot::<W>(frame, *a);
            let v = cast_vlane(to, from, &va).0;
            write_slot(frame, *dst, &v);
        }
        Desc::Select { ty, dst, cond, a, b } => {
            let vc = read_slot::<W>(frame, *cond);
            let va = read_slot::<W>(frame, *a);
            let vb = read_slot::<W>(frame, *b);
            let v = select_vlane(ty, &vc, &va, &vb)?.0;
            write_slot(frame, *dst, &v);
        }
        Desc::Wi { func, dim, dst } => {
            let v = wi_vlane(*func, *dim, launch, local_ids).0;
            write_slot(frame, *dst, &v);
        }
        Desc::Math { func, ty, dst, args } => {
            let vals: Vec<VLane<W>> = args.iter().map(|s| read_slot::<W>(frame, *s)).collect();
            let refs: Vec<&VLane<W>> = vals.iter().collect();
            let v = math_vlane(*func, ty, &refs)?.0;
            write_slot(frame, *dst, &v);
        }
        Desc::Load { ty, dst, ptr } => {
            let vp = read_slot::<W>(frame, *ptr);
            let v = load_vlane(&vp, ty, store, mem)?;
            write_slot(frame, *dst, &v);
        }
        Desc::Store { ty, ptr, val } => {
            let vp = read_slot::<W>(frame, *ptr);
            let vv = read_slot::<W>(frame, *val);
            store_vlane(&vp, &vv, ty, store, mem)?;
        }
    }
    Ok(())
}

/// The callback the jitted `call` sequences target. SysV C ABI:
/// `rdi` = context, `esi` = desc index; returns the protocol code in
/// `eax` (`0` = ok, `2` = error parked in `ctx.error`).
///
/// # Safety
/// Called (only) from jitted code with a context built by
/// [`run_jit_region`]; every pointer in it is live for the call.
unsafe extern "C" fn dispatch<const W: usize>(ctx: *mut JitCtx<W>, idx: u32) -> u32 {
    let c = &mut *ctx;
    let descs = slice::from_raw_parts(c.descs, c.ndescs);
    let desc = match descs.get(idx as usize) {
        Some(d) => d,
        None => {
            *c.error = Some(Error::exec("jit: bad dispatch index"));
            return 2;
        }
    };
    let store = &mut *c.store;
    let mut mem = MemoryRefs {
        global: slice::from_raw_parts_mut(c.global_base, c.global_len as usize),
        local: slice::from_raw_parts_mut(c.local_base, c.local_len as usize),
    };
    let launch = &*c.launch;
    let local_ids = &*c.local_ids;
    match run_desc(c.frame, desc, store, &mut mem, launch, local_ids) {
        Ok(()) => 0,
        Err(e) => {
            *c.error = Some(e);
            2
        }
    }
}

// ---------------------------------------------------------------------
// The work-group driver.

/// Execute one work-group through the jit tier in gangs of `width`
/// lanes. Widths without jit support — and programs with no jit or
/// bytecode attached — degrade to the bytecode tier (which itself
/// degrades to the vector engine).
pub fn run_workgroup(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    width: usize,
) -> Result<GangStats> {
    match width {
        2 => run_wg::<2>(wgf, args, mem, ctx),
        4 => run_wg::<4>(wgf, args, mem, ctx),
        8 => run_wg::<8>(wgf, args, mem, ctx),
        16 => run_wg::<16>(wgf, args, mem, ctx),
        _ => bytecode::run_workgroup(wgf, args, mem, ctx, width),
    }
}

/// Per-gang state: the bytecode gang (vector-engine gang state plus the
/// `VLane` register frame, so both fallback tiers are free) plus the
/// flat payload frame the jitted code addresses.
struct JitGang<const W: usize> {
    bc: BcGang<W>,
    pay: Vec<u64>,
}

fn run_wg<const W: usize>(
    wgf: &WorkGroupFunction,
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
) -> Result<GangStats> {
    let f = &wgf.reg_fn;
    let prog = match wgf.bytecode.as_ref().filter(|p| p.reg_count == f.reg_count()) {
        Some(p) => p,
        None => return bytecode::run_workgroup(wgf, args, mem, ctx, W),
    };
    // Wholesale fallback: no jit program (kill switch, lowering failed,
    // poclbin decode) or one built for another width / register frame.
    let jit = match wgf
        .jit
        .as_ref()
        .filter(|j| j.width == W && j.reg_count == f.reg_count())
    {
        Some(j) => j,
        None => return bytecode::run_workgroup(wgf, args, mem, ctx, W),
    };

    let mut region_of: Vec<Option<usize>> = vec![None; f.blocks.len()];
    for (i, r) in prog.regions.iter().enumerate() {
        if let Some(slot) = region_of.get_mut(r.start.0 as usize) {
            *slot = Some(i);
        }
    }

    // `VLane` constant pools for regions that run on the bytecode tier.
    let consts: Vec<Vec<VLane<W>>> = bytecode::resolve_consts(f, &prog.regions, args);

    // Private-slot base offsets (same cumulative layout `VecStore` and
    // `resolve_consts` use).
    let mut bases: Vec<u64> = Vec::with_capacity(f.slots.len());
    let mut total = 0u64;
    for s in &f.slots {
        bases.push(total);
        total += s.count as u64;
    }

    // Raw payload pools for jitted regions. `None` demotes the region
    // to the bytecode tier: a launch argument's runtime value does not
    // fit the payload kind the templates were specialised against.
    let cpay: Vec<Option<Vec<u64>>> = prog
        .regions
        .iter()
        .enumerate()
        .map(|(i, r)| {
            jit.regions.get(i)?.as_ref()?;
            let mut pool = Vec::with_capacity(r.consts.len());
            for c in &r.consts {
                let p = match c {
                    BcConst::Int(v, s) => norm_int(*v, *s) as u64,
                    BcConst::Float(v, s) => norm_float(*v, *s).to_bits(),
                    BcConst::Slot(s) => *bases.get(s.0 as usize)?,
                    BcConst::Arg(a) => {
                        let k = const_kind(f, c)?;
                        let sv = match args.get(*a as usize)? {
                            VVal::S(v) => *v,
                            VVal::V(_) => return None,
                        };
                        match (k, sv) {
                            (Kind::I, Val::I(v)) => v as u64,
                            (Kind::F, Val::F(v)) => v.to_bits(),
                            (Kind::P(t), Val::Ptr { space, offset }) if space == t => offset,
                            _ => return None,
                        }
                    }
                };
                pool.push(p);
            }
            Some(pool)
        })
        .collect();

    let n = wgf.wg_size();
    let [lx, ly, _lz] = wgf.local_size;
    let mut stats = GangStats::default();

    let local_id = |wi: usize| -> [u64; 3] {
        [(wi % lx) as u64, ((wi / lx) % ly) as u64, (wi / (lx * ly)) as u64]
    };

    let full_gangs = n / W;
    let mut gangs: Vec<JitGang<W>> = (0..full_gangs)
        .map(|g| JitGang {
            bc: BcGang {
                gs: GangState {
                    store: VecStore::for_function(f),
                    local_ids: std::array::from_fn(|l| local_id(g * W + l)),
                },
                frame: vec![VLane::Uni(VVal::i(0)); f.reg_count() as usize],
            },
            pay: vec![0u64; jit.frame_slots * W],
        })
        .collect();
    let mut tail: Vec<(SlotStore, [u64; 3])> = (full_gangs * W..n)
        .map(|wi| (SlotStore::for_function(f), local_id(wi)))
        .collect();

    // Barrier walk, identical to the bytecode tier.
    let mut cur: BlockId = f.entry;
    loop {
        let block = f.block(cur);
        debug_assert!(block.has_barrier());
        let start = match &block.term {
            Term::Ret => return Ok(stats),
            Term::Jump(s) => *s,
            Term::Br { .. } => return Err(Error::exec("barrier block with branch terminator")),
        };
        let region = region_of.get(start.0 as usize).copied().flatten();
        let mut next_barrier: Option<BlockId> = None;
        for gang in gangs.iter_mut() {
            stats.gangs += 1;
            let reached = match region {
                Some(ri) => {
                    let jr = jit.regions.get(ri).and_then(|o| o.as_ref());
                    match (jr, cpay[ri].as_ref()) {
                        (Some(jr), Some(pool)) => {
                            stats.jit_gangs += 1;
                            run_jit_region(f, jit, jr, pool, args, mem, ctx, gang, &mut stats)?
                        }
                        _ => {
                            stats.jit_fallbacks += 1;
                            stats.bytecode_gangs += 1;
                            let r = &prog.regions[ri];
                            bytecode::run_region(
                                f,
                                &r.code,
                                &consts[ri],
                                args,
                                mem,
                                ctx,
                                &mut gang.bc,
                                &mut stats,
                            )?
                        }
                    }
                }
                None => {
                    stats.jit_fallbacks += 1;
                    stats.bytecode_fallbacks += 1;
                    vecgang::run_gang_region_vec(
                        f,
                        args,
                        mem,
                        ctx,
                        &mut gang.bc.gs,
                        start,
                        &mut stats,
                    )?
                }
            };
            note_barrier(&mut next_barrier, reached, "across gangs")?;
        }
        if !tail.is_empty() {
            stats.gangs += 1;
        }
        for (store, lid) in tail.iter_mut() {
            let reached = run_lane_to_barrier(f, args, mem, ctx, store, start, *lid, &mut stats)?;
            note_barrier(&mut next_barrier, reached, "across gangs")?;
        }
        cur = next_barrier.expect("work-group is non-empty");
    }
}

/// Run one gang through one jitted region: marshal the constant pool
/// into the payload frame, call the region's entry point, and decode
/// the protocol result. Returns the barrier block the gang reached.
#[allow(clippy::too_many_arguments)]
fn run_jit_region<const W: usize>(
    f: &Function,
    jp: &JitProgram,
    jr: &JitRegion,
    pool: &[u64],
    args: &[VVal],
    mem: &mut MemoryRefs<'_>,
    ctx: &LaunchCtx,
    gang: &mut JitGang<W>,
    stats: &mut GangStats,
) -> Result<BlockId> {
    let nregs = jp.reg_count as usize;
    for (i, p) in pool.iter().enumerate() {
        let base = (nregs + i) * W;
        gang.pay[base..base + W].fill(*p);
    }

    let mut error: Option<Error> = None;
    let mut jctx = JitCtx::<W> {
        frame: gang.pay.as_mut_ptr(),
        global_base: mem.global.as_mut_ptr(),
        global_len: mem.global.len() as u64,
        local_base: mem.local.as_mut_ptr(),
        local_len: mem.local.len() as u64,
        insts: 0,
        exit: 0,
        div_idx: 0,
        div_mask: 0,
        _pad: 0,
        descs: jr.descs.as_ptr(),
        ndescs: jr.descs.len(),
        store: &mut gang.bc.gs.store,
        local_ids: &gang.bc.gs.local_ids,
        launch: ctx,
        error: &mut error,
    };
    // SAFETY: `entry` points at the still-mapped executable region the
    // lowerer emitted for exactly this context layout and width; every
    // pointer in `jctx` outlives the call.
    let ret = unsafe {
        let entry: unsafe extern "C" fn(*mut JitCtx<W>) -> u32 =
            std::mem::transmute(jp.code.at(jr.entry));
        entry(&mut jctx)
    };
    stats.jit_insts += jctx.insts as usize;
    match ret {
        0 => jr
            .ends
            .get(jctx.exit as usize)
            .copied()
            .ok_or_else(|| Error::exec("jit: bad exit index")),
        1 => {
            let (ir_t, ir_f) = *jr
                .branches
                .get(jctx.div_idx as usize)
                .ok_or_else(|| Error::exec("jit: bad branch index"))?;
            let mask = jctx.div_mask;
            let mut lt = [ir_t; W];
            for (l, tgt) in lt.iter_mut().enumerate() {
                *tgt = if mask & (1u32 << l) != 0 { ir_t } else { ir_f };
            }
            bytecode::diverge(f, args, mem, ctx, &mut gang.bc.gs, &lt, stats)
        }
        _ => Err(error
            .take()
            .unwrap_or_else(|| Error::exec("jit: out-of-bounds memory access"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_header_offsets_match_templates() {
        let mut frame = [0u64; 4];
        let mut err: Option<Error> = None;
        let ctx = JitCtx::<4> {
            frame: frame.as_mut_ptr(),
            global_base: std::ptr::null_mut(),
            global_len: 0,
            local_base: std::ptr::null_mut(),
            local_len: 0,
            insts: 0,
            exit: 0,
            div_idx: 0,
            div_mask: 0,
            _pad: 0,
            descs: std::ptr::null(),
            ndescs: 0,
            store: std::ptr::null_mut(),
            local_ids: std::ptr::null(),
            launch: std::ptr::null(),
            error: &mut err,
        };
        let base = &ctx as *const JitCtx<4> as usize;
        assert_eq!(&ctx.frame as *const _ as usize - base, OFF_FRAME as usize);
        assert_eq!(&ctx.global_base as *const _ as usize - base, off_base(0) as usize);
        assert_eq!(&ctx.global_len as *const _ as usize - base, off_len(0) as usize);
        assert_eq!(&ctx.local_base as *const _ as usize - base, off_base(SP_LOCAL) as usize);
        assert_eq!(&ctx.local_len as *const _ as usize - base, off_len(SP_LOCAL) as usize);
        assert_eq!(&ctx.insts as *const _ as usize - base, OFF_INSTS as usize);
        assert_eq!(&ctx.exit as *const _ as usize - base, OFF_EXIT as usize);
        assert_eq!(&ctx.div_idx as *const _ as usize - base, OFF_DIV_IDX as usize);
        assert_eq!(&ctx.div_mask as *const _ as usize - base, OFF_DIV_MASK as usize);
    }

    #[test]
    fn slot_payload_roundtrip() {
        let mut buf = vec![0u64; 3 * 4];
        let fs = SlotK { slot: 0, kind: Kind::F };
        let is = SlotK { slot: 1, kind: Kind::I };
        let ps = SlotK { slot: 2, kind: Kind::P(0) };
        let fv: VLane<4> = VLane::from_lanes(vec![
            VVal::S(Val::F(1.5)),
            VVal::S(Val::F(-2.0)),
            VVal::S(Val::F(0.0)),
            VVal::S(Val::F(3.25)),
        ]);
        let iv: VLane<4> = VLane::from_lanes(vec![
            VVal::S(Val::I(-1)),
            VVal::S(Val::I(0)),
            VVal::S(Val::I(7)),
            VVal::S(Val::I(i64::MAX)),
        ]);
        let pv: VLane<4> = VLane::from_lanes(vec![
            VVal::ptr(0, 0),
            VVal::ptr(0, 8),
            VVal::ptr(0, 16),
            VVal::ptr(0, 24),
        ]);
        unsafe {
            write_slot(buf.as_mut_ptr(), fs, &fv);
            write_slot(buf.as_mut_ptr(), is, &iv);
            write_slot(buf.as_mut_ptr(), ps, &pv);
            let rf: VLane<4> = read_slot(buf.as_ptr(), fs);
            let ri: VLane<4> = read_slot(buf.as_ptr(), is);
            let rp: VLane<4> = read_slot(buf.as_ptr(), ps);
            for l in 0..4 {
                assert_eq!(rf.get(l).scalar().as_f().to_bits(), fv.get(l).scalar().as_f().to_bits());
                assert_eq!(ri.get(l).scalar().as_i(), iv.get(l).scalar().as_i());
                assert_eq!(rp.get(l).scalar().as_i(), pv.get(l).scalar().as_i());
            }
        }
    }
}
