//! Warn-once parsing of `POCLRS_*` environment knobs.
//!
//! An invalid value in an environment override should be diagnosable —
//! a typo'd `POCLRS_OPT=o2` silently running at the default level is a
//! measurement hazard — but the warning must not repeat on every parse
//! (options are re-read per compile). This module centralises the
//! pattern first introduced for `POCLRS_GANG_WIDTH`: parse, and on
//! failure emit **one** stderr warning per variable per process, then
//! fall back to the default.

use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

/// Variables already warned about in this process.
fn warned() -> &'static Mutex<HashSet<&'static str>> {
    static WARNED: OnceLock<Mutex<HashSet<&'static str>>> = OnceLock::new();
    WARNED.get_or_init(|| Mutex::new(HashSet::new()))
}

/// Emit a one-time (per variable, per process) stderr warning that
/// `var`'s value `raw` was ignored. `expected` describes the accepted
/// form, `fallback` what happens instead.
pub fn warn_invalid(var: &'static str, raw: &str, expected: &str, fallback: &str) {
    let mut set = warned().lock().unwrap_or_else(|e| e.into_inner());
    if set.insert(var) {
        eprintln!("poclrs: ignoring invalid {var}={raw:?} (expected {expected}); {fallback}");
    }
}

/// Parse an environment value with `parse`, warning once (per variable,
/// per process) when the value is present but invalid. Returns `None`
/// both for an absent value and for an invalid one — callers supply
/// their own default either way.
pub fn parse_or_warn<T>(
    var: &'static str,
    raw: Option<&str>,
    expected: &str,
    fallback: &str,
    parse: impl FnOnce(&str) -> Option<T>,
) -> Option<T> {
    let raw = raw?;
    match parse(raw) {
        Some(v) => Some(v),
        None => {
            warn_invalid(var, raw, expected, fallback);
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_values_parse_through() {
        let v = parse_or_warn("POCLRS_TEST_A", Some("42"), "an integer", "using default", |s| {
            s.parse::<u32>().ok()
        });
        assert_eq!(v, Some(42));
    }

    #[test]
    fn absent_and_invalid_values_yield_none() {
        let absent = parse_or_warn("POCLRS_TEST_B", None, "an integer", "using default", |s| {
            s.parse::<u32>().ok()
        });
        assert_eq!(absent, None);
        let bad =
            parse_or_warn("POCLRS_TEST_B", Some("banana"), "an integer", "using default", |s| {
                s.parse::<u32>().ok()
            });
        assert_eq!(bad, None);
        // A second invalid parse of the same variable must not warn again
        // (observable only on stderr; here we just assert it still
        // returns None without panicking).
        let again =
            parse_or_warn("POCLRS_TEST_B", Some("banana"), "an integer", "using default", |s| {
                s.parse::<u32>().ok()
            });
        assert_eq!(again, None);
    }
}
