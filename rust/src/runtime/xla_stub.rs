//! Build-only stand-in for the external `xla` crate.
//!
//! The container this repo builds in does not vendor the `xla` PJRT
//! bindings, but the `pjrt` feature (device + runtime API surface) must
//! still compile so CI can build and type-check the offload path. This
//! module mirrors exactly the slice of the `xla` API the runtime touches;
//! every entry point that would reach the real PJRT C API returns an
//! error at run time. Enabling the `xla-backend` feature (and adding the
//! vendored dependency) swaps in the real crate with no source changes.

use std::fmt;
use std::path::Path;

/// Error type standing in for `xla::Error` (Display-compatible).
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable() -> XlaError {
    XlaError(
        "xla backend not linked (build with the `xla-backend` feature and a vendored `xla` \
         crate)"
            .to_string(),
    )
}

type XlaResult<T> = std::result::Result<T, XlaError>;

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client construction always fails in the stub.
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(unavailable())
    }

    /// Platform name (never observable: construction fails first).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Device count (never observable: construction fails first).
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compilation always fails in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parsing always fails in the stub.
    pub fn from_text_file(_path: impl AsRef<Path>) -> XlaResult<HloModuleProto> {
        Err(unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Proto wrapping (pure, infallible in the real crate too).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execution always fails in the stub.
    pub fn execute<L>(&self, _args: &[Literal]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetching always fails in the stub.
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(unavailable())
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Host-buffer wrapping (pure in the real crate).
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshaping always fails in the stub.
    pub fn reshape(self, _dims: &[i64]) -> XlaResult<Literal> {
        Err(unavailable())
    }

    /// Tuple decomposition always fails in the stub.
    pub fn decompose_tuple(&mut self) -> XlaResult<Vec<Literal>> {
        Err(unavailable())
    }

    /// Typed read-back always fails in the stub.
    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(unavailable())
    }
}
