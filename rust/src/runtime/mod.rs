//! PJRT runtime: loads AOT-compiled XLA/Pallas artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched. The compile path is
//! `python/compile/aot.py` (jax → StableHLO → HLO **text**); the rust side
//! loads the text with `HloModuleProto::from_text_file`, compiles it once on
//! the PJRT CPU client, and exposes a typed `execute` over `f32`/`i32`
//! host buffers. Python never runs on the request path.
//!
//! The `xla` dependency is gated twice: the `pjrt` feature compiles this
//! module against a type-compatible stub (so dependency-free environments
//! and CI can build the full API surface; execution errors at run time),
//! and the `xla-backend` feature swaps in the real vendored crate.

#[cfg(feature = "xla-backend")]
pub(crate) use ::xla;
#[cfg(not(feature = "xla-backend"))]
#[path = "xla_stub.rs"]
pub(crate) mod xla;

mod client;
mod executable;

pub use client::PjrtRuntime;
pub use executable::{ArgData, ArgSpec, DType, LoadedExecutable};
