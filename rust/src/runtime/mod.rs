//! PJRT runtime: loads AOT-compiled XLA/Pallas artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched. The compile path is
//! `python/compile/aot.py` (jax → StableHLO → HLO **text**); the rust side
//! loads the text with `HloModuleProto::from_text_file`, compiles it once on
//! the PJRT CPU client, and exposes a typed `execute` over `f32`/`i32`
//! host buffers. Python never runs on the request path.

mod client;
mod executable;

pub use client::PjrtRuntime;
pub use executable::{ArgData, ArgSpec, DType, LoadedExecutable};
