//! PJRT CPU client wrapper: one client per process, many loaded executables.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::cl::error::{Error, Result};

use super::executable::LoadedExecutable;
use super::xla;

/// A process-wide PJRT runtime holding the CPU client and a cache of
/// compiled executables keyed by artifact path.
///
/// Compilation of an HLO module is expensive (ms-scale); the cache makes the
/// `pjrt` device's kernel-enqueue path allocation- and compile-free after
/// the first launch, mirroring how pocl amortises kernel compilation across
/// enqueues (§6: "multiple execution iterations ... allow the kernel
/// compilers to amortize the kernel compilation time").
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<LoadedExecutable>>>,
}

// SAFETY: the `xla` crate wraps the PJRT client in `Rc` + raw pointers, so
// it is not auto-Send/Sync. All mutation funnels through this struct's
// Mutex-protected cache and `LoadedExecutable`'s execute lock; the PJRT
// CPU client itself is thread-safe at the C API level. The unsound corner
// (cloning the inner Rc concurrently) is never exercised: we hand out
// `Arc<LoadedExecutable>`, never the client.
unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Pjrt(e.to_string()))?;
        Ok(PjrtRuntime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform name reported by PJRT (e.g. `"cpu"` / `"Host"`).
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable PJRT devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact, compile it, and cache the executable.
    ///
    /// Returns the cached executable on subsequent calls with the same path.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<LoadedExecutable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(exe) = self.cache.lock().unwrap().get(&path) {
            return Ok(exe.clone());
        }
        let exe = Arc::new(LoadedExecutable::compile_from_file(&self.client, &path)?);
        self.cache.lock().unwrap().insert(path, exe.clone());
        Ok(exe)
    }

    /// Drop all cached executables (used by tests).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }

    /// Number of executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
