//! A compiled PJRT executable with a typed host-buffer execute interface.

use std::path::Path;

use crate::cl::error::{Error, Result};

use super::xla;

/// Shape + dtype of one executable argument, used to marshal flat host
/// buffers into PJRT literals.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    /// Row-major dimensions.
    pub dims: Vec<usize>,
    /// Element type (only f32/i32 are used by the suite kernels).
    pub dtype: DType,
}

/// Element dtypes supported on the artifact boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl ArgSpec {
    /// f32 tensor spec.
    pub fn f32(dims: &[usize]) -> Self {
        ArgSpec { dims: dims.to_vec(), dtype: DType::F32 }
    }
    /// i32 tensor spec.
    pub fn i32(dims: &[usize]) -> Self {
        ArgSpec { dims: dims.to_vec(), dtype: DType::I32 }
    }
    /// Total element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }
    /// True if zero-sized.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One argument's data, borrowed from host memory.
pub enum ArgData<'a> {
    /// f32 buffer.
    F32(&'a [f32]),
    /// i32 buffer.
    I32(&'a [i32]),
}

/// An HLO module compiled for the PJRT CPU client.
///
/// The python side lowers with `return_tuple=True`, so outputs are always a
/// tuple; `execute_f32` unpacks it into flat `Vec<f32>` buffers.
pub struct LoadedExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Serialises `execute` calls (see the Send/Sync note below).
    lock: std::sync::Mutex<()>,
    /// Artifact path (for diagnostics).
    pub path: String,
}

// SAFETY: see `PjrtRuntime` — execution is serialised through `lock`, and
// the wrapped executable is never cloned across threads.
unsafe impl Send for LoadedExecutable {}
unsafe impl Sync for LoadedExecutable {}

impl LoadedExecutable {
    /// Parse HLO text from `path` and compile it on `client`.
    pub fn compile_from_file(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::Pjrt(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Pjrt(format!("compile {}: {e}", path.display())))?;
        Ok(LoadedExecutable {
            exe,
            lock: std::sync::Mutex::new(()),
            path: path.display().to_string(),
        })
    }

    /// Execute with typed args; returns every tuple element as a flat f32
    /// vector (i32 outputs are not needed by the current artifacts).
    pub fn execute_f32(&self, args: &[(ArgData<'_>, &ArgSpec)]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(args.len());
        for (data, spec) in args {
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            let lit = match data {
                ArgData::F32(buf) => xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| Error::Pjrt(format!("reshape arg: {e}")))?,
                ArgData::I32(buf) => xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| Error::Pjrt(format!("reshape arg: {e}")))?,
            };
            literals.push(lit);
        }
        let _guard = self.lock.lock().unwrap();
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Pjrt(format!("execute {}: {e}", self.path)))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Pjrt(format!("fetch result: {e}")))?;
        // Outputs are lowered with return_tuple=True: decompose the tuple.
        let elems = result
            .decompose_tuple()
            .map_err(|e| Error::Pjrt(format!("decompose tuple: {e}")))?;
        let mut out = Vec::with_capacity(elems.len());
        for elem in elems {
            out.push(
                elem.to_vec::<f32>()
                    .map_err(|e| Error::Pjrt(format!("read output: {e}")))?,
            );
        }
        Ok(out)
    }
}
