//! Branch-free f32 elemental functions (§5 / §5.1 of the paper).
//!
//! Algorithms follow Vecmathlib's structure: bit manipulation for the
//! trivial functions, Newton iteration where a cheap inverse exists, and
//! range reduction + minimax polynomial (Cephes coefficients) for the
//! transcendentals. All bodies are straight-line code so the `RealVec`
//! lane loops auto-vectorise.

/// |x| via sign-bit clearing (§5.1: "fabs is implemented by setting the
/// sign bit to 0").
#[inline]
pub fn fabs(x: f32) -> f32 {
    f32::from_bits(x.to_bits() & 0x7FFF_FFFF)
}

/// Sign bit test via bit manipulation.
#[inline]
pub fn signbit(x: f32) -> bool {
    x.to_bits() >> 31 != 0
}

/// copysign via bit splicing.
#[inline]
pub fn copysign(x: f32, y: f32) -> f32 {
    f32::from_bits((x.to_bits() & 0x7FFF_FFFF) | (y.to_bits() & 0x8000_0000))
}

/// Square root via exponent halving + Newton iterations (§5.1). The
/// hardware `sqrtss` is what production uses ([`sqrt`]); this version
/// exists to validate the paper's algorithm and for targets without a
/// sqrt unit.
#[inline]
pub fn sqrt_newton(x: f32) -> f32 {
    if x <= 0.0 {
        return if x == 0.0 { 0.0 } else { f32::NAN };
    }
    // Initial guess: halve the exponent.
    let b = x.to_bits();
    let e = ((b >> 23) & 0xFF) as i32 - 127;
    let guess = f32::from_bits((((e / 2 + 127) as u32) << 23) | (b & 0x007F_FFFF) >> 1);
    // Newton: r' = (r + x/r) / 2 — doubles accurate digits per step.
    let mut r = guess.max(f32::MIN_POSITIVE);
    r = 0.5 * (r + x / r);
    r = 0.5 * (r + x / r);
    r = 0.5 * (r + x / r);
    r = 0.5 * (r + x / r);
    r
}

/// Hardware square root (the production path, like Vecmathlib's use of
/// `sqrtss`).
#[inline]
pub fn sqrt(x: f32) -> f32 {
    x.sqrt()
}

/// 1/sqrt(x).
#[inline]
pub fn rsqrt(x: f32) -> f32 {
    1.0 / x.sqrt()
}

const LOG2E: f32 = 1.442_695_04_f32;
const C1: f32 = 0.693_359_375_f32; // ln2 hi
const C2: f32 = -2.121_944_4e-4_f32; // ln2 lo

/// exp(x) via range reduction to [-ln2/2, ln2/2] + degree-5 minimax
/// polynomial (Cephes `expf` coefficients), exponent reassembled by bit
/// manipulation.
#[inline]
pub fn exp(x: f32) -> f32 {
    let x = x.clamp(-87.336_54, 88.722_835);
    let k = (x * LOG2E).round();
    let r = x - k * C1 - k * C2;
    let mut p = 1.987_569_2e-4_f32;
    p = p * r + 1.398_199_9e-3;
    p = p * r + 8.333_452e-3;
    p = p * r + 4.166_579_6e-2;
    p = p * r + 1.666_666_6e-1;
    p = p * r + 5.000_000_1e-1;
    let e = p * r * r + r + 1.0;
    // 2^k via exponent bits.
    let two_k = f32::from_bits((((k as i32 + 127) as u32) << 23).min(0xFF00_0000));
    e * two_k
}

/// 2^x.
#[inline]
pub fn exp2(x: f32) -> f32 {
    exp(x * core::f32::consts::LN_2)
}

/// ln(x) via mantissa/exponent split + atanh-series polynomial (Cephes
/// `logf`).
#[inline]
pub fn log(x: f32) -> f32 {
    if x <= 0.0 {
        return if x == 0.0 { f32::NEG_INFINITY } else { f32::NAN };
    }
    let b = x.to_bits();
    let mut e = ((b >> 23) & 0xFF) as i32 - 126;
    let mut m = f32::from_bits((b & 0x007F_FFFF) | (126 << 23)); // [0.5, 1)
    // Normalise to [sqrt(1/2), sqrt(2)).
    if m < core::f32::consts::FRAC_1_SQRT_2 {
        e -= 1;
        m = m + m - 1.0;
    } else {
        m -= 1.0;
    }
    let z = m * m;
    let mut p = 7.037_683_6e-2_f32;
    p = p * m - 1.151_461e-1;
    p = p * m + 1.167_699_9e-1;
    p = p * m - 1.242_014_1e-1;
    p = p * m + 1.424_932_3e-1;
    p = p * m - 1.666_805_7e-1;
    p = p * m + 2.000_071_5e-1;
    p = p * m - 2.499_999_4e-1;
    p = p * m + 3.333_333_1e-1;
    let mut r = m * z * p;
    let ef = e as f32;
    r += -2.121_944_4e-4 * ef;
    r -= 0.5 * z;
    r = m + r;
    r += 0.693_359_375 * ef;
    r
}

/// log2(x).
#[inline]
pub fn log2(x: f32) -> f32 {
    log(x) * core::f32::consts::LOG2_E
}

const FOPI: f32 = 1.273_239_5; // 4/pi
const DP1: f32 = 0.785_156_25;
const DP2: f32 = 2.418_756_5e-4;
const DP3: f32 = 3.774_895e-8;

/// Cephes-style octant reduction: returns (octant mod 8, reduced arg).
#[inline]
fn sincos_reduce(ax: f32) -> (i32, f32) {
    let mut j = (ax * FOPI) as i64;
    if j & 1 == 1 {
        j += 1;
    }
    let y = j as f32;
    let r = ((ax - y * DP1) - y * DP2) - y * DP3;
    ((j & 7) as i32, r)
}

#[inline]
fn sin_poly(r: f32) -> f32 {
    let z = r * r;
    ((-1.951_529_6e-4 * z + 8.332_161e-3) * z - 1.666_665_5e-1) * z * r + r
}

#[inline]
fn cos_poly(r: f32) -> f32 {
    let z = r * r;
    ((2.443_315_7e-5 * z - 1.388_731_6e-3) * z + 4.166_664_6e-2) * z * z - 0.5 * z + 1.0
}

/// sin(x) via Cephes-style reduction + polynomials. Accuracy degrades for
/// |x| ≳ 8192·π as with any single-precision payne-hanek-free reduction.
#[inline]
pub fn sin(x: f32) -> f32 {
    let mut sign = signbit(x);
    let ax = fabs(x);
    let (mut j, r) = sincos_reduce(ax);
    if j > 3 {
        sign = !sign;
        j -= 4;
    }
    let v = if j == 1 || j == 2 { cos_poly(r) } else { sin_poly(r) };
    if sign {
        -v
    } else {
        v
    }
}

/// cos(x).
#[inline]
pub fn cos(x: f32) -> f32 {
    let ax = fabs(x);
    let (mut j, r) = sincos_reduce(ax);
    let mut sign = false;
    if j > 3 {
        j -= 4;
        sign = !sign;
    }
    if j > 1 {
        sign = !sign;
    }
    let v = if j == 1 || j == 2 { sin_poly(r) } else { cos_poly(r) };
    if sign {
        -v
    } else {
        v
    }
}

/// tan(x) = sin/cos.
#[inline]
pub fn tan(x: f32) -> f32 {
    sin(x) / cos(x)
}

/// x^y for x > 0 (general signs handled per OpenCL pow rules minimally).
#[inline]
pub fn pow(x: f32, y: f32) -> f32 {
    if x == 0.0 {
        return if y == 0.0 { 1.0 } else { 0.0 };
    }
    if x < 0.0 {
        // Integer exponents keep sign semantics.
        let yi = y as i32;
        if y == yi as f32 {
            let v = exp(log(-x) * y);
            return if yi & 1 == 1 { -v } else { v };
        }
        return f32::NAN;
    }
    exp(log(x) * y)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(a: f32, b: f32) -> f32 {
        if b == 0.0 {
            a.abs()
        } else {
            ((a - b) / b).abs()
        }
    }

    #[test]
    fn bit_manipulation_functions() {
        assert_eq!(fabs(-2.5), 2.5);
        assert!(signbit(-0.0));
        assert!(!signbit(1.0));
        assert_eq!(copysign(3.0, -1.0), -3.0);
    }

    #[test]
    fn newton_sqrt_matches_hardware() {
        for &x in &[1e-6f32, 0.25, 1.0, 2.0, 3.14159, 1e6] {
            assert!(rel(sqrt_newton(x), x.sqrt()) < 1e-6, "sqrt({x})");
        }
        assert_eq!(sqrt_newton(0.0), 0.0);
        assert!(sqrt_newton(-1.0).is_nan());
    }

    #[test]
    fn exp_accuracy() {
        let mut x = -80.0f32;
        while x < 80.0 {
            assert!(rel(exp(x), x.exp()) < 3e-6, "exp({x}) = {} vs {}", exp(x), x.exp());
            x += 0.37;
        }
        assert_eq!(exp(0.0), 1.0);
    }

    #[test]
    fn log_accuracy() {
        let mut x = 1e-30f32;
        while x < 1e30 {
            assert!(rel(log(x), x.ln()) < 3e-6, "log({x}) = {} vs {}", log(x), x.ln());
            x *= 7.3;
        }
        assert_eq!(log(1.0), 0.0);
        assert_eq!(log(0.0), f32::NEG_INFINITY);
    }

    #[test]
    fn sin_cos_accuracy() {
        let mut x = -50.0f32;
        while x < 50.0 {
            assert!((sin(x) - x.sin()).abs() < 2e-6, "sin({x}) = {} vs {}", sin(x), x.sin());
            assert!((cos(x) - x.cos()).abs() < 2e-6, "cos({x}) = {} vs {}", cos(x), x.cos());
            x += 0.0917;
        }
    }

    #[test]
    fn pow_cases() {
        assert!(rel(pow(2.0, 10.0), 1024.0) < 1e-5);
        assert!(rel(pow(3.0, 0.5), 3.0f32.sqrt()) < 1e-5);
        assert_eq!(pow(-2.0, 2.0), 4.0);
        assert_eq!(pow(-2.0, 3.0), -8.0);
        assert!(pow(-2.0, 0.5).is_nan());
        assert_eq!(pow(0.0, 0.0), 1.0);
    }
}
