//! Vecmathlib port (§5): efficient, accurate, **vectorised** elemental
//! functions designed to inline into surrounding application code.
//!
//! * `scalar32`/`scalar64` — branch-light scalar algorithms (bit
//!   manipulation, Newton iteration, range reduction + polynomials).
//! * `realvec` — the `RealVec<N>` software-SIMD types whose lane loops
//!   LLVM auto-vectorises; Tables 3–4 of the paper are regenerated against
//!   these.
//!
//! The execution engines' math builtins dispatch here, mirroring how pocl
//! links kernels against Vecmathlib at bitcode level.

pub mod realvec;
pub mod scalar32;
pub mod scalar64;

pub use realvec::{RealVec, RealVec64};
