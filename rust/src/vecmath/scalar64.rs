//! f64 elemental functions: same structure as `scalar32` but with series
//! carried far enough for double precision (terms below 1e-16 on the
//! reduced ranges).

/// |x| via sign-bit clearing.
#[inline]
pub fn fabs(x: f64) -> f64 {
    f64::from_bits(x.to_bits() & 0x7FFF_FFFF_FFFF_FFFF)
}

/// Sign bit test.
#[inline]
pub fn signbit(x: f64) -> bool {
    x.to_bits() >> 63 != 0
}

/// Hardware square root.
#[inline]
pub fn sqrt(x: f64) -> f64 {
    x.sqrt()
}

/// Newton square root (validation of the §5.1 algorithm in f64).
#[inline]
pub fn sqrt_newton(x: f64) -> f64 {
    if x <= 0.0 {
        return if x == 0.0 { 0.0 } else { f64::NAN };
    }
    let b = x.to_bits();
    let e = ((b >> 52) & 0x7FF) as i64 - 1023;
    let guess = f64::from_bits((((e / 2 + 1023) as u64) << 52) | ((b & 0x000F_FFFF_FFFF_FFFF) >> 1));
    let mut r = guess.max(f64::MIN_POSITIVE);
    for _ in 0..6 {
        r = 0.5 * (r + x / r);
    }
    r
}

/// exp(x): reduce to r ∈ [-ln2/2, ln2/2], Taylor series to r¹²/12!
/// (max term ≈ 6e-15 on the range), scale by 2^k via exponent bits.
#[inline]
pub fn exp(x: f64) -> f64 {
    if x > 709.78 {
        return f64::INFINITY;
    }
    if x < -745.0 {
        return 0.0;
    }
    const LOG2E: f64 = core::f64::consts::LOG2_E;
    const LN2_HI: f64 = 6.931_471_803_691_238e-1;
    const LN2_LO: f64 = 1.908_214_929_270_587_7e-10;
    let k = (x * LOG2E).round();
    let r = (x - k * LN2_HI) - k * LN2_LO;
    // Horner Taylor: sum r^n / n!
    let mut p = 1.0 / 479_001_600.0; // 1/12!
    p = p * r + 1.0 / 39_916_800.0;
    p = p * r + 1.0 / 3_628_800.0;
    p = p * r + 1.0 / 362_880.0;
    p = p * r + 1.0 / 40_320.0;
    p = p * r + 1.0 / 5_040.0;
    p = p * r + 1.0 / 720.0;
    p = p * r + 1.0 / 120.0;
    p = p * r + 1.0 / 24.0;
    p = p * r + 1.0 / 6.0;
    p = p * r + 0.5;
    p = p * r + 1.0;
    p = p * r + 1.0;
    let two_k = f64::from_bits(((k as i64 + 1023) as u64) << 52);
    p * two_k
}

/// ln(x): mantissa in [√½, √2), atanh series 2·Σ r^(2n+1)/(2n+1) with
/// r = (m−1)/(m+1), |r| ≤ 0.1716 so r¹⁹/19 ≈ 3e-16.
#[inline]
pub fn log(x: f64) -> f64 {
    if x <= 0.0 {
        return if x == 0.0 { f64::NEG_INFINITY } else { f64::NAN };
    }
    let b = x.to_bits();
    let mut e = ((b >> 52) & 0x7FF) as i64 - 1022;
    let mut m = f64::from_bits((b & 0x000F_FFFF_FFFF_FFFF) | (1022u64 << 52)); // [0.5,1)
    if m < core::f64::consts::FRAC_1_SQRT_2 {
        e -= 1;
        m *= 2.0;
    }
    let r = (m - 1.0) / (m + 1.0);
    let z = r * r;
    let mut p = 1.0 / 19.0;
    p = p * z + 1.0 / 17.0;
    p = p * z + 1.0 / 15.0;
    p = p * z + 1.0 / 13.0;
    p = p * z + 1.0 / 11.0;
    p = p * z + 1.0 / 9.0;
    p = p * z + 1.0 / 7.0;
    p = p * z + 1.0 / 5.0;
    p = p * z + 1.0 / 3.0;
    p = p * z + 1.0;
    2.0 * r * p + e as f64 * core::f64::consts::LN_2
}

const FOPI: f64 = 1.273_239_544_735_162_7; // 4/pi
const DP1: f64 = 7.853_981_554_508_209e-1;
const DP2: f64 = 7.946_627_356_147_928e-9;
const DP3: f64 = 3.061_616_997_868_383e-17;

#[inline]
fn reduce(ax: f64) -> (i64, f64) {
    let mut j = (ax * FOPI) as i64;
    if j & 1 == 1 {
        j += 1;
    }
    let y = j as f64;
    let r = ((ax - y * DP1) - y * DP2) - y * DP3;
    (j & 7, r)
}

/// Taylor sine on |r| ≤ π/4 to r¹⁵ (max term ≈ 2e-14·r).
#[inline]
fn sin_poly(r: f64) -> f64 {
    let z = r * r;
    let mut p = -1.0 / 1_307_674_368_000.0; // -1/15!
    p = p * z + 1.0 / 6_227_020_800.0;
    p = p * z - 1.0 / 39_916_800.0;
    p = p * z + 1.0 / 362_880.0;
    p = p * z - 1.0 / 5_040.0;
    p = p * z + 1.0 / 120.0;
    p = p * z - 1.0 / 6.0;
    p * z * r + r
}

/// Taylor cosine on |r| ≤ π/4 to r¹⁴: cos = 1 − z/2 + z²·P(z), z = r².
#[inline]
fn cos_poly(r: f64) -> f64 {
    let z = r * r;
    let mut p = -1.0 / 87_178_291_200.0; // -1/14!
    p = p * z + 1.0 / 479_001_600.0;
    p = p * z - 1.0 / 3_628_800.0;
    p = p * z + 1.0 / 40_320.0;
    p = p * z - 1.0 / 720.0;
    p = p * z + 1.0 / 24.0;
    p * z * z - 0.5 * z + 1.0
}

/// sin(x).
#[inline]
pub fn sin(x: f64) -> f64 {
    let mut sign = signbit(x);
    let (mut j, r) = reduce(fabs(x));
    if j > 3 {
        sign = !sign;
        j -= 4;
    }
    let v = if j == 1 || j == 2 { cos_poly(r) } else { sin_poly(r) };
    if sign {
        -v
    } else {
        v
    }
}

/// cos(x).
#[inline]
pub fn cos(x: f64) -> f64 {
    let (mut j, r) = reduce(fabs(x));
    let mut sign = false;
    if j > 3 {
        j -= 4;
        sign = !sign;
    }
    if j > 1 {
        sign = !sign;
    }
    let v = if j == 1 || j == 2 { sin_poly(r) } else { cos_poly(r) };
    if sign {
        -v
    } else {
        v
    }
}

/// x^y (positive base via exp∘log; negative handled for integer y).
#[inline]
pub fn pow(x: f64, y: f64) -> f64 {
    if x == 0.0 {
        return if y == 0.0 { 1.0 } else { 0.0 };
    }
    if x < 0.0 {
        let yi = y as i64;
        if y == yi as f64 {
            let v = exp(log(-x) * y);
            return if yi & 1 == 1 { -v } else { v };
        }
        return f64::NAN;
    }
    exp(log(x) * y)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(a: f64, b: f64) -> f64 {
        if b == 0.0 {
            a.abs()
        } else {
            ((a - b) / b).abs()
        }
    }

    #[test]
    fn exp_accuracy() {
        let mut x = -700.0f64;
        while x < 700.0 {
            assert!(rel(exp(x), x.exp()) < 1e-13, "exp({x})");
            x += 13.37;
        }
        assert_eq!(exp(0.0), 1.0);
        assert_eq!(exp(800.0), f64::INFINITY);
    }

    #[test]
    fn log_accuracy() {
        let mut x = 1e-300f64;
        while x < 1e300 {
            assert!(rel(log(x), x.ln()) < 1e-13, "log({x})");
            x *= 911.7;
        }
    }

    #[test]
    fn sin_cos_accuracy() {
        let mut x = -300.0f64;
        while x < 300.0 {
            assert!((sin(x) - x.sin()).abs() < 1e-12, "sin({x}): {} vs {}", sin(x), x.sin());
            assert!((cos(x) - x.cos()).abs() < 1e-12, "cos({x})");
            x += 0.617;
        }
    }

    #[test]
    fn newton_sqrt() {
        for &x in &[1e-12, 0.25, 2.0, 1e12] {
            assert!(rel(sqrt_newton(x), x.sqrt()) < 1e-14, "sqrt({x})");
        }
    }

    #[test]
    fn bit_ops() {
        assert_eq!(fabs(-1.5), 1.5);
        assert!(signbit(-0.0));
    }

    #[test]
    fn pow_matches_std() {
        assert!(rel(pow(2.0, 10.0), 1024.0) < 1e-12);
        assert!(rel(pow(9.0, 0.5), 3.0) < 1e-12);
    }
}
