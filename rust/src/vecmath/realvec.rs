//! `RealVec` — the paper's software-SIMD vector type (§5).
//!
//! `realvec<typename T, int D>` becomes `RealVec<const N: usize>` (f32)
//! and `RealVec64<const N: usize>` (f64). Lane loops over fixed-size
//! arrays compile to SIMD: the elemental algorithms in `scalar32`/
//! `scalar64` are branch-light straight-line code, so LLVM vectorises the
//! loops the same way Vecmathlib's intrinsics specialisations would be
//! selected per target. Sizes not natively supported by the hardware are
//! split/extended automatically by the compiler, mirroring the paper's
//! "`realvec<float,8>` operations may be split into two `realvec<float,4>`".

use std::ops::{Add, Div, Mul, Neg, Sub};

use super::{scalar32, scalar64};

/// f32 SIMD vector of N lanes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RealVec<const N: usize>(pub [f32; N]);

/// f64 SIMD vector of N lanes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RealVec64<const N: usize>(pub [f64; N]);

macro_rules! lanewise {
    ($self:ident, $f:expr) => {{
        let mut out = $self.0;
        for v in out.iter_mut() {
            *v = $f(*v);
        }
        Self(out)
    }};
}

macro_rules! impl_ops {
    ($ty:ident, $elem:ty) => {
        impl<const N: usize> $ty<N> {
            /// Broadcast a scalar to all lanes.
            pub fn splat(v: $elem) -> Self {
                Self([v; N])
            }
            /// Lane accessor.
            pub fn lane(&self, i: usize) -> $elem {
                self.0[i]
            }
            /// Horizontal sum.
            pub fn hsum(&self) -> $elem {
                self.0.iter().sum()
            }
            /// Fused-ish multiply-add (a*b+c lane-wise).
            pub fn mul_add(self, b: Self, c: Self) -> Self {
                let mut out = self.0;
                for i in 0..N {
                    out[i] = out[i] * b.0[i] + c.0[i];
                }
                Self(out)
            }
        }
        impl<const N: usize> Add for $ty<N> {
            type Output = Self;
            fn add(self, rhs: Self) -> Self {
                let mut out = self.0;
                for i in 0..N {
                    out[i] += rhs.0[i];
                }
                Self(out)
            }
        }
        impl<const N: usize> Sub for $ty<N> {
            type Output = Self;
            fn sub(self, rhs: Self) -> Self {
                let mut out = self.0;
                for i in 0..N {
                    out[i] -= rhs.0[i];
                }
                Self(out)
            }
        }
        impl<const N: usize> Mul for $ty<N> {
            type Output = Self;
            fn mul(self, rhs: Self) -> Self {
                let mut out = self.0;
                for i in 0..N {
                    out[i] *= rhs.0[i];
                }
                Self(out)
            }
        }
        impl<const N: usize> Div for $ty<N> {
            type Output = Self;
            fn div(self, rhs: Self) -> Self {
                let mut out = self.0;
                for i in 0..N {
                    out[i] /= rhs.0[i];
                }
                Self(out)
            }
        }
        impl<const N: usize> Neg for $ty<N> {
            type Output = Self;
            fn neg(self) -> Self {
                let mut out = self.0;
                for v in out.iter_mut() {
                    *v = -*v;
                }
                Self(out)
            }
        }
    };
}

impl_ops!(RealVec, f32);
impl_ops!(RealVec64, f64);

impl<const N: usize> RealVec<N> {
    /// Lane-wise exp (vectorised elemental function).
    pub fn exp(self) -> Self {
        lanewise!(self, scalar32::exp)
    }
    /// Lane-wise sin.
    pub fn sin(self) -> Self {
        lanewise!(self, scalar32::sin)
    }
    /// Lane-wise cos.
    pub fn cos(self) -> Self {
        lanewise!(self, scalar32::cos)
    }
    /// Lane-wise natural log.
    pub fn log(self) -> Self {
        lanewise!(self, scalar32::log)
    }
    /// Lane-wise sqrt (hardware instruction per lane → SIMD sqrt).
    pub fn sqrt(self) -> Self {
        lanewise!(self, scalar32::sqrt)
    }
    /// Lane-wise |x| via bit manipulation.
    pub fn fabs(self) -> Self {
        lanewise!(self, scalar32::fabs)
    }
}

impl<const N: usize> RealVec64<N> {
    /// Lane-wise exp.
    pub fn exp(self) -> Self {
        lanewise!(self, scalar64::exp)
    }
    /// Lane-wise sin.
    pub fn sin(self) -> Self {
        lanewise!(self, scalar64::sin)
    }
    /// Lane-wise cos.
    pub fn cos(self) -> Self {
        lanewise!(self, scalar64::cos)
    }
    /// Lane-wise natural log.
    pub fn log(self) -> Self {
        lanewise!(self, scalar64::log)
    }
    /// Lane-wise sqrt.
    pub fn sqrt(self) -> Self {
        lanewise!(self, scalar64::sqrt)
    }
    /// Lane-wise |x|.
    pub fn fabs(self) -> Self {
        lanewise!(self, scalar64::fabs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_arithmetic() {
        let a = RealVec::<4>([1.0, 2.0, 3.0, 4.0]);
        let b = RealVec::<4>::splat(2.0);
        assert_eq!((a + b).0, [3.0, 4.0, 5.0, 6.0]);
        assert_eq!((a * b).0, [2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.mul_add(b, a).0, [3.0, 6.0, 9.0, 12.0]);
        assert_eq!(a.hsum(), 10.0);
    }

    #[test]
    fn vector_elementals_match_scalar() {
        let xs = [0.1f32, 1.0, 2.5, 7.25];
        let v = RealVec::<4>(xs);
        for i in 0..4 {
            assert_eq!(v.exp().lane(i), super::scalar32::exp(xs[i]));
            assert_eq!(v.sin().lane(i), super::scalar32::sin(xs[i]));
            assert_eq!(v.sqrt().lane(i), xs[i].sqrt());
        }
    }

    #[test]
    fn double_lanes() {
        let v = RealVec64::<2>([1.0, 4.0]);
        assert_eq!(v.sqrt().0, [1.0, 2.0]);
        assert!((v.exp().lane(1) - 4f64.exp()).abs() / 4f64.exp() < 1e-13);
    }

    #[test]
    fn wide_vectors_split_transparently() {
        // realvec<float,8> semantics: same results as two 4-lane ops.
        let xs: [f32; 8] = [0.5, 1.5, 2.5, 3.5, 4.5, 5.5, 6.5, 7.5];
        let v8 = RealVec::<8>(xs).exp();
        for i in 0..8 {
            assert_eq!(v8.lane(i), super::scalar32::exp(xs[i]));
        }
    }
}
