//! Partitioning policies: how a launch's split dimension is divided
//! among the member devices of a [`DeviceGroup`](super::DeviceGroup).
//!
//! A policy is consulted once per launch via [`SchedPolicy::plan`],
//! which returns a [`ChunkSource`] — a shared hand-out of contiguous
//! slice ranges along the split dimension. Member worker threads pull
//! chunks concurrently until the source runs dry, so every policy is
//! expressed as a thread-safe iterator rather than an up-front
//! assignment; the static policy simply hands each member its whole
//! range as one chunk.

use std::sync::Mutex;

/// One contiguous range of slices along the split dimension, handed to
/// a member device as a single sub-launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    /// First slice index (relative to the launch's range).
    pub start: usize,
    /// Slice count; always ≥ 1.
    pub len: usize,
    /// True when the chunk started outside the pulling member's
    /// even-split segment — the work-stealing bookkeeping bit.
    pub steal: bool,
}

/// A partitioning policy: turns a launch's split-dimension extent into
/// a per-member chunk hand-out.
pub trait SchedPolicy: Send + Sync {
    /// Human-readable policy name, reported through `SchedStats`.
    fn name(&self) -> String;
    /// Begin a launch of `total` slices over `members` devices.
    fn plan(&self, total: usize, members: usize) -> Box<dyn ChunkSource>;
}

/// A thread-safe chunk hand-out for one launch. Implementations must
/// cover `0..total` exactly once across all members combined.
pub trait ChunkSource: Send + Sync {
    /// Next chunk for member `dev` (`dev < members`), given the member's
    /// current measured throughput in slices per second (`0.0` before
    /// its first chunk completes). `None` when no work remains for this
    /// member.
    fn next(&self, dev: usize, rate: f64) -> Option<Chunk>;
}

/// Static proportional split: member `i` receives one contiguous chunk
/// sized by `ratios[i] / sum(ratios)`. Ratios can come from the CLI
/// (`--ratios`), from profiling, or default to an even split.
#[derive(Debug, Clone)]
pub struct StaticSplit {
    ratios: Vec<f64>,
}

impl StaticSplit {
    /// Split by explicit ratios. Non-finite or negative entries are
    /// treated as `0` (that member receives no work); an all-zero or
    /// empty list degrades to an even split. When a launch has more
    /// members than ratios, the missing ratios default to `1.0`;
    /// surplus ratios are ignored.
    pub fn new(ratios: Vec<f64>) -> StaticSplit {
        let mut ratios: Vec<f64> =
            ratios.iter().map(|r| if r.is_finite() && *r > 0.0 { *r } else { 0.0 }).collect();
        if ratios.iter().sum::<f64>() == 0.0 {
            ratios.clear();
        }
        StaticSplit { ratios }
    }

    /// Even split across all members.
    pub fn even() -> StaticSplit {
        StaticSplit { ratios: Vec::new() }
    }
}

impl SchedPolicy for StaticSplit {
    fn name(&self) -> String {
        if self.ratios.is_empty() {
            "static[even]".to_string()
        } else {
            let parts: Vec<String> = self.ratios.iter().map(|r| format!("{r}")).collect();
            format!("static[{}]", parts.join(","))
        }
    }

    fn plan(&self, total: usize, members: usize) -> Box<dyn ChunkSource> {
        let mut weights: Vec<f64> = (0..members)
            .map(|i| self.ratios.get(i).copied().unwrap_or(1.0))
            .collect();
        if weights.iter().sum::<f64>() == 0.0 {
            weights = vec![1.0; members];
        }
        let cuts = boundaries(total, &weights);
        let slots: Vec<Option<Chunk>> = (0..members)
            .map(|i| {
                let len = cuts[i + 1] - cuts[i];
                (len > 0).then_some(Chunk { start: cuts[i], len, steal: false })
            })
            .collect();
        Box::new(StaticSource { slots: Mutex::new(slots) })
    }
}

/// Cumulative cut points for a proportional split: `cuts[i]..cuts[i+1]`
/// is member `i`'s range, `cuts[0] == 0`, `cuts[n] == total`, and the
/// sequence is monotone, so the ranges tile `0..total` exactly.
fn boundaries(total: usize, weights: &[f64]) -> Vec<usize> {
    let sum: f64 = weights.iter().sum();
    let n = weights.len();
    let mut cuts = Vec::with_capacity(n + 1);
    cuts.push(0usize);
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        let cut =
            if i + 1 == n { total } else { (total as f64 * acc / sum).round() as usize };
        let prev = *cuts.last().expect("cuts is non-empty");
        cuts.push(cut.clamp(prev, total));
    }
    cuts
}

struct StaticSource {
    slots: Mutex<Vec<Option<Chunk>>>,
}

impl ChunkSource for StaticSource {
    fn next(&self, dev: usize, _rate: f64) -> Option<Chunk> {
        self.slots.lock().expect("static source poisoned").get_mut(dev)?.take()
    }
}

/// Chunked self-scheduling with throughput feedback (the EngineCL-style
/// dynamic policy): members pull chunks from a shared cursor. Before a
/// member has produced feedback it receives small starter chunks (a
/// quarter of its even share); afterwards each grab is half of its
/// rate-proportional share of the remaining work, so a fast jit member
/// takes big chunks while a serial member nibbles — and nobody grabs
/// the whole tail in one piece. Chunks a member pulls from outside its
/// even-split segment count as steals.
#[derive(Debug, Clone, Default)]
pub struct Dynamic {
    /// Fixed chunk size override: every grab is exactly this many
    /// slices, disabling the feedback sizing. Useful for deterministic
    /// tests and for benchmarking the sizing itself.
    pub chunk: Option<usize>,
}

impl Dynamic {
    /// Feedback-sized chunks (the default).
    pub fn new() -> Dynamic {
        Dynamic { chunk: None }
    }

    /// Fixed-size chunks of `size` slices.
    pub fn fixed(size: usize) -> Dynamic {
        Dynamic { chunk: Some(size.max(1)) }
    }
}

impl SchedPolicy for Dynamic {
    fn name(&self) -> String {
        match self.chunk {
            Some(c) => format!("dynamic[chunk={c}]"),
            None => "dynamic".to_string(),
        }
    }

    fn plan(&self, total: usize, members: usize) -> Box<dyn ChunkSource> {
        Box::new(DynamicSource {
            total,
            members: members.max(1),
            fixed: self.chunk,
            state: Mutex::new(DynamicState { next: 0, rates: vec![0.0; members.max(1)] }),
        })
    }
}

struct DynamicState {
    next: usize,
    rates: Vec<f64>,
}

struct DynamicSource {
    total: usize,
    members: usize,
    fixed: Option<usize>,
    state: Mutex<DynamicState>,
}

impl ChunkSource for DynamicSource {
    fn next(&self, dev: usize, rate: f64) -> Option<Chunk> {
        debug_assert!(dev < self.members);
        let mut st = self.state.lock().expect("dynamic source poisoned");
        if st.next >= self.total {
            return None;
        }
        if rate > 0.0 && rate.is_finite() {
            st.rates[dev] = rate;
        }
        let remaining = self.total - st.next;
        let size = match self.fixed {
            Some(c) => c,
            None => {
                let known: f64 = st.rates.iter().filter(|r| **r > 0.0).sum();
                let mine = st.rates.get(dev).copied().unwrap_or(0.0);
                let share = if mine > 0.0 && known > 0.0 {
                    (remaining as f64 * mine / (2.0 * known)).round() as usize
                } else {
                    remaining / (self.members * 4)
                };
                share.max(1)
            }
        };
        let size = size.clamp(1, remaining);
        let start = st.next;
        st.next += size;
        // Even-split segment this member would own under a static even
        // partition; pulling from outside it is a steal.
        let fair_lo = self.total * dev / self.members;
        let fair_hi = self.total * (dev + 1) / self.members;
        let steal = start < fair_lo || start >= fair_hi;
        Some(Chunk { start, len: size, steal })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a source by polling members round-robin with the given fake
    /// rates; returns all chunks handed out.
    fn drain(src: &dyn ChunkSource, members: usize, rates: &[f64]) -> Vec<(usize, Chunk)> {
        let mut out = Vec::new();
        let mut live: Vec<usize> = (0..members).collect();
        while !live.is_empty() {
            let mut still = Vec::new();
            for &d in &live {
                if let Some(c) = src.next(d, rates.get(d).copied().unwrap_or(0.0)) {
                    out.push((d, c));
                    still.push(d);
                }
            }
            live = still;
        }
        out
    }

    /// Every slice of `0..total` covered exactly once.
    fn assert_exact_cover(total: usize, chunks: &[(usize, Chunk)]) {
        let mut seen = vec![0usize; total];
        for (_, c) in chunks {
            assert!(c.len >= 1, "empty chunk handed out");
            for s in c.start..c.start + c.len {
                seen[s] += 1;
            }
        }
        assert!(seen.iter().all(|&n| n == 1), "partition is not an exact cover: {seen:?}");
    }

    #[test]
    fn static_split_tiles_exactly() {
        for (total, ratios) in [
            (64, vec![1.0, 1.0, 1.0]),
            (7, vec![1.0, 2.0, 3.0]),
            (1, vec![5.0, 1.0]),
            (100, vec![0.0, 1.0]),
            (13, vec![1.0]),
        ] {
            let members = ratios.len();
            let src = StaticSplit::new(ratios).plan(total, members);
            assert_exact_cover(total, &drain(&*src, members, &[]));
        }
    }

    #[test]
    fn static_split_sanitises_ratios() {
        // Negative/NaN entries become 0; an all-zero list degrades to even.
        let src = StaticSplit::new(vec![-1.0, f64::NAN]).plan(10, 2);
        let chunks = drain(&*src, 2, &[]);
        assert_exact_cover(10, &chunks);
        assert_eq!(chunks.len(), 2, "even fallback gives both members work");
    }

    #[test]
    fn static_split_is_proportional() {
        let src = StaticSplit::new(vec![1.0, 3.0]).plan(100, 2);
        let chunks = drain(&*src, 2, &[]);
        let d0: usize = chunks.iter().filter(|(d, _)| *d == 0).map(|(_, c)| c.len).sum();
        let d1: usize = chunks.iter().filter(|(d, _)| *d == 1).map(|(_, c)| c.len).sum();
        assert_eq!((d0, d1), (25, 75));
    }

    #[test]
    fn static_split_pads_missing_ratios_with_one() {
        let src = StaticSplit::new(vec![2.0]).plan(40, 3);
        let chunks = drain(&*src, 3, &[]);
        assert_exact_cover(40, &chunks);
        let d0: usize = chunks.iter().filter(|(d, _)| *d == 0).map(|(_, c)| c.len).sum();
        assert_eq!(d0, 20, "explicit ratio 2 vs two implicit 1s");
    }

    #[test]
    fn dynamic_fixed_chunks_tile_exactly() {
        for chunk in [1, 2, 3, 7, 64, 1000] {
            let src = Dynamic::fixed(chunk).plan(64, 3);
            assert_exact_cover(64, &drain(&*src, 3, &[]));
        }
    }

    #[test]
    fn dynamic_feedback_chunks_tile_exactly() {
        let src = Dynamic::new().plan(257, 4);
        assert_exact_cover(257, &drain(&*src, 4, &[100.0, 1.0, 50.0, 0.0]));
    }

    #[test]
    fn dynamic_feedback_sizes_follow_rates() {
        // A member reporting 9x the other's throughput must receive the
        // larger total share.
        let src = Dynamic::new().plan(1000, 2);
        let chunks = drain(&*src, 2, &[90.0, 10.0]);
        let fast: usize = chunks.iter().filter(|(d, _)| *d == 0).map(|(_, c)| c.len).sum();
        let slow: usize = chunks.iter().filter(|(d, _)| *d == 1).map(|(_, c)| c.len).sum();
        assert_eq!(fast + slow, 1000);
        assert!(fast > slow, "fast member got {fast} of 1000, slow got {slow}");
    }

    #[test]
    fn dynamic_counts_steals_outside_even_segment() {
        // One member drains everything: chunks past its even segment are
        // steals.
        let src = Dynamic::fixed(10).plan(60, 3);
        let mut steals = 0;
        while let Some(c) = src.next(0, 0.0) {
            steals += usize::from(c.steal);
        }
        // Member 0's even segment is 0..20: chunks at 20,30,40,50 are steals.
        assert_eq!(steals, 4);
    }

    #[test]
    fn policy_names_are_descriptive() {
        assert_eq!(StaticSplit::even().name(), "static[even]");
        assert_eq!(StaticSplit::new(vec![1.0, 2.0]).name(), "static[1,2]");
        assert_eq!(Dynamic::new().name(), "dynamic");
        assert_eq!(Dynamic::fixed(4).name(), "dynamic[chunk=4]");
    }
}
