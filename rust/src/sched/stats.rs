//! Scheduler statistics: the per-device breakdown of a co-executed
//! launch.
//!
//! [`LaunchStats`](crate::devices::LaunchStats) counters are
//! engine-typed, so summing a serial member's numbers into a jit
//! member's produces a blob that is only meaningful as a grand total.
//! [`SchedStats`] keeps the per-device, per-engine rows intact — which
//! member executed how many groups, how many chunks it pulled, how many
//! of those were steals, and how long it was busy — and derives the
//! totals and the balance metrics from them.

use std::time::Instant;

use crate::devices::LaunchStats;

/// One member device's share of a co-executed launch.
#[derive(Debug, Clone, Default)]
pub struct DeviceSchedStats {
    /// Member device name.
    pub name: String,
    /// Work-groups this member executed.
    pub groups: usize,
    /// Chunks this member pulled from the partitioner.
    pub chunks: usize,
    /// Chunks pulled from outside this member's even-split segment
    /// (work-stealing under the dynamic policy; always 0 under static).
    pub steals: usize,
    /// Wall-clock nanoseconds this member spent executing sub-launches.
    pub busy_ns: u64,
    /// When this member started its first sub-launch (`None` if it
    /// never pulled a chunk).
    pub started: Option<Instant>,
    /// When this member finished its last sub-launch.
    pub ended: Option<Instant>,
    /// This member's engine-typed launch statistics.
    pub stats: LaunchStats,
}

/// Per-device breakdown plus balance metrics for one scheduled launch.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Partitioning policy name (e.g. `static[1,2,3]`, `dynamic`).
    pub policy: String,
    /// Grid dimension the launch was split along (slowest-varying used
    /// dimension).
    pub split_dim: usize,
    /// One row per member device, in group member order.
    pub devices: Vec<DeviceSchedStats>,
}

impl SchedStats {
    /// Grand-total launch statistics across all members (engine-typed
    /// counters summed into one blob — see the per-device rows for the
    /// meaningful breakdown).
    pub fn total(&self) -> LaunchStats {
        let mut t = LaunchStats::default();
        for d in &self.devices {
            t.accumulate(&d.stats);
        }
        t
    }

    /// Total work-groups executed across all members.
    pub fn groups(&self) -> usize {
        self.devices.iter().map(|d| d.groups).sum()
    }

    /// Total chunks stolen across all members.
    pub fn steals(&self) -> usize {
        self.devices.iter().map(|d| d.steals).sum()
    }

    /// The union of the member execution windows: earliest member start
    /// to latest member end. `None` when no member recorded a window.
    /// Event profiling on split launches reports this span, so
    /// `CL_PROFILING_COMMAND_START/END` cover all sub-launches rather
    /// than the dispatching worker's bookkeeping.
    pub fn exec_span(&self) -> Option<(Instant, Instant)> {
        let start = self.devices.iter().filter_map(|d| d.started).min()?;
        let end = self.devices.iter().filter_map(|d| d.ended).max()?;
        Some((start, end.max(start)))
    }

    /// Imbalance ratio: the busiest member's wall-clock time over the
    /// mean busy time. `1.0` is a perfectly balanced launch; `n` (the
    /// member count) means one device did all the work while the rest
    /// idled.
    pub fn imbalance(&self) -> f64 {
        let n = self.devices.len();
        if n == 0 {
            return 1.0;
        }
        let sum: u64 = self.devices.iter().map(|d| d.busy_ns).sum();
        if sum == 0 {
            return 1.0;
        }
        let max = self.devices.iter().map(|d| d.busy_ns).max().unwrap_or(0);
        max as f64 * n as f64 / sum as f64
    }

    /// Fold another scheduled launch's breakdown into this one
    /// (multi-pass apps: rows match member-by-member). Breakdown shapes
    /// that disagree (different group compositions) replace `self` with
    /// the later launch rather than mixing rows from different members.
    pub fn accumulate(&mut self, other: &SchedStats) {
        if self.devices.len() != other.devices.len()
            || self.devices.iter().zip(&other.devices).any(|(a, b)| a.name != b.name)
        {
            *self = other.clone();
            return;
        }
        for (d, o) in self.devices.iter_mut().zip(&other.devices) {
            d.groups += o.groups;
            d.chunks += o.chunks;
            d.steals += o.steals;
            d.busy_ns += o.busy_ns;
            d.started = match (d.started, o.started) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            d.ended = match (d.ended, o.ended) {
                (Some(a), Some(b)) => Some(a.max(b)),
                (a, b) => a.or(b),
            };
            d.stats.accumulate(&o.stats);
        }
    }
}
