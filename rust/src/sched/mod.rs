//! Heterogeneous multi-device scheduler: co-executes one NDRange launch
//! across several platform devices (the EngineCL-style runtime half of
//! the performance-portability story).
//!
//! A [`DeviceGroup`] is itself a [`Device`], so it slots into a
//! `Context` like any single engine. When the host enqueues an NDRange
//! on a group, the launch's work-group grid is partitioned along its
//! slowest-varying used dimension ([`split_dim`]) into contiguous
//! chunks, each executed by a member device as a sub-launch
//! ([`LaunchRequest::sub_range`]) against the shared global memory.
//! Work-groups are independent under the OpenCL execution model, so the
//! members need no synchronisation beyond the chunk hand-out; the whole
//! split runs inside one command, joined by a single completion `Event`
//! on the queue's dependency DAG.
//!
//! Partitioning is pluggable through [`SchedPolicy`]:
//!
//! * [`StaticSplit`] — one proportional contiguous range per member
//!   (explicit `--ratios`, profile-seeded, or even).
//! * [`Dynamic`] — chunked self-scheduling: members pull chunks from a
//!   shared cursor, with per-member throughput EWMA feedback sizing
//!   later chunks (so a jit member is not held hostage by a serial
//!   one); chunks pulled outside a member's even segment count as
//!   steals.
//!
//! Each member compiles *its own* artifact for the kernel under its own
//! persistent-cache key (`cl/queue.rs::enqueue_nd_range_split` passes
//! one `WorkGroupFunction` per member), and the per-device, per-engine
//! statistics breakdown is preserved in [`SchedStats`] rather than
//! being summed into one cross-engine blob.

pub mod policy;
pub mod stats;

pub use policy::{Chunk, ChunkSource, Dynamic, SchedPolicy, StaticSplit};
pub use stats::{DeviceSchedStats, SchedStats};

use std::sync::{Arc, OnceLock};

use crate::cl::error::{Error, Result};
use crate::devices::{Device, DeviceInfo, LaunchRequest, LaunchStats};
use crate::kcc::{CompileOptions, WorkGroupFunction};
use crate::trace::{self, ArgVal};

/// The dimension a launch is split along: the slowest-varying used
/// dimension (highest index — outermost in row-major group order, so
/// chunks are contiguous in memory-traversal order); dimension 0 for
/// degenerate single-group grids.
pub fn split_dim(groups: [usize; 3]) -> usize {
    (0..3).rev().find(|&d| groups[d] > 1).unwrap_or(0)
}

/// Shared mutable global memory handed to member workers. Work-groups
/// are independent; simultaneous writes to the same location are UB in
/// the source program, mirroring real OpenCL devices (same pattern as
/// `devices/threaded.rs`).
struct SharedMem(*mut u8, usize);
unsafe impl Send for SharedMem {}
unsafe impl Sync for SharedMem {}

/// A heterogeneous group of devices acting as one logical device.
pub struct DeviceGroup {
    name: String,
    members: Vec<Arc<dyn Device>>,
    policy: Arc<dyn SchedPolicy>,
    /// Lazily allocated tracer tracks, one per member, carrying that
    /// member's chunk timeline as async spans.
    tracks: OnceLock<Vec<u64>>,
}

impl DeviceGroup {
    /// Group `members` under `policy`. Fails on an empty member list and
    /// on nested groups (a group cannot contain another group).
    pub fn new(
        name: impl Into<String>,
        members: Vec<Arc<dyn Device>>,
        policy: Arc<dyn SchedPolicy>,
    ) -> Result<DeviceGroup> {
        if members.is_empty() {
            return Err(Error::invalid("device group needs at least one member"));
        }
        if members.iter().any(|m| m.as_group().is_some()) {
            return Err(Error::invalid("device groups cannot nest"));
        }
        Ok(DeviceGroup { name: name.into(), members, policy, tracks: OnceLock::new() })
    }

    /// One tracer track per member, allocated on first use.
    fn member_tracks(&self) -> &[u64] {
        self.tracks.get_or_init(|| {
            self.members
                .iter()
                .map(|m| trace::alloc_track(format!("{}:{}", self.name, m.info().name)))
                .collect()
        })
    }

    /// Member devices, in scheduling order.
    pub fn members(&self) -> &[Arc<dyn Device>] {
        &self.members
    }

    /// The group's partitioning policy.
    pub fn policy(&self) -> &Arc<dyn SchedPolicy> {
        &self.policy
    }

    /// Compile options per member, in member order. Each member's
    /// options carry its own engine kind and gang width, so the
    /// persistent cache keeps one artifact per member (`cache::key`
    /// folds the full `CompileOptions` into the `SpecKey`).
    pub fn member_compile_options(&self) -> Vec<CompileOptions> {
        self.members.iter().map(|m| m.compile_options()).collect()
    }

    /// Co-execute one launch across the members: partition `req`'s
    /// range along [`split_dim`] per the group policy, run each chunk
    /// on its member with that member's own artifact (`wgfs[i]`), and
    /// return the grand-total launch statistics plus the per-device
    /// breakdown.
    pub fn launch_split(
        &self,
        global: &mut [u8],
        req: &LaunchRequest,
        wgfs: &[Arc<WorkGroupFunction>],
    ) -> Result<(LaunchStats, SchedStats)> {
        if wgfs.len() != self.members.len() {
            return Err(Error::invalid(format!(
                "device group '{}' expects {} per-member artifacts, got {}",
                self.name,
                self.members.len(),
                wgfs.len()
            )));
        }
        let dim = split_dim(req.groups);
        let total = req.groups[dim];
        let traced = trace::enabled();
        let _split_span = traced.then(|| {
            trace::span_args(
                trace::CAT_SCHED,
                format!("split {}", req.wgf.name),
                vec![
                    ("policy", ArgVal::s(self.policy.name())),
                    ("dim", ArgVal::u(dim as u64)),
                    ("total", ArgVal::u(total as u64)),
                ],
            )
        });
        trace::metrics::add("sched.splits", 1);
        let mut sched =
            SchedStats { policy: self.policy.name(), split_dim: dim, devices: Vec::new() };

        if self.members.len() == 1 || total < 2 {
            // Nothing to split: the first member runs the whole range.
            let sub = req.sub_range(dim, 0, total, wgfs[0].clone());
            let t0 = std::time::Instant::now();
            let stats = self.members[0].launch(global, &sub)?;
            let t1 = std::time::Instant::now();
            let busy = (t1 - t0).as_nanos() as u64;
            sched.devices = self
                .members
                .iter()
                .enumerate()
                .map(|(i, m)| DeviceSchedStats {
                    name: m.info().name,
                    groups: if i == 0 { stats.workgroups } else { 0 },
                    chunks: usize::from(i == 0),
                    steals: 0,
                    busy_ns: if i == 0 { busy } else { 0 },
                    started: (i == 0).then_some(t0),
                    ended: (i == 0).then_some(t1),
                    stats: if i == 0 { stats } else { LaunchStats::default() },
                })
                .collect();
            trace::metrics::add("sched.chunks", 1);
            return Ok((stats, sched));
        }

        let source = self.policy.plan(total, self.members.len());
        // One async track per member while tracing: each chunk renders
        // as an async span on its member's timeline.
        let tracks: Option<&[u64]> = traced.then(|| self.member_tracks());
        let shared = SharedMem(global.as_mut_ptr(), global.len());
        let results: Vec<Result<DeviceSchedStats>> = std::thread::scope(|scope| {
            let shared = &shared;
            let source = &*source;
            let mut handles = Vec::new();
            for (i, member) in self.members.iter().enumerate() {
                let wgf = wgfs[i].clone();
                handles.push(scope.spawn(move || {
                    let mut row =
                        DeviceSchedStats { name: member.info().name, ..Default::default() };
                    let mut rate = 0.0_f64;
                    while let Some(chunk) = source.next(i, rate) {
                        let sub = req.sub_range(dim, chunk.start, chunk.len, wgf.clone());
                        let traced_chunk = tracks.map(|t| {
                            let id = trace::next_id();
                            trace::async_begin_args(
                                trace::CAT_SCHED,
                                format!("chunk {}", wgf.name),
                                t[i],
                                id,
                                vec![
                                    ("start", ArgVal::u(chunk.start as u64)),
                                    ("len", ArgVal::u(chunk.len as u64)),
                                ],
                            );
                            (t[i], id)
                        });
                        // Each member gets the same full view of global
                        // memory; chunks are disjoint in group space and
                        // work-group independence makes concurrent
                        // access safe for conforming kernels.
                        let global_view =
                            unsafe { std::slice::from_raw_parts_mut(shared.0, shared.1) };
                        let t0 = std::time::Instant::now();
                        let launched = member.launch(global_view, &sub);
                        let t1 = std::time::Instant::now();
                        if let Some((track, id)) = traced_chunk {
                            if chunk.steal {
                                trace::async_instant(trace::CAT_SCHED, "steal", track, id);
                            }
                            trace::async_end(
                                trace::CAT_SCHED,
                                format!("chunk {}", wgf.name),
                                track,
                                id,
                            );
                        }
                        let s = launched?;
                        let dt = t1 - t0;
                        row.busy_ns += dt.as_nanos() as u64;
                        row.groups += s.workgroups;
                        row.chunks += 1;
                        row.steals += usize::from(chunk.steal);
                        row.started = Some(row.started.map_or(t0, |s0| s0.min(t0)));
                        row.ended = Some(row.ended.map_or(t1, |e0| e0.max(t1)));
                        row.stats.accumulate(&s);
                        // EWMA of the member's throughput in
                        // split-dimension slices per second, fed back to
                        // size its next chunk.
                        let inst = chunk.len as f64 / dt.as_secs_f64().max(1e-9);
                        rate = if rate > 0.0 { 0.6 * inst + 0.4 * rate } else { inst };
                    }
                    Ok(row)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("scheduler member panicked")).collect()
        });

        let mut total_stats = LaunchStats::default();
        for r in results {
            let row = r.map_err(|e| Error::exec(format!("device group member failed: {e}")))?;
            trace::metrics::add("sched.chunks", row.chunks as u64);
            trace::metrics::add("sched.steals", row.steals as u64);
            total_stats.accumulate(&row.stats);
            sched.devices.push(row);
        }
        Ok((total_stats, sched))
    }
}

impl Device for DeviceGroup {
    fn info(&self) -> DeviceInfo {
        let infos: Vec<DeviceInfo> = self.members.iter().map(|m| m.info()).collect();
        DeviceInfo {
            name: self.name.clone(),
            tlp: infos.iter().map(|i| i.tlp).sum(),
            ilp: "per-member",
            dlp: "heterogeneous group",
            global_mem: infos.iter().map(|i| i.global_mem).min().unwrap_or(0),
            local_mem: infos.iter().map(|i| i.local_mem).min().unwrap_or(0),
        }
    }

    /// Shared-artifact fallback options: the widest-ganged member's
    /// options, so a single artifact carries every form the members can
    /// consume (lower tiers degrade per region). The split enqueue path
    /// compiles one artifact per member instead — see
    /// [`DeviceGroup::member_compile_options`].
    fn compile_options(&self) -> CompileOptions {
        self.members
            .iter()
            .map(|m| m.compile_options())
            .max_by_key(|o| o.gang_width)
            .unwrap_or_default()
    }

    fn launch(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats> {
        // Plain-Device path (no per-member artifacts supplied): every
        // member consumes the request's shared artifact.
        let wgfs: Vec<Arc<WorkGroupFunction>> = vec![req.wgf.clone(); self.members.len()];
        self.launch_split(global, req, &wgfs).map(|(stats, _)| stats)
    }

    fn as_group(&self) -> Option<&DeviceGroup> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{basic::BasicDevice, EngineKind};

    fn serial() -> Arc<dyn Device> {
        Arc::new(BasicDevice::new(EngineKind::Serial))
    }

    #[test]
    fn split_dim_picks_slowest_varying_used_dimension() {
        assert_eq!(split_dim([8, 1, 1]), 0);
        assert_eq!(split_dim([8, 4, 1]), 1);
        assert_eq!(split_dim([8, 4, 2]), 2);
        assert_eq!(split_dim([8, 1, 2]), 2);
        assert_eq!(split_dim([1, 1, 1]), 0);
    }

    #[test]
    fn empty_group_is_rejected() {
        let r = DeviceGroup::new("g", Vec::new(), Arc::new(Dynamic::new()));
        assert!(r.is_err());
    }

    #[test]
    fn nested_groups_are_rejected() {
        let inner =
            DeviceGroup::new("inner", vec![serial()], Arc::new(Dynamic::new())).unwrap();
        let r = DeviceGroup::new("outer", vec![Arc::new(inner)], Arc::new(Dynamic::new()));
        assert!(r.is_err());
    }

    #[test]
    fn group_info_aggregates_members() {
        let g = DeviceGroup::new(
            "pair",
            vec![serial(), serial()],
            Arc::new(StaticSplit::even()),
        )
        .unwrap();
        let info = g.info();
        assert_eq!(info.name, "pair");
        assert_eq!(info.tlp, 2);
        assert!(info.global_mem > 0);
    }

    #[test]
    fn group_compile_options_prefer_widest_gang() {
        let members: Vec<Arc<dyn Device>> = vec![
            Arc::new(BasicDevice::new(EngineKind::Serial)),
            Arc::new(BasicDevice::new(EngineKind::GangVector(8))),
            Arc::new(BasicDevice::new(EngineKind::Bytecode(4))),
        ];
        let g = DeviceGroup::new("mix", members, Arc::new(Dynamic::new())).unwrap();
        assert_eq!(g.compile_options().gang_width, 8);
        assert_eq!(g.member_compile_options().len(), 3);
        assert_eq!(g.member_compile_options()[0].gang_width, 0);
    }
}
