//! **Bufalloc** — the chunked device-buffer allocator of §3.
//!
//! A single region of memory (one host `malloc`, or a known range of an
//! OS-less device's RAM) is split into *chunks* kept in a list ordered by
//! start address, each with a free/allocated flag; the last chunk is a
//! sentinel holding all unallocated space. Allocation walks the list
//! first-fit and splits the found chunk; an optional **greedy** mode
//! serves requests from the region's end (the sentinel) whenever
//! possible, so successive kernel-buffer allocations land contiguously.
//! Freeing coalesces with free neighbours.

use crate::cl::error::{Error, Result};

/// One chunk of the managed region.
#[derive(Debug, Clone)]
struct Chunk {
    start: usize,
    size: usize,
    free: bool,
}

/// The §3 buffer allocator.
#[derive(Debug)]
pub struct Bufalloc {
    chunks: Vec<Chunk>,
    region_size: usize,
    alignment: usize,
    greedy: bool,
}

impl Bufalloc {
    /// Manage `region_size` bytes with the given alignment (power of two).
    pub fn new(region_size: usize, alignment: usize, greedy: bool) -> Bufalloc {
        assert!(alignment.is_power_of_two());
        Bufalloc {
            chunks: vec![Chunk { start: 0, size: region_size, free: true }],
            region_size,
            alignment,
            greedy,
        }
    }

    fn align(&self, v: usize) -> usize {
        (v + self.alignment - 1) & !(self.alignment - 1)
    }

    /// Allocate `size` bytes; returns the offset within the region.
    pub fn alloc(&mut self, size: usize) -> Result<usize> {
        if size == 0 {
            return Err(Error::invalid("zero-sized allocation"));
        }
        let size = self.align(size);
        // Greedy mode: serve from the last (sentinel) chunk if possible,
        // so successive requests are contiguous at the region's end.
        if self.greedy {
            let last = self.chunks.len() - 1;
            if self.chunks[last].free && self.chunks[last].size >= size {
                return Ok(self.split(last, size));
            }
        }
        // First fit.
        let idx = self
            .chunks
            .iter()
            .position(|c| c.free && c.size >= size)
            .ok_or(Error::OutOfMemory { requested: size, available: self.largest_free() })?;
        Ok(self.split(idx, size))
    }

    /// Split chunk `idx`, marking the first `size` bytes allocated.
    fn split(&mut self, idx: usize, size: usize) -> usize {
        let start = self.chunks[idx].start;
        let rest = self.chunks[idx].size - size;
        self.chunks[idx].size = size;
        self.chunks[idx].free = false;
        if rest > 0 {
            self.chunks.insert(idx + 1, Chunk { start: start + size, size: rest, free: true });
        }
        start
    }

    /// Free the chunk starting at `offset`, coalescing neighbours.
    pub fn free(&mut self, offset: usize) -> Result<()> {
        let idx = self
            .chunks
            .iter()
            .position(|c| c.start == offset && !c.free)
            .ok_or_else(|| Error::invalid(format!("free of unallocated offset {offset}")))?;
        self.chunks[idx].free = true;
        // Coalesce with the next chunk.
        if idx + 1 < self.chunks.len() && self.chunks[idx + 1].free {
            self.chunks[idx].size += self.chunks[idx + 1].size;
            self.chunks.remove(idx + 1);
        }
        // Coalesce with the previous chunk.
        if idx > 0 && self.chunks[idx - 1].free {
            self.chunks[idx - 1].size += self.chunks[idx].size;
            self.chunks.remove(idx);
        }
        Ok(())
    }

    /// Total bytes currently allocated.
    pub fn allocated(&self) -> usize {
        self.chunks.iter().filter(|c| !c.free).map(|c| c.size).sum()
    }

    /// Largest free chunk (what the next alloc can serve).
    pub fn largest_free(&self) -> usize {
        self.chunks.iter().filter(|c| c.free).map(|c| c.size).max().unwrap_or(0)
    }

    /// Number of chunks (fragmentation indicator used by tests/benches).
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// Managed region size.
    pub fn region_size(&self) -> usize {
        self.region_size
    }

    /// Internal invariant check (tests): chunks tile the region exactly,
    /// ordered, non-overlapping, no two adjacent free chunks.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let mut pos = 0;
        for (i, c) in self.chunks.iter().enumerate() {
            if c.start != pos {
                return Err(format!("chunk {i} starts at {} expected {pos}", c.start));
            }
            pos += c.size;
            if i + 1 < self.chunks.len() && c.free && self.chunks[i + 1].free {
                return Err(format!("adjacent free chunks at {i}"));
            }
        }
        if pos != self.region_size {
            return Err(format!("chunks cover {pos} of {} bytes", self.region_size));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut b = Bufalloc::new(1024, 16, false);
        let a = b.alloc(100).unwrap();
        let c = b.alloc(200).unwrap();
        assert_ne!(a, c);
        b.check_invariants().unwrap();
        b.free(a).unwrap();
        b.free(c).unwrap();
        b.check_invariants().unwrap();
        assert_eq!(b.allocated(), 0);
        assert_eq!(b.chunk_count(), 1, "full coalescing");
    }

    #[test]
    fn alignment_respected() {
        let mut b = Bufalloc::new(1024, 64, false);
        let a = b.alloc(1).unwrap();
        let c = b.alloc(1).unwrap();
        assert_eq!(a % 64, 0);
        assert_eq!(c % 64, 0);
        assert_eq!(c - a, 64);
    }

    #[test]
    fn first_fit_reuses_freed_space() {
        let mut b = Bufalloc::new(1024, 16, false);
        let a = b.alloc(128).unwrap();
        let _c = b.alloc(128).unwrap();
        b.free(a).unwrap();
        let d = b.alloc(64).unwrap();
        assert_eq!(d, a, "first fit takes the earliest hole");
    }

    #[test]
    fn greedy_mode_allocates_contiguously_at_end() {
        let mut b = Bufalloc::new(1024, 16, true);
        let a = b.alloc(128).unwrap();
        b.free(a).unwrap();
        // Non-greedy would reuse offset 0; greedy serves from the sentinel.
        let c = b.alloc(64).unwrap();
        let d = b.alloc(64).unwrap();
        assert_eq!(d, c + 64, "successive allocations contiguous");
        b.check_invariants().unwrap();
    }

    #[test]
    fn out_of_memory_reports_available() {
        let mut b = Bufalloc::new(256, 16, false);
        b.alloc(192).unwrap();
        match b.alloc(128) {
            Err(Error::OutOfMemory { requested, available }) => {
                assert_eq!(requested, 128);
                assert_eq!(available, 64);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn double_free_rejected() {
        let mut b = Bufalloc::new(256, 16, false);
        let a = b.alloc(32).unwrap();
        b.free(a).unwrap();
        assert!(b.free(a).is_err());
    }

    #[test]
    fn group_alloc_free_pattern() {
        // The paper's assumption: buffers allocated and freed in groups.
        let mut b = Bufalloc::new(1 << 20, 64, true);
        for _ in 0..10 {
            let group: Vec<usize> = (0..8).map(|i| b.alloc(1000 * (i + 1)).unwrap()).collect();
            b.check_invariants().unwrap();
            for off in group {
                b.free(off).unwrap();
            }
            b.check_invariants().unwrap();
            assert_eq!(b.allocated(), 0);
        }
        assert_eq!(b.chunk_count(), 1, "no fragmentation after group frees");
    }
}
