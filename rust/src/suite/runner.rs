//! Suite runner: executes an [`App`] through the host API on a device and
//! verifies against the native baseline.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cl::{CommandQueue, Context, Kernel, KernelArg, Program};
use crate::cl::error::{Error, Result};
use crate::devices::{Device, LaunchStats};

use super::{App, BufInit, PassArg};

/// Result of one device run.
pub struct RunResult {
    /// Final contents of every buffer.
    pub buffers: Vec<BufInit>,
    /// Kernel-only wall time (sum over passes).
    pub kernel_time: Duration,
    /// Aggregate device stats.
    pub stats: LaunchStats,
}

/// Run all passes of `app` once on `device`.
pub fn run_on_device(app: &App, device: Arc<dyn Device>) -> Result<RunResult> {
    let ctx = Arc::new(Context::new(device));
    let mut queue = CommandQueue::new(ctx.clone());
    let program = Program::build(app.source)?;

    // Create + fill buffers.
    let mut bufs = Vec::with_capacity(app.buffers.len());
    for b in &app.buffers {
        let handle = ctx.create_buffer(b.byte_len())?;
        match b {
            BufInit::F32(d) => ctx.write_f32(handle, d)?,
            BufInit::U32(d) => ctx.write_u32(handle, d)?,
        }
        bufs.push(handle);
    }

    let mut kernel_time = Duration::ZERO;
    let mut stats = LaunchStats::default();
    for pass in &app.passes {
        let mut k = Kernel::new(&program, pass.kernel)?;
        for (i, a) in pass.args.iter().enumerate() {
            let arg = match a {
                PassArg::Buf(bi) => KernelArg::Buf(bufs[*bi]),
                PassArg::Scalar(s) => s.clone(),
                PassArg::Local(sz) => KernelArg::LocalSize(*sz),
            };
            k.set_arg(i, arg)?;
        }
        let t0 = Instant::now();
        let ev = queue.enqueue_nd_range(&program, &k, pass.global, pass.local)?;
        kernel_time += t0.elapsed();
        stats.workgroups += ev.stats.workgroups;
        stats.diverged_gangs += ev.stats.diverged_gangs;
        stats.cycles += ev.stats.cycles;
    }

    // Read everything back.
    let mut out = Vec::with_capacity(bufs.len());
    for (handle, init) in bufs.iter().zip(&app.buffers) {
        out.push(match init {
            BufInit::F32(d) => BufInit::F32(ctx.read_f32(*handle, d.len())?),
            BufInit::U32(d) => BufInit::U32(ctx.read_u32(*handle, d.len())?),
        });
    }
    Ok(RunResult { buffers: out, kernel_time, stats })
}

/// Time the native baseline.
pub fn run_native_timed(app: &App) -> (Vec<BufInit>, Duration) {
    let t0 = Instant::now();
    let out = app.run_native();
    (out, t0.elapsed())
}

/// Compare device results against the native baseline on the app's
/// output buffers.
pub fn verify(app: &App, got: &[BufInit]) -> Result<()> {
    let expect = app.run_native();
    for &i in &app.outputs {
        match (&got[i], &expect[i]) {
            (BufInit::F32(g), BufInit::F32(e)) => {
                if g.len() != e.len() {
                    return Err(Error::exec(format!("{}: output {i} length mismatch", app.name)));
                }
                for (j, (a, b)) in g.iter().zip(e).enumerate() {
                    let scale = b.abs().max(1.0);
                    if (a - b).abs() > app.tol * scale {
                        return Err(Error::exec(format!(
                            "{}: buffer {i}[{j}] = {a}, expected {b} (tol {})",
                            app.name, app.tol
                        )));
                    }
                }
            }
            (BufInit::U32(g), BufInit::U32(e)) => {
                if g != e {
                    let j = g.iter().zip(e).position(|(a, b)| a != b).unwrap_or(0);
                    return Err(Error::exec(format!(
                        "{}: buffer {i}[{j}] = {}, expected {}",
                        app.name, g[j], e[j]
                    )));
                }
            }
            _ => return Err(Error::exec(format!("{}: buffer {i} type mismatch", app.name))),
        }
    }
    Ok(())
}

/// Convenience: run on device + verify.
pub fn run_and_verify(app: &App, device: Arc<dyn Device>) -> Result<RunResult> {
    let r = run_on_device(app, device)?;
    verify(app, &r.buffers)?;
    Ok(r)
}
