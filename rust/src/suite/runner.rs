//! Suite runner: executes an [`App`] through the host API on a device and
//! verifies against the native baseline.
//!
//! The runner exploits the asynchronous queue API: it enqueues every
//! buffer upload without dependencies, chains kernel passes behind their
//! predecessor plus the uploads of the buffers they actually touch, and
//! reads every output back concurrently. On an out-of-order queue the
//! independent per-pass transfers therefore overlap with compute — the
//! first scalability win of the event-graph redesign on the multi-pass
//! apps (prefixsum, bitonicsort, reduction).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cl::{CommandQueue, Context, Event, Kernel, KernelArg, Program, QueueProperties};
use crate::cl::error::{Error, Result};
use crate::devices::{Device, LaunchStats};
use crate::sched::SchedStats;

use super::{App, BufInit, PassArg};

/// Result of one device run.
pub struct RunResult {
    /// Final contents of every buffer.
    pub buffers: Vec<BufInit>,
    /// Kernel-only execution time (sum over pass events).
    pub kernel_time: Duration,
    /// Aggregate device stats.
    pub stats: LaunchStats,
    /// Per-device scheduler breakdown, accumulated across passes when
    /// the device is a heterogeneous group (`None` on single devices).
    pub sched: Option<SchedStats>,
    /// The program the run built — callers report its specialisation
    /// cache counters and compiled-kernel stats from here instead of
    /// recompiling anything.
    pub program: Program,
}

/// Run all passes of `app` once on `device` (out-of-order queue: uploads
/// and read-backs overlap with compute along the event graph).
pub fn run_on_device(app: &App, device: Arc<dyn Device>) -> Result<RunResult> {
    run_on_device_with_queue(app, device, QueueProperties::OutOfOrder)
}

/// Run all passes of `app` once on `device` with an explicit queue mode.
/// The program reads through the process-default persistent kernel
/// cache (see `cache::default_cache`), so repeat runs of a suite app —
/// in this process or a later one — skip the kernel compiler.
pub fn run_on_device_with_queue(
    app: &App,
    device: Arc<dyn Device>,
    props: QueueProperties,
) -> Result<RunResult> {
    let program = Program::build_cached(app.source, crate::cache::default_cache())?;
    run_with_program(app, device, props, program)
}

/// Run all passes of `app` through an explicit pre-built `program`
/// (e.g. one reconstructed via `Program::from_binary`), returning it in
/// the result.
pub fn run_with_program(
    app: &App,
    device: Arc<dyn Device>,
    props: QueueProperties,
    program: Program,
) -> Result<RunResult> {
    let ctx = Arc::new(Context::new(device));
    let queue = CommandQueue::with_properties(ctx.clone(), props);

    // Create buffers and enqueue all uploads, dependency-free: they can
    // overlap with each other and with any pass that doesn't touch them.
    let mut bufs = Vec::with_capacity(app.buffers.len());
    let mut uploads = Vec::with_capacity(app.buffers.len());
    for b in &app.buffers {
        let handle = ctx.create_buffer(b.byte_len())?;
        let ev = match b {
            BufInit::F32(d) => queue.enqueue_write_slice(handle, d, &[])?,
            BufInit::U32(d) => queue.enqueue_write_slice(handle, d, &[])?,
        };
        bufs.push(handle);
        uploads.push(ev);
    }

    // Passes chain behind their predecessor (they share buffers) and the
    // uploads of the buffers they reference.
    let mut prev: Option<Event> = None;
    let mut kernel_events = Vec::with_capacity(app.passes.len());
    for pass in &app.passes {
        let mut k = Kernel::new(&program, pass.kernel)?;
        let mut wait: Vec<Event> = Vec::new();
        for (i, a) in pass.args.iter().enumerate() {
            let arg = match a {
                PassArg::Buf(bi) => {
                    wait.push(uploads[*bi].clone());
                    KernelArg::Buf(bufs[*bi])
                }
                PassArg::Scalar(s) => s.clone(),
                PassArg::Local(sz) => KernelArg::LocalSize(*sz),
            };
            k.set_arg(i, arg)?;
        }
        if let Some(p) = &prev {
            wait.push(p.clone());
        }
        let ev = queue.enqueue_nd_range(&program, &k, pass.global, pass.local, &wait)?;
        kernel_events.push(ev.clone());
        prev = Some(ev);
    }

    // Read everything back concurrently: each read waits on the last
    // pass (which transitively covers all passes) and its own upload.
    let mut reads = Vec::with_capacity(bufs.len());
    for (i, handle) in bufs.iter().enumerate() {
        let mut wait = vec![uploads[i].clone()];
        if let Some(p) = &prev {
            wait.push(p.clone());
        }
        reads.push(queue.enqueue_read_buffer(*handle, 0, app.buffers[i].byte_len(), &wait)?);
    }
    queue.flush();

    let mut out = Vec::with_capacity(bufs.len());
    for (ev, init) in reads.iter().zip(&app.buffers) {
        out.push(match init {
            BufInit::F32(_) => BufInit::F32(ev.wait_vec::<f32>()?),
            BufInit::U32(_) => BufInit::U32(ev.wait_vec::<u32>()?),
        });
    }

    let mut stats = LaunchStats::default();
    let mut sched: Option<SchedStats> = None;
    let mut kernel_time = Duration::ZERO;
    for ev in &kernel_events {
        let s = ev.wait()?;
        stats.accumulate(&s);
        kernel_time += Duration::from_nanos(ev.duration_ns() as u64);
        if let Some(sc) = ev.sched_stats() {
            match &mut sched {
                Some(total) => total.accumulate(&sc),
                None => sched = Some(sc),
            }
        }
    }
    queue.finish()?;
    Ok(RunResult { buffers: out, kernel_time, stats, sched, program })
}

/// Time the native baseline.
pub fn run_native_timed(app: &App) -> (Vec<BufInit>, Duration) {
    let t0 = Instant::now();
    let out = app.run_native();
    (out, t0.elapsed())
}

/// Compare device results against the native baseline on the app's
/// output buffers.
pub fn verify(app: &App, got: &[BufInit]) -> Result<()> {
    let expect = app.run_native();
    for &i in &app.outputs {
        match (&got[i], &expect[i]) {
            (BufInit::F32(g), BufInit::F32(e)) => {
                if g.len() != e.len() {
                    return Err(Error::exec(format!("{}: output {i} length mismatch", app.name)));
                }
                for (j, (a, b)) in g.iter().zip(e).enumerate() {
                    let scale = b.abs().max(1.0);
                    if (a - b).abs() > app.tol * scale {
                        return Err(Error::exec(format!(
                            "{}: buffer {i}[{j}] = {a}, expected {b} (tol {})",
                            app.name, app.tol
                        )));
                    }
                }
            }
            (BufInit::U32(g), BufInit::U32(e)) => {
                if g != e {
                    let j = g.iter().zip(e).position(|(a, b)| a != b).unwrap_or(0);
                    return Err(Error::exec(format!(
                        "{}: buffer {i}[{j}] = {}, expected {}",
                        app.name, g[j], e[j]
                    )));
                }
            }
            _ => return Err(Error::exec(format!("{}: buffer {i} type mismatch", app.name))),
        }
    }
    Ok(())
}

/// Convenience: run on device + verify.
pub fn run_and_verify(app: &App, device: Arc<dyn Device>) -> Result<RunResult> {
    let r = run_on_device(app, device)?;
    verify(app, &r.buffers)?;
    Ok(r)
}

/// Run with an explicit queue mode + verify.
pub fn run_and_verify_with_queue(
    app: &App,
    device: Arc<dyn Device>,
    props: QueueProperties,
) -> Result<RunResult> {
    let r = run_on_device_with_queue(app, device, props)?;
    verify(app, &r.buffers)?;
    Ok(r)
}
