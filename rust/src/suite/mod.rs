//! The AMD APP SDK-style benchmark suite used by §6's evaluation.
//!
//! Every application bundles: a MiniCL kernel (the unmodified-OpenCL-style
//! workload), one or more launch passes, input generators, a handwritten
//! Rust **native baseline** (the proprietary-vendor stand-in — see
//! DESIGN.md §Substitutions), and a verifier.

pub mod apps;
pub mod runner;

use crate::cl::program::KernelArg;

/// A device buffer's initial contents.
#[derive(Debug, Clone)]
pub enum BufInit {
    /// f32 data.
    F32(Vec<f32>),
    /// u32 data.
    U32(Vec<u32>),
}

impl BufInit {
    /// Byte length.
    pub fn byte_len(&self) -> usize {
        match self {
            BufInit::F32(v) => v.len() * 4,
            BufInit::U32(v) => v.len() * 4,
        }
    }
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            BufInit::F32(v) => v.len(),
            BufInit::U32(v) => v.len(),
        }
    }
    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One kernel argument of a pass.
#[derive(Debug, Clone)]
pub enum PassArg {
    /// Index into the app's buffer list.
    Buf(usize),
    /// Scalar argument.
    Scalar(KernelArg),
    /// Explicit `__local` buffer of the given byte size.
    Local(usize),
}

/// One kernel launch.
#[derive(Debug, Clone)]
pub struct Pass {
    /// Kernel name within the app's program.
    pub kernel: &'static str,
    /// Arguments in kernel order.
    pub args: Vec<PassArg>,
    /// Global work size.
    pub global: [usize; 3],
    /// Local work size.
    pub local: [usize; 3],
}

/// A benchmark application.
pub struct App {
    /// Display name (matches the paper's figures).
    pub name: &'static str,
    /// MiniCL program source.
    pub source: &'static str,
    /// Device buffers (initial contents).
    pub buffers: Vec<BufInit>,
    /// Launch passes in order (one iteration of the benchmark).
    pub passes: Vec<Pass>,
    /// Buffer indices verified against the native baseline.
    pub outputs: Vec<usize>,
    /// Handwritten Rust baseline: takes the initial buffers, returns the
    /// full post-run buffer contents (only `outputs` are compared).
    pub native: Box<dyn Fn(&[BufInit]) -> Vec<BufInit> + Send + Sync>,
    /// Comparison tolerance for f32 outputs (0.0 = exact).
    pub tol: f32,
}

impl App {
    /// Run the native baseline.
    pub fn run_native(&self) -> Vec<BufInit> {
        (self.native)(&self.buffers)
    }
}

/// Problem-size preset: tests use `Small`, benches use `Bench`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeClass {
    /// Quick verification sizes.
    Small,
    /// Benchmark sizes (still laptop-scale; the interpreter substrate is
    /// ~100× slower than compiled code, see DESIGN.md).
    Bench,
}

/// All suite applications at a size class, in Fig. 12 order.
pub fn all_apps(size: SizeClass) -> Vec<App> {
    vec![
        apps::binarysearch::build(size),
        apps::binomialoption::build(size),
        apps::bitonicsort::build(size),
        apps::blackscholes::build(size),
        apps::dct::build(size),
        apps::dwthaar::build(size),
        apps::fastwalsh::build(size),
        apps::floydwarshall::build(size),
        apps::histogram::build(size),
        apps::matmul::build(size),
        apps::mattranspose::build(size),
        apps::nbody::build(size),
        apps::prefixsum::build(size),
        apps::reduction::build(size),
        apps::simpleconv::build(size),
        apps::mandelbrot::build(size),
    ]
}

/// Look up one app by (case-insensitive) name.
pub fn app_by_name(name: &str, size: SizeClass) -> Option<App> {
    all_apps(size).into_iter().find(|a| a.name.eq_ignore_ascii_case(name))
}
