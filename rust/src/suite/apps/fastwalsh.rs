//! FastWalshTransform: log₂(n) global passes over one buffer.

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void fastwalsh(__global float *a, uint step) {
    uint tid = (uint)get_global_id(0);
    uint group = tid % step;
    uint pair = 2u * step * (tid / step) + group;
    uint match_ = pair + step;
    float t1 = a[pair];
    float t2 = a[match_];
    a[pair] = t1 + t2;
    a[match_] = t1 - t2;
}
"#;

fn native(data: &[f32]) -> Vec<f32> {
    let n = data.len();
    let mut a = data.to_vec();
    let mut step = 1usize;
    while step < n {
        for tid in 0..n / 2 {
            let group = tid % step;
            let pair = 2 * step * (tid / step) + group;
            let mat = pair + step;
            let (t1, t2) = (a[pair], a[mat]);
            a[pair] = t1 + t2;
            a[mat] = t1 - t2;
        }
        step *= 2;
    }
    a
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let n = match size {
        SizeClass::Small => 256usize,
        SizeClass::Bench => 1 << 13,
    };
    let data = super::rand_f32(n, 41);
    let mut passes = Vec::new();
    let mut step = 1usize;
    while step < n {
        passes.push(Pass {
            kernel: "fastwalsh",
            args: vec![PassArg::Buf(0), PassArg::Scalar(KernelArg::U32(step as u32))],
            global: [n / 2, 1, 1],
            local: [64.min(n / 2), 1, 1],
        });
        step *= 2;
    }
    App {
        name: "FastWalshTransform",
        source: SRC,
        buffers: vec![BufInit::F32(data)],
        passes,
        outputs: vec![0],
        native: Box::new(|bufs| {
            let BufInit::F32(d) = &bufs[0] else { unreachable!() };
            vec![BufInit::F32(native(d))]
        }),
        tol: 1e-4,
    }
}
