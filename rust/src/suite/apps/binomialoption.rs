//! BinomialOption: one option per work-group, lattice walked with a
//! barrier per level (the canonical b-loop workload, §4.5).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};
use crate::vecmath::scalar32;

const SRC: &str = r#"
__kernel void binomialoption(__global const float *randArray,
                             __global float *output,
                             __local float *callA,
                             __local float *callB,
                             uint numSteps) {
    uint tid = (uint)get_local_id(0);
    uint bid = (uint)get_group_id(0);
    float inRand = randArray[bid];
    float s = (1.0f - inRand) * 5.0f + inRand * 30.0f;
    float x = (1.0f - inRand) * 1.0f + inRand * 100.0f;
    float optionYears = (1.0f - inRand) * 0.25f + inRand * 10.0f;
    float dt = optionYears / (float)numSteps;
    float vsdt = 0.3f * sqrt(dt);
    float rdt = 0.02f * dt;
    float r = exp(rdt);
    float rInv = 1.0f / r;
    float u = exp(vsdt);
    float d = 1.0f / u;
    float pu = (r - d) / (u - d);
    float pd = 1.0f - pu;
    float puByr = pu * rInv;
    float pdByr = pd * rInv;
    float profit = s * exp(vsdt * (2.0f * (float)tid - (float)numSteps)) - x;
    callA[tid] = (profit > 0.0f) ? profit : 0.0f;
    barrier(CLK_LOCAL_MEM_FENCE);
    for (int j = (int)numSteps; j > 0; j -= 2) {
        if ((int)tid < j) {
            callB[tid] = puByr * callA[tid + 1u] + pdByr * callA[tid];
        }
        barrier(CLK_LOCAL_MEM_FENCE);
        if ((int)tid < j - 1) {
            callA[tid] = puByr * callB[tid + 1u] + pdByr * callB[tid];
        }
        barrier(CLK_LOCAL_MEM_FENCE);
    }
    if (tid == 0u) { output[bid] = callA[0]; }
}
"#;

/// Native lattice evaluation, mirroring the kernel's float order.
fn native_one(in_rand: f32, num_steps: usize) -> f32 {
    let s = (1.0 - in_rand) * 5.0 + in_rand * 30.0;
    let x = (1.0 - in_rand) * 1.0 + in_rand * 100.0;
    let option_years = (1.0 - in_rand) * 0.25 + in_rand * 10.0;
    let dt = option_years / num_steps as f32;
    let vsdt = 0.3 * dt.sqrt();
    let rdt = 0.02 * dt;
    let r = scalar32::exp(rdt);
    let r_inv = 1.0 / r;
    let u = scalar32::exp(vsdt);
    let d = 1.0 / u;
    let pu = (r - d) / (u - d);
    let pd = 1.0 - pu;
    let pu_byr = pu * r_inv;
    let pd_byr = pd * r_inv;
    let n = num_steps + 1;
    let mut call_a: Vec<f32> = (0..n)
        .map(|t| {
            let profit = s * scalar32::exp(vsdt * (2.0 * t as f32 - num_steps as f32)) - x;
            profit.max(0.0)
        })
        .collect();
    let mut call_b = vec![0.0f32; n];
    let mut j = num_steps as i64;
    while j > 0 {
        for t in 0..n {
            if (t as i64) < j {
                call_b[t] = pu_byr * call_a[t + 1] + pd_byr * call_a[t];
            }
        }
        for t in 0..n {
            if (t as i64) < j - 1 {
                call_a[t] = pu_byr * call_b[t + 1] + pd_byr * call_b[t];
            }
        }
        j -= 2;
    }
    call_a[0]
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let (options, steps) = match size {
        SizeClass::Small => (4usize, 15usize),
        SizeClass::Bench => (16, 63),
    };
    let wg = steps + 1;
    App {
        name: "BinomialOption",
        source: SRC,
        buffers: vec![
            BufInit::F32(super::rand_f32(options, 29)),
            BufInit::F32(vec![0.0; options]),
        ],
        passes: vec![Pass {
            kernel: "binomialoption",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Local(wg * 4),
                PassArg::Local(wg * 4),
                PassArg::Scalar(KernelArg::U32(steps as u32)),
            ],
            global: [options * wg, 1, 1],
            local: [wg, 1, 1],
        }],
        outputs: vec![1],
        native: Box::new(move |bufs| {
            let BufInit::F32(rand) = &bufs[0] else { unreachable!() };
            let out: Vec<f32> = rand.iter().map(|&r| native_one(r, steps)).collect();
            vec![bufs[0].clone(), BufInit::F32(out)]
        }),
        tol: 5e-3,
    }
}
