//! BinarySearch: per-work-item binary search — the paper's worst case on
//! x86 (divergent, data-dependent loop; §6.1 and §8 discuss why).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void binarysearch(__global uint *out,
                           __global const uint *sorted,
                           __global const uint *keys,
                           uint n) {
    size_t i = get_global_id(0);
    uint key = keys[i];
    uint lo = 0u;
    uint hi = n;
    while (lo < hi) {
        uint mid = (lo + hi) / 2u;
        if (sorted[mid] < key) { lo = mid + 1u; } else { hi = mid; }
    }
    out[i] = lo;
}
"#;

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let (n, m) = match size {
        SizeClass::Small => (256usize, 64usize),
        SizeClass::Bench => (1 << 14, 4096),
    };
    let mut sorted = super::rand_u32(n, 1 << 20, 11);
    sorted.sort_unstable();
    let keys = super::rand_u32(m, 1 << 20, 13);
    App {
        name: "BinarySearch",
        source: SRC,
        buffers: vec![
            BufInit::U32(vec![0; m]),
            BufInit::U32(sorted),
            BufInit::U32(keys),
        ],
        passes: vec![Pass {
            kernel: "binarysearch",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Buf(2),
                PassArg::Scalar(KernelArg::U32(n as u32)),
            ],
            global: [m, 1, 1],
            local: [64, 1, 1],
        }],
        outputs: vec![0],
        native: Box::new(|bufs| {
            let (BufInit::U32(sorted), BufInit::U32(keys)) = (&bufs[1], &bufs[2]) else {
                unreachable!()
            };
            let out: Vec<u32> =
                keys.iter().map(|k| sorted.partition_point(|v| v < k) as u32).collect();
            vec![BufInit::U32(out), bufs[1].clone(), bufs[2].clone()]
        }),
        tol: 0.0,
    }
}
