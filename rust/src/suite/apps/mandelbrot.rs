//! Mandelbrot: data-dependent escape loop (maximally divergent — the
//! gang executor's per-lane fallback runs almost everywhere).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void mandelbrot(__global uint *counts, uint width, float scale, uint maxIter) {
    uint x = (uint)get_global_id(0);
    uint y = (uint)get_global_id(1);
    float cx = ((float)x / (float)width) * scale - scale * 0.75f;
    float cy = ((float)y / (float)width) * scale - scale * 0.5f;
    float zx = 0.0f;
    float zy = 0.0f;
    uint it = 0u;
    while (it < maxIter && zx * zx + zy * zy < 4.0f) {
        float t = zx * zx - zy * zy + cx;
        zy = 2.0f * zx * zy + cy;
        zx = t;
        it++;
    }
    counts[y * width + x] = it;
}
"#;

fn native(width: usize, scale: f32, max_iter: u32) -> Vec<u32> {
    let mut out = vec![0u32; width * width];
    for y in 0..width {
        for x in 0..width {
            let cx = (x as f32 / width as f32) * scale - scale * 0.75;
            let cy = (y as f32 / width as f32) * scale - scale * 0.5;
            let (mut zx, mut zy) = (0f32, 0f32);
            let mut it = 0u32;
            while it < max_iter && zx * zx + zy * zy < 4.0 {
                let t = zx * zx - zy * zy + cx;
                zy = 2.0 * zx * zy + cy;
                zx = t;
                it += 1;
            }
            out[y * width + x] = it;
        }
    }
    out
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let (width, max_iter) = match size {
        SizeClass::Small => (16usize, 64u32),
        SizeClass::Bench => (64, 256),
    };
    let scale = 2.5f32;
    App {
        name: "Mandelbrot",
        source: SRC,
        buffers: vec![BufInit::U32(vec![0; width * width])],
        passes: vec![Pass {
            kernel: "mandelbrot",
            args: vec![
                PassArg::Buf(0),
                PassArg::Scalar(KernelArg::U32(width as u32)),
                PassArg::Scalar(KernelArg::F32(scale)),
                PassArg::Scalar(KernelArg::U32(max_iter)),
            ],
            global: [width, width, 1],
            local: [8.min(width), 8.min(width), 1],
        }],
        outputs: vec![0],
        native: Box::new(move |_| vec![BufInit::U32(native(width, scale, max_iter))]),
        tol: 0.0,
    }
}
