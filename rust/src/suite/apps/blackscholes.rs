//! BlackScholes: transcendental-heavy option pricing (exercises the §5
//! Vecmathlib builtins; no barriers, perfectly data-parallel).

use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};
use crate::vecmath::scalar32;

const SRC: &str = r#"
float phi(float x) {
    float zabs = fabs(x);
    float k2 = 1.0f / (1.0f + 0.2316419f * zabs);
    float poly = k2 * (0.319381530f + k2 * (-0.356563782f +
                 k2 * (1.781477937f + k2 * (-1.821255978f + k2 * 1.330274429f))));
    float pdf = 0.3989422804f * exp(-0.5f * zabs * zabs);
    float cnd = 1.0f - pdf * poly;
    return (x < 0.0f) ? 1.0f - cnd : cnd;
}

__kernel void blackscholes(__global const float *rnd,
                           __global float *call,
                           __global float *put) {
    size_t i = get_global_id(0);
    float in = rnd[i];
    float s = 10.0f + in * 90.0f;
    float k = 10.0f + in * 90.0f;
    float t = 1.0f + in * 9.0f;
    float r = 0.01f;
    float sigma = 0.10f + in * 0.4f;
    float sqrtT = sqrt(t);
    float d1 = (log(s / k) + (r + sigma * sigma * 0.5f) * t) / (sigma * sqrtT);
    float d2 = d1 - sigma * sqrtT;
    float kexp = k * exp(-r * t);
    call[i] = s * phi(d1) - kexp * phi(d2);
    put[i] = kexp * phi(0.0f - d2) - s * phi(0.0f - d1);
}
"#;

fn phi_native(x: f32) -> f32 {
    let zabs = x.abs();
    let k2 = 1.0 / (1.0 + 0.2316419 * zabs);
    let poly = k2
        * (0.319381530
            + k2 * (-0.356563782 + k2 * (1.781477937 + k2 * (-1.821255978 + k2 * 1.330274429))));
    let pdf = 0.3989422804 * scalar32::exp(-0.5 * zabs * zabs);
    let cnd = 1.0 - pdf * poly;
    if x < 0.0 {
        1.0 - cnd
    } else {
        cnd
    }
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let n = match size {
        SizeClass::Small => 512usize,
        SizeClass::Bench => 1 << 14,
    };
    App {
        name: "BlackScholes",
        source: SRC,
        buffers: vec![
            BufInit::F32(super::rand_f32(n, 17)),
            BufInit::F32(vec![0.0; n]),
            BufInit::F32(vec![0.0; n]),
        ],
        passes: vec![Pass {
            kernel: "blackscholes",
            args: vec![PassArg::Buf(0), PassArg::Buf(1), PassArg::Buf(2)],
            global: [n, 1, 1],
            local: [64, 1, 1],
        }],
        outputs: vec![1, 2],
        native: Box::new(|bufs| {
            let BufInit::F32(rnd) = &bufs[0] else { unreachable!() };
            let mut call = Vec::with_capacity(rnd.len());
            let mut put = Vec::with_capacity(rnd.len());
            for &inr in rnd {
                let s = 10.0 + inr * 90.0;
                let k = 10.0 + inr * 90.0;
                let t = 1.0 + inr * 9.0;
                let r = 0.01f32;
                let sigma = 0.10 + inr * 0.4;
                let sqrt_t = t.sqrt();
                let d1 = (scalar32::log(s / k) + (r + sigma * sigma * 0.5) * t) / (sigma * sqrt_t);
                let d2 = d1 - sigma * sqrt_t;
                let kexp = k * scalar32::exp(-r * t);
                call.push(s * phi_native(d1) - kexp * phi_native(d2));
                put.push(kexp * phi_native(-d2) - s * phi_native(-d1));
            }
            vec![bufs[0].clone(), BufInit::F32(call), BufInit::F32(put)]
        }),
        tol: 2e-3,
    }
}
