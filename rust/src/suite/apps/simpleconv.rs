//! SimpleConvolution: 2-D convolution with border handling (divergent
//! guards inside uniform loops — the horizontal pass must reject these).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void simpleconv(__global float *out,
                         __global const float *in,
                         __global const float *mask,
                         uint width,
                         uint height,
                         uint maskW) {
    uint x = (uint)get_global_id(0);
    uint y = (uint)get_global_id(1);
    uint half_ = maskW / 2u;
    float sum = 0.0f;
    for (uint r = 0u; r < maskW; r++) {
        for (uint c = 0u; c < maskW; c++) {
            int yy = (int)y + (int)r - (int)half_;
            int xx = (int)x + (int)c - (int)half_;
            if (yy >= 0 && yy < (int)height && xx >= 0 && xx < (int)width) {
                sum += in[(uint)yy * width + (uint)xx] * mask[r * maskW + c];
            }
        }
    }
    out[y * width + x] = sum;
}
"#;

fn native(input: &[f32], mask: &[f32], w: usize, h: usize, mw: usize) -> Vec<f32> {
    let half = (mw / 2) as i64;
    let mut out = vec![0f32; w * h];
    for y in 0..h as i64 {
        for x in 0..w as i64 {
            let mut sum = 0f32;
            for r in 0..mw as i64 {
                for c in 0..mw as i64 {
                    let yy = y + r - half;
                    let xx = x + c - half;
                    if yy >= 0 && yy < h as i64 && xx >= 0 && xx < w as i64 {
                        sum += input[yy as usize * w + xx as usize]
                            * mask[(r * mw as i64 + c) as usize];
                    }
                }
            }
            out[y as usize * w + x as usize] = sum;
        }
    }
    out
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let w = match size {
        SizeClass::Small => 16usize,
        SizeClass::Bench => 64,
    };
    let mw = 5usize;
    let input = super::rand_f32(w * w, 79);
    let mask = super::rand_f32(mw * mw, 83);
    App {
        name: "SimpleConvolution",
        source: SRC,
        buffers: vec![
            BufInit::F32(vec![0.0; w * w]),
            BufInit::F32(input),
            BufInit::F32(mask),
        ],
        passes: vec![Pass {
            kernel: "simpleconv",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Buf(2),
                PassArg::Scalar(KernelArg::U32(w as u32)),
                PassArg::Scalar(KernelArg::U32(w as u32)),
                PassArg::Scalar(KernelArg::U32(mw as u32)),
            ],
            global: [w, w, 1],
            local: [8.min(w), 8.min(w), 1],
        }],
        outputs: vec![0],
        native: Box::new(move |bufs| {
            let (BufInit::F32(input), BufInit::F32(mask)) = (&bufs[1], &bufs[2]) else {
                unreachable!()
            };
            vec![
                BufInit::F32(native(input, mask, w, w, mw)),
                bufs[1].clone(),
                bufs[2].clone(),
            ]
        }),
        tol: 1e-4,
    }
}
