//! DCT: the 8×8 block discrete cosine transform from the AMD SDK — the
//! paper's flagship for horizontal inner-loop parallelisation (§4.6,
//! Fig. 9/10) and the §6.4 TTA experiment.

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
uint getIdx(uint blockIdx, uint blockIdy, uint idx, uint idy, uint blockWidth, uint width) {
    return (blockIdy * blockWidth + idy) * width + (blockIdx * blockWidth + idx);
}

__kernel void dct(__global float *output,
                  __global const float *input,
                  __global const float *dct8x8,
                  __local float *inter,
                  const uint width,
                  const uint blockWidth,
                  const uint inverse) {
    uint i = (uint)get_local_id(0);
    uint j = (uint)get_local_id(1);
    uint groupIdx = (uint)get_group_id(0);
    uint groupIdy = (uint)get_group_id(1);
    float acc = 0.0f;
    for (uint k = 0u; k < blockWidth; k++) {
        uint index1 = (inverse != 0u) ? (k * blockWidth + j) : (j * blockWidth + k);
        uint index2 = getIdx(groupIdx, groupIdy, i, k, blockWidth, width);
        acc += dct8x8[index1] * input[index2];
    }
    inter[j * blockWidth + i] = acc;
    barrier(CLK_LOCAL_MEM_FENCE);
    acc = 0.0f;
    for (uint k = 0u; k < blockWidth; k++) {
        uint index1 = (inverse != 0u) ? (k * blockWidth + i) : (i * blockWidth + k);
        acc += inter[j * blockWidth + k] * dct8x8[index1];
    }
    output[getIdx(groupIdx, groupIdy, i, j, blockWidth, width)] = acc;
}
"#;

/// The 8×8 DCT basis matrix D[j][k] = c_j cos((2k+1) jπ/16).
pub fn dct_matrix(bw: usize) -> Vec<f32> {
    let mut d = vec![0f32; bw * bw];
    for j in 0..bw {
        let cj = if j == 0 { (1.0 / bw as f64).sqrt() } else { (2.0 / bw as f64).sqrt() };
        for k in 0..bw {
            d[j * bw + k] =
                (cj * ((2.0 * k as f64 + 1.0) * j as f64 * std::f64::consts::PI
                    / (2.0 * bw as f64))
                    .cos()) as f32;
        }
    }
    d
}

/// Native baseline: Y = D · X · Dᵀ per 8×8 block, same accumulation order.
fn native(input: &[f32], d: &[f32], width: usize, bw: usize) -> Vec<f32> {
    let height = input.len() / width;
    let mut out = vec![0f32; input.len()];
    for by in (0..height).step_by(bw) {
        for bx in (0..width).step_by(bw) {
            // inter[j][i] = sum_k D[j][k] * X[k][i]
            let mut inter = vec![0f32; bw * bw];
            for j in 0..bw {
                for i in 0..bw {
                    let mut acc = 0f32;
                    for k in 0..bw {
                        acc += d[j * bw + k] * input[(by + k) * width + bx + i];
                    }
                    inter[j * bw + i] = acc;
                }
            }
            // out[j][i] = sum_k inter[j][k] * D[i][k]
            for j in 0..bw {
                for i in 0..bw {
                    let mut acc = 0f32;
                    for k in 0..bw {
                        acc += inter[j * bw + k] * d[i * bw + k];
                    }
                    out[(by + j) * width + bx + i] = acc;
                }
            }
        }
    }
    out
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let width = match size {
        SizeClass::Small => 16usize,
        SizeClass::Bench => 64,
    };
    let bw = 8usize;
    let input = super::rand_f32(width * width, 31);
    let d = dct_matrix(bw);
    App {
        name: "DCT",
        source: SRC,
        buffers: vec![
            BufInit::F32(vec![0.0; width * width]),
            BufInit::F32(input),
            BufInit::F32(d),
        ],
        passes: vec![Pass {
            kernel: "dct",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Buf(2),
                PassArg::Local(bw * bw * 4),
                PassArg::Scalar(KernelArg::U32(width as u32)),
                PassArg::Scalar(KernelArg::U32(bw as u32)),
                PassArg::Scalar(KernelArg::U32(0)),
            ],
            global: [width, width, 1],
            local: [bw, bw, 1],
        }],
        outputs: vec![0],
        native: Box::new(move |bufs| {
            let (BufInit::F32(input), BufInit::F32(d)) = (&bufs[1], &bufs[2]) else {
                unreachable!()
            };
            vec![BufInit::F32(native(input, d, width, bw)), bufs[1].clone(), bufs[2].clone()]
        }),
        tol: 1e-4,
    }
}
