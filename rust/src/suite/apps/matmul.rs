//! MatrixMultiplication: tiled GEMM with local-memory staging and a
//! barrier per tile (b-loop + privatised accumulator).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void matmul(__global float *C,
                     __global const float *A,
                     __global const float *B,
                     uint n,
                     __local float *As,
                     __local float *Bs) {
    uint tx = (uint)get_local_id(0);
    uint ty = (uint)get_local_id(1);
    uint col = (uint)get_global_id(0);
    uint row = (uint)get_global_id(1);
    float acc = 0.0f;
    uint tiles = n / 8u;
    for (uint t = 0u; t < tiles; t++) {
        As[ty * 8u + tx] = A[row * n + (t * 8u + tx)];
        Bs[ty * 8u + tx] = B[(t * 8u + ty) * n + col];
        barrier(CLK_LOCAL_MEM_FENCE);
        for (uint k = 0u; k < 8u; k++) {
            acc += As[ty * 8u + k] * Bs[k * 8u + tx];
        }
        barrier(CLK_LOCAL_MEM_FENCE);
    }
    C[row * n + col] = acc;
}
"#;

/// Native baseline with the same tile-ordered accumulation.
fn native(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0f32; n * n];
    for row in 0..n {
        for col in 0..n {
            let mut acc = 0f32;
            for k in 0..n {
                acc += a[row * n + k] * b[k * n + col];
            }
            c[row * n + col] = acc;
        }
    }
    c
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let n = match size {
        SizeClass::Small => 16usize,
        SizeClass::Bench => 64,
    };
    let a = super::rand_f32(n * n, 53);
    let b = super::rand_f32(n * n, 59);
    App {
        name: "MatrixMultiplication",
        source: SRC,
        buffers: vec![BufInit::F32(vec![0.0; n * n]), BufInit::F32(a), BufInit::F32(b)],
        passes: vec![Pass {
            kernel: "matmul",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Buf(2),
                PassArg::Scalar(KernelArg::U32(n as u32)),
                PassArg::Local(8 * 8 * 4),
                PassArg::Local(8 * 8 * 4),
            ],
            global: [n, n, 1],
            local: [8, 8, 1],
        }],
        outputs: vec![0],
        native: Box::new(move |bufs| {
            let (BufInit::F32(a), BufInit::F32(b)) = (&bufs[1], &bufs[2]) else { unreachable!() };
            vec![BufInit::F32(native(a, b, n)), bufs[1].clone(), bufs[2].clone()]
        }),
        tol: 1e-3,
    }
}
