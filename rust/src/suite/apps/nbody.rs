//! NBody: all-pairs gravity step with float4 positions — the paper's
//! other worst case on x86 (§6.1); math-heavy with a uniform inner loop
//! that the horizontal pass parallelises.

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void nbody(__global const float4 *pos,
                    __global float4 *newPos,
                    __global const float4 *vel,
                    __global float4 *newVel,
                    uint numBodies,
                    float deltaTime,
                    float epsSqr) {
    size_t gid = get_global_id(0);
    float4 myPos = pos[gid];
    float4 acc = (float4)(0.0f, 0.0f, 0.0f, 0.0f);
    for (uint j = 0u; j < numBodies; j++) {
        float4 p = pos[j];
        float rx = p.x - myPos.x;
        float ry = p.y - myPos.y;
        float rz = p.z - myPos.z;
        float distSqr = rx * rx + ry * ry + rz * rz;
        float invDist = 1.0f / sqrt(distSqr + epsSqr);
        float invDistCube = invDist * invDist * invDist;
        float s = p.w * invDistCube;
        acc.x += s * rx;
        acc.y += s * ry;
        acc.z += s * rz;
    }
    float4 oldVel = vel[gid];
    float4 np = myPos + oldVel * deltaTime + acc * (0.5f * deltaTime * deltaTime);
    np.w = myPos.w;
    float4 nv = oldVel + acc * deltaTime;
    newPos[gid] = np;
    newVel[gid] = nv;
}
"#;

fn native(pos: &[f32], vel: &[f32], n: usize, dt: f32, eps: f32) -> (Vec<f32>, Vec<f32>) {
    let mut np = vec![0f32; n * 4];
    let mut nv = vec![0f32; n * 4];
    for i in 0..n {
        let my = &pos[i * 4..i * 4 + 4];
        let mut acc = [0f32; 3];
        for j in 0..n {
            let p = &pos[j * 4..j * 4 + 4];
            let r = [p[0] - my[0], p[1] - my[1], p[2] - my[2]];
            let dist_sqr = r[0] * r[0] + r[1] * r[1] + r[2] * r[2];
            let inv = 1.0 / (dist_sqr + eps).sqrt();
            let s = p[3] * inv * inv * inv;
            acc[0] += s * r[0];
            acc[1] += s * r[1];
            acc[2] += s * r[2];
        }
        let ov = &vel[i * 4..i * 4 + 4];
        for c in 0..3 {
            np[i * 4 + c] = my[c] + ov[c] * dt + acc[c] * (0.5 * dt * dt);
            nv[i * 4 + c] = ov[c] + acc[c] * dt;
        }
        np[i * 4 + 3] = my[3];
        nv[i * 4 + 3] = ov[3];
    }
    (np, nv)
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let n = match size {
        SizeClass::Small => 64usize,
        SizeClass::Bench => 512,
    };
    let (dt, eps) = (0.005f32, 50.0f32);
    let pos = super::rand_f32(n * 4, 67);
    let vel = vec![0.0f32; n * 4];
    App {
        name: "NBody",
        source: SRC,
        buffers: vec![
            BufInit::F32(pos),
            BufInit::F32(vec![0.0; n * 4]),
            BufInit::F32(vel),
            BufInit::F32(vec![0.0; n * 4]),
        ],
        passes: vec![Pass {
            kernel: "nbody",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Buf(2),
                PassArg::Buf(3),
                PassArg::Scalar(KernelArg::U32(n as u32)),
                PassArg::Scalar(KernelArg::F32(dt)),
                PassArg::Scalar(KernelArg::F32(eps)),
            ],
            global: [n, 1, 1],
            local: [64.min(n), 1, 1],
        }],
        outputs: vec![1, 3],
        native: Box::new(move |bufs| {
            let (BufInit::F32(pos), BufInit::F32(vel)) = (&bufs[0], &bufs[2]) else {
                unreachable!()
            };
            let (np, nv) = native(pos, vel, n, dt, eps);
            vec![bufs[0].clone(), BufInit::F32(np), bufs[2].clone(), BufInit::F32(nv)]
        }),
        tol: 2e-3,
    }
}
