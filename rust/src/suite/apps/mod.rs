//! Suite applications (AMD APP SDK analogs), one module each.

pub mod binarysearch;
pub mod binomialoption;
pub mod bitonicsort;
pub mod blackscholes;
pub mod dct;
pub mod dwthaar;
pub mod fastwalsh;
pub mod floydwarshall;
pub mod histogram;
pub mod mandelbrot;
pub mod matmul;
pub mod mattranspose;
pub mod nbody;
pub mod prefixsum;
pub mod reduction;
pub mod simpleconv;

use crate::testing::Rng;

/// Shared input generator: deterministic pseudo-random f32s in [0,1).
pub fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    r.f32s(n, 0.0, 1.0)
}

/// Deterministic pseudo-random u32s below `below`.
pub fn rand_u32(n: usize, below: u32, seed: u64) -> Vec<u32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| (r.next_u64() % below as u64) as u32).collect()
}
