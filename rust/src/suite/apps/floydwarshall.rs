//! FloydWarshall: all-pairs shortest paths, n passes of an n×n kernel.

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void floydwarshall(__global uint *path, uint n, uint k) {
    uint x = (uint)get_global_id(0);
    uint y = (uint)get_global_id(1);
    uint yx = y * n + x;
    uint d = path[y * n + k] + path[k * n + x];
    if (d < path[yx]) { path[yx] = d; }
}
"#;

fn native(adj: &[u32], n: usize) -> Vec<u32> {
    let mut p = adj.to_vec();
    for k in 0..n {
        for y in 0..n {
            for x in 0..n {
                let d = p[y * n + k].saturating_add(p[k * n + x]);
                if d < p[y * n + x] {
                    p[y * n + x] = d;
                }
            }
        }
    }
    p
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let n = match size {
        SizeClass::Small => 16usize,
        SizeClass::Bench => 64,
    };
    // Random edge weights; keep small so sums never overflow u32.
    let adj: Vec<u32> = super::rand_u32(n * n, 200, 43)
        .into_iter()
        .enumerate()
        .map(|(i, v)| if i % (n + 1) == 0 { 0 } else { v + 1 })
        .collect();
    let passes = (0..n)
        .map(|k| Pass {
            kernel: "floydwarshall",
            args: vec![
                PassArg::Buf(0),
                PassArg::Scalar(KernelArg::U32(n as u32)),
                PassArg::Scalar(KernelArg::U32(k as u32)),
            ],
            global: [n, n, 1],
            local: [8.min(n), 8.min(n), 1],
        })
        .collect();
    App {
        name: "FloydWarshall",
        source: SRC,
        buffers: vec![BufInit::U32(adj)],
        passes,
        outputs: vec![0],
        native: Box::new(move |bufs| {
            let BufInit::U32(adj) = &bufs[0] else { unreachable!() };
            vec![BufInit::U32(native(adj, n))]
        }),
        tol: 0.0,
    }
}
