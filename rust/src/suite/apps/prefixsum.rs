//! PrefixSum: Blelchoch-style work-group exclusive scan (up-sweep +
//! down-sweep, barriers inside loops with uniform-but-accumulating
//! bounds — the hardest b-loop shape in the suite).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void prefixsum(__global float *output,
                        __global const float *input,
                        __local float *block,
                        uint length) {
    uint tid = (uint)get_local_id(0);
    uint offset = 1u;
    block[2u * tid] = input[2u * tid];
    block[2u * tid + 1u] = input[2u * tid + 1u];
    for (uint d = length >> 1; d > 0u; d >>= 1) {
        barrier(CLK_LOCAL_MEM_FENCE);
        if (tid < d) {
            uint ai = offset * (2u * tid + 1u) - 1u;
            uint bi = offset * (2u * tid + 2u) - 1u;
            block[bi] += block[ai];
        }
        offset *= 2u;
    }
    barrier(CLK_LOCAL_MEM_FENCE);
    if (tid == 0u) { block[length - 1u] = 0.0f; }
    for (uint d = 1u; d < length; d *= 2u) {
        offset >>= 1;
        barrier(CLK_LOCAL_MEM_FENCE);
        if (tid < d) {
            uint ai = offset * (2u * tid + 1u) - 1u;
            uint bi = offset * (2u * tid + 2u) - 1u;
            float t = block[ai];
            block[ai] = block[bi];
            block[bi] += t;
        }
    }
    barrier(CLK_LOCAL_MEM_FENCE);
    output[2u * tid] = block[2u * tid];
    output[2u * tid + 1u] = block[2u * tid + 1u];
}
"#;

/// Build the app (single work-group, like the AMD sample's group scan).
pub fn build(size: SizeClass) -> App {
    let n = match size {
        SizeClass::Small => 32usize,
        SizeClass::Bench => 512,
    };
    let input = super::rand_f32(n, 71);
    App {
        name: "PrefixSum",
        source: SRC,
        buffers: vec![BufInit::F32(vec![0.0; n]), BufInit::F32(input)],
        passes: vec![Pass {
            kernel: "prefixsum",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Local(n * 4),
                PassArg::Scalar(KernelArg::U32(n as u32)),
            ],
            global: [n / 2, 1, 1],
            local: [n / 2, 1, 1],
        }],
        outputs: vec![0],
        native: Box::new(move |bufs| {
            let BufInit::F32(input) = &bufs[1] else { unreachable!() };
            // Replicate the Blelloch tree order so f32 rounding matches.
            let mut block = input.clone();
            let mut offset = 1usize;
            let mut d = n >> 1;
            while d > 0 {
                for t in 0..d {
                    let ai = offset * (2 * t + 1) - 1;
                    let bi = offset * (2 * t + 2) - 1;
                    block[bi] += block[ai];
                }
                offset *= 2;
                d >>= 1;
            }
            block[n - 1] = 0.0;
            let mut d = 1usize;
            while d < n {
                offset >>= 1;
                for t in 0..d {
                    let ai = offset * (2 * t + 1) - 1;
                    let bi = offset * (2 * t + 2) - 1;
                    let tmp = block[ai];
                    block[ai] = block[bi];
                    block[bi] += tmp;
                }
                d *= 2;
            }
            vec![BufInit::F32(block), bufs[1].clone()]
        }),
        tol: 1e-4,
    }
}
