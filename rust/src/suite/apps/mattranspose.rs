//! MatrixTranspose: local-tile staging with a barrier (coalescing
//! pattern from the AMD SDK).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void mattranspose(__global float *out,
                           __global const float *in,
                           __local float *tile,
                           uint w) {
    uint lx = (uint)get_local_id(0);
    uint ly = (uint)get_local_id(1);
    uint gx = (uint)get_global_id(0);
    uint gy = (uint)get_global_id(1);
    tile[ly * 8u + lx] = in[gy * w + gx];
    barrier(CLK_LOCAL_MEM_FENCE);
    uint ox = (uint)get_group_id(1) * 8u + lx;
    uint oy = (uint)get_group_id(0) * 8u + ly;
    out[oy * w + ox] = tile[lx * 8u + ly];
}
"#;

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let w = match size {
        SizeClass::Small => 16usize,
        SizeClass::Bench => 128,
    };
    let input = super::rand_f32(w * w, 61);
    App {
        name: "MatrixTranspose",
        source: SRC,
        buffers: vec![BufInit::F32(vec![0.0; w * w]), BufInit::F32(input)],
        passes: vec![Pass {
            kernel: "mattranspose",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Local(8 * 8 * 4),
                PassArg::Scalar(KernelArg::U32(w as u32)),
            ],
            global: [w, w, 1],
            local: [8, 8, 1],
        }],
        outputs: vec![0],
        native: Box::new(move |bufs| {
            let BufInit::F32(input) = &bufs[1] else { unreachable!() };
            let mut out = vec![0f32; w * w];
            for y in 0..w {
                for x in 0..w {
                    out[x * w + y] = input[y * w + x];
                }
            }
            vec![BufInit::F32(out), bufs[1].clone()]
        }),
        tol: 0.0,
    }
}
