//! BitonicSort: multi-pass comparator network (stage/pass kernel
//! relaunches — exercises the enqueue-time specialisation cache, §4.1).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void bitonicsort(__global uint *a, uint stage, uint passOfStage) {
    uint threadId = (uint)get_global_id(0);
    uint pairDistance = 1u << (stage - passOfStage);
    uint blockWidth = 2u * pairDistance;
    uint leftId = (threadId % pairDistance) + (threadId / pairDistance) * blockWidth;
    uint rightId = leftId + pairDistance;
    uint leftElement = a[leftId];
    uint rightElement = a[rightId];
    uint sameDirectionBlockWidth = 1u << stage;
    uint sortIncreasing = 1u;
    if ((threadId / sameDirectionBlockWidth) % 2u == 1u) {
        sortIncreasing = 1u - sortIncreasing;
    }
    uint greater = (leftElement > rightElement) ? leftElement : rightElement;
    uint lesser = (leftElement > rightElement) ? rightElement : leftElement;
    if (sortIncreasing == 1u) {
        a[leftId] = lesser;
        a[rightId] = greater;
    } else {
        a[leftId] = greater;
        a[rightId] = lesser;
    }
}
"#;

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let n = match size {
        SizeClass::Small => 256usize,
        SizeClass::Bench => 1 << 13,
    };
    let data = super::rand_u32(n, u32::MAX, 23);
    let stages = n.trailing_zeros();
    let mut passes = Vec::new();
    for stage in 0..stages {
        for pass in 0..=stage {
            passes.push(Pass {
                kernel: "bitonicsort",
                args: vec![
                    PassArg::Buf(0),
                    PassArg::Scalar(KernelArg::U32(stage)),
                    PassArg::Scalar(KernelArg::U32(pass)),
                ],
                global: [n / 2, 1, 1],
                local: [64.min(n / 2), 1, 1],
            });
        }
    }
    App {
        name: "BitonicSort",
        source: SRC,
        buffers: vec![BufInit::U32(data)],
        passes,
        outputs: vec![0],
        native: Box::new(|bufs| {
            let BufInit::U32(data) = &bufs[0] else { unreachable!() };
            let mut v = data.clone();
            v.sort_unstable();
            vec![BufInit::U32(v)]
        }),
        tol: 0.0,
    }
}
