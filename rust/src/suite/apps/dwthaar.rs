//! DwtHaar1D: per-work-group multi-level Haar wavelet transform
//! (b-loop with halving active set; exercises privatised region-crossing
//! scalars — Fig. 11's `b` pattern).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void dwthaar(__global const float *in,
                      __global float *out,
                      __local float *t,
                      uint n) {
    uint i = (uint)get_local_id(0);
    size_t g = (size_t)get_group_id(0) * (size_t)n;
    t[2u * i] = in[g + (size_t)(2u * i)];
    t[2u * i + 1u] = in[g + (size_t)(2u * i + 1u)];
    barrier(CLK_LOCAL_MEM_FENCE);
    uint len = n;
    while (len > 1u) {
        uint half = len / 2u;
        float a = 0.0f;
        float d = 0.0f;
        if (i < half) {
            a = (t[2u * i] + t[2u * i + 1u]) * 0.70710678f;
            d = (t[2u * i] - t[2u * i + 1u]) * 0.70710678f;
        }
        barrier(CLK_LOCAL_MEM_FENCE);
        if (i < half) {
            t[i] = a;
            out[g + (size_t)(half + i)] = d;
        }
        barrier(CLK_LOCAL_MEM_FENCE);
        len = half;
    }
    if (i == 0u) { out[g] = t[0]; }
}
"#;

fn native(input: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0f32; input.len()];
    for (g, chunk) in input.chunks(n).enumerate() {
        let mut t = chunk.to_vec();
        let base = g * n;
        let mut len = n;
        while len > 1 {
            let half = len / 2;
            let mut next = vec![0f32; half];
            for i in 0..half {
                next[i] = (t[2 * i] + t[2 * i + 1]) * 0.70710678;
                out[base + half + i] = (t[2 * i] - t[2 * i + 1]) * 0.70710678;
            }
            t[..half].copy_from_slice(&next);
            len = half;
        }
        out[base] = t[0];
    }
    out
}

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let (n, groups) = match size {
        SizeClass::Small => (16usize, 4usize),
        SizeClass::Bench => (256, 32),
    };
    let input = super::rand_f32(n * groups, 37);
    App {
        name: "DwtHaar1D",
        source: SRC,
        buffers: vec![BufInit::F32(input), BufInit::F32(vec![0.0; n * groups])],
        passes: vec![Pass {
            kernel: "dwthaar",
            args: vec![
                PassArg::Buf(0),
                PassArg::Buf(1),
                PassArg::Local(n * 4),
                PassArg::Scalar(KernelArg::U32(n as u32)),
            ],
            global: [groups * n / 2, 1, 1],
            local: [n / 2, 1, 1],
        }],
        outputs: vec![1],
        native: Box::new(move |bufs| {
            let BufInit::F32(input) = &bufs[0] else { unreachable!() };
            vec![bufs[0].clone(), BufInit::F32(native(input, n))]
        }),
        tol: 1e-4,
    }
}
