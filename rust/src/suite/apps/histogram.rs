//! Histogram: per-work-item private bins (exercises private arrays and
//! the context-array rewrite) + a reduction pass. The AMD original uses
//! local atomics; MiniCL has none, so this is the standard atomics-free
//! two-phase formulation (documented in DESIGN.md §Substitutions).

use crate::cl::program::KernelArg;
use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void histogram_partial(__global const uint *data,
                                __global uint *partial,
                                uint itemsPerWi) {
    size_t i = get_global_id(0);
    size_t nwi = get_global_size(0);
    uint bins[16];
    for (uint b = 0u; b < 16u; b++) { bins[b] = 0u; }
    for (uint k = 0u; k < itemsPerWi; k++) {
        uint v = data[i * (size_t)itemsPerWi + (size_t)k];
        bins[v & 15u] += 1u;
    }
    for (uint b = 0u; b < 16u; b++) {
        partial[(size_t)b * nwi + i] = bins[b];
    }
}

__kernel void histogram_reduce(__global const uint *partial,
                               __global uint *hist,
                               uint chunks) {
    uint b = (uint)get_global_id(0);
    uint acc = 0u;
    for (uint c = 0u; c < chunks; c++) {
        acc += partial[b * chunks + c];
    }
    hist[b] = acc;
}
"#;

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let (wis, per) = match size {
        SizeClass::Small => (32usize, 16usize),
        SizeClass::Bench => (256, 256),
    };
    let data = super::rand_u32(wis * per, 1 << 16, 47);
    App {
        name: "Histogram",
        source: SRC,
        buffers: vec![
            BufInit::U32(data),
            BufInit::U32(vec![0; 16 * wis]),
            BufInit::U32(vec![0; 16]),
        ],
        passes: vec![
            Pass {
                kernel: "histogram_partial",
                args: vec![
                    PassArg::Buf(0),
                    PassArg::Buf(1),
                    PassArg::Scalar(KernelArg::U32(per as u32)),
                ],
                global: [wis, 1, 1],
                local: [16.min(wis), 1, 1],
            },
            Pass {
                kernel: "histogram_reduce",
                args: vec![
                    PassArg::Buf(1),
                    PassArg::Buf(2),
                    PassArg::Scalar(KernelArg::U32(wis as u32)),
                ],
                global: [16, 1, 1],
                local: [16, 1, 1],
            },
        ],
        outputs: vec![2],
        native: Box::new(move |bufs| {
            let BufInit::U32(data) = &bufs[0] else { unreachable!() };
            let mut hist = vec![0u32; 16];
            for &v in data {
                hist[(v & 15) as usize] += 1;
            }
            vec![bufs[0].clone(), bufs[1].clone(), BufInit::U32(hist)]
        }),
        tol: 0.0,
    }
}
