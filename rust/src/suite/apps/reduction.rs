//! Reduction: classic local-memory tree sum with a stride-halving
//! barrier loop.

use crate::suite::{App, BufInit, Pass, PassArg, SizeClass};

const SRC: &str = r#"
__kernel void reduction(__global const float *in,
                        __global float *out,
                        __local float *sdata) {
    uint tid = (uint)get_local_id(0);
    size_t i = get_global_id(0);
    sdata[tid] = in[i];
    barrier(CLK_LOCAL_MEM_FENCE);
    for (uint s = (uint)get_local_size(0) / 2u; s > 0u; s >>= 1) {
        if (tid < s) { sdata[tid] += sdata[tid + s]; }
        barrier(CLK_LOCAL_MEM_FENCE);
    }
    if (tid == 0u) { out[get_group_id(0)] = sdata[0]; }
}
"#;

/// Build the app.
pub fn build(size: SizeClass) -> App {
    let (n, wg) = match size {
        SizeClass::Small => (256usize, 32usize),
        SizeClass::Bench => (1 << 14, 128),
    };
    let input = super::rand_f32(n, 73);
    let groups = n / wg;
    App {
        name: "Reduction",
        source: SRC,
        buffers: vec![BufInit::F32(input), BufInit::F32(vec![0.0; groups])],
        passes: vec![Pass {
            kernel: "reduction",
            args: vec![PassArg::Buf(0), PassArg::Buf(1), PassArg::Local(wg * 4)],
            global: [n, 1, 1],
            local: [wg, 1, 1],
        }],
        outputs: vec![1],
        native: Box::new(move |bufs| {
            let BufInit::F32(input) = &bufs[0] else { unreachable!() };
            // Tree order matches the kernel exactly → tight tolerance.
            let out: Vec<f32> = input
                .chunks(wg)
                .map(|chunk| {
                    let mut t = chunk.to_vec();
                    let mut s = wg / 2;
                    while s > 0 {
                        for i in 0..s {
                            t[i] += t[i + s];
                        }
                        s /= 2;
                    }
                    t[0]
                })
                .collect();
            vec![bufs[0].clone(), BufInit::F32(out)]
        }),
        tol: 1e-5,
    }
}
