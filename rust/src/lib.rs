//! # pocl-rs — a performance-portable OpenCL-style runtime and kernel compiler
//!
//! Reproduction of *"pocl: A Performance-Portable OpenCL Implementation"*
//! (Jääskeläinen, Sánchez de La Lama, Schnetter, Raiskila, Takala, Berg;
//! Int. J. Parallel Programming, 2015) as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! The crate is organised exactly like the paper's system (Fig. 2):
//!
//! * [`cl`] — the **host layer**: a `cl*`-style API (platform, context,
//!   command queue, buffers, programs, kernels, events).
//! * [`frontend`] — the Clang analog: a lexer/parser/semantic analyser for
//!   *MiniCL*, an OpenCL C subset, lowering to the typed IR in [`ir`].
//! * [`ir`] — the LLVM-IR analog: typed SSA-lite IR on a control-flow
//!   graph, with the CFG utilities the paper's algorithms are written
//!   against (`CreateSubgraph`, `ReplicateCFG`, dominators, natural loops).
//! * [`kcc`] — the **kernel compiler**, the paper's core contribution:
//!   parallel region formation, conditional-barrier tail duplication,
//!   work-item loop generation with parallel-loop metadata, b-loop handling,
//!   horizontal inner-loop parallelisation, and variable privatisation.
//! * [`exec`] — execution engines for work-group functions: a serial
//!   interpreter, a lane-parallel *gang* executor (the SIMD mapping), and a
//!   fiber-based per-work-item baseline (the FreeOCL / Twin Peaks analog).
//! * [`devices`] — the **device layer**: `basic`, `threaded` (pthread
//!   analog), `ttasim` (static multi-issue TTA simulator) and `pjrt`
//!   (SPMD-style offload of AOT-compiled Pallas/XLA kernels).
//! * [`runtime`] — the PJRT client wrapper used by the `pjrt` device to
//!   load and execute `artifacts/*.hlo.txt` produced by `python/compile`.
//! * [`sched`] — the heterogeneous multi-device scheduler: a
//!   `DeviceGroup` co-executes one NDRange across asymmetric engines
//!   (static proportional splits or chunked self-scheduling with
//!   throughput feedback), joined by a single completion event.
//! * [`cache`] — the persistent kernel-binary cache (the
//!   `POCL_CACHE_DIR` analog): the `poclbin` serialization format plus a
//!   content-addressed on-disk store, so built kernels survive the
//!   process and warm starts skip the kernel compiler entirely.
//! * [`bufalloc`] — the chunked first-fit buffer allocator of §3.
//! * [`vecmath`] — the Vecmathlib port of §5: vectorised elementary
//!   functions over software-SIMD `RealVec` types.
//! * [`suite`] — the AMD APP SDK-style benchmark applications used in §6,
//!   with handwritten Rust "vendor stand-in" baselines.
//! * [`bench`] — the measurement harness regenerating every table/figure.
//! * [`testing`] — a minimal property-testing module (seeded generators)
//!   used by the test suite.
//! * [`trace`] — the always-compiled-in runtime tracer and metrics
//!   registry (the `POCL_TRACING` analog): per-thread span buffers with
//!   Chrome trace-event export, instrumenting the queue, compiler,
//!   cache, scheduler, and execution engines.
//! * [`envcfg`] — warn-once parsing of `POCLRS_*` environment knobs.

pub mod bench;
pub mod bufalloc;
pub mod cache;
pub mod cl;
pub mod devices;
pub mod envcfg;
pub mod exec;
pub mod frontend;
pub mod ir;
pub mod kcc;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sched;
pub mod suite;
pub mod testing;
pub mod trace;
pub mod vecmath;

pub use cl::error::{Error, Result};
