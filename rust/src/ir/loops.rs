//! Natural-loop detection and canonicalisation.
//!
//! §4.5 assumes "all OpenCL kernel loops can be converted to natural
//! canonical loops which have a single entry node, the loop header ... and
//! just one loop latch", with early exits converged to a single exit block.
//! `canonicalize` establishes exactly that shape (dedicated preheader,
//! single latch, dedicated exit block) so the b-loop barrier insertion has
//! unambiguous program points.

use std::collections::HashSet;

use super::cfg::split_edge;
use super::dom::DomTree;
use super::func::Function;
use super::inst::{BlockId, Term};

/// One natural loop.
#[derive(Debug, Clone)]
pub struct Loop {
    /// Loop header (single entry of the loop).
    pub header: BlockId,
    /// Latch blocks (sources of back edges). After canonicalisation there
    /// is exactly one.
    pub latches: Vec<BlockId>,
    /// All blocks in the loop body, header included, sorted by id.
    pub blocks: Vec<BlockId>,
    /// Blocks inside the loop with an edge leaving the loop.
    pub exiting: Vec<BlockId>,
    /// Blocks outside the loop targeted by exiting edges.
    pub exits: Vec<BlockId>,
    /// Nesting depth (1 = outermost). Filled by `find_loops`.
    pub depth: usize,
}

impl Loop {
    /// True if `b` belongs to the loop body.
    pub fn contains(&self, b: BlockId) -> bool {
        self.blocks.binary_search(&b).is_ok()
    }

    /// The single preheader if canonical: the unique predecessor of the
    /// header outside the loop.
    pub fn preheader(&self, f: &Function) -> Option<BlockId> {
        let preds = f.preds();
        let outside: Vec<BlockId> = preds[self.header.0 as usize]
            .iter()
            .copied()
            .filter(|p| !self.contains(*p))
            .collect();
        if outside.len() == 1 {
            Some(outside[0])
        } else {
            None
        }
    }
}

/// Find all natural loops (back edge t→h where h dominates t), merging
/// loops that share a header, and computing nesting depths.
pub fn find_loops(f: &Function) -> Vec<Loop> {
    let dom = DomTree::compute(f);
    let mut by_header: Vec<(BlockId, Vec<BlockId>)> = Vec::new();
    for b in super::cfg::reachable(f) {
        for s in f.succs(b) {
            if dom.dominates(s, b) {
                // back edge b -> s
                match by_header.iter_mut().find(|(h, _)| *h == s) {
                    Some((_, latches)) => latches.push(b),
                    None => by_header.push((s, vec![b])),
                }
            }
        }
    }
    let preds = f.preds();
    let mut loops: Vec<Loop> = Vec::new();
    for (header, latches) in by_header {
        // Standard natural-loop body computation: walk predecessors from
        // the latches until the header.
        let mut body: HashSet<BlockId> = HashSet::new();
        body.insert(header);
        let mut stack = latches.clone();
        while let Some(b) = stack.pop() {
            if body.insert(b) {
                for &p in &preds[b.0 as usize] {
                    if dom.is_reachable(p) {
                        stack.push(p);
                    }
                }
            }
        }
        let mut blocks: Vec<BlockId> = body.iter().copied().collect();
        blocks.sort();
        let mut exiting = Vec::new();
        let mut exits = Vec::new();
        for &b in &blocks {
            for s in f.succs(b) {
                if !body.contains(&s) {
                    if !exiting.contains(&b) {
                        exiting.push(b);
                    }
                    if !exits.contains(&s) {
                        exits.push(s);
                    }
                }
            }
        }
        loops.push(Loop { header, latches, blocks, exiting, exits, depth: 0 });
    }
    // Nesting depth: number of loops whose body contains this header
    // (including itself).
    let snapshot: Vec<(BlockId, Vec<BlockId>)> =
        loops.iter().map(|l| (l.header, l.blocks.clone())).collect();
    for l in &mut loops {
        l.depth = snapshot
            .iter()
            .filter(|(_, blocks)| blocks.binary_search(&l.header).is_ok())
            .count();
    }
    // Outermost first for deterministic processing.
    loops.sort_by_key(|l| (l.depth, l.header));
    loops
}

/// Canonicalise every loop: dedicated preheader, single latch, and
/// dedicated exit blocks (each exit block's predecessors are all inside the
/// loop). Returns the number of edits made.
pub fn canonicalize(f: &mut Function) -> usize {
    let mut edits = 0;
    // Iterate to a fixed point: splitting edges invalidates loop info.
    loop {
        let loops = find_loops(f);
        let mut changed = false;
        for l in &loops {
            // 1. Dedicated preheader: exactly one out-of-loop predecessor
            //    of the header, and that predecessor has a single successor.
            let preds = f.preds();
            let outside: Vec<BlockId> = preds[l.header.0 as usize]
                .iter()
                .copied()
                .filter(|p| !l.contains(*p))
                .collect();
            let needs_preheader = outside.len() != 1
                || f.succs(outside[0]).len() != 1;
            if needs_preheader && !outside.is_empty() {
                // Split every entering edge onto a fresh preheader chain:
                // split one edge, loop again.
                let from = outside[0];
                split_edge(f, from, l.header);
                edits += 1;
                changed = true;
                break;
            }
            // 2. Single latch: if several, split each back edge then merge.
            if l.latches.len() > 1 {
                // Insert a shared latch block: all back edges jump to it.
                let shared = f.add_block(format!("{}.latch", f.block(l.header).name));
                f.set_term(shared, Term::Jump(l.header));
                for &latch in &l.latches {
                    let mut term = f.block(latch).term.clone();
                    term.map_succs(|s| if s == l.header { shared } else { s });
                    f.block_mut(latch).term = term;
                }
                edits += 1;
                changed = true;
                break;
            }
            // 3. Dedicated exits: every exit block must have only in-loop
            //    predecessors.
            let preds = f.preds();
            for &x in &l.exits {
                let mixed = preds[x.0 as usize].iter().any(|p| !l.contains(*p));
                if mixed {
                    // Split each in-loop edge into x via a dedicated block.
                    let from = *preds[x.0 as usize].iter().find(|p| l.contains(**p)).unwrap();
                    split_edge(f, from, x);
                    edits += 1;
                    changed = true;
                    break;
                }
            }
            if changed {
                break;
            }
        }
        if !changed {
            return edits;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::Operand;

    /// while-loop shape: entry -> h; h -> body | exit; body -> h.
    fn simple_loop() -> (Function, BlockId, BlockId, BlockId) {
        let mut f = Function::new("k");
        let e = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let x = f.add_block("x");
        f.set_term(e, Term::Jump(h));
        f.set_term(h, Term::Br { cond: Operand::cbool(true), t: body, f: x });
        f.set_term(body, Term::Jump(h));
        f.set_term(x, Term::Ret);
        (f, h, body, x)
    }

    #[test]
    fn finds_simple_loop() {
        let (f, h, body, x) = simple_loop();
        let loops = find_loops(&f);
        assert_eq!(loops.len(), 1);
        let l = &loops[0];
        assert_eq!(l.header, h);
        assert_eq!(l.latches, vec![body]);
        assert!(l.contains(body));
        assert!(!l.contains(x));
        assert_eq!(l.exits, vec![x]);
        assert_eq!(l.depth, 1);
    }

    #[test]
    fn nested_loop_depths() {
        // e -> h1; h1 -> h2|x; h2 -> b2|l1; b2 -> h2 ; l1 -> h1
        let mut f = Function::new("k");
        let e = f.entry;
        let h1 = f.add_block("h1");
        let h2 = f.add_block("h2");
        let b2 = f.add_block("b2");
        let l1 = f.add_block("l1");
        let x = f.add_block("x");
        f.set_term(e, Term::Jump(h1));
        f.set_term(h1, Term::Br { cond: Operand::cbool(true), t: h2, f: x });
        f.set_term(h2, Term::Br { cond: Operand::cbool(true), t: b2, f: l1 });
        f.set_term(b2, Term::Jump(h2));
        f.set_term(l1, Term::Jump(h1));
        f.set_term(x, Term::Ret);
        let loops = find_loops(&f);
        assert_eq!(loops.len(), 2);
        assert_eq!(loops[0].header, h1);
        assert_eq!(loops[0].depth, 1);
        assert_eq!(loops[1].header, h2);
        assert_eq!(loops[1].depth, 2);
    }

    #[test]
    fn canonicalize_inserts_preheader() {
        let (mut f, h, _body, _x) = simple_loop();
        canonicalize(&mut f);
        let loops = find_loops(&f);
        let l = loops.iter().find(|l| l.header == h).unwrap();
        let ph = l.preheader(&f).expect("preheader exists");
        assert_eq!(f.succs(ph), vec![h]);
    }

    #[test]
    fn canonicalize_merges_latches() {
        // Loop with two latches.
        let mut f = Function::new("k");
        let e = f.entry;
        let h = f.add_block("h");
        let b1 = f.add_block("b1");
        let b2 = f.add_block("b2");
        let x = f.add_block("x");
        f.set_term(e, Term::Jump(h));
        f.set_term(h, Term::Br { cond: Operand::cbool(true), t: b1, f: x });
        f.set_term(b1, Term::Br { cond: Operand::cbool(true), t: h, f: b2 });
        f.set_term(b2, Term::Jump(h));
        f.set_term(x, Term::Ret);
        canonicalize(&mut f);
        let loops = find_loops(&f);
        let l = loops.iter().find(|l| l.header == h).unwrap();
        assert_eq!(l.latches.len(), 1, "latches merged");
    }

    #[test]
    fn canonicalize_is_idempotent() {
        let (mut f, _h, _b, _x) = simple_loop();
        canonicalize(&mut f);
        let edits = canonicalize(&mut f);
        assert_eq!(edits, 0);
    }
}
