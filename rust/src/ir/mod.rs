//! Kernel IR: the LLVM-IR analog the kernel compiler operates on.
//!
//! See `inst` for the core invariant (block-local registers) and `cfg` for
//! the paper's `CreateSubgraph`/`ReplicateCFG` helpers (§4.2).

pub mod cfg;
pub mod dom;
pub mod func;
pub mod inst;
pub mod loops;
pub mod print;
pub mod types;
pub mod verify;

pub use func::{AllocaInfo, Block, Function, Module, Param, WiLoopMeta};
pub use inst::{BarrierKind, BinOp, BlockId, Imm, Inst, MathFn, Operand, Reg, SlotId, Term, UnOp, WiFn};
pub use types::{AddrSpace, Scalar, Type};
