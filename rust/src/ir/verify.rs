//! IR verifier: checks the structural invariants the kernel compiler
//! depends on. Run after the frontend and after every kcc pass (tests do,
//! the pipeline does in debug builds).

use std::collections::HashSet;

use super::cfg::reachable;
use super::func::Function;
use super::inst::{Inst, Operand, Term};
use crate::cl::error::{Error, Result};

/// Verify `f`, returning the first violated invariant.
///
/// Checked invariants:
/// 1. Block ids in terminators are in range.
/// 2. Every register use is dominated by its def **within the same block**
///    (the block-locality invariant; see `ir::inst` module docs).
/// 3. No register is defined twice.
/// 4. Slot and argument references are in range.
/// 5. Branch conditions are registers, immediates, or args (not slots).
/// 6. Every reachable block's terminator targets reachable code (trivially
///    true by construction; kept as a sanity check).
pub fn verify(f: &Function) -> Result<()> {
    let nblocks = f.blocks.len() as u32;
    let mut defined: HashSet<u32> = HashSet::new();
    for bb in f.block_ids() {
        let block = f.block(bb);
        let mut local: HashSet<u32> = HashSet::new();
        for (idx, (def, inst)) in block.insts.iter().enumerate() {
            for op in inst.operands() {
                check_operand(f, bb, idx, &local, &op)?;
            }
            if let Some(r) = def {
                if !defined.insert(r.0) {
                    return Err(Error::Verify(format!(
                        "register r{} defined twice (block {} `{}`)",
                        r.0, bb.0, block.name
                    )));
                }
                local.insert(r.0);
            }
            // Result-type/def consistency.
            let has_result = inst.result_ty() != super::types::Type::Void;
            if has_result != def.is_some() {
                return Err(Error::Verify(format!(
                    "instruction {idx} in block `{}` result/def mismatch",
                    block.name
                )));
            }
        }
        match &block.term {
            Term::Jump(t) => {
                if t.0 >= nblocks {
                    return Err(Error::Verify(format!("jump target {} out of range", t.0)));
                }
            }
            Term::Br { cond, t, f: fb } => {
                if t.0 >= nblocks || fb.0 >= nblocks {
                    return Err(Error::Verify("branch target out of range".into()));
                }
                if let Operand::Reg(r) = cond {
                    if !local.contains(&r.0) {
                        return Err(Error::Verify(format!(
                            "branch condition r{} not defined in block `{}`",
                            r.0, block.name
                        )));
                    }
                }
            }
            Term::Ret => {}
        }
    }
    Ok(())
}

fn check_operand(
    f: &Function,
    bb: super::inst::BlockId,
    idx: usize,
    local: &HashSet<u32>,
    op: &Operand,
) -> Result<()> {
    match op {
        Operand::Reg(r) => {
            if !local.contains(&r.0) {
                return Err(Error::Verify(format!(
                    "use of r{} in block {} `{}` inst {} before/without block-local def \
                     (register temporaries must not cross blocks)",
                    r.0,
                    bb.0,
                    f.block(bb).name,
                    idx
                )));
            }
        }
        Operand::Slot(s) => {
            if s.0 as usize >= f.slots.len() {
                return Err(Error::Verify(format!("slot s{} out of range", s.0)));
            }
        }
        Operand::Arg(a) => {
            if *a as usize >= f.params.len() {
                return Err(Error::Verify(format!("arg {} out of range", a)));
            }
        }
        Operand::Imm(_) => {}
    }
    Ok(())
}

/// Count barriers over reachable blocks (test/diagnostic helper).
pub fn barrier_count(f: &Function) -> usize {
    reachable(f)
        .iter()
        .map(|&b| f.block(b).insts.iter().filter(|(_, i)| matches!(i, Inst::Barrier { .. })).count())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::{BinOp, Reg};
    use crate::ir::types::Type;

    #[test]
    fn accepts_block_local_dataflow() {
        let mut f = Function::new("k");
        let e = f.entry;
        let r = f.push_val(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::ci32(1), b: Operand::ci32(2) },
        );
        f.push(
            e,
            Inst::Bin { op: BinOp::Mul, ty: Type::I32, a: Operand::Reg(r), b: Operand::ci32(3) },
        );
        assert!(verify(&f).is_ok());
    }

    #[test]
    fn rejects_cross_block_register_use() {
        let mut f = Function::new("k");
        let e = f.entry;
        let b = f.add_block("b");
        let r = f.push_val(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::ci32(1), b: Operand::ci32(2) },
        );
        f.set_term(e, Term::Jump(b));
        f.push(
            b,
            Inst::Bin { op: BinOp::Mul, ty: Type::I32, a: Operand::Reg(r), b: Operand::ci32(3) },
        );
        assert!(verify(&f).is_err());
    }

    #[test]
    fn rejects_use_before_def_in_block() {
        let mut f = Function::new("k");
        let e = f.entry;
        f.push(
            e,
            Inst::Bin { op: BinOp::Mul, ty: Type::I32, a: Operand::Reg(Reg(99)), b: Operand::ci32(3) },
        );
        assert!(verify(&f).is_err());
    }

    #[test]
    fn rejects_out_of_range_targets() {
        let mut f = Function::new("k");
        let e = f.entry;
        f.set_term(e, Term::Jump(super::super::inst::BlockId(42)));
        assert!(verify(&f).is_err());
    }

    #[test]
    fn rejects_out_of_range_slot() {
        let mut f = Function::new("k");
        let e = f.entry;
        f.push(
            e,
            Inst::Load { ty: Type::I32, ptr: Operand::Slot(super::super::inst::SlotId(7)) },
        );
        assert!(verify(&f).is_err());
    }
}
