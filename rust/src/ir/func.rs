//! Functions, basic blocks, alloca slots, and modules.

use std::collections::HashMap;

use super::inst::{BlockId, Inst, Operand, Reg, SlotId, Term};
use super::types::{AddrSpace, Type};

/// One basic block: a branchless instruction sequence plus a terminator.
#[derive(Debug, Clone)]
pub struct Block {
    /// Human-readable label (unique-ified by the printer, not the IR).
    pub name: String,
    /// Instructions with their (optional) result registers.
    pub insts: Vec<(Option<Reg>, Inst)>,
    /// The single terminator.
    pub term: Term,
}

impl Block {
    /// True if any instruction in the block is a barrier.
    pub fn has_barrier(&self) -> bool {
        self.insts.iter().any(|(_, i)| i.is_barrier())
    }
}

/// A private variable ("alloca"): a per-work-item stack slot.
#[derive(Debug, Clone)]
pub struct AllocaInfo {
    /// Source-level name (for diagnostics and the printer).
    pub name: String,
    /// Element type.
    pub ty: Type,
    /// Array length in elements (1 for scalar variables).
    pub count: usize,
    /// Set by the privatisation pass (§4.7): the slot's lifetime crosses a
    /// parallel-region boundary, so it is expanded into a *context array*
    /// with one element per work-item.
    pub privatized: bool,
    /// Set by the uniformity analysis: the value is identical for all
    /// work-items, so a single shared slot suffices (uniform merging, §4.7).
    pub uniform: bool,
}

/// A function parameter. Kernel arguments keep their OpenCL address-space
/// qualified types; the work-group function generation appends extra
/// context parameters (group ids, sizes) per §4.1.
#[derive(Debug, Clone)]
pub struct Param {
    /// Parameter name.
    pub name: String,
    /// Parameter type.
    pub ty: Type,
    /// True if this is a `__local` pointer argument whose buffer the host
    /// (or launcher) must allocate — including converted automatic locals.
    pub is_local_buf: bool,
    /// For converted automatic locals (§4.7): required size in bytes.
    pub auto_local_size: Option<usize>,
}

/// A kernel function as an explicit control-flow graph.
#[derive(Debug, Clone)]
pub struct Function {
    /// Kernel name.
    pub name: String,
    /// Parameters (kernel args first, then appended context args).
    pub params: Vec<Param>,
    /// All blocks; ids index this vector. Blocks never get removed, only
    /// unreachable (the verifier reports reachability separately).
    pub blocks: Vec<Block>,
    /// Entry block id.
    pub entry: BlockId,
    /// Private variable slots.
    pub slots: Vec<AllocaInfo>,
    /// Next fresh register number.
    next_reg: u32,
    /// Work-item loop metadata (filled by `kcc::wiloops`): the analog of
    /// pocl's `llvm.mem.parallel_loop_access` — each entry marks one
    /// materialised WI loop whose iterations are independent.
    pub wi_loops: Vec<WiLoopMeta>,
}

/// Metadata describing one materialised parallel work-item loop (§4.1:
/// "the parallel loops are annotated with LLVM metadata that retains the
/// information of the parallel iterations for later phases").
#[derive(Debug, Clone)]
pub struct WiLoopMeta {
    /// Which parallel region this loop iterates (index into the
    /// `WorkGroupFunction::regions` list).
    pub region: usize,
    /// Loop dimension (0 = x innermost, 1 = y, 2 = z).
    pub dim: u32,
    /// Loop header block.
    pub header: BlockId,
    /// Loop latch block.
    pub latch: BlockId,
    /// Trip count if specialised for a known local size.
    pub trip_count: Option<usize>,
    /// Always true — kept explicit to mirror the metadata the paper
    /// describes (a later pass must not have to re-prove independence).
    pub parallel: bool,
}

impl Function {
    /// New empty function with an entry block.
    pub fn new(name: impl Into<String>) -> Function {
        Function {
            name: name.into(),
            params: Vec::new(),
            blocks: vec![Block { name: "entry".into(), insts: Vec::new(), term: Term::Ret }],
            entry: BlockId(0),
            slots: Vec::new(),
            next_reg: 0,
            wi_loops: Vec::new(),
        }
    }

    /// Rebuild a function from its serialized parts (`cache::poclbin`
    /// deserialization). `reg_count` restores the fresh-register
    /// high-water mark so engines size their frames correctly and later
    /// `fresh_reg` calls never collide with deserialized registers.
    #[allow(clippy::too_many_arguments)]
    pub fn from_raw_parts(
        name: String,
        params: Vec<Param>,
        blocks: Vec<Block>,
        entry: BlockId,
        slots: Vec<AllocaInfo>,
        reg_count: u32,
        wi_loops: Vec<WiLoopMeta>,
    ) -> Function {
        Function { name, params, blocks, entry, slots, next_reg: reg_count, wi_loops }
    }

    /// Access a block.
    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id.0 as usize]
    }

    /// Access a block mutably.
    pub fn block_mut(&mut self, id: BlockId) -> &mut Block {
        &mut self.blocks[id.0 as usize]
    }

    /// Append a new empty block and return its id.
    pub fn add_block(&mut self, name: impl Into<String>) -> BlockId {
        let id = BlockId(self.blocks.len() as u32);
        self.blocks.push(Block { name: name.into(), insts: Vec::new(), term: Term::Ret });
        id
    }

    /// All block ids (including unreachable ones).
    pub fn block_ids(&self) -> impl Iterator<Item = BlockId> {
        (0..self.blocks.len() as u32).map(BlockId)
    }

    /// Allocate a fresh virtual register.
    pub fn fresh_reg(&mut self) -> Reg {
        let r = Reg(self.next_reg);
        self.next_reg += 1;
        r
    }

    /// Current register high-water mark (for engines sizing frames).
    pub fn reg_count(&self) -> u32 {
        self.next_reg
    }

    /// Add a private variable slot.
    pub fn add_slot(&mut self, name: impl Into<String>, ty: Type, count: usize) -> SlotId {
        let id = SlotId(self.slots.len() as u32);
        self.slots.push(AllocaInfo { name: name.into(), ty, count, privatized: false, uniform: false });
        id
    }

    /// Append `inst` to block `bb`; returns the result register if the
    /// instruction produces a value.
    pub fn push(&mut self, bb: BlockId, inst: Inst) -> Option<Reg> {
        let reg = if inst.result_ty() == Type::Void { None } else { Some(self.fresh_reg()) };
        self.block_mut(bb).insts.push((reg, inst));
        reg
    }

    /// Append `inst` and unwrap the result register (panics on void).
    pub fn push_val(&mut self, bb: BlockId, inst: Inst) -> Reg {
        self.push(bb, inst).expect("instruction produces no value")
    }

    /// Set the terminator of `bb`.
    pub fn set_term(&mut self, bb: BlockId, term: Term) {
        self.block_mut(bb).term = term;
    }

    /// Predecessor map (derived from terminators). Order is deterministic
    /// (by block id, then successor order).
    pub fn preds(&self) -> Vec<Vec<BlockId>> {
        let mut preds = vec![Vec::new(); self.blocks.len()];
        for id in self.block_ids() {
            for s in self.block(id).term.succs() {
                preds[s.0 as usize].push(id);
            }
        }
        preds
    }

    /// Successors of a block.
    pub fn succs(&self, id: BlockId) -> Vec<BlockId> {
        self.block(id).term.succs()
    }

    /// All blocks containing at least one barrier instruction.
    pub fn barrier_blocks(&self) -> Vec<BlockId> {
        self.block_ids().filter(|&b| self.block(b).has_barrier()).collect()
    }

    /// Exit blocks (terminator = Ret), in id order.
    pub fn exit_blocks(&self) -> Vec<BlockId> {
        self.block_ids().filter(|&b| matches!(self.block(b).term, Term::Ret)).collect()
    }

    /// Total instruction count over reachable blocks (used by stats/tests).
    pub fn inst_count(&self) -> usize {
        super::cfg::reachable(self).iter().map(|&b| self.block(b).insts.len()).sum()
    }
}

/// Address-space of a pointer-typed operand as far as the type system
/// knows. Slots are always `Private`; arguments carry their own space.
pub fn operand_space(f: &Function, op: &Operand) -> Option<AddrSpace> {
    match op {
        Operand::Slot(_) => Some(AddrSpace::Private),
        Operand::Arg(i) => match &f.params.get(*i as usize)?.ty {
            Type::Ptr(_, sp) => Some(*sp),
            _ => None,
        },
        _ => None,
    }
}

/// A translation unit: the set of kernels produced from one MiniCL source
/// string (the analog of an LLVM module produced by Clang).
#[derive(Debug, Clone, Default)]
pub struct Module {
    /// Kernels by definition order.
    pub kernels: Vec<Function>,
}

impl Module {
    /// Find a kernel by name.
    pub fn kernel(&self, name: &str) -> Option<&Function> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// Kernel names in definition order.
    pub fn kernel_names(&self) -> Vec<&str> {
        self.kernels.iter().map(|k| k.name.as_str()).collect()
    }
}

/// Remap helper used by `ReplicateCFG`/tail duplication: rewrites the
/// registers of a cloned block so clones define fresh registers. Because
/// registers are block-local (IR invariant), the map never needs to span
/// blocks.
pub fn remap_block_regs(f: &mut Function, bb: BlockId) {
    let mut map: HashMap<Reg, Reg> = HashMap::new();
    // Two phases to satisfy the borrow checker: compute fresh names first.
    let n = f.block(bb).insts.len();
    for i in 0..n {
        // Remap operands through defs seen so far.
        let mut inst = f.block(bb).insts[i].1.clone();
        for op in inst.operands_mut() {
            if let Operand::Reg(r) = op {
                if let Some(nr) = map.get(r) {
                    *op = Operand::Reg(*nr);
                }
            }
        }
        let old = f.block(bb).insts[i].0;
        let fresh = old.map(|_| f.fresh_reg());
        if let (Some(o), Some(fr)) = (old, fresh) {
            map.insert(o, fr);
        }
        f.block_mut(bb).insts[i] = (fresh, inst);
    }
    // Terminator condition may reference a remapped register.
    let mut term = f.block(bb).term.clone();
    if let Term::Br { cond, .. } = &mut term {
        if let Operand::Reg(r) = cond {
            if let Some(nr) = map.get(r) {
                *cond = Operand::Reg(*nr);
            }
        }
    }
    f.block_mut(bb).term = term;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::{BinOp, Imm};
    use crate::ir::types::Scalar;

    fn add_inst() -> Inst {
        Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::ci32(1), b: Operand::ci32(2) }
    }

    #[test]
    fn push_assigns_registers() {
        let mut f = Function::new("k");
        let e = f.entry;
        let r = f.push(e, add_inst());
        assert!(r.is_some());
        let s = f.push(
            e,
            Inst::Store { ty: Type::I32, ptr: Operand::Slot(SlotId(0)), val: Operand::Reg(r.unwrap()) },
        );
        assert!(s.is_none());
    }

    #[test]
    fn preds_and_succs() {
        let mut f = Function::new("k");
        let a = f.entry;
        let b = f.add_block("b");
        let c = f.add_block("c");
        f.set_term(a, Term::Br { cond: Operand::cbool(true), t: b, f: c });
        f.set_term(b, Term::Jump(c));
        let preds = f.preds();
        assert_eq!(preds[c.0 as usize], vec![a, b]);
        assert_eq!(f.succs(a), vec![b, c]);
        assert_eq!(f.exit_blocks(), vec![c]);
    }

    #[test]
    fn remap_block_regs_freshens_defs_and_uses() {
        let mut f = Function::new("k");
        let e = f.entry;
        let r0 = f.push_val(e, add_inst());
        let _r1 = f.push_val(
            e,
            Inst::Bin { op: BinOp::Mul, ty: Type::I32, a: Operand::Reg(r0), b: Operand::Imm(Imm::Int(3, Scalar::I32)) },
        );
        let before = f.reg_count();
        remap_block_regs(&mut f, e);
        assert_eq!(f.reg_count(), before + 2);
        // The use of r0 in the second instruction must point at the fresh def.
        let def0 = f.block(e).insts[0].0.unwrap();
        match f.block(e).insts[1].1 {
            Inst::Bin { a: Operand::Reg(r), .. } => assert_eq!(r, def0),
            _ => panic!(),
        }
        assert_ne!(def0, r0);
    }
}
