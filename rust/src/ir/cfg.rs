//! CFG utilities the paper's algorithms are written against (§4.2):
//! depth-first traversal, `CreateSubgraph`, `ReplicateCFG`, edge splitting,
//! and single-exit normalisation.

use std::collections::{HashMap, HashSet};

use super::func::{remap_block_regs, Function};
use super::inst::{BlockId, Term};

/// Blocks reachable from the entry, in depth-first preorder.
pub fn reachable(f: &Function) -> Vec<BlockId> {
    let mut order = Vec::new();
    let mut seen = HashSet::new();
    let mut stack = vec![f.entry];
    while let Some(b) = stack.pop() {
        if !seen.insert(b) {
            continue;
        }
        order.push(b);
        // Push successors in reverse so traversal visits them in order.
        for s in f.succs(b).into_iter().rev() {
            stack.push(s);
        }
    }
    order
}

/// Reverse postorder over reachable blocks (the canonical iteration order
/// for forward dataflow).
pub fn reverse_postorder(f: &Function) -> Vec<BlockId> {
    let mut post = Vec::new();
    let mut seen = HashSet::new();
    // Iterative DFS with an explicit "visit children first" state machine.
    let mut stack: Vec<(BlockId, usize)> = vec![(f.entry, 0)];
    seen.insert(f.entry);
    while let Some(&mut (b, ref mut i)) = stack.last_mut() {
        let succs = f.succs(b);
        if *i < succs.len() {
            let s = succs[*i];
            *i += 1;
            if seen.insert(s) {
                stack.push((s, 0));
            }
        } else {
            post.push(b);
            stack.pop();
        }
    }
    post.reverse();
    post
}

/// The paper's `CreateSubgraph(A, B)`: all nodes that can be visited on a
/// path from `entry` to `exit`, ignoring back edges to already-visited
/// nodes (so loops inside the region are included without looping forever).
///
/// Implemented, as in the paper, with a depth-first search from `entry`
/// recording every node on any path reaching `exit`. A node belongs to the
/// subgraph iff it is reachable from `entry` without passing through `exit`
/// (plus `exit` itself) *and* it can reach `exit`.
pub fn create_subgraph(f: &Function, entry: BlockId, exit: BlockId) -> Vec<BlockId> {
    // Forward reachability from entry, not traversing past `exit`.
    let mut fwd = HashSet::new();
    let mut stack = vec![entry];
    while let Some(b) = stack.pop() {
        if !fwd.insert(b) {
            continue;
        }
        if b == exit {
            continue;
        }
        for s in f.succs(b) {
            stack.push(s);
        }
    }
    // Backward reachability from exit over the predecessor relation,
    // restricted to `fwd` (don't escape upstream of entry).
    let preds = f.preds();
    let mut bwd = HashSet::new();
    let mut stack = vec![exit];
    while let Some(b) = stack.pop() {
        if !bwd.insert(b) {
            continue;
        }
        if b == entry {
            continue;
        }
        for &p in &preds[b.0 as usize] {
            if fwd.contains(&p) {
                stack.push(p);
            }
        }
    }
    let mut nodes: Vec<BlockId> = fwd.intersection(&bwd).copied().collect();
    nodes.sort();
    nodes
}

/// The paper's `ReplicateCFG`: clone the given sub-CFG (blocks and their
/// internal edges). Edges leaving the set keep pointing at the original
/// targets — exactly the "copy of B keeps B's edge to C" property of §4.2.
///
/// Returns the old→new block map. Cloned blocks get fresh registers
/// (registers are block-local, so remapping is per-block).
pub fn replicate_cfg(f: &mut Function, nodes: &[BlockId]) -> HashMap<BlockId, BlockId> {
    let set: HashSet<BlockId> = nodes.iter().copied().collect();
    let mut map = HashMap::new();
    for &b in nodes {
        let mut clone = f.block(b).clone();
        clone.name = format!("{}.dup", clone.name);
        let nb = BlockId(f.blocks.len() as u32);
        f.blocks.push(clone);
        map.insert(b, nb);
    }
    // Rewire internal edges and freshen registers.
    for &b in nodes {
        let nb = map[&b];
        let mut term = f.block(nb).term.clone();
        term.map_succs(|s| if set.contains(&s) { map[&s] } else { s });
        f.block_mut(nb).term = term;
        remap_block_regs(f, nb);
    }
    map
}

/// Split the edge `from → to` by inserting a fresh empty block. Returns the
/// new block. Needed for loop canonicalisation (preheaders, latch merging).
pub fn split_edge(f: &mut Function, from: BlockId, to: BlockId) -> BlockId {
    let name = format!("{}.{}.split", f.block(from).name, f.block(to).name);
    let mid = f.add_block(name);
    f.set_term(mid, Term::Jump(to));
    let mut term = f.block(from).term.clone();
    term.map_succs(|s| if s == to { mid } else { s });
    f.block_mut(from).term = term;
    mid
}

/// Normalise the function to a single exit block: if several blocks return,
/// make them jump to one fresh `exit` block (§4.3: "a single exit point ...
/// can be achieved by a normalization transformation").
pub fn unify_exits(f: &mut Function) -> BlockId {
    let exits = f.exit_blocks();
    let reach: HashSet<BlockId> = reachable(f).into_iter().collect();
    let live: Vec<BlockId> = exits.into_iter().filter(|b| reach.contains(b)).collect();
    if live.len() == 1 {
        return live[0];
    }
    let exit = f.add_block("exit");
    f.set_term(exit, Term::Ret);
    for b in live {
        f.set_term(b, Term::Jump(exit));
    }
    exit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::Operand;

    /// Build the diamond a → {b,c} → d.
    fn diamond() -> (Function, BlockId, BlockId, BlockId, BlockId) {
        let mut f = Function::new("k");
        let a = f.entry;
        let b = f.add_block("b");
        let c = f.add_block("c");
        let d = f.add_block("d");
        f.set_term(a, Term::Br { cond: Operand::cbool(true), t: b, f: c });
        f.set_term(b, Term::Jump(d));
        f.set_term(c, Term::Jump(d));
        (f, a, b, c, d)
    }

    #[test]
    fn rpo_visits_entry_first_exit_last() {
        let (f, a, _, _, d) = diamond();
        let rpo = reverse_postorder(&f);
        assert_eq!(rpo[0], a);
        assert_eq!(*rpo.last().unwrap(), d);
        assert_eq!(rpo.len(), 4);
    }

    #[test]
    fn subgraph_of_diamond_is_whole() {
        let (f, a, b, c, d) = diamond();
        let sub = create_subgraph(&f, a, d);
        assert_eq!(sub, vec![a, b, c, d]);
    }

    #[test]
    fn subgraph_excludes_off_path_nodes() {
        let (mut f, a, b, _c, d) = diamond();
        // Hang a side block off b that doesn't reach d.
        let side = f.add_block("side");
        f.set_term(side, Term::Ret);
        f.set_term(b, Term::Br { cond: Operand::cbool(true), t: d, f: side });
        let sub = create_subgraph(&f, a, d);
        assert!(!sub.contains(&side));
        assert!(sub.contains(&b));
    }

    #[test]
    fn subgraph_includes_loops() {
        let mut f = Function::new("k");
        let a = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let x = f.add_block("x");
        f.set_term(a, Term::Jump(h));
        f.set_term(h, Term::Br { cond: Operand::cbool(true), t: body, f: x });
        f.set_term(body, Term::Jump(h));
        f.set_term(x, Term::Ret);
        let sub = create_subgraph(&f, a, x);
        assert!(sub.contains(&body));
        assert_eq!(sub.len(), 4);
    }

    #[test]
    fn replicate_keeps_external_edges() {
        let (mut f, _a, b, c, d) = diamond();
        let map = replicate_cfg(&mut f, &[b]);
        let nb = map[&b];
        // Clone's edge still points at d (outside the replicated set).
        assert_eq!(f.succs(nb), vec![d]);
        // Original untouched.
        assert_eq!(f.succs(b), vec![d]);
        assert_eq!(f.succs(c), vec![d]);
    }

    #[test]
    fn replicate_rewires_internal_edges() {
        let (mut f, _a, b, _c, d) = diamond();
        let map = replicate_cfg(&mut f, &[b, d]);
        assert_eq!(f.succs(map[&b]), vec![map[&d]]);
    }

    #[test]
    fn split_edge_preserves_path() {
        let (mut f, a, b, _c, _d) = diamond();
        let mid = split_edge(&mut f, a, b);
        assert!(f.succs(a).contains(&mid));
        assert_eq!(f.succs(mid), vec![b]);
    }

    #[test]
    fn unify_exits_single() {
        let mut f = Function::new("k");
        let a = f.entry;
        let b = f.add_block("b");
        let c = f.add_block("c");
        f.set_term(a, Term::Br { cond: Operand::cbool(true), t: b, f: c });
        // both b and c return
        let exit = unify_exits(&mut f);
        assert_eq!(f.exit_blocks(), vec![exit]);
        assert_eq!(f.succs(b), vec![exit]);
    }
}
