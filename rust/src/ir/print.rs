//! Textual IR printer — for debugging, docs, and golden tests.

use std::fmt::Write;

use super::func::Function;
use super::inst::{BinOp, Imm, Inst, Operand, Term, UnOp};

/// Render a function as readable text.
pub fn print_function(f: &Function) -> String {
    let mut out = String::new();
    let params: Vec<String> =
        f.params.iter().map(|p| format!("{} %{}", p.ty, p.name)).collect();
    let _ = writeln!(out, "kernel @{}({}) {{", f.name, params.join(", "));
    for (i, slot) in f.slots.iter().enumerate() {
        let mut flags = String::new();
        if slot.privatized {
            flags.push_str(" privatized");
        }
        if slot.uniform {
            flags.push_str(" uniform");
        }
        let _ = writeln!(out, "  slot s{} : {} x{}{}   ; {}", i, slot.ty, slot.count, flags, slot.name);
    }
    for id in f.block_ids() {
        let b = f.block(id);
        let _ = writeln!(out, "bb{} ({}):", id.0, b.name);
        for (def, inst) in &b.insts {
            let lhs = match def {
                Some(r) => format!("  r{} = ", r.0),
                None => "  ".to_string(),
            };
            let _ = writeln!(out, "{}{}", lhs, fmt_inst(inst));
        }
        let term = match &b.term {
            Term::Jump(t) => format!("  jump bb{}", t.0),
            Term::Br { cond, t, f } => format!("  br {}, bb{}, bb{}", fmt_op(cond), t.0, f.0),
            Term::Ret => "  ret".to_string(),
        };
        let _ = writeln!(out, "{term}");
    }
    // WI-loop metadata footer (the "parallel loop" annotations).
    for m in &f.wi_loops {
        let _ = writeln!(
            out,
            "; wi_loop region={} dim={} header=bb{} latch=bb{} trip={:?} parallel={}",
            m.region, m.dim, m.header.0, m.latch.0, m.trip_count, m.parallel
        );
    }
    out.push_str("}\n");
    out
}

fn fmt_op(op: &Operand) -> String {
    match op {
        Operand::Reg(r) => format!("r{}", r.0),
        Operand::Imm(Imm::Int(v, s)) => format!("{v}:{s:?}"),
        Operand::Imm(Imm::Float(v, s)) => format!("{v}:{s:?}"),
        Operand::Arg(a) => format!("%arg{a}"),
        Operand::Slot(s) => format!("&s{}", s.0),
    }
}

fn fmt_inst(inst: &Inst) -> String {
    match inst {
        Inst::Bin { op, ty, a, b } => {
            format!("{} {} {}, {}", bin_name(*op), ty, fmt_op(a), fmt_op(b))
        }
        Inst::Un { op, ty, a } => format!("{} {} {}", un_name(*op), ty, fmt_op(a)),
        Inst::Cast { to, from, a } => format!("cast {} -> {} {}", from, to, fmt_op(a)),
        Inst::Load { ty, ptr } => format!("load {} {}", ty, fmt_op(ptr)),
        Inst::Store { ty, ptr, val } => format!("store {} {}, {}", ty, fmt_op(val), fmt_op(ptr)),
        Inst::Gep { elem, base, idx } => format!("gep {} {}, {}", elem, fmt_op(base), fmt_op(idx)),
        Inst::Wi { func, dim } => format!("wi {:?}({dim})", func),
        Inst::Math { func, ty, args } => {
            let a: Vec<String> = args.iter().map(fmt_op).collect();
            format!("math {:?} {} {}", func, ty, a.join(", "))
        }
        Inst::Select { ty, cond, a, b } => {
            format!("select {} {}, {}, {}", ty, fmt_op(cond), fmt_op(a), fmt_op(b))
        }
        Inst::VecBuild { ty, elems } => {
            let a: Vec<String> = elems.iter().map(fmt_op).collect();
            format!("vecbuild {} ({})", ty, a.join(", "))
        }
        Inst::VecExtract { elem, a, lane } => format!("extract {} {}[{}]", elem, fmt_op(a), lane),
        Inst::VecInsert { ty, a, lane, v } => {
            format!("insert {} {}[{}] = {}", ty, fmt_op(a), lane, fmt_op(v))
        }
        Inst::Splat { ty, a } => format!("splat {} {}", ty, fmt_op(a)),
        Inst::Barrier { kind } => format!("barrier ({kind:?})"),
        Inst::Marker { label } => format!("marker {label}"),
    }
}

fn bin_name(op: BinOp) -> &'static str {
    match op {
        BinOp::Add => "add",
        BinOp::Sub => "sub",
        BinOp::Mul => "mul",
        BinOp::Div => "div",
        BinOp::Rem => "rem",
        BinOp::And => "and",
        BinOp::Or => "or",
        BinOp::Xor => "xor",
        BinOp::Shl => "shl",
        BinOp::Shr => "shr",
        BinOp::Eq => "cmpeq",
        BinOp::Ne => "cmpne",
        BinOp::Lt => "cmplt",
        BinOp::Le => "cmple",
        BinOp::Gt => "cmpgt",
        BinOp::Ge => "cmpge",
        BinOp::LAnd => "land",
        BinOp::LOr => "lor",
    }
}

fn un_name(op: UnOp) -> &'static str {
    match op {
        UnOp::Neg => "neg",
        UnOp::Not => "not",
        UnOp::LNot => "lnot",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::BinOp;
    use crate::ir::types::Type;

    #[test]
    fn prints_blocks_and_regs() {
        let mut f = Function::new("k");
        let e = f.entry;
        f.push(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::ci32(1), b: Operand::ci32(2) },
        );
        let s = print_function(&f);
        assert!(s.contains("kernel @k"));
        assert!(s.contains("r0 = add int 1:I32, 2:I32"));
        assert!(s.contains("ret"));
    }
}
