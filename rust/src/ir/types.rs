//! Type system of the kernel IR: OpenCL C scalar, vector and pointer types.

use std::fmt;

/// Scalar element types. The subset covers everything the AMD APP SDK-style
/// suite kernels need (OpenCL `char/uchar` omitted; `half` unsupported like
/// in base OpenCL 1.2 without `cl_khr_fp16`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scalar {
    /// `bool` — result of comparisons; not addressable in OpenCL C.
    Bool,
    /// `int` — 32-bit signed.
    I32,
    /// `uint` — 32-bit unsigned.
    U32,
    /// `long` — 64-bit signed.
    I64,
    /// `ulong` / `size_t` — 64-bit unsigned.
    U64,
    /// `float` — IEEE binary32.
    F32,
    /// `double` — IEEE binary64 (`cl_khr_fp64`).
    F64,
}

impl Scalar {
    /// Byte size of the scalar.
    pub fn size(self) -> usize {
        match self {
            Scalar::Bool => 1,
            Scalar::I32 | Scalar::U32 | Scalar::F32 => 4,
            Scalar::I64 | Scalar::U64 | Scalar::F64 => 8,
        }
    }
    /// True for `float`/`double`.
    pub fn is_float(self) -> bool {
        matches!(self, Scalar::F32 | Scalar::F64)
    }
    /// True for any integer (including bool).
    pub fn is_int(self) -> bool {
        !self.is_float()
    }
    /// True for signed integers.
    pub fn is_signed(self) -> bool {
        matches!(self, Scalar::I32 | Scalar::I64)
    }
}

/// OpenCL disjoint address spaces (§2 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AddrSpace {
    /// `__global` — device global memory, shared by all work-items.
    Global,
    /// `__local` — per-work-group scratchpad.
    Local,
    /// `__constant` — read-only global data.
    Constant,
    /// `__private` — per-work-item stack data (allocas).
    Private,
}

impl AddrSpace {
    /// Qualifier spelling used by the printer.
    pub fn keyword(self) -> &'static str {
        match self {
            AddrSpace::Global => "__global",
            AddrSpace::Local => "__local",
            AddrSpace::Constant => "__constant",
            AddrSpace::Private => "__private",
        }
    }
}

/// Full IR types.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Type {
    /// No value (void returns, store results).
    Void,
    /// Scalar value.
    Scalar(Scalar),
    /// Short vector `elem x lanes`, lanes ∈ {2,3,4,8,16}.
    Vec(Scalar, u8),
    /// Pointer to `elem` values in an address space. Element type is scalar
    /// or vector (OpenCL C pointers-to-pointers are not needed by the suite).
    Ptr(Box<Type>, AddrSpace),
}

impl Type {
    /// `float` shorthand.
    pub const F32: Type = Type::Scalar(Scalar::F32);
    /// `int` shorthand.
    pub const I32: Type = Type::Scalar(Scalar::I32);
    /// `uint` shorthand.
    pub const U32: Type = Type::Scalar(Scalar::U32);
    /// `bool` shorthand.
    pub const BOOL: Type = Type::Scalar(Scalar::Bool);
    /// `size_t` shorthand.
    pub const U64: Type = Type::Scalar(Scalar::U64);

    /// Pointer-to-self in the given address space.
    pub fn ptr(self, space: AddrSpace) -> Type {
        Type::Ptr(Box::new(self), space)
    }

    /// Element scalar type of a scalar or vector type.
    pub fn elem_scalar(&self) -> Option<Scalar> {
        match self {
            Type::Scalar(s) => Some(*s),
            Type::Vec(s, _) => Some(*s),
            _ => None,
        }
    }

    /// Lane count: 1 for scalars, N for vectors.
    pub fn lanes(&self) -> usize {
        match self {
            Type::Vec(_, n) => *n as usize,
            _ => 1,
        }
    }

    /// Byte size of a value of this type (pointers are 8 bytes; vec3 is
    /// padded to 4 lanes per the OpenCL spec).
    pub fn size(&self) -> usize {
        match self {
            Type::Void => 0,
            Type::Scalar(s) => s.size(),
            Type::Vec(s, n) => s.size() * if *n == 3 { 4 } else { *n as usize },
            Type::Ptr(..) => 8,
        }
    }

    /// True if scalar or vector of floats.
    pub fn is_float(&self) -> bool {
        self.elem_scalar().map(|s| s.is_float()).unwrap_or(false)
    }

    /// True if scalar or vector of (signed or unsigned) integers.
    pub fn is_int(&self) -> bool {
        self.elem_scalar().map(|s| s.is_int()).unwrap_or(false)
    }

    /// True for pointer types.
    pub fn is_ptr(&self) -> bool {
        matches!(self, Type::Ptr(..))
    }

    /// With the same shape (scalar/vector lane count) but a new element.
    pub fn with_elem(&self, s: Scalar) -> Type {
        match self {
            Type::Vec(_, n) => Type::Vec(s, *n),
            _ => Type::Scalar(s),
        }
    }
}

impl fmt::Display for Type {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Type::Void => write!(f, "void"),
            Type::Scalar(s) => write!(f, "{}", scalar_name(*s)),
            Type::Vec(s, n) => write!(f, "{}{}", scalar_name(*s), n),
            Type::Ptr(e, sp) => write!(f, "{} {}*", sp.keyword(), e),
        }
    }
}

fn scalar_name(s: Scalar) -> &'static str {
    match s {
        Scalar::Bool => "bool",
        Scalar::I32 => "int",
        Scalar::U32 => "uint",
        Scalar::I64 => "long",
        Scalar::U64 => "ulong",
        Scalar::F32 => "float",
        Scalar::F64 => "double",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(Type::F32.size(), 4);
        assert_eq!(Type::Vec(Scalar::F32, 4).size(), 16);
        assert_eq!(Type::Vec(Scalar::F32, 3).size(), 16); // vec3 padded
        assert_eq!(Type::F32.ptr(AddrSpace::Global).size(), 8);
    }

    #[test]
    fn display() {
        assert_eq!(Type::Vec(Scalar::F32, 4).to_string(), "float4");
        assert_eq!(
            Type::U32.ptr(AddrSpace::Local).to_string(),
            "__local uint*"
        );
    }

    #[test]
    fn classification() {
        assert!(Type::F32.is_float());
        assert!(Type::Vec(Scalar::I32, 8).is_int());
        assert!(!Type::F32.ptr(AddrSpace::Global).is_float());
        assert_eq!(Type::Vec(Scalar::F32, 8).with_elem(Scalar::U32), Type::Vec(Scalar::U32, 8));
    }
}
