//! Dominator tree (Cooper–Harvey–Kennedy "a simple, fast dominance
//! algorithm"). Used for barrier classification (a barrier is
//! *unconditional* iff it dominates the exit node — §4.3) and natural-loop
//! detection.

use std::collections::HashMap;

use super::cfg::reverse_postorder;
use super::func::Function;
use super::inst::BlockId;

/// Immediate-dominator table over reachable blocks.
pub struct DomTree {
    /// `idom[b]` for every reachable block; the entry maps to itself.
    idom: HashMap<BlockId, BlockId>,
    /// Reverse postorder index used for intersection.
    rpo_index: HashMap<BlockId, usize>,
    entry: BlockId,
}

impl DomTree {
    /// Compute dominators for `f`.
    pub fn compute(f: &Function) -> DomTree {
        let rpo = reverse_postorder(f);
        let rpo_index: HashMap<BlockId, usize> =
            rpo.iter().enumerate().map(|(i, &b)| (b, i)).collect();
        let preds = f.preds();
        let mut idom: HashMap<BlockId, BlockId> = HashMap::new();
        idom.insert(f.entry, f.entry);
        let mut changed = true;
        while changed {
            changed = false;
            for &b in rpo.iter().skip(1) {
                // First processed predecessor.
                let mut new_idom: Option<BlockId> = None;
                for &p in &preds[b.0 as usize] {
                    if !rpo_index.contains_key(&p) {
                        continue; // unreachable predecessor
                    }
                    if idom.contains_key(&p) {
                        new_idom = Some(match new_idom {
                            None => p,
                            Some(cur) => intersect(&idom, &rpo_index, p, cur),
                        });
                    }
                }
                if let Some(ni) = new_idom {
                    if idom.get(&b) != Some(&ni) {
                        idom.insert(b, ni);
                        changed = true;
                    }
                }
            }
        }
        DomTree { idom, rpo_index, entry: f.entry }
    }

    /// Immediate dominator of `b` (entry's idom is entry itself).
    pub fn idom(&self, b: BlockId) -> Option<BlockId> {
        self.idom.get(&b).copied()
    }

    /// Does `a` dominate `b`? (Reflexive.) Unreachable blocks dominate
    /// nothing and are dominated by nothing.
    pub fn dominates(&self, a: BlockId, b: BlockId) -> bool {
        if !self.idom.contains_key(&a) || !self.idom.contains_key(&b) {
            return false;
        }
        let mut cur = b;
        loop {
            if cur == a {
                return true;
            }
            if cur == self.entry {
                return false;
            }
            cur = self.idom[&cur];
        }
    }

    /// True if the block is reachable (has a dominator entry).
    pub fn is_reachable(&self, b: BlockId) -> bool {
        self.idom.contains_key(&b)
    }
}

fn intersect(
    idom: &HashMap<BlockId, BlockId>,
    rpo_index: &HashMap<BlockId, usize>,
    mut a: BlockId,
    mut b: BlockId,
) -> BlockId {
    while a != b {
        while rpo_index[&a] > rpo_index[&b] {
            a = idom[&a];
        }
        while rpo_index[&b] > rpo_index[&a] {
            b = idom[&b];
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::{Operand, Term};

    #[test]
    fn diamond_dominance() {
        let mut f = Function::new("k");
        let a = f.entry;
        let b = f.add_block("b");
        let c = f.add_block("c");
        let d = f.add_block("d");
        f.set_term(a, Term::Br { cond: Operand::cbool(true), t: b, f: c });
        f.set_term(b, Term::Jump(d));
        f.set_term(c, Term::Jump(d));
        let dom = DomTree::compute(&f);
        assert!(dom.dominates(a, d));
        assert!(!dom.dominates(b, d));
        assert!(dom.dominates(d, d));
        assert_eq!(dom.idom(d), Some(a));
        assert_eq!(dom.idom(b), Some(a));
    }

    #[test]
    fn loop_dominance() {
        // a -> h; h -> body|x; body -> h
        let mut f = Function::new("k");
        let a = f.entry;
        let h = f.add_block("h");
        let body = f.add_block("body");
        let x = f.add_block("x");
        f.set_term(a, Term::Jump(h));
        f.set_term(h, Term::Br { cond: Operand::cbool(true), t: body, f: x });
        f.set_term(body, Term::Jump(h));
        f.set_term(x, Term::Ret);
        let dom = DomTree::compute(&f);
        assert!(dom.dominates(h, body));
        assert!(dom.dominates(h, x));
        assert!(!dom.dominates(body, x));
    }

    #[test]
    fn unreachable_blocks() {
        let mut f = Function::new("k");
        let dead = f.add_block("dead");
        let dom = DomTree::compute(&f);
        assert!(!dom.is_reachable(dead));
        assert!(!dom.dominates(dead, f.entry));
    }
}
