//! Instructions, operands, and builtins of the kernel IR.
//!
//! The IR is three-address form over virtual registers with one crucial
//! structural invariant (enforced by the verifier, relied upon by the whole
//! kernel compiler): **register temporaries never cross basic-block
//! boundaries**. All cross-block dataflow goes through `Alloca` slots via
//! `Load`/`Store`. This mirrors clang's pre-mem2reg output that pocl's
//! privatisation operates on, and makes `ReplicateCFG`/tail duplication a
//! simple block-local register remap.

use super::types::{Scalar, Type};

/// A virtual register id, local to a function.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Reg(pub u32);

/// A basic block id, local to a function.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// An alloca slot id (private variable), local to a function.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SlotId(pub u32);

/// Immediate constant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Imm {
    /// Integer constant with its scalar type (Bool encoded 0/1).
    Int(i64, Scalar),
    /// Floating constant with its scalar type.
    Float(f64, Scalar),
}

impl Imm {
    /// The immediate's type.
    pub fn ty(&self) -> Type {
        match self {
            Imm::Int(_, s) | Imm::Float(_, s) => Type::Scalar(*s),
        }
    }
}

/// Instruction operand: a register, an immediate, or a kernel argument.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Operand {
    /// Virtual register defined earlier in the same block.
    Reg(Reg),
    /// Immediate constant.
    Imm(Imm),
    /// Kernel/work-group function argument by index.
    Arg(u32),
    /// Address of a private alloca slot (base pointer).
    Slot(SlotId),
}

impl Operand {
    /// i32 immediate shorthand.
    pub fn ci32(v: i32) -> Operand {
        Operand::Imm(Imm::Int(v as i64, Scalar::I32))
    }
    /// u32 immediate shorthand.
    pub fn cu32(v: u32) -> Operand {
        Operand::Imm(Imm::Int(v as i64, Scalar::U32))
    }
    /// u64 immediate shorthand.
    pub fn cu64(v: u64) -> Operand {
        Operand::Imm(Imm::Int(v as i64, Scalar::U64))
    }
    /// f32 immediate shorthand.
    pub fn cf32(v: f32) -> Operand {
        Operand::Imm(Imm::Float(v as f64, Scalar::F32))
    }
    /// bool immediate shorthand.
    pub fn cbool(v: bool) -> Operand {
        Operand::Imm(Imm::Int(v as i64, Scalar::Bool))
    }
}

/// Binary operators. Comparison ops produce `bool` (or bool vectors).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    /// Short-circuit-free logical and (bool operands).
    LAnd,
    /// Short-circuit-free logical or (bool operands).
    LOr,
}

impl BinOp {
    /// True if the result type is bool-shaped regardless of operand type.
    pub fn is_cmp(self) -> bool {
        matches!(self, BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge)
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    /// Arithmetic negation.
    Neg,
    /// Bitwise complement.
    Not,
    /// Logical not (bool).
    LNot,
}

/// Work-item index functions (OpenCL §6.12.1). Kept symbolic in the IR so
/// the WI-loop materialiser can rewrite `LocalId` to loop induction
/// variables and devices can bind the rest from launch geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WiFn {
    GlobalId,
    LocalId,
    GroupId,
    GlobalSize,
    LocalSize,
    NumGroups,
    WorkDim,
    GlobalOffset,
}

/// Math and misc builtin functions, implemented by `vecmath` in every
/// engine (the paper's §5 Vecmathlib role).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MathFn {
    Sqrt,
    RSqrt,
    Exp,
    Exp2,
    Log,
    Log2,
    Sin,
    Cos,
    Tan,
    Fabs,
    Floor,
    Ceil,
    Round,
    Trunc,
    Pow,
    Fmin,
    Fmax,
    Fmod,
    Mad,
    Fma,
    Min,
    Max,
    Clamp,
    Abs,
    Mix,
    Dot,
    Length,
    Normalize,
    Distance,
    NativeSqrt,
    NativeRSqrt,
    NativeExp,
    NativeLog,
    NativeSin,
    NativeCos,
    NativeDivide,
    NativeRecip,
}

impl MathFn {
    /// Number of value arguments the builtin takes.
    pub fn arity(self) -> usize {
        use MathFn::*;
        match self {
            Pow | Fmin | Fmax | Fmod | Min | Max | Dot | Distance | NativeDivide => 2,
            Mad | Fma | Clamp | Mix => 3,
            _ => 1,
        }
    }
}

/// Instructions. Each instruction optionally defines one register (see
/// `Inst::result_ty`).
#[derive(Debug, Clone, PartialEq)]
pub enum Inst {
    /// `dst = a <op> b` on `ty`-typed operands (comparisons yield bool-shaped `ty`).
    Bin { op: BinOp, ty: Type, a: Operand, b: Operand },
    /// `dst = <op> a`.
    Un { op: UnOp, ty: Type, a: Operand },
    /// `dst = (to) a` — numeric conversion / pointer cast.
    Cast { to: Type, from: Type, a: Operand },
    /// `dst = load ty, ptr` (ptr's address space recorded for the engines).
    Load { ty: Type, ptr: Operand },
    /// `store val, ptr`. No result.
    Store { ty: Type, ptr: Operand, val: Operand },
    /// `dst = ptr + idx * sizeof(elem)` — element pointer (GEP).
    Gep { elem: Type, base: Operand, idx: Operand },
    /// `dst = wi_fn(dim)` — work-item geometry query.
    Wi { func: WiFn, dim: u32 },
    /// `dst = math_fn(args...)` over scalar or vector `ty`.
    Math { func: MathFn, ty: Type, args: Vec<Operand> },
    /// `dst = cond ? a : b` (lane-wise for vector cond).
    Select { ty: Type, cond: Operand, a: Operand, b: Operand },
    /// `dst = (ty)(elems...)` — build a vector from scalars/subvectors.
    VecBuild { ty: Type, elems: Vec<Operand> },
    /// `dst = a.s[lane]` — extract one lane.
    VecExtract { elem: Type, a: Operand, lane: u32 },
    /// `dst = a with lane = v`.
    VecInsert { ty: Type, a: Operand, lane: u32, v: Operand },
    /// `dst = splat(a)` to vector `ty`.
    Splat { ty: Type, a: Operand },
    /// Work-group barrier (CLK_LOCAL_MEM_FENCE | CLK_GLOBAL_MEM_FENCE).
    /// `kind` distinguishes programmer barriers from compiler-inserted
    /// implicit ones (§4.5) — useful for debugging and tests.
    Barrier { kind: BarrierKind },
    /// No-op marker carrying a label; used by tests and the TTA scheduler
    /// to delimit traces. Never affects semantics.
    Marker { label: u32 },
}

/// Provenance of a barrier instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BarrierKind {
    /// Written by the programmer (`barrier(...)` call).
    Explicit,
    /// Inserted by the b-loop handling (§4.5) or horizontal
    /// parallelisation (§4.6).
    Implicit,
}

impl Inst {
    /// The type of the defined register, or `Void` if none.
    pub fn result_ty(&self) -> Type {
        match self {
            Inst::Bin { op, ty, .. } => {
                if op.is_cmp() {
                    ty.with_elem(Scalar::Bool)
                } else {
                    ty.clone()
                }
            }
            Inst::Un { ty, .. } => ty.clone(),
            Inst::Cast { to, .. } => to.clone(),
            Inst::Load { ty, .. } => ty.clone(),
            Inst::Store { .. } => Type::Void,
            Inst::Gep { elem, base: _, .. } => {
                // The result is a pointer to elem; the address space is that
                // of the base, which the verifier tracks. For result typing
                // purposes Private is a placeholder refined by context.
                elem.clone().ptr(super::types::AddrSpace::Private)
            }
            Inst::Wi { .. } => Type::U64,
            Inst::Math { ty, .. } => ty.clone(),
            Inst::Select { ty, .. } => ty.clone(),
            Inst::VecBuild { ty, .. } => ty.clone(),
            Inst::VecExtract { elem, .. } => elem.clone(),
            Inst::VecInsert { ty, .. } => ty.clone(),
            Inst::Splat { ty, .. } => ty.clone(),
            Inst::Barrier { .. } | Inst::Marker { .. } => Type::Void,
        }
    }

    /// True for barrier instructions.
    pub fn is_barrier(&self) -> bool {
        matches!(self, Inst::Barrier { .. })
    }

    /// Visit all operand slots (for remapping during replication).
    pub fn operands_mut(&mut self) -> Vec<&mut Operand> {
        match self {
            Inst::Bin { a, b, .. } => vec![a, b],
            Inst::Un { a, .. } => vec![a],
            Inst::Cast { a, .. } => vec![a],
            Inst::Load { ptr, .. } => vec![ptr],
            Inst::Store { ptr, val, .. } => vec![ptr, val],
            Inst::Gep { base, idx, .. } => vec![base, idx],
            Inst::Wi { .. } => vec![],
            Inst::Math { args, .. } => args.iter_mut().collect(),
            Inst::Select { cond, a, b, .. } => vec![cond, a, b],
            Inst::VecBuild { elems, .. } => elems.iter_mut().collect(),
            Inst::VecExtract { a, .. } => vec![a],
            Inst::VecInsert { a, v, .. } => vec![a, v],
            Inst::Splat { a, .. } => vec![a],
            Inst::Barrier { .. } | Inst::Marker { .. } => vec![],
        }
    }

    /// Visit all operands (read-only).
    pub fn operands(&self) -> Vec<Operand> {
        match self {
            Inst::Bin { a, b, .. } => vec![*a, *b],
            Inst::Un { a, .. } => vec![*a],
            Inst::Cast { a, .. } => vec![*a],
            Inst::Load { ptr, .. } => vec![*ptr],
            Inst::Store { ptr, val, .. } => vec![*ptr, *val],
            Inst::Gep { base, idx, .. } => vec![*base, *idx],
            Inst::Wi { .. } => vec![],
            Inst::Math { args, .. } => args.clone(),
            Inst::Select { cond, a, b, .. } => vec![*cond, *a, *b],
            Inst::VecBuild { elems, .. } => elems.clone(),
            Inst::VecExtract { a, .. } => vec![*a],
            Inst::VecInsert { a, v, .. } => vec![*a, *v],
            Inst::Splat { a, .. } => vec![*a],
            Inst::Barrier { .. } | Inst::Marker { .. } => vec![],
        }
    }
}

/// Block terminators. Every block has exactly one.
#[derive(Debug, Clone, PartialEq)]
pub enum Term {
    /// Unconditional jump.
    Jump(BlockId),
    /// Two-way conditional branch on a bool operand.
    Br { cond: Operand, t: BlockId, f: BlockId },
    /// Return from the kernel (kernels are void).
    Ret,
}

impl Term {
    /// Successor block ids in order.
    pub fn succs(&self) -> Vec<BlockId> {
        match self {
            Term::Jump(b) => vec![*b],
            Term::Br { t, f, .. } => vec![*t, *f],
            Term::Ret => vec![],
        }
    }

    /// Remap successor ids through `f`.
    pub fn map_succs(&mut self, mut f: impl FnMut(BlockId) -> BlockId) {
        match self {
            Term::Jump(b) => *b = f(*b),
            Term::Br { t, f: fb, .. } => {
                *t = f(*t);
                *fb = f(*fb);
            }
            Term::Ret => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmp_results_are_bool_shaped() {
        let i = Inst::Bin { op: BinOp::Lt, ty: Type::Vec(Scalar::F32, 4), a: Operand::ci32(0), b: Operand::ci32(1) };
        assert_eq!(i.result_ty(), Type::Vec(Scalar::Bool, 4));
    }

    #[test]
    fn term_succs() {
        let t = Term::Br { cond: Operand::cbool(true), t: BlockId(1), f: BlockId(2) };
        assert_eq!(t.succs(), vec![BlockId(1), BlockId(2)]);
        assert_eq!(Term::Ret.succs(), vec![]);
    }

    #[test]
    fn math_arity() {
        assert_eq!(MathFn::Mad.arity(), 3);
        assert_eq!(MathFn::Pow.arity(), 2);
        assert_eq!(MathFn::Sqrt.arity(), 1);
    }
}
