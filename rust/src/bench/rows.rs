//! Row printers matching the paper's figures/tables.

use super::BenchResult;

/// Print a figure-style grouped bar row: one workload, several
/// implementations (ms, lower is better), plus ratios vs the first.
pub fn figure_row(workload: &str, results: &[(&str, &BenchResult)]) {
    let base = results[0].1.ms();
    let cells: Vec<String> = results
        .iter()
        .map(|(label, r)| format!("{label}={:.2}ms ({:.2}x)", r.ms(), r.ms() / base))
        .collect();
    println!("{workload:<22} {}", cells.join("  "));
}

/// Print a Table 3/4-style row: implementation, per-call cycles.
pub fn cycles_row(ty: &str, width: usize, imp: &str, overhead: f64, cols: &[(&str, f64)]) {
    let cells: Vec<String> = cols.iter().map(|(n, c)| format!("{n}={c:.1}")).collect();
    println!("{ty:<7} x{width:<3} {imp:<10} overhead={overhead:<6.1} {}", cells.join("  "));
}
