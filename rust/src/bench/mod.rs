//! Measurement harness (criterion is unavailable offline; this follows
//! the same warmup + repeated-sampling + robust-statistics method).

pub mod harness;
pub mod rows;

pub use harness::{bench_fn, BenchResult};

pub mod figures;
