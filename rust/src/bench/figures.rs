//! Shared driver for the Fig. 12/13/14 suite comparisons: run every suite
//! application on a set of device configurations, verify, and print the
//! grouped rows (execution time, smaller is better — like the paper's
//! bars).

use std::sync::Arc;
use std::time::Duration;

use crate::devices::Device;
use crate::suite::{all_apps, runner, SizeClass};

use super::{bench_fn, rows, BenchResult};

/// Run the whole suite across `configs`; the native baseline is always
/// measured and printed first (the proprietary-vendor stand-in).
pub fn run_suite_figure(title: &str, configs: &[(&str, Arc<dyn Device>)]) {
    println!("== {title} ==");
    println!("(medians; first column is the baseline the ratios compare to)\n");
    let budget = Duration::from_millis(
        std::env::var("POCLRS_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(300),
    );
    for app in all_apps(SizeClass::Bench) {
        // Correctness gate first: a mis-verifying config is reported, not
        // silently timed.
        let mut results: Vec<(&str, BenchResult)> = Vec::new();
        let native = bench_fn(format!("{}/native", app.name), 1, 15, budget, || {
            let _ = app.run_native();
        });
        results.push(("native", native));
        let mut failed = Vec::new();
        for (label, device) in configs {
            match runner::run_and_verify(&app, device.clone()) {
                Ok(_) => {
                    let r = bench_fn(format!("{}/{label}", app.name), 1, 15, budget, || {
                        let _ = runner::run_on_device(&app, device.clone()).unwrap();
                    });
                    results.push((label, r));
                }
                Err(e) => failed.push(format!("{label}: {e}")),
            }
        }
        let refs: Vec<(&str, &BenchResult)> =
            results.iter().map(|(l, r)| (*l, r)).collect();
        rows::figure_row(app.name, &refs);
        for f in failed {
            println!("{:<22} FAILED {f}", app.name);
        }
    }
}
