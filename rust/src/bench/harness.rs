//! Timing core: warm up, then sample until a time budget or sample count
//! is reached; report median + median-absolute-deviation.

use std::time::{Duration, Instant};

/// One benchmark's statistics.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Median sample duration.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Samples taken.
    pub samples: usize,
}

impl BenchResult {
    /// Milliseconds (median).
    pub fn ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
}

/// Benchmark a closure: `warmup` runs, then sample up to `max_samples`
/// or until `budget` elapses (at least 3 samples).
pub fn bench_fn(
    name: impl Into<String>,
    warmup: usize,
    max_samples: usize,
    budget: Duration,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let started = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < max_samples
        && (samples.len() < 3 || started.elapsed() < budget)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mut devs: Vec<Duration> = samples
        .iter()
        .map(|s| if *s > median { *s - median } else { median - *s })
        .collect();
    devs.sort();
    let mad = devs[devs.len() / 2];
    BenchResult { name: name.into(), median, mad, samples: samples.len() }
}
