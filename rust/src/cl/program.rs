//! Programs and kernels (`clCreateProgramWithSource` / `clBuildProgram` /
//! `clCreateKernel` / `clSetKernelArg` analogs), including the §4.1
//! enqueue-time work-group-function specialisation cache.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cl::context::Buffer;
use crate::cl::error::{Error, Result};
use crate::ir::Module;
use crate::kcc::{compile_workgroup, CompileOptions, WorkGroupFunction};

/// A built program: the IR module plus the per-local-size cache of
/// specialised work-group functions.
pub struct Program {
    /// Frontend output (single-work-item kernels).
    pub module: Module,
    cache: Mutex<HashMap<(String, [usize; 3], bool), Arc<WorkGroupFunction>>>,
    /// Cache statistics (tested by the §4.1 integration test).
    pub cache_hits: Mutex<usize>,
    /// Cache misses = actual compilations.
    pub cache_misses: Mutex<usize>,
}

impl Program {
    /// Build from MiniCL source (the `clBuildProgram` moment).
    pub fn build(source: &str) -> Result<Program> {
        let module = crate::frontend::compile(source)?;
        Ok(Program {
            module,
            cache: Mutex::new(HashMap::new()),
            cache_hits: Mutex::new(0),
            cache_misses: Mutex::new(0),
        })
    }

    /// Kernel names available in this program.
    pub fn kernel_names(&self) -> Vec<String> {
        self.module.kernels.iter().map(|k| k.name.clone()).collect()
    }

    /// Get (or compile) the work-group function for a kernel at a local
    /// size — "the work-group function generation is performed at kernel
    /// enqueue time, when the local size is known" (§4.1). One function is
    /// generated per local size; re-enqueues hit the cache.
    pub fn workgroup_function(
        &self,
        kernel: &str,
        local: [usize; 3],
        opts: &CompileOptions,
    ) -> Result<Arc<WorkGroupFunction>> {
        let key = (kernel.to_string(), local, opts.horizontal && !opts.spmd);
        if let Some(w) = self.cache.lock().unwrap().get(&key) {
            *self.cache_hits.lock().unwrap() += 1;
            return Ok(w.clone());
        }
        let k = self
            .module
            .kernel(kernel)
            .ok_or_else(|| Error::NotFound(format!("kernel `{kernel}`")))?;
        let wgf = Arc::new(compile_workgroup(k, local, opts)?);
        *self.cache_misses.lock().unwrap() += 1;
        self.cache.lock().unwrap().insert(key, wgf.clone());
        Ok(wgf)
    }
}

/// A kernel argument value set by the host.
#[derive(Debug, Clone)]
pub enum KernelArg {
    /// Global buffer.
    Buf(Buffer),
    /// `__local` buffer of the given byte size (clSetKernelArg with NULL).
    LocalSize(usize),
    /// 32-bit signed scalar.
    I32(i32),
    /// 32-bit unsigned scalar.
    U32(u32),
    /// 64-bit scalar (size_t).
    U64(u64),
    /// f32 scalar.
    F32(f32),
}

/// A kernel object with bound arguments (`cl_kernel` analog).
pub struct Kernel {
    /// Kernel name (must exist in the program).
    pub name: String,
    /// Bound arguments, indexed by position.
    pub args: Vec<Option<KernelArg>>,
}

impl Kernel {
    /// Create a kernel object for `name` with `nargs` settable arguments.
    pub fn new(program: &Program, name: &str) -> Result<Kernel> {
        let k = program
            .module
            .kernel(name)
            .ok_or_else(|| Error::NotFound(format!("kernel `{name}`")))?;
        // Count only the user-settable params (auto-locals are appended by
        // the frontend and bound automatically at enqueue).
        let nargs =
            k.params.iter().filter(|p| p.auto_local_size.is_none()).count();
        Ok(Kernel { name: name.to_string(), args: vec![None; nargs] })
    }

    /// Bind an argument (`clSetKernelArg`).
    pub fn set_arg(&mut self, index: usize, arg: KernelArg) -> Result<()> {
        if index >= self.args.len() {
            return Err(Error::invalid(format!(
                "arg index {index} out of range (kernel `{}` has {})",
                self.name,
                self.args.len()
            )));
        }
        self.args[index] = Some(arg);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = "__kernel void k(__global float *x, uint n) { x[get_global_id(0)] = (float)n; }";

    #[test]
    fn build_and_enumerate() {
        let p = Program::build(SRC).unwrap();
        assert_eq!(p.kernel_names(), vec!["k"]);
        assert!(Program::build("int broken").is_err());
    }

    #[test]
    fn specialization_cache_per_local_size() {
        let p = Program::build(SRC).unwrap();
        let opts = CompileOptions::default();
        let _ = p.workgroup_function("k", [8, 1, 1], &opts).unwrap();
        let _ = p.workgroup_function("k", [8, 1, 1], &opts).unwrap();
        let _ = p.workgroup_function("k", [16, 1, 1], &opts).unwrap();
        assert_eq!(*p.cache_misses.lock().unwrap(), 2, "one compile per local size");
        assert_eq!(*p.cache_hits.lock().unwrap(), 1);
    }

    #[test]
    fn kernel_arg_binding() {
        let p = Program::build(SRC).unwrap();
        let mut k = Kernel::new(&p, "k").unwrap();
        assert_eq!(k.args.len(), 2);
        k.set_arg(1, KernelArg::U32(7)).unwrap();
        assert!(k.set_arg(5, KernelArg::U32(0)).is_err());
        assert!(Kernel::new(&p, "missing").is_err());
    }
}
