//! Programs and kernels (`clCreateProgramWithSource` / `clBuildProgram` /
//! `clCreateProgramWithBinary` / `clCreateKernel` / `clSetKernelArg`
//! analogs), including the §4.1 enqueue-time work-group-function
//! specialisation cache.
//!
//! Specialisations are keyed by [`SpecKey`] — kernel name, local size,
//! and the **full** [`CompileOptions`] — so two devices that disagree on
//! any compile knob can never share an entry. Lookups go memory → disk
//! (when a [`DiskCache`] is attached) → compile, with compiled results
//! written back to disk; see the `cache` module docs for the flow.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cache::poclbin;
use crate::cache::{fnv128, CacheKey, DiskCache, SpecKey};
use crate::cl::context::Buffer;
use crate::cl::error::{Error, Result};
use crate::ir::Module;
use crate::kcc::{compile_workgroup, CompileOptions, WorkGroupFunction};

/// Specialisation-cache counters for one program (the §4.1 integration
/// tests and `run --stats` report these).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProgramCacheStats {
    /// Lookups served from the in-process map.
    pub memory_hits: usize,
    /// Lookups served by decoding a persistent `poclbin` entry.
    pub disk_hits: usize,
    /// Lookups that ran `compile_workgroup` (including entries that came
    /// pre-populated from neither source).
    pub misses: usize,
}

impl ProgramCacheStats {
    /// All lookups that avoided a compile.
    pub fn hits(&self) -> usize {
        self.memory_hits + self.disk_hits
    }
}

/// The cache map plus its counters behind one lock, so hit/miss counts
/// can never drift from the map contents.
struct ProgState {
    specs: HashMap<SpecKey, Arc<WorkGroupFunction>>,
    stats: ProgramCacheStats,
}

/// A built program: the IR module plus the specialisation cache of
/// work-group functions (in-memory always; persistent when a
/// [`DiskCache`] is attached).
pub struct Program {
    /// Frontend output (single-work-item kernels).
    pub module: Module,
    /// Digest of the source text (stable across processes; binary-built
    /// programs inherit it from their
    /// [`ProgramBinary`](crate::cache::poclbin::ProgramBinary)).
    source_hash: u128,
    /// Optional persistent kernel-binary cache (read-through/write-back).
    disk: Option<Arc<DiskCache>>,
    state: Mutex<ProgState>,
}

impl Program {
    /// Build from MiniCL source (the `clBuildProgram` moment), without a
    /// persistent cache: every specialisation is compiled at most once
    /// per program object.
    pub fn build(source: &str) -> Result<Program> {
        Program::build_cached(source, None)
    }

    /// Build from MiniCL source with an optional persistent cache.
    /// Specialisation lookups then read through to `disk` and compiled
    /// results are written back, so a later process (or a later program
    /// object) skips `compile_workgroup` entirely.
    pub fn build_cached(source: &str, disk: Option<Arc<DiskCache>>) -> Result<Program> {
        let module = crate::frontend::compile(source)?;
        Ok(Program {
            module,
            source_hash: fnv128(source.as_bytes()),
            disk,
            state: Mutex::new(ProgState {
                specs: HashMap::new(),
                stats: ProgramCacheStats::default(),
            }),
        })
    }

    /// Reconstruct a program from [`Program::binaries`] output — the
    /// `clCreateProgramWithBinary` analog. No frontend work happens: the
    /// module and every embedded specialisation are decoded directly,
    /// and the embedded specialisations are served as memory hits.
    pub fn from_binary(bytes: &[u8]) -> Result<Program> {
        Program::from_binary_cached(bytes, None)
    }

    /// [`Program::from_binary`] with a persistent cache attached; the
    /// source digest stored in the binary keeps disk keys identical to
    /// the source-built program's.
    pub fn from_binary_cached(bytes: &[u8], disk: Option<Arc<DiskCache>>) -> Result<Program> {
        let bin = poclbin::decode_program(bytes)?;
        let specs: HashMap<SpecKey, Arc<WorkGroupFunction>> = bin
            .entries
            .into_iter()
            .map(|(k, mut w)| {
                // Machine code is never serialised: re-lower the jit
                // tier from the decoded bytecode.
                crate::exec::jit::attach(&mut w, k.opts.gang_width);
                (k, Arc::new(w))
            })
            .collect();
        Ok(Program {
            module: bin.module,
            source_hash: bin.source_hash,
            disk,
            state: Mutex::new(ProgState { specs, stats: ProgramCacheStats::default() }),
        })
    }

    /// Export the program as a `poclbin` program binary: the IR module
    /// plus every specialisation cached so far (the
    /// `clGetProgramInfo(CL_PROGRAM_BINARIES)` analog). Feeding the
    /// bytes to [`Program::from_binary`] yields a program that performs
    /// zero compiles for the exported specialisations.
    pub fn binaries(&self) -> Vec<u8> {
        let state = self.state.lock().unwrap();
        let mut entries: Vec<(&SpecKey, &WorkGroupFunction)> =
            state.specs.iter().map(|(k, w)| (k, &**w)).collect();
        // Deterministic export order (HashMap iteration is not). SpecKey's
        // full Ord covers options too, so two entries sharing kernel and
        // local size still export in a stable order.
        entries.sort_by(|a, b| a.0.cmp(b.0));
        poclbin::encode_program_parts(self.source_hash, &self.module, &entries)
    }

    /// Kernel names available in this program.
    pub fn kernel_names(&self) -> Vec<String> {
        self.module.kernels.iter().map(|k| k.name.clone()).collect()
    }

    /// Cache counters so far.
    pub fn cache_stats(&self) -> ProgramCacheStats {
        self.state.lock().unwrap().stats
    }

    /// Digest of the program source (on-disk cache key component).
    pub fn source_hash(&self) -> u128 {
        self.source_hash
    }

    /// Snapshot of the cached specialisations, sorted by kernel name and
    /// local size (deterministic for reporting).
    pub fn cached_specializations(&self) -> Vec<(SpecKey, Arc<WorkGroupFunction>)> {
        let state = self.state.lock().unwrap();
        let mut out: Vec<(SpecKey, Arc<WorkGroupFunction>)> =
            state.specs.iter().map(|(k, w)| (k.clone(), w.clone())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Get (or compile) the work-group function for a kernel at a local
    /// size — "the work-group function generation is performed at kernel
    /// enqueue time, when the local size is known" (§4.1). One function
    /// is generated per (local size, compile options); re-enqueues hit
    /// the in-memory map, fresh processes hit the persistent cache.
    pub fn workgroup_function(
        &self,
        kernel: &str,
        local: [usize; 3],
        opts: &CompileOptions,
    ) -> Result<Arc<WorkGroupFunction>> {
        let spec = SpecKey { kernel: kernel.to_string(), local, opts: opts.clone() };
        let mut lookup = crate::trace::enabled().then(|| {
            crate::trace::span_args(
                crate::trace::CAT_CACHE,
                "lookup",
                vec![("kernel", crate::trace::ArgVal::s(kernel))],
            )
        });
        // One lock covers lookup, compile, and insert: counters stay
        // exact and concurrent enqueues never compile the same
        // specialisation twice.
        let mut state = self.state.lock().unwrap();
        if let Some(w) = state.specs.get(&spec) {
            let w = w.clone();
            state.stats.memory_hits += 1;
            crate::trace::metrics::add("cache.memory_hits", 1);
            if let Some(sp) = lookup.as_mut() {
                sp.arg("outcome", crate::trace::ArgVal::s("memory_hit"));
            }
            return Ok(w);
        }
        if let Some(disk) = &self.disk {
            let key = CacheKey::for_spec(self.source_hash, &spec);
            if let Some(mut wgf) = disk.load(key) {
                // Belt and braces against key collisions or shuffled
                // files: a served entry must actually be this kernel at
                // this local size, else fall through and recompile.
                if wgf.name == spec.kernel && wgf.local_size == spec.local {
                    // Jitted code is not part of the on-disk format;
                    // re-lower it from the cached bytecode.
                    {
                        let _jit_span =
                            crate::trace::span(crate::trace::CAT_COMPILER, "jit_emit");
                        crate::exec::jit::attach(&mut wgf, spec.opts.gang_width);
                    }
                    let wgf = Arc::new(wgf);
                    state.stats.disk_hits += 1;
                    state.specs.insert(spec, wgf.clone());
                    if let Some(sp) = lookup.as_mut() {
                        sp.arg("outcome", crate::trace::ArgVal::s("disk_hit"));
                    }
                    return Ok(wgf);
                }
            }
        }
        let k = self
            .module
            .kernel(kernel)
            .ok_or_else(|| Error::NotFound(format!("kernel `{kernel}`")))?;
        if let Some(sp) = lookup.as_mut() {
            sp.arg("outcome", crate::trace::ArgVal::s("compile"));
        }
        drop(lookup);
        let wgf = Arc::new(compile_workgroup(k, local, opts)?);
        state.stats.misses += 1;
        crate::trace::metrics::add("cache.compile_misses", 1);
        state.specs.insert(spec.clone(), wgf.clone());
        drop(state);
        // Write-back outside the lock; persistence is best-effort (a
        // full disk must not fail the enqueue).
        if let Some(disk) = &self.disk {
            let key = CacheKey::for_spec(self.source_hash, &spec);
            let _ = disk.store(key, &wgf);
        }
        Ok(wgf)
    }
}

/// A kernel argument value set by the host.
#[derive(Debug, Clone)]
pub enum KernelArg {
    /// Global buffer.
    Buf(Buffer),
    /// `__local` buffer of the given byte size (clSetKernelArg with NULL).
    LocalSize(usize),
    /// 32-bit signed scalar.
    I32(i32),
    /// 32-bit unsigned scalar.
    U32(u32),
    /// 64-bit scalar (size_t).
    U64(u64),
    /// f32 scalar.
    F32(f32),
}

/// A kernel object with bound arguments (`cl_kernel` analog).
pub struct Kernel {
    /// Kernel name (must exist in the program).
    pub name: String,
    /// Bound arguments, indexed by position.
    pub args: Vec<Option<KernelArg>>,
}

impl Kernel {
    /// Create a kernel object for `name` with `nargs` settable arguments.
    pub fn new(program: &Program, name: &str) -> Result<Kernel> {
        let k = program
            .module
            .kernel(name)
            .ok_or_else(|| Error::NotFound(format!("kernel `{name}`")))?;
        // Count only the user-settable params (auto-locals are appended by
        // the frontend and bound automatically at enqueue).
        let nargs =
            k.params.iter().filter(|p| p.auto_local_size.is_none()).count();
        Ok(Kernel { name: name.to_string(), args: vec![None; nargs] })
    }

    /// Bind an argument (`clSetKernelArg`).
    pub fn set_arg(&mut self, index: usize, arg: KernelArg) -> Result<()> {
        if index >= self.args.len() {
            return Err(Error::invalid(format!(
                "arg index {index} out of range (kernel `{}` has {})",
                self.name,
                self.args.len()
            )));
        }
        self.args[index] = Some(arg);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kcc::TargetKind;

    const SRC: &str = "__kernel void k(__global float *x, uint n) { x[get_global_id(0)] = (float)n; }";

    #[test]
    fn build_and_enumerate() {
        let p = Program::build(SRC).unwrap();
        assert_eq!(p.kernel_names(), vec!["k"]);
        assert!(Program::build("int broken").is_err());
    }

    #[test]
    fn specialization_cache_per_local_size() {
        let p = Program::build(SRC).unwrap();
        let opts = CompileOptions::default();
        let _ = p.workgroup_function("k", [8, 1, 1], &opts).unwrap();
        let _ = p.workgroup_function("k", [8, 1, 1], &opts).unwrap();
        let _ = p.workgroup_function("k", [16, 1, 1], &opts).unwrap();
        let s = p.cache_stats();
        assert_eq!(s.misses, 2, "one compile per local size");
        assert_eq!(s.memory_hits, 1);
        assert_eq!(s.disk_hits, 0, "no persistent cache attached");
    }

    #[test]
    fn full_options_split_cache_entries() {
        // The stale-cache regression: the old key was
        // (kernel, local, horizontal && !spmd), so options differing in
        // any other field shared one entry. Every field must split now.
        let p = Program::build(SRC).unwrap();
        let base = CompileOptions::default();
        let variants = [
            CompileOptions { horizontal: false, ..base.clone() },
            CompileOptions { work_dim: 2, ..base.clone() },
            CompileOptions { spmd: true, ..base.clone() },
            CompileOptions { target: TargetKind::Tta, ..base.clone() },
            CompileOptions { gang_width: 8, ..base.clone() },
            CompileOptions {
                opt_level: if base.opt_level == crate::kcc::OptLevel::O0 {
                    crate::kcc::OptLevel::O2
                } else {
                    crate::kcc::OptLevel::O0
                },
                ..base.clone()
            },
        ];
        let _ = p.workgroup_function("k", [8, 1, 1], &base).unwrap();
        for v in &variants {
            let _ = p.workgroup_function("k", [8, 1, 1], v).unwrap();
        }
        let s = p.cache_stats();
        assert_eq!(s.misses, 1 + variants.len(), "every option variant compiles separately");
        assert_eq!(s.memory_hits, 0);
        // Re-querying any variant hits.
        let _ = p.workgroup_function("k", [8, 1, 1], &variants[3]).unwrap();
        assert_eq!(p.cache_stats().memory_hits, 1);
    }

    #[test]
    fn binaries_roundtrip_without_recompiling() {
        let p = Program::build(SRC).unwrap();
        let opts = CompileOptions::default();
        let _ = p.workgroup_function("k", [8, 1, 1], &opts).unwrap();
        let _ = p.workgroup_function("k", [16, 1, 1], &opts).unwrap();
        let bytes = p.binaries();

        let q = Program::from_binary(&bytes).unwrap();
        assert_eq!(q.kernel_names(), vec!["k"]);
        assert_eq!(q.source_hash(), p.source_hash());
        let w = q.workgroup_function("k", [8, 1, 1], &opts).unwrap();
        assert_eq!(w.local_size, [8, 1, 1]);
        let _ = q.workgroup_function("k", [16, 1, 1], &opts).unwrap();
        let s = q.cache_stats();
        assert_eq!(s.misses, 0, "embedded specialisations: zero compiles");
        assert_eq!(s.memory_hits, 2);
        // A *new* local size still compiles from the embedded module.
        let _ = q.workgroup_function("k", [32, 1, 1], &opts).unwrap();
        assert_eq!(q.cache_stats().misses, 1);
        // Garbage input is rejected, not misinterpreted.
        assert!(matches!(Program::from_binary(b"junk"), Err(Error::BadBinary(_))));
    }

    #[test]
    fn kernel_arg_binding() {
        let p = Program::build(SRC).unwrap();
        let mut k = Kernel::new(&p, "k").unwrap();
        assert_eq!(k.args.len(), 2);
        k.set_arg(1, KernelArg::U32(7)).unwrap();
        assert!(k.set_arg(5, KernelArg::U32(0)).is_err());
        assert!(Kernel::new(&p, "missing").is_err());
    }
}
