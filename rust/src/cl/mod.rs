//! Host layer (§3, Fig. 2): the `cl*`-style API.
//!
//! `Platform` → `Context` (+ `Buffer` via Bufalloc) → `Program` (+ the
//! §4.1 specialisation cache, optionally persistent via `cache`) →
//! `Kernel` → `CommandQueue` (+ live `Event`s).
//!
//! Programs are built from source (`Program::build` /
//! `Program::build_cached`) or reconstructed from a `poclbin` program
//! binary (`Program::from_binary`, the `clCreateProgramWithBinary`
//! analog, paired with `Program::binaries`).
//!
//! # Command lifecycle
//!
//! The queue API is **deferred**: every `enqueue_*` call resolves its
//! arguments immediately (kernel launches compile/fetch their §4.1
//! work-group function here), wraps the work in a [`Command`], and
//! returns a live [`Event`]:
//!
//! ```text
//!   enqueue_*            flush()/wait()        scheduler         done
//!  ───────────▶ Queued ───────────────▶ Submitted ──▶ Running ──▶ Complete
//!                                                         ╲─────▶ Error
//! ```
//!
//! Commands form a dependency DAG through explicit wait-lists (the
//! `wait: &[Event]` parameter); [`Event::wait`] and
//! [`CommandQueue::finish`] block until completion, and events carry
//! OpenCL-style profiling timestamps for every transition.
//!
//! # Queue modes
//!
//! * [`QueueProperties::InOrder`] (default) — commands implicitly chain
//!   behind their predecessor: classic sequential OpenCL semantics.
//! * [`QueueProperties::OutOfOrder`] — all *ready* commands run
//!   concurrently on a worker pool; ordering comes only from wait-lists
//!   and [`CommandQueue::enqueue_barrier`] fences. Independent transfers
//!   and kernel launches overlap — see `examples/async_pipeline.rs`.
//!
//! Buffer reads deliver data through the event
//! ([`Event::wait_vec`]); the context's typed helpers
//! (`write_f32` & co.) remain as blocking conveniences that share the
//! same command implementations.

pub mod command;
pub mod context;
pub mod error;
pub mod event;
pub mod platform;
pub mod program;
pub mod queue;

pub use command::Command;
pub use context::{Buffer, Context, Scalar};
pub use error::{Error, Result};
pub use event::{CommandStatus, Event, EventProfile};
pub use platform::Platform;
pub use program::{Kernel, KernelArg, Program, ProgramCacheStats};
pub use queue::{CommandQueue, QueueProperties};
