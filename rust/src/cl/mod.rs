//! Host layer (§3, Fig. 2): the `cl*`-style API.
//!
//! `Platform` → `Context` (+ `Buffer` via Bufalloc) → `Program` (+ the
//! §4.1 per-local-size specialisation cache) → `Kernel` → `CommandQueue`
//! (+ profiling `Event`s).

pub mod context;
pub mod error;
pub mod platform;
pub mod program;
pub mod queue;

pub use context::{Buffer, Context};
pub use error::{Error, Result};
pub use platform::Platform;
pub use program::{Kernel, KernelArg, Program};
pub use queue::{CommandQueue, Event};
