//! Error type shared across the host API, kernel compiler, and devices.
//!
//! Mirrors the OpenCL error-code style (`CL_INVALID_VALUE`, ...) but as a
//! structured Rust enum so callers can match on failure classes.

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways a pocl-rs operation can fail.
#[derive(Debug, Clone)]
pub enum Error {
    /// Lexing / parsing failure in the MiniCL frontend (`CL_BUILD_PROGRAM_FAILURE`).
    Parse { line: u32, col: u32, msg: String },
    /// Semantic / type-checking failure in the frontend.
    Sema { line: u32, col: u32, msg: String },
    /// IR verification failure (compiler-internal invariant broken).
    Verify(String),
    /// Kernel-compiler pass failure.
    Compile(String),
    /// Runtime execution failure (trap in a kernel, OOB access, ...).
    Exec(String),
    /// Host API misuse (`CL_INVALID_*`).
    InvalidArg(String),
    /// Named entity (kernel, device, builtin) not found.
    NotFound(String),
    /// Buffer allocator out of space (`CL_MEM_OBJECT_ALLOCATION_FAILURE`).
    OutOfMemory { requested: usize, available: usize },
    /// PJRT / XLA runtime failure (wraps the `xla` crate's error text).
    Pjrt(String),
    /// I/O failure (artifact files, kernel sources).
    Io(String),
    /// Malformed, corrupt, or version-incompatible `poclbin` data
    /// (`CL_INVALID_BINARY`). The on-disk cache treats this as a miss;
    /// `Program::from_binary` surfaces it to the caller.
    BadBinary(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse { line, col, msg } => write!(f, "parse error at {line}:{col}: {msg}"),
            Error::Sema { line, col, msg } => write!(f, "semantic error at {line}:{col}: {msg}"),
            Error::Verify(m) => write!(f, "IR verification failed: {m}"),
            Error::Compile(m) => write!(f, "kernel compilation failed: {m}"),
            Error::Exec(m) => write!(f, "execution failed: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::NotFound(m) => write!(f, "not found: {m}"),
            Error::OutOfMemory { requested, available } => {
                write!(f, "out of device memory: requested {requested} B, {available} B available")
            }
            Error::Pjrt(m) => write!(f, "PJRT error: {m}"),
            Error::Io(m) => write!(f, "I/O error: {m}"),
            Error::BadBinary(m) => write!(f, "invalid program binary: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

impl Error {
    /// Shorthand for a compile-stage error.
    pub fn compile(msg: impl Into<String>) -> Self {
        Error::Compile(msg.into())
    }
    /// Shorthand for an execution-stage error.
    pub fn exec(msg: impl Into<String>) -> Self {
        Error::Exec(msg.into())
    }
    /// Shorthand for an invalid-argument error.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidArg(msg.into())
    }
}
