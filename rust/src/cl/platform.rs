//! Platform layer: device discovery (the `clGetPlatformIDs` /
//! `clGetDeviceIDs` analog).

use std::sync::Arc;

use crate::cl::error::{Error, Result};
use crate::devices::{
    basic::BasicDevice, native_gang_width, threaded::ThreadedDevice, ttasim::TtaSimDevice,
    Device, EngineKind,
};
use crate::sched::{DeviceGroup, Dynamic, SchedPolicy};

/// The pocl-rs platform: a named set of devices.
pub struct Platform {
    /// Platform name.
    pub name: &'static str,
    /// Available devices.
    pub devices: Vec<Arc<dyn Device>>,
}

impl Platform {
    /// The default platform with the device set used throughout §6:
    /// `basic` (serial), `pthread` (threaded gang, AVX2-width), narrow-SIMD
    /// variants (NEON/AltiVec width), lane-batched vector-gang and
    /// threaded-bytecode devices at the host-detected width, a fiber
    /// baseline device, the TTA simulator, and a heterogeneous
    /// `multidev` group (serial + vector-gang + bytecode members under
    /// the dynamic scheduler — see `sched`). The `pjrt` device is added
    /// separately because it needs artifacts (see `devices::pjrt`).
    pub fn default_platform() -> Platform {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let vw = native_gang_width();
        let multidev_members: Vec<Arc<dyn Device>> = vec![
            Arc::new(BasicDevice::new(EngineKind::Serial)),
            Arc::new(BasicDevice::new(EngineKind::GangVector(vw))),
            Arc::new(BasicDevice::new(EngineKind::Bytecode(vw))),
        ];
        let multidev =
            DeviceGroup::new("multidev", multidev_members, Arc::new(Dynamic::new()))
                .expect("static member list is non-empty and flat");
        Platform {
            name: "pocl-rs",
            devices: vec![
                Arc::new(BasicDevice::new(EngineKind::Serial)),
                Arc::new(ThreadedDevice::new(EngineKind::Gang(8), cores)),
                Arc::new(ThreadedDevice::new(EngineKind::Gang(4), 2)),
                Arc::new(ThreadedDevice::new(EngineKind::GangVector(vw), cores)),
                Arc::new(BasicDevice::new(EngineKind::GangVector(vw))),
                Arc::new(ThreadedDevice::new(EngineKind::Bytecode(vw), cores)),
                Arc::new(BasicDevice::new(EngineKind::Bytecode(vw))),
                Arc::new(ThreadedDevice::new(EngineKind::Jit(vw), cores)),
                Arc::new(BasicDevice::new(EngineKind::Jit(vw))),
                Arc::new(BasicDevice::new(EngineKind::Fiber)),
                Arc::new(TtaSimDevice::new(true)),
                Arc::new(multidev),
            ],
        }
    }

    /// Build a heterogeneous device group from platform device names
    /// ([`Platform::find_device`] resolution rules) under `policy`. The
    /// group's name joins the member names with `+`.
    pub fn group(&self, names: &[&str], policy: Arc<dyn SchedPolicy>) -> Result<DeviceGroup> {
        let members = names
            .iter()
            .map(|n| self.find_device(n))
            .collect::<Result<Vec<Arc<dyn Device>>>>()?;
        DeviceGroup::new(names.join("+"), members, policy)
    }

    /// Resolve a device by name: an exact match wins, otherwise the name
    /// must be a substring of exactly one device. Ambiguous names (e.g.
    /// `"basic"`, which matches both `basic-serial` and `basic-fiber`)
    /// and unknown names are errors, so a lookup can never silently bind
    /// to the wrong device as the platform grows.
    pub fn find_device(&self, name: &str) -> Result<Arc<dyn Device>> {
        if let Some(d) = self.devices.iter().find(|d| d.info().name == name) {
            return Ok(d.clone());
        }
        let matches: Vec<&Arc<dyn Device>> =
            self.devices.iter().filter(|d| d.info().name.contains(name)).collect();
        match matches.len() {
            0 => Err(Error::NotFound(format!("device `{name}`"))),
            1 => Ok(matches[0].clone()),
            _ => {
                let names: Vec<String> = matches.iter().map(|d| d.info().name).collect();
                Err(Error::invalid(format!(
                    "ambiguous device name `{name}`: matches {}",
                    names.join(", ")
                )))
            }
        }
    }

    /// Find a device by name ([`Platform::find_device`] rules); `None`
    /// for unknown *or ambiguous* names.
    pub fn device(&self, name: &str) -> Option<Arc<dyn Device>> {
        self.find_device(name).ok()
    }

    /// Render the Table 1-style capability table.
    pub fn capability_table(&self) -> String {
        let mut out = String::from(
            "| device | TLP | ILP | DLP |\n|---|---|---|---|\n",
        );
        for d in &self.devices {
            let i = d.info();
            out.push_str(&format!(
                "| {} | {} threads | {} | {} |\n",
                i.name, i.tlp, i.ilp, i.dlp
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_platform_has_expected_devices() {
        let p = Platform::default_platform();
        assert!(p.devices.len() >= 11);
        assert!(p.device("basic-serial").is_some());
        assert!(p.device("pthread-gang(8)").is_some());
        assert!(p.device("basic-gangvector").is_some(), "lane-batched vector device present");
        assert!(p.device("pthread-gangvector").is_some());
        assert!(p.device("basic-bytecode").is_some(), "threaded-bytecode device present");
        assert!(p.device("pthread-bytecode").is_some());
        assert!(p.device("basic-jit").is_some(), "template-jit device present");
        assert!(p.device("pthread-jit").is_some());
        assert!(p.device("ttasim").is_some(), "unique substring resolves");
        assert!(p.device("multidev").is_some(), "heterogeneous group device present");
        assert!(p.device("nonexistent").is_none());
    }

    #[test]
    fn multidev_device_is_a_group() {
        let p = Platform::default_platform();
        let d = p.device("multidev").unwrap();
        let g = d.as_group().expect("multidev downcasts to a DeviceGroup");
        assert_eq!(g.members().len(), 3);
        assert_eq!(g.policy().name(), "dynamic");
        assert_eq!(d.info().dlp, "heterogeneous group");
    }

    #[test]
    fn group_helper_builds_from_device_names() {
        let p = Platform::default_platform();
        let names = ["basic-serial", "basic-gangvector", "basic-bytecode"];
        let g = p.group(&names, Arc::new(Dynamic::new())).unwrap();
        assert_eq!(g.members().len(), 3);
        assert_eq!(g.info().name, "basic-serial+basic-gangvector+basic-bytecode");
        assert!(p.group(&["basic-serial", "nonexistent"], Arc::new(Dynamic::new())).is_err());
        // Groups cannot nest: naming the platform's multidev group as a
        // member is rejected.
        assert!(p.group(&["multidev", "basic-serial"], Arc::new(Dynamic::new())).is_err());
    }

    #[test]
    fn ambiguous_lookups_are_errors() {
        let p = Platform::default_platform();
        // `basic` matches basic-serial and basic-fiber; `pthread` matches
        // both gang widths.
        assert!(matches!(p.find_device("basic"), Err(Error::InvalidArg(_))));
        assert!(matches!(p.find_device("pthread"), Err(Error::InvalidArg(_))));
        assert!(p.device("basic").is_none());
        assert!(matches!(p.find_device("nonexistent"), Err(Error::NotFound(_))));
    }

    #[test]
    fn exact_match_beats_substring() {
        let p = Platform::default_platform();
        let d = p.find_device("basic-serial").unwrap();
        assert_eq!(d.info().name, "basic-serial");
    }

    #[test]
    fn capability_table_mentions_parallelism_classes() {
        let p = Platform::default_platform();
        let t = p.capability_table();
        assert!(t.contains("gang x8"));
        assert!(t.contains("static multi-issue"));
    }
}
