//! Platform layer: device discovery (the `clGetPlatformIDs` /
//! `clGetDeviceIDs` analog).

use std::sync::Arc;

use crate::devices::{basic::BasicDevice, threaded::ThreadedDevice, ttasim::TtaSimDevice, Device, EngineKind};

/// The pocl-rs platform: a named set of devices.
pub struct Platform {
    /// Platform name.
    pub name: &'static str,
    /// Available devices.
    pub devices: Vec<Arc<dyn Device>>,
}

impl Platform {
    /// The default platform with the device set used throughout §6:
    /// `basic` (serial), `pthread` (threaded gang, AVX2-width), narrow-SIMD
    /// variants (NEON/AltiVec width), a fiber baseline device, and the TTA
    /// simulator. The `pjrt` device is added separately because it needs
    /// artifacts (see `devices::pjrt`).
    pub fn default_platform() -> Platform {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Platform {
            name: "pocl-rs",
            devices: vec![
                Arc::new(BasicDevice::new(EngineKind::Serial)),
                Arc::new(ThreadedDevice::new(EngineKind::Gang(8), cores)),
                Arc::new(ThreadedDevice::new(EngineKind::Gang(4), 2)),
                Arc::new(BasicDevice::new(EngineKind::Fiber)),
                Arc::new(TtaSimDevice::new(true)),
            ],
        }
    }

    /// Find a device by (substring of) name.
    pub fn device(&self, name: &str) -> Option<Arc<dyn Device>> {
        self.devices.iter().find(|d| d.info().name.contains(name)).cloned()
    }

    /// Render the Table 1-style capability table.
    pub fn capability_table(&self) -> String {
        let mut out = String::from(
            "| device | TLP | ILP | DLP |\n|---|---|---|---|\n",
        );
        for d in &self.devices {
            let i = d.info();
            out.push_str(&format!(
                "| {} | {} threads | {} | {} |\n",
                i.name, i.tlp, i.ilp, i.dlp
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_platform_has_expected_devices() {
        let p = Platform::default_platform();
        assert!(p.devices.len() >= 5);
        assert!(p.device("basic").is_some());
        assert!(p.device("pthread").is_some());
        assert!(p.device("ttasim").is_some());
        assert!(p.device("nonexistent").is_none());
    }

    #[test]
    fn capability_table_mentions_parallelism_classes() {
        let p = Platform::default_platform();
        let t = p.capability_table();
        assert!(t.contains("gang x8"));
        assert!(t.contains("static multi-issue"));
    }
}
