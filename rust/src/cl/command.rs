//! Deferred commands: everything a queue can execute.
//!
//! A [`Command`] is a fully-resolved, self-contained unit of device work:
//! kernel launches carry their enqueue-time-specialised work-group
//! function (§4.1) and resolved argument values, transfers carry owned
//! host data. Commands are `Send`, so the queue's scheduler can run them
//! on worker threads; the same `execute` path also backs the context's
//! blocking typed helpers, so immediate and deferred transfers share one
//! implementation.

use std::sync::Arc;

use crate::cl::context::{Buffer, Context};
use crate::cl::error::{Error, Result};
use crate::devices::{LaunchRequest, LaunchStats};
use crate::exec::VVal;
use crate::kcc::WorkGroupFunction;
use crate::sched::SchedStats;

/// One unit of queued device work (the `clEnqueue*` families).
pub enum Command {
    /// ND-range kernel launch (`clEnqueueNDRangeKernel`).
    NdRange {
        /// Kernel name (for event labels).
        kernel: String,
        /// Enqueue-time-specialised work-group function.
        wgf: Arc<WorkGroupFunction>,
        /// Resolved argument values.
        args: Vec<VVal>,
        /// Buffers referenced by the args (re-validated at execution so a
        /// launch can't touch memory released while it was queued).
        buffers: Vec<Buffer>,
        /// Work-groups per dimension.
        groups: [usize; 3],
        /// Global work-item offset (`get_global_offset`).
        offset: [u64; 3],
        /// Work dimensions.
        work_dim: u32,
        /// Local memory bytes per work-group.
        local_mem: usize,
    },
    /// ND-range kernel launch co-executed across a heterogeneous device
    /// group (`sched::DeviceGroup`): one artifact per member, one
    /// completion event for the whole split.
    NdRangeSplit {
        /// Kernel name (for event labels).
        kernel: String,
        /// Per-member enqueue-time-specialised work-group functions, in
        /// group member order (each compiled under that member's own
        /// cache key).
        wgfs: Vec<Arc<WorkGroupFunction>>,
        /// Resolved argument values.
        args: Vec<VVal>,
        /// Buffers referenced by the args (re-validated at execution).
        buffers: Vec<Buffer>,
        /// Work-groups per dimension.
        groups: [usize; 3],
        /// Global work-item offset (`get_global_offset`).
        offset: [u64; 3],
        /// Work dimensions.
        work_dim: u32,
        /// Local memory bytes per work-group.
        local_mem: usize,
    },
    /// Host → device transfer (`clEnqueueWriteBuffer`); the host data is
    /// owned by the command.
    WriteBuffer {
        /// Destination buffer.
        buf: Buffer,
        /// Byte offset within the buffer.
        offset: usize,
        /// Bytes to write.
        data: Vec<u8>,
    },
    /// Device → host transfer (`clEnqueueReadBuffer`); the data is
    /// delivered through the event's payload.
    ReadBuffer {
        /// Source buffer.
        buf: Buffer,
        /// Byte offset within the buffer.
        offset: usize,
        /// Bytes to read.
        len: usize,
    },
    /// Device → device copy (`clEnqueueCopyBuffer`).
    CopyBuffer {
        /// Source buffer.
        src: Buffer,
        /// Destination buffer.
        dst: Buffer,
        /// Byte offset within `src`.
        src_offset: usize,
        /// Byte offset within `dst`.
        dst_offset: usize,
        /// Bytes to copy.
        len: usize,
    },
    /// Pattern fill (`clEnqueueFillBuffer`).
    FillBuffer {
        /// Destination buffer.
        buf: Buffer,
        /// Byte offset within the buffer.
        offset: usize,
        /// Fill pattern (repeated).
        pattern: Vec<u8>,
        /// Bytes to fill (multiple of the pattern length).
        len: usize,
    },
    /// Synchronisation point that completes when its wait-list does
    /// (`clEnqueueMarkerWithWaitList`).
    Marker,
    /// Out-of-order execution fence: later commands implicitly wait on it
    /// (`clEnqueueBarrierWithWaitList`).
    Barrier,
}

/// What executing a command produces.
pub(crate) struct CommandOutput {
    /// Device statistics (kernel launches).
    pub stats: LaunchStats,
    /// Per-device scheduler breakdown (split launches on device groups).
    pub sched: Option<SchedStats>,
    /// Result bytes (buffer reads).
    pub payload: Option<Vec<u8>>,
}

impl CommandOutput {
    fn empty() -> CommandOutput {
        CommandOutput { stats: LaunchStats::default(), sched: None, payload: None }
    }
}

impl Command {
    /// Short label for events and logs.
    pub fn label(&self) -> String {
        match self {
            Command::NdRange { kernel, .. } | Command::NdRangeSplit { kernel, .. } => {
                kernel.clone()
            }
            Command::WriteBuffer { .. } => "write_buffer".to_string(),
            Command::ReadBuffer { .. } => "read_buffer".to_string(),
            Command::CopyBuffer { .. } => "copy_buffer".to_string(),
            Command::FillBuffer { .. } => "fill_buffer".to_string(),
            Command::Marker => "marker".to_string(),
            Command::Barrier => "barrier".to_string(),
        }
    }

    /// Execute against the context. Called from queue workers and from the
    /// context's blocking helpers.
    pub(crate) fn execute(&self, ctx: &Context) -> Result<CommandOutput> {
        match self {
            Command::NdRange { wgf, args, buffers, groups, offset, work_dim, local_mem, .. } => {
                for b in buffers {
                    ctx.check_live(b)?;
                }
                let req = LaunchRequest::new(
                    Arc::clone(wgf),
                    args.clone(),
                    *groups,
                    *offset,
                    *work_dim,
                    *local_mem,
                );
                // SAFETY: commands that run concurrently were declared
                // independent by the client (no wait-list edge between
                // them); per the OpenCL execution model, racy access to
                // the same memory from independent commands is UB in the
                // *client* program — the same contract the threaded
                // device applies to work-groups.
                let global = unsafe { ctx.global.view() };
                let stats = ctx.device.launch(global, &req)?;
                Ok(CommandOutput { stats, sched: None, payload: None })
            }
            Command::NdRangeSplit {
                wgfs, args, buffers, groups, offset, work_dim, local_mem, ..
            } => {
                for b in buffers {
                    ctx.check_live(b)?;
                }
                let group = ctx.device.as_group().ok_or_else(|| {
                    Error::invalid("split launch enqueued on a non-group device")
                })?;
                let first = wgfs
                    .first()
                    .ok_or_else(|| Error::invalid("split launch carries no artifacts"))?;
                let req = LaunchRequest::new(
                    Arc::clone(first),
                    args.clone(),
                    *groups,
                    *offset,
                    *work_dim,
                    *local_mem,
                );
                // SAFETY: same independence contract as NdRange above.
                let global = unsafe { ctx.global.view() };
                let (stats, sched) = group.launch_split(global, &req, wgfs)?;
                Ok(CommandOutput { stats, sched: Some(sched), payload: None })
            }
            Command::WriteBuffer { buf, offset, data } => {
                ctx.write_buffer(*buf, *offset, data)?;
                Ok(CommandOutput::empty())
            }
            Command::ReadBuffer { buf, offset, len } => {
                let mut out = vec![0u8; *len];
                ctx.read_buffer(*buf, *offset, &mut out)?;
                Ok(CommandOutput {
                    stats: LaunchStats::default(),
                    sched: None,
                    payload: Some(out),
                })
            }
            Command::CopyBuffer { src, dst, src_offset, dst_offset, len } => {
                ctx.copy_buffer(*src, *dst, *src_offset, *dst_offset, *len)?;
                Ok(CommandOutput::empty())
            }
            Command::FillBuffer { buf, offset, pattern, len } => {
                ctx.fill_buffer(*buf, *offset, pattern, *len)?;
                Ok(CommandOutput::empty())
            }
            Command::Marker | Command::Barrier => Ok(CommandOutput::empty()),
        }
    }
}
