//! Contexts and buffers: device memory management on top of Bufalloc.
//!
//! The context owns the device's global-memory region and the buffer
//! allocator, and tracks which buffer handles are live so that stale
//! handles (released buffers, double frees) are rejected with
//! `Error::InvalidArg` instead of silently corrupting memory.
//!
//! Global memory is deliberately *not* behind a lock: independent
//! commands of an out-of-order queue must be able to touch disjoint
//! buffers concurrently. Commands that race on the same bytes without a
//! declared event edge are UB in the client program, exactly as on real
//! OpenCL devices (and as the threaded device already assumes for
//! work-groups).
//!
//! The typed helpers (`write_f32`, `read_u32`, ...) are thin wrappers
//! over the generic [`Context::write_slice`] / [`Context::read_vec`],
//! which delegate to a blocking execute-and-wait of the same
//! [`Command`]s an enqueue would defer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bufalloc::Bufalloc;
use crate::cl::command::Command;
use crate::cl::error::{Error, Result};
use crate::cl::event::Event;
use crate::devices::Device;

/// A buffer handle (`cl_mem` analog): an offset/length into the context's
/// global-memory region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Buffer {
    /// Byte offset in the device's global memory.
    pub offset: usize,
    /// Size in bytes.
    pub size: usize,
    /// Allocation id (used for stale-handle / double-free detection).
    pub id: u64,
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for u32 {}
    impl Sealed for i32 {}
}

/// The 4-byte scalar element types transferable through the typed buffer
/// helpers. Sealed: exactly `f32`, `u32` and `i32`.
pub trait Scalar: sealed::Sealed + Copy + 'static {
    /// Little-endian encoding.
    fn to_le(self) -> [u8; 4];
    /// Little-endian decoding.
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl Scalar for f32 {
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl Scalar for u32 {
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(bytes: [u8; 4]) -> Self {
        u32::from_le_bytes(bytes)
    }
}

impl Scalar for i32 {
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

/// Encode a scalar slice as little-endian bytes.
pub(crate) fn bytes_of<T: Scalar>(data: &[T]) -> Vec<u8> {
    data.iter().copied().flat_map(Scalar::to_le).collect()
}

/// Decode little-endian bytes as a scalar vector (trailing partial
/// elements are dropped).
pub(crate) fn vec_from_bytes<T: Scalar>(bytes: &[u8]) -> Vec<T> {
    bytes.chunks_exact(4).map(|c| T::from_le(c.try_into().unwrap())).collect()
}

/// The device's global memory region, shared without locking so that
/// independent commands can access disjoint buffers concurrently.
///
/// Transfers use raw-pointer copies on bounds-checked ranges, so they
/// never materialise aliasing `&mut` views. Kernel launches receive the
/// whole region as `&mut [u8]` — the same full-view contract the
/// threaded device's `SharedMem` already hands each worker — and rely on
/// the OpenCL rule that commands racing on the same bytes without a
/// declared event edge are UB in the *client* program.
pub(crate) struct GlobalMem {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: all access goes through bounds-checked buffer ranges; see the
// type-level contract above.
unsafe impl Send for GlobalMem {}
unsafe impl Sync for GlobalMem {}

impl GlobalMem {
    fn new(size: usize) -> GlobalMem {
        let boxed: Box<[u8]> = vec![0u8; size].into_boxed_slice();
        GlobalMem { ptr: Box::into_raw(boxed) as *mut u8, len: size }
    }

    /// Full mutable view of global memory (kernel launches).
    ///
    /// # Safety
    /// Callers must confine themselves to byte ranges they own (a live
    /// buffer's allocation) or otherwise uphold the racy-access-is-UB
    /// contract documented on [`GlobalMem`].
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn view(&self) -> &mut [u8] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }

    /// Copy host bytes into the region.
    ///
    /// # Safety
    /// `offset + data.len()` must be within bounds.
    pub(crate) unsafe fn write(&self, offset: usize, data: &[u8]) {
        std::ptr::copy_nonoverlapping(data.as_ptr(), self.ptr.add(offset), data.len());
    }

    /// Copy region bytes out to host memory.
    ///
    /// # Safety
    /// `offset + out.len()` must be within bounds.
    pub(crate) unsafe fn read(&self, offset: usize, out: &mut [u8]) {
        std::ptr::copy_nonoverlapping(self.ptr.add(offset), out.as_mut_ptr(), out.len());
    }

    /// Copy within the region (overlap-safe).
    ///
    /// # Safety
    /// Both ranges must be within bounds.
    pub(crate) unsafe fn copy(&self, src: usize, dst: usize, len: usize) {
        std::ptr::copy(self.ptr.add(src), self.ptr.add(dst), len);
    }
}

impl Drop for GlobalMem {
    fn drop(&mut self) {
        // SAFETY: ptr/len came from Box::into_raw of a boxed slice.
        unsafe { drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(self.ptr, self.len))) };
    }
}

/// A context (`cl_context` analog): one device plus its global memory,
/// managed by the §3 Bufalloc allocator.
pub struct Context {
    /// The device this context talks to.
    pub device: Arc<dyn Device>,
    pub(crate) global: GlobalMem,
    pub(crate) alloc: Mutex<Bufalloc>,
    /// Live buffer ids → allocation offset (stale-handle detection).
    live: Mutex<HashMap<u64, usize>>,
    next_id: AtomicU64,
    /// Timestamp origin for events produced by the blocking helpers.
    pub(crate) epoch: Instant,
}

impl Context {
    /// Create a context with the device's full global memory region,
    /// managed greedily (the paper's default for kernel buffers).
    pub fn new(device: Arc<dyn Device>) -> Context {
        let size = device.info().global_mem.min(512 << 20);
        Context {
            device,
            global: GlobalMem::new(size),
            alloc: Mutex::new(Bufalloc::new(size, 64, true)),
            live: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            epoch: Instant::now(),
        }
    }

    /// Allocate a device buffer (`clCreateBuffer`). Ids start at 1.
    pub fn create_buffer(&self, size: usize) -> Result<Buffer> {
        let offset = self.alloc.lock().unwrap().alloc(size)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.live.lock().unwrap().insert(id, offset);
        Ok(Buffer { offset, size, id })
    }

    /// Release a buffer (`clReleaseMemObject`). Releasing a handle twice
    /// (or a forged/stale handle) is an `InvalidArg` error.
    pub fn release_buffer(&self, buf: Buffer) -> Result<()> {
        let removed = self.live.lock().unwrap().remove(&buf.id);
        match removed {
            Some(offset) if offset == buf.offset => self.alloc.lock().unwrap().free(offset),
            Some(offset) => {
                // Defensive: id was live but at a different offset —
                // restore and reject the forged handle.
                self.live.lock().unwrap().insert(buf.id, offset);
                Err(Error::invalid(format!("buffer id {} does not match its allocation", buf.id)))
            }
            None => Err(Error::invalid(format!(
                "double free or stale buffer handle (id {})",
                buf.id
            ))),
        }
    }

    /// True while the handle refers to a live allocation.
    pub fn buffer_is_live(&self, buf: &Buffer) -> bool {
        self.live.lock().unwrap().get(&buf.id) == Some(&buf.offset)
    }

    /// Reject stale handles with `InvalidArg`.
    pub(crate) fn check_live(&self, buf: &Buffer) -> Result<()> {
        if self.buffer_is_live(buf) {
            Ok(())
        } else {
            Err(Error::invalid(format!(
                "stale buffer handle (id {}): buffer was released",
                buf.id
            )))
        }
    }

    /// Bytes currently allocated.
    pub fn allocated(&self) -> usize {
        self.alloc.lock().unwrap().allocated()
    }

    /// Write host data into a buffer (raw bytes).
    pub fn write_buffer(&self, buf: Buffer, offset: usize, data: &[u8]) -> Result<()> {
        self.check_live(&buf)?;
        if offset + data.len() > buf.size {
            return Err(Error::invalid("write exceeds buffer size"));
        }
        // SAFETY: range is bounds-checked against a live allocation.
        unsafe { self.global.write(buf.offset + offset, data) };
        Ok(())
    }

    /// Read a buffer back to host memory (raw bytes).
    pub fn read_buffer(&self, buf: Buffer, offset: usize, out: &mut [u8]) -> Result<()> {
        self.check_live(&buf)?;
        if offset + out.len() > buf.size {
            return Err(Error::invalid("read exceeds buffer size"));
        }
        // SAFETY: range is bounds-checked against a live allocation.
        unsafe { self.global.read(buf.offset + offset, out) };
        Ok(())
    }

    /// Device-side copy between buffers.
    pub fn copy_buffer(
        &self,
        src: Buffer,
        dst: Buffer,
        src_offset: usize,
        dst_offset: usize,
        len: usize,
    ) -> Result<()> {
        self.check_live(&src)?;
        self.check_live(&dst)?;
        if src_offset + len > src.size {
            return Err(Error::invalid("copy exceeds source buffer size"));
        }
        if dst_offset + len > dst.size {
            return Err(Error::invalid("copy exceeds destination buffer size"));
        }
        // SAFETY: ranges are bounds-checked against live allocations; the
        // copy is overlap-safe.
        unsafe { self.global.copy(src.offset + src_offset, dst.offset + dst_offset, len) };
        Ok(())
    }

    /// Fill a buffer range with a repeated byte pattern.
    pub fn fill_buffer(&self, buf: Buffer, offset: usize, pattern: &[u8], len: usize) -> Result<()> {
        self.check_live(&buf)?;
        if pattern.is_empty() || len % pattern.len() != 0 {
            return Err(Error::invalid("fill length must be a positive multiple of the pattern"));
        }
        if offset + len > buf.size {
            return Err(Error::invalid("fill exceeds buffer size"));
        }
        // SAFETY: range is bounds-checked against a live allocation.
        let base = buf.offset + offset;
        let mut off = 0;
        while off < len {
            let chunk = pattern.len().min(len - off);
            unsafe { self.global.write(base + off, &pattern[..chunk]) };
            off += chunk;
        }
        Ok(())
    }

    /// Execute one command immediately (blocking enqueue + wait), sharing
    /// the queue's command implementation.
    fn run_blocking(&self, cmd: Command) -> Result<Event> {
        let ns = self.epoch.elapsed().as_nanos() as u64;
        let ev = Event::new(cmd.label(), ns);
        ev.mark_submitted(ns);
        ev.mark_running(self.epoch.elapsed().as_nanos() as u64);
        match cmd.execute(self) {
            Ok(out) => {
                let exec_span = out.sched.as_ref().and_then(|sc| sc.exec_span()).map(
                    |(start, end)| {
                        (
                            start.saturating_duration_since(self.epoch).as_nanos() as u64,
                            end.saturating_duration_since(self.epoch).as_nanos() as u64,
                        )
                    },
                );
                ev.complete_ok(
                    self.epoch.elapsed().as_nanos() as u64,
                    out.stats,
                    out.sched,
                    out.payload,
                    exec_span,
                );
                Ok(ev)
            }
            Err(e) => {
                ev.complete_err(self.epoch.elapsed().as_nanos() as u64, e.clone());
                Err(e)
            }
        }
    }

    /// Write a typed scalar slice into a buffer (blocking).
    pub fn write_slice<T: Scalar>(&self, buf: Buffer, data: &[T]) -> Result<()> {
        self.run_blocking(Command::WriteBuffer { buf, offset: 0, data: bytes_of(data) })?;
        Ok(())
    }

    /// Read a typed scalar vector out of a buffer (blocking).
    pub fn read_vec<T: Scalar>(&self, buf: Buffer, n: usize) -> Result<Vec<T>> {
        let ev = self.run_blocking(Command::ReadBuffer { buf, offset: 0, len: n * 4 })?;
        ev.wait_vec::<T>()
    }

    /// Typed helper (f32) — thin wrapper over [`Context::write_slice`].
    pub fn write_f32(&self, buf: Buffer, data: &[f32]) -> Result<()> {
        self.write_slice(buf, data)
    }

    /// Read f32 data back.
    pub fn read_f32(&self, buf: Buffer, n: usize) -> Result<Vec<f32>> {
        self.read_vec(buf, n)
    }

    /// Typed helper (u32).
    pub fn write_u32(&self, buf: Buffer, data: &[u32]) -> Result<()> {
        self.write_slice(buf, data)
    }

    /// Read u32 data back.
    pub fn read_u32(&self, buf: Buffer, n: usize) -> Result<Vec<u32>> {
        self.read_vec(buf, n)
    }

    /// Typed helper (i32).
    pub fn write_i32(&self, buf: Buffer, data: &[i32]) -> Result<()> {
        self.write_slice(buf, data)
    }

    /// Read i32 data back.
    pub fn read_i32(&self, buf: Buffer, n: usize) -> Result<Vec<i32>> {
        self.read_vec(buf, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{basic::BasicDevice, EngineKind};

    fn ctx() -> Context {
        Context::new(Arc::new(BasicDevice::new(EngineKind::Serial)))
    }

    #[test]
    fn buffer_lifecycle() {
        let c = ctx();
        let b = c.create_buffer(1024).unwrap();
        c.write_f32(b, &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(c.read_f32(b, 3).unwrap(), vec![1.0, 2.0, 3.0]);
        c.release_buffer(b).unwrap();
        assert_eq!(c.allocated(), 0);
    }

    #[test]
    fn ids_start_at_one() {
        let c = ctx();
        let b = c.create_buffer(64).unwrap();
        assert_eq!(b.id, 1);
        assert_eq!(c.create_buffer(64).unwrap().id, 2);
    }

    #[test]
    fn double_free_rejected() {
        let c = ctx();
        let b = c.create_buffer(64).unwrap();
        c.release_buffer(b).unwrap();
        assert!(matches!(c.release_buffer(b), Err(Error::InvalidArg(_))));
    }

    #[test]
    fn use_after_free_rejected() {
        let c = ctx();
        let b = c.create_buffer(64).unwrap();
        c.release_buffer(b).unwrap();
        assert!(matches!(c.write_f32(b, &[1.0]), Err(Error::InvalidArg(_))));
        assert!(matches!(c.read_f32(b, 1), Err(Error::InvalidArg(_))));
        assert!(!c.buffer_is_live(&b));
    }

    #[test]
    fn generic_scalar_roundtrip() {
        let c = ctx();
        let b = c.create_buffer(64).unwrap();
        c.write_slice::<i32>(b, &[-3, 0, 7]).unwrap();
        assert_eq!(c.read_vec::<i32>(b, 3).unwrap(), vec![-3, 0, 7]);
        c.write_u32(b, &[1, 2, 3]).unwrap();
        assert_eq!(c.read_u32(b, 3).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn copy_and_fill() {
        let c = ctx();
        let a = c.create_buffer(64).unwrap();
        let b = c.create_buffer(64).unwrap();
        c.fill_buffer(a, 0, &5.0f32.to_le_bytes(), 64).unwrap();
        c.copy_buffer(a, b, 0, 0, 64).unwrap();
        assert!(c.read_f32(b, 16).unwrap().iter().all(|&v| v == 5.0));
        assert!(c.fill_buffer(a, 0, &[1, 2, 3], 64).is_err(), "non-multiple pattern");
    }

    #[test]
    fn oob_writes_rejected() {
        let c = ctx();
        let b = c.create_buffer(8).unwrap();
        assert!(c.write_f32(b, &[0.0; 3]).is_err());
    }

    #[test]
    fn buffers_are_disjoint() {
        let c = ctx();
        let a = c.create_buffer(64).unwrap();
        let b = c.create_buffer(64).unwrap();
        c.write_f32(a, &[7.0; 16]).unwrap();
        c.write_f32(b, &[9.0; 16]).unwrap();
        assert!(c.read_f32(a, 16).unwrap().iter().all(|&v| v == 7.0));
    }
}
