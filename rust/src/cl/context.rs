//! Contexts and buffers: device memory management on top of Bufalloc.

use std::sync::{Arc, Mutex};

use crate::bufalloc::Bufalloc;
use crate::cl::error::{Error, Result};
use crate::devices::Device;

/// A buffer handle (`cl_mem` analog): an offset/length into the context's
/// global-memory region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Buffer {
    /// Byte offset in the device's global memory.
    pub offset: usize,
    /// Size in bytes.
    pub size: usize,
    /// Allocation id (for double-free detection).
    pub id: u64,
}

/// A context (`cl_context` analog): one device plus its global memory,
/// managed by the §3 Bufalloc allocator.
pub struct Context {
    /// The device this context talks to.
    pub device: Arc<dyn Device>,
    pub(crate) global: Mutex<Vec<u8>>,
    pub(crate) alloc: Mutex<Bufalloc>,
    next_id: Mutex<u64>,
}

impl Context {
    /// Create a context with the device's full global memory region,
    /// managed greedily (the paper's default for kernel buffers).
    pub fn new(device: Arc<dyn Device>) -> Context {
        let size = device.info().global_mem.min(512 << 20);
        Context {
            device,
            global: Mutex::new(vec![0u8; size]),
            alloc: Mutex::new(Bufalloc::new(size, 64, true)),
            next_id: Mutex::new(1),
        }
    }

    /// Allocate a device buffer (`clCreateBuffer`).
    pub fn create_buffer(&self, size: usize) -> Result<Buffer> {
        let offset = self.alloc.lock().unwrap().alloc(size)?;
        let mut id = self.next_id.lock().unwrap();
        *id += 1;
        Ok(Buffer { offset, size, id: *id })
    }

    /// Release a buffer (`clReleaseMemObject`).
    pub fn release_buffer(&self, buf: Buffer) -> Result<()> {
        self.alloc.lock().unwrap().free(buf.offset)
    }

    /// Bytes currently allocated.
    pub fn allocated(&self) -> usize {
        self.alloc.lock().unwrap().allocated()
    }

    /// Write host data into a buffer.
    pub fn write_buffer(&self, buf: Buffer, offset: usize, data: &[u8]) -> Result<()> {
        if offset + data.len() > buf.size {
            return Err(Error::invalid("write exceeds buffer size"));
        }
        let mut g = self.global.lock().unwrap();
        g[buf.offset + offset..buf.offset + offset + data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Read a buffer back to host memory.
    pub fn read_buffer(&self, buf: Buffer, offset: usize, out: &mut [u8]) -> Result<()> {
        if offset + out.len() > buf.size {
            return Err(Error::invalid("read exceeds buffer size"));
        }
        let g = self.global.lock().unwrap();
        out.copy_from_slice(&g[buf.offset + offset..buf.offset + offset + out.len()]);
        Ok(())
    }

    /// Typed helpers (f32).
    pub fn write_f32(&self, buf: Buffer, data: &[f32]) -> Result<()> {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_buffer(buf, 0, &bytes)
    }

    /// Read f32 data back.
    pub fn read_f32(&self, buf: Buffer, n: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        self.read_buffer(buf, 0, &mut bytes)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Typed helpers (u32).
    pub fn write_u32(&self, buf: Buffer, data: &[u32]) -> Result<()> {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_buffer(buf, 0, &bytes)
    }

    /// Read u32 data back.
    pub fn read_u32(&self, buf: Buffer, n: usize) -> Result<Vec<u32>> {
        let mut bytes = vec![0u8; n * 4];
        self.read_buffer(buf, 0, &mut bytes)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Typed helpers (i32).
    pub fn write_i32(&self, buf: Buffer, data: &[i32]) -> Result<()> {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_buffer(buf, 0, &bytes)
    }

    /// Read i32 data back.
    pub fn read_i32(&self, buf: Buffer, n: usize) -> Result<Vec<i32>> {
        let mut bytes = vec![0u8; n * 4];
        self.read_buffer(buf, 0, &mut bytes)?;
        Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{basic::BasicDevice, EngineKind};

    fn ctx() -> Context {
        Context::new(Arc::new(BasicDevice::new(EngineKind::Serial)))
    }

    #[test]
    fn buffer_lifecycle() {
        let c = ctx();
        let b = c.create_buffer(1024).unwrap();
        c.write_f32(b, &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(c.read_f32(b, 3).unwrap(), vec![1.0, 2.0, 3.0]);
        c.release_buffer(b).unwrap();
        assert_eq!(c.allocated(), 0);
    }

    #[test]
    fn oob_writes_rejected() {
        let c = ctx();
        let b = c.create_buffer(8).unwrap();
        assert!(c.write_f32(b, &[0.0; 3]).is_err());
    }

    #[test]
    fn buffers_are_disjoint() {
        let c = ctx();
        let a = c.create_buffer(64).unwrap();
        let b = c.create_buffer(64).unwrap();
        c.write_f32(a, &[7.0; 16]).unwrap();
        c.write_f32(b, &[9.0; 16]).unwrap();
        assert!(c.read_f32(a, 16).unwrap().iter().all(|&v| v == 7.0));
    }
}
