//! Command queues (`clCommandQueue` analog): deferred submission, a
//! dependency DAG of events, and in-order / out-of-order execution.
//!
//! Every `enqueue_*` call resolves its arguments immediately (kernel
//! launches get their §4.1 enqueue-time-specialised work-group function
//! here), wraps the work in a [`Command`], and returns a live [`Event`]
//! in the `Queued` state. Nothing executes until the queue is flushed:
//! [`CommandQueue::flush`] submits all queued commands to the worker
//! pool, [`CommandQueue::finish`] flushes and blocks until everything
//! completed, and [`Event::wait`] flushes the owning queue implicitly.
//!
//! **In-order** queues ([`QueueProperties::InOrder`], the default) chain
//! every command behind the previous one, preserving classic OpenCL
//! sequential semantics on a single worker. **Out-of-order** queues run
//! all *ready* commands concurrently on a worker pool and synchronise
//! only on declared wait-list edges (plus explicit barriers), so
//! independent transfers and launches overlap.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::cl::command::Command;
use crate::cl::context::{bytes_of, Buffer, Context, Scalar};
use crate::cl::error::{Error, Result};
use crate::cl::event::{CommandStatus, Event};
use crate::cl::program::{Kernel, KernelArg, Program};
use crate::exec::value::{SP_GLOBAL, SP_LOCAL};
use crate::exec::VVal;
use crate::kcc::CompileOptions;
use crate::trace;

/// Queue execution mode (`CL_QUEUE_OUT_OF_ORDER_EXEC_MODE_ENABLE` analog).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum QueueProperties {
    /// Commands execute in enqueue order (each implicitly waits on its
    /// predecessor).
    #[default]
    InOrder,
    /// Ready commands execute concurrently; ordering comes only from
    /// wait-lists and barriers.
    OutOfOrder,
}

/// One not-yet-executed command with its dependency edges.
struct PendingCmd {
    cmd: Command,
    event: Event,
    deps: Vec<Event>,
    submitted: bool,
}

struct SchedState {
    pending: VecDeque<PendingCmd>,
    /// Commands currently executing on workers.
    running: usize,
    /// High-water mark of `running` (worker-pool instrumentation).
    max_running: usize,
    shutdown: bool,
}

/// Shared between the queue handle, its worker threads, and its events.
pub(crate) struct SchedulerShared {
    ctx: Arc<Context>,
    state: Mutex<SchedState>,
    cv: Condvar,
    epoch: Instant,
}

impl SchedulerShared {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Submit every still-queued command to the workers (`clFlush`).
    pub(crate) fn submit_all(&self) {
        {
            let mut st = self.state.lock().unwrap();
            let ns = self.now_ns();
            for p in st.pending.iter_mut() {
                if !p.submitted {
                    p.submitted = true;
                    p.event.mark_submitted(ns);
                }
            }
        }
        self.cv.notify_all();
    }

    fn push(&self, cmd: Command, event: Event, deps: Vec<Event>) {
        self.state
            .lock()
            .unwrap()
            .pending
            .push_back(PendingCmd { cmd, event, deps, submitted: false });
    }
}

/// Validate an ND-range geometry (OpenCL 1.2 divisibility rule) and
/// return its work dimension.
fn check_nd_range(global: [usize; 3], local: [usize; 3]) -> Result<u32> {
    for d in 0..3 {
        if local[d] == 0 || global[d] % local[d] != 0 {
            return Err(Error::invalid(format!(
                "global size {global:?} not divisible by local {local:?}"
            )));
        }
    }
    Ok(if global[2] > 1 {
        3
    } else if global[1] > 1 {
        2
    } else {
        1
    })
}

/// First submitted command whose wait-list is fully finished.
fn find_ready(pending: &VecDeque<PendingCmd>) -> Option<usize> {
    pending.iter().position(|p| p.submitted && p.deps.iter().all(Event::is_finished))
}

fn worker_loop(shared: &SchedulerShared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(i) = find_ready(&st.pending) {
                    let job = st.pending.remove(i).expect("ready index valid");
                    st.running += 1;
                    if st.running > st.max_running {
                        st.max_running = st.running;
                    }
                    break Some(job);
                }
                if st.shutdown {
                    break None;
                }
                // A submitted command may wait on events of a *different*
                // queue that was never flushed; submit their owners so the
                // dependency can make progress (clWaitForEvents-style
                // implicit flush, applied transitively).
                let mut stuck: Vec<Event> = Vec::new();
                for p in st.pending.iter() {
                    if !p.submitted {
                        continue;
                    }
                    for d in &p.deps {
                        if d.status() == CommandStatus::Queued {
                            stuck.push(d.clone());
                        }
                    }
                }
                if !stuck.is_empty() {
                    drop(st);
                    for d in stuck {
                        d.ensure_submitted();
                    }
                    st = shared.state.lock().unwrap();
                    // Fall through to the timed wait: if a dependency can
                    // never be submitted (its queue is gone), this stays a
                    // bounded poll instead of a hot spin.
                }
                if st.pending.iter().any(|p| p.submitted) {
                    // Foreign dependencies' completion doesn't signal our
                    // condvar — re-poll on a short timeout.
                    let (guard, _) =
                        shared.cv.wait_timeout(st, Duration::from_millis(2)).unwrap();
                    st = guard;
                } else {
                    // Nothing submitted: sleep until a flush or shutdown.
                    st = shared.cv.wait(st).unwrap();
                }
            }
        };
        let Some(job) = job else { return };
        if let Some(dep_err) = job.deps.iter().find_map(Event::error_of) {
            trace::metrics::add("queue.errors", 1);
            job.event.complete_err(
                shared.now_ns(),
                Error::exec(format!("dependency failed: {dep_err}")),
            );
        } else {
            job.event.mark_running(shared.now_ns());
            let traced = trace::enabled();
            // The worker-side complete span; the wait-list edges render
            // as flow arrows into it.
            let run_span =
                traced.then(|| trace::span(trace::CAT_QUEUE, format!("run {}", job.event.what())));
            if traced {
                for dep in &job.deps {
                    if let Some(id) = dep.trace_id() {
                        trace::flow_end(trace::CAT_QUEUE, id);
                    }
                }
            }
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                job.cmd.execute(&shared.ctx)
            }));
            if traced {
                // Producing end of this command's own outgoing edges,
                // anchored inside its run span now that the work is done.
                if let Some(id) = job.event.trace_id() {
                    trace::flow_start(trace::CAT_QUEUE, id);
                }
            }
            drop(run_span);
            match result {
                Ok(Ok(out)) => {
                    trace::metrics::add("queue.commands", 1);
                    // Split launches report the union of their member
                    // sub-launch spans; convert to queue-relative ns so
                    // profiling covers earliest start → latest end.
                    let exec_span = out.sched.as_ref().and_then(|sc| sc.exec_span()).map(
                        |(start, end)| {
                            (
                                start.saturating_duration_since(shared.epoch).as_nanos() as u64,
                                end.saturating_duration_since(shared.epoch).as_nanos() as u64,
                            )
                        },
                    );
                    job.event.complete_ok(
                        shared.now_ns(),
                        out.stats,
                        out.sched,
                        out.payload,
                        exec_span,
                    )
                }
                Ok(Err(e)) => {
                    trace::metrics::add("queue.errors", 1);
                    job.event.complete_err(shared.now_ns(), e)
                }
                Err(_) => {
                    trace::metrics::add("queue.errors", 1);
                    job.event.complete_err(
                        shared.now_ns(),
                        Error::exec(format!("command `{}` panicked", job.event.what())),
                    )
                }
            }
        }
        {
            let mut st = shared.state.lock().unwrap();
            st.running -= 1;
        }
        shared.cv.notify_all();
    }
}

/// Per-queue bookkeeping of issued events.
#[derive(Default)]
struct IssueState {
    /// Previous command (in-order chaining).
    last: Option<Event>,
    /// Last barrier (out-of-order fence).
    barrier: Option<Event>,
    /// Every event issued, in order (profiling log, `finish`).
    all: Vec<Event>,
}

/// A command queue bound to one context.
pub struct CommandQueue {
    /// The context (device + memory).
    pub context: Arc<Context>,
    props: QueueProperties,
    shared: Arc<SchedulerShared>,
    workers: Vec<thread::JoinHandle<()>>,
    issued: Mutex<IssueState>,
    /// Process-unique queue number (worker-thread names, trace track).
    serial: u64,
    /// Lazily allocated tracer track carrying this queue's command
    /// lifecycle async spans.
    track: OnceLock<u64>,
}

impl CommandQueue {
    /// Create an in-order queue on a context.
    pub fn new(context: Arc<Context>) -> CommandQueue {
        CommandQueue::with_properties(context, QueueProperties::InOrder)
    }

    /// Create a queue with explicit properties.
    pub fn with_properties(context: Arc<Context>, props: QueueProperties) -> CommandQueue {
        static QUEUE_SERIAL: AtomicU64 = AtomicU64::new(0);
        let serial = QUEUE_SERIAL.fetch_add(1, Ordering::Relaxed);
        let nworkers = match props {
            QueueProperties::InOrder => 1,
            QueueProperties::OutOfOrder => thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(2, 8),
        };
        let shared = Arc::new(SchedulerShared {
            ctx: context.clone(),
            state: Mutex::new(SchedState {
                pending: VecDeque::new(),
                running: 0,
                max_running: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
            epoch: Instant::now(),
        });
        let workers = (0..nworkers)
            .map(|i| {
                let s = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("poclrs-q{serial}-w{i}"))
                    .spawn(move || worker_loop(&s))
                    .expect("spawn queue worker")
            })
            .collect();
        CommandQueue {
            context,
            props,
            shared,
            workers,
            issued: Mutex::new(IssueState::default()),
            serial,
            track: OnceLock::new(),
        }
    }

    /// The queue's execution mode.
    pub fn properties(&self) -> QueueProperties {
        self.props
    }

    /// Record a command with its dependency edges; returns its event.
    fn issue(&self, cmd: Command, wait: &[Event]) -> Event {
        let ev = Event::new(cmd.label(), self.shared.now_ns());
        ev.attach_scheduler(Arc::downgrade(&self.shared));
        if trace::enabled() {
            let track =
                *self.track.get_or_init(|| trace::alloc_track(format!("queue-{}", self.serial)));
            ev.trace_begin(track);
        }
        let mut deps: Vec<Event> = wait.to_vec();
        {
            let mut iss = self.issued.lock().unwrap();
            match self.props {
                QueueProperties::InOrder => {
                    if let Some(prev) = &iss.last {
                        deps.push(prev.clone());
                    }
                }
                QueueProperties::OutOfOrder => {
                    if let Some(b) = &iss.barrier {
                        deps.push(b.clone());
                    }
                }
            }
            iss.last = Some(ev.clone());
            iss.all.push(ev.clone());
        }
        self.shared.push(cmd, ev.clone(), deps);
        ev
    }

    /// Enqueue an ND-range kernel (`clEnqueueNDRangeKernel`) with a zero
    /// global offset.
    ///
    /// `global` must be divisible by `local` in every dimension (OpenCL
    /// 1.2 rule). The command is deferred; it executes after `wait` (and,
    /// in-order, after every earlier command) once the queue is flushed.
    /// On a heterogeneous device group (`sched::DeviceGroup`) the launch
    /// is routed through the split path automatically — see
    /// [`CommandQueue::enqueue_nd_range_split`].
    pub fn enqueue_nd_range(
        &self,
        program: &Program,
        kernel: &Kernel,
        global: [usize; 3],
        local: [usize; 3],
        wait: &[Event],
    ) -> Result<Event> {
        self.enqueue_nd_range_at(program, kernel, global, local, [0; 3], wait)
    }

    /// Enqueue an ND-range kernel with an explicit global work-item
    /// offset (`clEnqueueNDRangeKernel`'s `global_work_offset`): every
    /// work-item's `get_global_id(d)` is shifted by `offset[d]`.
    pub fn enqueue_nd_range_at(
        &self,
        program: &Program,
        kernel: &Kernel,
        global: [usize; 3],
        local: [usize; 3],
        offset: [u64; 3],
        wait: &[Event],
    ) -> Result<Event> {
        if self.context.device.as_group().is_some() {
            return self.enqueue_nd_range_split(program, kernel, global, local, offset, wait);
        }
        let work_dim = check_nd_range(global, local)?;
        let mut opts: CompileOptions = self.context.device.compile_options();
        opts.work_dim = work_dim;
        let wgf = program.workgroup_function(&kernel.name, local, &opts)?;
        let (args, buffers, local_mem) = self.resolve_kernel_args(program, kernel)?;
        let groups = [global[0] / local[0], global[1] / local[1], global[2] / local[2]];
        let cmd = Command::NdRange {
            kernel: kernel.name.clone(),
            wgf,
            args,
            buffers,
            groups,
            offset,
            work_dim,
            local_mem,
        };
        Ok(self.issue(cmd, wait))
    }

    /// Enqueue an ND-range kernel co-executed across the members of a
    /// heterogeneous device group. One artifact is compiled per member
    /// under that member's own options (and therefore its own
    /// persistent-cache key: a serial member and a width-8 jit member
    /// never share a specialisation); the scheduler partitions the
    /// work-group grid among the members and the returned event
    /// completes when every member's share has. Fails when the
    /// context's device is not a `sched::DeviceGroup`.
    pub fn enqueue_nd_range_split(
        &self,
        program: &Program,
        kernel: &Kernel,
        global: [usize; 3],
        local: [usize; 3],
        offset: [u64; 3],
        wait: &[Event],
    ) -> Result<Event> {
        let group = self.context.device.as_group().ok_or_else(|| {
            Error::invalid("enqueue_nd_range_split needs a device-group context")
        })?;
        let work_dim = check_nd_range(global, local)?;
        let mut wgfs = Vec::with_capacity(group.members().len());
        for mut opts in group.member_compile_options() {
            opts.work_dim = work_dim;
            wgfs.push(program.workgroup_function(&kernel.name, local, &opts)?);
        }
        let (args, buffers, local_mem) = self.resolve_kernel_args(program, kernel)?;
        let groups = [global[0] / local[0], global[1] / local[1], global[2] / local[2]];
        let cmd = Command::NdRangeSplit {
            kernel: kernel.name.clone(),
            wgfs,
            args,
            buffers,
            groups,
            offset,
            work_dim,
            local_mem,
        };
        Ok(self.issue(cmd, wait))
    }

    /// Resolve kernel arguments: buffers → global offsets; local sizes →
    /// local offsets; auto-locals appended after user args. Returns the
    /// resolved values, the referenced buffers (for execute-time
    /// liveness re-checks), and the local-memory footprint.
    fn resolve_kernel_args(
        &self,
        program: &Program,
        kernel: &Kernel,
    ) -> Result<(Vec<VVal>, Vec<Buffer>, usize)> {
        let kfun = program.module.kernel(&kernel.name).unwrap();
        let mut args: Vec<VVal> = Vec::with_capacity(kfun.params.len());
        let mut buffers: Vec<Buffer> = Vec::new();
        let mut local_off = 0usize;
        let mut user_idx = 0usize;
        for p in &kfun.params {
            if let Some(bytes) = p.auto_local_size {
                args.push(VVal::ptr(SP_LOCAL, local_off as u64));
                local_off += bytes;
                continue;
            }
            let a = kernel.args.get(user_idx).and_then(|a| a.as_ref()).ok_or_else(|| {
                Error::invalid(format!("kernel `{}` arg {user_idx} not set", kernel.name))
            })?;
            user_idx += 1;
            args.push(match a {
                KernelArg::Buf(b) => {
                    self.context.check_live(b)?;
                    buffers.push(*b);
                    VVal::ptr(SP_GLOBAL, b.offset as u64)
                }
                KernelArg::LocalSize(sz) => {
                    let v = VVal::ptr(SP_LOCAL, local_off as u64);
                    local_off += sz;
                    v
                }
                KernelArg::I32(v) => VVal::i(*v as i64),
                KernelArg::U32(v) => VVal::i(*v as i64),
                KernelArg::U64(v) => VVal::i(*v as i64),
                KernelArg::F32(v) => VVal::f(*v as f64),
            });
        }
        Ok((args, buffers, local_off))
    }

    /// Enqueue a host → device write of raw bytes; the queue owns `data`.
    pub fn enqueue_write_buffer(
        &self,
        buf: Buffer,
        offset: usize,
        data: Vec<u8>,
        wait: &[Event],
    ) -> Result<Event> {
        self.context.check_live(&buf)?;
        if offset + data.len() > buf.size {
            return Err(Error::invalid("write exceeds buffer size"));
        }
        Ok(self.issue(Command::WriteBuffer { buf, offset, data }, wait))
    }

    /// Enqueue a typed host → device write.
    pub fn enqueue_write_slice<T: Scalar>(
        &self,
        buf: Buffer,
        data: &[T],
        wait: &[Event],
    ) -> Result<Event> {
        self.enqueue_write_buffer(buf, 0, bytes_of(data), wait)
    }

    /// Enqueue a device → host read; the bytes arrive in the event's
    /// payload ([`Event::wait_data`] / [`Event::wait_vec`]).
    pub fn enqueue_read_buffer(
        &self,
        buf: Buffer,
        offset: usize,
        len: usize,
        wait: &[Event],
    ) -> Result<Event> {
        self.context.check_live(&buf)?;
        if offset + len > buf.size {
            return Err(Error::invalid("read exceeds buffer size"));
        }
        Ok(self.issue(Command::ReadBuffer { buf, offset, len }, wait))
    }

    /// Enqueue a device-side buffer copy.
    pub fn enqueue_copy_buffer(
        &self,
        src: Buffer,
        dst: Buffer,
        src_offset: usize,
        dst_offset: usize,
        len: usize,
        wait: &[Event],
    ) -> Result<Event> {
        self.context.check_live(&src)?;
        self.context.check_live(&dst)?;
        if src_offset + len > src.size || dst_offset + len > dst.size {
            return Err(Error::invalid("copy exceeds buffer size"));
        }
        Ok(self.issue(Command::CopyBuffer { src, dst, src_offset, dst_offset, len }, wait))
    }

    /// Enqueue a pattern fill.
    pub fn enqueue_fill_buffer(
        &self,
        buf: Buffer,
        offset: usize,
        pattern: Vec<u8>,
        len: usize,
        wait: &[Event],
    ) -> Result<Event> {
        self.context.check_live(&buf)?;
        if pattern.is_empty() || len % pattern.len() != 0 {
            return Err(Error::invalid("fill length must be a positive multiple of the pattern"));
        }
        if offset + len > buf.size {
            return Err(Error::invalid("fill exceeds buffer size"));
        }
        Ok(self.issue(Command::FillBuffer { buf, offset, pattern, len }, wait))
    }

    /// Enqueue a marker: completes when `wait` completes, or — with an
    /// empty wait-list — when every previously enqueued command completes
    /// (`clEnqueueMarkerWithWaitList` semantics).
    pub fn enqueue_marker(&self, wait: &[Event]) -> Event {
        let deps: Vec<Event> =
            if wait.is_empty() { self.issued.lock().unwrap().all.clone() } else { wait.to_vec() };
        self.issue(Command::Marker, &deps)
    }

    /// Enqueue a barrier: waits on every previously enqueued command, and
    /// every later command implicitly waits on it (out-of-order fence).
    pub fn enqueue_barrier(&self) -> Event {
        let deps: Vec<Event> = self.issued.lock().unwrap().all.clone();
        let ev = self.issue(Command::Barrier, &deps);
        self.issued.lock().unwrap().barrier = Some(ev.clone());
        ev
    }

    /// Submit all queued commands to the workers (`clFlush`). Returns
    /// immediately.
    pub fn flush(&self) {
        self.shared.submit_all();
    }

    /// Flush, then block until every command has completed (`clFinish`).
    /// Returns the first command error, if any.
    pub fn finish(&self) -> Result<()> {
        self.flush();
        let events: Vec<Event> = self.issued.lock().unwrap().all.clone();
        let mut first_err = None;
        for ev in events {
            if let Err(e) = ev.wait() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Every event issued on this queue, in enqueue order (profiling log).
    pub fn events(&self) -> Vec<Event> {
        self.issued.lock().unwrap().all.clone()
    }

    /// Total execution time across completed events (profiling sum).
    pub fn total_kernel_ns(&self) -> u128 {
        self.events().iter().map(Event::duration_ns).sum()
    }

    /// High-water mark of concurrently running commands (worker-pool
    /// instrumentation; ≥ 2 proves overlapped execution).
    pub fn max_concurrency(&self) -> usize {
        self.shared.state.lock().unwrap().max_running
    }
}

impl Drop for CommandQueue {
    fn drop(&mut self) {
        // Implicit flush + finish (clReleaseCommandQueue semantics), then
        // stop the workers.
        let _ = self.finish();
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cl::event::CommandStatus;
    use crate::cl::platform::Platform;

    const VECADD: &str = "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
        size_t i = get_global_id(0);
        c[i] = a[i] + b[i];
    }";

    /// A kernel heavy enough (interpreted) that independent launches
    /// observably overlap on the worker pool.
    const SPIN: &str = "__kernel void spin(__global float *x, int iters) {
        size_t g = get_global_id(0);
        float v = x[g];
        for (int i = 0; i < iters; i++) { v = v * 1.000001f + 1.0f; }
        x[g] = v;
    }";

    fn serial_ctx() -> Arc<Context> {
        let platform = Platform::default_platform();
        Arc::new(Context::new(platform.device("basic-serial").unwrap()))
    }

    #[test]
    fn end_to_end_vecadd_through_host_api() {
        let platform = Platform::default_platform();
        let device = platform.device("pthread-gang(8)").unwrap();
        let ctx = Arc::new(Context::new(device));
        let q = CommandQueue::new(ctx.clone());
        let program = Program::build(VECADD).unwrap();
        let n = 1024;
        let a = ctx.create_buffer(n * 4).unwrap();
        let b = ctx.create_buffer(n * 4).unwrap();
        let c = ctx.create_buffer(n * 4).unwrap();
        let av: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let bv: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();
        let wa = q.enqueue_write_slice(a, &av, &[]).unwrap();
        let wb = q.enqueue_write_slice(b, &bv, &[]).unwrap();
        let mut k = Kernel::new(&program, "vecadd").unwrap();
        k.set_arg(0, KernelArg::Buf(a)).unwrap();
        k.set_arg(1, KernelArg::Buf(b)).unwrap();
        k.set_arg(2, KernelArg::Buf(c)).unwrap();
        let ev = q.enqueue_nd_range(&program, &k, [n, 1, 1], [64, 1, 1], &[wa, wb]).unwrap();
        assert_eq!(ev.status(), CommandStatus::Queued, "deferred until flush");
        let rd = q.enqueue_read_buffer(c, 0, n * 4, &[ev.clone()]).unwrap();
        q.flush();
        let out: Vec<f32> = rd.wait_vec().unwrap();
        let stats = ev.wait().unwrap();
        assert_eq!(stats.workgroups, n / 64);
        assert!(ev.duration_ns() > 0);
        assert!(out.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32));
        q.finish().unwrap();
    }

    #[test]
    fn commands_deferred_until_flush_and_wait_blocks() {
        let ctx = serial_ctx();
        let q = CommandQueue::new(ctx.clone());
        let program =
            Program::build("__kernel void k(__global float *x) { x[get_global_id(0)] = 1.0f; }")
                .unwrap();
        let buf = ctx.create_buffer(64 * 4).unwrap();
        ctx.write_f32(buf, &vec![0.0; 64]).unwrap();
        let mut k = Kernel::new(&program, "k").unwrap();
        k.set_arg(0, KernelArg::Buf(buf)).unwrap();
        let ev = q.enqueue_nd_range(&program, &k, [64, 1, 1], [16, 1, 1], &[]).unwrap();
        // Unflushed → the command cannot have run: memory is untouched.
        assert_eq!(ev.status(), CommandStatus::Queued);
        assert!(ctx.read_f32(buf, 64).unwrap().iter().all(|&v| v == 0.0));
        // wait() implicitly flushes the owning queue, then blocks.
        let stats = ev.wait().unwrap();
        assert_eq!(stats.workgroups, 4);
        assert_eq!(ev.status(), CommandStatus::Complete);
        assert!(ctx.read_f32(buf, 64).unwrap().iter().all(|&v| v == 1.0));
        let p = ev.profile();
        assert!(p.queued_ns <= p.submitted_ns && p.submitted_ns <= p.start_ns);
        assert!(p.start_ns <= p.end_ns);
    }

    #[test]
    fn out_of_order_runs_independent_commands_concurrently() {
        let ctx = serial_ctx();
        let q = CommandQueue::with_properties(ctx.clone(), QueueProperties::OutOfOrder);
        let program = Program::build(SPIN).unwrap();
        let bufs: Vec<_> = (0..3).map(|_| ctx.create_buffer(64 * 4).unwrap()).collect();
        for &b in &bufs {
            ctx.write_f32(b, &vec![0.0; 64]).unwrap();
        }
        for &b in &bufs {
            let mut k = Kernel::new(&program, "spin").unwrap();
            k.set_arg(0, KernelArg::Buf(b)).unwrap();
            k.set_arg(1, KernelArg::I32(20000)).unwrap();
            q.enqueue_nd_range(&program, &k, [64, 1, 1], [32, 1, 1], &[]).unwrap();
        }
        q.finish().unwrap();
        assert!(
            q.max_concurrency() >= 2,
            "independent launches should overlap on the worker pool (saw {})",
            q.max_concurrency()
        );
    }

    #[test]
    fn wait_list_edge_forces_sequential_execution() {
        let ctx = serial_ctx();
        let q = CommandQueue::with_properties(ctx.clone(), QueueProperties::OutOfOrder);
        let program = Program::build(
            "__kernel void writer(__global float *x) {
                 uint g = (uint)get_global_id(0);
                 x[g] = (float)g * 3.0f;
             }
             __kernel void reader(__global const float *x, __global float *y) {
                 size_t g = get_global_id(0);
                 y[g] = x[g] + 1.0f;
             }",
        )
        .unwrap();
        let n = 256;
        let x = ctx.create_buffer(n * 4).unwrap();
        let y = ctx.create_buffer(n * 4).unwrap();
        let mut kw = Kernel::new(&program, "writer").unwrap();
        kw.set_arg(0, KernelArg::Buf(x)).unwrap();
        let ew = q.enqueue_nd_range(&program, &kw, [n, 1, 1], [32, 1, 1], &[]).unwrap();
        let mut kr = Kernel::new(&program, "reader").unwrap();
        kr.set_arg(0, KernelArg::Buf(x)).unwrap();
        kr.set_arg(1, KernelArg::Buf(y)).unwrap();
        let er = q.enqueue_nd_range(&program, &kr, [n, 1, 1], [32, 1, 1], &[ew.clone()]).unwrap();
        q.finish().unwrap();
        let out = ctx.read_f32(y, n).unwrap();
        assert!(out.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32 + 1.0));
        // The declared edge serialises them: the reader started only
        // after the writer ended.
        assert!(
            er.profile().start_ns >= ew.profile().end_ns,
            "wait-list edge must order writer before reader"
        );
    }

    #[test]
    fn fill_copy_marker_barrier() {
        let ctx = serial_ctx();
        let q = CommandQueue::with_properties(ctx.clone(), QueueProperties::OutOfOrder);
        let a = ctx.create_buffer(64 * 4).unwrap();
        let b = ctx.create_buffer(64 * 4).unwrap();
        let f = q.enqueue_fill_buffer(a, 0, 4.0f32.to_le_bytes().to_vec(), 64 * 4, &[]).unwrap();
        let c = q.enqueue_copy_buffer(a, b, 0, 0, 64 * 4, &[f]).unwrap();
        let m = q.enqueue_marker(&[]);
        let bar = q.enqueue_barrier();
        let rd = q.enqueue_read_buffer(b, 0, 64 * 4, &[]).unwrap();
        q.flush();
        let out: Vec<f32> = rd.wait_vec().unwrap();
        assert!(out.iter().all(|&v| v == 4.0));
        m.wait().unwrap();
        bar.wait().unwrap();
        c.wait().unwrap();
        q.finish().unwrap();
    }

    #[test]
    fn stale_buffer_handles_rejected_at_enqueue() {
        let ctx = serial_ctx();
        let q = CommandQueue::new(ctx.clone());
        let program =
            Program::build("__kernel void k(__global float *x) { x[0] = 1.0f; }").unwrap();
        let buf = ctx.create_buffer(64).unwrap();
        ctx.release_buffer(buf).unwrap();
        let mut k = Kernel::new(&program, "k").unwrap();
        k.set_arg(0, KernelArg::Buf(buf)).unwrap();
        assert!(q.enqueue_nd_range(&program, &k, [8, 1, 1], [8, 1, 1], &[]).is_err());
        assert!(q.enqueue_write_buffer(buf, 0, vec![0u8; 8], &[]).is_err());
        assert!(q.enqueue_read_buffer(buf, 0, 8, &[]).is_err());
    }

    #[test]
    fn failed_dependency_propagates() {
        let ctx = serial_ctx();
        let q = CommandQueue::with_properties(ctx.clone(), QueueProperties::OutOfOrder);
        let buf = ctx.create_buffer(16).unwrap();
        let w = q.enqueue_write_buffer(buf, 0, vec![1u8; 16], &[]).unwrap();
        let r = q.enqueue_read_buffer(buf, 0, 16, &[w.clone()]).unwrap();
        // Release the buffer while the commands are still queued: the
        // write fails its execute-time liveness check, and the failure
        // propagates along the wait-list edge to the read.
        ctx.release_buffer(buf).unwrap();
        q.flush();
        assert!(w.wait().is_err(), "write to a released buffer must fail");
        assert!(r.wait().is_err(), "dependents of failed commands fail too");
        assert!(q.finish().is_err());
    }

    #[test]
    fn invalid_nd_range_rejected() {
        let ctx = serial_ctx();
        let q = CommandQueue::new(ctx);
        let program =
            Program::build("__kernel void k(__global float *x) { x[0] = 1.0f; }").unwrap();
        let k = Kernel::new(&program, "k").unwrap();
        assert!(q.enqueue_nd_range(&program, &k, [10, 1, 1], [3, 1, 1], &[]).is_err());
    }

    #[test]
    fn unset_args_rejected() {
        let ctx = serial_ctx();
        let q = CommandQueue::new(ctx);
        let program =
            Program::build("__kernel void k(__global float *x) { x[0] = 1.0f; }").unwrap();
        let k = Kernel::new(&program, "k").unwrap();
        let e = q.enqueue_nd_range(&program, &k, [8, 1, 1], [8, 1, 1], &[]);
        assert!(e.is_err());
    }
}
