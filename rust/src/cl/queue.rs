//! Command queues (`clCommandQueue` analog) with events and profiling.
//!
//! The queue resolves kernel arguments against the context, asks the
//! program for the enqueue-time specialised work-group function (§4.1),
//! plans local memory, and dispatches to the device layer. Execution is
//! in-order; every enqueue returns an [`Event`] carrying profiling
//! timestamps (`CL_QUEUE_PROFILING_ENABLE` semantics — the §6 benchmarks
//! time kernels this way).

use std::sync::Arc;
use std::time::Instant;

use crate::cl::context::Context;
use crate::cl::error::{Error, Result};
use crate::cl::program::{Kernel, KernelArg, Program};
use crate::devices::{LaunchRequest, LaunchStats};
use crate::exec::value::{SP_GLOBAL, SP_LOCAL};
use crate::exec::VVal;
use crate::kcc::CompileOptions;

/// A completed command's record.
#[derive(Debug, Clone)]
pub struct Event {
    /// What ran (kernel name or transfer).
    pub what: String,
    /// Wall-clock duration in nanoseconds.
    pub duration_ns: u128,
    /// Device statistics for kernel launches.
    pub stats: LaunchStats,
}

/// In-order command queue bound to one context.
pub struct CommandQueue {
    /// The context (device + memory).
    pub context: Arc<Context>,
    /// Completed events (profiling log).
    pub events: Vec<Event>,
}

impl CommandQueue {
    /// Create a queue on a context.
    pub fn new(context: Arc<Context>) -> CommandQueue {
        CommandQueue { context, events: Vec::new() }
    }

    /// Enqueue an ND-range kernel (`clEnqueueNDRangeKernel`).
    ///
    /// `global` must be divisible by `local` in every dimension (OpenCL
    /// 1.2 rule).
    pub fn enqueue_nd_range(
        &mut self,
        program: &Program,
        kernel: &Kernel,
        global: [usize; 3],
        local: [usize; 3],
    ) -> Result<Event> {
        let t0 = Instant::now();
        for d in 0..3 {
            if local[d] == 0 || global[d] % local[d] != 0 {
                return Err(Error::invalid(format!(
                    "global size {global:?} not divisible by local {local:?}"
                )));
            }
        }
        let work_dim = if global[2] > 1 { 3 } else if global[1] > 1 { 2 } else { 1 };
        let mut opts: CompileOptions = self.context.device.compile_options();
        opts.work_dim = work_dim;
        let wgf = program.workgroup_function(&kernel.name, local, &opts)?;

        // Resolve arguments: buffers → global offsets; local sizes →
        // local offsets; auto-locals appended after user args.
        let kfun = program.module.kernel(&kernel.name).unwrap();
        let mut args: Vec<VVal> = Vec::with_capacity(kfun.params.len());
        let mut local_off = 0usize;
        let mut user_idx = 0usize;
        for p in &kfun.params {
            if let Some(bytes) = p.auto_local_size {
                args.push(VVal::ptr(SP_LOCAL, local_off as u64));
                local_off += bytes;
                continue;
            }
            let a = kernel.args.get(user_idx).and_then(|a| a.as_ref()).ok_or_else(|| {
                Error::invalid(format!("kernel `{}` arg {user_idx} not set", kernel.name))
            })?;
            user_idx += 1;
            args.push(match a {
                KernelArg::Buf(b) => VVal::ptr(SP_GLOBAL, b.offset as u64),
                KernelArg::LocalSize(sz) => {
                    let v = VVal::ptr(SP_LOCAL, local_off as u64);
                    local_off += sz;
                    v
                }
                KernelArg::I32(v) => VVal::i(*v as i64),
                KernelArg::U32(v) => VVal::i(*v as i64),
                KernelArg::U64(v) => VVal::i(*v as i64),
                KernelArg::F32(v) => VVal::f(*v as f64),
            });
        }

        let groups = [global[0] / local[0], global[1] / local[1], global[2] / local[2]];
        let req = LaunchRequest {
            wgf: &wgf,
            args,
            groups,
            offset: [0; 3],
            work_dim,
            local_mem: local_off,
        };
        let mut g = self.context.global.lock().unwrap();
        let stats = self.context.device.launch(&mut g, &req)?;
        drop(g);
        let ev = Event {
            what: kernel.name.clone(),
            duration_ns: t0.elapsed().as_nanos(),
            stats,
        };
        self.events.push(ev.clone());
        Ok(ev)
    }

    /// Total kernel time across recorded events (profiling sum).
    pub fn total_kernel_ns(&self) -> u128 {
        self.events.iter().map(|e| e.duration_ns).sum()
    }

    /// Wait for completion (in-order queue executes eagerly; kept for API
    /// parity with `clFinish`).
    pub fn finish(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cl::platform::Platform;

    #[test]
    fn end_to_end_vecadd_through_host_api() {
        let platform = Platform::default_platform();
        let device = platform.device("pthread-gang(8)").unwrap();
        let ctx = Arc::new(Context::new(device));
        let mut q = CommandQueue::new(ctx.clone());
        let program = Program::build(
            "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
                 size_t i = get_global_id(0);
                 c[i] = a[i] + b[i];
             }",
        )
        .unwrap();
        let n = 1024;
        let a = ctx.create_buffer(n * 4).unwrap();
        let b = ctx.create_buffer(n * 4).unwrap();
        let c = ctx.create_buffer(n * 4).unwrap();
        let av: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let bv: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();
        ctx.write_f32(a, &av).unwrap();
        ctx.write_f32(b, &bv).unwrap();
        let mut k = Kernel::new(&program, "vecadd").unwrap();
        k.set_arg(0, KernelArg::Buf(a)).unwrap();
        k.set_arg(1, KernelArg::Buf(b)).unwrap();
        k.set_arg(2, KernelArg::Buf(c)).unwrap();
        let ev = q.enqueue_nd_range(&program, &k, [n, 1, 1], [64, 1, 1]).unwrap();
        assert_eq!(ev.stats.workgroups, n / 64);
        let out = ctx.read_f32(c, n).unwrap();
        assert!(out.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32));
    }

    #[test]
    fn invalid_nd_range_rejected() {
        let platform = Platform::default_platform();
        let ctx = Arc::new(Context::new(platform.device("basic").unwrap()));
        let mut q = CommandQueue::new(ctx);
        let program =
            Program::build("__kernel void k(__global float *x) { x[0] = 1.0f; }").unwrap();
        let k = Kernel::new(&program, "k").unwrap();
        assert!(q.enqueue_nd_range(&program, &k, [10, 1, 1], [3, 1, 1]).is_err());
    }

    #[test]
    fn unset_args_rejected() {
        let platform = Platform::default_platform();
        let ctx = Arc::new(Context::new(platform.device("basic").unwrap()));
        let mut q = CommandQueue::new(ctx);
        let program =
            Program::build("__kernel void k(__global float *x) { x[0] = 1.0f; }").unwrap();
        let k = Kernel::new(&program, "k").unwrap();
        let e = q.enqueue_nd_range(&program, &k, [8, 1, 1], [8, 1, 1]);
        assert!(e.is_err());
    }
}
