//! Live events (`cl_event` analog) for deferred commands.
//!
//! Every `enqueue_*` call on a [`crate::cl::CommandQueue`] returns an
//! [`Event`] — a shared handle onto the command's lifecycle. The status
//! progresses
//!
//! ```text
//! Queued → Submitted → Running → Complete
//!                   ╲→ Error (command failed or a dependency failed)
//! ```
//!
//! mirroring OpenCL's `CL_QUEUED / CL_SUBMITTED / CL_RUNNING /
//! CL_COMPLETE` execution statuses. Events double as the edges of the
//! command dependency DAG (wait-lists) and carry
//! `CL_QUEUE_PROFILING_ENABLE`-style timestamps for each transition,
//! taken against the owning queue's creation instant.
//!
//! [`Event::wait`] blocks until the command finishes; like
//! `clWaitForEvents` it implicitly flushes the owning queue first, so
//! waiting on a merely-queued command cannot deadlock. Buffer reads
//! deliver their data through the event ([`Event::wait_data`] /
//! [`Event::wait_vec`]).

use std::fmt;
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};

use crate::cl::context::{vec_from_bytes, Scalar};
use crate::cl::error::{Error, Result};
use crate::cl::queue::SchedulerShared;
use crate::devices::LaunchStats;
use crate::sched::SchedStats;
use crate::trace;

/// Tracer identity of one command: the async track it renders on (its
/// queue's track) and its process-unique async-span / flow-arrow id.
#[derive(Debug, Clone, Copy)]
struct TraceIds {
    track: u64,
    id: u64,
}

/// Execution status of a command (ordered by lifecycle progress).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CommandStatus {
    /// Enqueued on the host queue, not yet submitted to the scheduler.
    Queued,
    /// Submitted (the queue was flushed); eligible to run once its
    /// wait-list dependencies complete.
    Submitted,
    /// Executing on a queue worker.
    Running,
    /// Finished successfully.
    Complete,
    /// Finished with an error (its own, or a failed dependency).
    Error,
}

/// Profiling timestamps in nanoseconds since the owning queue's creation.
#[derive(Debug, Clone, Copy, Default)]
pub struct EventProfile {
    /// When the command was enqueued (`CL_PROFILING_COMMAND_QUEUED`).
    pub queued_ns: u64,
    /// When the queue submitted it (`CL_PROFILING_COMMAND_SUBMIT`).
    pub submitted_ns: u64,
    /// When a worker started executing it (`CL_PROFILING_COMMAND_START`).
    pub start_ns: u64,
    /// When execution finished (`CL_PROFILING_COMMAND_END`).
    pub end_ns: u64,
}

struct EventState {
    status: CommandStatus,
    profile: EventProfile,
    stats: LaunchStats,
    sched: Option<SchedStats>,
    payload: Option<Vec<u8>>,
    error: Option<Error>,
}

struct EventInner {
    what: String,
    state: Mutex<EventState>,
    cv: Condvar,
    /// Back-reference to the owning queue's scheduler so `wait()` can
    /// flush it (avoids the wait-on-unflushed-queue deadlock). `None` for
    /// events produced by the context's blocking helpers.
    scheduler: Mutex<Option<Weak<SchedulerShared>>>,
    /// Tracer identity, set once when the owning queue issues the
    /// command while tracing is enabled.
    trace: OnceLock<TraceIds>,
}

/// A live handle onto one enqueued command. Cheap to clone; clones share
/// the same underlying state.
#[derive(Clone)]
pub struct Event(Arc<EventInner>);

impl Event {
    /// Create a fresh event in the `Queued` state.
    pub(crate) fn new(what: impl Into<String>, queued_ns: u64) -> Event {
        Event(Arc::new(EventInner {
            what: what.into(),
            state: Mutex::new(EventState {
                status: CommandStatus::Queued,
                profile: EventProfile { queued_ns, ..Default::default() },
                stats: LaunchStats::default(),
                sched: None,
                payload: None,
                error: None,
            }),
            cv: Condvar::new(),
            scheduler: Mutex::new(None),
            trace: OnceLock::new(),
        }))
    }

    /// Open this command's async trace span on `track` (the owning
    /// queue's track). No-op unless tracing is enabled.
    pub(crate) fn trace_begin(&self, track: u64) {
        if !trace::enabled() {
            return;
        }
        let ids = TraceIds { track, id: trace::next_id() };
        if self.0.trace.set(ids).is_ok() {
            trace::async_begin(trace::CAT_QUEUE, self.0.what.clone(), ids.track, ids.id);
        }
    }

    /// The flow-arrow id of this command's trace span, if it has one
    /// (used to draw wait-list edges between command spans).
    pub(crate) fn trace_id(&self) -> Option<u64> {
        self.0.trace.get().map(|t| t.id)
    }

    fn trace_mark(&self, name: &'static str) {
        if let Some(t) = self.0.trace.get() {
            trace::async_instant(trace::CAT_QUEUE, name, t.track, t.id);
        }
    }

    fn trace_end(&self) {
        if let Some(t) = self.0.trace.get() {
            trace::async_end(trace::CAT_QUEUE, self.0.what.clone(), t.track, t.id);
        }
    }

    /// Attach the owning queue's scheduler (for the implicit flush in
    /// `wait`).
    pub(crate) fn attach_scheduler(&self, scheduler: Weak<SchedulerShared>) {
        *self.0.scheduler.lock().unwrap() = Some(scheduler);
    }

    /// What this command is (kernel name or transfer kind).
    pub fn what(&self) -> &str {
        &self.0.what
    }

    /// Current status.
    pub fn status(&self) -> CommandStatus {
        self.0.state.lock().unwrap().status
    }

    /// True once the command reached `Complete` or `Error`.
    pub fn is_finished(&self) -> bool {
        matches!(self.status(), CommandStatus::Complete | CommandStatus::Error)
    }

    /// The command's error, if it finished unsuccessfully.
    pub(crate) fn error_of(&self) -> Option<Error> {
        let st = self.0.state.lock().unwrap();
        if st.status == CommandStatus::Error {
            Some(st.error.clone().unwrap_or_else(|| Error::exec("command failed")))
        } else {
            None
        }
    }

    /// Submit the owning queue if this event is still merely queued
    /// (used by schedulers to unstick commands that wait on events of a
    /// different, never-flushed queue).
    pub(crate) fn ensure_submitted(&self) {
        if self.status() == CommandStatus::Queued {
            let sched = self.0.scheduler.lock().unwrap().clone();
            if let Some(weak) = sched {
                if let Some(shared) = weak.upgrade() {
                    shared.submit_all();
                }
            }
        }
    }

    pub(crate) fn mark_submitted(&self, ns: u64) {
        let newly = {
            let mut st = self.0.state.lock().unwrap();
            if st.status == CommandStatus::Queued {
                st.status = CommandStatus::Submitted;
                st.profile.submitted_ns = ns;
                true
            } else {
                false
            }
        };
        if newly {
            self.trace_mark("submitted");
        }
    }

    pub(crate) fn mark_running(&self, ns: u64) {
        {
            let mut st = self.0.state.lock().unwrap();
            st.status = CommandStatus::Running;
            st.profile.start_ns = ns;
        }
        self.trace_mark("running");
    }

    /// Complete the command successfully at `ns`. For split launches,
    /// `exec_span_ns` carries the union of all member sub-launch spans
    /// as `(start, end)` queue-relative nanoseconds, so profiling covers
    /// earliest-member-start → latest-member-end rather than just the
    /// dispatching worker's return time.
    pub(crate) fn complete_ok(
        &self,
        ns: u64,
        stats: LaunchStats,
        sched: Option<SchedStats>,
        payload: Option<Vec<u8>>,
        exec_span_ns: Option<(u64, u64)>,
    ) {
        {
            let mut st = self.0.state.lock().unwrap();
            st.status = CommandStatus::Complete;
            st.profile.end_ns = ns;
            if let Some((start, end)) = exec_span_ns {
                if start <= end && start >= st.profile.submitted_ns {
                    st.profile.start_ns = start;
                    st.profile.end_ns = end.max(st.profile.start_ns);
                }
            }
            st.stats = stats;
            st.sched = sched;
            st.payload = payload;
        }
        // Close the trace span before waking waiters: a woken waiter may
        // immediately drain the trace buffer, and the async `e` event must
        // already be there for the span to balance.
        self.trace_end();
        self.0.cv.notify_all();
    }

    pub(crate) fn complete_err(&self, ns: u64, err: Error) {
        {
            let mut st = self.0.state.lock().unwrap();
            st.status = CommandStatus::Error;
            st.profile.end_ns = ns;
            st.error = Some(err);
        }
        self.trace_end();
        self.0.cv.notify_all();
    }

    /// Block until the command finishes (flushing the owning queue first,
    /// like `clWaitForEvents`). Returns the device statistics on success.
    pub fn wait(&self) -> Result<LaunchStats> {
        let sched = self.0.scheduler.lock().unwrap().clone();
        if let Some(weak) = sched {
            if let Some(shared) = weak.upgrade() {
                shared.submit_all();
            }
        }
        let mut st = self.0.state.lock().unwrap();
        while !matches!(st.status, CommandStatus::Complete | CommandStatus::Error) {
            st = self.0.cv.wait(st).unwrap();
        }
        match &st.error {
            Some(e) => Err(e.clone()),
            None => Ok(st.stats),
        }
    }

    /// Wait, then take the command's result bytes (buffer reads only).
    /// The payload can be taken once.
    pub fn wait_data(&self) -> Result<Vec<u8>> {
        self.wait()?;
        self.0.state.lock().unwrap().payload.take().ok_or_else(|| {
            Error::invalid(format!(
                "event `{}` carries no data (not a read, or already taken)",
                self.0.what
            ))
        })
    }

    /// Wait, then decode the result bytes as a typed vector.
    pub fn wait_vec<T: Scalar>(&self) -> Result<Vec<T>> {
        Ok(vec_from_bytes(&self.wait_data()?))
    }

    /// Profiling timestamps recorded so far.
    pub fn profile(&self) -> EventProfile {
        self.0.state.lock().unwrap().profile
    }

    /// Execution duration (start → end) in nanoseconds; 0 until complete.
    pub fn duration_ns(&self) -> u128 {
        let st = self.0.state.lock().unwrap();
        if st.status == CommandStatus::Complete && st.profile.end_ns >= st.profile.start_ns {
            (st.profile.end_ns - st.profile.start_ns) as u128
        } else {
            0
        }
    }

    /// Device statistics, once complete.
    pub fn stats(&self) -> Option<LaunchStats> {
        let st = self.0.state.lock().unwrap();
        if st.status == CommandStatus::Complete {
            Some(st.stats)
        } else {
            None
        }
    }

    /// Per-device scheduler breakdown, once complete. `None` for
    /// commands that did not run through a device group's split path.
    pub fn sched_stats(&self) -> Option<SchedStats> {
        let st = self.0.state.lock().unwrap();
        if st.status == CommandStatus::Complete {
            st.sched.clone()
        } else {
            None
        }
    }
}

impl fmt::Debug for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Event")
            .field("what", &self.0.what)
            .field("status", &self.status())
            .finish()
    }
}
