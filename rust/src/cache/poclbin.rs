//! The `poclbin` binary format: a versioned, deterministic serialization
//! of compiled kernel artifacts, with **no external dependencies**.
//!
//! Three payload kinds share one envelope:
//!
//! * an [`ir::Module`](crate::ir::Module) (frontend output),
//! * a [`WorkGroupFunction`] (one §4.1 enqueue-time specialisation —
//!   this is what the on-disk kernel cache stores per
//!   [`CacheKey`](crate::cache::CacheKey) (see `cache::key`)),
//! * a [`ProgramBinary`] (module + all cached specialisations — what
//!   `Program::binaries()` / `Program::from_binary` exchange, the
//!   `clGetProgramInfo(CL_PROGRAM_BINARIES)` / `clCreateProgramWithBinary`
//!   analog).
//!
//! # Envelope
//!
//! ```text
//! offset size  field
//! 0      8     magic  b"POCLBIN\0"
//! 8      4     format version (u32 LE) = POCLBIN_VERSION
//! 12     1     payload kind (module / wgf / program)
//! 13     8     payload length (u64 LE)
//! 21     16    payload digest (128-bit FNV-1a, LE)
//! 37     ...   payload
//! ```
//!
//! Decoding checks magic, version, kind, length and digest **before**
//! touching the payload, so truncated, corrupted, or version-bumped
//! files fail with [`Error::BadBinary`] (the disk cache maps that to a
//! miss). All integers are little-endian; floats are serialized as IEEE
//! bit patterns, so round-trips are bit-exact (NaNs included).
//!
//! The encoding is deterministic — the same in-memory value always
//! produces the same bytes — which is what makes content-addressed
//! storage and the round-trip-vs-`ir::print` golden tests possible.

use crate::cl::error::{Error, Result};
use crate::exec::bytecode::{BcConst, BcInst, BcRegion, BytecodeProgram};
use crate::ir::{
    AddrSpace, AllocaInfo, BarrierKind, BinOp, Block, BlockId, Function, Imm, Inst, MathFn,
    Module, Operand, Param, Reg, Scalar, SlotId, Term, Type, UnOp, WiFn, WiLoopMeta,
};
use crate::kcc::{
    CompileOptions, CompileStats, OptLevel, OptStats, Region, TargetKind, WorkGroupFunction,
};

use super::key::{fnv128, SpecKey};

/// File magic.
pub const POCLBIN_MAGIC: [u8; 8] = *b"POCLBIN\0";
/// Format version. Bump on any encoding change: old files then decode as
/// [`Error::BadBinary`] and cache lookups fall back to a clean recompile.
/// v2: `CompileOptions::opt_level` + `CompileStats::opt` (optimizer).
/// v3: `WorkGroupFunction::bytecode` (threaded-bytecode tier) +
/// `CompileStats` bytecode counters.
/// v4: `CompileStats` jit counters (the jitted code itself is never
/// serialised — machine code is re-lowered from the cached bytecode).
pub const POCLBIN_VERSION: u32 = 4;

/// Envelope size in bytes (magic + version + kind + length + digest).
pub const HEADER_LEN: usize = 8 + 4 + 1 + 8 + 16;

const KIND_MODULE: u8 = 1;
const KIND_WGF: u8 = 2;
const KIND_PROGRAM: u8 = 3;

fn bad(msg: impl Into<String>) -> Error {
    Error::BadBinary(msg.into())
}

// ---------------------------------------------------------------------
// Byte-level writer / reader
// ---------------------------------------------------------------------

/// Append-only payload writer.
struct W {
    buf: Vec<u8>,
}

impl W {
    fn new() -> W {
        W { buf: Vec::with_capacity(1024) }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Checked payload reader: every read fails cleanly on truncation.
struct R<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn new(buf: &'a [u8]) -> R<'a> {
        R { buf, pos: 0 }
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(bad(format!(
                "truncated payload: need {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(bad(format!("bad bool byte {v}"))),
        }
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.len_prefix()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| bad("non-UTF-8 string"))
    }
    /// A u32 length prefix, sanity-capped by the bytes actually left so a
    /// bogus length can never trigger a huge allocation.
    fn len_prefix(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        if n > self.remaining() {
            return Err(bad(format!(
                "length prefix {n} exceeds {} remaining bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }
    fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(bad(format!("{} trailing payload bytes", self.remaining())));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Per-type codec
// ---------------------------------------------------------------------

/// Symmetric encode/decode for one IR type. Field order in `put` and
/// `get` must match exactly; the round-trip tests hold this invariant.
trait Codec: Sized {
    fn put(&self, w: &mut W);
    fn get(r: &mut R) -> Result<Self>;
}

/// Codec for a fieldless enum as a single tag byte, with strict
/// rejection of unknown tags on decode.
macro_rules! tag_enum {
    ($ty:ident { $($variant:ident = $tag:literal),+ $(,)? }) => {
        impl Codec for $ty {
            fn put(&self, w: &mut W) {
                w.u8(match self { $( $ty::$variant => $tag, )+ });
            }
            fn get(r: &mut R) -> Result<Self> {
                Ok(match r.u8()? {
                    $( $tag => $ty::$variant, )+
                    t => return Err(bad(format!("bad {} tag {t}", stringify!($ty)))),
                })
            }
        }
    };
}

tag_enum!(Scalar { Bool = 0, I32 = 1, U32 = 2, I64 = 3, U64 = 4, F32 = 5, F64 = 6 });
tag_enum!(AddrSpace { Global = 0, Local = 1, Constant = 2, Private = 3 });
tag_enum!(UnOp { Neg = 0, Not = 1, LNot = 2 });
tag_enum!(BarrierKind { Explicit = 0, Implicit = 1 });
tag_enum!(TargetKind { Cpu = 0, Tta = 1, Spmd = 2 });
tag_enum!(OptLevel { O0 = 0, O1 = 1, O2 = 2 });
tag_enum!(BinOp {
    Add = 0, Sub = 1, Mul = 2, Div = 3, Rem = 4, And = 5, Or = 6, Xor = 7,
    Shl = 8, Shr = 9, Eq = 10, Ne = 11, Lt = 12, Le = 13, Gt = 14, Ge = 15,
    LAnd = 16, LOr = 17,
});
tag_enum!(WiFn {
    GlobalId = 0, LocalId = 1, GroupId = 2, GlobalSize = 3, LocalSize = 4,
    NumGroups = 5, WorkDim = 6, GlobalOffset = 7,
});
tag_enum!(MathFn {
    Sqrt = 0, RSqrt = 1, Exp = 2, Exp2 = 3, Log = 4, Log2 = 5, Sin = 6,
    Cos = 7, Tan = 8, Fabs = 9, Floor = 10, Ceil = 11, Round = 12,
    Trunc = 13, Pow = 14, Fmin = 15, Fmax = 16, Fmod = 17, Mad = 18,
    Fma = 19, Min = 20, Max = 21, Clamp = 22, Abs = 23, Mix = 24, Dot = 25,
    Length = 26, Normalize = 27, Distance = 28, NativeSqrt = 29,
    NativeRSqrt = 30, NativeExp = 31, NativeLog = 32, NativeSin = 33,
    NativeCos = 34, NativeDivide = 35, NativeRecip = 36,
});

impl Codec for usize {
    fn put(&self, w: &mut W) {
        w.u64(*self as u64);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(r.u64()? as usize)
    }
}

impl Codec for bool {
    fn put(&self, w: &mut W) {
        w.bool(*self);
    }
    fn get(r: &mut R) -> Result<Self> {
        r.bool()
    }
}

impl Codec for Reg {
    fn put(&self, w: &mut W) {
        w.u32(self.0);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(Reg(r.u32()?))
    }
}

impl Codec for BlockId {
    fn put(&self, w: &mut W) {
        w.u32(self.0);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(BlockId(r.u32()?))
    }
}

impl Codec for SlotId {
    fn put(&self, w: &mut W) {
        w.u32(self.0);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(SlotId(r.u32()?))
    }
}

impl<T: Codec> Codec for Option<T> {
    fn put(&self, w: &mut W) {
        match self {
            None => w.u8(0),
            Some(v) => {
                w.u8(1);
                v.put(w);
            }
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        match r.u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::get(r)?)),
            t => Err(bad(format!("bad Option tag {t}"))),
        }
    }
}

impl<T: Codec> Codec for Vec<T> {
    fn put(&self, w: &mut W) {
        w.u32(self.len() as u32);
        for v in self {
            v.put(w);
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        let n = r.len_prefix()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::get(r)?);
        }
        Ok(out)
    }
}

impl Codec for Type {
    fn put(&self, w: &mut W) {
        match self {
            Type::Void => w.u8(0),
            Type::Scalar(s) => {
                w.u8(1);
                s.put(w);
            }
            Type::Vec(s, n) => {
                w.u8(2);
                s.put(w);
                w.u8(*n);
            }
            Type::Ptr(elem, sp) => {
                w.u8(3);
                elem.put(w);
                sp.put(w);
            }
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(match r.u8()? {
            0 => Type::Void,
            1 => Type::Scalar(Scalar::get(r)?),
            2 => Type::Vec(Scalar::get(r)?, r.u8()?),
            3 => Type::Ptr(Box::new(Type::get(r)?), AddrSpace::get(r)?),
            t => return Err(bad(format!("bad Type tag {t}"))),
        })
    }
}

impl Codec for Imm {
    fn put(&self, w: &mut W) {
        match self {
            Imm::Int(v, s) => {
                w.u8(0);
                w.i64(*v);
                s.put(w);
            }
            Imm::Float(v, s) => {
                w.u8(1);
                w.u64(v.to_bits());
                s.put(w);
            }
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(match r.u8()? {
            0 => Imm::Int(r.i64()?, Scalar::get(r)?),
            1 => Imm::Float(f64::from_bits(r.u64()?), Scalar::get(r)?),
            t => return Err(bad(format!("bad Imm tag {t}"))),
        })
    }
}

impl Codec for Operand {
    fn put(&self, w: &mut W) {
        match self {
            Operand::Reg(v) => {
                w.u8(0);
                v.put(w);
            }
            Operand::Imm(v) => {
                w.u8(1);
                v.put(w);
            }
            Operand::Arg(v) => {
                w.u8(2);
                w.u32(*v);
            }
            Operand::Slot(v) => {
                w.u8(3);
                v.put(w);
            }
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(match r.u8()? {
            0 => Operand::Reg(Reg::get(r)?),
            1 => Operand::Imm(Imm::get(r)?),
            2 => Operand::Arg(r.u32()?),
            3 => Operand::Slot(SlotId::get(r)?),
            t => return Err(bad(format!("bad Operand tag {t}"))),
        })
    }
}

impl Codec for Inst {
    fn put(&self, w: &mut W) {
        match self {
            Inst::Bin { op, ty, a, b } => {
                w.u8(0);
                op.put(w);
                ty.put(w);
                a.put(w);
                b.put(w);
            }
            Inst::Un { op, ty, a } => {
                w.u8(1);
                op.put(w);
                ty.put(w);
                a.put(w);
            }
            Inst::Cast { to, from, a } => {
                w.u8(2);
                to.put(w);
                from.put(w);
                a.put(w);
            }
            Inst::Load { ty, ptr } => {
                w.u8(3);
                ty.put(w);
                ptr.put(w);
            }
            Inst::Store { ty, ptr, val } => {
                w.u8(4);
                ty.put(w);
                ptr.put(w);
                val.put(w);
            }
            Inst::Gep { elem, base, idx } => {
                w.u8(5);
                elem.put(w);
                base.put(w);
                idx.put(w);
            }
            Inst::Wi { func, dim } => {
                w.u8(6);
                func.put(w);
                w.u32(*dim);
            }
            Inst::Math { func, ty, args } => {
                w.u8(7);
                func.put(w);
                ty.put(w);
                args.put(w);
            }
            Inst::Select { ty, cond, a, b } => {
                w.u8(8);
                ty.put(w);
                cond.put(w);
                a.put(w);
                b.put(w);
            }
            Inst::VecBuild { ty, elems } => {
                w.u8(9);
                ty.put(w);
                elems.put(w);
            }
            Inst::VecExtract { elem, a, lane } => {
                w.u8(10);
                elem.put(w);
                a.put(w);
                w.u32(*lane);
            }
            Inst::VecInsert { ty, a, lane, v } => {
                w.u8(11);
                ty.put(w);
                a.put(w);
                w.u32(*lane);
                v.put(w);
            }
            Inst::Splat { ty, a } => {
                w.u8(12);
                ty.put(w);
                a.put(w);
            }
            Inst::Barrier { kind } => {
                w.u8(13);
                kind.put(w);
            }
            Inst::Marker { label } => {
                w.u8(14);
                w.u32(*label);
            }
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(match r.u8()? {
            0 => Inst::Bin {
                op: BinOp::get(r)?,
                ty: Type::get(r)?,
                a: Operand::get(r)?,
                b: Operand::get(r)?,
            },
            1 => Inst::Un { op: UnOp::get(r)?, ty: Type::get(r)?, a: Operand::get(r)? },
            2 => Inst::Cast { to: Type::get(r)?, from: Type::get(r)?, a: Operand::get(r)? },
            3 => Inst::Load { ty: Type::get(r)?, ptr: Operand::get(r)? },
            4 => Inst::Store { ty: Type::get(r)?, ptr: Operand::get(r)?, val: Operand::get(r)? },
            5 => Inst::Gep { elem: Type::get(r)?, base: Operand::get(r)?, idx: Operand::get(r)? },
            6 => Inst::Wi { func: WiFn::get(r)?, dim: r.u32()? },
            7 => Inst::Math { func: MathFn::get(r)?, ty: Type::get(r)?, args: Vec::get(r)? },
            8 => Inst::Select {
                ty: Type::get(r)?,
                cond: Operand::get(r)?,
                a: Operand::get(r)?,
                b: Operand::get(r)?,
            },
            9 => Inst::VecBuild { ty: Type::get(r)?, elems: Vec::get(r)? },
            10 => Inst::VecExtract { elem: Type::get(r)?, a: Operand::get(r)?, lane: r.u32()? },
            11 => Inst::VecInsert {
                ty: Type::get(r)?,
                a: Operand::get(r)?,
                lane: r.u32()?,
                v: Operand::get(r)?,
            },
            12 => Inst::Splat { ty: Type::get(r)?, a: Operand::get(r)? },
            13 => Inst::Barrier { kind: BarrierKind::get(r)? },
            14 => Inst::Marker { label: r.u32()? },
            t => return Err(bad(format!("bad Inst tag {t}"))),
        })
    }
}

impl Codec for Term {
    fn put(&self, w: &mut W) {
        match self {
            Term::Jump(b) => {
                w.u8(0);
                b.put(w);
            }
            Term::Br { cond, t, f } => {
                w.u8(1);
                cond.put(w);
                t.put(w);
                f.put(w);
            }
            Term::Ret => w.u8(2),
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(match r.u8()? {
            0 => Term::Jump(BlockId::get(r)?),
            1 => Term::Br { cond: Operand::get(r)?, t: BlockId::get(r)?, f: BlockId::get(r)? },
            2 => Term::Ret,
            t => return Err(bad(format!("bad Term tag {t}"))),
        })
    }
}

impl Codec for Block {
    fn put(&self, w: &mut W) {
        w.str(&self.name);
        w.u32(self.insts.len() as u32);
        for (reg, inst) in &self.insts {
            reg.put(w);
            inst.put(w);
        }
        self.term.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        let name = r.str()?;
        let n = r.len_prefix()?;
        let mut insts = Vec::with_capacity(n);
        for _ in 0..n {
            let reg = Option::<Reg>::get(r)?;
            let inst = Inst::get(r)?;
            insts.push((reg, inst));
        }
        let term = Term::get(r)?;
        Ok(Block { name, insts, term })
    }
}

impl Codec for Param {
    fn put(&self, w: &mut W) {
        w.str(&self.name);
        self.ty.put(w);
        w.bool(self.is_local_buf);
        self.auto_local_size.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(Param {
            name: r.str()?,
            ty: Type::get(r)?,
            is_local_buf: r.bool()?,
            auto_local_size: Option::get(r)?,
        })
    }
}

impl Codec for AllocaInfo {
    fn put(&self, w: &mut W) {
        w.str(&self.name);
        self.ty.put(w);
        self.count.put(w);
        w.bool(self.privatized);
        w.bool(self.uniform);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(AllocaInfo {
            name: r.str()?,
            ty: Type::get(r)?,
            count: usize::get(r)?,
            privatized: r.bool()?,
            uniform: r.bool()?,
        })
    }
}

impl Codec for WiLoopMeta {
    fn put(&self, w: &mut W) {
        self.region.put(w);
        w.u32(self.dim);
        self.header.put(w);
        self.latch.put(w);
        self.trip_count.put(w);
        w.bool(self.parallel);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(WiLoopMeta {
            region: usize::get(r)?,
            dim: r.u32()?,
            header: BlockId::get(r)?,
            latch: BlockId::get(r)?,
            trip_count: Option::get(r)?,
            parallel: r.bool()?,
        })
    }
}

impl Codec for Function {
    fn put(&self, w: &mut W) {
        w.str(&self.name);
        self.params.put(w);
        self.entry.put(w);
        self.blocks.put(w);
        self.slots.put(w);
        w.u32(self.reg_count());
        self.wi_loops.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        let name = r.str()?;
        let params = Vec::get(r)?;
        let entry = BlockId::get(r)?;
        let blocks: Vec<Block> = Vec::get(r)?;
        let slots = Vec::get(r)?;
        let reg_count = r.u32()?;
        let wi_loops: Vec<WiLoopMeta> = Vec::get(r)?;
        if (entry.0 as usize) >= blocks.len() {
            return Err(bad(format!("entry bb{} out of range ({} blocks)", entry.0, blocks.len())));
        }
        for m in &wi_loops {
            if m.header.0 as usize >= blocks.len() || m.latch.0 as usize >= blocks.len() {
                return Err(bad(format!("wi-loop block ids out of range in `{name}`")));
            }
        }
        // Every register the engines will index must fit the frame the
        // serialized high-water mark sizes. The verifier guarantees uses
        // are covered by block-local defs, so checking defs (plus branch
        // conditions, for belt and braces) bounds every register id.
        for b in &blocks {
            for (def, _) in &b.insts {
                if let Some(rg) = def {
                    if rg.0 >= reg_count {
                        return Err(bad(format!(
                            "register r{} exceeds the declared count {reg_count}",
                            rg.0
                        )));
                    }
                }
            }
            if let Term::Br { cond: Operand::Reg(rg), .. } = &b.term {
                if rg.0 >= reg_count {
                    return Err(bad(format!(
                        "branch register r{} exceeds the declared count {reg_count}",
                        rg.0
                    )));
                }
            }
        }
        let f = Function::from_raw_parts(name, params, blocks, entry, slots, reg_count, wi_loops);
        // Full structural verification (terminator targets, slot/arg
        // ranges, register block-locality): a digest only proves the file
        // is what somebody wrote, not that what they wrote is an IR the
        // engines can index into safely.
        crate::ir::verify::verify(&f)
            .map_err(|e| bad(format!("embedded function `{}` rejected: {e}", f.name)))?;
        Ok(f)
    }
}

impl Codec for Region {
    fn put(&self, w: &mut W) {
        self.id.put(w);
        self.pre.put(w);
        self.post.put(w);
        self.blocks.put(w);
        w.bool(self.via_back_edge);
        w.bool(self.needs_peeling);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(Region {
            id: usize::get(r)?,
            pre: BlockId::get(r)?,
            post: BlockId::get(r)?,
            blocks: Vec::get(r)?,
            via_back_edge: r.bool()?,
            needs_peeling: r.bool()?,
        })
    }
}

impl Codec for BcConst {
    fn put(&self, w: &mut W) {
        match self {
            BcConst::Int(v, s) => {
                w.u8(0);
                w.i64(*v);
                s.put(w);
            }
            BcConst::Float(v, s) => {
                w.u8(1);
                w.u64(v.to_bits());
                s.put(w);
            }
            BcConst::Arg(i) => {
                w.u8(2);
                w.u32(*i);
            }
            BcConst::Slot(s) => {
                w.u8(3);
                s.put(w);
            }
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(match r.u8()? {
            0 => BcConst::Int(r.i64()?, Scalar::get(r)?),
            1 => BcConst::Float(f64::from_bits(r.u64()?), Scalar::get(r)?),
            2 => BcConst::Arg(r.u32()?),
            3 => BcConst::Slot(SlotId::get(r)?),
            t => return Err(bad(format!("bad BcConst tag {t}"))),
        })
    }
}

impl Codec for BcInst {
    fn put(&self, w: &mut W) {
        match self {
            BcInst::Bin { op, ty, dst, a, b } => {
                w.u8(0);
                op.put(w);
                ty.put(w);
                w.u32(*dst);
                w.u32(*a);
                w.u32(*b);
            }
            BcInst::Un { op, ty, dst, a } => {
                w.u8(1);
                op.put(w);
                ty.put(w);
                w.u32(*dst);
                w.u32(*a);
            }
            BcInst::Cast { to, from, dst, a } => {
                w.u8(2);
                to.put(w);
                from.put(w);
                w.u32(*dst);
                w.u32(*a);
            }
            BcInst::Load { ty, dst, ptr } => {
                w.u8(3);
                ty.put(w);
                w.u32(*dst);
                w.u32(*ptr);
            }
            BcInst::Store { ty, ptr, val } => {
                w.u8(4);
                ty.put(w);
                w.u32(*ptr);
                w.u32(*val);
            }
            BcInst::Gep { elem, dst, base, idx } => {
                w.u8(5);
                elem.put(w);
                w.u32(*dst);
                w.u32(*base);
                w.u32(*idx);
            }
            BcInst::Wi { func, dim, dst } => {
                w.u8(6);
                func.put(w);
                w.u32(*dim);
                w.u32(*dst);
            }
            BcInst::Math { func, ty, dst, args } => {
                w.u8(7);
                func.put(w);
                ty.put(w);
                w.u32(*dst);
                w.u32(args.len() as u32);
                for a in args {
                    w.u32(*a);
                }
            }
            BcInst::Select { ty, dst, cond, a, b } => {
                w.u8(8);
                ty.put(w);
                w.u32(*dst);
                w.u32(*cond);
                w.u32(*a);
                w.u32(*b);
            }
            BcInst::GepLoad { elem, ty, dst, base, idx } => {
                w.u8(9);
                elem.put(w);
                ty.put(w);
                w.u32(*dst);
                w.u32(*base);
                w.u32(*idx);
            }
            BcInst::LoadBin { op, ty, load_ty, dst, ptr, other, load_first } => {
                w.u8(10);
                op.put(w);
                ty.put(w);
                load_ty.put(w);
                w.u32(*dst);
                w.u32(*ptr);
                w.u32(*other);
                w.bool(*load_first);
            }
            BcInst::BinStore { op, ty, store_ty, ptr, a, b } => {
                w.u8(11);
                op.put(w);
                ty.put(w);
                store_ty.put(w);
                w.u32(*ptr);
                w.u32(*a);
                w.u32(*b);
            }
            BcInst::MulAdd { ty, dst, a, b, c, mul_first } => {
                w.u8(12);
                ty.put(w);
                w.u32(*dst);
                w.u32(*a);
                w.u32(*b);
                w.u32(*c);
                w.bool(*mul_first);
            }
            BcInst::CmpBr { op, ty, a, b, t, f, ir_t, ir_f } => {
                w.u8(13);
                op.put(w);
                ty.put(w);
                w.u32(*a);
                w.u32(*b);
                w.u32(*t);
                w.u32(*f);
                ir_t.put(w);
                ir_f.put(w);
            }
            BcInst::Jump { pc } => {
                w.u8(14);
                w.u32(*pc);
            }
            BcInst::Br { cond, t, f, ir_t, ir_f } => {
                w.u8(15);
                w.u32(*cond);
                w.u32(*t);
                w.u32(*f);
                ir_t.put(w);
                ir_f.put(w);
            }
            BcInst::End { barrier } => {
                w.u8(16);
                barrier.put(w);
            }
        }
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(match r.u8()? {
            0 => BcInst::Bin {
                op: BinOp::get(r)?,
                ty: Type::get(r)?,
                dst: r.u32()?,
                a: r.u32()?,
                b: r.u32()?,
            },
            1 => BcInst::Un {
                op: UnOp::get(r)?,
                ty: Type::get(r)?,
                dst: r.u32()?,
                a: r.u32()?,
            },
            2 => BcInst::Cast {
                to: Type::get(r)?,
                from: Type::get(r)?,
                dst: r.u32()?,
                a: r.u32()?,
            },
            3 => BcInst::Load { ty: Type::get(r)?, dst: r.u32()?, ptr: r.u32()? },
            4 => BcInst::Store { ty: Type::get(r)?, ptr: r.u32()?, val: r.u32()? },
            5 => BcInst::Gep {
                elem: Type::get(r)?,
                dst: r.u32()?,
                base: r.u32()?,
                idx: r.u32()?,
            },
            6 => BcInst::Wi { func: WiFn::get(r)?, dim: r.u32()?, dst: r.u32()? },
            7 => {
                let func = MathFn::get(r)?;
                let ty = Type::get(r)?;
                let dst = r.u32()?;
                let n = r.len_prefix()?;
                let mut args = Vec::with_capacity(n);
                for _ in 0..n {
                    args.push(r.u32()?);
                }
                BcInst::Math { func, ty, dst, args }
            }
            8 => BcInst::Select {
                ty: Type::get(r)?,
                dst: r.u32()?,
                cond: r.u32()?,
                a: r.u32()?,
                b: r.u32()?,
            },
            9 => BcInst::GepLoad {
                elem: Type::get(r)?,
                ty: Type::get(r)?,
                dst: r.u32()?,
                base: r.u32()?,
                idx: r.u32()?,
            },
            10 => BcInst::LoadBin {
                op: BinOp::get(r)?,
                ty: Type::get(r)?,
                load_ty: Type::get(r)?,
                dst: r.u32()?,
                ptr: r.u32()?,
                other: r.u32()?,
                load_first: r.bool()?,
            },
            11 => BcInst::BinStore {
                op: BinOp::get(r)?,
                ty: Type::get(r)?,
                store_ty: Type::get(r)?,
                ptr: r.u32()?,
                a: r.u32()?,
                b: r.u32()?,
            },
            12 => BcInst::MulAdd {
                ty: Type::get(r)?,
                dst: r.u32()?,
                a: r.u32()?,
                b: r.u32()?,
                c: r.u32()?,
                mul_first: r.bool()?,
            },
            13 => BcInst::CmpBr {
                op: BinOp::get(r)?,
                ty: Type::get(r)?,
                a: r.u32()?,
                b: r.u32()?,
                t: r.u32()?,
                f: r.u32()?,
                ir_t: BlockId::get(r)?,
                ir_f: BlockId::get(r)?,
            },
            14 => BcInst::Jump { pc: r.u32()? },
            15 => BcInst::Br {
                cond: r.u32()?,
                t: r.u32()?,
                f: r.u32()?,
                ir_t: BlockId::get(r)?,
                ir_f: BlockId::get(r)?,
            },
            16 => BcInst::End { barrier: BlockId::get(r)? },
            t => return Err(bad(format!("bad BcInst tag {t}"))),
        })
    }
}

impl Codec for BcRegion {
    fn put(&self, w: &mut W) {
        self.start.put(w);
        self.consts.put(w);
        self.code.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(BcRegion { start: BlockId::get(r)?, consts: Vec::get(r)?, code: Vec::get(r)? })
    }
}

impl Codec for BytecodeProgram {
    fn put(&self, w: &mut W) {
        w.u32(self.reg_count);
        self.regions.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(BytecodeProgram { reg_count: r.u32()?, regions: Vec::get(r)? })
    }
}

impl Codec for CompileStats {
    fn put(&self, w: &mut W) {
        self.regions.put(w);
        self.horizontal_loops.put(w);
        self.b_loops.put(w);
        self.taildup_barriers.put(w);
        self.taildup_blocks.put(w);
        self.privatized_slots.put(w);
        self.uniform_slots.put(w);
        self.wi_loops.put(w);
        self.peeled_barriers.put(w);
        self.uniform_regs.put(w);
        self.divergent_regions.put(w);
        self.bytecode_regions.put(w);
        self.bytecode_fused.put(w);
        self.bytecode_insts.put(w);
        self.jit_regions.put(w);
        self.jit_insts.put(w);
        self.jit_fallbacks.put(w);
        self.opt.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(CompileStats {
            regions: usize::get(r)?,
            horizontal_loops: usize::get(r)?,
            b_loops: usize::get(r)?,
            taildup_barriers: usize::get(r)?,
            taildup_blocks: usize::get(r)?,
            privatized_slots: usize::get(r)?,
            uniform_slots: usize::get(r)?,
            wi_loops: usize::get(r)?,
            peeled_barriers: usize::get(r)?,
            uniform_regs: usize::get(r)?,
            divergent_regions: usize::get(r)?,
            bytecode_regions: usize::get(r)?,
            bytecode_fused: usize::get(r)?,
            bytecode_insts: usize::get(r)?,
            jit_regions: usize::get(r)?,
            jit_insts: usize::get(r)?,
            jit_fallbacks: usize::get(r)?,
            opt: OptStats::get(r)?,
        })
    }
}

impl Codec for OptStats {
    fn put(&self, w: &mut W) {
        self.insts_before.put(w);
        self.insts_after.put(w);
        self.blocks_before.put(w);
        self.blocks_after.put(w);
        self.iterations.put(w);
        self.cfg_simplified.put(w);
        self.folded.put(w);
        self.algebraic.put(w);
        self.propagated.put(w);
        self.cse_hits.put(w);
        self.loads_forwarded.put(w);
        self.dce_removed.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(OptStats {
            insts_before: usize::get(r)?,
            insts_after: usize::get(r)?,
            blocks_before: usize::get(r)?,
            blocks_after: usize::get(r)?,
            iterations: usize::get(r)?,
            cfg_simplified: usize::get(r)?,
            folded: usize::get(r)?,
            algebraic: usize::get(r)?,
            propagated: usize::get(r)?,
            cse_hits: usize::get(r)?,
            loads_forwarded: usize::get(r)?,
            dce_removed: usize::get(r)?,
        })
    }
}

impl Codec for CompileOptions {
    fn put(&self, w: &mut W) {
        w.bool(self.horizontal);
        w.u32(self.work_dim);
        w.bool(self.spmd);
        self.target.put(w);
        self.gang_width.put(w);
        self.opt_level.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(CompileOptions {
            horizontal: r.bool()?,
            work_dim: r.u32()?,
            spmd: r.bool()?,
            target: TargetKind::get(r)?,
            gang_width: usize::get(r)?,
            opt_level: OptLevel::get(r)?,
        })
    }
}

impl Codec for WorkGroupFunction {
    fn put(&self, w: &mut W) {
        w.str(&self.name);
        self.reg_fn.put(w);
        self.regions.put(w);
        self.loop_fn.put(w);
        for d in self.local_size {
            d.put(w);
        }
        self.reg_uniform.put(w);
        self.region_divergent.put(w);
        self.stats.put(w);
        self.bytecode.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        let name = r.str()?;
        let reg_fn = Function::get(r)?;
        let regions: Vec<Region> = Vec::get(r)?;
        let loop_fn = Function::get(r)?;
        let local_size = [usize::get(r)?, usize::get(r)?, usize::get(r)?];
        let reg_uniform: Vec<bool> = Vec::get(r)?;
        let region_divergent: Vec<bool> = Vec::get(r)?;
        let stats = CompileStats::get(r)?;
        let bytecode: Option<BytecodeProgram> = Option::get(r)?;
        // Metadata consistency: the engines index these without bounds
        // checks of their own.
        let nblocks = reg_fn.blocks.len() as u32;
        for rg in &regions {
            if rg.pre.0 >= nblocks
                || rg.post.0 >= nblocks
                || rg.blocks.iter().any(|b| b.0 >= nblocks)
            {
                return Err(bad(format!("region {} block ids out of range", rg.id)));
            }
        }
        if reg_uniform.len() != reg_fn.reg_count() as usize {
            return Err(bad("reg_uniform length does not match the register count"));
        }
        if region_divergent.len() != regions.len() {
            return Err(bad("region_divergent length does not match the region count"));
        }
        if let Some(prog) = &bytecode {
            verify_bytecode(prog, &reg_fn)?;
        }
        Ok(WorkGroupFunction {
            name,
            reg_fn,
            regions,
            loop_fn,
            local_size,
            reg_uniform,
            region_divergent,
            stats,
            bytecode,
            // Machine code is never serialised: callers re-attach the
            // jit tier from the decoded bytecode (`exec::jit::attach`).
            jit: None,
        })
    }
}

/// Structural checks on a decoded bytecode program: the engine indexes
/// frames, constant pools and the code array with these values and (like
/// the IR `verify` call above) must never have to bounds-check a cached
/// artifact at dispatch time.
fn verify_bytecode(prog: &BytecodeProgram, reg_fn: &Function) -> Result<()> {
    if prog.reg_count != reg_fn.reg_count() {
        return Err(bad(format!(
            "bytecode register count {} does not match the function's {}",
            prog.reg_count,
            reg_fn.reg_count()
        )));
    }
    let nblocks = reg_fn.blocks.len() as u32;
    let nparams = reg_fn.params.len() as u32;
    let nslots = reg_fn.slots.len() as u32;
    for (i, region) in prog.regions.iter().enumerate() {
        let err = |msg: String| bad(format!("bytecode region {i}: {msg}"));
        if region.start.0 >= nblocks {
            return Err(err(format!("start bb{} out of range", region.start.0)));
        }
        if region.code.is_empty() {
            return Err(err("empty code array".into()));
        }
        let nslot = prog.reg_count + region.consts.len() as u32;
        let npc = region.code.len() as u32;
        for c in &region.consts {
            match c {
                BcConst::Arg(a) if *a >= nparams => {
                    return Err(err(format!("const arg {a} out of range")));
                }
                BcConst::Slot(s) if s.0 >= nslots => {
                    return Err(err(format!("const slot {} out of range", s.0)));
                }
                _ => {}
            }
        }
        let slot = |s: u32| -> Result<()> {
            if s >= nslot {
                return Err(err(format!("slot {s} exceeds frame+pool size {nslot}")));
            }
            Ok(())
        };
        let pc_ok = |p: u32| -> Result<()> {
            if p >= npc {
                return Err(err(format!("pc target {p} exceeds code length {npc}")));
            }
            Ok(())
        };
        let blk = |b: BlockId| -> Result<()> {
            if b.0 >= nblocks {
                return Err(err(format!("IR target bb{} out of range", b.0)));
            }
            Ok(())
        };
        for inst in &region.code {
            match inst {
                BcInst::Bin { dst, a, b, .. } => {
                    slot(*dst)?;
                    slot(*a)?;
                    slot(*b)?;
                }
                BcInst::Un { dst, a, .. } | BcInst::Cast { dst, a, .. } => {
                    slot(*dst)?;
                    slot(*a)?;
                }
                BcInst::Load { dst, ptr, .. } => {
                    slot(*dst)?;
                    slot(*ptr)?;
                }
                BcInst::Store { ptr, val, .. } => {
                    slot(*ptr)?;
                    slot(*val)?;
                }
                BcInst::Gep { dst, base, idx, .. }
                | BcInst::GepLoad { dst, base, idx, .. } => {
                    slot(*dst)?;
                    slot(*base)?;
                    slot(*idx)?;
                }
                BcInst::Wi { dst, .. } => slot(*dst)?,
                BcInst::Math { dst, args, .. } => {
                    slot(*dst)?;
                    for a in args {
                        slot(*a)?;
                    }
                }
                BcInst::Select { dst, cond, a, b, .. } => {
                    slot(*dst)?;
                    slot(*cond)?;
                    slot(*a)?;
                    slot(*b)?;
                }
                BcInst::LoadBin { dst, ptr, other, .. } => {
                    slot(*dst)?;
                    slot(*ptr)?;
                    slot(*other)?;
                }
                BcInst::BinStore { ptr, a, b, .. } => {
                    slot(*ptr)?;
                    slot(*a)?;
                    slot(*b)?;
                }
                BcInst::MulAdd { dst, a, b, c, .. } => {
                    slot(*dst)?;
                    slot(*a)?;
                    slot(*b)?;
                    slot(*c)?;
                }
                BcInst::CmpBr { a, b, t, f, ir_t, ir_f, .. } => {
                    slot(*a)?;
                    slot(*b)?;
                    pc_ok(*t)?;
                    pc_ok(*f)?;
                    blk(*ir_t)?;
                    blk(*ir_f)?;
                }
                BcInst::Jump { pc } => pc_ok(*pc)?,
                BcInst::Br { cond, t, f, ir_t, ir_f } => {
                    slot(*cond)?;
                    pc_ok(*t)?;
                    pc_ok(*f)?;
                    blk(*ir_t)?;
                    blk(*ir_f)?;
                }
                BcInst::End { barrier } => blk(*barrier)?,
            }
        }
    }
    Ok(())
}

impl Codec for SpecKey {
    fn put(&self, w: &mut W) {
        w.str(&self.kernel);
        for d in self.local {
            d.put(w);
        }
        self.opts.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(SpecKey {
            kernel: r.str()?,
            local: [usize::get(r)?, usize::get(r)?, usize::get(r)?],
            opts: CompileOptions::get(r)?,
        })
    }
}

impl Codec for Module {
    fn put(&self, w: &mut W) {
        self.kernels.put(w);
    }
    fn get(r: &mut R) -> Result<Self> {
        Ok(Module { kernels: Vec::get(r)? })
    }
}

// ---------------------------------------------------------------------
// Envelope + public API
// ---------------------------------------------------------------------

/// A whole program as exchanged by `Program::binaries()` /
/// `Program::from_binary`: the IR module plus every cached §4.1
/// specialisation, tagged with the source digest so a reconstructed
/// program keeps addressing the same on-disk cache entries.
#[derive(Debug, Clone)]
pub struct ProgramBinary {
    /// FNV-1a digest of the original MiniCL source text.
    pub source_hash: u128,
    /// Frontend output (single-work-item kernels).
    pub module: Module,
    /// Cached specialisations at export time.
    pub entries: Vec<(SpecKey, WorkGroupFunction)>,
}

fn seal(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&POCLBIN_MAGIC);
    out.extend_from_slice(&POCLBIN_VERSION.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv128(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn open(bytes: &[u8], want_kind: u8) -> Result<&[u8]> {
    if bytes.len() < HEADER_LEN {
        return Err(bad(format!("{} bytes is too short for a poclbin header", bytes.len())));
    }
    if bytes[0..8] != POCLBIN_MAGIC {
        return Err(bad("bad magic (not a poclbin file)"));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != POCLBIN_VERSION {
        return Err(bad(format!("format version {version}, this build reads {POCLBIN_VERSION}")));
    }
    let kind = bytes[12];
    if kind != want_kind {
        return Err(bad(format!("payload kind {kind}, expected {want_kind}")));
    }
    let len = u64::from_le_bytes(bytes[13..21].try_into().unwrap()) as usize;
    let digest = u128::from_le_bytes(bytes[21..37].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    if payload.len() != len {
        return Err(bad(format!("payload length {} != header length {len}", payload.len())));
    }
    if fnv128(payload) != digest {
        return Err(bad("payload digest mismatch (corrupt file)"));
    }
    Ok(payload)
}

fn encode<T: Codec>(kind: u8, value: &T) -> Vec<u8> {
    let mut w = W::new();
    value.put(&mut w);
    seal(kind, &w.buf)
}

fn decode<T: Codec>(kind: u8, bytes: &[u8]) -> Result<T> {
    let payload = open(bytes, kind)?;
    let mut r = R::new(payload);
    let value = T::get(&mut r)?;
    r.finish()?;
    Ok(value)
}

/// Serialize an IR module.
pub fn encode_module(m: &Module) -> Vec<u8> {
    encode(KIND_MODULE, m)
}

/// Deserialize an IR module.
pub fn decode_module(bytes: &[u8]) -> Result<Module> {
    decode(KIND_MODULE, bytes)
}

/// Serialize one compiled work-group function (the on-disk cache entry
/// payload).
pub fn encode_wgf(wgf: &WorkGroupFunction) -> Vec<u8> {
    encode(KIND_WGF, wgf)
}

/// Deserialize one compiled work-group function.
pub fn decode_wgf(bytes: &[u8]) -> Result<WorkGroupFunction> {
    decode(KIND_WGF, bytes)
}

/// Serialize a whole program (module + cached specialisations).
pub fn encode_program(p: &ProgramBinary) -> Vec<u8> {
    let entries: Vec<(&SpecKey, &WorkGroupFunction)> =
        p.entries.iter().map(|(k, w)| (k, w)).collect();
    encode_program_parts(p.source_hash, &p.module, &entries)
}

/// Serialize a program from borrowed parts — `Program::binaries()` uses
/// this to export straight out of its cache map without cloning any IR.
pub fn encode_program_parts(
    source_hash: u128,
    module: &Module,
    entries: &[(&SpecKey, &WorkGroupFunction)],
) -> Vec<u8> {
    let mut w = W::new();
    w.u128(source_hash);
    module.put(&mut w);
    w.u32(entries.len() as u32);
    for (spec, wgf) in entries {
        spec.put(&mut w);
        wgf.put(&mut w);
    }
    seal(KIND_PROGRAM, &w.buf)
}

/// Deserialize a whole program.
pub fn decode_program(bytes: &[u8]) -> Result<ProgramBinary> {
    let payload = open(bytes, KIND_PROGRAM)?;
    let mut r = R::new(payload);
    let source_hash = r.u128()?;
    let module = Module::get(&mut r)?;
    let n = r.len_prefix()?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let spec = SpecKey::get(&mut r)?;
        let wgf = WorkGroupFunction::get(&mut r)?;
        if spec.kernel != wgf.name || spec.local != wgf.local_size {
            return Err(bad(format!(
                "entry key `{}` @ {:?} does not match its function `{}` @ {:?}",
                spec.kernel, spec.local, wgf.name, wgf.local_size
            )));
        }
        entries.push((spec, wgf));
    }
    r.finish()?;
    Ok(ProgramBinary { source_hash, module, entries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend;
    use crate::ir::print::print_function;
    use crate::kcc::compile_workgroup;

    const SRC: &str = "__kernel void k(__global float *x, __local float *t, uint n) {
        size_t i = get_local_id(0);
        t[i] = x[i] * 2.0f;
        barrier(CLK_LOCAL_MEM_FENCE);
        if (i < (size_t)n) { x[i] = t[0] + sqrt(t[i]); }
    }";

    fn wgf() -> WorkGroupFunction {
        let m = frontend::compile(SRC).unwrap();
        compile_workgroup(&m.kernels[0], [8, 1, 1], &CompileOptions::default()).unwrap()
    }

    #[test]
    fn module_roundtrips_against_printer() {
        let m = frontend::compile(SRC).unwrap();
        let bytes = encode_module(&m);
        let back = decode_module(&bytes).unwrap();
        assert_eq!(m.kernels.len(), back.kernels.len());
        for (a, b) in m.kernels.iter().zip(&back.kernels) {
            assert_eq!(print_function(a), print_function(b));
            assert_eq!(a.reg_count(), b.reg_count());
        }
    }

    #[test]
    fn wgf_roundtrips_against_printer() {
        let w = wgf();
        let bytes = encode_wgf(&w);
        let back = decode_wgf(&bytes).unwrap();
        assert_eq!(print_function(&w.reg_fn), print_function(&back.reg_fn));
        assert_eq!(print_function(&w.loop_fn), print_function(&back.loop_fn));
        assert_eq!(w.local_size, back.local_size);
        assert_eq!(w.reg_uniform, back.reg_uniform);
        assert_eq!(w.region_divergent, back.region_divergent);
        assert_eq!(w.regions.len(), back.regions.len());
        for (x, y) in w.regions.iter().zip(&back.regions) {
            assert_eq!((x.id, x.pre, x.post), (y.id, y.pre, y.post));
            assert_eq!(x.blocks, y.blocks);
            assert_eq!(x.via_back_edge, y.via_back_edge);
            assert_eq!(x.needs_peeling, y.needs_peeling);
        }
        assert_eq!(format!("{:?}", w.stats), format!("{:?}", back.stats));
        // Determinism: encoding the decoded value reproduces the bytes.
        assert_eq!(bytes, encode_wgf(&back));
    }

    #[test]
    fn program_roundtrips() {
        let m = frontend::compile(SRC).unwrap();
        let w = wgf();
        let spec = SpecKey {
            kernel: "k".into(),
            local: [8, 1, 1],
            opts: CompileOptions::default(),
        };
        let p = ProgramBinary {
            source_hash: super::super::key::fnv128(SRC.as_bytes()),
            module: m,
            entries: vec![(spec.clone(), w)],
        };
        let bytes = encode_program(&p);
        let back = decode_program(&bytes).unwrap();
        assert_eq!(back.source_hash, p.source_hash);
        assert_eq!(back.entries.len(), 1);
        assert_eq!(back.entries[0].0, spec);
        assert_eq!(
            print_function(&p.module.kernels[0]),
            print_function(&back.module.kernels[0])
        );
    }

    #[test]
    fn corrupt_payload_is_rejected() {
        let bytes = encode_wgf(&wgf());
        // Flip one payload byte: the digest check must catch it.
        let mut corrupt = bytes.clone();
        let i = HEADER_LEN + corrupt[HEADER_LEN..].len() / 2;
        corrupt[i] ^= 0x40;
        assert!(matches!(decode_wgf(&corrupt), Err(Error::BadBinary(_))));
        // Truncation is rejected too.
        assert!(matches!(decode_wgf(&bytes[..bytes.len() - 1]), Err(Error::BadBinary(_))));
        assert!(matches!(decode_wgf(&bytes[..10]), Err(Error::BadBinary(_))));
        // Wrong kind: a module envelope is not a wgf.
        let m = frontend::compile(SRC).unwrap();
        assert!(matches!(decode_wgf(&encode_module(&m)), Err(Error::BadBinary(_))));
    }

    #[test]
    fn version_bump_is_rejected() {
        let mut bytes = encode_wgf(&wgf());
        let bumped = (POCLBIN_VERSION + 1).to_le_bytes();
        bytes[8..12].copy_from_slice(&bumped);
        match decode_wgf(&bytes) {
            Err(Error::BadBinary(msg)) => assert!(msg.contains("version"), "{msg}"),
            other => panic!("expected BadBinary, got {other:?}"),
        }
        // Bad magic.
        let mut bytes = encode_wgf(&wgf());
        bytes[0] = b'X';
        assert!(matches!(decode_wgf(&bytes), Err(Error::BadBinary(_))));
    }
}
