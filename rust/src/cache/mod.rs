//! Persistent kernel-binary cache — the `POCL_CACHE_DIR` analog.
//!
//! The paper's §4.1 flow specialises work-group functions at enqueue
//! time; pocl amortises that cost across *processes* with a
//! content-addressed on-disk kernel cache plus a program-binary format.
//! This module reproduces both, dependency-free:
//!
//! * [`poclbin`] — the versioned binary serialization of
//!   [`ir::Module`](crate::ir::Module) and compiled
//!   [`WorkGroupFunction`](crate::kcc::WorkGroupFunction)s (magic +
//!   format version + payload digest; round-trip tested against
//!   `ir::print`).
//! * [`key`] — deterministic 128-bit FNV-1a content hashing:
//!   [`SpecKey`] (kernel + local size + the **full**
//!   [`CompileOptions`](crate::kcc::CompileOptions), device kind and
//!   gang width included) and the on-disk [`CacheKey`] derived from it
//!   plus the source digest.
//! * [`store`] — the [`DiskCache`]: one `poclbin` file per compiled
//!   work-group function under `POCLRS_CACHE_DIR` (default
//!   `~/.cache/poclrs`), atomic tmp-file+rename writes, corrupt or
//!   version-mismatched entries treated as misses, size-capped with
//!   oldest-first eviction, and [`CacheStats`] counters.
//!
//! # Who persists what
//!
//! A cache entry stores the *whole* work-group function —
//! `reg_fn` + regions + uniformity
//! metadata for the region-level engines (gang/vecgang/fiber) and
//! `loop_fn` + `wi_loops` for the WI-loop engines (serial/ttasim) — so
//! one warm entry serves every engine that shares the same compile
//! options. Program-level exchange (`Program::binaries()` /
//! `Program::from_binary`, the `clCreateProgramWithBinary` analog)
//! additionally carries the IR module itself, so a binary-built program
//! can still specialise *new* local sizes without any source.
//!
//! # Flow
//!
//! ```text
//! Program::workgroup_function(kernel, local, opts)
//!   ├─ in-memory map hit  ──────────────► Arc clone          (per process)
//!   ├─ DiskCache::load(CacheKey) hit ───► decode poclbin     (per machine)
//!   └─ miss ──► compile_workgroup ──► DiskCache::store (atomic write-back)
//! ```
//!
//! Environment knobs: `POCLRS_CACHE_DIR` (location),
//! `POCLRS_CACHE_MAX_BYTES` (eviction cap, default 256 MiB),
//! `POCLRS_CACHE=0` (disable the default cache entirely).

pub mod key;
pub mod poclbin;
pub mod store;

pub use key::{fnv128, CacheKey, Fnv128, SpecKey};
pub use poclbin::{ProgramBinary, POCLBIN_MAGIC, POCLBIN_VERSION};
pub use store::{default_cache, CacheEntry, CacheStats, DiskCache};
