//! Content-addressed cache keys.
//!
//! A [`CacheKey`] identifies one compiled work-group function on disk. It
//! is a 128-bit FNV-1a digest over everything that can influence the
//! compiled artifact:
//!
//! * the full program **source** text,
//! * the **kernel** name,
//! * the enqueue-time **local size**,
//! * the **full** [`CompileOptions`] — every knob, including the device
//!   kind ([`TargetKind`]) and gang width (pocl folds the target device
//!   into its cache hash the same way),
//! * the `poclbin` **format version**, the crate version, and the
//!   compiler build's own source fingerprint (`POCLRS_BUILD_ID`, from
//!   `build.rs`) — so neither format changes nor compiler-behavior
//!   changes can resurrect stale artifacts, with or without a version
//!   bump.
//!
//! FNV-1a is used because the crate is dependency-free; 128 bits makes
//! accidental collisions across a cache directory implausible, and a
//! corrupted payload is independently rejected by the `poclbin` header's
//! payload digest.

use std::fmt;

use crate::kcc::{CompileOptions, TargetKind};

/// 128-bit FNV-1a offset basis.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// 128-bit FNV-1a prime.
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

/// Incremental 128-bit FNV-1a hasher (deterministic across runs and
/// platforms, unlike `std::hash`).
#[derive(Debug, Clone)]
pub struct Fnv128 {
    state: u128,
}

impl Default for Fnv128 {
    fn default() -> Self {
        Fnv128 { state: FNV_OFFSET }
    }
}

impl Fnv128 {
    /// Fresh hasher.
    pub fn new() -> Fnv128 {
        Fnv128::default()
    }

    /// Fold raw bytes into the digest.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    /// Fold a length-prefixed string (prefixing keeps `("ab","c")` and
    /// `("a","bc")` distinct).
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    /// Fold a u64 (little-endian).
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Final digest.
    pub fn finish(&self) -> u128 {
        self.state
    }
}

/// One-shot digest of a byte string.
pub fn fnv128(bytes: &[u8]) -> u128 {
    let mut h = Fnv128::new();
    h.write(bytes);
    h.finish()
}

/// The in-memory specialisation key: everything `compile_workgroup`
/// depends on besides the module itself. Keying on the **full**
/// [`CompileOptions`] (not a projection of it) is what prevents two
/// devices with different options from sharing a wrong entry.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SpecKey {
    /// Kernel name within the program.
    pub kernel: String,
    /// Enqueue-time local size.
    pub local: [usize; 3],
    /// Full per-device compile options.
    pub opts: CompileOptions,
}

/// A content-addressed on-disk cache key (hex digest = file stem).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey(pub u128);

impl CacheKey {
    /// Key for one work-group-function artifact. `source_hash` is the
    /// digest of the program source (so the source text itself need not
    /// be re-hashed per specialisation).
    pub fn for_spec(source_hash: u128, spec: &SpecKey) -> CacheKey {
        let mut h = Fnv128::new();
        // Format version, crate version, and the build's own source
        // fingerprint (`POCLRS_BUILD_ID` from build.rs): artifacts
        // compiled by a different build of the kernel compiler — even at
        // the same crate version — can never be served.
        h.write_u64(super::poclbin::POCLBIN_VERSION as u64);
        h.write_str(env!("CARGO_PKG_VERSION"));
        h.write_str(option_env!("POCLRS_BUILD_ID").unwrap_or("dev"));
        h.write(&source_hash.to_le_bytes());
        h.write_str(&spec.kernel);
        for d in spec.local {
            h.write_u64(d as u64);
        }
        let o = &spec.opts;
        h.write_u64(o.horizontal as u64);
        h.write_u64(o.work_dim as u64);
        h.write_u64(o.spmd as u64);
        h.write_u64(match o.target {
            TargetKind::Cpu => 0,
            TargetKind::Tta => 1,
            TargetKind::Spmd => 2,
        });
        h.write_u64(o.gang_width as u64);
        h.write_u64(o.opt_level.as_u32() as u64);
        CacheKey(h.finish())
    }

    /// 32-hex-digit file stem.
    pub fn hex(&self) -> String {
        format!("{:032x}", self.0)
    }

    /// Parse a 32-hex-digit stem back into a key.
    pub fn from_hex(s: &str) -> Option<CacheKey> {
        if s.len() != 32 {
            return None;
        }
        u128::from_str_radix(s, 16).ok().map(CacheKey)
    }
}

impl fmt::Display for CacheKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.hex())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kernel: &str, local: [usize; 3], opts: CompileOptions) -> SpecKey {
        SpecKey { kernel: kernel.to_string(), local, opts }
    }

    #[test]
    fn digest_is_deterministic_and_input_sensitive() {
        assert_eq!(fnv128(b"abc"), fnv128(b"abc"));
        assert_ne!(fnv128(b"abc"), fnv128(b"abd"));
        // Length prefixing keeps concatenations apart.
        let mut a = Fnv128::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = Fnv128::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn key_covers_every_option_field() {
        let src = fnv128(b"__kernel void k() {}");
        let base = CacheKey::for_spec(src, &spec("k", [8, 1, 1], CompileOptions::default()));
        // Same inputs → same key.
        assert_eq!(
            base,
            CacheKey::for_spec(src, &spec("k", [8, 1, 1], CompileOptions::default()))
        );
        // Each key component flips the digest.
        // Pick an opt level that differs from the (env-derived) default.
        let other_level = if CompileOptions::default().opt_level == crate::kcc::OptLevel::O0 {
            crate::kcc::OptLevel::O2
        } else {
            crate::kcc::OptLevel::O0
        };
        let variants = [
            CompileOptions { horizontal: false, ..Default::default() },
            CompileOptions { work_dim: 2, ..Default::default() },
            CompileOptions { spmd: true, ..Default::default() },
            CompileOptions { target: TargetKind::Tta, ..Default::default() },
            CompileOptions { gang_width: 8, ..Default::default() },
            CompileOptions { opt_level: other_level, ..Default::default() },
        ];
        for v in variants {
            assert_ne!(base, CacheKey::for_spec(src, &spec("k", [8, 1, 1], v)));
        }
        let dflt = CompileOptions::default;
        assert_ne!(base, CacheKey::for_spec(src, &spec("k", [16, 1, 1], dflt())));
        assert_ne!(base, CacheKey::for_spec(src, &spec("j", [8, 1, 1], dflt())));
        assert_ne!(
            base,
            CacheKey::for_spec(fnv128(b"other source"), &spec("k", [8, 1, 1], dflt()))
        );
    }

    #[test]
    fn hex_roundtrip() {
        let k = CacheKey(0x0123_4567_89ab_cdef_0011_2233_4455_6677);
        assert_eq!(CacheKey::from_hex(&k.hex()), Some(k));
        assert_eq!(k.hex().len(), 32);
        assert_eq!(CacheKey::from_hex("nope"), None);
        assert_eq!(CacheKey::from_hex(&"f".repeat(33)), None);
    }
}
