//! The persistent kernel-binary store: a content-addressed directory of
//! `poclbin` files (the `POCL_CACHE_DIR` analog).
//!
//! Layout: one file per compiled work-group function,
//! `<dir>/<32-hex-key>.poclbin`, where the key is
//! [`CacheKey::for_spec`](super::key::CacheKey::for_spec) — a digest of
//! source, kernel, local size, and the full compile options (device kind
//! and gang width included). There is no index file: the directory *is*
//! the index, which keeps concurrent processes safe.
//!
//! Guarantees:
//!
//! * **Atomic writes** — entries are written to a unique `*.tmp` file in
//!   the same directory and `rename`d into place, so readers never see a
//!   partial entry (POSIX rename atomicity). A crash leaves at worst a
//!   stray tmp file, which the next directory scan (any write-back's
//!   eviction pass, or `cache clear`) removes once it is older than
//!   [`STALE_TMP_SECS`].
//! * **Corruption safety** — a load that fails the `poclbin` magic,
//!   version, length, or digest checks counts as a miss (and the bad
//!   entry is deleted); the caller recompiles and overwrites it.
//! * **Bounded size** — after a write pushes the directory over
//!   `POCLRS_CACHE_MAX_BYTES` (default 256 MiB), oldest-modified entries
//!   are evicted until the total fits again.
//!
//! Every handle keeps [`CacheStats`] counters; `poclrs cache stats` and
//! `poclrs run --stats` surface them.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::SystemTime;

use crate::cl::error::{Error, Result};
use crate::kcc::WorkGroupFunction;

use super::key::CacheKey;
use super::poclbin;

/// File extension of cache entries.
pub const ENTRY_EXT: &str = "poclbin";
/// Default size cap when `POCLRS_CACHE_MAX_BYTES` is unset.
pub const DEFAULT_MAX_BYTES: u64 = 256 << 20;
/// Age (seconds) after which an orphaned tmp file from a crashed writer
/// is swept by the next directory scan.
pub const STALE_TMP_SECS: u64 = 600;

/// Cumulative counters for one [`DiskCache`] handle.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Entries found on disk and successfully decoded.
    pub hits: u64,
    /// Lookups that found nothing usable (absent, corrupt, or
    /// version-mismatched — the latter two also count in `rejected`).
    pub misses: u64,
    /// Misses caused by a present-but-unusable entry.
    pub rejected: u64,
    /// Entries written.
    pub writes: u64,
    /// Bytes read by successful hits.
    pub bytes_read: u64,
    /// Bytes written by stores.
    pub bytes_written: u64,
    /// Entries evicted by the size cap.
    pub evictions: u64,
}

/// One entry as listed by [`DiskCache::entries`].
#[derive(Debug, Clone)]
pub struct CacheEntry {
    /// Content-addressed key (file stem).
    pub key: CacheKey,
    /// File size in bytes.
    pub bytes: u64,
    /// Last-modified time.
    pub modified: SystemTime,
    /// Kernel name, if the entry decodes (`None` = corrupt/foreign file).
    pub kernel: Option<String>,
    /// Specialised local size, if the entry decodes.
    pub local_size: Option<[usize; 3]>,
}

/// A content-addressed on-disk cache of compiled work-group functions.
pub struct DiskCache {
    dir: PathBuf,
    max_bytes: u64,
    stats: Mutex<CacheStats>,
}

/// Process-unique suffix source for tmp files.
static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

impl DiskCache {
    /// Open (creating if needed) a cache at `dir`.
    pub fn at(dir: impl Into<PathBuf>) -> Result<DiskCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| Error::Io(format!("cannot create cache dir {}: {e}", dir.display())))?;
        let max_bytes = crate::envcfg::parse_or_warn(
            "POCLRS_CACHE_MAX_BYTES",
            std::env::var("POCLRS_CACHE_MAX_BYTES").ok().as_deref(),
            "a byte count",
            "using the 256 MiB default",
            |s| s.parse::<u64>().ok(),
        )
        .unwrap_or(DEFAULT_MAX_BYTES);
        Ok(DiskCache { dir, max_bytes, stats: Mutex::new(CacheStats::default()) })
    }

    /// The default cache directory: `POCLRS_CACHE_DIR` if set, else
    /// `$HOME/.cache/poclrs`, else a `poclrs-cache` directory under the
    /// system temp dir.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("POCLRS_CACHE_DIR") {
            if !dir.is_empty() {
                return PathBuf::from(dir);
            }
        }
        if let Ok(home) = std::env::var("HOME") {
            if !home.is_empty() {
                return Path::new(&home).join(".cache").join("poclrs");
            }
        }
        std::env::temp_dir().join("poclrs-cache")
    }

    /// Directory this cache lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Size cap in bytes.
    pub fn max_bytes(&self) -> u64 {
        self.max_bytes
    }

    fn entry_path(&self, key: CacheKey) -> PathBuf {
        self.dir.join(format!("{}.{ENTRY_EXT}", key.hex()))
    }

    /// Look up a compiled work-group function. Absent, corrupt, or
    /// version-mismatched entries are misses; unusable files are removed
    /// so the follow-up write-back replaces them.
    pub fn load(&self, key: CacheKey) -> Option<WorkGroupFunction> {
        let mut span = crate::trace::enabled()
            .then(|| crate::trace::span(crate::trace::CAT_CACHE, "disk_load"));
        let path = self.entry_path(key);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                self.stats.lock().unwrap().misses += 1;
                crate::trace::metrics::add("cache.disk_misses", 1);
                if let Some(sp) = span.as_mut() {
                    sp.arg("outcome", crate::trace::ArgVal::s("miss"));
                }
                return None;
            }
        };
        let decoded = {
            let _decode_span = crate::trace::span(crate::trace::CAT_CACHE, "decode");
            poclbin::decode_wgf(&bytes)
        };
        match decoded {
            Ok(wgf) => {
                let mut s = self.stats.lock().unwrap();
                s.hits += 1;
                s.bytes_read += bytes.len() as u64;
                drop(s);
                crate::trace::metrics::add("cache.disk_hits", 1);
                crate::trace::metrics::add("cache.bytes_read", bytes.len() as u64);
                if let Some(sp) = span.as_mut() {
                    sp.arg("outcome", crate::trace::ArgVal::s("hit"));
                    sp.arg("bytes", crate::trace::ArgVal::u(bytes.len() as u64));
                }
                Some(wgf)
            }
            Err(_) => {
                // Stale format or bit rot: drop the entry and recompile.
                let _ = std::fs::remove_file(&path);
                let mut s = self.stats.lock().unwrap();
                s.misses += 1;
                s.rejected += 1;
                drop(s);
                crate::trace::metrics::add("cache.disk_misses", 1);
                crate::trace::metrics::add("cache.rejected", 1);
                if let Some(sp) = span.as_mut() {
                    sp.arg("outcome", crate::trace::ArgVal::s("rejected"));
                }
                None
            }
        }
    }

    /// Write (or overwrite) an entry atomically: serialize, write to a
    /// unique tmp file in the cache dir, then rename into place.
    pub fn store(&self, key: CacheKey, wgf: &WorkGroupFunction) -> Result<()> {
        let mut span = crate::trace::enabled()
            .then(|| crate::trace::span(crate::trace::CAT_CACHE, "write_back"));
        let bytes = poclbin::encode_wgf(wgf);
        crate::trace::metrics::add("cache.writes", 1);
        crate::trace::metrics::add("cache.bytes_written", bytes.len() as u64);
        if let Some(sp) = span.as_mut() {
            sp.arg("bytes", crate::trace::ArgVal::u(bytes.len() as u64));
        }
        let tmp = self.dir.join(format!(
            ".{}-{}-{}.tmp",
            key.hex(),
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        let path = self.entry_path(key);
        std::fs::write(&tmp, &bytes)
            .map_err(|e| Error::Io(format!("cache write {}: {e}", tmp.display())))?;
        if let Err(e) = std::fs::rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(Error::Io(format!("cache rename {}: {e}", path.display())));
        }
        {
            let mut s = self.stats.lock().unwrap();
            s.writes += 1;
            s.bytes_written += bytes.len() as u64;
        }
        self.evict_over_cap();
        Ok(())
    }

    /// Lightweight directory scan (sorted newest-first): file metadata
    /// only, nothing is read or decoded — this is what eviction and
    /// `total_bytes` run on every write-back. As a side effect, stale
    /// tmp files left behind by crashed writers are removed (no healthy
    /// writer holds a tmp file for anywhere near [`STALE_TMP_SECS`]).
    fn scan(&self) -> Result<Vec<CacheEntry>> {
        let mut out = Vec::new();
        let now = SystemTime::now();
        let rd = std::fs::read_dir(&self.dir)
            .map_err(|e| Error::Io(format!("cache dir {}: {e}", self.dir.display())))?;
        for item in rd.flatten() {
            let path = item.path();
            let ext = path.extension().and_then(|e| e.to_str());
            let Ok(meta) = item.metadata() else { continue };
            let modified = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
            if ext == Some("tmp") {
                let stale = now
                    .duration_since(modified)
                    .map(|d| d.as_secs() > STALE_TMP_SECS)
                    .unwrap_or(false);
                if stale {
                    let _ = std::fs::remove_file(&path);
                }
                continue;
            }
            if ext != Some(ENTRY_EXT) {
                continue;
            }
            let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
            let Some(key) = CacheKey::from_hex(stem) else { continue };
            out.push(CacheEntry {
                key,
                bytes: meta.len(),
                modified,
                kernel: None,
                local_size: None,
            });
        }
        out.sort_by(|a, b| b.modified.cmp(&a.modified).then(a.key.cmp(&b.key)));
        Ok(out)
    }

    /// List entries (sorted newest-first) with decoded kernel metadata —
    /// the `cache ls` view. Files that do not decode are listed with
    /// `kernel: None` rather than skipped, so bit-rotted entries show up
    /// instead of hiding. This decodes every entry; size accounting
    /// (`total_bytes`, eviction) uses the metadata-only scan instead.
    pub fn entries(&self) -> Result<Vec<CacheEntry>> {
        let mut out = self.scan()?;
        for e in &mut out {
            let path = self.entry_path(e.key);
            let decoded = std::fs::read(&path).ok().and_then(|b| poclbin::decode_wgf(&b).ok());
            if let Some(w) = decoded {
                e.kernel = Some(w.name);
                e.local_size = Some(w.local_size);
            }
        }
        Ok(out)
    }

    /// Total bytes of all entries (metadata scan, no decoding).
    pub fn total_bytes(&self) -> u64 {
        self.scan().map(|es| es.iter().map(|e| e.bytes).sum()).unwrap_or(0)
    }

    /// Remove every entry (and stray tmp files). Returns the number of
    /// entries removed.
    pub fn clear(&self) -> Result<usize> {
        let mut removed = 0;
        let rd = std::fs::read_dir(&self.dir)
            .map_err(|e| Error::Io(format!("cache dir {}: {e}", self.dir.display())))?;
        for item in rd.flatten() {
            let path = item.path();
            let ext = path.extension().and_then(|e| e.to_str());
            if ext == Some(ENTRY_EXT) {
                if std::fs::remove_file(&path).is_ok() {
                    removed += 1;
                }
            } else if ext == Some("tmp") {
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(removed)
    }

    /// Evict oldest-modified entries until the directory fits the cap
    /// (metadata scan only — nothing is decoded on the write path).
    fn evict_over_cap(&self) {
        let Ok(mut entries) = self.scan() else { return };
        let mut total: u64 = entries.iter().map(|e| e.bytes).sum();
        if total <= self.max_bytes {
            return;
        }
        // scan() sorts newest-first; evict from the back (oldest).
        while total > self.max_bytes {
            let Some(oldest) = entries.pop() else { break };
            if std::fs::remove_file(self.entry_path(oldest.key)).is_ok() {
                total = total.saturating_sub(oldest.bytes);
                self.stats.lock().unwrap().evictions += 1;
            }
        }
    }

    /// Snapshot of this handle's counters.
    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }
}

/// The process-wide default cache used for transparent read-through in
/// `Program::build_cached(..)` callers (suite runner, CLI): opened once
/// at [`DiskCache::default_dir`], shared by every program. `None` when
/// caching is disabled (`POCLRS_CACHE=0`/`off`) or the directory cannot
/// be created (e.g. read-only filesystem) — callers then compile as
/// before, the cache is strictly an accelerator.
pub fn default_cache() -> Option<Arc<DiskCache>> {
    static DEFAULT: OnceLock<Option<Arc<DiskCache>>> = OnceLock::new();
    DEFAULT
        .get_or_init(|| {
            if let Ok(v) = std::env::var("POCLRS_CACHE") {
                let v = v.to_ascii_lowercase();
                if v == "0" || v == "off" || v == "no" || v == "false" {
                    return None;
                }
            }
            DiskCache::at(DiskCache::default_dir()).ok().map(Arc::new)
        })
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kcc::{compile_workgroup, CompileOptions};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "poclrs-store-test-{tag}-{}-{}",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample_wgf(local: usize) -> WorkGroupFunction {
        let m = crate::frontend::compile(
            "__kernel void k(__global float *x) { x[get_global_id(0)] = 1.0f; }",
        )
        .unwrap();
        compile_workgroup(&m.kernels[0], [local, 1, 1], &CompileOptions::default()).unwrap()
    }

    #[test]
    fn store_load_roundtrip_and_stats() {
        let dir = tmpdir("roundtrip");
        let cache = DiskCache::at(&dir).unwrap();
        let key = CacheKey(42);
        assert!(cache.load(key).is_none(), "cold cache misses");
        let wgf = sample_wgf(8);
        cache.store(key, &wgf).unwrap();
        let back = cache.load(key).expect("warm cache hits");
        assert_eq!(back.name, wgf.name);
        assert_eq!(back.local_size, wgf.local_size);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.writes), (1, 1, 1));
        assert!(s.bytes_written > 0 && s.bytes_read > 0);
        // Listing sees the entry with its kernel metadata.
        let es = cache.entries().unwrap();
        assert_eq!(es.len(), 1);
        assert_eq!(es[0].key, key);
        assert_eq!(es[0].kernel.as_deref(), Some("k"));
        assert_eq!(cache.clear().unwrap(), 1);
        assert!(cache.entries().unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_entry_is_a_miss_and_gets_removed() {
        let dir = tmpdir("corrupt");
        let cache = DiskCache::at(&dir).unwrap();
        let key = CacheKey(7);
        cache.store(key, &sample_wgf(4)).unwrap();
        // Corrupt the file in place.
        let path = dir.join(format!("{}.{ENTRY_EXT}", key.hex()));
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(cache.load(key).is_none(), "corrupt entry must miss");
        assert!(!path.exists(), "corrupt entry must be removed");
        let s = cache.stats();
        assert_eq!(s.rejected, 1);
        // Write-back then hits again.
        cache.store(key, &sample_wgf(4)).unwrap();
        assert!(cache.load(key).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_keeps_directory_under_cap() {
        let dir = tmpdir("evict");
        let mut cache = DiskCache::at(&dir).unwrap();
        let wgf = sample_wgf(8);
        let entry_len = poclbin::encode_wgf(&wgf).len() as u64;
        // Cap at ~3 entries.
        cache.max_bytes = entry_len * 3 + entry_len / 2;
        for i in 0..6u128 {
            cache.store(CacheKey(i), &wgf).unwrap();
        }
        assert!(cache.total_bytes() <= cache.max_bytes, "cap respected");
        let s = cache.stats();
        assert!(s.evictions >= 2, "evictions counted: {s:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
