//! Minimal property-testing support (proptest is unavailable offline):
//! a seeded xorshift generator with convenience samplers. Failures print
//! the seed so runs are reproducible.

/// Deterministic xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seeded generator (seed 0 is remapped).
    pub fn new(seed: u64) -> Rng {
        Rng(if seed == 0 { 0x9E3779B97F4A7C15 } else { seed })
    }
    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }
    /// Uniform usize in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }
    /// Uniform f32 in [lo, hi).
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_u64() as f32 / u64::MAX as f32) * (hi - lo)
    }
    /// Uniform bool.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
    /// Vector of random f32s.
    pub fn f32s(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }
    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Run `f` for `cases` seeds; on panic the failing seed is reported.
pub fn check(cases: u64, f: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
            let f = r.f32(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
