//! MiniCL abstract syntax tree.

use crate::ir::types::{AddrSpace, Type};

/// Source position for diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct Pos {
    pub line: u32,
    pub col: u32,
}

/// A parsed translation unit: helper functions and kernels.
#[derive(Debug, Clone, Default)]
pub struct Unit {
    pub funcs: Vec<FuncDef>,
}

/// A function definition (kernel or helper).
#[derive(Debug, Clone)]
pub struct FuncDef {
    pub name: String,
    pub is_kernel: bool,
    pub ret: Type,
    pub params: Vec<ParamDecl>,
    pub body: Vec<Stmt>,
    pub pos: Pos,
}

/// One function parameter.
#[derive(Debug, Clone)]
pub struct ParamDecl {
    pub name: String,
    pub ty: Type,
    pub is_const: bool,
    pub pos: Pos,
}

/// Statements.
#[derive(Debug, Clone)]
pub enum Stmt {
    /// Variable declaration: `float4 acc = 0.0f;` or `__local float t[64];`
    /// or `float dct[8][8] = {...};` flattened to 1-D.
    Decl {
        name: String,
        ty: Type,
        space: AddrSpace,
        /// Array length (product of all dimensions); 1 = scalar.
        array: Option<Expr>,
        init: Option<Expr>,
        /// Aggregate initialiser for arrays: `{1, 2, 3}`.
        init_list: Option<Vec<Expr>>,
        pos: Pos,
    },
    /// Expression statement (assignments, calls, ++).
    Expr(Expr),
    /// `if (c) { .. } else { .. }`.
    If { cond: Expr, then_body: Vec<Stmt>, else_body: Vec<Stmt>, pos: Pos },
    /// `for (init; cond; step) body`.
    For {
        init: Option<Box<Stmt>>,
        cond: Option<Expr>,
        step: Option<Expr>,
        body: Vec<Stmt>,
        pos: Pos,
    },
    /// `while (c) body`.
    While { cond: Expr, body: Vec<Stmt>, pos: Pos },
    /// `do body while (c);`
    DoWhile { cond: Expr, body: Vec<Stmt>, pos: Pos },
    /// `break;`
    Break(Pos),
    /// `continue;`
    Continue(Pos),
    /// `return;` / `return e;`
    Return(Option<Expr>, Pos),
    /// `barrier(CLK_LOCAL_MEM_FENCE);`
    Barrier(Pos),
    /// Nested block `{ ... }`.
    Block(Vec<Stmt>),
}

/// Expressions. Every node carries its position for diagnostics.
#[derive(Debug, Clone)]
pub enum Expr {
    /// Integer literal.
    Int(i64, bool, Pos),
    /// Float literal.
    Float(f64, bool, Pos),
    /// Variable / parameter reference.
    Ident(String, Pos),
    /// `a <op> b` where op is a C binary operator token.
    Bin(&'static str, Box<Expr>, Box<Expr>, Pos),
    /// `<op> a` (`-`, `!`, `~`).
    Un(&'static str, Box<Expr>, Pos),
    /// Prefix or postfix `++`/`--` (value semantics of postfix are honoured).
    IncDec { op: &'static str, prefix: bool, target: Box<Expr>, pos: Pos },
    /// `target = value` or compound `target += value` (op = "" for plain).
    Assign { op: &'static str, target: Box<Expr>, value: Box<Expr>, pos: Pos },
    /// `c ? a : b`.
    Ternary(Box<Expr>, Box<Expr>, Box<Expr>, Pos),
    /// `(type) expr` cast.
    Cast(Type, Box<Expr>, Pos),
    /// `(float4)(a, b, c, d)` vector construction.
    VecLit(Type, Vec<Expr>, Pos),
    /// `f(args...)` builtin or helper call.
    Call(String, Vec<Expr>, Pos),
    /// `base[idx]`.
    Index(Box<Expr>, Box<Expr>, Pos),
    /// `base.xyzw` / `.s0` / `.lo` / `.hi` / `.even` / `.odd`.
    Swizzle(Box<Expr>, String, Pos),
}

impl Expr {
    /// Position accessor.
    pub fn pos(&self) -> Pos {
        match self {
            Expr::Int(_, _, p)
            | Expr::Float(_, _, p)
            | Expr::Ident(_, p)
            | Expr::Bin(_, _, _, p)
            | Expr::Un(_, _, p)
            | Expr::Ternary(_, _, _, p)
            | Expr::Cast(_, _, p)
            | Expr::VecLit(_, _, p)
            | Expr::Call(_, _, p)
            | Expr::Index(_, _, p)
            | Expr::Swizzle(_, _, p) => *p,
            Expr::IncDec { pos, .. } | Expr::Assign { pos, .. } => *pos,
        }
    }
}
