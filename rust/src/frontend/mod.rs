//! MiniCL frontend: the Clang analog. Lexes, parses and lowers an OpenCL C
//! subset into the kernel IR.

pub mod ast;
pub mod lexer;
pub mod lower;
pub mod parser;

use crate::cl::error::Result;
use crate::ir::Module;

/// Compile MiniCL source to an IR module (single-work-item kernels, the
/// input to the kernel compiler of `kcc`).
pub fn compile(src: &str) -> Result<Module> {
    let _span = crate::trace::span(crate::trace::CAT_COMPILER, "frontend");
    let unit = parser::parse(src)?;
    lower::lower_unit(&unit)
}
