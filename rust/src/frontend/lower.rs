//! AST → IR lowering with type checking (the "sema" stage).
//!
//! Lowering decisions that matter to the rest of the stack:
//!
//! * Every local variable (and every scalar parameter) becomes an alloca
//!   **slot**; expression temporaries stay in block-local registers. This
//!   establishes the IR invariant the kernel compiler's privatisation
//!   relies on (only slots cross parallel regions).
//! * Automatic `__local` variables are converted to appended kernel
//!   parameters (§4.7 / Fig. 3 of the paper) with a recorded byte size, so
//!   host- and kernel-allocated local buffers are handled uniformly.
//! * Helper functions are inlined at the call site (pocl inlines all
//!   built-ins and callees into the kernel, §8).
//! * `&&`/`||` lower to short-circuit control flow; ternaries lower to
//!   `select` when both arms are pure, otherwise to control flow.

use std::collections::HashMap;

use super::ast::*;
use crate::cl::error::{Error, Result};
use crate::ir::func::{Function, Module, Param};
use crate::ir::inst::{BarrierKind, BinOp, BlockId, Imm, Inst, MathFn, Operand, SlotId, Term, UnOp, WiFn};
use crate::ir::types::{AddrSpace, Scalar, Type};

/// Lower a parsed unit into an IR module (kernels only; helpers inline).
pub fn lower_unit(unit: &Unit) -> Result<Module> {
    let helpers: HashMap<&str, &FuncDef> =
        unit.funcs.iter().filter(|f| !f.is_kernel).map(|f| (f.name.as_str(), f)).collect();
    let mut module = Module::default();
    for def in unit.funcs.iter().filter(|f| f.is_kernel) {
        let mut lw = Lowerer::new(def, &helpers)?;
        lw.lower_body(&def.body)?;
        // Fall-through return.
        lw.func.set_term(lw.cur, Term::Ret);
        crate::ir::verify::verify(&lw.func).map_err(|e| {
            Error::Compile(format!("internal: lowering of `{}` produced invalid IR: {e}", def.name))
        })?;
        module.kernels.push(lw.func);
    }
    if module.kernels.is_empty() {
        return Err(Error::compile("no __kernel function in source"));
    }
    Ok(module)
}

#[derive(Debug, Clone)]
enum Binding {
    /// Private variable slot (element type, array length).
    Slot { slot: SlotId, ty: Type, count: usize },
    /// Pointer parameter used directly (not assignable).
    ParamPtr { index: u32, ty: Type },
    /// Pointer value captured at helper-inline time. Only block-position-
    /// independent operands (`Arg`, `Slot`) are allowed here — a register
    /// would violate the block-locality invariant inside multi-block
    /// helper bodies.
    PtrValue { val: Operand, ty: Type },
}

/// An lvalue resolved to a memory location.
enum LValue {
    /// Whole object at `ptr` (pointer operand + element type + space).
    Mem { ptr: Operand, ty: Type, space: AddrSpace },
    /// One lane of a vector stored at `ptr`.
    Lane { ptr: Operand, vec_ty: Type, lane: u32, space: AddrSpace },
}

struct InlineCtx {
    ret_slot: Option<(SlotId, Type)>,
    join: BlockId,
}

struct Lowerer<'a> {
    func: Function,
    cur: BlockId,
    scopes: Vec<HashMap<String, Binding>>,
    /// (continue target, break target)
    loops: Vec<(BlockId, BlockId)>,
    helpers: &'a HashMap<&'a str, &'a FuncDef>,
    inline_stack: Vec<InlineCtx>,
    blk_counter: u32,
}

impl<'a> Lowerer<'a> {
    fn new(def: &FuncDef, helpers: &'a HashMap<&'a str, &'a FuncDef>) -> Result<Lowerer<'a>> {
        let mut func = Function::new(def.name.clone());
        let cur = func.entry;
        let mut lw = Lowerer {
            func,
            cur,
            scopes: vec![HashMap::new()],
            loops: Vec::new(),
            helpers,
            inline_stack: Vec::new(),
            blk_counter: 0,
        };
        for (i, p) in def.params.iter().enumerate() {
            let index = i as u32;
            lw.func.params.push(Param {
                name: p.name.clone(),
                ty: p.ty.clone(),
                is_local_buf: matches!(&p.ty, Type::Ptr(_, AddrSpace::Local)),
                auto_local_size: None,
            });
            match &p.ty {
                Type::Ptr(..) => {
                    lw.bind(p.name.clone(), Binding::ParamPtr { index, ty: p.ty.clone() });
                }
                ty => {
                    // Scalar params are copied into slots so kernels may
                    // assign to them; the entry-block store from an Arg is
                    // what the uniformity analysis recognises as a uniform
                    // root (§4.6).
                    let slot = lw.func.add_slot(p.name.clone(), ty.clone(), 1);
                    lw.func.block_mut(cur).insts.push((
                        None,
                        Inst::Store { ty: ty.clone(), ptr: Operand::Slot(slot), val: Operand::Arg(index) },
                    ));
                    lw.bind(p.name.clone(), Binding::Slot { slot, ty: ty.clone(), count: 1 });
                }
            }
        }
        Ok(lw)
    }

    fn bind(&mut self, name: String, b: Binding) {
        self.scopes.last_mut().unwrap().insert(name, b);
    }

    fn lookup(&self, name: &str) -> Option<&Binding> {
        self.scopes.iter().rev().find_map(|s| s.get(name))
    }

    fn err<T>(&self, pos: Pos, msg: impl Into<String>) -> Result<T> {
        Err(Error::Sema { line: pos.line, col: pos.col, msg: msg.into() })
    }

    fn new_block(&mut self, tag: &str) -> BlockId {
        self.blk_counter += 1;
        self.func.add_block(format!("{}{}", tag, self.blk_counter))
    }

    fn push(&mut self, inst: Inst) -> Option<Operand> {
        self.func.push(self.cur, inst).map(Operand::Reg)
    }

    fn push_val(&mut self, inst: Inst) -> Operand {
        Operand::Reg(self.func.push_val(self.cur, inst))
    }

    // ---- statements ------------------------------------------------------

    fn lower_body(&mut self, stmts: &[Stmt]) -> Result<()> {
        self.scopes.push(HashMap::new());
        for s in stmts {
            self.lower_stmt(s)?;
        }
        self.scopes.pop();
        Ok(())
    }

    fn lower_stmt(&mut self, stmt: &Stmt) -> Result<()> {
        match stmt {
            Stmt::Block(body) => self.lower_body(body),
            Stmt::Decl { name, ty, space, array, init, init_list, pos } => {
                self.lower_decl(name, ty, *space, array, init, init_list, *pos)
            }
            Stmt::Expr(e) => {
                self.lower_expr(e)?;
                Ok(())
            }
            Stmt::Barrier(_) => {
                self.push(Inst::Barrier { kind: BarrierKind::Explicit });
                Ok(())
            }
            Stmt::If { cond, then_body, else_body, .. } => {
                let (c, cty) = self.lower_expr(cond)?;
                let c = self.to_bool(c, &cty);
                let then_bb = self.new_block("then");
                let join = self.new_block("ifjoin");
                let else_bb = if else_body.is_empty() { join } else { self.new_block("else") };
                self.func.set_term(self.cur, Term::Br { cond: c, t: then_bb, f: else_bb });
                self.cur = then_bb;
                self.lower_body(then_body)?;
                self.func.set_term(self.cur, Term::Jump(join));
                if !else_body.is_empty() {
                    self.cur = else_bb;
                    self.lower_body(else_body)?;
                    self.func.set_term(self.cur, Term::Jump(join));
                }
                self.cur = join;
                Ok(())
            }
            Stmt::For { init, cond, step, body, .. } => {
                self.scopes.push(HashMap::new());
                if let Some(i) = init {
                    self.lower_stmt(i)?;
                }
                let header = self.new_block("for.h");
                let body_bb = self.new_block("for.body");
                let step_bb = self.new_block("for.step");
                let join = self.new_block("for.end");
                self.func.set_term(self.cur, Term::Jump(header));
                self.cur = header;
                match cond {
                    Some(c) => {
                        let (cv, cty) = self.lower_expr(c)?;
                        let cv = self.to_bool(cv, &cty);
                        self.func.set_term(self.cur, Term::Br { cond: cv, t: body_bb, f: join });
                    }
                    None => self.func.set_term(self.cur, Term::Jump(body_bb)),
                }
                self.loops.push((step_bb, join));
                self.cur = body_bb;
                self.lower_body(body)?;
                self.func.set_term(self.cur, Term::Jump(step_bb));
                self.loops.pop();
                self.cur = step_bb;
                if let Some(s) = step {
                    self.lower_expr(s)?;
                }
                self.func.set_term(self.cur, Term::Jump(header));
                self.cur = join;
                self.scopes.pop();
                Ok(())
            }
            Stmt::While { cond, body, .. } => {
                let header = self.new_block("wh.h");
                let body_bb = self.new_block("wh.body");
                let join = self.new_block("wh.end");
                self.func.set_term(self.cur, Term::Jump(header));
                self.cur = header;
                let (cv, cty) = self.lower_expr(cond)?;
                let cv = self.to_bool(cv, &cty);
                self.func.set_term(self.cur, Term::Br { cond: cv, t: body_bb, f: join });
                self.loops.push((header, join));
                self.cur = body_bb;
                self.lower_body(body)?;
                self.func.set_term(self.cur, Term::Jump(header));
                self.loops.pop();
                self.cur = join;
                Ok(())
            }
            Stmt::DoWhile { cond, body, .. } => {
                let body_bb = self.new_block("do.body");
                let cond_bb = self.new_block("do.cond");
                let join = self.new_block("do.end");
                self.func.set_term(self.cur, Term::Jump(body_bb));
                self.loops.push((cond_bb, join));
                self.cur = body_bb;
                self.lower_body(body)?;
                self.func.set_term(self.cur, Term::Jump(cond_bb));
                self.loops.pop();
                self.cur = cond_bb;
                let (cv, cty) = self.lower_expr(cond)?;
                let cv = self.to_bool(cv, &cty);
                self.func.set_term(self.cur, Term::Br { cond: cv, t: body_bb, f: join });
                self.cur = join;
                Ok(())
            }
            Stmt::Break(pos) => {
                match self.loops.last() {
                    Some(&(_, brk)) => {
                        self.func.set_term(self.cur, Term::Jump(brk));
                        self.cur = self.new_block("dead");
                        Ok(())
                    }
                    None => self.err(*pos, "break outside loop"),
                }
            }
            Stmt::Continue(pos) => {
                match self.loops.last() {
                    Some(&(cont, _)) => {
                        self.func.set_term(self.cur, Term::Jump(cont));
                        self.cur = self.new_block("dead");
                        Ok(())
                    }
                    None => self.err(*pos, "continue outside loop"),
                }
            }
            Stmt::Return(val, pos) => {
                if let Some(ctx) = self.inline_stack.last() {
                    let join = ctx.join;
                    let ret_slot = ctx.ret_slot.clone();
                    if let Some((slot, ty)) = ret_slot {
                        let v = match val {
                            Some(e) => {
                                let (v, vt) = self.lower_expr(e)?;
                                self.coerce(v, &vt, &ty, *pos)?
                            }
                            None => return self.err(*pos, "missing return value"),
                        };
                        self.push(Inst::Store { ty, ptr: Operand::Slot(slot), val: v });
                    }
                    self.func.set_term(self.cur, Term::Jump(join));
                    self.cur = self.new_block("dead");
                    return Ok(());
                }
                if val.is_some() {
                    return self.err(*pos, "kernels return void");
                }
                self.func.set_term(self.cur, Term::Ret);
                self.cur = self.new_block("dead");
                Ok(())
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn lower_decl(
        &mut self,
        name: &str,
        ty: &Type,
        space: AddrSpace,
        array: &Option<Expr>,
        init: &Option<Expr>,
        init_list: &Option<Vec<Expr>>,
        pos: Pos,
    ) -> Result<()> {
        let count = match array {
            Some(e) => self.const_eval_usize(e, pos)?,
            None => 1,
        };
        if space == AddrSpace::Local {
            // Automatic local → appended parameter (§4.7).
            let index = self.func.params.len() as u32;
            let bytes = ty.size() * count;
            self.func.params.push(Param {
                name: format!("{name}.auto_local"),
                ty: ty.clone().ptr(AddrSpace::Local),
                is_local_buf: true,
                auto_local_size: Some(bytes),
            });
            self.bind(
                name.to_string(),
                Binding::ParamPtr { index, ty: ty.clone().ptr(AddrSpace::Local) },
            );
            if init.is_some() || init_list.is_some() {
                return self.err(pos, "local variables cannot have initialisers");
            }
            return Ok(());
        }
        let slot = self.func.add_slot(name, ty.clone(), count);
        self.bind(name.to_string(), Binding::Slot { slot, ty: ty.clone(), count });
        if let Some(e) = init {
            let (v, vt) = self.lower_expr(e)?;
            let v = self.coerce(v, &vt, ty, pos)?;
            self.push(Inst::Store { ty: ty.clone(), ptr: Operand::Slot(slot), val: v });
        }
        if let Some(elems) = init_list {
            if elems.len() > count {
                return self.err(pos, format!("too many initialisers ({} > {count})", elems.len()));
            }
            for (i, e) in elems.iter().enumerate() {
                let (v, vt) = self.lower_expr(e)?;
                let v = self.coerce(v, &vt, ty, pos)?;
                let ptr = self.push_val(Inst::Gep {
                    elem: ty.clone(),
                    base: Operand::Slot(slot),
                    idx: Operand::cu64(i as u64),
                });
                self.push(Inst::Store { ty: ty.clone(), ptr, val: v });
            }
        }
        Ok(())
    }

    /// Constant-evaluate small integer expressions (array sizes).
    fn const_eval_usize(&self, e: &Expr, pos: Pos) -> Result<usize> {
        fn eval(e: &Expr) -> Option<i64> {
            match e {
                Expr::Int(v, _, _) => Some(*v),
                Expr::Bin(op, a, b, _) => {
                    let (a, b) = (eval(a)?, eval(b)?);
                    Some(match *op {
                        "+" => a + b,
                        "-" => a - b,
                        "*" => a * b,
                        "/" => a / b,
                        "<<" => a << b,
                        ">>" => a >> b,
                        _ => return None,
                    })
                }
                Expr::Un("-", a, _) => Some(-eval(a)?),
                Expr::Cast(_, a, _) => eval(a),
                _ => None,
            }
        }
        match eval(e) {
            Some(v) if v > 0 => Ok(v as usize),
            _ => self.err(pos, "array size must be a positive integer constant"),
        }
    }

    // ---- expressions -----------------------------------------------------

    fn lower_expr(&mut self, e: &Expr) -> Result<(Operand, Type)> {
        match e {
            Expr::Int(v, unsigned, _) => {
                let s = if *unsigned { Scalar::U32 } else { Scalar::I32 };
                Ok((Operand::Imm(Imm::Int(*v, s)), Type::Scalar(s)))
            }
            Expr::Float(v, is_f32, _) => {
                let s = if *is_f32 { Scalar::F32 } else { Scalar::F64 };
                Ok((Operand::Imm(Imm::Float(*v, s)), Type::Scalar(s)))
            }
            Expr::Ident(name, pos) => match self.lookup(name) {
                Some(Binding::Slot { slot, ty, count }) => {
                    if *count > 1 {
                        // Array decays to a pointer (private space).
                        Ok((Operand::Slot(*slot), ty.clone().ptr(AddrSpace::Private)))
                    } else {
                        let ty = ty.clone();
                        let slot = *slot;
                        let v = self.push_val(Inst::Load { ty: ty.clone(), ptr: Operand::Slot(slot) });
                        Ok((v, ty))
                    }
                }
                Some(Binding::ParamPtr { index, ty }) => Ok((Operand::Arg(*index), ty.clone())),
                Some(Binding::PtrValue { val, ty }) => Ok((*val, ty.clone())),
                None => self.err(*pos, format!("unknown identifier `{name}`")),
            },
            Expr::Bin(op, a, b, pos) => self.lower_binop(op, a, b, *pos),
            Expr::Un(op, a, pos) => {
                let (v, ty) = self.lower_expr(a)?;
                match *op {
                    "-" => {
                        let r = self.push_val(Inst::Un { op: UnOp::Neg, ty: ty.clone(), a: v });
                        Ok((r, ty))
                    }
                    "~" => {
                        if !ty.is_int() {
                            return self.err(*pos, "~ requires an integer operand");
                        }
                        let r = self.push_val(Inst::Un { op: UnOp::Not, ty: ty.clone(), a: v });
                        Ok((r, ty))
                    }
                    "!" => {
                        let bv = self.to_bool(v, &ty);
                        let r = self.push_val(Inst::Un { op: UnOp::LNot, ty: Type::BOOL, a: bv });
                        Ok((r, Type::BOOL))
                    }
                    _ => self.err(*pos, format!("unsupported unary `{op}`")),
                }
            }
            Expr::IncDec { op, prefix, target, pos } => {
                let lv = self.lower_lvalue(target, *pos)?;
                let (old, ty) = self.load_lvalue(&lv);
                let binop = if *op == "+" { BinOp::Add } else { BinOp::Sub };
                let one = if ty.is_float() { Operand::cf32(1.0) } else { Operand::ci32(1) };
                let one = self.coerce(one, &one_ty(one), &ty, *pos)?;
                let newv = self.push_val(Inst::Bin { op: binop, ty: ty.clone(), a: old, b: one });
                self.store_lvalue(&lv, newv);
                Ok((if *prefix { newv } else { old }, ty))
            }
            Expr::Assign { op, target, value, pos } => {
                // The value is evaluated first (C leaves the order
                // unspecified); if resolving the target can change blocks
                // (e.g. `x[getIdx(...)] = v`), the value is spilled so its
                // register does not cross the inlined body.
                let (rv0, rty) = self.lower_expr(value)?;
                let staged = if expr_may_branch(target) && matches!(rv0, Operand::Reg(_)) {
                    let slot = self.func.add_slot("spill", rty.clone(), 1);
                    self.push(Inst::Store { ty: rty.clone(), ptr: Operand::Slot(slot), val: rv0 });
                    Err(slot)
                } else {
                    Ok(rv0)
                };
                let lv = self.lower_lvalue(target, *pos)?;
                let lty = lvalue_ty(&lv);
                let rv = match staged {
                    Ok(v) => v,
                    Err(slot) => {
                        self.push_val(Inst::Load { ty: rty.clone(), ptr: Operand::Slot(slot) })
                    }
                };
                let newv = if op.is_empty() {
                    self.coerce(rv, &rty, &lty, *pos)?
                } else {
                    let (old, _) = self.load_lvalue(&lv);
                    let binop = binop_from_str(op)
                        .ok_or_else(|| Error::Sema {
                            line: pos.line,
                            col: pos.col,
                            msg: format!("bad compound op `{op}`"),
                        })?;
                    let (a, b, opty) = self.usual_conversions(old, &lty, rv, &rty, *pos)?;
                    let r = self.push_val(Inst::Bin { op: binop, ty: opty.clone(), a, b });
                    self.coerce(r, &opty, &lty, *pos)?
                };
                self.store_lvalue(&lv, newv);
                Ok((newv, lty))
            }
            Expr::Ternary(c, a, b, pos) => {
                let pure = expr_is_pure(a) && expr_is_pure(b);
                let (cv, cty) = self.lower_expr(c)?;
                let cv = self.to_bool(cv, &cty);
                if pure {
                    let (av, aty) = self.lower_expr(a)?;
                    let (bv, bty) = self.lower_expr(b)?;
                    let (av, bv, ty) = self.usual_conversions(av, &aty, bv, &bty, *pos)?;
                    let r = self.push_val(Inst::Select { ty: ty.clone(), cond: cv, a: av, b: bv });
                    Ok((r, ty))
                } else {
                    // Control-flow lowering with a temp slot. Type is
                    // resolved by lowering arm `a` first into the slot's type.
                    let then_bb = self.new_block("sel.t");
                    let else_bb = self.new_block("sel.f");
                    let join = self.new_block("sel.j");
                    self.func.set_term(self.cur, Term::Br { cond: cv, t: then_bb, f: else_bb });
                    self.cur = then_bb;
                    let (av, aty) = self.lower_expr(a)?;
                    let slot = self.func.add_slot("ternary.tmp", aty.clone(), 1);
                    self.push(Inst::Store { ty: aty.clone(), ptr: Operand::Slot(slot), val: av });
                    self.func.set_term(self.cur, Term::Jump(join));
                    self.cur = else_bb;
                    let (bv, bty) = self.lower_expr(b)?;
                    let bv = self.coerce(bv, &bty, &aty, *pos)?;
                    self.push(Inst::Store { ty: aty.clone(), ptr: Operand::Slot(slot), val: bv });
                    self.func.set_term(self.cur, Term::Jump(join));
                    self.cur = join;
                    let v = self.push_val(Inst::Load { ty: aty.clone(), ptr: Operand::Slot(slot) });
                    Ok((v, aty))
                }
            }
            Expr::Cast(ty, inner, pos) => {
                let (v, vt) = self.lower_expr(inner)?;
                let r = self.coerce(v, &vt, ty, *pos)?;
                Ok((r, ty.clone()))
            }
            Expr::VecLit(ty, elems, pos) => self.lower_veclit(ty, elems, *pos),
            Expr::Call(name, args, pos) => self.lower_call(name, args, *pos),
            Expr::Index(base, idx, pos) => {
                let lv = self.lower_index_lvalue(base, idx, *pos)?;
                Ok(self.load_lvalue(&lv))
            }
            Expr::Swizzle(base, field, pos) => {
                let (v, ty) = self.lower_expr(base)?;
                let (elem_s, n) = match &ty {
                    Type::Vec(s, n) => (*s, *n as usize),
                    _ => return self.err(*pos, format!("swizzle on non-vector type {ty}")),
                };
                let lanes = swizzle_lanes(field, n)
                    .ok_or_else(|| Error::Sema {
                        line: pos.line,
                        col: pos.col,
                        msg: format!("bad swizzle `.{field}` on {ty}"),
                    })?;
                if lanes.len() == 1 {
                    let r = self.push_val(Inst::VecExtract {
                        elem: Type::Scalar(elem_s),
                        a: v,
                        lane: lanes[0],
                    });
                    Ok((r, Type::Scalar(elem_s)))
                } else {
                    let mut parts = Vec::new();
                    for &l in &lanes {
                        parts.push(self.push_val(Inst::VecExtract {
                            elem: Type::Scalar(elem_s),
                            a: v,
                            lane: l,
                        }));
                    }
                    let vty = Type::Vec(elem_s, lanes.len() as u8);
                    let r = self.push_val(Inst::VecBuild { ty: vty.clone(), elems: parts });
                    Ok((r, vty))
                }
            }
        }
    }

    fn lower_binop(&mut self, op: &str, a: &Expr, b: &Expr, pos: Pos) -> Result<(Operand, Type)> {
        // Short-circuit logical ops get control-flow lowering.
        if op == "&&" || op == "||" {
            let slot = self.func.add_slot("sc.tmp", Type::BOOL, 1);
            let (av, aty) = self.lower_expr(a)?;
            let av = self.to_bool(av, &aty);
            self.push(Inst::Store { ty: Type::BOOL, ptr: Operand::Slot(slot), val: av });
            let rhs_bb = self.new_block("sc.rhs");
            let join = self.new_block("sc.join");
            let term = if op == "&&" {
                Term::Br { cond: av, t: rhs_bb, f: join }
            } else {
                Term::Br { cond: av, t: join, f: rhs_bb }
            };
            self.func.set_term(self.cur, term);
            self.cur = rhs_bb;
            let (bv, bty) = self.lower_expr(b)?;
            let bv = self.to_bool(bv, &bty);
            self.push(Inst::Store { ty: Type::BOOL, ptr: Operand::Slot(slot), val: bv });
            self.func.set_term(self.cur, Term::Jump(join));
            self.cur = join;
            let v = self.push_val(Inst::Load { ty: Type::BOOL, ptr: Operand::Slot(slot) });
            return Ok((v, Type::BOOL));
        }
        let mut vals = self.lower_siblings(&[a, b])?;
        let (bv, bty) = vals.pop().unwrap();
        let (av, aty) = vals.pop().unwrap();
        // Pointer arithmetic: p + i.
        if let Type::Ptr(elem, space) = &aty {
            if op == "+" || op == "-" {
                let idx = if op == "-" {
                    self.push_val(Inst::Un { op: UnOp::Neg, ty: bty.clone(), a: bv })
                } else {
                    bv
                };
                let r = self.push_val(Inst::Gep { elem: (**elem).clone(), base: av, idx });
                return Ok((r, (**elem).clone().ptr(*space)));
            }
            return self.err(pos, format!("unsupported pointer op `{op}`"));
        }
        let binop = binop_from_str(op)
            .ok_or_else(|| Error::Sema { line: pos.line, col: pos.col, msg: format!("bad op `{op}`") })?;
        let (av, bv, opty) = self.usual_conversions(av, &aty, bv, &bty, pos)?;
        if binop.is_cmp() {
            let r = self.push_val(Inst::Bin { op: binop, ty: opty.clone(), a: av, b: bv });
            Ok((r, opty.with_elem(Scalar::Bool)))
        } else {
            let r = self.push_val(Inst::Bin { op: binop, ty: opty.clone(), a: av, b: bv });
            Ok((r, opty))
        }
    }

    fn lower_veclit(&mut self, ty: &Type, elems: &[Expr], pos: Pos) -> Result<(Operand, Type)> {
        let (elem_s, n) = match ty {
            Type::Vec(s, n) => (*s, *n as usize),
            _ => return self.err(pos, "vector literal requires vector type"),
        };
        let mut lanes: Vec<Operand> = Vec::new();
        for e in elems {
            let (v, vt) = self.lower_expr(e)?;
            match &vt {
                Type::Vec(s, m) => {
                    // Flatten a subvector into scalar lanes.
                    for l in 0..*m {
                        let x = self.push_val(Inst::VecExtract {
                            elem: Type::Scalar(*s),
                            a: v,
                            lane: l as u32,
                        });
                        let x = self.coerce(x, &Type::Scalar(*s), &Type::Scalar(elem_s), pos)?;
                        lanes.push(x);
                    }
                }
                _ => {
                    let x = self.coerce(v, &vt, &Type::Scalar(elem_s), pos)?;
                    lanes.push(x);
                }
            }
        }
        if lanes.len() == 1 {
            // Broadcast form: (float4)(x).
            let r = self.push_val(Inst::Splat { ty: ty.clone(), a: lanes[0] });
            return Ok((r, ty.clone()));
        }
        if lanes.len() != n {
            return self.err(pos, format!("vector literal has {} lanes, needs {n}", lanes.len()));
        }
        let r = self.push_val(Inst::VecBuild { ty: ty.clone(), elems: lanes });
        Ok((r, ty.clone()))
    }

    fn lower_call(&mut self, name: &str, args: &[Expr], pos: Pos) -> Result<(Operand, Type)> {
        // Work-item geometry builtins.
        let wi = match name {
            "get_global_id" => Some(WiFn::GlobalId),
            "get_local_id" => Some(WiFn::LocalId),
            "get_group_id" => Some(WiFn::GroupId),
            "get_global_size" => Some(WiFn::GlobalSize),
            "get_local_size" => Some(WiFn::LocalSize),
            "get_num_groups" => Some(WiFn::NumGroups),
            "get_work_dim" => Some(WiFn::WorkDim),
            "get_global_offset" => Some(WiFn::GlobalOffset),
            _ => None,
        };
        if let Some(func) = wi {
            let dim = match args.first() {
                Some(Expr::Int(v, _, _)) => *v as u32,
                None if func == WiFn::WorkDim => 0,
                _ => return self.err(pos, "work-item builtins need a literal dimension"),
            };
            let v = self.push_val(Inst::Wi { func, dim });
            return Ok((v, Type::U64));
        }
        // convert_<type>() family.
        if let Some(rest) = name.strip_prefix("convert_") {
            if let Some(ty) = super::parser::type_from_name(rest) {
                let (v, vt) = self.lower_expr(&args[0])?;
                let r = self.coerce(v, &vt, &ty, pos)?;
                return Ok((r, ty));
            }
        }
        // OpenCL select(a, b, c) = c ? b : a (lane-wise).
        if name == "select" {
            if args.len() != 3 {
                return self.err(pos, "select takes 3 arguments");
            }
            let refs: Vec<&Expr> = args.iter().collect();
            let mut vals = self.lower_siblings(&refs)?;
            let (c, cty) = vals.pop().unwrap();
            let (b, bty) = vals.pop().unwrap();
            let (a, aty) = vals.pop().unwrap();
            let (a, b, ty) = self.usual_conversions(a, &aty, b, &bty, pos)?;
            let cond = self.to_bool_shaped(c, &cty, &ty);
            let r = self.push_val(Inst::Select { ty: ty.clone(), cond, a: b, b: a });
            return Ok((r, ty));
        }
        // Math builtins.
        if let Some((func, int_ok)) = mathfn_from_name(name) {
            if args.len() != func.arity() {
                return self.err(pos, format!("{name} takes {} arguments", func.arity()));
            }
            let refs: Vec<&Expr> = args.iter().collect();
            let lowered = self.lower_siblings(&refs)?;
            let mut vals = Vec::new();
            let mut types = Vec::new();
            for (v, t) in lowered {
                vals.push(v);
                types.push(t);
            }
            // Common type across args (float-promote unless int function).
            let mut ty = types[0].clone();
            for t in &types[1..] {
                ty = common_type(&ty, t);
            }
            if !int_ok && !ty.is_float() {
                ty = ty.with_elem(Scalar::F32);
            }
            for (v, t) in vals.iter_mut().zip(&types) {
                *v = self.coerce(*v, t, &ty, pos)?;
            }
            let ret_ty = match func {
                MathFn::Dot | MathFn::Length | MathFn::Distance => {
                    Type::Scalar(ty.elem_scalar().unwrap_or(Scalar::F32))
                }
                _ => ty.clone(),
            };
            let r = self.push_val(Inst::Math { func, ty, args: vals });
            return Ok((r, ret_ty));
        }
        // Helper function inline expansion.
        if let Some(def) = self.helpers.get(name).copied() {
            if self.inline_stack.len() > 16 {
                return self.err(pos, format!("inline depth exceeded calling `{name}` (recursion?)"));
            }
            if args.len() != def.params.len() {
                return self.err(pos, format!("`{name}` takes {} args", def.params.len()));
            }
            // Bind arguments into fresh slots in a fresh scope (lowered
            // spill-safely: argument expressions may themselves inline
            // helpers).
            let refs: Vec<&Expr> = args.iter().collect();
            let lowered = self.lower_siblings(&refs)?;
            let mut frame = HashMap::new();
            for (p, (v, vt)) in def.params.iter().zip(lowered) {
                match &p.ty {
                    Type::Ptr(..) => {
                        // Pointers are captured by value. Only block-
                        // position-independent operands may be captured
                        // (the helper body can span blocks).
                        let ty = if matches!(vt, Type::Ptr(..)) { vt.clone() } else { p.ty.clone() };
                        match v {
                            Operand::Arg(i) => {
                                frame.insert(p.name.clone(), Binding::ParamPtr { index: i, ty });
                            }
                            Operand::Slot(_) => {
                                frame.insert(p.name.clone(), Binding::PtrValue { val: v, ty });
                            }
                            _ => {
                                return self.err(
                                    pos,
                                    format!(
                                        "pointer argument to `{name}` must be a parameter or \
                                         private array, not a computed pointer"
                                    ),
                                )
                            }
                        }
                    }
                    ty => {
                        let slot = self.func.add_slot(format!("{name}.{}", p.name), ty.clone(), 1);
                        let v = self.coerce(v, &vt, ty, pos)?;
                        self.push(Inst::Store { ty: ty.clone(), ptr: Operand::Slot(slot), val: v });
                        frame.insert(
                            p.name.clone(),
                            Binding::Slot { slot, ty: ty.clone(), count: 1 },
                        );
                    }
                }
            }
            let join = self.new_block("inl.join");
            let ret_slot = if def.ret == Type::Void {
                None
            } else {
                Some((self.func.add_slot(format!("{name}.ret"), def.ret.clone(), 1), def.ret.clone()))
            };
            self.inline_stack.push(InlineCtx { ret_slot: ret_slot.clone(), join });
            self.scopes.push(frame);
            for s in &def.body {
                self.lower_stmt(s)?;
            }
            self.scopes.pop();
            self.inline_stack.pop();
            self.func.set_term(self.cur, Term::Jump(join));
            self.cur = join;
            match ret_slot {
                Some((slot, ty)) => {
                    let v = self.push_val(Inst::Load { ty: ty.clone(), ptr: Operand::Slot(slot) });
                    Ok((v, ty))
                }
                None => Ok((Operand::ci32(0), Type::Void)),
            }
        } else {
            self.err(pos, format!("unknown function `{name}`"))
        }
    }

    /// Lower sibling expressions left-to-right, spilling earlier register
    /// results to slots whenever a *later* sibling can change the current
    /// block (helper inlining, short-circuit). This preserves the
    /// block-local-registers invariant across multi-block subexpressions.
    fn lower_siblings(&mut self, exprs: &[&Expr]) -> Result<Vec<(Operand, Type)>> {
        enum Staged {
            Direct(Operand, Type),
            Spilled(SlotId, Type),
        }
        let branchy: Vec<bool> = exprs.iter().map(|e| expr_may_branch(e)).collect();
        let mut staged = Vec::with_capacity(exprs.len());
        for (i, e) in exprs.iter().enumerate() {
            let (v, t) = self.lower_expr(e)?;
            let later_branches = branchy[i + 1..].iter().any(|b| *b);
            if later_branches && matches!(v, Operand::Reg(_)) {
                let slot = self.func.add_slot("spill", t.clone(), 1);
                self.push(Inst::Store { ty: t.clone(), ptr: Operand::Slot(slot), val: v });
                staged.push(Staged::Spilled(slot, t));
            } else {
                staged.push(Staged::Direct(v, t));
            }
        }
        let mut out = Vec::with_capacity(staged.len());
        for s in staged {
            out.push(match s {
                Staged::Direct(v, t) => (v, t),
                Staged::Spilled(slot, t) => {
                    let v = self.push_val(Inst::Load { ty: t.clone(), ptr: Operand::Slot(slot) });
                    (v, t)
                }
            });
        }
        Ok(out)
    }

    // ---- lvalues ---------------------------------------------------------

    fn lower_lvalue(&mut self, e: &Expr, pos: Pos) -> Result<LValue> {
        match e {
            Expr::Ident(name, _) => match self.lookup(name).cloned() {
                Some(Binding::Slot { slot, ty, count }) => {
                    if count > 1 {
                        return self.err(pos, format!("array `{name}` is not assignable"));
                    }
                    Ok(LValue::Mem { ptr: Operand::Slot(slot), ty, space: AddrSpace::Private })
                }
                Some(Binding::ParamPtr { .. }) | Some(Binding::PtrValue { .. }) => {
                    self.err(pos, format!("pointer `{name}` is not assignable"))
                }
                None => self.err(pos, format!("unknown identifier `{name}`")),
            },
            Expr::Index(base, idx, pos) => self.lower_index_lvalue(base, idx, *pos),
            Expr::Swizzle(base, field, pos) => {
                let lv = self.lower_lvalue(base, *pos)?;
                let (ptr, vec_ty, space) = match lv {
                    LValue::Mem { ptr, ty, space } => (ptr, ty, space),
                    LValue::Lane { .. } => return self.err(*pos, "nested swizzle lvalue"),
                };
                let n = vec_ty.lanes();
                let lanes = swizzle_lanes(field, n).ok_or_else(|| Error::Sema {
                    line: pos.line,
                    col: pos.col,
                    msg: format!("bad swizzle `.{field}`"),
                })?;
                if lanes.len() != 1 {
                    return self.err(*pos, "multi-lane swizzle assignment unsupported");
                }
                Ok(LValue::Lane { ptr, vec_ty, lane: lanes[0], space })
            }
            _ => self.err(pos, "expression is not assignable"),
        }
    }

    fn lower_index_lvalue(&mut self, base: &Expr, idx: &Expr, pos: Pos) -> Result<LValue> {
        let mut vals = self.lower_siblings(&[base, idx])?;
        let (iv, _ity) = vals.pop().unwrap();
        let (bv, bty) = vals.pop().unwrap();
        match bty {
            Type::Ptr(elem, space) => {
                let ptr = self.push_val(Inst::Gep { elem: (*elem).clone(), base: bv, idx: iv });
                Ok(LValue::Mem { ptr, ty: *elem, space })
            }
            _ => self.err(pos, format!("cannot index non-pointer type {bty}")),
        }
    }

    fn load_lvalue(&mut self, lv: &LValue) -> (Operand, Type) {
        match lv {
            LValue::Mem { ptr, ty, .. } => {
                let v = self.push_val(Inst::Load { ty: ty.clone(), ptr: *ptr });
                (v, ty.clone())
            }
            LValue::Lane { ptr, vec_ty, lane, .. } => {
                let v = self.push_val(Inst::Load { ty: vec_ty.clone(), ptr: *ptr });
                let elem = Type::Scalar(vec_ty.elem_scalar().unwrap());
                let x = self.push_val(Inst::VecExtract { elem: elem.clone(), a: v, lane: *lane });
                (x, elem)
            }
        }
    }

    fn store_lvalue(&mut self, lv: &LValue, val: Operand) {
        match lv {
            LValue::Mem { ptr, ty, .. } => {
                self.push(Inst::Store { ty: ty.clone(), ptr: *ptr, val });
            }
            LValue::Lane { ptr, vec_ty, lane, .. } => {
                let old = self.push_val(Inst::Load { ty: vec_ty.clone(), ptr: *ptr });
                let newv = self.push_val(Inst::VecInsert {
                    ty: vec_ty.clone(),
                    a: old,
                    lane: *lane,
                    v: val,
                });
                self.push(Inst::Store { ty: vec_ty.clone(), ptr: *ptr, val: newv });
            }
        }
    }

    // ---- conversions -----------------------------------------------------

    /// Convert `v : from` to type `to`, emitting a Cast if needed.
    fn coerce(&mut self, v: Operand, from: &Type, to: &Type, pos: Pos) -> Result<Operand> {
        if from == to {
            return Ok(v);
        }
        match (from, to) {
            (Type::Scalar(_), Type::Scalar(_)) => {
                // Fold immediates.
                if let Operand::Imm(imm) = v {
                    if let Some(folded) = fold_imm(imm, to) {
                        return Ok(Operand::Imm(folded));
                    }
                }
                Ok(self.push_val(Inst::Cast { to: to.clone(), from: from.clone(), a: v }))
            }
            (Type::Scalar(_), Type::Vec(s, _)) => {
                let x = self.coerce(v, from, &Type::Scalar(*s), pos)?;
                Ok(self.push_val(Inst::Splat { ty: to.clone(), a: x }))
            }
            (Type::Vec(_, n), Type::Vec(_, m)) if n == m => {
                Ok(self.push_val(Inst::Cast { to: to.clone(), from: from.clone(), a: v }))
            }
            (Type::Ptr(_, _), Type::Ptr(_, sp)) => {
                // Reinterpreting pointer casts keep the operand.
                let _ = sp;
                Ok(v)
            }
            _ => self.err(pos, format!("cannot convert {from} to {to}")),
        }
    }

    /// C usual arithmetic conversions extended to vectors.
    fn usual_conversions(
        &mut self,
        a: Operand,
        aty: &Type,
        b: Operand,
        bty: &Type,
        pos: Pos,
    ) -> Result<(Operand, Operand, Type)> {
        let ty = common_type(aty, bty);
        let a = self.coerce(a, aty, &ty, pos)?;
        let b = self.coerce(b, bty, &ty, pos)?;
        Ok((a, b, ty))
    }

    /// Reduce a value to a scalar bool (compare != 0 unless already bool).
    fn to_bool(&mut self, v: Operand, ty: &Type) -> Operand {
        if *ty == Type::BOOL {
            return v;
        }
        let zero = if ty.is_float() {
            Operand::Imm(Imm::Float(0.0, ty.elem_scalar().unwrap()))
        } else {
            Operand::Imm(Imm::Int(0, ty.elem_scalar().unwrap_or(Scalar::I32)))
        };
        self.push_val(Inst::Bin { op: BinOp::Ne, ty: ty.clone(), a: v, b: zero })
    }

    /// Shape a select condition to match the value type's lanes.
    fn to_bool_shaped(&mut self, c: Operand, cty: &Type, val_ty: &Type) -> Operand {
        match (cty, val_ty) {
            (Type::Vec(..), Type::Vec(..)) => {
                // OpenCL vector select uses the MSB of each int lane.
                let zero = Operand::Imm(Imm::Int(0, cty.elem_scalar().unwrap()));
                self.push_val(Inst::Bin { op: BinOp::Lt, ty: cty.clone(), a: c, b: zero })
            }
            _ => self.to_bool(c, cty),
        }
    }
}

fn one_ty(op: Operand) -> Type {
    match op {
        Operand::Imm(i) => i.ty(),
        _ => Type::I32,
    }
}

fn lvalue_ty(lv: &LValue) -> Type {
    match lv {
        LValue::Mem { ty, .. } => ty.clone(),
        LValue::Lane { vec_ty, .. } => Type::Scalar(vec_ty.elem_scalar().unwrap()),
    }
}

fn fold_imm(imm: Imm, to: &Type) -> Option<Imm> {
    let s = match to {
        Type::Scalar(s) => *s,
        _ => return None,
    };
    Some(match (imm, s) {
        (Imm::Int(v, _), s) if s.is_int() => Imm::Int(v, s),
        (Imm::Int(v, _), s) => Imm::Float(v as f64, s),
        (Imm::Float(v, _), s) if s.is_float() => Imm::Float(v, s),
        (Imm::Float(v, _), s) => Imm::Int(v as i64, s),
    })
}

/// C usual-arithmetic-conversions result type, extended lane-wise.
fn common_type(a: &Type, b: &Type) -> Type {
    use Scalar::*;
    // Vector shape wins.
    let lanes = a.lanes().max(b.lanes());
    let (sa, sb) = match (a.elem_scalar(), b.elem_scalar()) {
        (Some(x), Some(y)) => (x, y),
        _ => return a.clone(),
    };
    fn rank(s: Scalar) -> u8 {
        match s {
            Bool => 0,
            I32 => 1,
            U32 => 2,
            I64 => 3,
            U64 => 4,
            F32 => 5,
            F64 => 6,
        }
    }
    let s = if rank(sa) >= rank(sb) { sa } else { sb };
    // bool arithmetic promotes to int.
    let s = if s == Bool { I32 } else { s };
    if lanes > 1 {
        Type::Vec(s, lanes as u8)
    } else {
        Type::Scalar(s)
    }
}

fn binop_from_str(op: &str) -> Option<BinOp> {
    Some(match op {
        "+" => BinOp::Add,
        "-" => BinOp::Sub,
        "*" => BinOp::Mul,
        "/" => BinOp::Div,
        "%" => BinOp::Rem,
        "&" => BinOp::And,
        "|" => BinOp::Or,
        "^" => BinOp::Xor,
        "<<" => BinOp::Shl,
        ">>" => BinOp::Shr,
        "==" => BinOp::Eq,
        "!=" => BinOp::Ne,
        "<" => BinOp::Lt,
        "<=" => BinOp::Le,
        ">" => BinOp::Gt,
        ">=" => BinOp::Ge,
        _ => return None,
    })
}

/// Map OpenCL builtin names to MathFn; bool = integer types permitted.
fn mathfn_from_name(name: &str) -> Option<(MathFn, bool)> {
    use MathFn::*;
    Some(match name {
        "sqrt" => (Sqrt, false),
        "rsqrt" => (RSqrt, false),
        "exp" => (Exp, false),
        "exp2" => (Exp2, false),
        "log" => (Log, false),
        "log2" => (Log2, false),
        "sin" => (Sin, false),
        "cos" => (Cos, false),
        "tan" => (Tan, false),
        "fabs" => (Fabs, false),
        "floor" => (Floor, false),
        "ceil" => (Ceil, false),
        "round" => (Round, false),
        "trunc" => (Trunc, false),
        "pow" => (Pow, false),
        "fmin" => (Fmin, false),
        "fmax" => (Fmax, false),
        "fmod" => (Fmod, false),
        "mad" => (Mad, false),
        "fma" => (Fma, false),
        "min" => (Min, true),
        "max" => (Max, true),
        "clamp" => (Clamp, true),
        "abs" => (Abs, true),
        "mix" => (Mix, false),
        "dot" => (Dot, false),
        "length" => (Length, false),
        "normalize" => (Normalize, false),
        "distance" => (Distance, false),
        "native_sqrt" => (NativeSqrt, false),
        "native_rsqrt" => (NativeRSqrt, false),
        "native_exp" => (NativeExp, false),
        "native_log" => (NativeLog, false),
        "native_sin" => (NativeSin, false),
        "native_cos" => (NativeCos, false),
        "native_divide" => (NativeDivide, false),
        "native_recip" => (NativeRecip, false),
        "half_sqrt" => (NativeSqrt, false),
        "half_exp" => (NativeExp, false),
        _ => return None,
    })
}

/// Lanes selected by a swizzle suffix, or None if invalid.
fn swizzle_lanes(field: &str, n: usize) -> Option<Vec<u32>> {
    match field {
        "lo" => return Some((0..n as u32 / 2).collect()),
        "hi" => return Some((n as u32 / 2..n as u32).collect()),
        "even" => return Some((0..n as u32).step_by(2).collect()),
        "odd" => return Some((1..n as u32).step_by(2).collect()),
        _ => {}
    }
    if let Some(rest) = field.strip_prefix('s') {
        if !rest.is_empty() && rest.chars().all(|c| c.is_ascii_hexdigit()) {
            let lanes: Vec<u32> =
                rest.chars().map(|c| c.to_digit(16).unwrap()).collect();
            if lanes.iter().all(|&l| (l as usize) < n) {
                return Some(lanes);
            }
            return None;
        }
    }
    let mut lanes = Vec::new();
    for c in field.chars() {
        let l = match c {
            'x' => 0,
            'y' => 1,
            'z' => 2,
            'w' => 3,
            _ => return None,
        };
        if l >= n as u32 {
            return None;
        }
        lanes.push(l);
    }
    if lanes.is_empty() {
        None
    } else {
        Some(lanes)
    }
}

/// Can lowering this expression change the current block (helper-call
/// inlining, short-circuit ops, impure ternaries)? Used to decide when
/// earlier register operands must be spilled to slots (registers are
/// block-local).
fn expr_may_branch(e: &Expr) -> bool {
    match e {
        Expr::Int(..) | Expr::Float(..) | Expr::Ident(..) => false,
        Expr::Bin(op, a, b, _) => *op == "&&" || *op == "||" || expr_may_branch(a) || expr_may_branch(b),
        Expr::Un(_, a, _) => expr_may_branch(a),
        Expr::IncDec { target, .. } => expr_may_branch(target),
        Expr::Assign { target, value, .. } => expr_may_branch(target) || expr_may_branch(value),
        Expr::Ternary(c, a, b, _) => {
            !(expr_is_pure(a) && expr_is_pure(b))
                || expr_may_branch(c)
                || expr_may_branch(a)
                || expr_may_branch(b)
        }
        Expr::Cast(_, a, _) => expr_may_branch(a),
        Expr::VecLit(_, es, _) => es.iter().any(expr_may_branch),
        Expr::Call(name, args, _) => {
            // Helper calls inline multi-block bodies; wi/math/convert
            // builtins never branch.
            let builtin = mathfn_from_name(name).is_some()
                || name.starts_with("get_")
                || name.starts_with("convert_")
                || name == "select";
            !builtin || args.iter().any(expr_may_branch)
        }
        Expr::Index(a, i, _) => expr_may_branch(a) || expr_may_branch(i),
        Expr::Swizzle(a, _, _) => expr_may_branch(a),
    }
}

/// Side-effect-free check for ternary → select lowering.
fn expr_is_pure(e: &Expr) -> bool {
    match e {
        Expr::Int(..) | Expr::Float(..) | Expr::Ident(..) => true,
        Expr::Bin(op, a, b, _) => *op != "&&" && *op != "||" && expr_is_pure(a) && expr_is_pure(b),
        Expr::Un(_, a, _) => expr_is_pure(a),
        Expr::Ternary(c, a, b, _) => expr_is_pure(c) && expr_is_pure(a) && expr_is_pure(b),
        Expr::Cast(_, a, _) => expr_is_pure(a),
        Expr::VecLit(_, es, _) => es.iter().all(expr_is_pure),
        Expr::Index(a, i, _) => expr_is_pure(a) && expr_is_pure(i),
        Expr::Swizzle(a, _, _) => expr_is_pure(a),
        Expr::Call(name, args, _) => {
            mathfn_from_name(name).is_some() && args.iter().all(expr_is_pure)
        }
        Expr::IncDec { .. } | Expr::Assign { .. } => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::ir::verify::{barrier_count, verify};

    #[test]
    fn lowers_vecadd() {
        let m = compile(
            "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
                 size_t i = get_global_id(0);
                 c[i] = a[i] + b[i];
             }",
        )
        .unwrap();
        let k = m.kernel("vecadd").unwrap();
        verify(k).unwrap();
        assert_eq!(k.params.len(), 3);
        assert_eq!(k.slots.len(), 1); // `i`
    }

    #[test]
    fn scalar_params_become_slots() {
        let m = compile(
            "__kernel void k(__global float *x, uint n) { n >>= 1; x[0] = (float)n; }",
        )
        .unwrap();
        let k = m.kernel("k").unwrap();
        verify(k).unwrap();
        assert!(k.slots.iter().any(|s| s.name == "n"));
    }

    #[test]
    fn automatic_local_becomes_param() {
        let m = compile(
            "__kernel void k(__global float *x) {
                 __local float tile[4][8];
                 tile[get_local_id(0)][0] = x[0];
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[1] = tile[0][0];
             }",
        );
        // 2-D local array indexing `tile[a][b]` needs pointer-to-pointer,
        // which MiniCL flattens: `tile[a][b]` is unsupported — kernels in
        // the suite use flat indexing. Check the conversion itself with a
        // 1-D local instead.
        assert!(m.is_err());
        let m = compile(
            "__kernel void k(__global float *x) {
                 __local float tile[32];
                 tile[get_local_id(0)] = x[0];
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[1] = tile[0];
             }",
        )
        .unwrap();
        let k = m.kernel("k").unwrap();
        let lp = k.params.last().unwrap();
        assert!(lp.is_local_buf);
        assert_eq!(lp.auto_local_size, Some(32 * 4));
        assert_eq!(barrier_count(k), 1);
    }

    #[test]
    fn short_circuit_creates_blocks() {
        let m = compile(
            "__kernel void k(__global int *x, int n) {
                 int i = (int)get_global_id(0);
                 if (i < n && x[i] > 0) x[i] = 0;
             }",
        )
        .unwrap();
        let k = m.kernel("k").unwrap();
        verify(k).unwrap();
        assert!(k.blocks.len() >= 5, "short-circuit + if should create blocks");
    }

    #[test]
    fn helper_inlining() {
        let m = compile(
            "uint getIdx(uint g, uint l, uint w) { return g * w + l; }
             __kernel void k(__global float *x, uint w) {
                 x[getIdx((uint)get_group_id(0), (uint)get_local_id(0), w)] = 1.0f;
             }",
        )
        .unwrap();
        let k = m.kernel("k").unwrap();
        verify(k).unwrap();
        // Inlined body: slots for helper params + ret.
        assert!(k.slots.iter().any(|s| s.name.contains("getIdx")));
    }

    #[test]
    fn vector_swizzle_assignment() {
        let m = compile(
            "__kernel void k(__global float4 *v) {
                 float4 a = (float4)(1.0f, 2.0f, 3.0f, 4.0f);
                 a.x = a.y;
                 a.s2 = 7.0f;
                 v[0] = a.wzyx;
             }",
        )
        .unwrap();
        verify(m.kernel("k").unwrap()).unwrap();
    }

    #[test]
    fn loops_lower_to_cfg() {
        let m = compile(
            "__kernel void k(__global int *x, int n) {
                 for (int i = 0; i < n; i++) {
                     if (x[i] < 0) continue;
                     x[i] += 1;
                 }
                 int j = 0;
                 while (j < n) { j++; if (j == 3) break; }
             }",
        )
        .unwrap();
        let k = m.kernel("k").unwrap();
        verify(k).unwrap();
        let loops = crate::ir::loops::find_loops(k);
        assert_eq!(loops.len(), 2);
    }

    #[test]
    fn ternary_pure_becomes_select() {
        let m = compile(
            "__kernel void k(__global uint *x, uint n, uint inv) {
                 uint i = (uint)get_global_id(0);
                 x[i] = (inv) ? i * n : n * i;
             }",
        )
        .unwrap();
        let k = m.kernel("k").unwrap();
        let has_select = k
            .blocks
            .iter()
            .flat_map(|b| &b.insts)
            .any(|(_, i)| matches!(i, Inst::Select { .. }));
        assert!(has_select);
        // Pure ternary: no extra control flow from the ternary itself.
        assert_eq!(k.blocks.len(), 1);
    }

    #[test]
    fn rejects_unknown_identifier_with_position() {
        let e = compile("__kernel void k(__global int *x) {\n x[0] = y;\n }").unwrap_err();
        match e {
            Error::Sema { line, .. } => assert_eq!(line, 2),
            other => panic!("{other}"),
        }
    }

    #[test]
    fn math_builtins_and_conversions() {
        let m = compile(
            "__kernel void k(__global float *x) {
                 size_t i = get_global_id(0);
                 float a = sqrt(x[i]) + exp(x[i]) * sin(x[i]);
                 float4 v = (float4)(a) * 2.0f;
                 x[i] = mad(a, 2.0f, dot(v, v)) + fmax(a, 0.5f) + (float)max(1, 2);
             }",
        )
        .unwrap();
        verify(m.kernel("k").unwrap()).unwrap();
    }
}
