//! MiniCL recursive-descent parser.

use super::ast::*;
use super::lexer::{lex, Tok, Token};
use crate::cl::error::{Error, Result};
use crate::ir::types::{AddrSpace, Scalar, Type};

/// Parse a MiniCL source string into a `Unit`.
pub fn parse(src: &str) -> Result<Unit> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.unit()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }
    fn peek2(&self) -> &Tok {
        &self.toks[(self.pos + 1).min(self.toks.len() - 1)].tok
    }
    fn here(&self) -> Pos {
        let t = &self.toks[self.pos];
        Pos { line: t.line, col: t.col }
    }
    fn bump(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }
    fn err<T>(&self, msg: impl Into<String>) -> Result<T> {
        let p = self.here();
        Err(Error::Parse { line: p.line, col: p.col, msg: msg.into() })
    }
    fn eat_punct(&mut self, p: &str) -> bool {
        if matches!(self.peek(), Tok::Punct(q) if *q == p) {
            self.bump();
            true
        } else {
            false
        }
    }
    fn expect_punct(&mut self, p: &str) -> Result<()> {
        if self.eat_punct(p) {
            Ok(())
        } else {
            self.err(format!("expected `{p}`, found {:?}", self.peek()))
        }
    }
    fn eat_ident(&mut self, name: &str) -> bool {
        if matches!(self.peek(), Tok::Ident(s) if s == name) {
            self.bump();
            true
        } else {
            false
        }
    }
    fn expect_ident(&mut self) -> Result<String> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => {
                self.pos -= 1;
                self.err(format!("expected identifier, found {other:?}"))
            }
        }
    }

    // ---- types ----------------------------------------------------------

    /// Try to parse a scalar/vector type name. Does not consume on failure.
    fn try_type_name(&mut self) -> Option<Type> {
        let name = match self.peek() {
            Tok::Ident(s) => s.clone(),
            _ => return None,
        };
        let ty = type_from_name(&name)?;
        self.bump();
        Some(ty)
    }

    /// True if the current token begins a type (used to disambiguate decls
    /// from expressions).
    fn starts_type(&self) -> bool {
        match self.peek() {
            Tok::Ident(s) => {
                type_from_name(s).is_some()
                    || matches!(
                        s.as_str(),
                        "__global"
                            | "global"
                            | "__local"
                            | "local"
                            | "__constant"
                            | "constant"
                            | "__private"
                            | "private"
                            | "const"
                            | "void"
                    )
            }
            _ => false,
        }
    }

    /// Parse `[qualifiers] base [*]`, returning (type, space, is_const).
    fn full_type(&mut self) -> Result<(Type, AddrSpace, bool)> {
        let mut space = AddrSpace::Private;
        let mut is_const = false;
        loop {
            match self.peek() {
                Tok::Ident(s) => match s.as_str() {
                    "__global" | "global" => {
                        space = AddrSpace::Global;
                        self.bump();
                    }
                    "__local" | "local" => {
                        space = AddrSpace::Local;
                        self.bump();
                    }
                    "__constant" | "constant" => {
                        space = AddrSpace::Constant;
                        self.bump();
                    }
                    "__private" | "private" => {
                        space = AddrSpace::Private;
                        self.bump();
                    }
                    "const" => {
                        is_const = true;
                        self.bump();
                    }
                    "volatile" | "restrict" | "__restrict" => {
                        self.bump();
                    }
                    _ => break,
                },
                _ => break,
            }
        }
        let base = match self.try_type_name() {
            Some(t) => t,
            None => return self.err(format!("expected type, found {:?}", self.peek())),
        };
        let mut ty = base;
        while self.eat_punct("*") {
            ty = ty.ptr(space);
        }
        Ok((ty, space, is_const))
    }

    // ---- top level -------------------------------------------------------

    fn unit(&mut self) -> Result<Unit> {
        let mut unit = Unit::default();
        while !matches!(self.peek(), Tok::Eof) {
            unit.funcs.push(self.func_def()?);
        }
        Ok(unit)
    }

    fn func_def(&mut self) -> Result<FuncDef> {
        let pos = self.here();
        let mut is_kernel = false;
        loop {
            if self.eat_ident("__kernel") || self.eat_ident("kernel") {
                is_kernel = true;
            } else if self.eat_ident("__attribute__") {
                // skip __attribute__((...))
                self.expect_punct("(")?;
                let mut depth = 1;
                while depth > 0 {
                    match self.bump() {
                        Tok::Punct("(") => depth += 1,
                        Tok::Punct(")") => depth -= 1,
                        Tok::Eof => return self.err("unterminated attribute"),
                        _ => {}
                    }
                }
            } else if self.eat_ident("static") || self.eat_ident("inline") {
            } else {
                break;
            }
        }
        let ret = if self.eat_ident("void") {
            Type::Void
        } else {
            let (t, _, _) = self.full_type()?;
            t
        };
        let name = self.expect_ident()?;
        self.expect_punct("(")?;
        let mut params = Vec::new();
        if !self.eat_punct(")") {
            loop {
                let ppos = self.here();
                let (ty, _space, is_const) = self.full_type()?;
                let pname = self.expect_ident()?;
                params.push(ParamDecl { name: pname, ty, is_const, pos: ppos });
                if self.eat_punct(")") {
                    break;
                }
                self.expect_punct(",")?;
            }
        }
        self.expect_punct("{")?;
        let body = self.block_body()?;
        Ok(FuncDef { name, is_kernel, ret, params, body, pos })
    }

    // ---- statements ------------------------------------------------------

    /// Parse statements until `}` (consumed).
    fn block_body(&mut self) -> Result<Vec<Stmt>> {
        let mut stmts = Vec::new();
        while !self.eat_punct("}") {
            if matches!(self.peek(), Tok::Eof) {
                return self.err("unexpected EOF in block");
            }
            stmts.push(self.stmt()?);
        }
        Ok(stmts)
    }

    fn stmt(&mut self) -> Result<Stmt> {
        let pos = self.here();
        if self.eat_punct("{") {
            return Ok(Stmt::Block(self.block_body()?));
        }
        if self.eat_punct(";") {
            return Ok(Stmt::Block(vec![]));
        }
        match self.peek() {
            Tok::Ident(s) => match s.as_str() {
                "if" => return self.if_stmt(),
                "for" => return self.for_stmt(),
                "while" => return self.while_stmt(),
                "do" => return self.do_stmt(),
                "break" => {
                    self.bump();
                    self.expect_punct(";")?;
                    return Ok(Stmt::Break(pos));
                }
                "continue" => {
                    self.bump();
                    self.expect_punct(";")?;
                    return Ok(Stmt::Continue(pos));
                }
                "return" => {
                    self.bump();
                    if self.eat_punct(";") {
                        return Ok(Stmt::Return(None, pos));
                    }
                    let e = self.expr()?;
                    self.expect_punct(";")?;
                    return Ok(Stmt::Return(Some(e), pos));
                }
                "barrier" | "mem_fence" => {
                    self.bump();
                    self.expect_punct("(")?;
                    // Swallow the fence-flag expression.
                    let mut depth = 1;
                    while depth > 0 {
                        match self.bump() {
                            Tok::Punct("(") => depth += 1,
                            Tok::Punct(")") => depth -= 1,
                            Tok::Eof => return self.err("unterminated barrier()"),
                            _ => {}
                        }
                    }
                    self.expect_punct(";")?;
                    return Ok(Stmt::Barrier(pos));
                }
                _ => {}
            },
            _ => {}
        }
        if self.starts_type() {
            return self.decl_stmt();
        }
        let e = self.expr()?;
        self.expect_punct(";")?;
        Ok(Stmt::Expr(e))
    }

    fn decl_stmt(&mut self) -> Result<Stmt> {
        let pos = self.here();
        let (ty, space, _c) = self.full_type()?;
        let mut decls = Vec::new();
        loop {
            let name = self.expect_ident()?;
            // Array suffixes: flatten multi-dim.
            let mut array: Option<Expr> = None;
            while self.eat_punct("[") {
                let len = self.expr()?;
                self.expect_punct("]")?;
                array = Some(match array {
                    None => len,
                    Some(prev) => {
                        Expr::Bin("*", Box::new(prev), Box::new(len), pos)
                    }
                });
            }
            let mut init = None;
            let mut init_list = None;
            if self.eat_punct("=") {
                if self.eat_punct("{") {
                    let mut elems = Vec::new();
                    if !self.eat_punct("}") {
                        loop {
                            // Flatten nested braces for 2-D initialisers.
                            if self.eat_punct("{") {
                                loop {
                                    elems.push(self.assign_expr()?);
                                    if self.eat_punct("}") {
                                        break;
                                    }
                                    self.expect_punct(",")?;
                                }
                            } else {
                                elems.push(self.assign_expr()?);
                            }
                            if self.eat_punct("}") {
                                break;
                            }
                            self.expect_punct(",")?;
                        }
                    }
                    init_list = Some(elems);
                } else {
                    init = Some(self.assign_expr()?);
                }
            }
            decls.push(Stmt::Decl { name, ty: ty.clone(), space, array, init, init_list, pos });
            if self.eat_punct(";") {
                break;
            }
            self.expect_punct(",")?;
        }
        Ok(if decls.len() == 1 { decls.pop().unwrap() } else { Stmt::Block(decls) })
    }

    fn if_stmt(&mut self) -> Result<Stmt> {
        let pos = self.here();
        self.bump(); // if
        self.expect_punct("(")?;
        let cond = self.expr()?;
        self.expect_punct(")")?;
        let then_body = self.stmt_as_block()?;
        let else_body = if self.eat_ident("else") { self.stmt_as_block()? } else { vec![] };
        Ok(Stmt::If { cond, then_body, else_body, pos })
    }

    fn stmt_as_block(&mut self) -> Result<Vec<Stmt>> {
        if self.eat_punct("{") {
            self.block_body()
        } else {
            Ok(vec![self.stmt()?])
        }
    }

    fn for_stmt(&mut self) -> Result<Stmt> {
        let pos = self.here();
        self.bump(); // for
        self.expect_punct("(")?;
        let init = if self.eat_punct(";") {
            None
        } else if self.starts_type() {
            Some(Box::new(self.decl_stmt()?)) // consumes `;`
        } else {
            let e = self.expr()?;
            self.expect_punct(";")?;
            Some(Box::new(Stmt::Expr(e)))
        };
        let cond = if self.eat_punct(";") {
            None
        } else {
            let e = self.expr()?;
            self.expect_punct(";")?;
            Some(e)
        };
        let step = if self.eat_punct(")") {
            None
        } else {
            let e = self.expr()?;
            self.expect_punct(")")?;
            Some(e)
        };
        let body = self.stmt_as_block()?;
        Ok(Stmt::For { init, cond, step, body, pos })
    }

    fn while_stmt(&mut self) -> Result<Stmt> {
        let pos = self.here();
        self.bump();
        self.expect_punct("(")?;
        let cond = self.expr()?;
        self.expect_punct(")")?;
        let body = self.stmt_as_block()?;
        Ok(Stmt::While { cond, body, pos })
    }

    fn do_stmt(&mut self) -> Result<Stmt> {
        let pos = self.here();
        self.bump();
        let body = self.stmt_as_block()?;
        if !self.eat_ident("while") {
            return self.err("expected `while` after do-body");
        }
        self.expect_punct("(")?;
        let cond = self.expr()?;
        self.expect_punct(")")?;
        self.expect_punct(";")?;
        Ok(Stmt::DoWhile { cond, body, pos })
    }

    // ---- expressions (precedence climbing) --------------------------------

    fn expr(&mut self) -> Result<Expr> {
        self.assign_expr()
    }

    fn assign_expr(&mut self) -> Result<Expr> {
        let lhs = self.ternary_expr()?;
        let pos = self.here();
        let op = match self.peek() {
            Tok::Punct("=") => "",
            Tok::Punct("+=") => "+",
            Tok::Punct("-=") => "-",
            Tok::Punct("*=") => "*",
            Tok::Punct("/=") => "/",
            Tok::Punct("%=") => "%",
            Tok::Punct("&=") => "&",
            Tok::Punct("|=") => "|",
            Tok::Punct("^=") => "^",
            Tok::Punct("<<=") => "<<",
            Tok::Punct(">>=") => ">>",
            _ => return Ok(lhs),
        };
        self.bump();
        let value = self.assign_expr()?;
        Ok(Expr::Assign { op, target: Box::new(lhs), value: Box::new(value), pos })
    }

    fn ternary_expr(&mut self) -> Result<Expr> {
        let cond = self.bin_expr(0)?;
        if self.eat_punct("?") {
            let pos = cond.pos();
            let a = self.expr()?;
            self.expect_punct(":")?;
            let b = self.ternary_expr()?;
            Ok(Expr::Ternary(Box::new(cond), Box::new(a), Box::new(b), pos))
        } else {
            Ok(cond)
        }
    }

    fn bin_expr(&mut self, min_prec: u8) -> Result<Expr> {
        let mut lhs = self.unary_expr()?;
        loop {
            let (op, prec) = match self.peek() {
                Tok::Punct("||") => ("||", 1),
                Tok::Punct("&&") => ("&&", 2),
                Tok::Punct("|") => ("|", 3),
                Tok::Punct("^") => ("^", 4),
                Tok::Punct("&") => ("&", 5),
                Tok::Punct("==") => ("==", 6),
                Tok::Punct("!=") => ("!=", 6),
                Tok::Punct("<") => ("<", 7),
                Tok::Punct(">") => (">", 7),
                Tok::Punct("<=") => ("<=", 7),
                Tok::Punct(">=") => (">=", 7),
                Tok::Punct("<<") => ("<<", 8),
                Tok::Punct(">>") => (">>", 8),
                Tok::Punct("+") => ("+", 9),
                Tok::Punct("-") => ("-", 9),
                Tok::Punct("*") => ("*", 10),
                Tok::Punct("/") => ("/", 10),
                Tok::Punct("%") => ("%", 10),
                _ => break,
            };
            if prec < min_prec {
                break;
            }
            let pos = self.here();
            self.bump();
            let rhs = self.bin_expr(prec + 1)?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs), pos);
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr> {
        let pos = self.here();
        if self.eat_punct("-") {
            return Ok(Expr::Un("-", Box::new(self.unary_expr()?), pos));
        }
        if self.eat_punct("!") {
            return Ok(Expr::Un("!", Box::new(self.unary_expr()?), pos));
        }
        if self.eat_punct("~") {
            return Ok(Expr::Un("~", Box::new(self.unary_expr()?), pos));
        }
        if self.eat_punct("+") {
            return self.unary_expr();
        }
        if self.eat_punct("++") {
            return Ok(Expr::IncDec {
                op: "+",
                prefix: true,
                target: Box::new(self.unary_expr()?),
                pos,
            });
        }
        if self.eat_punct("--") {
            return Ok(Expr::IncDec {
                op: "-",
                prefix: true,
                target: Box::new(self.unary_expr()?),
                pos,
            });
        }
        // `(type) expr` cast or `(typeN)(...)` vector literal.
        if matches!(self.peek(), Tok::Punct("(")) {
            if let Tok::Ident(name) = self.peek2() {
                if let Some(ty) = type_from_name(name) {
                    // Need a 3-token lookahead for `)` after the type.
                    let save = self.pos;
                    self.bump(); // (
                    self.bump(); // type
                    if self.eat_punct(")") {
                        if matches!(ty, Type::Vec(..)) && matches!(self.peek(), Tok::Punct("(")) {
                            // vector literal
                            self.expect_punct("(")?;
                            let mut elems = Vec::new();
                            loop {
                                elems.push(self.assign_expr()?);
                                if self.eat_punct(")") {
                                    break;
                                }
                                self.expect_punct(",")?;
                            }
                            return self.postfix_tail(Expr::VecLit(ty, elems, pos));
                        }
                        let e = self.unary_expr()?;
                        return Ok(Expr::Cast(ty, Box::new(e), pos));
                    }
                    self.pos = save;
                }
            }
        }
        self.postfix_expr()
    }

    fn postfix_expr(&mut self) -> Result<Expr> {
        let pos = self.here();
        let mut e = match self.bump() {
            Tok::Int(v, u) => Expr::Int(v, u, pos),
            Tok::Float(v, f) => Expr::Float(v, f, pos),
            Tok::Ident(name) => {
                if matches!(self.peek(), Tok::Punct("(")) {
                    self.bump();
                    let mut args = Vec::new();
                    if !self.eat_punct(")") {
                        loop {
                            args.push(self.assign_expr()?);
                            if self.eat_punct(")") {
                                break;
                            }
                            self.expect_punct(",")?;
                        }
                    }
                    Expr::Call(name, args, pos)
                } else {
                    Expr::Ident(name, pos)
                }
            }
            Tok::Punct("(") => {
                let inner = self.expr()?;
                self.expect_punct(")")?;
                inner
            }
            other => {
                self.pos -= 1;
                return self.err(format!("expected expression, found {other:?}"));
            }
        };
        e = self.postfix_tail(e)?;
        Ok(e)
    }

    fn postfix_tail(&mut self, mut e: Expr) -> Result<Expr> {
        loop {
            let pos = self.here();
            if self.eat_punct("[") {
                let idx = self.expr()?;
                self.expect_punct("]")?;
                e = Expr::Index(Box::new(e), Box::new(idx), pos);
            } else if self.eat_punct(".") {
                let field = self.expect_ident()?;
                e = Expr::Swizzle(Box::new(e), field, pos);
            } else if self.eat_punct("++") {
                e = Expr::IncDec { op: "+", prefix: false, target: Box::new(e), pos };
            } else if self.eat_punct("--") {
                e = Expr::IncDec { op: "-", prefix: false, target: Box::new(e), pos };
            } else {
                return Ok(e);
            }
        }
    }
}

/// Map a type name to a `Type` (None if not a type).
pub fn type_from_name(name: &str) -> Option<Type> {
    let (base, lanes) = split_vec_suffix(name);
    let scalar = match base {
        "float" => Scalar::F32,
        "double" => Scalar::F64,
        "int" => Scalar::I32,
        "uint" | "unsigned" => Scalar::U32,
        "long" => Scalar::I64,
        "ulong" | "size_t" => Scalar::U64,
        "bool" => Scalar::Bool,
        "uchar" | "char" | "short" | "ushort" => return None, // unsupported widths
        _ => return None,
    };
    match lanes {
        1 => Some(Type::Scalar(scalar)),
        2 | 3 | 4 | 8 | 16 => Some(Type::Vec(scalar, lanes as u8)),
        _ => None,
    }
}

fn split_vec_suffix(name: &str) -> (&str, usize) {
    for n in [16usize, 8, 4, 3, 2] {
        let suffix = n.to_string();
        if let Some(base) = name.strip_suffix(&suffix) {
            if !base.is_empty() && base.chars().all(|c| c.is_ascii_alphabetic() || c == '_') {
                return (base, n);
            }
        }
    }
    (name, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_vecadd() {
        let unit = parse(
            "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
                 size_t i = get_global_id(0);
                 c[i] = a[i] + b[i];
             }",
        )
        .unwrap();
        assert_eq!(unit.funcs.len(), 1);
        let k = &unit.funcs[0];
        assert!(k.is_kernel);
        assert_eq!(k.name, "vecadd");
        assert_eq!(k.params.len(), 3);
        assert_eq!(k.body.len(), 2);
    }

    #[test]
    fn parses_control_flow() {
        let unit = parse(
            "__kernel void k(__global int *x) {
                 for (int i = 0; i < 10; i++) {
                     if (x[i] > 0) { x[i] -= 1; } else { continue; }
                     while (x[i] < 0) x[i] = x[i] + 2;
                 }
                 barrier(CLK_LOCAL_MEM_FENCE);
             }",
        )
        .unwrap();
        assert!(matches!(unit.funcs[0].body[0], Stmt::For { .. }));
        assert!(matches!(unit.funcs[0].body[1], Stmt::Barrier(_)));
    }

    #[test]
    fn parses_vector_literals_and_swizzles() {
        let unit = parse(
            "__kernel void k(__global float4 *v) {
                 float4 a = (float4)(1.0f, 2.0f, 3.0f, 4.0f);
                 a.x = a.y + a.w;
                 v[0] = a;
             }",
        )
        .unwrap();
        assert_eq!(unit.funcs[0].body.len(), 3);
    }

    #[test]
    fn parses_helper_functions() {
        let unit = parse(
            "uint getIdx(uint g, uint l, uint w) { return g * w + l; }
             __kernel void k(__global float *x, uint w) {
                 x[getIdx(get_group_id(0), get_local_id(0), w)] = 1.0f;
             }",
        )
        .unwrap();
        assert_eq!(unit.funcs.len(), 2);
        assert!(!unit.funcs[0].is_kernel);
    }

    #[test]
    fn parses_local_arrays() {
        let unit = parse(
            "__kernel void k(__global float *x) {
                 __local float tile[8][8];
                 float priv[4];
                 tile[0][0] = priv[0];
             }",
        )
        .unwrap();
        match &unit.funcs[0].body[0] {
            Stmt::Decl { space, array, .. } => {
                assert_eq!(*space, crate::ir::types::AddrSpace::Local);
                assert!(array.is_some());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_ternary_and_casts() {
        parse(
            "__kernel void k(__global uint *x, uint n, uint inv) {
                 uint i = get_global_id(0);
                 uint idx = (inv) ? i * n : n * i;
                 x[idx] = (uint)((float)idx * 0.5f);
             }",
        )
        .unwrap();
    }

    #[test]
    fn type_names() {
        assert_eq!(type_from_name("float4"), Some(Type::Vec(Scalar::F32, 4)));
        assert_eq!(type_from_name("uint"), Some(Type::U32));
        assert_eq!(type_from_name("size_t"), Some(Type::U64));
        assert_eq!(type_from_name("floaty"), None);
        assert_eq!(type_from_name("x2"), None);
    }

    #[test]
    fn error_position_reported() {
        let e = parse("__kernel void k() { int = 3; }").unwrap_err();
        match e {
            Error::Parse { line, .. } => assert_eq!(line, 1),
            _ => panic!(),
        }
    }
}
