//! MiniCL lexer. Handles comments, a one-pass object-like `#define`
//! preprocessor, and OpenCL C literal suffixes (`1.0f`, `4u`).

use crate::cl::error::{Error, Result};
use std::collections::HashMap;

/// Token kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// Integer literal (value, is_unsigned).
    Int(i64, bool),
    /// Floating literal (value, is_f32). `1.0` defaults to double per C,
    /// but MiniCL treats unsuffixed floats as f32 (OpenCL kernels almost
    /// always mean f32; `cl_khr_fp64` users write explicit casts).
    Float(f64, bool),
    /// Punctuation / operator, e.g. `"+"`, `"<<="`, `"("`.
    Punct(&'static str),
    /// End of input.
    Eof,
}

/// A token with source position.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
    pub col: u32,
}

/// All multi-char punctuation, longest-first so maximal munch works.
const PUNCTS: &[&str] = &[
    "<<=", ">>=", "...", "==", "!=", "<=", ">=", "&&", "||", "<<", ">>", "+=", "-=", "*=", "/=",
    "%=", "&=", "|=", "^=", "++", "--", "->", "+", "-", "*", "/", "%", "=", "<", ">", "!", "~",
    "&", "|", "^", "?", ":", ";", ",", ".", "(", ")", "[", "]", "{", "}",
];

/// Strip comments and expand object-like `#define NAME tokens...` macros.
/// Unsupported directives (`#if`, function-like macros) are reported.
fn preprocess(src: &str) -> Result<String> {
    let mut defines: HashMap<String, String> = HashMap::new();
    let mut out = String::with_capacity(src.len());
    // Comment removal first (preserving newlines so line numbers survive).
    let decommented = strip_comments(src);
    for (lineno, line) in decommented.lines().enumerate() {
        let trimmed = line.trim_start();
        if let Some(rest) = trimmed.strip_prefix('#') {
            let rest = rest.trim_start();
            if let Some(def) = rest.strip_prefix("define") {
                let def = def.trim_start();
                let mut parts = def.splitn(2, char::is_whitespace);
                let name = parts.next().unwrap_or("").to_string();
                if name.contains('(') {
                    return Err(Error::Parse {
                        line: lineno as u32 + 1,
                        col: 1,
                        msg: format!("function-like macro `{name}` not supported"),
                    });
                }
                let body = parts.next().unwrap_or("").trim().to_string();
                defines.insert(name, body);
                out.push('\n');
                continue;
            }
            if rest.starts_with("pragma") || rest.starts_with("include") {
                // Pragmas (fp64 enables) and includes are ignored.
                out.push('\n');
                continue;
            }
            return Err(Error::Parse {
                line: lineno as u32 + 1,
                col: 1,
                msg: format!("unsupported preprocessor directive: #{rest}"),
            });
        }
        // Substitute defines on identifier boundaries (iteratively, so
        // defines can reference earlier defines; depth-capped).
        let mut cur = line.to_string();
        for _ in 0..8 {
            let next = substitute(&cur, &defines);
            if next == cur {
                break;
            }
            cur = next;
        }
        out.push_str(&cur);
        out.push('\n');
    }
    Ok(out)
}

fn strip_comments(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    while i < b.len() {
        if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '/' {
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
        } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
            i += 2;
            while i + 1 < b.len() && !(b[i] == '*' && b[i + 1] == '/') {
                if b[i] == '\n' {
                    out.push('\n'); // keep line count
                }
                i += 1;
            }
            i += 2;
        } else {
            out.push(b[i]);
            i += 1;
        }
    }
    out
}

fn substitute(line: &str, defines: &HashMap<String, String>) -> String {
    let chars: Vec<char> = line.chars().collect();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    while i < chars.len() {
        if chars[i].is_ascii_alphabetic() || chars[i] == '_' {
            let start = i;
            while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            let word: String = chars[start..i].iter().collect();
            match defines.get(&word) {
                Some(body) => out.push_str(body),
                None => out.push_str(&word),
            }
        } else {
            out.push(chars[i]);
            i += 1;
        }
    }
    out
}

/// Tokenise MiniCL source.
pub fn lex(src: &str) -> Result<Vec<Token>> {
    let src = preprocess(src)?;
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    let mut line: u32 = 1;
    let mut col: u32 = 1;
    macro_rules! bump {
        () => {{
            if chars[i] == '\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
            i += 1;
        }};
    }
    while i < chars.len() {
        let c = chars[i];
        if c.is_whitespace() {
            bump!();
            continue;
        }
        let (tline, tcol) = (line, col);
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                bump!();
            }
            let word: String = chars[start..i].iter().collect();
            toks.push(Token { tok: Tok::Ident(word), line: tline, col: tcol });
            continue;
        }
        if c.is_ascii_digit() || (c == '.' && i + 1 < chars.len() && chars[i + 1].is_ascii_digit())
        {
            let start = i;
            let mut is_float = false;
            // Hex?
            if c == '0' && i + 1 < chars.len() && (chars[i + 1] == 'x' || chars[i + 1] == 'X') {
                bump!();
                bump!();
                while i < chars.len() && chars[i].is_ascii_hexdigit() {
                    bump!();
                }
                let text: String = chars[start + 2..i].iter().collect();
                let v = i64::from_str_radix(&text, 16).map_err(|e| Error::Parse {
                    line: tline,
                    col: tcol,
                    msg: format!("bad hex literal: {e}"),
                })?;
                let unsigned = i < chars.len() && (chars[i] == 'u' || chars[i] == 'U');
                if unsigned {
                    bump!();
                }
                toks.push(Token { tok: Tok::Int(v, unsigned), line: tline, col: tcol });
                continue;
            }
            while i < chars.len() && chars[i].is_ascii_digit() {
                bump!();
            }
            if i < chars.len() && chars[i] == '.' {
                is_float = true;
                bump!();
                while i < chars.len() && chars[i].is_ascii_digit() {
                    bump!();
                }
            }
            if i < chars.len() && (chars[i] == 'e' || chars[i] == 'E') {
                is_float = true;
                bump!();
                if i < chars.len() && (chars[i] == '+' || chars[i] == '-') {
                    bump!();
                }
                while i < chars.len() && chars[i].is_ascii_digit() {
                    bump!();
                }
            }
            let text: String = chars[start..i].iter().collect();
            if is_float {
                let mut is_f32 = true; // MiniCL default (see Tok::Float)
                if i < chars.len() && (chars[i] == 'f' || chars[i] == 'F') {
                    bump!();
                } else if i < chars.len() && (chars[i] == 'd' || chars[i] == 'D') {
                    is_f32 = false;
                    bump!();
                }
                let v: f64 = text.parse().map_err(|e| Error::Parse {
                    line: tline,
                    col: tcol,
                    msg: format!("bad float literal `{text}`: {e}"),
                })?;
                toks.push(Token { tok: Tok::Float(v, is_f32), line: tline, col: tcol });
            } else {
                let v: i64 = text.parse().map_err(|e| Error::Parse {
                    line: tline,
                    col: tcol,
                    msg: format!("bad int literal `{text}`: {e}"),
                })?;
                let mut unsigned = false;
                if i < chars.len() && (chars[i] == 'u' || chars[i] == 'U') {
                    unsigned = true;
                    bump!();
                }
                if i < chars.len() && (chars[i] == 'f' || chars[i] == 'F') {
                    // `4f` style float
                    bump!();
                    toks.push(Token { tok: Tok::Float(v as f64, true), line: tline, col: tcol });
                    continue;
                }
                toks.push(Token { tok: Tok::Int(v, unsigned), line: tline, col: tcol });
            }
            continue;
        }
        // Punctuation, maximal munch.
        let mut matched = None;
        for p in PUNCTS {
            if chars[i..].iter().take(p.len()).collect::<String>() == **p {
                matched = Some(*p);
                break;
            }
        }
        match matched {
            Some(p) => {
                for _ in 0..p.len() {
                    bump!();
                }
                toks.push(Token { tok: Tok::Punct(p), line: tline, col: tcol });
            }
            None => {
                return Err(Error::Parse {
                    line: tline,
                    col: tcol,
                    msg: format!("unexpected character `{c}`"),
                })
            }
        }
    }
    toks.push(Token { tok: Tok::Eof, line, col });
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn idents_and_ints() {
        assert_eq!(
            kinds("foo 42 4u"),
            vec![Tok::Ident("foo".into()), Tok::Int(42, false), Tok::Int(4, true), Tok::Eof]
        );
    }

    #[test]
    fn floats() {
        assert_eq!(
            kinds("1.5f 2.0 1e-3f 4f"),
            vec![
                Tok::Float(1.5, true),
                Tok::Float(2.0, true),
                Tok::Float(1e-3, true),
                Tok::Float(4.0, true),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn hex_literals() {
        assert_eq!(kinds("0xFF 0x10u"), vec![Tok::Int(255, false), Tok::Int(16, true), Tok::Eof]);
    }

    #[test]
    fn maximal_munch() {
        assert_eq!(
            kinds("a <<= b << c <= d"),
            vec![
                Tok::Ident("a".into()),
                Tok::Punct("<<="),
                Tok::Ident("b".into()),
                Tok::Punct("<<"),
                Tok::Ident("c".into()),
                Tok::Punct("<="),
                Tok::Ident("d".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn comments_stripped() {
        assert_eq!(
            kinds("a // line\nb /* block\nstill */ c"),
            vec![Tok::Ident("a".into()), Tok::Ident("b".into()), Tok::Ident("c".into()), Tok::Eof]
        );
    }

    #[test]
    fn defines_expand() {
        let toks = kinds("#define N 16\nint x = N;");
        assert!(toks.contains(&Tok::Int(16, false)));
    }

    #[test]
    fn define_chains() {
        let toks = kinds("#define A 4\n#define B A\nB");
        assert_eq!(toks[0], Tok::Int(4, false));
    }

    #[test]
    fn define_does_not_touch_substrings() {
        let toks = kinds("#define N 16\nint Nx = 3;");
        assert!(toks.contains(&Tok::Ident("Nx".into())));
    }

    #[test]
    fn line_numbers_survive_comments() {
        let toks = lex("/* a\nb */\nfoo").unwrap();
        assert_eq!(toks[0].line, 3);
    }

    #[test]
    fn rejects_function_macros() {
        assert!(lex("#define F(x) x\n").is_err());
    }
}
