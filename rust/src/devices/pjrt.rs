//! The `pjrt` device: the SPMD-device path of Fig. 3.
//!
//! Like pocl's GPU path, this device does **not** need the work-group
//! function generation: the kernel is executed by the device's own
//! compiler/runtime — here an AOT-compiled XLA module authored as a JAX +
//! Pallas program (`python/compile/`), loaded from `artifacts/*.hlo.txt`
//! and executed through the PJRT C API. Python never runs at launch time.
//!
//! Kernels are *registered*: a kernel name maps to an artifact path plus
//! a marshalling spec describing how the OpenCL-style buffer arguments
//! map onto the XLA executable's tensor parameters and results.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cl::error::{Error, Result};
use crate::exec::value::{SP_GLOBAL, Val};
use crate::exec::VVal;
use crate::runtime::{ArgData, ArgSpec, LoadedExecutable, PjrtRuntime};

use super::{Device, DeviceInfo, LaunchRequest, LaunchStats};

/// How one registered kernel marshals its arguments.
#[derive(Clone)]
pub struct KernelBinding {
    /// Artifact path (HLO text).
    pub artifact: String,
    /// For each executable input: which kernel arg index it reads, its
    /// shape, and element type.
    pub inputs: Vec<(usize, ArgSpec)>,
    /// For each executable output: which kernel arg (buffer) index it
    /// writes back to, and the f32 element count.
    pub outputs: Vec<(usize, usize)>,
}

/// SPMD offload device backed by the PJRT CPU client.
pub struct PjrtDevice {
    runtime: Arc<PjrtRuntime>,
    bindings: HashMap<String, KernelBinding>,
}

impl PjrtDevice {
    /// Create the device (one PJRT client).
    pub fn new() -> Result<PjrtDevice> {
        Ok(PjrtDevice { runtime: Arc::new(PjrtRuntime::cpu()?), bindings: HashMap::new() })
    }

    /// Register a kernel → artifact binding.
    pub fn register(&mut self, kernel: &str, binding: KernelBinding) {
        self.bindings.insert(kernel.to_string(), binding);
    }

    /// True if the kernel has an artifact binding.
    pub fn supports(&self, kernel: &str) -> bool {
        self.bindings.contains_key(kernel)
    }

    /// Pre-compile a kernel's artifact (amortised across launches).
    pub fn warm(&self, kernel: &str) -> Result<Arc<LoadedExecutable>> {
        let b = self
            .bindings
            .get(kernel)
            .ok_or_else(|| Error::NotFound(format!("no artifact for kernel `{kernel}`")))?;
        self.runtime.load(&b.artifact)
    }

    /// Execute a registered kernel against global memory.
    pub fn launch_binding(
        &self,
        global: &mut [u8],
        kernel: &str,
        args: &[VVal],
    ) -> Result<()> {
        let b = self
            .bindings
            .get(kernel)
            .ok_or_else(|| Error::NotFound(format!("no artifact for kernel `{kernel}`")))?;
        let exe = self.runtime.load(&b.artifact)?;
        // Marshal inputs out of global memory.
        let mut staged: Vec<(Vec<f32>, ArgSpec)> = Vec::new();
        let mut staged_i32: Vec<(Vec<i32>, ArgSpec)> = Vec::new();
        let mut order: Vec<(bool, usize)> = Vec::new(); // (is_f32, idx into staged vec)
        for (arg_idx, spec) in &b.inputs {
            match args.get(*arg_idx) {
                Some(VVal::S(Val::Ptr { space, offset })) if *space == SP_GLOBAL => {
                    let data =
                        crate::exec::mem::read_f32s(global, *offset as usize, spec.len());
                    order.push((true, staged.len()));
                    staged.push((data, spec.clone()));
                }
                Some(VVal::S(Val::I(v))) => {
                    order.push((false, staged_i32.len()));
                    staged_i32.push((vec![*v as i32], spec.clone()));
                }
                Some(VVal::S(Val::F(v))) => {
                    order.push((true, staged.len()));
                    staged.push((vec![*v as f32], spec.clone()));
                }
                other => {
                    return Err(Error::invalid(format!(
                        "pjrt kernel `{kernel}` arg {arg_idx}: unsupported value {other:?}"
                    )))
                }
            }
        }
        let call_args: Vec<(ArgData<'_>, &ArgSpec)> = order
            .iter()
            .map(|(is_f32, i)| {
                if *is_f32 {
                    let (d, s) = &staged[*i];
                    (ArgData::F32(d), s)
                } else {
                    let (d, s) = &staged_i32[*i];
                    (ArgData::I32(d), s)
                }
            })
            .collect();
        let outputs = exe.execute_f32(&call_args)?;
        // Write results back into the bound buffers.
        for ((arg_idx, len), out) in b.outputs.iter().zip(outputs.iter()) {
            match args.get(*arg_idx) {
                Some(VVal::S(Val::Ptr { space, offset })) if *space == SP_GLOBAL => {
                    if out.len() != *len {
                        return Err(Error::exec(format!(
                            "pjrt kernel `{kernel}`: output length {} != bound {len}",
                            out.len()
                        )));
                    }
                    crate::exec::mem::write_f32s(global, *offset as usize, out);
                }
                other => {
                    return Err(Error::invalid(format!(
                        "pjrt kernel `{kernel}` output arg {arg_idx}: not a global buffer \
                         ({other:?})"
                    )))
                }
            }
        }
        Ok(())
    }
}

impl Device for PjrtDevice {
    fn info(&self) -> DeviceInfo {
        DeviceInfo {
            name: format!("pjrt-{}", self.runtime.platform_name()),
            tlp: self.runtime.device_count(),
            ilp: "XLA-compiled (SPMD path)",
            dlp: "XLA vectorisation / Pallas kernels",
            global_mem: 256 << 20,
            local_mem: 0,
        }
    }

    fn compile_options(&self) -> crate::kcc::CompileOptions {
        crate::kcc::CompileOptions {
            spmd: true,
            target: crate::kcc::TargetKind::Spmd,
            ..Default::default()
        }
    }

    fn launch(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats> {
        self.launch_binding(global, &req.wgf.name, &req.args)?;
        Ok(LaunchStats { workgroups: req.all_groups().len(), ..Default::default() })
    }
}
