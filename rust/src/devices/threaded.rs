//! The `pthread` device analog (§3): executes work-groups in parallel on
//! a pool of OS threads — the thread-level-parallelism axis of Table 1.
//!
//! Work-groups are independent by the OpenCL execution model, so the pool
//! splits the group space statically. Each worker owns its local-memory
//! buffer ("local data is thread-local data ... allocated in the kernel
//! launcher thread", §4.7). Global memory is shared without locking —
//! racy kernels are UB per the OpenCL spec, exactly like on real devices.

use crate::cl::error::{Error, Result};
use crate::kcc::CompileOptions;

use super::{Device, DeviceInfo, EngineKind, LaunchRequest, LaunchStats};

/// Multi-threaded CPU device.
pub struct ThreadedDevice {
    /// Work-group execution engine per worker.
    pub engine: EngineKind,
    /// Worker count (cores/threads modelled).
    pub threads: usize,
    /// Global memory capacity.
    pub global_mem: usize,
    /// Local memory per work-group.
    pub local_mem: usize,
}

impl ThreadedDevice {
    /// Device with `threads` workers.
    pub fn new(engine: EngineKind, threads: usize) -> ThreadedDevice {
        ThreadedDevice { engine, threads: threads.max(1), global_mem: 256 << 20, local_mem: 64 << 10 }
    }
}

/// Shared mutable global memory handed to workers. Work-groups are
/// independent; simultaneous writes to the same location are UB in the
/// source program, mirroring real OpenCL devices.
struct SharedMem(*mut u8, usize);
unsafe impl Send for SharedMem {}
unsafe impl Sync for SharedMem {}

impl Device for ThreadedDevice {
    fn info(&self) -> DeviceInfo {
        DeviceInfo {
            name: format!("pthread-{:?}-x{}", self.engine, self.threads).to_lowercase(),
            tlp: self.threads,
            ilp: "interpreted",
            dlp: match self.engine {
                EngineKind::Gang(w) => {
                    if w == 8 {
                        "gang x8 (AVX2 model)"
                    } else {
                        "gang x4 (NEON/AltiVec model)"
                    }
                }
                EngineKind::GangVector(8) => "gang-vector x8 (AVX2 SoA)",
                EngineKind::GangVector(4) => "gang-vector x4 (NEON/AltiVec SoA)",
                EngineKind::GangVector(_) => "gang-vector (SoA)",
                EngineKind::Bytecode(8) => "bytecode x8 (fused SoA dispatch)",
                EngineKind::Bytecode(4) => "bytecode x4 (fused SoA dispatch)",
                EngineKind::Bytecode(_) => "bytecode (fused SoA dispatch)",
                EngineKind::Jit(8) => "jit x8 (x86-64 templates)",
                EngineKind::Jit(4) => "jit x4 (x86-64 templates)",
                EngineKind::Jit(_) => "jit (x86-64 templates)",
                EngineKind::Serial => "scalar WI loops",
                EngineKind::Fiber => "fibers (no DLP)",
            },
            global_mem: self.global_mem,
            local_mem: self.local_mem,
        }
    }

    fn compile_options(&self) -> CompileOptions {
        super::cpu_compile_options(self.engine)
    }

    fn launch(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats> {
        let groups = req.all_groups();
        let nthreads = self.threads.min(groups.len()).max(1);
        if nthreads == 1 {
            // Degenerate to basic behaviour without thread spawn cost.
            let basic = super::basic::BasicDevice {
                engine: self.engine,
                global_mem: self.global_mem,
                local_mem: self.local_mem,
                opt_level: None,
            };
            return basic.launch(global, req);
        }
        let _launch_span = crate::trace::enabled().then(|| {
            crate::trace::span_args(
                crate::trace::CAT_EXEC,
                format!("launch {}", req.wgf.name),
                vec![
                    ("engine", crate::trace::ArgVal::s(format!("{:?}", self.engine))),
                    ("groups", crate::trace::ArgVal::u(groups.len() as u64)),
                    ("threads", crate::trace::ArgVal::u(nthreads as u64)),
                ],
            )
        });
        // The degenerate nthreads==1 path above delegates to a
        // BasicDevice, which counts these metrics itself.
        crate::trace::metrics::add("exec.launches", 1);
        crate::trace::metrics::add("exec.workgroups", groups.len() as u64);
        let shared = SharedMem(global.as_mut_ptr(), global.len());
        let engine = self.engine;
        let results: Vec<Result<LaunchStats>> = std::thread::scope(|scope| {
            let shared = &shared;
            let mut handles = Vec::new();
            for t in 0..nthreads {
                let chunk: Vec<[usize; 3]> =
                    groups.iter().copied().skip(t).step_by(nthreads).collect();
                let req_ref = &*req;
                handles.push(scope.spawn(move || {
                    // Launcher-thread-local local memory (§4.7).
                    let mut local = vec![0u8; req_ref.local_mem.max(1)];
                    let mut stats = LaunchStats::default();
                    for g in chunk {
                        let ctx = req_ref.ctx(g);
                        // Each worker gets the same full view of global
                        // memory; the work-group independence rule makes
                        // this safe for conforming kernels.
                        let global_view =
                            unsafe { std::slice::from_raw_parts_mut(shared.0, shared.1) };
                        let gs = super::run_one_group(
                            engine,
                            &req_ref.wgf,
                            &req_ref.args,
                            global_view,
                            &mut local,
                            &ctx,
                        )?;
                        stats.merge_gang(&gs);
                        stats.workgroups += 1;
                    }
                    Ok(stats)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        let mut total = LaunchStats::default();
        for r in results {
            let s = r.map_err(|e| Error::exec(format!("worker failed: {e}")))?;
            total.accumulate(&s);
        }
        Ok(total)
    }
}
