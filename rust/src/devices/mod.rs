//! The device layer (§3, Fig. 2): the hardware abstraction the host layer
//! delegates to.
//!
//! * [`basic`] — single-threaded CPU device, one work-group at a time.
//! * [`threaded`] — the `pthread` analog: a worker pool executes
//!   work-groups in parallel (thread-level parallelism).
//! * [`ttasim`] — static multi-issue TTA simulator (the `ttasim`/TCE
//!   analog), cycle-accurate at the block-schedule level (§6.4).
//! * [`pjrt`] — SPMD-style offload device executing AOT-compiled
//!   Pallas/XLA artifacts through the PJRT C API.

pub mod basic;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod threaded;
pub mod ttasim;

use std::sync::Arc;

use crate::cl::error::Result;
use crate::exec::{LaunchCtx, VVal};
use crate::kcc::{CompileOptions, TargetKind, WorkGroupFunction};

/// Which work-group execution engine a CPU-style device uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Serial WI-loop execution (paper `basic`).
    Serial,
    /// Per-lane lockstep gangs of the given SIMD width (8 ≈ AVX2, 4 ≈
    /// NEON/AltiVec): one interpreter dispatch per instruction per lane.
    Gang(usize),
    /// Lane-batched (structure-of-arrays) gangs of the given width: one
    /// dispatch per instruction per *gang*, uniform values computed once
    /// (`exec::vecgang`). Use [`native_gang_width`] for a host-tuned width.
    GangVector(usize),
    /// Threaded-bytecode tier over lane-batched gangs of the given width:
    /// covered regions run pre-resolved, fused bytecode (`exec::bytecode`),
    /// the rest fall back to the `GangVector` region interpreter.
    Bytecode(usize),
    /// Template-JIT tier over lane-batched gangs of the given width:
    /// covered regions run hand-encoded x86-64 machine code
    /// (`exec::jit`), the rest fall back per region to the bytecode
    /// tier; non-x86-64 hosts degrade wholesale to `Bytecode`.
    Jit(usize),
    /// Per-work-item fibers (FreeOCL / Twin Peaks baseline).
    Fiber,
}

/// Host-appropriate default gang width: AVX2-class x86-64 hosts get 8
/// lanes, everything else 4 (Table 1's DLP column). The
/// `POCLRS_GANG_WIDTH` environment variable overrides the detection (the
/// vector engine is specialised for widths 2/4/8/16; other values degrade
/// to the per-lane gang engine).
pub fn native_gang_width() -> usize {
    if let Some(w) = gang_width_override(std::env::var("POCLRS_GANG_WIDTH").ok().as_deref()) {
        return w;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return 8;
        }
    }
    4
}

/// Parse a `POCLRS_GANG_WIDTH` override. Invalid values (unparsable, or
/// `0`) are rejected with a one-time stderr warning (`crate::envcfg`)
/// instead of being silently ignored, so a typo'd override is
/// diagnosable.
fn gang_width_override(raw: Option<&str>) -> Option<usize> {
    crate::envcfg::parse_or_warn(
        "POCLRS_GANG_WIDTH",
        raw,
        "a positive integer",
        "autodetecting",
        |s| s.parse::<usize>().ok().filter(|w| *w > 0),
    )
}

/// Compile options for a CPU device running `engine`: the CPU target
/// class plus the engine's gang width. Both are cache-key components
/// (see `cache::key`), so a width-8 gang device and a serial device
/// keep separate persistent-cache entries even though today's engines
/// consume the same compiled forms.
pub fn cpu_compile_options(engine: EngineKind) -> CompileOptions {
    let gang_width = match engine {
        EngineKind::Gang(w)
        | EngineKind::GangVector(w)
        | EngineKind::Bytecode(w)
        | EngineKind::Jit(w) => w,
        EngineKind::Serial | EngineKind::Fiber => 0,
    };
    CompileOptions { target: TargetKind::Cpu, gang_width, ..Default::default() }
}

/// Table 1-style device description.
#[derive(Debug, Clone)]
pub struct DeviceInfo {
    /// Device name (e.g. `"pthread-avx2"`).
    pub name: String,
    /// Thread-level parallelism: worker threads over work-groups.
    pub tlp: usize,
    /// Instruction-level parallelism description.
    pub ilp: &'static str,
    /// Data-level parallelism description (SIMD width modelled).
    pub dlp: &'static str,
    /// Global memory capacity in bytes.
    pub global_mem: usize,
    /// Local memory per work-group in bytes.
    pub local_mem: usize,
}

/// A kernel launch prepared by the host layer: the specialised work-group
/// function, resolved argument values, and the launch geometry.
///
/// Owns its work-group function (shared with the program's §4.1 cache),
/// so launches are `Send` and can be deferred into a queue's scheduler.
pub struct LaunchRequest {
    /// Enqueue-time-specialised work-group function.
    pub wgf: Arc<WorkGroupFunction>,
    /// Argument values (buffers already resolved to global offsets,
    /// local pointers to local offsets).
    pub args: Vec<VVal>,
    /// Number of work-groups per dimension *this request executes* — the
    /// whole grid for a plain launch, a chunk of it for a scheduler
    /// sub-launch (see [`LaunchRequest::sub_range`]).
    pub groups: [usize; 3],
    /// Absolute id of the first group this request executes. `[0; 3]`
    /// for plain launches; scheduler sub-launches shift it so kernels
    /// observe their true `get_group_id`.
    pub group_offset: [usize; 3],
    /// Work-group grid of the *full* launch, reported to kernels via
    /// `get_num_groups`/`get_global_size`. Equals `groups` for plain
    /// launches; stays the full grid for sub-launches.
    pub grid: [usize; 3],
    /// Global offset.
    pub offset: [u64; 3],
    /// Work dimensions used by the launch.
    pub work_dim: u32,
    /// Bytes of local memory the launch needs per work-group.
    pub local_mem: usize,
}

impl LaunchRequest {
    /// A plain (whole-grid) launch: executes every group of `groups`
    /// with no group offset.
    pub fn new(
        wgf: Arc<WorkGroupFunction>,
        args: Vec<VVal>,
        groups: [usize; 3],
        offset: [u64; 3],
        work_dim: u32,
        local_mem: usize,
    ) -> LaunchRequest {
        LaunchRequest {
            wgf,
            args,
            groups,
            group_offset: [0; 3],
            grid: groups,
            offset,
            work_dim,
            local_mem,
        }
    }

    /// A sub-launch executing `count` slices of this request's range
    /// starting `start` slices in along dimension `dim`, running `wgf`
    /// (each scheduler member supplies its own compiled artifact).
    /// Kernels inside the sub-launch still observe the full grid and
    /// their absolute group ids.
    pub fn sub_range(
        &self,
        dim: usize,
        start: usize,
        count: usize,
        wgf: Arc<WorkGroupFunction>,
    ) -> LaunchRequest {
        debug_assert!(start + count <= self.groups[dim]);
        let mut groups = self.groups;
        groups[dim] = count;
        let mut group_offset = self.group_offset;
        group_offset[dim] += start;
        LaunchRequest {
            wgf,
            args: self.args.clone(),
            groups,
            group_offset,
            grid: self.grid,
            offset: self.offset,
            work_dim: self.work_dim,
            local_mem: self.local_mem,
        }
    }

    /// Launch context for one work-group (absolute group id).
    pub fn ctx(&self, g: [usize; 3]) -> LaunchCtx {
        LaunchCtx {
            group_id: [g[0] as u64, g[1] as u64, g[2] as u64],
            num_groups: [self.grid[0] as u64, self.grid[1] as u64, self.grid[2] as u64],
            global_offset: self.offset,
            local_size: self.wgf.local_size,
            work_dim: self.work_dim,
        }
    }

    /// Absolute ids of every group this request executes, row-major.
    pub fn all_groups(&self) -> Vec<[usize; 3]> {
        let mut v = Vec::with_capacity(self.groups.iter().product());
        for gz in 0..self.groups[2] {
            for gy in 0..self.groups[1] {
                for gx in 0..self.groups[0] {
                    v.push([
                        self.group_offset[0] + gx,
                        self.group_offset[1] + gy,
                        self.group_offset[2] + gz,
                    ]);
                }
            }
        }
        v
    }
}

/// Per-launch statistics reported by devices.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaunchStats {
    /// Work-groups executed.
    pub workgroups: usize,
    /// Gangs executed (gang engines only; chunks × regions).
    pub gangs: usize,
    /// Gangs that diverged (gang engines only).
    pub diverged_gangs: usize,
    /// Lane-batched instruction dispatches (vector gang engine).
    pub vector_insts: usize,
    /// Uniform (once-per-gang scalar) instruction dispatches (vector gang
    /// engine).
    pub uniform_insts: usize,
    /// Per-lane instruction dispatches (scalar gang lockstep and both
    /// engines' divergence/tail fallback paths).
    pub lane_insts: usize,
    /// Bytecode dispatches (threaded-bytecode engine; superinstructions
    /// count once).
    pub bytecode_insts: usize,
    /// Gang-regions executed through the bytecode tier.
    pub bytecode_gangs: usize,
    /// Gang-regions with no lowered bytecode that fell back to the vector
    /// region interpreter.
    pub bytecode_fallbacks: usize,
    /// Bytecode (super)instructions retired by jitted machine code (jit
    /// engine; excluded from [`LaunchStats::dispatches`]).
    pub jit_insts: usize,
    /// Gang-regions executed through jitted machine code.
    pub jit_gangs: usize,
    /// Gang-regions the jit engine ran on a lower tier instead.
    pub jit_fallbacks: usize,
    /// Simulated cycles (ttasim only).
    pub cycles: u64,
}

impl LaunchStats {
    /// Fold one work-group's gang-engine statistics into the launch total.
    pub fn merge_gang(&mut self, g: &crate::exec::gang::GangStats) {
        self.gangs += g.gangs;
        self.diverged_gangs += g.diverged;
        self.vector_insts += g.vector_insts;
        self.uniform_insts += g.uniform_insts;
        self.lane_insts += g.lane_insts;
        self.bytecode_insts += g.bytecode_insts;
        self.bytecode_gangs += g.bytecode_gangs;
        self.bytecode_fallbacks += g.bytecode_fallbacks;
        self.jit_insts += g.jit_insts;
        self.jit_gangs += g.jit_gangs;
        self.jit_fallbacks += g.jit_fallbacks;
    }

    /// Fold another launch's statistics into this one (worker pools,
    /// multi-pass runs).
    ///
    /// Counters here are engine-typed (a serial member contributes no
    /// gang counters, a jit member retires through `jit_insts`), so a
    /// cross-engine sum is only meaningful as a *grand total*. When
    /// launches from different engine kinds are folded together — a
    /// heterogeneous `sched::DeviceGroup` launch — the per-device,
    /// per-engine breakdown is preserved separately in
    /// `sched::SchedStats`; this accumulated blob is just the total row.
    pub fn accumulate(&mut self, other: &LaunchStats) {
        self.workgroups += other.workgroups;
        self.gangs += other.gangs;
        self.diverged_gangs += other.diverged_gangs;
        self.vector_insts += other.vector_insts;
        self.uniform_insts += other.uniform_insts;
        self.lane_insts += other.lane_insts;
        self.bytecode_insts += other.bytecode_insts;
        self.bytecode_gangs += other.bytecode_gangs;
        self.bytecode_fallbacks += other.bytecode_fallbacks;
        self.jit_insts += other.jit_insts;
        self.jit_gangs += other.jit_gangs;
        self.jit_fallbacks += other.jit_fallbacks;
        self.cycles += other.cycles;
    }

    /// Total interpreter dispatches across the launch — the metric the
    /// lane-batched engine shrinks by ~width× on uniform kernels and the
    /// bytecode tier shrinks further via superinstruction fusion.
    pub fn dispatches(&self) -> usize {
        self.vector_insts + self.uniform_insts + self.lane_insts + self.bytecode_insts
    }
}

/// The host-device interface: every device executes prepared launches
/// against the context's global memory.
pub trait Device: Send + Sync {
    /// Device description (Table 1 row).
    fn info(&self) -> DeviceInfo;
    /// Kernel-compiler options this device wants (e.g. SPMD devices skip
    /// WI-loop materialisation).
    fn compile_options(&self) -> CompileOptions {
        CompileOptions::default()
    }
    /// Execute a launch. Devices may be called concurrently from a
    /// queue's worker pool; implementations must be reentrant.
    fn launch(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats>;
    /// Downcast to a heterogeneous device group, if this device is one.
    /// The host layer uses this to route NDRange launches through the
    /// multi-device scheduler (`sched::DeviceGroup`) instead of a single
    /// engine.
    fn as_group(&self) -> Option<&crate::sched::DeviceGroup> {
        None
    }
}

/// Run one work-group with the chosen engine (shared by basic/threaded),
/// returning the engine's execution statistics (zeroed for engines that
/// do not gang).
pub fn run_one_group(
    engine: EngineKind,
    wgf: &WorkGroupFunction,
    args: &[VVal],
    global: &mut [u8],
    local: &mut [u8],
    ctx: &LaunchCtx,
) -> Result<crate::exec::gang::GangStats> {
    // Per-work-group execution span. Guarded so the disabled path does
    // no formatting; per-group granularity is the finest the tracer
    // records, so large grids produce large traces — see docs/tracing.md.
    let _wg_span = crate::trace::enabled().then(|| {
        crate::trace::span_args(
            crate::trace::CAT_EXEC,
            format!("wg {}", wgf.name),
            vec![
                ("gx", crate::trace::ArgVal::u(ctx.group_id[0])),
                ("gy", crate::trace::ArgVal::u(ctx.group_id[1])),
                ("gz", crate::trace::ArgVal::u(ctx.group_id[2])),
            ],
        )
    });
    let mut mem = crate::exec::MemoryRefs { global, local };
    match engine {
        EngineKind::Serial => {
            crate::exec::serial::run_workgroup(wgf, args, &mut mem, ctx)?;
            Ok(Default::default())
        }
        EngineKind::Gang(w) => crate::exec::gang::run_workgroup(wgf, args, &mut mem, ctx, w),
        EngineKind::GangVector(w) => {
            crate::exec::vecgang::run_workgroup(wgf, args, &mut mem, ctx, w)
        }
        EngineKind::Bytecode(w) => {
            crate::exec::bytecode::run_workgroup(wgf, args, &mut mem, ctx, w)
        }
        EngineKind::Jit(w) => crate::exec::jit::run_workgroup(wgf, args, &mut mem, ctx, w),
        EngineKind::Fiber => {
            crate::exec::fiber::run_workgroup(wgf, args, &mut mem, ctx)?;
            Ok(Default::default())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::gang_width_override;

    #[test]
    fn gang_width_override_accepts_positive_integers() {
        assert_eq!(gang_width_override(Some("8")), Some(8));
        assert_eq!(gang_width_override(Some("4")), Some(4));
        assert_eq!(gang_width_override(Some("16")), Some(16));
    }

    #[test]
    fn gang_width_override_rejects_invalid_values() {
        // Unparsable and zero overrides fall through to autodetection
        // (with a one-time warning) instead of panicking or silently
        // producing width 0.
        assert_eq!(gang_width_override(Some("banana")), None);
        assert_eq!(gang_width_override(Some("0")), None);
        assert_eq!(gang_width_override(Some("")), None);
        assert_eq!(gang_width_override(Some("-4")), None);
        assert_eq!(gang_width_override(None), None);
    }
}
