//! The device layer (§3, Fig. 2): the hardware abstraction the host layer
//! delegates to.
//!
//! * [`basic`] — single-threaded CPU device, one work-group at a time.
//! * [`threaded`] — the `pthread` analog: a worker pool executes
//!   work-groups in parallel (thread-level parallelism).
//! * [`ttasim`] — static multi-issue TTA simulator (the `ttasim`/TCE
//!   analog), cycle-accurate at the block-schedule level (§6.4).
//! * [`pjrt`] — SPMD-style offload device executing AOT-compiled
//!   Pallas/XLA artifacts through the PJRT C API.

pub mod basic;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod threaded;
pub mod ttasim;

use std::sync::Arc;

use crate::cl::error::Result;
use crate::exec::{LaunchCtx, VVal};
use crate::kcc::{CompileOptions, WorkGroupFunction};

/// Which work-group execution engine a CPU-style device uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Serial WI-loop execution (paper `basic`).
    Serial,
    /// Lockstep gangs of the given SIMD width (8 ≈ AVX2, 4 ≈ NEON/AltiVec).
    Gang(usize),
    /// Per-work-item fibers (FreeOCL / Twin Peaks baseline).
    Fiber,
}

/// Table 1-style device description.
#[derive(Debug, Clone)]
pub struct DeviceInfo {
    /// Device name (e.g. `"pthread-avx2"`).
    pub name: String,
    /// Thread-level parallelism: worker threads over work-groups.
    pub tlp: usize,
    /// Instruction-level parallelism description.
    pub ilp: &'static str,
    /// Data-level parallelism description (SIMD width modelled).
    pub dlp: &'static str,
    /// Global memory capacity in bytes.
    pub global_mem: usize,
    /// Local memory per work-group in bytes.
    pub local_mem: usize,
}

/// A kernel launch prepared by the host layer: the specialised work-group
/// function, resolved argument values, and the launch geometry.
///
/// Owns its work-group function (shared with the program's §4.1 cache),
/// so launches are `Send` and can be deferred into a queue's scheduler.
pub struct LaunchRequest {
    /// Enqueue-time-specialised work-group function.
    pub wgf: Arc<WorkGroupFunction>,
    /// Argument values (buffers already resolved to global offsets,
    /// local pointers to local offsets).
    pub args: Vec<VVal>,
    /// Number of work-groups per dimension.
    pub groups: [usize; 3],
    /// Global offset.
    pub offset: [u64; 3],
    /// Work dimensions used by the launch.
    pub work_dim: u32,
    /// Bytes of local memory the launch needs per work-group.
    pub local_mem: usize,
}

impl LaunchRequest {
    /// Launch context for one work-group.
    pub fn ctx(&self, g: [usize; 3]) -> LaunchCtx {
        LaunchCtx {
            group_id: [g[0] as u64, g[1] as u64, g[2] as u64],
            num_groups: [self.groups[0] as u64, self.groups[1] as u64, self.groups[2] as u64],
            global_offset: self.offset,
            local_size: self.wgf.local_size,
            work_dim: self.work_dim,
        }
    }

    /// All group ids in row-major order.
    pub fn all_groups(&self) -> Vec<[usize; 3]> {
        let mut v = Vec::with_capacity(self.groups.iter().product());
        for gz in 0..self.groups[2] {
            for gy in 0..self.groups[1] {
                for gx in 0..self.groups[0] {
                    v.push([gx, gy, gz]);
                }
            }
        }
        v
    }
}

/// Per-launch statistics reported by devices.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaunchStats {
    /// Work-groups executed.
    pub workgroups: usize,
    /// Gangs that diverged (gang engine only).
    pub diverged_gangs: usize,
    /// Simulated cycles (ttasim only).
    pub cycles: u64,
}

/// The host-device interface: every device executes prepared launches
/// against the context's global memory.
pub trait Device: Send + Sync {
    /// Device description (Table 1 row).
    fn info(&self) -> DeviceInfo;
    /// Kernel-compiler options this device wants (e.g. SPMD devices skip
    /// WI-loop materialisation).
    fn compile_options(&self) -> CompileOptions {
        CompileOptions::default()
    }
    /// Execute a launch. Devices may be called concurrently from a
    /// queue's worker pool; implementations must be reentrant.
    fn launch(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats>;
}

/// Run one work-group with the chosen engine (shared by basic/threaded).
pub fn run_one_group(
    engine: EngineKind,
    wgf: &WorkGroupFunction,
    args: &[VVal],
    global: &mut [u8],
    local: &mut [u8],
    ctx: &LaunchCtx,
) -> Result<usize> {
    let mut mem = crate::exec::MemoryRefs { global, local };
    match engine {
        EngineKind::Serial => {
            crate::exec::serial::run_workgroup(wgf, args, &mut mem, ctx)?;
            Ok(0)
        }
        EngineKind::Gang(w) => {
            let stats = crate::exec::gang::run_workgroup(wgf, args, &mut mem, ctx, w)?;
            Ok(stats.diverged)
        }
        EngineKind::Fiber => {
            crate::exec::fiber::run_workgroup(wgf, args, &mut mem, ctx)?;
            Ok(0)
        }
    }
}
