//! `ttasim` — static multi-issue TTA simulator (§6.4, Table 2).
//!
//! Models a Transport-Triggered Architecture datapath with the Table 2
//! resource mix and measures how much instruction-level parallelism the
//! kernel compiler's output exposes. Each basic block of the materialised
//! work-group function is **list-scheduled** once onto the function units
//! (greedy earliest-cycle, honouring register dataflow and conservative
//! memory ordering); execution then interprets the function while
//! charging each block's schedule length per execution.
//!
//! Blocks inside **parallel work-item loops** (the `wi_loops` metadata the
//! kernel compiler emits — §4.1) may be scheduled with their iterations
//! overlapped (unroll factor `ilp_window`), because the metadata
//! guarantees independence; that is precisely the §6.4 experiment: with
//! horizontal inner-loop parallelisation the DCT inner loop becomes a
//! work-item loop and the scheduler can fill the FUs, without it the loop
//! stays sequential inside one work-item.

use std::collections::HashMap;

use crate::cl::error::{Error, Result};
use crate::exec::interp::{Flow, Machine, SlotStore};
use crate::exec::{MemoryRefs, VVal};
use crate::ir::cfg::create_subgraph;
use crate::ir::func::Function;
use crate::ir::inst::{BinOp, BlockId, Inst, Operand, Reg};

use super::{Device, DeviceInfo, LaunchRequest, LaunchStats};

/// Function-unit classes of the modelled datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fu {
    /// Integer ALUs (also address computation, compares, moves).
    Alu,
    /// Float add/sub units.
    Fadd,
    /// Float multiplier units (also div and the elemental functions).
    Fmul,
    /// Load-store units (global and local).
    Lsu,
}

/// Datapath resources (Table 2) and operation latencies.
#[derive(Debug, Clone)]
pub struct TtaConfig {
    /// Units per FU class.
    pub units: HashMap<Fu, usize>,
    /// Iteration-overlap window for parallel WI loops.
    pub ilp_window: usize,
    /// Simulated clock in MHz (the paper reports "scaled to 100 MHz").
    pub clock_mhz: u64,
}

impl Default for TtaConfig {
    fn default() -> Self {
        // Table 2: 4 integer ALUs, 4 float add+sub, 4 float mul, 9 LSUs.
        let mut units = HashMap::new();
        units.insert(Fu::Alu, 4);
        units.insert(Fu::Fadd, 4);
        units.insert(Fu::Fmul, 4);
        units.insert(Fu::Lsu, 9);
        TtaConfig { units, ilp_window: 16, clock_mhz: 100 }
    }
}

/// FU class + latency for one instruction.
fn classify(inst: &Inst) -> Option<(Fu, u64)> {
    match inst {
        Inst::Bin { op, ty, .. } => {
            if ty.is_float() {
                match op {
                    BinOp::Add | BinOp::Sub => Some((Fu::Fadd, 3)),
                    BinOp::Mul => Some((Fu::Fmul, 3)),
                    BinOp::Div | BinOp::Rem => Some((Fu::Fmul, 12)),
                    _ => Some((Fu::Alu, 1)),
                }
            } else {
                match op {
                    BinOp::Mul => Some((Fu::Alu, 2)),
                    BinOp::Div | BinOp::Rem => Some((Fu::Alu, 8)),
                    _ => Some((Fu::Alu, 1)),
                }
            }
        }
        Inst::Un { .. } | Inst::Cast { .. } | Inst::Select { .. } | Inst::Gep { .. } => {
            Some((Fu::Alu, 1))
        }
        Inst::Load { .. } | Inst::Store { .. } => Some((Fu::Lsu, 3)),
        Inst::Math { .. } => Some((Fu::Fmul, 10)),
        Inst::VecBuild { .. } | Inst::VecExtract { .. } | Inst::VecInsert { .. }
        | Inst::Splat { .. } => Some((Fu::Alu, 1)),
        Inst::Wi { .. } => Some((Fu::Alu, 1)),
        Inst::Barrier { .. } | Inst::Marker { .. } => None,
    }
}

/// Greedy list schedule of `copies` independent copies of a block's
/// instruction list onto the FU mix; returns the makespan in cycles.
fn schedule_block(cfg: &TtaConfig, insts: &[(Option<Reg>, Inst)], copies: usize) -> u64 {
    // Dependence edges within one copy: register def→use and conservative
    // memory/control order (stores order against loads and stores).
    let n = insts.len();
    let mut ready_dep: Vec<Vec<usize>> = vec![Vec::new(); n]; // preds
    let mut last_mem: Option<usize> = None;
    let mut def_site: HashMap<u32, usize> = HashMap::new();
    for (i, (def, inst)) in insts.iter().enumerate() {
        for op in inst.operands() {
            if let Operand::Reg(r) = op {
                if let Some(&d) = def_site.get(&r.0) {
                    ready_dep[i].push(d);
                }
            }
        }
        match inst {
            Inst::Store { .. } => {
                if let Some(m) = last_mem {
                    ready_dep[i].push(m);
                }
                last_mem = Some(i);
            }
            Inst::Load { .. } => {
                if let Some(m) = last_mem {
                    // Loads depend on the last store only (store→load).
                    if matches!(insts[m].1, Inst::Store { .. }) {
                        ready_dep[i].push(m);
                    }
                }
            }
            _ => {}
        }
        if let Some(r) = def {
            def_site.insert(r.0, i);
        }
    }
    // Cycle-by-cycle issue. Copies are fully independent (parallel WI
    // iterations), so the scheduler interleaves them freely.
    let total = n * copies;
    let mut finish: Vec<u64> = vec![0; total];
    let mut issued: Vec<bool> = vec![false; total];
    let mut done = 0usize;
    let mut cycle: u64 = 0;
    let mut makespan = 0u64;
    while done < total {
        let mut used: HashMap<Fu, usize> = HashMap::new();
        for c in 0..copies {
            for i in 0..n {
                let id = c * n + i;
                if issued[id] {
                    continue;
                }
                let Some((fu, lat)) = classify(&insts[i].1) else {
                    issued[id] = true;
                    finish[id] = cycle;
                    done += 1;
                    continue;
                };
                // Dependencies satisfied?
                let ok = ready_dep[i]
                    .iter()
                    .all(|&d| issued[c * n + d] && finish[c * n + d] <= cycle);
                if !ok {
                    continue;
                }
                let avail = cfg.units.get(&fu).copied().unwrap_or(1);
                let u = used.entry(fu).or_insert(0);
                if *u >= avail {
                    continue;
                }
                *u += 1;
                issued[id] = true;
                finish[id] = cycle + lat;
                makespan = makespan.max(cycle + lat);
                done += 1;
            }
        }
        cycle += 1;
        if cycle > 10_000_000 {
            break; // safety
        }
    }
    makespan.max(1)
}

/// Cycle model for one work-group function: per-block cycles, with
/// parallel-WI-loop bodies scheduled `ilp_window`-wide.
pub struct BlockSchedule {
    /// Cycles charged per execution of each block.
    pub cycles: Vec<u64>,
    /// Blocks that were scheduled with iteration overlap.
    pub overlapped: Vec<bool>,
}

/// Build the schedule for `f` using its `wi_loops` metadata.
pub fn schedule_function(cfg: &TtaConfig, f: &Function) -> BlockSchedule {
    // Blocks inside parallel WI loops: between header and latch — but only
    // when the loop body is free of *nested* loops. A static multi-issue
    // scheduler overlaps iterations by unrolling straight-line(ish)
    // traces; it cannot software-pipeline across a nested sequential
    // loop's back edge. This is precisely the §6.4 point: without
    // horizontal parallelisation the DCT inner loop sits inside the WI
    // loop body and blocks all overlap; with it, each region body is
    // branch-light and the FUs fill.
    let loops = crate::ir::loops::find_loops(f);
    // Per-block unroll window (0 = sequential); WI loop control blocks of
    // unrollable loops cost nothing (fully unrolled away — the trip count
    // is an enqueue-time constant, §4.1).
    let mut window = vec![0usize; f.blocks.len()];
    let mut control = vec![false; f.blocks.len()];
    for m in &f.wi_loops {
        if !m.parallel {
            continue;
        }
        let body = create_subgraph(f, m.header, m.latch);
        let has_nested_loop = loops
            .iter()
            .any(|l| l.header != m.header && body.binary_search(&l.header).is_ok());
        if has_nested_loop {
            continue;
        }
        let w = m.trip_count.unwrap_or(cfg.ilp_window).min(cfg.ilp_window.max(16));
        for b in body {
            window[b.0 as usize] = w.max(window[b.0 as usize]);
        }
        // Constant-trip-count WI loops are fully unrolled: the header
        // compare/branch and latch increment vanish.
        control[m.header.0 as usize] = true;
        control[m.latch.0 as usize] = true;
        window[m.header.0 as usize] = 0;
        window[m.latch.0 as usize] = 0;
    }
    let mut cycles = Vec::with_capacity(f.blocks.len());
    let mut overlapped = Vec::with_capacity(f.blocks.len());
    for (i, block) in f.blocks.iter().enumerate() {
        if control[i] || block.name.starts_with("wi.init") {
            // Unrolled-away loop bookkeeping (incl. induction init).
            cycles.push(0);
            overlapped.push(false);
        } else if block.insts.is_empty() {
            // Empty glue blocks: branch folding makes them free-ish.
            cycles.push(1);
            overlapped.push(false);
        } else if window[i] > 1 {
            // The §4.1 payoff: the metadata lets the scheduler overlap
            // iterations without re-proving independence. Charge the
            // per-iteration amortised cost.
            let w = window[i] as u64;
            let span = schedule_block(cfg, &block.insts, window[i]);
            cycles.push(span.div_ceil(w).max(1));
            overlapped.push(true);
        } else {
            cycles.push(schedule_block(cfg, &block.insts, 1) + 1); // +1 branch
            overlapped.push(false);
        }
    }
    BlockSchedule { cycles, overlapped }
}

/// The simulated TTA accelerator device.
pub struct TtaSimDevice {
    /// Datapath configuration.
    pub config: TtaConfig,
    /// Kernel-compiler options (the §6.4 toggle lives here).
    pub opts: crate::kcc::CompileOptions,
}

impl TtaSimDevice {
    /// Default Table 2 datapath.
    pub fn new(horizontal: bool) -> TtaSimDevice {
        TtaSimDevice {
            config: TtaConfig::default(),
            opts: crate::kcc::CompileOptions {
                horizontal,
                target: crate::kcc::TargetKind::Tta,
                ..Default::default()
            },
        }
    }

    /// Execute + count cycles for one launch (all work-groups).
    pub fn simulate(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats> {
        let f = &req.wgf.loop_fn;
        let sched = schedule_function(&self.config, f);
        let mut stats = LaunchStats::default();
        let mut local = vec![0u8; req.local_mem.max(1)];
        for g in req.all_groups() {
            let ctx = req.ctx(g);
            let mut full_args = req.args.clone();
            for d in 0..3 {
                full_args.push(VVal::i(ctx.group_id[d] as i64));
            }
            for d in 0..3 {
                full_args.push(VVal::i(ctx.num_groups[d] as i64));
            }
            for d in 0..3 {
                full_args.push(VVal::i(ctx.global_offset[d] as i64));
            }
            let mut slots = SlotStore::for_function(f);
            let mut mem = MemoryRefs { global, local: &mut local };
            let mut m = Machine::new(f, &full_args, &mut slots, &mut mem, &ctx);
            // Interpret while charging the block schedule.
            let mut cur = f.entry;
            loop {
                stats.cycles += sched.cycles[cur.0 as usize];
                match m.exec_block(f, cur, false)? {
                    Flow::Goto(b) => cur = b,
                    Flow::Done => break,
                    Flow::AtBarrier(_) => {
                        return Err(Error::exec("barrier in materialised function"))
                    }
                }
            }
            stats.workgroups += 1;
        }
        Ok(stats)
    }

    /// Convert cycles to milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.config.clock_mhz as f64 * 1e3)
    }
}

impl Device for TtaSimDevice {
    fn info(&self) -> DeviceInfo {
        DeviceInfo {
            name: format!(
                "ttasim-{}",
                if self.opts.horizontal { "horizontal" } else { "baseline" }
            ),
            tlp: 1,
            ilp: "static multi-issue (4 ALU, 4 FADD, 4 FMUL, 9 LSU)",
            dlp: "n/a (Table 1)",
            global_mem: 64 << 20,
            local_mem: 64 << 10,
        }
    }

    fn compile_options(&self) -> crate::kcc::CompileOptions {
        self.opts.clone()
    }

    fn launch(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats> {
        self.simulate(global, req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::Type;

    #[test]
    fn classify_covers_op_classes() {
        let fadd = Inst::Bin {
            op: BinOp::Add,
            ty: Type::F32,
            a: Operand::cf32(1.0),
            b: Operand::cf32(2.0),
        };
        assert_eq!(classify(&fadd), Some((Fu::Fadd, 3)));
        let ld = Inst::Load { ty: Type::F32, ptr: Operand::Arg(0) };
        assert_eq!(classify(&ld).unwrap().0, Fu::Lsu);
    }

    #[test]
    fn independent_copies_schedule_wider() {
        // A float-add chain: one copy is latency-bound; four copies
        // overlap on the 4 FADD units.
        let cfg = TtaConfig::default();
        let mut insts = Vec::new();
        let mut prev: Option<Reg> = None;
        for i in 0..8u32 {
            let a = prev.map(Operand::Reg).unwrap_or(Operand::cf32(1.0));
            insts.push((
                Some(Reg(i)),
                Inst::Bin { op: BinOp::Add, ty: Type::F32, a, b: Operand::cf32(2.0) },
            ));
            prev = Some(Reg(i));
        }
        let one = schedule_block(&cfg, &insts, 1);
        let four = schedule_block(&cfg, &insts, 4);
        assert!(four < one * 4, "overlap exploits the FU mix: {one} vs {four}");
        assert!(four >= one, "chain latency still bounds");
    }

    #[test]
    fn lsu_count_limits_memory_throughput() {
        let mut narrow = TtaConfig::default();
        narrow.units.insert(Fu::Lsu, 1);
        let wide = TtaConfig::default();
        let insts: Vec<(Option<Reg>, Inst)> = (0..8u32)
            .map(|i| (Some(Reg(i)), Inst::Load { ty: Type::F32, ptr: Operand::Arg(0) }))
            .collect();
        let n = schedule_block(&narrow, &insts, 1);
        let w = schedule_block(&wide, &insts, 1);
        assert!(w < n, "9 LSUs beat 1 LSU: {w} vs {n}");
    }
}
