//! The `basic` device (§3): minimal single-threaded CPU device executing
//! one work-group at a time.

use crate::cl::error::Result;
use crate::kcc::{CompileOptions, OptLevel};

use super::{Device, DeviceInfo, EngineKind, LaunchRequest, LaunchStats};

/// Single-threaded CPU device.
pub struct BasicDevice {
    /// Work-group execution engine.
    pub engine: EngineKind,
    /// Global memory capacity (the context sizes its region from this).
    pub global_mem: usize,
    /// Local memory per work-group.
    pub local_mem: usize,
    /// Optimizer level override. `None` follows the process default
    /// (`POCLRS_OPT` / O2); tests use `Some` to pin a level without
    /// racing on environment variables.
    pub opt_level: Option<OptLevel>,
}

impl BasicDevice {
    /// Default basic device: serial engine, 256 MiB global, 64 KiB local.
    pub fn new(engine: EngineKind) -> BasicDevice {
        BasicDevice { engine, global_mem: 256 << 20, local_mem: 64 << 10, opt_level: None }
    }

    /// Basic device pinned to a specific optimizer level.
    pub fn with_opt_level(engine: EngineKind, level: OptLevel) -> BasicDevice {
        BasicDevice { opt_level: Some(level), ..BasicDevice::new(engine) }
    }
}

impl Device for BasicDevice {
    fn info(&self) -> DeviceInfo {
        DeviceInfo {
            name: format!("basic-{:?}", self.engine).to_lowercase(),
            tlp: 1,
            ilp: "interpreted",
            dlp: match self.engine {
                EngineKind::Gang(8) => "gang x8 (AVX2 model)",
                EngineKind::Gang(4) => "gang x4 (NEON/AltiVec model)",
                EngineKind::Gang(_) => "gang",
                EngineKind::GangVector(8) => "gang-vector x8 (AVX2 SoA)",
                EngineKind::GangVector(4) => "gang-vector x4 (NEON/AltiVec SoA)",
                EngineKind::GangVector(_) => "gang-vector (SoA)",
                EngineKind::Bytecode(8) => "bytecode x8 (fused SoA dispatch)",
                EngineKind::Bytecode(4) => "bytecode x4 (fused SoA dispatch)",
                EngineKind::Bytecode(_) => "bytecode (fused SoA dispatch)",
                EngineKind::Jit(8) => "jit x8 (x86-64 templates)",
                EngineKind::Jit(4) => "jit x4 (x86-64 templates)",
                EngineKind::Jit(_) => "jit (x86-64 templates)",
                EngineKind::Serial => "scalar WI loops",
                EngineKind::Fiber => "fibers (no DLP)",
            },
            global_mem: self.global_mem,
            local_mem: self.local_mem,
        }
    }

    fn compile_options(&self) -> CompileOptions {
        let mut opts = super::cpu_compile_options(self.engine);
        if let Some(level) = self.opt_level {
            opts.opt_level = level;
        }
        opts
    }

    fn launch(&self, global: &mut [u8], req: &LaunchRequest) -> Result<LaunchStats> {
        let _launch_span = crate::trace::enabled().then(|| {
            crate::trace::span_args(
                crate::trace::CAT_EXEC,
                format!("launch {}", req.wgf.name),
                vec![
                    ("engine", crate::trace::ArgVal::s(format!("{:?}", self.engine))),
                    ("groups", crate::trace::ArgVal::u(req.groups.iter().product::<usize>() as u64)),
                ],
            )
        });
        crate::trace::metrics::add("exec.launches", 1);
        crate::trace::metrics::add("exec.workgroups", req.groups.iter().product::<usize>() as u64);
        let mut stats = LaunchStats::default();
        let mut local = vec![0u8; req.local_mem.max(1)];
        for g in req.all_groups() {
            let ctx = req.ctx(g);
            let gs =
                super::run_one_group(self.engine, &req.wgf, &req.args, global, &mut local, &ctx)?;
            stats.merge_gang(&gs);
            stats.workgroups += 1;
        }
        Ok(stats)
    }
}
