//! Barrier normalisation, classification, and the **Barrier CFG** (§4.3,
//! Definitions 1–5).
//!
//! After `normalize`, every barrier instruction sits alone in its own
//! *barrier block* whose terminator is an unconditional jump, the entry
//! node starts with an implicit barrier, and the (unified) exit node is an
//! implicit barrier block terminated by `ret`. Parallel regions are then
//! exactly the sub-CFGs between barrier blocks.

use std::collections::{HashMap, HashSet};

use crate::cl::error::{Error, Result};
use crate::ir::cfg::{reachable, unify_exits};
use crate::ir::dom::DomTree;
use crate::ir::func::Function;
use crate::ir::inst::{BarrierKind, BlockId, Inst, Operand, Term};

/// Normalise `f`: unify exits, add implicit entry/exit barriers, and
/// isolate every barrier into its own block (Algorithm 1 step 1).
pub fn normalize(f: &mut Function) -> Result<()> {
    // 1. Single exit.
    let exit = unify_exits(f);
    // 2. Implicit entry barrier: new entry block containing only a barrier.
    let new_entry = f.add_block("entry.barrier");
    f.push(new_entry, Inst::Barrier { kind: BarrierKind::Implicit });
    f.set_term(new_entry, Term::Jump(f.entry));
    f.entry = new_entry;
    // 3. Implicit exit barrier: `exit` gets a trailing barrier, then
    //    isolation below will leave the barrier in a dedicated ret block.
    f.push(exit, Inst::Barrier { kind: BarrierKind::Implicit });
    // 4. Isolate all barriers.
    isolate_barriers(f)?;
    Ok(())
}

/// Split blocks so each barrier instruction is alone in a block whose
/// terminator is a `Jump` (or `Ret` for the exit barrier).
pub fn isolate_barriers(f: &mut Function) -> Result<()> {
    // Iterate until no block holds a barrier together with anything else.
    loop {
        let mut work: Option<(BlockId, usize)> = None;
        'outer: for bb in f.block_ids() {
            let block = f.block(bb);
            for (i, (_, inst)) in block.insts.iter().enumerate() {
                if inst.is_barrier() && (block.insts.len() > 1 || !matches!(block.term, Term::Jump(_) | Term::Ret)) {
                    // Needs isolation unless it is already alone with a
                    // jump/ret terminator.
                    if block.insts.len() == 1 && matches!(block.term, Term::Jump(_) | Term::Ret) {
                        continue;
                    }
                    work = Some((bb, i));
                    break 'outer;
                }
            }
        }
        let Some((bb, i)) = work else { return Ok(()) };
        split_at_barrier(f, bb, i)?;
    }
}

/// Split block `bb` around the barrier at instruction index `i`:
/// `pre` (everything before) → `bar` (the barrier alone) → `post`
/// (everything after + original terminator).
fn split_at_barrier(f: &mut Function, bb: BlockId, i: usize) -> Result<()> {
    let name = f.block(bb).name.clone();
    let insts = std::mem::take(&mut f.block_mut(bb).insts);
    let term = f.block(bb).term.clone();
    let (pre, rest) = insts.split_at(i);
    let (bar, post) = (rest[0].clone(), rest[1..].to_vec());

    // Registers must not cross the split (IR invariant gives this for
    // frontend output; verify defensively).
    let pre_defs: HashSet<u32> = pre.iter().filter_map(|(d, _)| d.map(|r| r.0)).collect();
    for (_, inst) in &post {
        for op in inst.operands() {
            if let Operand::Reg(r) = op {
                if pre_defs.contains(&r.0) {
                    return Err(Error::compile(format!(
                        "register r{} crosses a barrier in block `{name}`",
                        r.0
                    )));
                }
            }
        }
    }
    if let Term::Br { cond: Operand::Reg(r), .. } = &term {
        if pre_defs.contains(&r.0) {
            return Err(Error::compile(format!(
                "branch condition crosses a barrier in block `{name}`"
            )));
        }
    }

    let post_needed = !post.is_empty() || !matches!(term, Term::Jump(_) | Term::Ret);
    // bb keeps the pre part.
    f.block_mut(bb).insts = pre.to_vec();
    let bar_bb = f.add_block(format!("{name}.bar"));
    f.block_mut(bar_bb).insts.push(bar);
    if post_needed {
        let post_bb = f.add_block(format!("{name}.post"));
        f.block_mut(post_bb).insts = post;
        f.set_term(post_bb, term);
        f.set_term(bar_bb, Term::Jump(post_bb));
    } else {
        f.set_term(bar_bb, term);
    }
    f.set_term(bb, Term::Jump(bar_bb));
    Ok(())
}

/// The reduced **Barrier CFG** (Definition 1): nodes are barrier blocks;
/// there is an edge `a → b` iff a barrier-free CFG path connects them.
/// Back edges of the underlying CFG are excluded (Algorithm 1 step 2
/// "ignore the possible back edges"), making the graph a DAG.
#[derive(Debug)]
pub struct BarrierGraph {
    /// Barrier blocks in entry-first DFS discovery order.
    pub nodes: Vec<BlockId>,
    /// Forward edges (barrier DAG).
    pub edges: Vec<(BlockId, BlockId)>,
    /// Edges realised through a CFG back edge (loop latch → header paths);
    /// kept separately because region formation needs them but
    /// predecessor-counting must ignore them.
    pub back_edges: Vec<(BlockId, BlockId)>,
}

impl BarrierGraph {
    /// Immediate predecessor barriers of `b` (Definition 4), DAG edges only.
    pub fn imm_preds(&self, b: BlockId) -> Vec<BlockId> {
        self.edges.iter().filter(|(_, t)| *t == b).map(|(s, _)| *s).collect()
    }

    /// Immediate successor barriers of `b` (Definition 5), DAG edges only.
    pub fn imm_succs(&self, b: BlockId) -> Vec<BlockId> {
        self.edges.iter().filter(|(s, _)| *s == b).map(|(_, t)| *t).collect()
    }

    /// All (src, dst) pairs including loop back-edge paths — every pair
    /// needs a parallel region.
    pub fn all_edges(&self) -> Vec<(BlockId, BlockId)> {
        let mut v = self.edges.clone();
        v.extend(self.back_edges.iter().copied());
        v
    }
}

/// Build the barrier graph of a normalised function.
pub fn barrier_graph(f: &Function) -> BarrierGraph {
    let barrier_set: HashSet<BlockId> =
        f.barrier_blocks().into_iter().collect();
    // CFG back edges via dominance.
    let dom = DomTree::compute(f);
    let mut back: HashSet<(BlockId, BlockId)> = HashSet::new();
    for b in reachable(f) {
        for s in f.succs(b) {
            if dom.dominates(s, b) {
                back.insert((b, s));
            }
        }
    }
    // From each barrier block, DFS through non-barrier blocks.
    let mut nodes = Vec::new();
    let mut edges = Vec::new();
    let mut back_edges = Vec::new();
    let order = reachable(f);
    for &b in order.iter().filter(|b| barrier_set.contains(b)) {
        nodes.push(b);
        // (block to visit, whether the path used a CFG back edge)
        let mut stack: Vec<(BlockId, bool)> = f
            .succs(b)
            .into_iter()
            .map(|s| (s, back.contains(&(b, s))))
            .collect();
        let mut seen: HashMap<BlockId, bool> = HashMap::new();
        let mut found: Vec<(BlockId, bool)> = Vec::new();
        while let Some((n, via_back)) = stack.pop() {
            // `seen[n]` records the best (forward < back) path class found
            // so far. Revisit only to upgrade a back-edge visit to a
            // forward one.
            match seen.get(&n) {
                Some(false) => continue,            // already forward-visited
                Some(true) if via_back => continue, // no upgrade
                _ => {}
            }
            seen.insert(n, via_back);
            if barrier_set.contains(&n) {
                found.push((n, via_back));
                continue;
            }
            for s in f.succs(n) {
                stack.push((s, via_back || back.contains(&(n, s))));
            }
        }
        // Deduplicate: prefer recording a forward edge over a back edge.
        let mut best: HashMap<BlockId, bool> = HashMap::new();
        for (t, vb) in found {
            let e = best.entry(t).or_insert(vb);
            *e = *e && vb;
        }
        let mut keys: Vec<BlockId> = best.keys().copied().collect();
        keys.sort();
        for t in keys {
            if best[&t] {
                back_edges.push((b, t));
            } else {
                edges.push((b, t));
            }
        }
    }
    BarrierGraph { nodes, edges, back_edges }
}

/// Classify a barrier block: **unconditional** iff it dominates the exit
/// node (§4.3); everything else is a conditional barrier.
pub fn is_unconditional(f: &Function, dom: &DomTree, b: BlockId) -> bool {
    let exits = f.exit_blocks();
    exits.iter().all(|&x| !dom.is_reachable(x) || dom.dominates(b, x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;

    fn normalized(src: &str) -> Function {
        let m = compile(src).unwrap();
        let mut f = m.kernels.into_iter().next().unwrap();
        normalize(&mut f).unwrap();
        crate::ir::verify::verify(&f).unwrap();
        f
    }

    #[test]
    fn no_barrier_kernel_has_entry_and_exit_barriers() {
        let f = normalized("__kernel void k(__global float *x) { x[get_global_id(0)] = 1.0f; }");
        let g = barrier_graph(&f);
        assert_eq!(g.nodes.len(), 2); // entry + exit
        assert_eq!(g.edges.len(), 1);
        assert!(g.back_edges.is_empty());
    }

    #[test]
    fn barriers_are_isolated() {
        let f = normalized(
            "__kernel void k(__global float *x, __local float *t) {
                 size_t i = get_local_id(0);
                 t[i] = x[i];
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[i] = t[0];
             }",
        );
        for bb in f.barrier_blocks() {
            let b = f.block(bb);
            assert_eq!(b.insts.len(), 1, "barrier block has only the barrier");
            assert!(matches!(b.term, Term::Jump(_) | Term::Ret));
        }
    }

    #[test]
    fn unconditional_barrier_splits_graph_in_two_edges() {
        let f = normalized(
            "__kernel void k(__global float *x) {
                 x[0] = 1.0f;
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[1] = 2.0f;
             }",
        );
        let g = barrier_graph(&f);
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.edges.len(), 2);
        let dom = DomTree::compute(&f);
        for &b in &g.nodes {
            assert!(is_unconditional(&f, &dom, b));
        }
    }

    #[test]
    fn conditional_barrier_detected() {
        let f = normalized(
            "__kernel void k(__global float *x, int c) {
                 if (c > 0) {
                     barrier(CLK_LOCAL_MEM_FENCE);
                     x[0] = 1.0f;
                 }
                 x[1] = 2.0f;
             }",
        );
        let dom = DomTree::compute(&f);
        let g = barrier_graph(&f);
        let conditional: Vec<_> =
            g.nodes.iter().filter(|&&b| !is_unconditional(&f, &dom, b)).collect();
        assert_eq!(conditional.len(), 1);
        // Prop. 1: some barrier has more than one immediate predecessor.
        assert!(g.nodes.iter().any(|&b| g.imm_preds(b).len() > 1));
    }

    #[test]
    fn loop_barrier_produces_back_edge() {
        let f = normalized(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) {
                     x[i] += 1.0f;
                     barrier(CLK_LOCAL_MEM_FENCE);
                 }
             }",
        );
        let g = barrier_graph(&f);
        assert!(
            !g.back_edges.is_empty(),
            "barrier in loop reaches itself through the latch: {:?}",
            g
        );
    }

    #[test]
    fn barrier_graph_is_dag_on_forward_edges() {
        let f = normalized(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) {
                     barrier(CLK_LOCAL_MEM_FENCE);
                     x[i] = (float)i;
                     barrier(CLK_GLOBAL_MEM_FENCE);
                 }
             }",
        );
        let g = barrier_graph(&f);
        // Kahn: forward edges alone must topologically sort completely.
        let mut indeg: HashMap<BlockId, usize> = g.nodes.iter().map(|&n| (n, 0)).collect();
        for (_, t) in &g.edges {
            *indeg.get_mut(t).unwrap() += 1;
        }
        let mut queue: Vec<BlockId> =
            g.nodes.iter().copied().filter(|n| indeg[n] == 0).collect();
        let mut seen = 0;
        while let Some(n) = queue.pop() {
            seen += 1;
            for (s, t) in &g.edges {
                if *s == n {
                    let d = indeg.get_mut(t).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        queue.push(*t);
                    }
                }
            }
        }
        assert_eq!(seen, g.nodes.len(), "forward barrier edges form a DAG");
    }
}
