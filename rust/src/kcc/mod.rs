//! The pocl kernel compiler (§4): parallel region formation separated from
//! target-specific parallel mapping.

pub mod barriers;
pub mod bloops;
pub mod horizontal;
pub mod passes;
pub mod privatize;
pub mod regions;
pub mod taildup;
pub mod uniformity;
pub mod wiloops;

pub use passes::{compile_workgroup, CompileOptions, CompileStats, WorkGroupFunction};
pub use regions::Region;
