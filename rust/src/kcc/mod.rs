//! The pocl kernel compiler (§4): parallel region formation separated from
//! target-specific parallel mapping.
//!
//! The compiler's outputs and their consumers (the execution-engine
//! matrix; see also `exec`):
//!
//! * `reg_fn` + `regions` — consumed by the region-level engines: the
//!   per-lane `gang` executor, the lane-batched `vecgang` executor (which
//!   keeps uniform registers and merged uniform slots scalar, computed
//!   once per gang, and widens only varying values), and the `fiber`
//!   baseline. The §4.6 uniformity exports
//!   (`WorkGroupFunction::reg_uniform`, `region_divergent`) are the
//!   static contract behind `vecgang`'s dynamic uniformity lattice —
//!   surfaced through `CompileStats`/`--stats` and asserted by tests; an
//!   AOT vectorising backend would consume them directly.
//! * `loop_fn` + `wi_loops` metadata — consumed by the WI-loop engines:
//!   the serial interpreter and the TTA scheduler (`devices::ttasim`).
//! * SPMD mode (`CompileOptions::spmd`) skips WI-loop materialisation for
//!   devices that execute work-items themselves (`devices::pjrt`).

pub mod barriers;
pub mod bloops;
pub mod horizontal;
pub mod opt;
pub mod passes;
pub mod privatize;
pub mod regions;
pub mod taildup;
pub mod uniformity;
pub mod wiloops;

pub use opt::{OptLevel, OptStats};
pub use passes::{compile_workgroup, CompileOptions, CompileStats, TargetKind, WorkGroupFunction};
pub use regions::Region;
