//! Variable uniformity and divergence analysis (§4.6).
//!
//! A value is **uniform** when it is provably identical for every work-item
//! in the work-group: constants and kernel arguments are uniform roots;
//! work-item ids are divergent roots; everything else propagates. A slot
//! (private variable) is uniform when every store to it stores a uniform
//! value at a uniform address from a control-uniform block.
//!
//! The analysis additionally reports **accumulating** slots (read-modify-
//! written, e.g. loop induction variables): those must be replicated per
//! work-item even when their values are uniform, because a merged copy
//! would be updated once per work-item in the work-item loop (§4.5 notes
//! the same per-target tradeoff).

use std::collections::{HashMap, HashSet};

use crate::ir::cfg::{create_subgraph, reachable};
use crate::ir::func::Function;
use crate::ir::inst::{BlockId, Inst, Operand, Reg, SlotId, Term, WiFn};

/// Result of the analysis.
#[derive(Debug, Clone)]
pub struct Uniformity {
    /// Per-slot: all stores uniform (value + address + control).
    pub uniform_slots: Vec<bool>,
    /// Per-slot: some block loads the slot before storing it (read-modify-
    /// write), so per-WI replication is required regardless of uniformity.
    pub accumulating_slots: Vec<bool>,
    /// Blocks under divergent control (between a divergent branch and its
    /// reconvergence point).
    pub divergent_blocks: HashSet<BlockId>,
}

impl Uniformity {
    /// True if the branch condition terminating `b` is uniform.
    pub fn uniform_branch(&self, f: &Function, b: BlockId) -> bool {
        match &f.block(b).term {
            Term::Br { cond, .. } => {
                let regs = block_value_kinds(f, b, &self.uniform_slots);
                operand_uniform(cond, &regs)
            }
            _ => true,
        }
    }
}

/// What we know about a register inside one block.
#[derive(Debug, Clone, Copy)]
enum Kind {
    /// Plain value; `true` = uniform.
    Val(bool),
    /// Pointer with a root (None = global/local/constant memory) and
    /// whether the address computation is uniform.
    Ptr { root: Option<SlotId>, addr_uniform: bool },
}

impl Kind {
    fn uniform(&self) -> bool {
        match self {
            Kind::Val(u) => *u,
            Kind::Ptr { addr_uniform, .. } => *addr_uniform,
        }
    }
}

/// Run the analysis to fixpoint.
pub fn analyze(f: &Function) -> Uniformity {
    let nslots = f.slots.len();
    let mut u = Uniformity {
        uniform_slots: vec![true; nslots],
        accumulating_slots: accumulating(f),
        divergent_blocks: HashSet::new(),
    };
    for _ in 0..(nslots + 2) {
        // 1. Divergent blocks from divergent branches, under the current
        //    slot assumption.
        u.divergent_blocks = divergent_blocks(f, &u.uniform_slots);
        // 2. Demote slots with non-uniform stores.
        let mut changed = false;
        for b in reachable(f) {
            let regs = block_value_kinds(f, b, &u.uniform_slots);
            let divergent_block = u.divergent_blocks.contains(&b);
            for (_, inst) in &f.block(b).insts {
                if let Inst::Store { ptr, val, .. } = inst {
                    let root = match ptr {
                        Operand::Slot(s) => Some(*s),
                        Operand::Reg(r) => match regs.get(r) {
                            Some(Kind::Ptr { root, .. }) => *root,
                            _ => None,
                        },
                        _ => None,
                    };
                    let Some(slot) = root else { continue };
                    if !u.uniform_slots[slot.0 as usize] {
                        continue;
                    }
                    let val_u = operand_uniform(val, &regs);
                    let addr_u = match ptr {
                        Operand::Slot(_) => true,
                        Operand::Reg(r) => regs.get(r).map(|k| k.uniform()).unwrap_or(false),
                        _ => true,
                    };
                    if divergent_block || !val_u || !addr_u {
                        u.uniform_slots[slot.0 as usize] = false;
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
    u
}

/// Function-wide register uniformity classification.
///
/// Registers are single-def and block-local (IR invariant), so one flat
/// table indexed by register number is exact. A register is uniform when
/// its defining instruction produces a provably lane-invariant value *and*
/// the defining block is not under divergent control. The table is the
/// static projection of the dynamic uniformity lattice the lane-batched
/// vector engine maintains at run time (`exec::vecgang`): every register
/// marked uniform here is guaranteed to stay in the engine's scalar
/// (computed-once-per-gang) form.
///
/// `f` may be any (possibly barrier-normalised / tail-duplicated)
/// function; only the slot-uniformity assumption is carried over, which is
/// stable across those transforms because slot ids never change.
pub fn classify_regs(f: &Function, uniform_slots: &[bool]) -> Vec<bool> {
    let divergent = divergent_blocks(f, uniform_slots);
    let mut out = vec![false; f.reg_count() as usize];
    for b in reachable(f) {
        let div = divergent.contains(&b);
        let kinds = block_value_kinds(f, b, uniform_slots);
        for (r, k) in kinds {
            out[r.0 as usize] = !div && k.uniform();
        }
    }
    out
}

/// Slots that are loaded before being stored within a single block chain —
/// the read-modify-write pattern (`i = i + 1`, `acc += ...`).
fn accumulating(f: &Function) -> Vec<bool> {
    let mut acc = vec![false; f.slots.len()];
    for b in f.block_ids() {
        // Track which regs carry a loaded slot value within this block.
        let mut loaded_from: HashMap<Reg, SlotId> = HashMap::new();
        let mut tainted: HashMap<Reg, HashSet<SlotId>> = HashMap::new();
        for (def, inst) in &f.block(b).insts {
            // Propagate taint: result depends on loads of which slots?
            let mut deps: HashSet<SlotId> = HashSet::new();
            for op in inst.operands() {
                if let Operand::Reg(r) = op {
                    if let Some(s) = loaded_from.get(&r) {
                        deps.insert(*s);
                    }
                    if let Some(t) = tainted.get(&r) {
                        deps.extend(t.iter().copied());
                    }
                }
            }
            if let Inst::Load { ptr, .. } = inst {
                if let Operand::Slot(s) = ptr {
                    if let Some(d) = def {
                        loaded_from.insert(*d, *s);
                    }
                }
            }
            if let Inst::Store { ptr: Operand::Slot(s), val, .. } = inst {
                let mut val_deps = HashSet::new();
                if let Operand::Reg(r) = val {
                    if let Some(src) = loaded_from.get(r) {
                        val_deps.insert(*src);
                    }
                    if let Some(t) = tainted.get(r) {
                        val_deps.extend(t.iter().copied());
                    }
                }
                if val_deps.contains(s) {
                    acc[s.0 as usize] = true;
                }
            }
            if let Some(d) = def {
                tainted.insert(*d, deps);
            }
        }
    }
    acc
}

/// Per-block register classification under a slot-uniformity assumption.
fn block_value_kinds(f: &Function, b: BlockId, uniform_slots: &[bool]) -> HashMap<Reg, Kind> {
    let mut kinds: HashMap<Reg, Kind> = HashMap::new();
    for (def, inst) in &f.block(b).insts {
        let Some(d) = def else { continue };
        let k = match inst {
            Inst::Wi { func, .. } => Kind::Val(matches!(
                func,
                WiFn::GroupId
                    | WiFn::LocalSize
                    | WiFn::GlobalSize
                    | WiFn::NumGroups
                    | WiFn::WorkDim
                    | WiFn::GlobalOffset
            )),
            Inst::Load { ptr, .. } => match ptr {
                Operand::Slot(s) => Kind::Val(uniform_slots[s.0 as usize]),
                Operand::Reg(r) => match kinds.get(r) {
                    Some(Kind::Ptr { root: Some(s), addr_uniform }) => {
                        Kind::Val(*addr_uniform && uniform_slots[s.0 as usize])
                    }
                    // Loads from global/local memory are conservatively
                    // divergent (another work-item may have stored there).
                    _ => Kind::Val(false),
                },
                Operand::Arg(_) => Kind::Val(false),
                Operand::Imm(_) => Kind::Val(false),
            },
            Inst::Gep { base, idx, .. } => {
                let idx_u = operand_uniform(idx, &kinds);
                match base {
                    Operand::Slot(s) => Kind::Ptr { root: Some(*s), addr_uniform: idx_u },
                    Operand::Arg(_) => Kind::Ptr { root: None, addr_uniform: idx_u },
                    Operand::Reg(r) => match kinds.get(r) {
                        Some(Kind::Ptr { root, addr_uniform }) => {
                            Kind::Ptr { root: *root, addr_uniform: *addr_uniform && idx_u }
                        }
                        _ => Kind::Ptr { root: None, addr_uniform: false },
                    },
                    Operand::Imm(_) => Kind::Ptr { root: None, addr_uniform: idx_u },
                }
            }
            _ => {
                let all = inst.operands().iter().all(|op| operand_uniform(op, &kinds));
                Kind::Val(all)
            }
        };
        kinds.insert(*d, k);
    }
    kinds
}

fn operand_uniform(op: &Operand, kinds: &HashMap<Reg, Kind>) -> bool {
    match op {
        Operand::Imm(_) | Operand::Arg(_) | Operand::Slot(_) => true,
        Operand::Reg(r) => kinds.get(r).map(|k| k.uniform()).unwrap_or(false),
    }
}

/// Blocks strictly between each divergent branch and its immediate
/// postdominator (the reconvergence point).
fn divergent_blocks(f: &Function, uniform_slots: &[bool]) -> HashSet<BlockId> {
    let ipdom = ipostdoms(f);
    let mut out = HashSet::new();
    for b in reachable(f) {
        let Term::Br { cond, .. } = &f.block(b).term else { continue };
        let kinds = block_value_kinds(f, b, uniform_slots);
        if operand_uniform(cond, &kinds) {
            continue;
        }
        match ipdom.get(&b) {
            Some(Some(j)) => {
                for n in create_subgraph(f, b, *j) {
                    if n != b && n != *j {
                        out.insert(n);
                    }
                }
            }
            _ => {
                // No reconvergence point: everything reachable from b
                // (except b) is divergent-controlled.
                let mut stack = f.succs(b);
                while let Some(n) = stack.pop() {
                    if out.insert(n) {
                        stack.extend(f.succs(n));
                    }
                }
            }
        }
    }
    out
}

/// Immediate postdominators via the CHK algorithm on the reversed CFG with
/// a virtual exit. Returns `None` for blocks whose only postdominator is
/// the virtual exit.
pub fn ipostdoms(f: &Function) -> HashMap<BlockId, Option<BlockId>> {
    let blocks = reachable(f);
    let n = blocks.len();
    let index: HashMap<BlockId, usize> = blocks.iter().enumerate().map(|(i, &b)| (b, i)).collect();
    // Reversed graph: node n = virtual exit; succs_rev(virtual) = exits;
    // succs_rev(b) = preds(b); preds_rev(b) = succs(b) (+virtual for exits).
    let exits: Vec<usize> =
        f.exit_blocks().iter().filter_map(|b| index.get(b).copied()).collect();
    let preds_cfg = f.preds();
    // Post-order of reversed graph from virtual exit.
    let mut post: Vec<usize> = Vec::new();
    let mut seen = vec![false; n + 1];
    let mut stack: Vec<(usize, usize)> = vec![(n, 0)];
    seen[n] = true;
    let rev_succs = |v: usize| -> Vec<usize> {
        if v == n {
            exits.clone()
        } else {
            preds_cfg[blocks[v].0 as usize]
                .iter()
                .filter_map(|p| index.get(p).copied())
                .collect()
        }
    };
    while let Some(&mut (v, ref mut i)) = stack.last_mut() {
        let succs = rev_succs(v);
        if *i < succs.len() {
            let s = succs[*i];
            *i += 1;
            if !seen[s] {
                seen[s] = true;
                stack.push((s, 0));
            }
        } else {
            post.push(v);
            stack.pop();
        }
    }
    let rpo: Vec<usize> = post.iter().rev().copied().collect();
    let rpo_idx: HashMap<usize, usize> = rpo.iter().enumerate().map(|(i, &v)| (v, i)).collect();
    let mut idom: Vec<Option<usize>> = vec![None; n + 1];
    idom[n] = Some(n);
    let preds_rev = |v: usize| -> Vec<usize> {
        // predecessors in reversed graph = successors in CFG, plus the
        // virtual node for exit blocks.
        let mut out: Vec<usize> = f
            .succs(blocks[v])
            .iter()
            .filter_map(|s| index.get(s).copied())
            .collect();
        if exits.contains(&v) {
            out.push(n);
        }
        out
    };
    let intersect = |idom: &Vec<Option<usize>>, mut a: usize, mut b: usize| -> usize {
        while a != b {
            while rpo_idx[&a] > rpo_idx[&b] {
                a = idom[a].unwrap();
            }
            while rpo_idx[&b] > rpo_idx[&a] {
                b = idom[b].unwrap();
            }
        }
        a
    };
    let mut changed = true;
    while changed {
        changed = false;
        for &v in rpo.iter().skip(1) {
            let mut new: Option<usize> = None;
            for p in preds_rev(v) {
                if !rpo_idx.contains_key(&p) {
                    continue;
                }
                if idom[p].is_some() {
                    new = Some(match new {
                        None => p,
                        Some(cur) => intersect(&idom, p, cur),
                    });
                }
            }
            if let Some(ni) = new {
                if idom[v] != Some(ni) {
                    idom[v] = Some(ni);
                    changed = true;
                }
            }
        }
    }
    let mut out = HashMap::new();
    for (i, &b) in blocks.iter().enumerate() {
        out.insert(
            b,
            match idom[i] {
                Some(p) if p < n => Some(blocks[p]),
                _ => None,
            },
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;

    fn analyzed(src: &str) -> (Function, Uniformity) {
        let m = compile(src).unwrap();
        let f = m.kernels.into_iter().next().unwrap();
        let u = analyze(&f);
        (f, u)
    }

    fn slot_named(f: &Function, name: &str) -> usize {
        f.slots.iter().position(|s| s.name == name).unwrap()
    }

    #[test]
    fn kernel_args_are_uniform_roots() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x, uint w) {
                 uint lim = w * 2u;
                 x[get_global_id(0)] = (float)lim;
             }",
        );
        assert!(u.uniform_slots[slot_named(&f, "w")]);
        assert!(u.uniform_slots[slot_named(&f, "lim")]);
    }

    #[test]
    fn work_item_ids_are_divergent() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x) {
                 uint i = (uint)get_global_id(0);
                 x[i] = 1.0f;
             }",
        );
        assert!(!u.uniform_slots[slot_named(&f, "i")]);
    }

    #[test]
    fn divergence_poisons_control_dependent_stores() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x, uint w) {
                 uint flag = 0u;
                 if (get_global_id(0) > (size_t)w) { flag = 1u; }
                 x[0] = (float)flag;
             }",
        );
        assert!(!u.uniform_slots[slot_named(&f, "flag")], "store under divergent control");
    }

    #[test]
    fn uniform_branch_does_not_poison() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x, uint w) {
                 uint flag = 0u;
                 if (w > 4u) { flag = 1u; }
                 x[get_global_id(0)] = (float)flag;
             }",
        );
        assert!(u.uniform_slots[slot_named(&f, "flag")]);
    }

    #[test]
    fn induction_variable_is_uniform_but_accumulating() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) { x[get_global_id(0)] += 1.0f; }
             }",
        );
        let i = slot_named(&f, "i");
        assert!(u.uniform_slots[i], "loop bound from arg → uniform induction");
        assert!(u.accumulating_slots[i], "i = i + 1 is read-modify-write");
    }

    #[test]
    fn loads_from_global_memory_are_divergent() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x) {
                 float v = x[0];
                 x[1] = v;
             }",
        );
        assert!(!u.uniform_slots[slot_named(&f, "v")]);
    }

    #[test]
    fn divergent_blocks_detected() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x) {
                 if (get_global_id(0) == 0u) { x[0] = 1.0f; }
                 x[1] = 2.0f;
             }",
        );
        assert!(!u.divergent_blocks.is_empty());
        // The reconvergence block (storing x[1]) must NOT be divergent.
        let last_store_block = crate::ir::cfg::reachable(&f)
            .into_iter()
            .filter(|b| {
                f.block(*b)
                    .insts
                    .iter()
                    .any(|(_, i)| matches!(i, Inst::Store { .. }))
            })
            .next_back()
            .unwrap();
        assert!(!u.divergent_blocks.contains(&last_store_block));
    }

    #[test]
    fn register_classification_splits_uniform_and_varying() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x, uint w) {
                 uint lim = w * 2u;
                 x[get_global_id(0)] = (float)lim;
             }",
        );
        let regs = classify_regs(&f, &u.uniform_slots);
        assert_eq!(regs.len(), f.reg_count() as usize);
        assert!(regs.iter().any(|&r| r), "arg-derived registers are uniform");
        assert!(!regs.iter().all(|&r| r), "the global-id address chain is varying");
    }

    #[test]
    fn registers_under_divergent_control_are_varying() {
        let (f, u) = analyzed(
            "__kernel void k(__global float *x, uint w) {
                 if (get_global_id(0) > (size_t)w) { x[0] = (float)(w * 3u); }
             }",
        );
        let regs = classify_regs(&f, &u.uniform_slots);
        // The `w * 3u` computation has uniform operands but sits inside a
        // divergently-controlled block, so it must not be marked uniform.
        for b in crate::ir::cfg::reachable(&f) {
            if u.divergent_blocks.contains(&b) {
                for (def, _) in &f.block(b).insts {
                    if let Some(r) = def {
                        assert!(!regs[r.0 as usize], "r{} in divergent block", r.0);
                    }
                }
            }
        }
    }

    #[test]
    fn postdoms_of_diamond() {
        let (f, _) = analyzed(
            "__kernel void k(__global float *x, int c) {
                 if (c > 0) { x[0] = 1.0f; } else { x[1] = 2.0f; }
                 x[2] = 3.0f;
             }",
        );
        let ipd = ipostdoms(&f);
        // Entry's ipostdom is the join (or further) — never None here.
        assert!(ipd[&f.entry].is_some());
    }
}
