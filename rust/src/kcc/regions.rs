//! Parallel region formation (§4.3, Algorithm 1).
//!
//! A **parallel region** is the single-entry single-exit sub-CFG between a
//! barrier and one of its immediate successor barriers. All work-items
//! execute a region to completion (in any relative order) before any
//! work-item proceeds past the region's closing barrier.

use std::collections::{HashMap, HashSet};

use crate::ir::func::Function;
use crate::ir::inst::BlockId;

use super::barriers::{barrier_graph, BarrierGraph};

/// One parallel region.
#[derive(Debug, Clone)]
pub struct Region {
    /// Region index (discovery order from the entry barrier).
    pub id: usize,
    /// The barrier block the region starts after.
    pub pre: BlockId,
    /// The barrier block the region ends at.
    pub post: BlockId,
    /// Non-barrier blocks strictly between `pre` and `post`, sorted.
    /// May be empty (two adjacent barriers).
    pub blocks: Vec<BlockId>,
    /// True if `pre → post` is realised through a CFG back edge (the
    /// latch-side region of a b-loop, §4.5).
    pub via_back_edge: bool,
    /// True if `pre` has several immediate successor barriers, i.e. the
    /// peeling transformation (§4.4, Fig. 7) applies when materialising
    /// work-item loops.
    pub needs_peeling: bool,
}

impl Region {
    /// True if `b` is one of the region's body blocks.
    pub fn contains(&self, b: BlockId) -> bool {
        self.blocks.binary_search(&b).is_ok()
    }
}

/// Form all parallel regions of a normalised (barrier-isolated) function.
pub fn form_regions(f: &Function) -> (Vec<Region>, BarrierGraph) {
    let g = barrier_graph(f);
    let barrier_set: HashSet<BlockId> = g.nodes.iter().copied().collect();
    let mut regions = Vec::new();
    let mut succ_count: HashMap<BlockId, usize> = HashMap::new();
    for (s, _) in g.all_edges() {
        *succ_count.entry(s).or_insert(0) += 1;
    }
    for (pre, post) in g.all_edges() {
        let blocks = region_blocks(f, &barrier_set, pre, post);
        let via_back_edge = g.back_edges.contains(&(pre, post));
        regions.push(Region {
            id: regions.len(),
            pre,
            post,
            blocks,
            via_back_edge,
            needs_peeling: succ_count[&pre] > 1,
        });
    }
    (regions, g)
}

/// Blocks on barrier-free paths from `pre` to `post`: forward-reachable
/// from `pre` without crossing another barrier, intersected with
/// backward-reachable from `post` likewise.
pub fn region_blocks(
    f: &Function,
    barrier_set: &HashSet<BlockId>,
    pre: BlockId,
    post: BlockId,
) -> Vec<BlockId> {
    let mut fwd = HashSet::new();
    let mut stack: Vec<BlockId> = f.succs(pre);
    while let Some(b) = stack.pop() {
        if barrier_set.contains(&b) || !fwd.insert(b) {
            continue;
        }
        for s in f.succs(b) {
            stack.push(s);
        }
    }
    let preds = f.preds();
    let mut bwd = HashSet::new();
    let mut stack: Vec<BlockId> = preds[post.0 as usize].clone();
    while let Some(b) = stack.pop() {
        if barrier_set.contains(&b) || !bwd.insert(b) {
            continue;
        }
        for &p in &preds[b.0 as usize] {
            stack.push(p);
        }
    }
    let mut out: Vec<BlockId> = fwd.intersection(&bwd).copied().collect();
    out.sort();
    out
}

/// Region invariant checks used by tests and (in debug builds) the pass
/// pipeline: regions contain no barriers and flow only into their own
/// blocks, their closing barrier, or sibling regions of the same `pre`
/// (shared prefixes before a barrier-selecting branch).
pub fn check_regions(f: &Function, regions: &[Region]) -> Result<(), String> {
    for r in regions {
        for &b in &r.blocks {
            if f.block(b).has_barrier() {
                return Err(format!("region {} contains barrier block {}", r.id, b.0));
            }
        }
        let siblings: HashSet<BlockId> = regions
            .iter()
            .filter(|s| s.pre == r.pre)
            .flat_map(|s| s.blocks.iter().copied().chain(std::iter::once(s.post)))
            .collect();
        for &b in &r.blocks {
            for s in f.succs(b) {
                if !r.contains(s) && s != r.post && !siblings.contains(&s) {
                    return Err(format!(
                        "region {} block {} escapes to block {} (not post/sibling)",
                        r.id, b.0, s.0
                    ));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::kcc::barriers::normalize;

    fn regions_of(src: &str) -> (Function, Vec<Region>) {
        let m = compile(src).unwrap();
        let mut f = m.kernels.into_iter().next().unwrap();
        normalize(&mut f).unwrap();
        let (regions, _) = form_regions(&f);
        check_regions(&f, &regions).unwrap();
        (f, regions)
    }

    #[test]
    fn kernel_without_barriers_is_one_region() {
        let (_, regions) =
            regions_of("__kernel void k(__global float *x) { x[get_global_id(0)] = 1.0f; }");
        assert_eq!(regions.len(), 1);
        assert!(!regions[0].needs_peeling);
        assert!(!regions[0].via_back_edge);
        assert!(!regions[0].blocks.is_empty());
    }

    #[test]
    fn unconditional_barrier_creates_two_regions() {
        let (_, regions) = regions_of(
            "__kernel void k(__global float *x, __local float *t) {
                 size_t i = get_local_id(0);
                 t[i] = x[i];
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[i] = t[0];
             }",
        );
        assert_eq!(regions.len(), 2, "Fig. 4(b): one region per side of the barrier");
    }

    #[test]
    fn barrier_loop_has_back_edge_region() {
        let (_, regions) = regions_of(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) {
                     x[i] += 1.0f;
                     barrier(CLK_LOCAL_MEM_FENCE);
                 }
             }",
        );
        assert!(regions.iter().any(|r| r.via_back_edge), "latch-side region exists");
    }

    #[test]
    fn conditional_barrier_regions_need_peeling() {
        let (_, regions) = regions_of(
            "__kernel void k(__global float *x, int c) {
                 if (c > 0) { barrier(CLK_LOCAL_MEM_FENCE); x[0] = 1.0f; }
                 x[1] = 2.0f;
             }",
        );
        assert!(regions.iter().any(|r| r.needs_peeling));
    }

    #[test]
    fn adjacent_barriers_make_empty_region() {
        let (_, regions) = regions_of(
            "__kernel void k(__global float *x) {
                 barrier(CLK_LOCAL_MEM_FENCE);
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[0] = 1.0f;
             }",
        );
        assert!(regions.iter().any(|r| r.blocks.len() <= 1));
    }
}
