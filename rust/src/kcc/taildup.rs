//! Tail duplication for conditional barriers (§4.4, Algorithm 2).
//!
//! Transforms the CFG so that every barrier has **at most one immediate
//! predecessor barrier** in the (back-edge-free) barrier DAG, which makes
//! single-entry single-exit parallel region formation unambiguous
//! (Proposition 1 guarantees the trigger exists whenever a conditional
//! barrier does).
//!
//! Implementation: while some barrier `u` has ≥2 immediate predecessor
//! barriers, replicate `u`'s *tail* — the sub-CFG forward-reachable from
//! `u` without following CFG back edges — once per extra predecessor, and
//! redirect that predecessor's paths into the copy. Back edges inside the
//! replicated set keep pointing at the original loop headers, which
//! preserves loop semantics (both copies iterate the same loop).

use std::collections::HashSet;

use crate::cl::error::{Error, Result};
use crate::ir::cfg::replicate_cfg;
use crate::ir::dom::DomTree;
use crate::ir::func::Function;
use crate::ir::inst::BlockId;

use super::barriers::barrier_graph;


/// Statistics returned by the pass (consumed by `CompileStats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct TailDupStats {
    /// Number of barrier nodes that triggered duplication.
    pub barriers_split: usize,
    /// Total blocks created by replication.
    pub blocks_duplicated: usize,
}

/// Run tail duplication until every barrier has ≤1 immediate predecessor
/// barrier. Returns statistics.
///
/// When a barrier `u` has several immediate predecessor barriers, one of
/// them — the one dominating `u`, if any — keeps the original tail; every
/// other predecessor `p` is a *conditional* path into `u`, and the whole
/// tail starting **at `p`** is replicated for it (Algorithm 2 duplicates
/// from the conditional barrier to the exit). Edges entering `p` are
/// redirected into the copy; the copy's back edges keep pointing at the
/// original loop headers.
pub fn run(f: &mut Function) -> Result<TailDupStats> {
    let mut stats = TailDupStats::default();
    // Each iteration fixes one violating barrier. Cap generously to catch
    // non-termination bugs rather than hanging.
    for _ in 0..1024 {
        let g = barrier_graph(f);
        let Some(&u) = g.nodes.iter().find(|&&n| g.imm_preds(n).len() > 1) else {
            return Ok(stats);
        };
        let preds = g.imm_preds(u);
        stats.barriers_split += 1;

        let dom = DomTree::compute(f);
        // The dominating predecessor (the unconditional path) keeps the
        // original blocks; ties broken by taking the first.
        let keep = preds.iter().copied().find(|&p| dom.dominates(p, u)).unwrap_or(preds[0]);
        for &p in preds.iter().filter(|&&p| p != keep) {
            // Replicate everything forward-reachable from p (p included).
            let tail = forward_tail(f, &dom, p);
            let map = replicate_cfg(f, &tail);
            stats.blocks_duplicated += map.len();
            // Redirect edges into p from outside the tail.
            let tail_set: HashSet<BlockId> = tail.iter().copied().collect();
            let cfg_preds = f.preds();
            let redirect: Vec<BlockId> = cfg_preds[p.0 as usize]
                .iter()
                .copied()
                .filter(|pb| !tail_set.contains(pb))
                .collect();
            if redirect.is_empty() {
                return Err(Error::compile(format!(
                    "tail duplication: conditional barrier {} has no external edge",
                    p.0
                )));
            }
            let new_p = map[&p];
            for rb in redirect {
                let mut term = f.block(rb).term.clone();
                term.map_succs(|s| if s == p { new_p } else { s });
                f.block_mut(rb).term = term;
            }
        }
    }
    Err(Error::compile("tail duplication did not converge in 1024 iterations"))
}

/// Sub-CFG forward-reachable from `from`, never following back edges
/// (computed via dominance: an edge b→s with s dominating b is a back
/// edge). Includes `from` itself.
fn forward_tail(f: &Function, dom: &DomTree, from: BlockId) -> Vec<BlockId> {
    let mut out = HashSet::new();
    let mut stack = vec![from];
    while let Some(b) = stack.pop() {
        if !out.insert(b) {
            continue;
        }
        for s in f.succs(b) {
            if dom.is_reachable(s) && dom.dominates(s, b) {
                continue; // back edge
            }
            stack.push(s);
        }
    }
    let mut v: Vec<BlockId> = out.into_iter().collect();
    v.sort();
    v
}

/// The property the pass establishes; exposed for tests and the pipeline's
/// debug assertions.
pub fn max_imm_preds(f: &Function) -> usize {
    let g = barrier_graph(f);
    g.nodes.iter().map(|&n| g.imm_preds(n).len()).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::ir::verify::verify;
    use crate::kcc::barriers::normalize;
    use crate::kcc::regions::{check_regions, form_regions};

    fn prepare(src: &str) -> Function {
        let m = compile(src).unwrap();
        let mut f = m.kernels.into_iter().next().unwrap();
        normalize(&mut f).unwrap();
        f
    }

    #[test]
    fn no_op_without_conditional_barriers() {
        let mut f = prepare(
            "__kernel void k(__global float *x) {
                 x[0] = 1.0f;
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[1] = 2.0f;
             }",
        );
        let stats = run(&mut f).unwrap();
        assert_eq!(stats.barriers_split, 0);
    }

    #[test]
    fn conditional_barrier_gets_unique_preds() {
        let mut f = prepare(
            "__kernel void k(__global float *x, int c) {
                 if (c > 0) { barrier(CLK_LOCAL_MEM_FENCE); x[0] = 1.0f; }
                 x[1] = 2.0f;
             }",
        );
        assert!(max_imm_preds(&f) > 1, "precondition: violation exists");
        let stats = run(&mut f).unwrap();
        verify(&f).unwrap();
        assert!(stats.barriers_split >= 1);
        assert!(max_imm_preds(&f) <= 1, "property established");
        let (regions, _) = form_regions(&f);
        check_regions(&f, &regions).unwrap();
    }

    #[test]
    fn nested_conditional_barriers() {
        let mut f = prepare(
            "__kernel void k(__global float *x, int c, int d) {
                 if (c > 0) {
                     barrier(CLK_LOCAL_MEM_FENCE);
                     if (d > 0) { barrier(CLK_LOCAL_MEM_FENCE); x[0] = 1.0f; }
                 }
                 barrier(CLK_GLOBAL_MEM_FENCE);
                 x[1] = 2.0f;
             }",
        );
        run(&mut f).unwrap();
        verify(&f).unwrap();
        assert!(max_imm_preds(&f) <= 1);
        let (regions, _) = form_regions(&f);
        check_regions(&f, &regions).unwrap();
    }

    #[test]
    fn if_else_with_barriers_on_both_sides() {
        let mut f = prepare(
            "__kernel void k(__global float *x, int c) {
                 if (c > 0) { x[0] = 1.0f; barrier(CLK_LOCAL_MEM_FENCE); x[1] = 1.0f; }
                 else { x[2] = 2.0f; barrier(CLK_LOCAL_MEM_FENCE); x[3] = 2.0f; }
                 x[4] = 3.0f;
             }",
        );
        run(&mut f).unwrap();
        verify(&f).unwrap();
        assert!(max_imm_preds(&f) <= 1);
    }

    #[test]
    fn barrier_in_loop_stays_intact() {
        let mut f = prepare(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) {
                     x[i] += 1.0f;
                     barrier(CLK_LOCAL_MEM_FENCE);
                 }
                 x[0] = 0.0f;
             }",
        );
        run(&mut f).unwrap();
        verify(&f).unwrap();
        assert!(max_imm_preds(&f) <= 1);
        // The loop must still exist.
        assert!(!crate::ir::loops::find_loops(&f).is_empty());
    }

    #[test]
    fn conditional_barrier_inside_loop() {
        let mut f = prepare(
            "__kernel void k(__global float *x, int n, int c) {
                 for (int i = 0; i < n; i++) {
                     if (c > 0) { barrier(CLK_LOCAL_MEM_FENCE); }
                     x[i] += 1.0f;
                 }
             }",
        );
        run(&mut f).unwrap();
        verify(&f).unwrap();
        assert!(max_imm_preds(&f) <= 1);
    }
}
