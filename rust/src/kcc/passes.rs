//! The kernel-compiler pass pipeline (Fig. 3).
//!
//! `compile_workgroup` takes a single-work-item kernel (frontend output)
//! and an enqueue-time local size and produces a [`WorkGroupFunction`]:
//!
//! * `reg_fn` — the region-formed function (barriers intact, privatisation
//!   flags set). This is what SPMD-style engines (the gang executor)
//!   consume: the separation the paper's §4 headline contribution is about.
//! * `loop_fn` — the work-item-loop materialised function (no barriers,
//!   `wi_loops` metadata). This is what serial/ILP engines (interpreter,
//!   TTA scheduler) consume.
//!
//! Pipeline: unify exits → canonicalise loops → horizontal inner-loop
//! parallelisation (§4.6, optional) → b-loop implicit barriers (§4.5) →
//! normalise/isolate barriers (§4.3) → tail duplication (§4.4) → region
//! formation (Alg. 1) → privatisation (§4.7) → WI-loop materialisation
//! (incl. peeling, Fig. 7).

use crate::cl::error::Result;
use crate::ir::cfg::unify_exits;
use crate::ir::func::Function;
use crate::ir::loops::canonicalize;

use super::barriers::normalize;
use super::bloops;
use super::horizontal;
use super::opt::{self, OptLevel, OptStats};
use super::privatize;
use super::regions::{check_regions, form_regions, Region};
use super::taildup;
use super::uniformity;
use super::wiloops;

/// Coarse device-class tag carried in [`CompileOptions`] so compiled
/// artifacts are keyed per device kind (pocl's on-disk kernel cache
/// likewise folds the target device into its build hash). Artifacts
/// compiled for one class are never served to another.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TargetKind {
    /// CPU interpreter devices (`basic`/`pthread`, any engine).
    Cpu,
    /// Static multi-issue TTA simulator (`ttasim`).
    Tta,
    /// SPMD offload devices (`pjrt`) — work-items execute device-side.
    Spmd,
}

/// Compilation options (per-device knobs).
///
/// The struct derives `Hash`/`Eq` and is hashed **in full** into every
/// specialisation-cache key (in-memory and on-disk): two devices that
/// disagree on *any* knob can never share a compiled artifact.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CompileOptions {
    /// Enable horizontal inner-loop parallelisation (§4.6). The §6.4 TTA
    /// experiment toggles this.
    pub horizontal: bool,
    /// Work dimension used by `get_work_dim()`.
    pub work_dim: u32,
    /// Skip work-group function generation (SPMD targets, Fig. 3) — only
    /// region formation runs; `loop_fn` equals the single-WI kernel with
    /// barriers stripped. Used when the device executes work-items itself.
    pub spmd: bool,
    /// Device class requesting the compile (cache-key component).
    pub target: TargetKind,
    /// SIMD gang width of the requesting engine, 0 when not ganged
    /// (cache-key component: a width-8 artifact slot is distinct from a
    /// width-4 one even though today's engines consume the same forms).
    pub gang_width: usize,
    /// Mid-level optimizer level (kcc/opt/), run before region formation.
    /// Cache-key component: artifacts compiled at different levels are
    /// distinct specialisations.
    pub opt_level: OptLevel,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            horizontal: true,
            work_dim: 1,
            spmd: false,
            target: TargetKind::Cpu,
            gang_width: 0,
            opt_level: OptLevel::from_env(),
        }
    }
}

/// Aggregate statistics from all passes — reported by the CLI and asserted
/// on by tests/benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompileStats {
    /// Parallel regions formed.
    pub regions: usize,
    /// Loops horizontally parallelised.
    pub horizontal_loops: usize,
    /// b-loops instrumented.
    pub b_loops: usize,
    /// Barriers that triggered tail duplication.
    pub taildup_barriers: usize,
    /// Blocks duplicated by tail duplication.
    pub taildup_blocks: usize,
    /// Slots privatised into context arrays.
    pub privatized_slots: usize,
    /// Slots merged as uniform.
    pub uniform_slots: usize,
    /// WI loops materialised.
    pub wi_loops: usize,
    /// Barriers requiring the peeling treatment.
    pub peeled_barriers: usize,
    /// Registers of `reg_fn` classified uniform (lane-invariant) — the
    /// values the vector engine keeps scalar, computed once per gang.
    pub uniform_regs: usize,
    /// Parallel regions containing at least one potentially-divergent
    /// branch (the regions where the vector engine may have to fall back
    /// to per-lane execution).
    pub divergent_regions: usize,
    /// Regions lowered to flattened bytecode (the rest run through the
    /// vector engine's region interpreter as fallback).
    pub bytecode_regions: usize,
    /// Superinstructions formed by the bytecode peephole fuser (each one
    /// retires ≥2 IR instructions per dispatch).
    pub bytecode_fused: usize,
    /// Total bytecode instructions across all lowered regions.
    pub bytecode_insts: usize,
    /// Bytecode regions lowered further to x86-64 machine code.
    pub jit_regions: usize,
    /// Static bytecode (super)instructions covered by jitted regions.
    pub jit_insts: usize,
    /// Bytecode regions the template JIT rejected (they keep running on
    /// the bytecode tier), or all of them when the tier is disabled or
    /// compiled out.
    pub jit_fallbacks: usize,
    /// Mid-level optimizer statistics (per-pass rewrite/removal counts).
    pub opt: OptStats,
}

/// A compiled work-group function, specialised for one local size (§4.1:
/// generation happens at enqueue time when the local size is known).
#[derive(Debug, Clone)]
pub struct WorkGroupFunction {
    /// Kernel name.
    pub name: String,
    /// Region-formed function: barriers intact, for region-level engines.
    pub reg_fn: Function,
    /// Parallel regions of `reg_fn`.
    pub regions: Vec<Region>,
    /// WI-loop materialised function: no barriers, `wi_loops` metadata.
    pub loop_fn: Function,
    /// The local size this work-group function is specialised for.
    pub local_size: [usize; 3],
    /// Per-register uniformity of `reg_fn`, indexed by register number
    /// (§4.6 exported as IR metadata): `true` = provably identical across
    /// all work-items, so SIMD mappings keep it scalar.
    pub reg_uniform: Vec<bool>,
    /// Per-region divergence verdict, indexed like `regions`: `true` when
    /// the region contains a branch whose condition could not be proven
    /// uniform (the vector engine's per-lane fallback may trigger there).
    pub region_divergent: Vec<bool>,
    /// Flattened bytecode for the uniform, legal regions of `reg_fn`
    /// (CPU targets only; `None` when nothing lowered). The threaded
    /// bytecode engine consumes this; other engines ignore it.
    pub bytecode: Option<crate::exec::bytecode::BytecodeProgram>,
    /// Jitted machine code for the bytecode regions (x86-64 hosts only;
    /// `None` when the tier is disabled, unsupported, or nothing
    /// lowered). Never serialised — rebuilt from `bytecode` on cache
    /// load. `Arc` because code buffers are not cloneable.
    pub jit: Option<std::sync::Arc<crate::exec::jit::JitProgram>>,
    /// Pass statistics.
    pub stats: CompileStats,
}

impl WorkGroupFunction {
    /// Total work-items per work-group.
    pub fn wg_size(&self) -> usize {
        self.local_size.iter().product()
    }

    /// Number of original kernel parameters (before the appended
    /// work-group context parameters of `loop_fn`).
    pub fn kernel_param_count(&self) -> usize {
        self.reg_fn.params.len()
    }
}

/// Run the full §4 pipeline.
pub fn compile_workgroup(
    kernel: &Function,
    local_size: [usize; 3],
    opts: &CompileOptions,
) -> Result<WorkGroupFunction> {
    let _compile_span = crate::trace::enabled().then(|| {
        crate::trace::span_args(
            crate::trace::CAT_COMPILER,
            format!("compile {}", kernel.name),
            vec![
                ("wg_size", crate::trace::ArgVal::u(local_size.iter().product::<usize>() as u64)),
                ("opt_level", crate::trace::ArgVal::u(opts.opt_level.as_u32() as u64)),
                ("gang_width", crate::trace::ArgVal::u(opts.gang_width as u64)),
            ],
        )
    });
    crate::trace::metrics::add("compiler.compiles", 1);
    let mut stats = CompileStats::default();
    let mut f = kernel.clone();

    // Mid-level optimizer: runs on the single-work-item kernel before any
    // region machinery, so every engine and both cached artifacts
    // (`reg_fn` and `loop_fn`) see the cleaned-up IR.
    stats.opt = opt::run(&mut f, opts.opt_level)?;

    // Target-independent parallel region formation.
    let region_span = crate::trace::span(crate::trace::CAT_COMPILER, "region_formation");
    unify_exits(&mut f);
    canonicalize(&mut f);
    if opts.horizontal && !opts.spmd {
        let h = horizontal::run(&mut f)?;
        stats.horizontal_loops = h.loops_parallelized;
    }
    stats.b_loops = bloops::run(&mut f)?;
    // Uniformity is analysed before barrier isolation mangles block
    // structure; slot ids are stable across the later passes.
    let uni = uniformity::analyze(&f);
    normalize(&mut f)?;
    let td = taildup::run(&mut f)?;
    stats.taildup_barriers = td.barriers_split;
    stats.taildup_blocks = td.blocks_duplicated;
    debug_assert!(taildup::max_imm_preds(&f) <= 1);
    let (regions, _graph) = form_regions(&f);
    stats.regions = regions.len();
    if cfg!(debug_assertions) {
        check_regions(&f, &regions).map_err(crate::cl::error::Error::Compile)?;
    }
    drop(region_span);
    let privatize_span = crate::trace::span(crate::trace::CAT_COMPILER, "privatize");
    let p = privatize::run(&mut f, &regions, &uni);
    stats.privatized_slots = p.privatized;
    stats.uniform_slots = p.merged_uniform;
    crate::ir::verify::verify(&f)?;
    drop(privatize_span);

    // Export the uniformity analysis on the final region form (§4.6 "kept
    // as metadata"): per-register classification plus a per-region
    // divergence verdict. Slot ids are stable across the barrier passes,
    // so the early slot-uniformity result carries over; the register table
    // must be recomputed here because tail duplication renamed registers.
    let reg_fn = f.clone();
    let reg_uniform = uniformity::classify_regs(&reg_fn, &uni.uniform_slots);
    let region_divergent: Vec<bool> = regions
        .iter()
        .map(|r| r.blocks.iter().any(|&b| !uni.uniform_branch(&reg_fn, b)))
        .collect();
    stats.uniform_regs = reg_uniform.iter().filter(|&&u| u).count();
    stats.divergent_regions = region_divergent.iter().filter(|&&d| d).count();

    // Target-specific lowering to the threaded-bytecode tier: flatten the
    // uniform, legal regions into pre-resolved, fused bytecode. CPU-only
    // (SPMD/TTA targets never execute through the bytecode engine).
    let bytecode = if opts.target == TargetKind::Cpu && !opts.spmd {
        let _bc_span = crate::trace::span(crate::trace::CAT_COMPILER, "bytecode_lower");
        let (prog, bstats) =
            crate::exec::bytecode::lower(&reg_fn, &regions, &region_divergent);
        stats.bytecode_regions = bstats.covered_regions;
        stats.bytecode_fused = bstats.fused;
        stats.bytecode_insts = bstats.insts;
        prog
    } else {
        None
    };

    // Target-specific parallel mapping: materialise WI loops.
    let wiloop_span = crate::trace::span(crate::trace::CAT_COMPILER, "wi_loops");
    let (loop_fn, wstats) = if opts.spmd {
        // SPMD devices run the single-WI function themselves; strip
        // barriers only (the device hardware provides their semantics).
        let mut g = f;
        for b in g.block_ids().collect::<Vec<_>>() {
            g.block_mut(b).insts.retain(|(_, i)| !i.is_barrier());
        }
        (g, wiloops::WiLoopStats::default())
    } else {
        wiloops::materialize(f, &regions, local_size, opts.work_dim)?
    };
    stats.wi_loops = wstats.loops_created;
    stats.peeled_barriers = wstats.peeled;
    drop(wiloop_span);

    let mut wgf = WorkGroupFunction {
        name: kernel.name.clone(),
        reg_fn,
        regions,
        loop_fn,
        local_size,
        reg_uniform,
        region_divergent,
        bytecode,
        jit: None,
        stats,
    };
    // Target-specific lowering, stage (b): template-jit the bytecode
    // regions to machine code (x86-64 hosts; no-op elsewhere).
    {
        let _jit_span = crate::trace::span(crate::trace::CAT_COMPILER, "jit_emit");
        crate::exec::jit::attach(&mut wgf, opts.gang_width);
    }
    Ok(wgf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::ir::verify::{barrier_count, verify};

    fn wg(src: &str, local: [usize; 3]) -> WorkGroupFunction {
        let m = compile(src).unwrap();
        let k = m.kernels.into_iter().next().unwrap();
        compile_workgroup(&k, local, &CompileOptions::default()).unwrap()
    }

    const VECADD: &str = "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
        size_t i = get_global_id(0);
        c[i] = a[i] + b[i];
    }";

    #[test]
    fn vecadd_pipeline() {
        let w = wg(VECADD, [8, 1, 1]);
        assert_eq!(w.stats.regions, 1);
        assert_eq!(w.stats.wi_loops, 1, "one x-dim WI loop");
        assert_eq!(barrier_count(&w.loop_fn), 0, "barriers stripped");
        assert!(barrier_count(&w.reg_fn) >= 2, "entry+exit barriers intact in region form");
        verify(&w.loop_fn).unwrap();
        assert_eq!(w.loop_fn.wi_loops.len(), 1);
        assert!(w.loop_fn.wi_loops[0].parallel);
        assert_eq!(w.loop_fn.wi_loops[0].trip_count, Some(8));
        // Uniformity metadata: the straight-line vecadd body has no
        // divergent region, and the pointer args yield uniform registers.
        assert_eq!(w.reg_uniform.len(), w.reg_fn.reg_count() as usize);
        assert_eq!(w.region_divergent.len(), w.regions.len());
        assert!(w.stats.uniform_regs > 0, "{:?}", w.stats);
        assert_eq!(w.stats.divergent_regions, 0, "{:?}", w.stats);
    }

    #[test]
    fn divergent_branch_marks_its_region() {
        let w = wg(
            "__kernel void k(__global float *x, uint w) {
                 float v = x[get_global_id(0)];
                 if (get_global_id(0) > (size_t)w) { v = v * 2.0f; }
                 x[get_global_id(0)] = v;
             }",
            [8, 1, 1],
        );
        assert!(w.stats.divergent_regions >= 1, "{:?}", w.stats);
        assert!(w.region_divergent.iter().any(|&d| d));
    }

    #[test]
    fn local_size_one_skips_wg_generation() {
        let w = wg(VECADD, [1, 1, 1]);
        assert_eq!(w.stats.wi_loops, 0);
        verify(&w.loop_fn).unwrap();
    }

    #[test]
    fn three_dim_local_size() {
        let w = wg(VECADD, [4, 2, 2]);
        assert_eq!(w.stats.wi_loops, 3, "x, y and z loops");
        verify(&w.loop_fn).unwrap();
    }

    #[test]
    fn barrier_kernel_two_nests() {
        let w = wg(
            "__kernel void k(__global float *x, __local float *t) {
                 size_t i = get_local_id(0);
                 t[i] = x[i];
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[i] = t[0] + (float)i;
             }",
            [16, 1, 1],
        );
        assert_eq!(w.stats.regions, 2);
        assert_eq!(w.stats.wi_loops, 2);
        // `i` crosses the barrier → context array of 16 u64s.
        let islot = w.loop_fn.slots.iter().find(|s| s.name == "i").unwrap();
        assert!(islot.privatized);
        assert_eq!(islot.count, 16);
        verify(&w.loop_fn).unwrap();
    }

    #[test]
    fn conditional_barrier_peels() {
        let w = wg(
            "__kernel void k(__global float *x, int c) {
                 if (c > 0) { barrier(CLK_LOCAL_MEM_FENCE); x[get_local_id(0)] = 1.0f; }
                 x[0] = 2.0f;
             }",
            [4, 1, 1],
        );
        assert!(w.stats.peeled_barriers >= 1, "{:?}", w.stats);
        assert!(w.stats.taildup_barriers >= 1);
        verify(&w.loop_fn).unwrap();
        assert_eq!(barrier_count(&w.loop_fn), 0);
    }

    #[test]
    fn dct_like_horizontal_parallelization() {
        let w = wg(
            "__kernel void dctish(__global float *out, __global float *in, uint blockWidth) {
                 uint i = (uint)get_local_id(0);
                 float acc = 0.0f;
                 for (uint k = 0u; k < blockWidth; k++) {
                     acc += in[k * blockWidth + i];
                 }
                 out[i] = acc;
             }",
            [8, 1, 1],
        );
        assert_eq!(w.stats.horizontal_loops, 1);
        // acc crosses regions now → context array.
        let acc = w.loop_fn.slots.iter().find(|s| s.name == "acc").unwrap();
        assert!(acc.privatized, "horizontal parallelisation privatises the accumulator");
        verify(&w.loop_fn).unwrap();
    }

    #[test]
    fn spmd_mode_skips_materialization() {
        let opts = CompileOptions { spmd: true, ..Default::default() };
        let m = compile(VECADD).unwrap();
        let k = m.kernels.into_iter().next().unwrap();
        let w = compile_workgroup(&k, [64, 1, 1], &opts).unwrap();
        assert_eq!(w.stats.wi_loops, 0);
        assert_eq!(barrier_count(&w.loop_fn), 0);
    }

    #[test]
    fn loop_with_barrier_compiles() {
        let w = wg(
            "__kernel void k(__global float *x, __local float *t, int n) {
                 for (int i = 0; i < n; i++) {
                     t[get_local_id(0)] = x[i];
                     barrier(CLK_LOCAL_MEM_FENCE);
                     x[i] = t[0];
                 }
             }",
            [4, 1, 1],
        );
        assert!(w.stats.b_loops >= 1);
        verify(&w.loop_fn).unwrap();
        assert_eq!(barrier_count(&w.loop_fn), 0);
    }
}
