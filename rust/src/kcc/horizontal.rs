//! Horizontal inner-loop parallelisation (§4.6).
//!
//! Inner loops with **uniform** trip counts and non-divergent entry are
//! treated "like a loop with a barrier inside": the b-loop implicit
//! barriers are inserted, which — after region formation and work-item
//! loop generation — effectively interchanges the work-item loop with the
//! inner loop (Fig. 9 → Fig. 10). The legality condition is exactly the
//! paper's: the loop exit condition and the predicates leading to the loop
//! entry must not depend on the work-item id.

use crate::cl::error::Result;
use crate::ir::func::Function;
use crate::ir::inst::Term;
use crate::ir::loops::find_loops;

use super::bloops::instrument_loop;
use super::uniformity::{analyze, Uniformity};

/// Statistics for reporting/tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct HorizontalStats {
    /// Loops examined.
    pub loops_seen: usize,
    /// Loops horizontally parallelised (implicit barriers inserted).
    pub loops_parallelized: usize,
    /// Loops rejected because of divergent exit conditions or entry.
    pub loops_divergent: usize,
}

/// Run the pass. `canonicalize` must have run; barriers may or may not be
/// present (loops already containing barriers are left to `bloops`).
pub fn run(f: &mut Function) -> Result<HorizontalStats> {
    let mut stats = HorizontalStats::default();
    let u = analyze(f);
    let loops = find_loops(f);
    // Instrument innermost-qualifying loops first is unnecessary: the
    // barrier insertion points of different loops never clash after
    // canonicalisation (distinct preheaders/latches), and instrumenting a
    // loop makes enclosing loops b-loops, handled by `bloops` later.
    let mut chosen = Vec::new();
    for l in &loops {
        stats.loops_seen += 1;
        if l.blocks.iter().any(|&b| f.block(b).has_barrier()) {
            continue; // already a b-loop; bloops will instrument
        }
        if !legal(f, &u, l) {
            stats.loops_divergent += 1;
            continue;
        }
        chosen.push(l.clone());
    }
    for l in &chosen {
        instrument_loop(f, l)?;
        stats.loops_parallelized += 1;
    }
    Ok(stats)
}

/// The §4.6 legality test: the loop's exit conditions are uniform, and the
/// path to the loop entry is not divergence-controlled, so inserting the
/// implicit barriers cannot deadlock/diverge work-items.
fn legal(f: &Function, u: &Uniformity, l: &crate::ir::loops::Loop) -> bool {
    // Every exiting block's branch must be uniform.
    for &e in &l.exiting {
        if matches!(f.block(e).term, Term::Br { .. }) && !u.uniform_branch(f, e) {
            return false;
        }
    }
    // All in-loop branches must be uniform as well: a divergent branch
    // inside the loop body would put the implicit latch barrier under
    // divergent control. (pocl's uniformity analysis makes the same
    // conservative choice for the loop as a whole.)
    for &b in &l.blocks {
        if matches!(f.block(b).term, Term::Br { .. }) && !u.uniform_branch(f, b) {
            return false;
        }
    }
    // The loop entry must not be divergence-controlled.
    match l.preheader(f) {
        Some(p) if !u.divergent_blocks.contains(&p) => {}
        _ => return false,
    }
    if u.divergent_blocks.contains(&l.header) {
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::ir::cfg::unify_exits;
    use crate::ir::loops::canonicalize;
    use crate::ir::verify::{barrier_count, verify};

    fn prepared(src: &str) -> Function {
        let m = compile(src).unwrap();
        let mut f = m.kernels.into_iter().next().unwrap();
        unify_exits(&mut f);
        canonicalize(&mut f);
        f
    }

    #[test]
    fn uniform_inner_loop_is_parallelized() {
        // The DCT shape from Fig. 9: inner loop with an argument-provided
        // trip count.
        let mut f = prepared(
            "__kernel void dctish(__global float *out, __global float *in, uint blockWidth) {
                 uint i = (uint)get_local_id(0);
                 float acc = 0.0f;
                 for (uint k = 0u; k < blockWidth; k++) {
                     acc += in[k * blockWidth + i];
                 }
                 out[i] = acc;
             }",
        );
        let stats = run(&mut f).unwrap();
        verify(&f).unwrap();
        assert_eq!(stats.loops_parallelized, 1, "{stats:?}");
        assert_eq!(barrier_count(&f), 3);
    }

    #[test]
    fn divergent_loop_is_rejected() {
        // BinarySearch shape: trip count depends on data loaded per WI.
        let mut f = prepared(
            "__kernel void bs(__global float *x) {
                 uint i = (uint)get_global_id(0);
                 uint n = (uint)x[i];
                 float acc = 0.0f;
                 for (uint k = 0u; k < n; k++) { acc += 1.0f; }
                 x[i] = acc;
             }",
        );
        let stats = run(&mut f).unwrap();
        assert_eq!(stats.loops_parallelized, 0);
        assert_eq!(stats.loops_divergent, 1);
        assert_eq!(barrier_count(&f), 0);
    }

    #[test]
    fn loop_under_divergent_if_is_rejected() {
        let mut f = prepared(
            "__kernel void k(__global float *x, uint n) {
                 uint i = (uint)get_global_id(0);
                 if (i < n / 2u) {
                     float acc = 0.0f;
                     for (uint k = 0u; k < n; k++) { acc += x[k]; }
                     x[i] = acc;
                 }
             }",
        );
        let stats = run(&mut f).unwrap();
        assert_eq!(stats.loops_parallelized, 0);
    }

    #[test]
    fn loop_with_divergent_body_branch_is_rejected() {
        let mut f = prepared(
            "__kernel void k(__global float *x, uint n) {
                 uint i = (uint)get_global_id(0);
                 float acc = 0.0f;
                 for (uint k = 0u; k < n; k++) {
                     if (x[k * n + i] > 0.0f) { acc += 1.0f; }
                 }
                 x[i] = acc;
             }",
        );
        let stats = run(&mut f).unwrap();
        assert_eq!(stats.loops_parallelized, 0, "divergent in-body branch");
    }

    #[test]
    fn barrier_loops_are_left_to_bloops() {
        let mut f = prepared(
            "__kernel void k(__global float *x, uint n) {
                 for (uint k = 0u; k < n; k++) {
                     barrier(CLK_LOCAL_MEM_FENCE);
                     x[k] = 1.0f;
                 }
             }",
        );
        let stats = run(&mut f).unwrap();
        assert_eq!(stats.loops_parallelized, 0);
        assert_eq!(barrier_count(&f), 1, "untouched");
    }
}
