//! Work-item loop materialisation (§4.1/§4.3 Fig. 4, §4.4 Fig. 7).
//!
//! Turns the region-formed function into a **work-group function**: each
//! parallel region is wrapped in (up to three nested) work-item loops with
//! constant trip counts (the local size is known at enqueue time, §4.1).
//! The loops are recorded in `Function::wi_loops` — the metadata that later
//! parallel-mapping stages (the gang executor, the TTA scheduler) consume
//! without having to re-prove iteration independence.
//!
//! Barriers whose region set diverges (conditional barriers after tail
//! duplication) get the **loop peeling** treatment of Fig. 7: the first
//! work-item executes a peeled copy of the shared region code; the barrier
//! it reaches selects which region's work-item loop the remaining
//! work-items execute, with the barrier-selecting branches removed from the
//! loop bodies.
//!
//! Work-item geometry builtins are rewritten here: `get_local_id` reads the
//! loop induction slots; group ids / counts / offsets become appended
//! work-group function parameters (the paper's "additional struct function
//! argument ... that contains the work-space coordinates").

use std::collections::{HashMap, HashSet};

use crate::cl::error::{Error, Result};
use crate::ir::cfg::replicate_cfg;
use crate::ir::func::{Function, Param, WiLoopMeta};
use crate::ir::inst::{BinOp, BlockId, Imm, Inst, Operand, Reg, SlotId, Term, WiFn};
use crate::ir::types::{AddrSpace, Scalar, Type};

use super::regions::Region;

/// Number of appended work-group context parameters:
/// `group_id[3] ++ num_groups[3] ++ global_offset[3]`.
pub const WG_EXTRA_PARAMS: usize = 9;

/// Index helpers for the appended parameters.
pub fn wg_param_base(kernel_params: usize) -> usize {
    kernel_params
}

/// Statistics for reporting/tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct WiLoopStats {
    /// Loop nests created.
    pub loops_created: usize,
    /// Barriers that required peeling.
    pub peeled: usize,
    /// Context-array accesses rewritten.
    pub ctx_rewrites: usize,
}

/// Materialise work-item loops. `f` must be normalised + tail-duplicated,
/// with privatisation flags set. `local` is the enqueue-time local size.
/// Returns the transformed **work-group function** (the input is consumed).
pub fn materialize(
    mut f: Function,
    regions: &[Region],
    local: [usize; 3],
    work_dim: u32,
) -> Result<(Function, WiLoopStats)> {
    let mut stats = WiLoopStats::default();
    let kernel_params = f.params.len();
    // Appended work-group context parameters.
    for name in ["group_id", "num_groups", "global_offset"] {
        for d in 0..3 {
            f.params.push(Param {
                name: format!("__pocl_{name}_{d}"),
                ty: Type::U64,
                is_local_buf: false,
                auto_local_size: None,
            });
        }
    }
    // Work-item index slots.
    let wi: [SlotId; 3] = [
        f.add_slot("__pocl_wi_0", Type::U64, 1),
        f.add_slot("__pocl_wi_1", Type::U64, 1),
        f.add_slot("__pocl_wi_2", Type::U64, 1),
    ];
    let total: usize = local.iter().product();

    // Group regions by their opening barrier.
    let mut by_pre: HashMap<BlockId, Vec<&Region>> = HashMap::new();
    for r in regions {
        by_pre.entry(r.pre).or_default().push(r);
    }
    let mut pres: Vec<BlockId> = by_pre.keys().copied().collect();
    pres.sort();

    for pre in pres {
        let rs = &by_pre[&pre];
        if total == 1 {
            // Local size 1: the whole work-group function generation is a
            // no-op (§4.1/Fig. 3: "or the local size is one, this step is
            // skipped"); barriers are stripped below.
            continue;
        }
        if rs.len() == 1 && !rs[0].needs_peeling {
            let r = rs[0];
            if r.blocks.is_empty() {
                continue; // adjacent barriers
            }
            let entry = single_succ(&f, pre)?;
            if !r.contains(entry) {
                return Err(Error::compile(format!(
                    "region {} entry mismatch at barrier bb{}",
                    r.id, pre.0
                )));
            }
            let nest = build_loop_nest(&mut f, &wi, local, r.id, false, &mut stats);
            let blocks = r.blocks.clone();
            wire_region(&mut f, &wi, local, r.id, pre, entry, &blocks, r.post, &nest);
        } else {
            // Peeling (Fig. 7). The union of sibling regions is the shared
            // code the first work-item executes.
            stats.peeled += 1;
            let mut union: Vec<BlockId> = rs.iter().flat_map(|r| r.blocks.iter().copied()).collect();
            union.sort();
            union.dedup();
            if union.is_empty() {
                continue;
            }
            let entry = single_succ(&f, pre)?;
            // The peeled copy is work-item (0,0,0): reset the wi slots at
            // the opening barrier (a previous region's loop left them at
            // the local size).
            for d in 0..3 {
                f.block_mut(pre).insts.push((
                    None,
                    Inst::Store { ty: Type::U64, ptr: Operand::Slot(wi[d]), val: Operand::cu64(0) },
                ));
            }
            // Peeled copy for work-item 0.
            let peel_map = replicate_cfg(&mut f, &union);
            f.set_term(pre, Term::Jump(peel_map[&entry]));
            // Per sibling region: a work-item loop over a branch-cleaned
            // copy, entered from the peeled copy's edge into r.post.
            for r in rs {
                // The loop body copy.
                let rc_map = if r.blocks.is_empty() {
                    HashMap::new()
                } else {
                    replicate_cfg(&mut f, &r.blocks)
                };
                let rc_set: HashSet<BlockId> = rc_map.values().copied().collect();
                // Remove barrier-selecting branches: any branch in the copy
                // with exactly one target inside {copy ∪ post} becomes a
                // jump to that target.
                for &cb in rc_map.values() {
                    if let Term::Br { t, f: fb, .. } = f.block(cb).term.clone() {
                        let t_ok = rc_set.contains(&t) || t == r.post;
                        let f_ok = rc_set.contains(&fb) || fb == r.post;
                        match (t_ok, f_ok) {
                            (true, false) => f.set_term(cb, Term::Jump(t)),
                            (false, true) => f.set_term(cb, Term::Jump(fb)),
                            (true, true) => {}
                            (false, false) => {
                                return Err(Error::compile(format!(
                                    "peeled region {}: block bb{} has no valid successor",
                                    r.id, cb.0
                                )))
                            }
                        }
                    }
                }
                // Setup block the peeled copy branches to when it reaches
                // this region's closing barrier.
                let setup = f.add_block(format!("peel.setup.r{}", r.id));
                if r.blocks.is_empty() {
                    f.set_term(setup, Term::Jump(r.post));
                } else {
                    let nest = build_loop_nest(&mut f, &wi, local, r.id, true, &mut stats);
                    let rc_entry = rc_map[&entry];
                    let rc_blocks: Vec<BlockId> = rc_map.values().copied().collect();
                    wire_region(&mut f, &wi, local, r.id, setup, rc_entry, &rc_blocks, r.post, &nest);
                }
                // Redirect the peeled copy's edges into r.post → setup.
                for &pb in peel_map.values() {
                    let mut term = f.block(pb).term.clone();
                    term.map_succs(|s| if s == r.post { setup } else { s });
                    f.block_mut(pb).term = term;
                }
            }
        }
    }

    // Strip barriers (the loop structure now carries their semantics).
    for b in f.block_ids().collect::<Vec<_>>() {
        f.block_mut(b).insts.retain(|(_, i)| !i.is_barrier());
    }

    // Prologue: zero the work-item index slots at function entry.
    let entry = f.entry;
    for d in (0..3).rev() {
        f.block_mut(entry).insts.insert(
            0,
            (None, Inst::Store { ty: Type::U64, ptr: Operand::Slot(wi[d]), val: Operand::cu64(0) }),
        );
    }

    // Rewrite work-item builtins and privatized slot accesses.
    rewrite_blocks(&mut f, &wi, local, work_dim, kernel_params, total, &mut stats)?;

    // Expand privatized slots into context arrays.
    for slot in f.slots.iter_mut() {
        if slot.privatized {
            slot.count *= total;
        }
    }

    crate::ir::verify::verify(&f)
        .map_err(|e| Error::Compile(format!("wiloops produced invalid IR: {e}")))?;
    Ok((f, stats))
}

fn single_succ(f: &Function, b: BlockId) -> Result<BlockId> {
    let s = f.succs(b);
    if s.len() != 1 {
        return Err(Error::compile(format!("barrier block bb{} has {} successors", b.0, s.len())));
    }
    Ok(s[0])
}

/// One dimension of a loop nest.
struct NestDim {
    dim: u32,
    init: BlockId,
    header: BlockId,
    latch: BlockId,
}

/// The created loop nest: dims ordered outermost→innermost, plus the block
/// the region body must eventually flow into (innermost latch) and where
/// the nest exits (filled by `wire_region`).
struct Nest {
    dims: Vec<NestDim>,
}

/// Build init/header/latch blocks for every dimension with size > 1,
/// z (2) outermost → x (0) innermost. `skip_first` makes the innermost
/// loop start at 1 when all outer indices are 0 (the peeled iteration).
fn build_loop_nest(
    f: &mut Function,
    wi: &[SlotId; 3],
    local: [usize; 3],
    region_id: usize,
    skip_first: bool,
    stats: &mut WiLoopStats,
) -> Nest {
    let mut dims = Vec::new();
    for d in [2u32, 1, 0] {
        if local[d as usize] > 1 {
            let init = f.add_block(format!("wi.init.r{region_id}.d{d}"));
            let header = f.add_block(format!("wi.head.r{region_id}.d{d}"));
            let latch = f.add_block(format!("wi.latch.r{region_id}.d{d}"));
            dims.push(NestDim { dim: d, init, header, latch });
        }
    }
    // Fill init/latch/header contents.
    for i in 0..dims.len() {
        let d = dims[i].dim;
        let slot = wi[d as usize];
        let innermost = i + 1 == dims.len();
        // init: wi_d = 0 (or the skip-first select on the innermost).
        let init_bb = dims[i].init;
        let init_val = if skip_first && innermost {
            // all outer dims zero → start at 1.
            let mut cond = Operand::cbool(true);
            for outer in dims.iter().take(i) {
                let v = f.push_val(
                    init_bb,
                    Inst::Load { ty: Type::U64, ptr: Operand::Slot(wi[outer.dim as usize]) },
                );
                let z = f.push_val(
                    init_bb,
                    Inst::Bin { op: BinOp::Eq, ty: Type::U64, a: Operand::Reg(v), b: Operand::cu64(0) },
                );
                cond = if matches!(cond, Operand::Imm(Imm::Int(1, Scalar::Bool))) {
                    Operand::Reg(z)
                } else {
                    Operand::Reg(f.push_val(
                        init_bb,
                        Inst::Bin { op: BinOp::LAnd, ty: Type::BOOL, a: cond, b: Operand::Reg(z) },
                    ))
                };
            }
            let sel = f.push_val(
                init_bb,
                Inst::Select { ty: Type::U64, cond, a: Operand::cu64(1), b: Operand::cu64(0) },
            );
            Operand::Reg(sel)
        } else {
            Operand::cu64(0)
        };
        f.block_mut(init_bb)
            .insts
            .push((None, Inst::Store { ty: Type::U64, ptr: Operand::Slot(slot), val: init_val }));
        // latch: wi_d += 1; jump header.
        let latch_bb = dims[i].latch;
        let v = f.push_val(latch_bb, Inst::Load { ty: Type::U64, ptr: Operand::Slot(slot) });
        let v1 = f.push_val(
            latch_bb,
            Inst::Bin { op: BinOp::Add, ty: Type::U64, a: Operand::Reg(v), b: Operand::cu64(1) },
        );
        f.block_mut(latch_bb).insts.push((
            None,
            Inst::Store { ty: Type::U64, ptr: Operand::Slot(slot), val: Operand::Reg(v1) },
        ));
        f.set_term(latch_bb, Term::Jump(dims[i].header));
        stats.loops_created += 1;
    }
    Nest { dims }
}

/// Wire a loop nest around a region: `from` (a barrier or setup block)
/// jumps into the nest, region exits to `post` are retargeted to the
/// innermost latch, and headers chain init/latch blocks.
#[allow(clippy::too_many_arguments)]
fn wire_region(
    f: &mut Function,
    wi: &[SlotId; 3],
    local: [usize; 3],
    region_id: usize,
    from: BlockId,
    entry: BlockId,
    region_blocks: &[BlockId],
    post: BlockId,
    nest: &Nest,
) {
    let n = nest.dims.len();
    let first = nest.dims.first().map(|d| d.init).unwrap_or(post);
    f.set_term(from, Term::Jump(first));
    if n == 0 {
        return;
    }
    // Header conditions and chaining.
    for i in 0..n {
        let d = nest.dims[i].dim;
        let header = nest.dims[i].header;
        // header: v = load wi_d; c = v < L_d; br c, body, exit
        let body = if i + 1 < n { nest.dims[i + 1].init } else { entry };
        let exit = if i == 0 { post } else { nest.dims[i - 1].latch };
        let v = f.push_val(header, Inst::Load { ty: Type::U64, ptr: Operand::Slot(wi[d as usize]) });
        let lim = Operand::cu64(local[d as usize] as u64);
        let c = f.push_val(
            header,
            Inst::Bin { op: BinOp::Lt, ty: Type::U64, a: Operand::Reg(v), b: lim },
        );
        f.set_term(header, Term::Br { cond: Operand::Reg(c), t: body, f: exit });
        f.set_term(nest.dims[i].init, Term::Jump(header));
    }
    // Region exits → innermost latch.
    let inner_latch = nest.dims[n - 1].latch;
    for &b in region_blocks {
        let mut term = f.block(b).term.clone();
        term.map_succs(|s| if s == post { inner_latch } else { s });
        f.block_mut(b).term = term;
    }
    // Record the parallel-loop metadata (the §4.1 "annotated using LLVM
    // metadata" analog).
    for dim in &nest.dims {
        f.wi_loops.push(WiLoopMeta {
            region: region_id,
            dim: dim.dim,
            header: dim.header,
            latch: dim.latch,
            trip_count: Some(local[dim.dim as usize]),
            parallel: true,
        });
    }
}

/// Rewrite `Wi` builtins and privatized-slot accesses in every block.
#[allow(clippy::too_many_arguments)]
fn rewrite_blocks(
    f: &mut Function,
    wi: &[SlotId; 3],
    local: [usize; 3],
    work_dim: u32,
    kernel_params: usize,
    total: usize,
    stats: &mut WiLoopStats,
) -> Result<()> {
    let base = wg_param_base(kernel_params) as u32;
    let privatized: Vec<bool> = f.slots.iter().map(|s| s.privatized).collect();
    let counts: Vec<usize> = f.slots.iter().map(|s| s.count).collect();
    let _ = total;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let old = std::mem::take(&mut f.block_mut(bb).insts);
        let mut new: Vec<(Option<Reg>, Inst)> = Vec::with_capacity(old.len());
        // Cache the flat work-item index per block.
        let mut flat: Option<Reg> = None;
        for (def, inst) in old {
            match inst {
                Inst::Wi { func, dim } => {
                    let d = dim.min(2) as usize;
                    let out = def.expect("Wi defines a value");
                    match func {
                        WiFn::LocalId => {
                            new.push((
                                Some(out),
                                Inst::Load { ty: Type::U64, ptr: Operand::Slot(wi[d]) },
                            ));
                        }
                        WiFn::GroupId => new.push((Some(out), identity(Operand::Arg(base + d as u32)))),
                        WiFn::NumGroups => {
                            new.push((Some(out), identity(Operand::Arg(base + 3 + d as u32))))
                        }
                        WiFn::GlobalOffset => {
                            new.push((Some(out), identity(Operand::Arg(base + 6 + d as u32))))
                        }
                        WiFn::LocalSize => {
                            new.push((Some(out), identity(Operand::cu64(local[d] as u64))))
                        }
                        WiFn::GlobalSize => new.push((
                            Some(out),
                            Inst::Bin {
                                op: BinOp::Mul,
                                ty: Type::U64,
                                a: Operand::Arg(base + 3 + d as u32),
                                b: Operand::cu64(local[d] as u64),
                            },
                        )),
                        WiFn::WorkDim => {
                            new.push((Some(out), identity(Operand::cu64(work_dim as u64))))
                        }
                        WiFn::GlobalId => {
                            // group_id*L + wi + offset
                            let t1 = f.fresh_reg();
                            new.push((
                                Some(t1),
                                Inst::Bin {
                                    op: BinOp::Mul,
                                    ty: Type::U64,
                                    a: Operand::Arg(base + d as u32),
                                    b: Operand::cu64(local[d] as u64),
                                },
                            ));
                            let t2 = f.fresh_reg();
                            new.push((
                                Some(t2),
                                Inst::Load { ty: Type::U64, ptr: Operand::Slot(wi[d]) },
                            ));
                            let t3 = f.fresh_reg();
                            new.push((
                                Some(t3),
                                Inst::Bin {
                                    op: BinOp::Add,
                                    ty: Type::U64,
                                    a: Operand::Reg(t1),
                                    b: Operand::Reg(t2),
                                },
                            ));
                            new.push((
                                Some(out),
                                Inst::Bin {
                                    op: BinOp::Add,
                                    ty: Type::U64,
                                    a: Operand::Reg(t3),
                                    b: Operand::Arg(base + 6 + d as u32),
                                },
                            ));
                        }
                    }
                }
                mut other => {
                    // Privatized slot rewrite.
                    let mut needs: Vec<SlotId> = Vec::new();
                    for op in other.operands() {
                        if let Operand::Slot(s) = op {
                            if privatized[s.0 as usize] {
                                needs.push(s);
                            }
                        }
                    }
                    if !needs.is_empty() {
                        let fl = match flat {
                            Some(r) => r,
                            None => {
                                let r = emit_flat(f, &mut new, wi, local);
                                flat = Some(r);
                                r
                            }
                        };
                        stats.ctx_rewrites += 1;
                        rewrite_private_access(f, &mut new, &mut other, fl, &privatized, &counts);
                    }
                    new.push((def, other));
                }
            }
        }
        f.block_mut(bb).insts = new;
    }
    Ok(())
}

fn identity(op: Operand) -> Inst {
    Inst::Bin { op: BinOp::Add, ty: Type::U64, a: op, b: Operand::cu64(0) }
}

/// Emit `flat = (wi2*L1 + wi1)*L0 + wi0` into `new`, returning the reg.
fn emit_flat(
    f: &mut Function,
    new: &mut Vec<(Option<Reg>, Inst)>,
    wi: &[SlotId; 3],
    local: [usize; 3],
) -> Reg {
    let mut acc: Option<Reg> = None;
    for d in [2usize, 1, 0] {
        let v = f.fresh_reg();
        new.push((Some(v), Inst::Load { ty: Type::U64, ptr: Operand::Slot(wi[d]) }));
        acc = Some(match acc {
            None => v,
            Some(prev) => {
                let m = f.fresh_reg();
                new.push((
                    Some(m),
                    Inst::Bin {
                        op: BinOp::Mul,
                        ty: Type::U64,
                        a: Operand::Reg(prev),
                        b: Operand::cu64(local[d] as u64),
                    },
                ));
                let a = f.fresh_reg();
                new.push((
                    Some(a),
                    Inst::Bin {
                        op: BinOp::Add,
                        ty: Type::U64,
                        a: Operand::Reg(m),
                        b: Operand::Reg(v),
                    },
                ));
                a
            }
        });
    }
    acc.unwrap()
}

/// Rewrite one instruction's accesses to privatized slots: direct
/// `Load`/`Store` pointers become `Gep(slot, flat*count)`, `Gep` bases get
/// `flat*count` added to the index.
fn rewrite_private_access(
    f: &mut Function,
    new: &mut Vec<(Option<Reg>, Inst)>,
    inst: &mut Inst,
    flat: Reg,
    privatized: &[bool],
    counts: &[usize],
) {
    // Helper: offset register = flat * count (count==1 → flat itself).
    let mut offset_of = |f: &mut Function, new: &mut Vec<(Option<Reg>, Inst)>, s: SlotId| -> Reg {
        let count = counts[s.0 as usize];
        if count == 1 {
            flat
        } else {
            let m = f.fresh_reg();
            new.push((
                Some(m),
                Inst::Bin {
                    op: BinOp::Mul,
                    ty: Type::U64,
                    a: Operand::Reg(flat),
                    b: Operand::cu64(count as u64),
                },
            ));
            m
        }
    };
    match inst {
        Inst::Load { ty, ptr } | Inst::Store { ty, ptr, .. } => {
            if let Operand::Slot(s) = *ptr {
                if privatized[s.0 as usize] {
                    let off = offset_of(f, new, s);
                    let p = f.fresh_reg();
                    new.push((
                        Some(p),
                        Inst::Gep { elem: ty.clone(), base: Operand::Slot(s), idx: Operand::Reg(off) },
                    ));
                    *ptr = Operand::Reg(p);
                }
            }
        }
        Inst::Gep { base, idx, elem: _ } => {
            if let Operand::Slot(s) = *base {
                if privatized[s.0 as usize] {
                    let off = offset_of(f, new, s);
                    let ni = f.fresh_reg();
                    new.push((
                        Some(ni),
                        Inst::Bin { op: BinOp::Add, ty: Type::U64, a: Operand::Reg(off), b: *idx },
                    ));
                    *idx = Operand::Reg(ni);
                }
            }
        }
        _ => {}
    }
}

