//! Private-variable handling (§4.7): context arrays for region-crossing
//! variables and uniform-variable merging.
//!
//! A slot whose lifetime is contained in a single parallel region stays a
//! plain per-iteration scalar (Fig. 11's `a`). A slot that is live across
//! regions (Fig. 11's `b`) is marked `privatized`: the work-item loop
//! materialiser expands it into a **context array** with one element per
//! work-item. Uniform, non-accumulating slots are *merged* instead — a
//! single shared copy (the paper's Loop-Invariant-Code-Motion-like
//! optimisation), which the engines may store/execute once per gang.

use std::collections::HashSet;

use crate::ir::func::Function;
use crate::ir::inst::{Inst, Operand, SlotId};

use super::regions::Region;
use super::uniformity::Uniformity;

/// Statistics for reporting/tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrivatizeStats {
    /// Slots expanded into context arrays.
    pub privatized: usize,
    /// Slots merged as shared uniform values.
    pub merged_uniform: usize,
    /// Slots left as region-local scalars.
    pub region_local: usize,
}

/// Classify every slot of `f`, setting `privatized`/`uniform` flags.
pub fn run(f: &mut Function, regions: &[Region], u: &Uniformity) -> PrivatizeStats {
    let mut stats = PrivatizeStats::default();
    let nslots = f.slots.len();
    // Which regions touch each slot?
    let mut touched: Vec<HashSet<usize>> = vec![HashSet::new(); nslots];
    for r in regions {
        for &b in &r.blocks {
            for (_, inst) in &f.block(b).insts {
                for op in inst.operands() {
                    if let Operand::Slot(s) = op {
                        touched[s.0 as usize].insert(r.id);
                    }
                }
                // Gep bases are covered by operands(); nothing else
                // references slots.
                let _ = inst;
            }
        }
    }
    for (i, slot) in f.slots.iter_mut().enumerate() {
        let uniform = u.uniform_slots[i] && !u.accumulating_slots[i];
        if uniform {
            slot.uniform = true;
            stats.merged_uniform += 1;
            continue;
        }
        if touched[i].len() > 1 {
            slot.privatized = true;
            stats.privatized += 1;
        } else {
            stats.region_local += 1;
        }
    }
    stats
}

/// Test helper: names of privatized slots.
pub fn privatized_names(f: &Function) -> Vec<&str> {
    f.slots.iter().filter(|s| s.privatized).map(|s| s.name.as_str()).collect()
}

/// Test helper: verify no instruction references an out-of-range slot after
/// context-array expansion (paranoia check used by the pipeline).
pub fn check_slot_refs(f: &Function) -> Result<(), String> {
    for b in f.block_ids() {
        for (_, inst) in &f.block(b).insts {
            for op in inst.operands() {
                if let Operand::Slot(s) = op {
                    if s.0 as usize >= f.slots.len() {
                        return Err(format!("slot {} out of range", s.0));
                    }
                }
            }
            let _ = inst;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::kcc::barriers::normalize;
    use crate::kcc::regions::form_regions;
    use crate::kcc::uniformity::analyze;

    fn classify(src: &str) -> Function {
        let m = compile(src).unwrap();
        let mut f = m.kernels.into_iter().next().unwrap();
        let u = analyze(&f); // uniformity on the pre-normalized body
        normalize(&mut f).unwrap();
        crate::kcc::taildup::run(&mut f).unwrap();
        let (regions, _) = form_regions(&f);
        run(&mut f, &regions, &u);
        f
    }

    #[test]
    fn fig11_lifespans() {
        // Variable a: used only before the barrier. Variable b: crosses it.
        let f = classify(
            "__kernel void k(__global float *x, __global float *y) {
                 size_t i = get_global_id(0);
                 float a = x[i] * 2.0f;
                 float b = x[i] + a;
                 x[i] = a;
                 barrier(CLK_LOCAL_MEM_FENCE);
                 y[i] = b;
             }",
        );
        let a = f.slots.iter().find(|s| s.name == "a").unwrap();
        let b = f.slots.iter().find(|s| s.name == "b").unwrap();
        assert!(!a.privatized, "a is region-local (Fig. 11)");
        assert!(b.privatized, "b crosses the barrier (Fig. 11)");
        // i crosses the barrier too and is divergent.
        let i = f.slots.iter().find(|s| s.name == "i").unwrap();
        assert!(i.privatized);
    }

    #[test]
    fn uniform_values_are_merged_not_privatized() {
        let f = classify(
            "__kernel void k(__global float *x, uint w) {
                 uint lim = w * 2u;
                 x[get_local_id(0)] = (float)lim;
                 barrier(CLK_LOCAL_MEM_FENCE);
                 x[get_local_id(0) + 1u] = (float)lim;
             }",
        );
        let lim = f.slots.iter().find(|s| s.name == "lim").unwrap();
        assert!(lim.uniform, "uniform value shared across regions is merged");
        assert!(!lim.privatized);
    }

    #[test]
    fn kernel_without_barriers_has_no_context_arrays() {
        let f = classify(
            "__kernel void k(__global float *x) {
                 size_t i = get_global_id(0);
                 float t = x[i] * 2.0f;
                 x[i] = t;
             }",
        );
        assert!(privatized_names(&f).is_empty());
    }
}
