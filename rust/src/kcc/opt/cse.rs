//! Block-local common-subexpression elimination.
//!
//! Pure instructions (arithmetic, casts, GEPs, work-item queries, math
//! builtins, vector shuffles — everything except loads, stores, barriers
//! and markers) are keyed structurally; a repeated computation is
//! replaced by the first definition's register. The value table and any
//! register-valued substitutions are discarded at barriers, so no value
//! is reused across a barrier boundary.
//!
//! Floating-point immediates are keyed by **bit pattern** (`-0.0` and
//! `0.0` stay distinct, NaNs compare by payload), which makes reuse
//! trivially bit-exact: only syntactically identical computations merge.

use std::collections::HashMap;

use crate::ir::func::Function;
use crate::ir::inst::{BinOp, Imm, Inst, MathFn, Operand, Reg, UnOp, WiFn};
use crate::ir::types::{Scalar, Type};

use super::Subst;

/// Hashable mirror of [`Operand`] (floats by bit pattern).
#[derive(PartialEq, Eq, Hash, Clone)]
enum KOp {
    R(u32),
    I(i64, Scalar),
    F(u64, Scalar),
    A(u32),
    S(u32),
}

fn kop(op: &Operand) -> KOp {
    match op {
        Operand::Reg(r) => KOp::R(r.0),
        Operand::Imm(Imm::Int(v, s)) => KOp::I(*v, *s),
        Operand::Imm(Imm::Float(v, s)) => KOp::F(v.to_bits(), *s),
        Operand::Arg(a) => KOp::A(*a),
        Operand::Slot(s) => KOp::S(s.0),
    }
}

/// Structural key of a pure instruction.
#[derive(PartialEq, Eq, Hash, Clone)]
enum Key {
    Bin(BinOp, Type, KOp, KOp),
    Un(UnOp, Type, KOp),
    Cast(Type, Type, KOp),
    Gep(Type, KOp, KOp),
    Wi(WiFn, u32),
    Math(MathFn, Type, Vec<KOp>),
    Select(Type, KOp, KOp, KOp),
    VecBuild(Type, Vec<KOp>),
    VecExtract(Type, KOp, u32),
    VecInsert(Type, KOp, u32, KOp),
    Splat(Type, KOp),
}

/// Key of `inst` if it is pure (side-effect free and
/// deterministic within one work-item invocation), else `None`.
fn key_of(inst: &Inst) -> Option<Key> {
    Some(match inst {
        Inst::Bin { op, ty, a, b } => Key::Bin(*op, ty.clone(), kop(a), kop(b)),
        Inst::Un { op, ty, a } => Key::Un(*op, ty.clone(), kop(a)),
        Inst::Cast { to, from, a } => Key::Cast(to.clone(), from.clone(), kop(a)),
        Inst::Gep { elem, base, idx } => Key::Gep(elem.clone(), kop(base), kop(idx)),
        Inst::Wi { func, dim } => Key::Wi(*func, *dim),
        Inst::Math { func, ty, args } => {
            Key::Math(*func, ty.clone(), args.iter().map(kop).collect())
        }
        Inst::Select { ty, cond, a, b } => Key::Select(ty.clone(), kop(cond), kop(a), kop(b)),
        Inst::VecBuild { ty, elems } => Key::VecBuild(ty.clone(), elems.iter().map(kop).collect()),
        Inst::VecExtract { elem, a, lane } => Key::VecExtract(elem.clone(), kop(a), *lane),
        Inst::VecInsert { ty, a, lane, v } => Key::VecInsert(ty.clone(), kop(a), *lane, kop(v)),
        Inst::Splat { ty, a } => Key::Splat(ty.clone(), kop(a)),
        Inst::Load { .. } | Inst::Store { .. } | Inst::Barrier { .. } | Inst::Marker { .. } => {
            return None
        }
    })
}

/// Run CSE over every block. Returns operand rewrites.
pub fn run(f: &mut Function) -> usize {
    let mut changed = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let block = f.block_mut(bb);
        let mut table: HashMap<Key, Reg> = HashMap::new();
        let mut env = Subst::new();
        for (def, inst) in block.insts.iter_mut() {
            changed += env.apply(inst);
            if inst.is_barrier() {
                table.clear();
                env.flush_regs();
                continue;
            }
            let Some(d) = def else { continue };
            let Some(key) = key_of(inst) else { continue };
            match table.get(&key) {
                Some(prev) => env.set(*d, Operand::Reg(*prev)),
                None => {
                    table.insert(key, *d);
                }
            }
        }
        changed += env.apply_term(&mut block.term);
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::BarrierKind;
    use crate::ir::verify::verify;

    fn add(a: Operand, b: Operand) -> Inst {
        Inst::Bin { op: BinOp::Add, ty: Type::I32, a, b }
    }

    #[test]
    fn duplicate_expression_is_reused() {
        let mut f = Function::new("k");
        let e = f.entry;
        let r1 = f.push_val(e, add(Operand::Arg(0), Operand::ci32(4)));
        let r2 = f.push_val(e, add(Operand::Arg(0), Operand::ci32(4)));
        f.params.push(crate::ir::func::Param {
            name: "n".into(),
            ty: Type::I32,
            is_local_buf: false,
            auto_local_size: None,
        });
        f.push(e, add(Operand::Reg(r1), Operand::Reg(r2)));
        assert_eq!(run(&mut f), 1, "second use rewritten to the first def");
        match f.block(e).insts[2].1 {
            Inst::Bin { a: Operand::Reg(a), b: Operand::Reg(b), .. } => {
                assert_eq!(a, b, "both operands point at the surviving def");
            }
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn barrier_clears_the_value_table() {
        let mut f = Function::new("k");
        let e = f.entry;
        let _r1 = f.push_val(e, add(Operand::ci32(1), Operand::ci32(2)));
        f.push(e, Inst::Barrier { kind: BarrierKind::Explicit });
        let r2 = f.push_val(e, add(Operand::ci32(1), Operand::ci32(2)));
        f.push(e, add(Operand::Reg(r2), Operand::ci32(0)));
        assert_eq!(run(&mut f), 0, "no reuse across the barrier");
        verify(&f).unwrap();
    }

    #[test]
    fn loads_are_not_merged() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::I32, 1);
        let e = f.entry;
        let l1 = f.push_val(e, Inst::Load { ty: Type::I32, ptr: Operand::Slot(s) });
        let l2 = f.push_val(e, Inst::Load { ty: Type::I32, ptr: Operand::Slot(s) });
        f.push(e, add(Operand::Reg(l1), Operand::Reg(l2)));
        assert_eq!(run(&mut f), 0, "memory operations are loadfwd's business");
    }
}
