//! Private-memory store-to-load forwarding, redundant-load elimination,
//! and in-block dead-store elimination.
//!
//! The frontend lowers every local variable to an `Alloca` slot and
//! every read to a fresh `Load` — `c[i] = a[i] + b[i]` loads the slot
//! holding `i` three times. This pass scans each block forward,
//! tracking what each **private cell** provably contains.
//!
//! Private memory is cell-addressed: one cell holds one whole value
//! (`interp::Machine` stores a `VVal` per cell), so a cell is identified
//! exactly by `(slot, offset)` and two distinct cells never alias as
//! long as GEPs stay in bounds — out-of-bounds private access is a
//! runtime error or UB, which optimised code need not preserve
//! byte-for-byte.
//!
//! Three rewrites, all block-local:
//!
//! * **Store-to-load forwarding** — a load from a cell whose stored value
//!   is known becomes a use of that value. `Store` normalises the value
//!   to the store type before writing while `Load` returns the raw cell,
//!   so a value is only forwarded when normalisation is provably the
//!   identity on it (see `forwardable`).
//! * **Redundant-load elimination** — a second load from an unchanged
//!   cell reuses the first load's register (always exact: both observe
//!   the same raw cell).
//! * **Dead-store elimination** — a store overwritten by a later store to
//!   the *same* cell with no possibly-aliasing read in between is
//!   deleted.
//!
//! Barriers discard all memory knowledge (and flush register-valued
//! substitutions): nothing is forwarded across a barrier, and no store
//! preceding a barrier is ever considered dead.

use std::collections::{HashMap, HashSet};

use crate::exec::value::norm_int;
use crate::ir::func::Function;
use crate::ir::inst::{Imm, Inst, Operand};
use crate::ir::types::{AddrSpace, Type};

use super::{normalized_result, Subst};

/// One private memory cell: `(slot id, cell offset from the slot base)`.
#[derive(PartialEq, Eq, Hash, Clone, Copy)]
struct Cell {
    slot: u32,
    off: i64,
}

/// What a pointer-valued operand is known to address.
#[derive(Clone, Copy)]
enum Ptr {
    /// Exactly this private cell.
    Cell(Cell),
    /// Somewhere inside this slot (GEP with a non-constant index).
    SlotUnknown(u32),
    /// Provably not private memory (global/local/constant buffer).
    NonPrivate,
}

/// Run the pass over every block. Returns operand rewrites plus dead
/// stores removed.
pub fn run(f: &mut Function) -> usize {
    // Pointer-typed params with their address space, computed before the
    // mutable block borrow.
    let arg_space: Vec<Option<AddrSpace>> = f
        .params
        .iter()
        .map(|p| match &p.ty {
            Type::Ptr(_, sp) => Some(*sp),
            _ => None,
        })
        .collect();
    let mut changed = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let block = f.block_mut(bb);
        let mut env = Subst::new();
        // Register → pointer knowledge (from Slot operands and GEPs).
        let mut ptrs: HashMap<u32, Ptr> = HashMap::new();
        // Cell → operand its current content equals.
        let mut vals: HashMap<Cell, Operand> = HashMap::new();
        // Cell → index of the last store to it, not yet read: a DSE
        // candidate if overwritten before any possibly-aliasing read.
        let mut pending: HashMap<Cell, usize> = HashMap::new();
        let mut dead: HashSet<usize> = HashSet::new();
        // Registers with provably-normalised values (for forwarding).
        let mut normed: HashMap<u32, Type> = HashMap::new();
        let resolve = |op: &Operand, ptrs: &HashMap<u32, Ptr>| -> Option<Ptr> {
            match op {
                Operand::Slot(s) => Some(Ptr::Cell(Cell { slot: s.0, off: 0 })),
                Operand::Reg(r) => ptrs.get(&r.0).copied(),
                Operand::Arg(a) => match arg_space.get(*a as usize).copied().flatten() {
                    Some(AddrSpace::Private) | None => None,
                    Some(_) => Some(Ptr::NonPrivate),
                },
                Operand::Imm(_) => None,
            }
        };
        for (idx, (def, inst)) in block.insts.iter_mut().enumerate() {
            changed += env.apply(inst);
            match inst {
                Inst::Barrier { .. } => {
                    vals.clear();
                    pending.clear();
                    ptrs.clear();
                    env.flush_regs();
                    continue;
                }
                Inst::Gep { base, idx: gidx, .. } => {
                    let Some(d) = *def else { continue };
                    match resolve(base, &ptrs) {
                        Some(Ptr::Cell(c)) => {
                            let p = match gidx {
                                Operand::Imm(Imm::Int(v, s)) => {
                                    Ptr::Cell(Cell { slot: c.slot, off: c.off + norm_int(*v, *s) })
                                }
                                _ => Ptr::SlotUnknown(c.slot),
                            };
                            ptrs.insert(d.0, p);
                        }
                        Some(Ptr::SlotUnknown(s)) => {
                            ptrs.insert(d.0, Ptr::SlotUnknown(s));
                        }
                        Some(Ptr::NonPrivate) => {
                            ptrs.insert(d.0, Ptr::NonPrivate);
                        }
                        None => {}
                    }
                }
                // Pointer-identity casts carry pointer knowledge through.
                Inst::Cast { to, a, .. } if to.elem_scalar().is_none() => {
                    if let (Some(d), Some(p)) = (def.as_ref(), resolve(a, &ptrs)) {
                        ptrs.insert(d.0, p);
                    }
                }
                Inst::Load { ptr, .. } => {
                    let Some(d) = *def else { continue };
                    match resolve(ptr, &ptrs) {
                        Some(Ptr::Cell(c)) => {
                            // The pending store (if any) is read: it is live.
                            pending.remove(&c);
                            match vals.get(&c) {
                                Some(v) => env.set(d, *v),
                                None => {
                                    vals.insert(c, Operand::Reg(d));
                                }
                            }
                        }
                        Some(Ptr::SlotUnknown(s)) => {
                            pending.retain(|c, _| c.slot != s);
                        }
                        Some(Ptr::NonPrivate) => {}
                        // Unknown pointer: could read any private cell.
                        None => pending.clear(),
                    }
                }
                Inst::Store { ty, ptr, val } => {
                    match resolve(ptr, &ptrs) {
                        Some(Ptr::Cell(c)) => {
                            // Overwriting an unread store kills it. A later
                            // same-cell store proves deadness even if an
                            // unknown write intervened (both overwrite it).
                            if let Some(prev) = pending.insert(c, idx) {
                                dead.insert(prev);
                            }
                            if forwardable(val, ty, &normed) {
                                vals.insert(c, *val);
                            } else {
                                vals.remove(&c);
                            }
                        }
                        Some(Ptr::SlotUnknown(s)) => {
                            vals.retain(|c, _| c.slot != s);
                        }
                        Some(Ptr::NonPrivate) => {}
                        // Unknown pointer: could hit any private cell.
                        None => vals.clear(),
                    }
                }
                _ => {}
            }
            if let Some(d) = def {
                if let Some(t) = normalized_result(inst) {
                    normed.insert(d.0, t);
                }
            }
        }
        changed += env.apply_term(&mut block.term);
        if !dead.is_empty() {
            changed += dead.len();
            let old = std::mem::take(&mut block.insts);
            block.insts =
                old.into_iter().enumerate().filter(|(i, _)| !dead.contains(i)).map(|(_, x)| x).collect();
        }
    }
    changed
}

/// True when substituting `val` for a load of the cell written by
/// `Store { ty, val, .. }` is bit-exact — i.e. the store's
/// `normalize_to(val, ty)` was the identity:
///
/// * pointer-typed stores never normalise (`elem_scalar` is `None`, and
///   `norm_val` is the identity on pointers);
/// * an integer/float immediate of exactly the store's scalar type reads
///   back (idempotently re-normalised) as the cell value;
/// * a register whose defining instruction provably normalised it to
///   exactly the store type.
///
/// Raw loads, `Select` results, and scalar `Arg`s are not provably
/// normalised and are never forwarded (the redundant-load rule still
/// covers repeated loads).
fn forwardable(val: &Operand, store_ty: &Type, normed: &HashMap<u32, Type>) -> bool {
    if store_ty.elem_scalar().is_none() {
        return true;
    }
    match val {
        Operand::Imm(i) => store_ty.lanes() == 1 && i.ty() == *store_ty,
        Operand::Reg(r) => normed.get(&r.0) == Some(store_ty),
        // Slot operands are pointers; pointer values pass through
        // `norm_val` untouched regardless of the store type.
        Operand::Slot(_) => true,
        Operand::Arg(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::{BarrierKind, BinOp};
    use crate::ir::verify::verify;

    fn store(s: crate::ir::inst::SlotId, v: Operand) -> Inst {
        Inst::Store { ty: Type::I32, ptr: Operand::Slot(s), val: v }
    }

    fn load(s: crate::ir::inst::SlotId) -> Inst {
        Inst::Load { ty: Type::I32, ptr: Operand::Slot(s) }
    }

    #[test]
    fn immediate_store_forwards_to_load() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::I32, 1);
        let e = f.entry;
        f.push(e, store(s, Operand::ci32(7)));
        let l = f.push_val(e, load(s));
        f.push(e, Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::Reg(l), b: Operand::ci32(1) });
        assert_eq!(run(&mut f), 1);
        match f.block(e).insts[2].1 {
            Inst::Bin { a: Operand::Imm(Imm::Int(7, _)), .. } => {}
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn normalized_register_forwards_raw_load_does_not() {
        let mut f = Function::new("k");
        let a = f.add_slot("a", Type::I32, 1);
        let b = f.add_slot("b", Type::I32, 1);
        let e = f.entry;
        // Raw load: not provably normalised — stored then reloaded stays.
        let l0 = f.push_val(e, load(a));
        f.push(e, store(b, Operand::Reg(l0)));
        let l1 = f.push_val(e, load(b));
        // Bin result: normalised to I32 — stored then reloaded forwards.
        let x = f.push_val(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::Reg(l1), b: Operand::ci32(1) },
        );
        f.push(e, store(a, Operand::Reg(x)));
        let l2 = f.push_val(e, load(a));
        f.push(
            e,
            Inst::Bin { op: BinOp::Mul, ty: Type::I32, a: Operand::Reg(l2), b: Operand::ci32(2) },
        );
        assert_eq!(run(&mut f), 1, "only the normalised register forwards");
        match f.block(e).insts[6].1 {
            Inst::Bin { a: Operand::Reg(r), .. } => assert_eq!(r, x),
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn repeated_load_is_reused_and_dead_store_removed() {
        let mut f = Function::new("k");
        let s = f.add_slot("i", Type::I32, 1);
        let e = f.entry;
        let l1 = f.push_val(e, load(s));
        let l2 = f.push_val(e, load(s));
        f.push(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::Reg(l1), b: Operand::Reg(l2) },
        );
        // Two stores, no read in between: the first is dead.
        f.push(e, store(s, Operand::ci32(1)));
        f.push(e, store(s, Operand::ci32(2)));
        let n = run(&mut f);
        assert_eq!(n, 2, "one reused load + one dead store, got {n}");
        assert_eq!(f.block(e).insts.len(), 4);
        verify(&f).unwrap();
    }

    #[test]
    fn barrier_blocks_forwarding_and_dse() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::I32, 1);
        let e = f.entry;
        f.push(e, store(s, Operand::ci32(1)));
        f.push(e, Inst::Barrier { kind: BarrierKind::Explicit });
        let l = f.push_val(e, load(s));
        f.push(e, Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::Reg(l), b: Operand::ci32(1) });
        f.push(e, store(s, Operand::ci32(2)));
        assert_eq!(run(&mut f), 0, "nothing crosses the barrier");
        assert_eq!(f.block(e).insts.len(), 5);
        verify(&f).unwrap();
    }

    #[test]
    fn gep_with_constant_index_tracks_distinct_cells() {
        let mut f = Function::new("k");
        let arr = f.add_slot("arr", Type::I32, 4);
        let e = f.entry;
        let p0 = f.push_val(
            e,
            Inst::Gep { elem: Type::I32, base: Operand::Slot(arr), idx: Operand::ci32(0) },
        );
        let p1 = f.push_val(
            e,
            Inst::Gep { elem: Type::I32, base: Operand::Slot(arr), idx: Operand::ci32(1) },
        );
        f.push(e, Inst::Store { ty: Type::I32, ptr: Operand::Reg(p0), val: Operand::ci32(10) });
        f.push(e, Inst::Store { ty: Type::I32, ptr: Operand::Reg(p1), val: Operand::ci32(11) });
        let l = f.push_val(e, Inst::Load { ty: Type::I32, ptr: Operand::Reg(p0) });
        f.push(e, Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::Reg(l), b: Operand::ci32(1) });
        // Cell (arr,0) still holds 10: the store to (arr,1) is no clobber
        // and no DSE trigger.
        assert_eq!(run(&mut f), 1);
        match f.block(e).insts[5].1 {
            Inst::Bin { a: Operand::Imm(Imm::Int(10, _)), .. } => {}
            ref other => panic!("{other:?}"),
        }
        assert_eq!(f.block(e).insts.len(), 6, "no store was removed");
        verify(&f).unwrap();
    }

    #[test]
    fn unknown_index_store_clobbers_whole_slot() {
        let mut f = Function::new("k");
        let arr = f.add_slot("arr", Type::I32, 4);
        let i = f.add_slot("i", Type::I32, 1);
        let e = f.entry;
        // `i` has no known value: its load stays opaque, so the GEP index
        // is genuinely unknown.
        let li = f.push_val(e, load(i));
        f.push(e, Inst::Store { ty: Type::I32, ptr: Operand::Slot(arr), val: Operand::ci32(5) });
        let p = f.push_val(
            e,
            Inst::Gep { elem: Type::I32, base: Operand::Slot(arr), idx: Operand::Reg(li) },
        );
        f.push(e, Inst::Store { ty: Type::I32, ptr: Operand::Reg(p), val: Operand::ci32(9) });
        let l = f.push_val(e, Inst::Load { ty: Type::I32, ptr: Operand::Slot(arr) });
        f.push(e, Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::Reg(l), b: Operand::ci32(1) });
        // The load of arr[0] must NOT be folded to 5: the variable-index
        // store may have hit cell 0. And the store of 5 must survive: the
        // possibly-aliasing load reads it.
        assert_eq!(run(&mut f), 0, "nothing is forwardable here");
        assert_eq!(f.block(e).insts.len(), 6, "both stores survive");
        match f.block(e).insts[4].1 {
            Inst::Load { .. } => {}
            ref other => panic!("arr load must survive: {other:?}"),
        }
        verify(&f).unwrap();
    }
}
