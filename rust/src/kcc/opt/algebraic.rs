//! Algebraic simplification and strength reduction — integer types only.
//!
//! Float "identities" (`x+0.0`, `x*1.0`) are deliberately never
//! rewritten: they are not bit-exact under IEEE semantics (`-0.0 + 0.0`,
//! NaN payloads), and bit-identical O0/O2 results are an acceptance
//! criterion of this optimizer.
//!
//! Integer identities need one extra proof: the interpreter normalises
//! both operands to the instruction's scalar type before operating, so
//! replacing `x + 0` with `x` is only exact when `x`'s runtime value is
//! already normalised to that type. That holds when `x` is defined by a
//! normalising instruction (`Bin`/`Un`/`Cast`/`Math` normalise their
//! outputs; `Wi` produces a u64) of the same scalar type, or is an
//! immediate of that type — private loads return raw cells and are
//! excluded.
//!
//! Strength reductions (`x * 2^k → x << k`, unsigned `x / 2^k → x >> k`,
//! unsigned `x % 2^k → x & (2^k-1)`) rewrite the instruction in place;
//! the wrapping/normalising semantics of both forms coincide.

use std::collections::HashMap;

use crate::exec::value::norm_int;
use crate::ir::func::Function;
use crate::ir::inst::{BinOp, Imm, Inst, Operand};
use crate::ir::types::{Scalar, Type};

use super::{normalized_result, Subst};

/// Run algebraic simplification over every block. Returns the number of
/// operand rewrites plus in-place strength reductions.
pub fn run(f: &mut Function) -> usize {
    let mut changed = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let block = f.block_mut(bb);
        let mut env = Subst::new();
        // Registers whose runtime value is provably normalised, with
        // their (scalar) result type.
        let mut normed: HashMap<u32, Type> = HashMap::new();
        for (def, inst) in block.insts.iter_mut() {
            changed += env.apply(inst);
            if inst.is_barrier() {
                env.flush_regs();
                continue;
            }
            if let Some(d) = def {
                if let Some(rewrite) = simplify(inst, &normed) {
                    match rewrite {
                        Rewrite::Value(op) => env.set(*d, op),
                        Rewrite::Inst(new) => {
                            *inst = new;
                            changed += 1;
                        }
                    }
                }
                if let Some(ty) = normalized_result(inst) {
                    normed.insert(d.0, ty);
                }
            }
        }
        changed += env.apply_term(&mut block.term);
    }
    changed
}

enum Rewrite {
    /// The defined register equals this operand (identity / annihilator).
    Value(Operand),
    /// Replace the instruction with a cheaper equivalent.
    Inst(Inst),
}

/// The normalised integer constant an operand denotes, if it is an
/// integer immediate.
fn int_const(op: &Operand, s: Scalar) -> Option<i64> {
    match op {
        Operand::Imm(Imm::Int(v, si)) => Some(norm_int(norm_int(*v, *si), s)),
        _ => None,
    }
}

/// True when substituting `op` for a result of scalar type `s` is exact:
/// the operand's runtime value is already normalised to `s`.
fn matches_ty(op: &Operand, s: Scalar, normed: &HashMap<u32, Type>) -> bool {
    let want = Type::Scalar(s);
    match op {
        Operand::Reg(r) => normed.get(&r.0) == Some(&want),
        Operand::Imm(i) => i.ty() == want,
        // Arguments are bound by the launcher and loads return raw
        // cells; neither is provably normalised.
        Operand::Arg(_) | Operand::Slot(_) => false,
    }
}

/// Try to simplify one scalar integer `Bin`.
fn simplify(inst: &Inst, normed: &HashMap<u32, Type>) -> Option<Rewrite> {
    let Inst::Bin { op, ty, a, b } = inst else { return None };
    if ty.lanes() != 1 {
        return None;
    }
    let s = ty.elem_scalar()?;
    if !s.is_int() {
        return None;
    }
    let ca = int_const(a, s);
    let cb = int_const(b, s);
    let zero = || Rewrite::Value(Operand::Imm(Imm::Int(0, s)));
    let ident = |x: &Operand| matches_ty(x, s, normed).then(|| Rewrite::Value(*x));
    let same_reg = matches!((a, b), (Operand::Reg(x), Operand::Reg(y)) if x == y);
    let all_ones = norm_int(-1, s);
    match op {
        BinOp::Add => {
            if cb == Some(0) {
                return ident(a);
            }
            if ca == Some(0) {
                return ident(b);
            }
        }
        BinOp::Sub => {
            if same_reg && s != Scalar::Bool {
                return Some(zero());
            }
            if cb == Some(0) {
                return ident(a);
            }
        }
        BinOp::Mul => {
            if ca == Some(0) || cb == Some(0) {
                return Some(zero());
            }
            if cb == Some(1) {
                return ident(a);
            }
            if ca == Some(1) {
                return ident(b);
            }
            if s != Scalar::Bool {
                if let Some(k) = power_of_two(cb) {
                    return Some(shl(ty, a, k, s));
                }
                if let Some(k) = power_of_two(ca) {
                    return Some(shl(ty, b, k, s));
                }
            }
        }
        BinOp::Div => {
            if cb == Some(1) {
                return ident(a);
            }
            if matches!(s, Scalar::U32 | Scalar::U64) {
                if let Some(k) = power_of_two(cb) {
                    return Some(Rewrite::Inst(Inst::Bin {
                        op: BinOp::Shr,
                        ty: ty.clone(),
                        a: *a,
                        b: Operand::Imm(Imm::Int(k, s)),
                    }));
                }
            }
        }
        BinOp::Rem => {
            if matches!(s, Scalar::U32 | Scalar::U64) {
                if let Some(c) = cb {
                    if power_of_two(cb).is_some() {
                        return Some(Rewrite::Inst(Inst::Bin {
                            op: BinOp::And,
                            ty: ty.clone(),
                            a: *a,
                            b: Operand::Imm(Imm::Int(c - 1, s)),
                        }));
                    }
                }
            }
        }
        BinOp::And => {
            if ca == Some(0) || cb == Some(0) {
                return Some(zero());
            }
            if cb == Some(all_ones) {
                return ident(a);
            }
            if ca == Some(all_ones) {
                return ident(b);
            }
        }
        BinOp::Or => {
            if cb == Some(0) {
                return ident(a);
            }
            if ca == Some(0) {
                return ident(b);
            }
        }
        BinOp::Xor => {
            if same_reg {
                return Some(zero());
            }
            if cb == Some(0) {
                return ident(a);
            }
            if ca == Some(0) {
                return ident(b);
            }
        }
        BinOp::Shl | BinOp::Shr => {
            if cb == Some(0) {
                return ident(a);
            }
        }
        BinOp::LAnd => {
            if ca == Some(0) || cb == Some(0) {
                return Some(zero());
            }
        }
        BinOp::LOr => {
            if ca.map(|c| c != 0).unwrap_or(false) || cb.map(|c| c != 0).unwrap_or(false) {
                return Some(Rewrite::Value(Operand::Imm(Imm::Int(1, Scalar::Bool))));
            }
        }
        _ => {}
    }
    None
}

/// `log2(c)` when the constant is a power of two ≥ 2 that fits the
/// shift-equivalence argument (positive as i64, exponent < 63).
fn power_of_two(c: Option<i64>) -> Option<i64> {
    let c = c?;
    if c >= 2 && (c as u64).is_power_of_two() {
        Some((c as u64).trailing_zeros() as i64)
    } else {
        None
    }
}

fn shl(ty: &Type, a: &Operand, k: i64, s: Scalar) -> Rewrite {
    Rewrite::Inst(Inst::Bin {
        op: BinOp::Shl,
        ty: ty.clone(),
        a: *a,
        b: Operand::Imm(Imm::Int(k, s)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify;

    fn bin(op: BinOp, a: Operand, b: Operand) -> Inst {
        Inst::Bin { op, ty: Type::I32, a, b }
    }

    #[test]
    fn mul_by_zero_annihilates() {
        let mut f = Function::new("k");
        let e = f.entry;
        let x = f.push_val(e, bin(BinOp::Add, Operand::Arg(0), Operand::ci32(1)));
        f.params.push(crate::ir::func::Param {
            name: "n".into(),
            ty: Type::I32,
            is_local_buf: false,
            auto_local_size: None,
        });
        let m = f.push_val(e, bin(BinOp::Mul, Operand::Reg(x), Operand::ci32(0)));
        f.push(e, bin(BinOp::Add, Operand::Reg(m), Operand::ci32(5)));
        assert_eq!(run(&mut f), 1);
        match f.block(e).insts[2].1 {
            Inst::Bin { a: Operand::Imm(Imm::Int(0, _)), .. } => {}
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn add_zero_identity_requires_normalized_source() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::I32, 1);
        let e = f.entry;
        // A load is NOT a normalised source: no identity rewrite.
        let l = f.push_val(e, Inst::Load { ty: Type::I32, ptr: Operand::Slot(s) });
        let a1 = f.push_val(e, bin(BinOp::Add, Operand::Reg(l), Operand::ci32(0)));
        // A Bin IS: identity fires on the second one.
        let a2 = f.push_val(e, bin(BinOp::Add, Operand::Reg(a1), Operand::ci32(0)));
        f.push(e, bin(BinOp::Mul, Operand::Reg(a2), Operand::ci32(3)));
        assert_eq!(run(&mut f), 1, "only the normalised add is propagated");
        match f.block(e).insts[3].1 {
            Inst::Bin { a: Operand::Reg(r), .. } => assert_eq!(r, a1),
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn mul_by_power_of_two_becomes_shift() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::U32, 1);
        let e = f.entry;
        let l = f.push_val(e, Inst::Load { ty: Type::U32, ptr: Operand::Slot(s) });
        f.push(
            e,
            Inst::Bin { op: BinOp::Mul, ty: Type::U32, a: Operand::Reg(l), b: Operand::cu32(8) },
        );
        assert_eq!(run(&mut f), 1);
        match f.block(e).insts[1].1 {
            Inst::Bin { op: BinOp::Shl, b: Operand::Imm(Imm::Int(3, _)), .. } => {}
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn unsigned_div_rem_strength_reduce() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::U32, 1);
        let e = f.entry;
        let l = f.push_val(e, Inst::Load { ty: Type::U32, ptr: Operand::Slot(s) });
        f.push(
            e,
            Inst::Bin { op: BinOp::Div, ty: Type::U32, a: Operand::Reg(l), b: Operand::cu32(16) },
        );
        f.push(
            e,
            Inst::Bin { op: BinOp::Rem, ty: Type::U32, a: Operand::Reg(l), b: Operand::cu32(16) },
        );
        assert_eq!(run(&mut f), 2);
        assert!(matches!(f.block(e).insts[1].1, Inst::Bin { op: BinOp::Shr, .. }));
        match f.block(e).insts[2].1 {
            Inst::Bin { op: BinOp::And, b: Operand::Imm(Imm::Int(15, _)), .. } => {}
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn float_identities_are_left_alone() {
        let mut f = Function::new("k");
        let e = f.entry;
        let x = f.push_val(
            e,
            Inst::Bin {
                op: BinOp::Add,
                ty: Type::F32,
                a: Operand::cf32(1.0),
                b: Operand::cf32(2.0),
            },
        );
        f.push(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::F32, a: Operand::Reg(x), b: Operand::cf32(0.0) },
        );
        assert_eq!(run(&mut f), 0);
    }
}
