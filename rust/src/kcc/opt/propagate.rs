//! Copy propagation.
//!
//! Two instruction shapes are exact copies under the interpreter's
//! semantics and can be replaced by their source operand:
//!
//! * `Cast` to a pointer type — `eval_cast` returns the input unchanged
//!   when the target type has no element scalar.
//! * Scalar `Select` with a constant condition — the interpreter returns
//!   the chosen operand's value **unnormalised**, so substituting the
//!   operand itself is bit-exact.
//!
//! Register-valued copies are flushed at barriers (no live range may be
//! created across a barrier); immediates keep propagating through.

use crate::ir::func::Function;
use crate::ir::inst::{Inst, Operand};

use super::{imm_truthy, Subst};

/// Run copy propagation over every block. Returns operand rewrites.
pub fn run(f: &mut Function) -> usize {
    let mut changed = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let block = f.block_mut(bb);
        let mut env = Subst::new();
        for (def, inst) in block.insts.iter_mut() {
            changed += env.apply(inst);
            if inst.is_barrier() {
                env.flush_regs();
                continue;
            }
            let Some(d) = def else { continue };
            match inst {
                // Pointer casts are identity: no element scalar to
                // normalise to.
                Inst::Cast { to, a, .. } if to.elem_scalar().is_none() => {
                    env.set(*d, *a);
                }
                // Constant-condition scalar select returns the chosen
                // operand verbatim.
                Inst::Select { ty, cond: Operand::Imm(c), a, b } if ty.lanes() == 1 => {
                    env.set(*d, if imm_truthy(c) { *a } else { *b });
                }
                _ => {}
            }
        }
        changed += env.apply_term(&mut block.term);
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::{BinOp, SlotId};
    use crate::ir::types::{AddrSpace, Type};
    use crate::ir::verify::verify;

    #[test]
    fn pointer_cast_is_propagated() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::F32, 1);
        let e = f.entry;
        let p = f.push_val(
            e,
            Inst::Cast {
                to: Type::F32.ptr(AddrSpace::Private),
                from: Type::F32.ptr(AddrSpace::Private),
                a: Operand::Slot(s),
            },
        );
        f.push(e, Inst::Load { ty: Type::F32, ptr: Operand::Reg(p) });
        assert_eq!(run(&mut f), 1);
        assert!(matches!(f.block(e).insts[1].1, Inst::Load { ptr: Operand::Slot(SlotId(0)), .. }));
        verify(&f).unwrap();
    }

    #[test]
    fn const_select_chooses_raw_operand() {
        let mut f = Function::new("k");
        let e = f.entry;
        let x = f.push_val(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::ci32(1), b: Operand::ci32(2) },
        );
        let sel = f.push_val(
            e,
            Inst::Select {
                ty: Type::I32,
                cond: Operand::cbool(false),
                a: Operand::Reg(x),
                b: Operand::ci32(9),
            },
        );
        f.push(
            e,
            Inst::Bin { op: BinOp::Mul, ty: Type::I32, a: Operand::Reg(sel), b: Operand::ci32(2) },
        );
        assert_eq!(run(&mut f), 1);
        match f.block(e).insts[2].1 {
            Inst::Bin { a: Operand::Imm(i), .. } => assert_eq!(super::super::imm_val(&i).as_i(), 9),
            ref other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }
}
