//! Dead code elimination.
//!
//! The rewriting passes (`fold`, `propagate`, `cse`, `algebraic`,
//! `loadfwd`) replace register *uses* and leave the defining
//! instructions behind; this pass collects them. A defining instruction
//! is removed when its register has no remaining uses in the block
//! (registers are block-local, so a block-local use count is a global
//! one) and the instruction is side-effect free.
//!
//! Side effects that keep an instruction alive:
//!
//! * `Store`, `Barrier`, `Marker` — never removed (they produce no
//!   register anyway).
//! * Integer `Div`/`Rem` with a possibly-zero divisor — division by zero
//!   is a **runtime error** in this IR, and the optimizer preserves it.
//!   A provably non-zero constant divisor makes the division pure.
//!
//! The sweep runs in reverse and decrements use counts as it deletes, so
//! an entire dead expression chain dies in a single pass.

use std::collections::HashMap;

use crate::exec::value::norm_int;
use crate::ir::func::Function;
use crate::ir::inst::{BinOp, Imm, Inst, Operand, Term};

/// Run DCE over every block. Returns the number of instructions removed.
pub fn run(f: &mut Function) -> usize {
    let mut removed = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let block = f.block_mut(bb);
        let mut uses: HashMap<u32, usize> = HashMap::new();
        for (_, inst) in &block.insts {
            for op in inst.operands() {
                if let Operand::Reg(r) = op {
                    *uses.entry(r.0).or_insert(0) += 1;
                }
            }
        }
        if let Term::Br { cond: Operand::Reg(r), .. } = &block.term {
            *uses.entry(r.0).or_insert(0) += 1;
        }
        let mut keep = vec![true; block.insts.len()];
        for i in (0..block.insts.len()).rev() {
            let (def, inst) = &block.insts[i];
            let Some(d) = def else { continue };
            if uses.get(&d.0).copied().unwrap_or(0) > 0 || !removable(inst) {
                continue;
            }
            keep[i] = false;
            removed += 1;
            for op in inst.operands() {
                if let Operand::Reg(r) = op {
                    if let Some(n) = uses.get_mut(&r.0) {
                        *n = n.saturating_sub(1);
                    }
                }
            }
        }
        if keep.iter().any(|k| !k) {
            let old = std::mem::take(&mut block.insts);
            block.insts = old
                .into_iter()
                .zip(keep)
                .filter_map(|(inst, k)| k.then_some(inst))
                .collect();
        }
    }
    removed
}

/// True when deleting an unused `inst` cannot change observable
/// behaviour (memory, barriers, or runtime errors).
fn removable(inst: &Inst) -> bool {
    match inst {
        Inst::Store { .. } | Inst::Barrier { .. } | Inst::Marker { .. } => false,
        Inst::Bin { op: BinOp::Div | BinOp::Rem, ty, b, .. }
            if ty.elem_scalar().map(|s| s.is_int()).unwrap_or(false) =>
        {
            matches!(b, Operand::Imm(Imm::Int(v, s)) if norm_int(*v, *s) != 0)
        }
        _ => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::Type;
    use crate::ir::verify::verify;

    fn add(a: Operand, b: Operand) -> Inst {
        Inst::Bin { op: BinOp::Add, ty: Type::I32, a, b }
    }

    #[test]
    fn dead_chain_dies_in_one_pass() {
        let mut f = Function::new("k");
        let e = f.entry;
        let a = f.push_val(e, add(Operand::ci32(1), Operand::ci32(2)));
        let b = f.push_val(e, add(Operand::Reg(a), Operand::ci32(3)));
        let _c = f.push_val(e, add(Operand::Reg(b), Operand::ci32(4)));
        let live = f.push_val(e, add(Operand::ci32(5), Operand::ci32(6)));
        let s = f.add_slot("out", Type::I32, 1);
        f.push(e, Inst::Store { ty: Type::I32, ptr: Operand::Slot(s), val: Operand::Reg(live) });
        assert_eq!(run(&mut f), 3, "the whole unused chain goes at once");
        assert_eq!(f.block(e).insts.len(), 2);
        verify(&f).unwrap();
    }

    #[test]
    fn possibly_trapping_division_survives() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::I32, 1);
        let e = f.entry;
        let l = f.push_val(e, Inst::Load { ty: Type::I32, ptr: Operand::Slot(s) });
        // Unknown divisor: must survive even though unused.
        let _d1 = f.push_val(
            e,
            Inst::Bin { op: BinOp::Div, ty: Type::I32, a: Operand::ci32(8), b: Operand::Reg(l) },
        );
        // Constant zero divisor: traps, must survive.
        let _d2 = f.push_val(
            e,
            Inst::Bin { op: BinOp::Rem, ty: Type::I32, a: Operand::ci32(8), b: Operand::ci32(0) },
        );
        // Constant non-zero divisor: pure, dies.
        let _d3 = f.push_val(
            e,
            Inst::Bin { op: BinOp::Div, ty: Type::I32, a: Operand::ci32(8), b: Operand::ci32(2) },
        );
        // Float division never traps: dies.
        let _d4 = f.push_val(
            e,
            Inst::Bin { op: BinOp::Div, ty: Type::F32, a: Operand::cf32(8.0), b: Operand::cf32(0.0) },
        );
        assert_eq!(run(&mut f), 2, "only the pure divisions are removed");
        assert_eq!(f.block(e).insts.len(), 3);
        verify(&f).unwrap();
    }

    #[test]
    fn stores_and_barriers_are_untouchable() {
        let mut f = Function::new("k");
        let s = f.add_slot("x", Type::I32, 1);
        let e = f.entry;
        f.push(e, Inst::Store { ty: Type::I32, ptr: Operand::Slot(s), val: Operand::ci32(1) });
        f.push(e, Inst::Barrier { kind: crate::ir::inst::BarrierKind::Explicit });
        assert_eq!(run(&mut f), 0);
        assert_eq!(f.block(e).insts.len(), 2);
    }
}
