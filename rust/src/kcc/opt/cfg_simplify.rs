//! CFG simplification: branch folding, jump threading through empty
//! blocks, single-predecessor block merging, and unreachable-block
//! removal.
//!
//! Runs before region formation, where blocks are referenced only by
//! terminators and the entry id, so removing and renumbering blocks is
//! safe. Block merging is the biggest enabler for the block-local passes
//! (`cse`, `loadfwd`): the frontend splits every `&&`/`||` and `if` into
//! tiny blocks, and merging them back gives the forward scans real scope.

use std::collections::{HashMap, HashSet};

use crate::ir::cfg::reachable;
use crate::ir::func::{Block, Function};
use crate::ir::inst::{BlockId, Operand, Term};

use super::imm_truthy;

/// Run one round of CFG simplification. Returns the number of edits.
pub fn run(f: &mut Function) -> usize {
    let mut changed = 0;
    changed += fold_branches(f);
    changed += thread_jumps(f);
    changed += merge_blocks(f);
    changed += drop_unreachable(f);
    changed
}

/// Turn constant-condition and same-target branches into jumps.
fn fold_branches(f: &mut Function) -> usize {
    let mut n = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let term = f.block(bb).term.clone();
        if let Term::Br { cond, t, f: fb } = term {
            if t == fb {
                f.set_term(bb, Term::Jump(t));
                n += 1;
            } else if let Operand::Imm(imm) = cond {
                let target = if imm_truthy(&imm) { t } else { fb };
                f.set_term(bb, Term::Jump(target));
                n += 1;
            }
        }
    }
    n
}

/// Final destination of a chain of empty forwarding blocks starting at
/// `b` (a block with no instructions whose terminator is a plain jump).
/// Cycles of empty blocks (degenerate infinite loops) stop the walk.
fn forward_target(f: &Function, b: BlockId) -> BlockId {
    let mut cur = b;
    let mut seen = HashSet::new();
    loop {
        if !seen.insert(cur) {
            return cur;
        }
        match (&f.block(cur).insts[..], &f.block(cur).term) {
            ([], Term::Jump(t)) if *t != cur => cur = *t,
            _ => return cur,
        }
    }
}

/// Redirect edges that point at empty forwarding blocks straight to
/// their final destination.
fn thread_jumps(f: &mut Function) -> usize {
    let targets: HashMap<BlockId, BlockId> =
        f.block_ids().map(|b| (b, forward_target(f, b))).collect();
    let mut n = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let mut term = f.block(bb).term.clone();
        let mut edits = 0;
        term.map_succs(|s| {
            let t = targets[&s];
            if t != s {
                edits += 1;
            }
            t
        });
        if edits > 0 {
            f.set_term(bb, term);
            n += edits;
        }
    }
    let new_entry = targets[&f.entry];
    if new_entry != f.entry {
        f.entry = new_entry;
        n += 1;
    }
    n
}

/// Merge blocks with a unique jump-predecessor into that predecessor.
/// The merged block's registers move together with their block-local
/// uses, so no register invariant is disturbed; the husk left behind is
/// unreachable and removed by [`drop_unreachable`].
fn merge_blocks(f: &mut Function) -> usize {
    let mut n = 0;
    loop {
        let live: HashSet<BlockId> = reachable(f).into_iter().collect();
        let preds = f.preds();
        let mut merged = false;
        for a in f.block_ids().collect::<Vec<_>>() {
            if !live.contains(&a) {
                continue;
            }
            let b = match f.block(a).term {
                Term::Jump(b) => b,
                _ => continue,
            };
            if b == a || b == f.entry {
                continue;
            }
            let live_preds: Vec<BlockId> = preds[b.0 as usize]
                .iter()
                .copied()
                .filter(|p| live.contains(p))
                .collect();
            if live_preds != [a] {
                continue;
            }
            // Move b's body and terminator into a, leaving b an
            // unreachable empty husk.
            let husk = Block { name: f.block(b).name.clone(), insts: Vec::new(), term: Term::Ret };
            let body = std::mem::replace(f.block_mut(b), husk);
            let ablock = f.block_mut(a);
            ablock.insts.extend(body.insts);
            ablock.term = body.term;
            n += 1;
            merged = true;
            break; // preds changed; recompute.
        }
        if !merged {
            return n;
        }
    }
}

/// Remove unreachable blocks entirely, compacting ids. Safe before
/// region formation: only terminators and `entry` (and, defensively,
/// `wi_loops`) reference block ids.
fn drop_unreachable(f: &mut Function) -> usize {
    let live = reachable(f);
    if live.len() == f.blocks.len() {
        return 0;
    }
    let keep: HashSet<BlockId> = live.iter().copied().collect();
    let mut remap: HashMap<BlockId, BlockId> = HashMap::new();
    let mut next = 0u32;
    for b in f.block_ids() {
        if keep.contains(&b) {
            remap.insert(b, BlockId(next));
            next += 1;
        }
    }
    let removed = f.blocks.len() - remap.len();
    let mut blocks = Vec::with_capacity(remap.len());
    for (i, blk) in std::mem::take(&mut f.blocks).into_iter().enumerate() {
        if keep.contains(&BlockId(i as u32)) {
            blocks.push(blk);
        }
    }
    for blk in &mut blocks {
        blk.term.map_succs(|s| remap[&s]);
    }
    f.blocks = blocks;
    f.entry = remap[&f.entry];
    // wi_loops is empty at this pipeline stage; remap defensively anyway.
    f.wi_loops.retain(|w| remap.contains_key(&w.header) && remap.contains_key(&w.latch));
    for w in &mut f.wi_loops {
        w.header = remap[&w.header];
        w.latch = remap[&w.latch];
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::{BinOp, Inst};
    use crate::ir::types::Type;
    use crate::ir::verify::verify;

    fn add(a: Operand, b: Operand) -> Inst {
        Inst::Bin { op: BinOp::Add, ty: Type::I32, a, b }
    }

    #[test]
    fn constant_branch_folds_and_dead_block_drops() {
        let mut f = Function::new("k");
        let e = f.entry;
        let t = f.add_block("t");
        let x = f.add_block("x");
        f.push(t, add(Operand::ci32(1), Operand::ci32(2)));
        f.set_term(e, Term::Br { cond: Operand::cbool(true), t, f: x });
        f.set_term(t, Term::Ret);
        let n = run(&mut f);
        assert!(n >= 2, "branch fold + unreachable removal, got {n}");
        verify(&f).unwrap();
        // Entry merged with t (single pred), x removed.
        assert_eq!(f.blocks.len(), 1);
        assert_eq!(f.block(f.entry).insts.len(), 1);
    }

    #[test]
    fn empty_block_is_threaded_away() {
        let mut f = Function::new("k");
        let e = f.entry;
        let mid = f.add_block("mid");
        let end = f.add_block("end");
        f.push(e, add(Operand::ci32(1), Operand::ci32(2)));
        f.push(end, add(Operand::ci32(3), Operand::ci32(4)));
        f.set_term(e, Term::Jump(mid));
        f.set_term(mid, Term::Jump(end));
        f.set_term(end, Term::Ret);
        run(&mut f);
        verify(&f).unwrap();
        assert_eq!(f.blocks.len(), 1, "everything merges into entry");
        assert_eq!(f.block(f.entry).insts.len(), 2);
    }

    #[test]
    fn self_loop_survives() {
        let mut f = Function::new("k");
        let e = f.entry;
        let l = f.add_block("loop");
        f.set_term(e, Term::Jump(l));
        f.set_term(l, Term::Jump(l));
        run(&mut f);
        verify(&f).unwrap();
        // The loop must still loop.
        let le = f.entry;
        let succs = f.succs(le);
        assert!(!succs.is_empty());
    }
}
